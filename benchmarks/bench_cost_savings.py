"""Fig. 3 — fraction of inference cost saved vs relative cost γ, for
parallelization ρ ∈ {0, 0.5, 1} at a fixed selection rate."""
from __future__ import annotations

import numpy as np

from benchmarks.common import csv_row, time_op
from repro.core.cost_model import fraction_cost_saved


def run(verbose=True):
    sel = 0.6
    k = 3
    gammas = [1 / 2, 1 / 5, 1 / 10, 1 / 50, 1 / 100, 1 / 1000]
    rows = {}
    for rho in (0.0, 0.5, 1.0):
        rows[rho] = [fraction_cost_saved(g, k, rho, sel) for g in gammas]
        if verbose:
            print(f"# rho={rho}: " + " ".join(f"{s:+.3f}" for s in rows[rho]))

    # paper claims: at gamma<=1/50 sequential ≈ parallel; at gamma>=1/5
    # sequential can go NEGATIVE (needs parallelism)
    gap_50 = rows[1.0][3] - rows[0.0][3]
    seq_5 = rows[0.0][1]
    us = time_op(lambda: fraction_cost_saved(0.02, 3, 0.5, 0.6) or 0.0, repeats=50)
    return csv_row(
        "fig3_cost_savings",
        us,
        f"seq_vs_par_gap_at_gamma_1_50={gap_50:.3f};seq_savings_at_gamma_1_5={seq_5:+.3f}",
    )
