"""Fig. 5 + Table 1 — black-box API cascades: ABC's voting rule vs
FrugalGPT-, AutoMix-, and MoT-style baselines under Together.ai pricing.

Baselines are reproduced at the *cost-structure* level (what each method
bills per query): AutoMix adds 8 self-verification samples at the answering
tier; MoT samples the weak model k times for consistency; FrugalGPT runs a
learned scorer that is conservative on hard tasks (modeled as a defer bias).
ABC bills its k members per reached tier (Eq. 3 needs no extra calls).
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import (
    PoolModel, csv_row, sample_pool_logits, skill_for_accuracy, time_op,
)
from repro.core import calibration, deferral
from repro.core.cost_model import API_TIERS, TOGETHER_PRICES

TOKENS = 1000.0  # tokens billed per query


def _price(name):
    return TOGETHER_PRICES[name] * TOKENS / 1e6


@jax.jit
def _vote_preds_score(preds):
    # module-level jit: repeated run() calls re-enter one cache (ABC101/102)
    return deferral.vote_rule_from_preds(preds, 0.67).score


def run(verbose=True):
    tier_accs = [0.74, 0.84, 0.90]
    tier_models = []
    for i, names in API_TIERS.items():
        ms = [
            PoolModel(nm, skill_for_accuracy(tier_accs[i - 1]), _price(nm), seed=i * 10 + j)
            for j, nm in enumerate(names)
        ]
        tier_models.append(ms)
    flat = [m for ms in tier_models for m in ms]
    y, d, logits = sample_pool_logits(flat, 6000, seed=13, difficulty_beta=(1, 3))
    yc, _, logits_c = sample_pool_logits(flat, 400, seed=131, difficulty_beta=(1, 3))

    preds = {m.name: logits[m.name].argmax(-1) for m in flat}
    best_by_tier = [ms[int(np.argmax([(preds[m.name] == y).mean() for m in ms]))] for ms in tier_models]

    # ---- ABC: vote over the tier's members (black-box Eq. 3) -------------
    def abc():
        answered = np.zeros(len(y), bool)
        pred = np.zeros(len(y), np.int64)
        cost = 0.0
        active = np.ones(len(y), bool)
        for i, ms in enumerate(tier_models):
            P = np.stack([preds[m.name] for m in ms])  # (k, n)
            cost += active.sum() * sum(m.flops for m in ms)
            if i == len(tier_models) - 1:
                sel = active
                pred[sel] = P[0][sel]
                break
            Pc = np.stack([logits_c[m.name].argmax(-1) for m in ms])
            oc = deferral.vote_rule_from_preds(jax.numpy.asarray(Pc), 0.0)
            theta, _ = calibration.estimate_threshold(
                np.asarray(oc.score), np.asarray(oc.pred) == yc, epsilon=0.03,
                n_samples=100,
            )
            o = deferral.vote_rule_from_preds(jax.numpy.asarray(P), theta)
            take = active & ~np.asarray(o.defer)
            pred[take] = np.asarray(o.pred)[take]
            active = active & np.asarray(o.defer)
        return pred, cost / len(y)

    # ---- baselines --------------------------------------------------------
    def conf_cascade(extra_calls=0, defer_bias=0.0, name_suffix=""):
        """Single best model per tier + confidence rule (+ billed extras)."""
        pred = np.zeros(len(y), np.int64)
        cost = 0.0
        active = np.ones(len(y), bool)
        for i, m in enumerate(best_by_tier):
            L = logits[m.name]
            cost += active.sum() * m.flops * (1 + extra_calls)
            if i == len(best_by_tier) - 1:
                pred[active] = L.argmax(-1)[active]
                break
            o = deferral.confidence_rule(jax.numpy.asarray(L), 0.75 + defer_bias)
            take = active & ~np.asarray(o.defer)
            pred[take] = np.asarray(o.pred)[take]
            active = active & np.asarray(o.defer)
        return pred, cost / len(y)

    abc_pred, abc_cost = abc()
    frugal_pred, frugal_cost = conf_cascade(extra_calls=0, defer_bias=0.15)  # conservative scorer
    automix_pred, automix_cost = conf_cascade(extra_calls=8)  # 8 self-verify samples
    mot_pred, mot_cost = conf_cascade(extra_calls=3)  # k-sample consistency

    single_cost = best_by_tier[-1].flops
    single_acc = (preds[best_by_tier[-1].name] == y).mean()

    rows = {
        "ABC": (abc_pred, abc_cost),
        "FrugalGPT-like": (frugal_pred, frugal_cost),
        "AutoMix-like": (automix_pred, automix_cost),
        "MoT-like": (mot_pred, mot_cost),
    }
    if verbose:
        print(f"# single-405b: acc={single_acc:.3f} $/q={single_cost:.5f}")
        for nm, (p, c) in rows.items():
            print(f"# {nm:15s} acc={(p == y).mean():.3f} $/q={c:.5f} "
                  f"({single_cost / c:.1f}x cheaper than single)")

    best_baseline_cost = min(frugal_cost, automix_cost, mot_cost)
    P = jax.numpy.asarray(np.stack([preds[m.name] for m in tier_models[0]]))
    us = time_op(_vote_preds_score, P)
    return csv_row(
        "fig5_api_cost",
        us,
        f"abc_vs_best_baseline={best_baseline_cost/abc_cost:.2f}x;abc_vs_single={single_cost/abc_cost:.2f}x;abc_acc={(abc_pred==y).mean():.3f}",
    )
