"""Fig. 8 — cascade length (2–4 levels) × ensemble size (2–5) under
parallel (ρ=1) and sequential (ρ=0) execution.

Also measures the two execution structures on a real (reduced) model: the
serving runtime's vmapped stacked-weights ensemble generation (one XLA
program advances all k members — structural ρ=1) against a serial Python
loop over the members (ρ=0), the regime §4.1 argues parallel hardware
"easily offsets"."""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import (
    PoolModel, csv_row, sample_pool_logits, skill_for_accuracy, time_op,
)
from repro.core import calibration, deferral
from repro.core.cost_model import ensemble_cost


def _cascade(accs, k, rho, n=5000, seed=23):
    flops = [10.0 ** (i + 1) for i in range(len(accs))]
    all_models = []
    for i, a in enumerate(accs):
        all_models += [PoolModel(f"t{i}m{j}", skill_for_accuracy(a), flops[i], seed=i * 10 + j)
                       for j in range(k)]
    y, _, logits = sample_pool_logits(all_models, n, seed=seed)
    yc, _, logits_c = sample_pool_logits(all_models, 400, seed=seed + 1)

    pred = np.zeros(n, np.int64)
    cost = 0.0
    active = np.ones(n, bool)
    for i, a in enumerate(accs):
        names = [f"t{i}m{j}" for j in range(k)]
        tier_cost = ensemble_cost(flops[i], k, rho)
        cost += active.sum() * tier_cost
        L = jax.numpy.asarray(np.stack([logits[nm] for nm in names]))
        if i == len(accs) - 1:
            o = deferral.vote_rule(L, -1.0)
            pred[active] = np.asarray(o.pred)[active]
            break
        Lc = jax.numpy.asarray(np.stack([logits_c[nm] for nm in names]))
        oc = deferral.vote_rule(Lc, 0.0)
        theta, _ = calibration.estimate_threshold(
            np.asarray(oc.score), np.asarray(oc.pred) == yc, epsilon=0.03, n_samples=100
        )
        o = deferral.vote_rule(L, theta)
        take = active & ~np.asarray(o.defer)
        pred[take] = np.asarray(o.pred)[take]
        active &= np.asarray(o.defer)
    return float((pred == y).mean()), cost / n


def run(verbose=True):
    ladders = {2: [0.7, 0.9], 3: [0.7, 0.8, 0.9], 4: [0.65, 0.75, 0.83, 0.9]}
    best = {}
    for rho in (1.0, 0.0):
        for levels, accs in ladders.items():
            # the comparable single model is the TOP model of this ladder
            single_cost = 10.0 ** levels
            for k in (2, 3, 5):
                acc, cost = _cascade(accs, k, rho)
                best.setdefault(rho, []).append(
                    (acc, cost / single_cost, levels, k)
                )
                if verbose:
                    print(f"# rho={rho} levels={levels} k={k}: acc={acc:.3f} "
                          f"relcost={cost/single_cost:.2f}")
    single_acc, _ = _cascade([0.9], 1, 1.0)

    def best_at_budget(rho, rel_budget):
        cands = [a for a, c, _, _ in best[rho] if c <= rel_budget]
        return max(cands) if cands else float("nan")

    d_par = best_at_budget(1.0, 0.6) - single_acc
    d_seq = best_at_budget(0.0, 0.9) - single_acc
    vmap_ms, serial_ms = _measured_rho(verbose=verbose)
    us = time_op(lambda: ensemble_cost(1.0, 3, 0.5), repeats=50)
    return csv_row(
        "fig8_parallelization",
        us,
        f"acc_delta_rho1_at_60pct_cost={d_par:+.3f};acc_delta_rho0_at_90pct_cost={d_seq:+.3f};"
        f"measured_vmap_gen_ms={vmap_ms:.1f};measured_serial_gen_ms={serial_ms:.1f}",
    )


def _measured_rho(k: int = 3, verbose: bool = True):
    """Measured ρ=1 (vmapped one-program ensemble) vs ρ=0 (serial member
    loop) generation on a real reduced model; returns (vmap_ms, serial_ms)
    steady-state per batch."""
    from repro.configs.base import ModelConfig
    from repro.core import ensemble as ens
    from repro.core.cascade import TierSpec
    from repro.models.params import unbox
    from repro.serve import CascadeTier, ServingEngine

    cfg = ModelConfig(
        name="par-bench", family="dense", n_layers=2, d_model=64, d_ff=128,
        vocab_size=128, n_heads=4, n_kv_heads=2, remat=False,
    )
    values, _ = unbox(ens.init_ensemble(cfg, k, jax.random.PRNGKey(0)))
    tier = CascadeTier(cfg, values, TierSpec("t", "vote", 0.5, k=k, cost=1.0))
    engines = [ServingEngine(cfg, ens.take_member(values, i)) for i in range(k)]
    toks = np.random.default_rng(0).integers(0, 128, (16, 16)).astype(np.int32)

    def timed(fn, reps=5):
        fn()  # warmup / compile
        t0 = time.perf_counter()
        for _ in range(reps):
            fn()
        return (time.perf_counter() - t0) / reps * 1e3

    vmap_ms = timed(lambda: tier.generate(toks, 8))
    serial_ms = timed(lambda: [e.generate(toks, 8) for e in engines])
    if verbose:
        print(f"# measured per-batch generation (k={k}): vmapped one-program "
              f"{vmap_ms:.1f} ms vs serial member loop {serial_ms:.1f} ms "
              f"({serial_ms / max(vmap_ms, 1e-9):.2f}x)")
    return vmap_ms, serial_ms
