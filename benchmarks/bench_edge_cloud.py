"""Fig. 4a — edge-to-cloud inference: on-device tier handles agreed samples
locally; only disagreements pay the network delay.

Two accountings, asserted to agree:

* analytic — the §5.2.1 ``EdgeCloudCost`` closed form (delay · defer_rate);
* measured — the same traffic actually routed through the serving runtime:
  ``cascade_apply_routed`` with on-device deferral compaction and a
  ``SimulatedLinkTransport`` edge→cloud hop, which meters the payload
  bytes and per-request link latency that really cross the boundary.

Reports the response-latency reduction vs always-cloud across the paper's
delay grid plus the measured bytes-over-link reduction (the ~14x headline:
only the deferred slice of the batch ever crosses).

Third accounting (wall clock, DESIGN.md §8): the same cascade served
continuously over a REAL-sleep ``AsyncTransport`` link, once blocking on
every hop (serial) and once overlapped (edge decode continues while
deferral payloads are in flight).  Generations and per-hop metered bytes
are asserted identical between the two runs; the reported
``overlap_ratio`` = serial makespan / overlapped makespan (> 1 means link
time really hid behind compute)."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import (
    PoolModel, csv_row, sample_pool_logits, skill_for_accuracy, smoke_mode,
    time_op,
)
from repro.core import calibration, deferral
from repro.core.cascade import TierSpec, cascade_apply_routed
from repro.core.cost_model import EDGE_DELAYS, EdgeCloudCost
from repro.serve.transport import SimulatedLinkTransport


@jax.jit
def _vote_defer(logits):
    # module-level jit: repeated run() calls re-enter one cache (ABC101/102)
    return deferral.vote_rule(logits, 0.67).defer


def _measure_overlap(verbose=True):
    """Drive ``benchmarks.common.measure_overlap`` (serial vs overlapped
    continuous serving over a real-sleep link; generations + metered hops
    asserted identical there) with this bench's edge/cloud tiers, and gate
    the wall-clock result: deferrals must actually occur, some link time
    must be hidden, and the overlap ratio must exceed 1."""
    from benchmarks.common import measure_overlap
    from repro.configs.base import ModelConfig
    from repro.core import ensemble as ens
    from repro.models.params import unbox
    from repro.obs import Observability
    from repro.serve import CascadeServer, CascadeTier, Request

    edge_cfg = ModelConfig(
        name="bench-s", family="dense", n_layers=2, d_model=64, d_ff=128,
        vocab_size=256, n_heads=4, n_kv_heads=2, remat=False,
    )
    cloud_cfg = ModelConfig(
        name="bench-b", family="dense", n_layers=4, d_model=128, d_ff=256,
        vocab_size=256, n_heads=8, n_kv_heads=4, remat=False,
    )
    v_edge, _ = unbox(ens.init_ensemble(edge_cfg, 3, jax.random.PRNGKey(0)))
    v_cloud, _ = unbox(ens.init_ensemble(cloud_cfg, 1, jax.random.PRNGKey(1)))
    # delay stays large relative to the tiny tiers' compute so the serial
    # penalty (>= n_deferrals * delay of pure sleep) dwarfs runner noise —
    # this is why the ratio>1 gate is safe where interpret-mode wall clock
    # was not (PR 4's gate=off rows)
    n_req, max_new, delay = (8, 6, 0.05) if smoke_mode() else (16, 8, 0.05)

    def requests():
        rng = np.random.default_rng(7)
        return [
            Request(tokens=rng.integers(0, 256, 8).astype(np.int32),
                    max_new_tokens=max_new)
            for _ in range(n_req)
        ]

    def build(placement):
        return CascadeServer(
            [
                CascadeTier(edge_cfg, v_edge,
                            TierSpec("edge", "vote", 0.67, k=3, cost=1.0)),
                CascadeTier(cloud_cfg, v_cloud,
                            TierSpec("cloud", "confidence", -1.0, k=1,
                                     cost=50.0)),
            ],
            placement=placement,
        )

    ob = Observability()
    m = measure_overlap(build, requests, delay=delay, obs=ob)
    link = m["link"]
    h_lat = ob.registry.get("serve.request_latency_s")
    assert h_lat.count == n_req  # one latency sample per completed request
    assert link.hops, (
        "overlap measurement needs real deferrals; the independently "
        "initialized edge members disagreeing is seed-deterministic, so an "
        "empty hop list means the tier setup changed"
    )
    if verbose:
        print(
            f"# overlap: {link.total_examples} deferrals x {delay*1e3:.0f}ms "
            f"link = {link.total_latency*1e3:.0f}ms serial link time; "
            f"makespan {m['wall_serial']*1e3:.0f}ms serial -> "
            f"{m['wall_overlap']*1e3:.0f}ms overlapped ({m['ratio']:.2f}x), "
            f"{m['hidden']*1e3:.0f}ms hidden behind edge decode "
            f"(blocked wait {link.total_wait*1e3:.0f}ms)"
        )
    # monotone invariant first (holds under any runner load: more compute
    # can only hide MORE link time), then the headline wall-clock gate
    assert link.total_wait < link.total_latency, \
        "async transport failed to hide any link time"
    assert m["ratio"] > 1.0, (
        f"overlap ratio <= 1: serial {m['wall_serial']:.3f}s vs "
        f"overlapped {m['wall_overlap']:.3f}s"
    )
    lat_ms = (h_lat.percentile(0.50) * 1e3, h_lat.percentile(0.99) * 1e3)
    return m["ratio"], m["hidden"], link.total_latency, lat_ms


def run(verbose=True):
    # edge tier: 3 tiny models (acc .72 each); cloud: big model (acc .90)
    edge = [PoolModel(f"edge{j}", skill_for_accuracy(0.72), 1.0, seed=j) for j in range(3)]
    cloud = [PoolModel("cloud", skill_for_accuracy(0.90), 100.0, seed=9)]
    n = 8000
    y, _, logits = sample_pool_logits(edge + cloud, n, seed=5, difficulty_beta=(1, 3))
    yc, _, logits_c = sample_pool_logits(edge + cloud, 400, seed=55, difficulty_beta=(1, 3))

    L = jax.numpy.asarray(np.stack([logits[m.name] for m in edge]))
    Lc = jax.numpy.asarray(np.stack([logits_c[m.name] for m in edge]))
    out_c = deferral.vote_rule(Lc, 0.0)
    theta, _ = calibration.estimate_threshold(
        np.asarray(out_c.score), np.asarray(out_c.pred) == yc, epsilon=0.03,
        n_samples=100,
    )

    # -- measured: route the batch through the runtime with a simulated link
    # each example carries a feature payload (what the cloud model would
    # need to see); only the compacted deferral slice crosses the transport
    feat_dim = 64
    feats = jax.numpy.asarray(
        np.random.default_rng(6).normal(size=(n, feat_dim)).astype(np.float32)
    )
    L_cloud = jax.numpy.asarray(logits["cloud"])[None]  # (1, n, C)

    fns = [
        lambda b, T=L: T[:, b["idx"]],
        lambda b, T=L_cloud: T[:, b["idx"]],
    ]
    specs = [
        TierSpec("edge", "vote", theta, k=3, cost=1.0),
        TierSpec("cloud", "confidence", -1.0, k=1, cost=100.0),
    ]

    # routing, deferral counts, and bytes are delay-independent: route the
    # batch ONCE through a unit-delay link, then sweep the delay grid over
    # the metered hop counts (each deferred request experiences the hop)
    link = SimulatedLinkTransport(delay=1.0)
    res = cascade_apply_routed(
        fns, specs,
        {"idx": np.arange(n), "payload": feats},
        pad_to=8, transport=link, hosts=["edge0", "cloud0"],
    )
    n_def = int(res.tier_counts[1])
    defer_rate = n_def / n
    assert link.total_examples == n_def
    # metered per-request hop count at unit delay == latency multiplier
    unit_lat_sum = sum(h.n_examples * h.latency for h in link.hops)

    row_bytes = feat_dim * 4 + 4 + 4  # payload + idx + routing index map
    always_cloud_bytes = n * row_bytes
    byte_reduction = always_cloud_bytes / max(1, link.total_bytes)

    reductions = {}
    for name, delay in EDGE_DELAYS.items():
        cm = EdgeCloudCost(delay=delay)
        abc_lat = cm.mean_latency(defer_rate)
        cloud_lat = cm.mean_latency(1.0)  # every request crosses the network
        reductions[name] = cloud_lat / abc_lat

        meas_lat = cm.local + unit_lat_sum * delay / n
        assert abs(meas_lat - abc_lat) <= 0.02 * abc_lat + 1e-9, (
            f"{name}: measured {meas_lat} vs analytic {abc_lat}"
        )
        if verbose:
            print(
                f"# delay={name}({delay}s): ABC {abc_lat*1e3:.3f}ms vs cloud "
                f"{cloud_lat*1e3:.3f}ms -> {reductions[name]:.1f}x | link "
                f"{link.total_bytes/1e3:.1f}kB ({link.total_examples} deferred) "
                f"vs always-cloud {always_cloud_bytes/1e3:.1f}kB -> "
                f"{byte_reduction:.1f}x"
            )

    # accuracy from the routed run (tier answers already merged)
    acc_abc = float((res.pred == y).mean())
    acc_cloud = float((logits["cloud"].argmax(-1) == y).mean())

    # -- wall clock: serial vs overlapped makespan over a real-sleep link
    overlap_ratio, hidden_s, serial_link_s, (p50_ms, p99_ms) = \
        _measure_overlap(verbose)

    us = time_op(_vote_defer, L)
    worst = reductions["large"]
    # transport/latency keys carry fully-qualified registry names (the
    # edge→cloud link's hosts are edge0/cloud0); perf_compare.NAME_MAP
    # keeps old-name baselines gating
    return csv_row(
        "fig4a_edge_cloud",
        us,
        f"comm_cost_reduction_large_delay={worst:.1f}x;"
        f"bytes_over_link_reduction={byte_reduction:.1f}x;"
        f"overlap_ratio={overlap_ratio:.2f}x;"
        f"transport.edge0_cloud0.hidden_ms={hidden_s*1e3:.0f};"
        f"serve.request_latency_s.p50_ms={p50_ms:.0f};"
        f"serve.request_latency_s.p99_ms={p99_ms:.0f};"
        f"acc_abc={acc_abc:.3f};acc_cloud={acc_cloud:.3f}",
    )
