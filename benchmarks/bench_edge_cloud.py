"""Fig. 4a — edge-to-cloud inference: on-device tier handles agreed samples
locally; only disagreements pay the network delay.  Reports the mean
response-latency reduction vs always-cloud across the paper's delay grid."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import (
    PoolModel, csv_row, sample_pool_logits, skill_for_accuracy, time_op,
)
from repro.core import calibration, deferral
from repro.core.cost_model import EDGE_DELAYS, EdgeCloudCost


def run(verbose=True):
    # edge tier: 3 tiny models (acc .72 each); cloud: big model (acc .90)
    edge = [PoolModel(f"edge{j}", skill_for_accuracy(0.72), 1.0, seed=j) for j in range(3)]
    cloud = [PoolModel("cloud", skill_for_accuracy(0.90), 100.0, seed=9)]
    y, _, logits = sample_pool_logits(edge + cloud, 8000, seed=5, difficulty_beta=(1, 3))
    yc, _, logits_c = sample_pool_logits(edge + cloud, 400, seed=55, difficulty_beta=(1, 3))

    L = jax.numpy.asarray(np.stack([logits[m.name] for m in edge]))
    Lc = jax.numpy.asarray(np.stack([logits_c[m.name] for m in edge]))
    out_c = deferral.vote_rule(Lc, 0.0)
    theta, _ = calibration.estimate_threshold(
        np.asarray(out_c.score), np.asarray(out_c.pred) == yc, epsilon=0.03,
        n_samples=100,
    )
    out = deferral.vote_rule(L, theta)
    defer = np.asarray(out.defer)
    pred = np.where(defer, logits["cloud"].argmax(-1), np.asarray(out.pred))
    acc_abc = float((pred == y).mean())
    acc_cloud = float((logits["cloud"].argmax(-1) == y).mean())

    reductions = {}
    for name, delay in EDGE_DELAYS.items():
        cm = EdgeCloudCost(delay=delay)
        abc_lat = cm.mean_latency(defer.mean())
        cloud_lat = cm.mean_latency(1.0)  # every request crosses the network
        reductions[name] = cloud_lat / abc_lat
        if verbose:
            print(f"# delay={name}({delay}s): ABC {abc_lat*1e3:.3f}ms vs cloud "
                  f"{cloud_lat*1e3:.3f}ms -> {reductions[name]:.1f}x")

    us = time_op(jax.jit(lambda l: deferral.vote_rule(l, 0.67).defer), L)
    worst = reductions["large"]
    return csv_row(
        "fig4a_edge_cloud",
        us,
        f"comm_cost_reduction_large_delay={worst:.1f}x;acc_abc={acc_abc:.3f};acc_cloud={acc_cloud:.3f}",
    )
