"""Shared benchmark utilities.

The paper's tables compare cascades built from a *pool of pretrained models*
of varying accuracy/cost.  Offline, we reproduce each table's mechanism with
a calibrated synthetic pool: examples carry a latent difficulty d ~ U(0,1);
a model of skill s answers correctly with probability sigmoid(a·(s - d) + b),
and its logits express confidence correlated with its margin — so ensembles
of equal-skill models disagree exactly on the hard tail, which is the
structure ABC exploits.  Every bench also times its hot op on real arrays
(the `us_per_call` column)."""
from __future__ import annotations

import dataclasses
import time
from typing import List, Sequence

import numpy as np


@dataclasses.dataclass
class PoolModel:
    name: str
    skill: float  # ~ accuracy level
    flops: float  # per-example cost
    seed: int = 0


def accuracy_of(skill: float, sharp: float = 6.0) -> float:
    d = np.linspace(0, 1, 2001)
    return float(np.mean(1 / (1 + np.exp(-sharp * (skill - d)))))


def skill_for_accuracy(target: float) -> float:
    lo, hi = -1.0, 3.0
    for _ in range(60):
        mid = (lo + hi) / 2
        if accuracy_of(mid) < target:
            lo = mid
        else:
            hi = mid
    return (lo + hi) / 2


def sample_pool_logits(
    models: Sequence[PoolModel],
    n: int,
    n_classes: int = 10,
    seed: int = 0,
    sharp: float = 6.0,
    difficulty_beta=None,
):
    """Returns (y (n,), difficulty (n,), logits dict name -> (n, C)).

    difficulty_beta=(a, b) skews the difficulty distribution; the paper's
    deployment scenarios assume easy-dominated traffic (that is ABC's
    premise — Table 5 measures 52–93% of samples exiting at tier 1), which
    (1, 3) approximates.  Default is uniform (the hardest case for ABC)."""
    rng = np.random.default_rng(seed)
    y = rng.integers(0, n_classes, n)
    d = rng.beta(*difficulty_beta, n) if difficulty_beta else rng.random(n)
    import zlib

    out = {}
    for m in models:
        # zlib.crc32: stable across processes (builtin hash() is randomized)
        mr = np.random.default_rng(seed * 7919 + m.seed + zlib.crc32(m.name.encode()) % 1000)
        p_correct = 1 / (1 + np.exp(-sharp * (m.skill - d)))
        correct = mr.random(n) < p_correct
        logits = mr.normal(0, 1, (n, n_classes)).astype(np.float32)
        # confidence scales with margin from the decision boundary
        conf = 1.5 + 4.0 * np.abs(m.skill - d)
        wrong = (y + 1 + mr.integers(0, n_classes - 1, n)) % n_classes
        target = np.where(correct, y, wrong)
        logits[np.arange(n), target] += conf
        out[m.name] = logits
    return y, d, out


def smoke_mode() -> bool:
    """CI fast mode (benchmarks/run.py --smoke): every bench still runs end
    to end, but timing loops shrink to a correctness-only footprint."""
    import os

    return os.environ.get("REPRO_BENCH_SMOKE", "0") == "1"


def time_op(fn, *args, repeats: int = 20, warmup: int = 3) -> float:
    """Median wall time in microseconds per call."""
    import jax

    if smoke_mode():
        repeats, warmup = min(repeats, 2), min(warmup, 1)

    def _block(r):
        try:
            jax.block_until_ready(r)
        except Exception:
            pass

    for _ in range(warmup):
        _block(fn(*args))
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        _block(fn(*args))
        times.append(time.perf_counter() - t0)
    return float(np.median(times) * 1e6)


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"


def measure_overlap(build_server, make_requests, *, delay, n_slots=4,
                    max_seq=32, obs=None):
    """Shared serial-vs-overlapped serving harness (DESIGN.md §8), used by
    bench_edge_cloud and bench_serving so the asserted invariants cannot
    drift apart (examples/edge_to_cloud.py keeps a deliberately inline copy
    as teaching code).

    ``build_server(placement) -> CascadeServer``; ``make_requests() ->
    [Request]`` must return a FRESH, identical request set per call.  Serves
    three times over an edge→cloud link — "sim" (compile warmup, off the
    clock), "serial" (real sleeps, every hop blocks), "async" (real sleeps,
    hops overlap edge decode) — and ASSERTS the equivalence contract:
    identical greedy generations + answering tiers, identical metered hop
    lists.  Returns a dict with both makespans, the overlapped link, and
    ``ratio`` = serial/overlapped makespan (1.0 when no hop ever crossed —
    nothing to overlap, nothing to divide).  Wall-clock GATES (ratio > 1,
    hop-count floors) are the caller's call: they know their deferral
    structure and flake budget.

    ``obs`` (a ``repro.obs.Observability``) is attached to the OVERLAPPED
    run only — the representative serving mode — so the caller's registry
    picks up ``serve.request_latency_s`` p50/p99, the per-tier cascade
    counters, and the ``transport.*`` mirror (plus a Perfetto trace when
    ``obs.tracer`` is enabled) for exactly one serve of the request set."""
    import time as _time

    from repro.serve import edge_cloud

    def serve(link_kind, obs=None):
        placement = edge_cloud(delay=delay, link=link_kind)
        server = build_server(placement)
        t0 = _time.perf_counter()
        done = server.serve_continuous(make_requests(), n_slots=n_slots,
                                       max_seq=max_seq, obs=obs)
        return done, _time.perf_counter() - t0, placement.link(0)

    serve("sim")
    done_ser, wall_ser, link_ser = serve("serial")
    done_ovl, wall_ovl, link_ovl = serve("async", obs=obs)

    key = lambda done: {tuple(r.tokens): (r.tier, tuple(r.output))
                        for r in done}
    assert key(done_ser) == key(done_ovl), \
        "overlap changed generations or answering tiers"
    hops = lambda link: [(h.src, h.dst, h.n_examples, h.payload_bytes)
                         for h in link.hops]
    assert hops(link_ser) == hops(link_ovl), \
        "overlap changed the metered hop list"

    return {
        "wall_serial": wall_ser,
        "wall_overlap": wall_ovl,
        "link": link_ovl,
        "ratio": (wall_ser / wall_ovl) if link_ovl.hops else 1.0,
        "hidden": link_ovl.total_latency - link_ovl.total_wait,
    }
