"""Fig. 6 — threshold-estimation stability: θ̂ vs number of calibration
samples, across models of different accuracy."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import (
    PoolModel, csv_row, sample_pool_logits, skill_for_accuracy, time_op,
)
from repro.core import calibration, deferral


def run(verbose=True):
    drifts = []
    for acc in (0.45, 0.6, 0.75, 0.86):
        ms = [PoolModel(f"a{acc}m{j}", skill_for_accuracy(acc), 1.0, seed=j) for j in range(3)]
        y, _, logits = sample_pool_logits(ms, 4000, seed=17)
        L = jax.numpy.asarray(np.stack([logits[m.name] for m in ms]))
        # the continuous flavor (Eq. 4 mean majority score) — the vote
        # fraction is quantized to k+1 levels, so its "drift" is one quantum
        out = deferral.score_rule(L, 0.0)
        curve = calibration.threshold_stability_curve(
            np.asarray(out.score), np.asarray(out.pred) == y, epsilon=0.03,
            sample_sizes=(100, 200, 400, 800, 1600, 3200),
        )
        thetas = [c["theta"] for c in curve]
        drift = max(abs(t - thetas[-1]) for t in thetas)
        drifts.append(drift)
        if verbose:
            print(f"# acc={acc}: theta(n) = " + " ".join(f"{t:.3f}" for t in thetas)
                  + f"  (drift {drift:.3f})")

    scores = np.random.default_rng(0).random(3200)
    correct = np.random.default_rng(1).random(3200) < scores
    us = time_op(
        lambda: calibration.estimate_threshold(scores, correct, 0.03, n_samples=100)[0],
        repeats=10,
    )
    return csv_row(
        "fig6_threshold_stability",
        us,
        f"max_theta_drift_100_vs_3200={max(drifts):.3f}",
    )
