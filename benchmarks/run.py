"""Benchmark harness: one entry per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only NAME]

Prints ``name,us_per_call,derived`` CSV rows (plus '#' commentary lines).
"""
from __future__ import annotations

import argparse
import sys
import traceback

BENCHES = [
    "bench_pareto",            # Fig 2
    "bench_cost_savings",      # Fig 3
    "bench_edge_cloud",        # Fig 4a
    "bench_gpu_rental",        # Fig 4b + Tables 4/5
    "bench_api_cost",          # Fig 5 + Table 1
    "bench_threshold",         # Fig 6 (App B)
    "bench_selection_rate",    # Fig 7 (App C)
    "bench_parallelization",   # Fig 8 (App E.1)
    "bench_kernels",           # kernels micro-bench
    "bench_serving",           # live cascade serving (Table 5 counterpart)
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args()

    names = [b for b in BENCHES if args.only is None or args.only in b]
    print("name,us_per_call,derived")
    failed = []
    for name in names:
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        try:
            row = mod.run(verbose=not args.quiet)
            print(row, flush=True)
        except Exception:
            failed.append(name)
            traceback.print_exc()
            print(f"{name},nan,ERROR", flush=True)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
