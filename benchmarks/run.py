"""Benchmark harness: one entry per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only NAME] [--smoke] [--json F]

Prints ``name,us_per_call,derived`` CSV rows (plus '#' commentary lines).
Exits nonzero if ANY bench raises (each failure still prints its traceback
and an ERROR row, so one rotten bench cannot hide behind the others).

``--smoke``: fast verbose-off mode for CI — sets REPRO_BENCH_SMOKE=1
(benchmarks.common trims timing repeats) and implies --quiet.  Smoke
numbers are NOT representative timings; the mode exists so every scenario
bench is executed on every push and cannot silently rot.

``--json FILE``: additionally persist every row as
``{"rows": {name: {"us_per_call": ..., "derived": ...}}, "failed": [...]}``
— CI's bench-smoke job uploads this as an artifact and gates it against the
committed baseline via ``tools/perf_compare.py`` (the perf trajectory).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import traceback

BENCHES = [
    "bench_pareto",            # Fig 2
    "bench_cost_savings",      # Fig 3
    "bench_edge_cloud",        # Fig 4a
    "bench_gpu_rental",        # Fig 4b + Tables 4/5
    "bench_api_cost",          # Fig 5 + Table 1
    "bench_threshold",         # Fig 6 (App B)
    "bench_selection_rate",    # Fig 7 (App C)
    "bench_parallelization",   # Fig 8 (App E.1)
    "bench_kernels",           # kernels micro-bench
    "bench_serving",           # live cascade serving (Table 5 counterpart)
]


def parse_rows(block: str) -> dict:
    """``name,us_per_call,derived`` lines -> {name: {us_per_call, derived}}
    ('#' commentary lines and malformed rows are skipped; derived keeps any
    embedded commas intact via maxsplit)."""
    rows = {}
    for line in str(block).splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        parts = line.split(",", 2)
        if len(parts) < 2:
            continue
        try:
            us = float(parts[1])
        except ValueError:
            continue
        rows[parts[0]] = {
            "us_per_call": us,
            "derived": parts[2] if len(parts) > 2 else "",
        }
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--quiet", action="store_true")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--json", default=None, metavar="FILE",
                    help="persist rows to FILE for the perf_compare gate")
    args = ap.parse_args()
    if args.smoke:
        # must land in the environment BEFORE bench modules import
        os.environ["REPRO_BENCH_SMOKE"] = "1"
        args.quiet = True

    names = [b for b in BENCHES if args.only is None or args.only in b]
    print("name,us_per_call,derived")
    failed = []
    results = {}
    for name in names:
        try:
            # import inside the guard: an import-time failure is just as
            # much a rotten bench as a run()-time one
            mod = __import__(f"benchmarks.{name}", fromlist=["run"])
            row = mod.run(verbose=not args.quiet)
            print(row, flush=True)
            results.update(parse_rows(row))
        except Exception:
            failed.append(name)
            traceback.print_exc()
            print(f"{name},nan,ERROR", flush=True)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"rows": results, "failed": failed}, f, indent=2, sort_keys=True)
            f.write("\n")
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
