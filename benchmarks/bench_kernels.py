"""Kernel micro-benchmarks (CPU wall time of the XLA path vs the naive
oracle — on TPU the Pallas path replaces the XLA path; the ratio shows the
structural win of the chunked forms) + roofline-relevant derived stats.

The starts sweep reports the block-skip win of the per-row starts
carve-out on a ragged left-padded batch.  The headline ratio is the
structural surviving/total block count from the kernels' own skip
predicate (``starts_block_counts`` — deterministic, and the fraction that
carries to the TPU lowering); interpret-mode wall clock for skip vs
no-skip rides along but is tagged ``gate=off`` (noise-prone on shared
CPU runners, excluded from the perf_compare baseline gate)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row, time_op
from repro.kernels.agreement import ops as agree_ops, ref as agree_ref
from repro.kernels.decode_attention import kernel as dec_kernel
from repro.kernels.decode_attention import ops as dec_ops, ref as dec_ref
from repro.kernels.flash_attention import kernel as flash_kernel
from repro.kernels.flash_attention import ops as flash_ops, ref as flash_ref
from repro.kernels.mamba2_ssd import ops as ssd_ops, ref as ssd_ref
from repro.kernels.rwkv6_wkv import ops as wkv_ops, ref as wkv_ref


# ---------------------------------------------------------------------------
# compile-once benchmark programs: jitted at MODULE level so repeated run()
# invocations (perf_compare reruns, the harness smoke test) re-enter one jit
# cache instead of re-tracing per call (abclint ABC101/ABC102)
# ---------------------------------------------------------------------------


@jax.jit
def _flash_chunk(q, k, v):
    return flash_ops.flash_attention(q, k, v, causal=True)


@jax.jit
def _flash_oracle(q, k, v):
    return flash_ref.attention_ref(q, k, v, causal=True)


@functools.partial(jax.jit, static_argnames=("length",))
def _decode_sweep(q, k, v, *, length):
    return dec_ops.decode_attention(q, k, v, length)


@functools.partial(jax.jit, static_argnames=("chunk",))
def _ssd_chunk(x, dt, A, B, C, *, chunk):
    return ssd_ops.ssd(x, dt, A, B, C, chunk=chunk)


@jax.jit
def _ssd_oracle(x, dt, A, B, C):
    return ssd_ref.ssd_ref(x, dt, A, B, C)


@functools.partial(jax.jit, static_argnames=("chunk",))
def _wkv_chunk(r, k, v, w, u, *, chunk):
    return wkv_ops.wkv6(r, k, v, w, u, chunk=chunk)


@jax.jit
def _wkv_oracle(r, k, v, w, u):
    return wkv_ref.wkv6_ref(r, k, v, w, u)


@jax.jit
def _agreement_vote_frac(logits):
    return agree_ops.agreement(logits)["vote_frac"]


@jax.jit
def _agreement_vote_frac_oracle(logits):
    return agree_ref.agreement_ref(logits)["vote_frac"]


def run(verbose=True):
    rows = []
    ks = jax.random.split(jax.random.PRNGKey(0), 8)

    # flash attention
    B, S, H, KVH, hd = 1, 1024, 8, 2, 64
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.bfloat16)
    k = jax.random.normal(ks[1], (B, S, KVH, hd), jnp.bfloat16)
    v = jax.random.normal(ks[2], (B, S, KVH, hd), jnp.bfloat16)
    us_c = time_op(_flash_chunk, q, k, v, repeats=5)
    us_r = time_op(_flash_oracle, q, k, v, repeats=5)
    rows.append(csv_row("kernel_flash_attention_1k", us_c, f"ref_us={us_r:.0f};speedup={us_r/us_c:.2f}x"))

    # decode attention over a 16k cache
    S2 = 16384
    kc = jax.random.normal(ks[3], (4, S2, KVH, hd), jnp.bfloat16)
    vc = jax.random.normal(ks[4], (4, S2, KVH, hd), jnp.bfloat16)
    qd = jax.random.normal(ks[5], (4, 1, H, hd), jnp.bfloat16)
    us_d = time_op(functools.partial(_decode_sweep, length=S2), qd, kc, vc, repeats=5)
    rows.append(csv_row("kernel_decode_attention_16k", us_d, f"bytes_swept={kc.nbytes*2}"))

    # mamba2 ssd: chunked vs step-scan oracle
    Bm, Sm, Hm, P, G, N = 2, 512, 4, 64, 1, 64
    x = jax.random.normal(ks[6], (Bm, Sm, Hm, P))
    dt = jax.nn.softplus(jax.random.normal(ks[7], (Bm, Sm, Hm))) * 0.5
    A = -jnp.exp(jax.random.normal(ks[0], (Hm,)) * 0.3)
    Bmat = jax.random.normal(ks[1], (Bm, Sm, G, N)) * 0.5
    Cmat = jax.random.normal(ks[2], (Bm, Sm, G, N)) * 0.5
    us_sc = time_op(functools.partial(_ssd_chunk, chunk=128), x, dt, A, Bmat, Cmat, repeats=5)
    us_sr = time_op(_ssd_oracle, x, dt, A, Bmat, Cmat, repeats=5)
    rows.append(csv_row("kernel_mamba2_ssd_512", us_sc, f"stepscan_us={us_sr:.0f};speedup={us_sr/us_sc:.2f}x"))

    # rwkv6 wkv: chunked vs step-scan oracle
    r = jax.random.normal(ks[3], (2, 512, 4, 64))
    kk = jax.random.normal(ks[4], (2, 512, 4, 64))
    vv = jax.random.normal(ks[5], (2, 512, 4, 64))
    lw = -jnp.exp(jax.random.normal(ks[6], (2, 512, 4, 64)) * 0.5)
    u = jax.random.normal(ks[7], (4, 64)) * 0.5
    us_wc = time_op(functools.partial(_wkv_chunk, chunk=32), r, kk, vv, lw, u, repeats=5)
    us_wr = time_op(_wkv_oracle, r, kk, vv, lw, u, repeats=5)
    rows.append(csv_row("kernel_rwkv6_wkv_512", us_wc, f"stepscan_us={us_wr:.0f};speedup={us_wr/us_wc:.2f}x"))

    # starts-aware flash prefill: block-skip speedup on ragged left-padding.
    # The headline number is STRUCTURAL — surviving/total kernel block pairs
    # from the kernel's own `relevant` predicate (starts_block_counts), which
    # is what carries to the TPU lowering.  Wall clock is the interpret-mode
    # kernel (skip vs no-skip) and is noise-prone on a shared CPU, so the
    # rows are tagged gate=off and excluded from the perf_compare baseline
    # gate (skip on/off outputs are bitwise identical — tested).
    Bs, Ss, Hs, hds = 4, 512, 2, 64
    qs = jax.random.normal(ks[0], (Bs, Hs, Ss, hds), jnp.float32)
    kv = jax.random.normal(ks[1], (Bs, Hs, Ss, hds), jnp.float32)
    vs = jax.random.normal(ks[2], (Bs, Hs, Ss, hds), jnp.float32)
    starts = jnp.asarray([0, 192, 320, 448], jnp.int32)  # 3/4 rows left-padded
    fb_skip, fb_all = flash_kernel.starts_block_counts(
        Ss, Ss, np.asarray(starts), causal=True, block_q=128, block_k=128
    )
    fk = functools.partial(
        flash_kernel.flash_attention_bhsd, causal=True,
        block_q=128, block_k=128, interpret=True,
    )
    us_skip = time_op(functools.partial(fk, skip_pad_blocks=True), qs, kv, vs, starts, repeats=5)
    us_nosk = time_op(functools.partial(fk, skip_pad_blocks=False), qs, kv, vs, starts, repeats=5)
    rows.append(csv_row(
        "kernel_flash_starts_ragged_prefill", us_skip,
        f"block_skip_speedup={fb_all/fb_skip:.2f}x;blocks={fb_skip}/{fb_all}"
        f";noskip_us={us_nosk:.0f};gate=off",
    ))

    # starts-aware decode: cache blocks below each row's start are skipped
    S3 = 4096
    kc3 = jax.random.normal(ks[3], (4, 1, S3, hds), jnp.float32)
    vc3 = jax.random.normal(ks[4], (4, 1, S3, hds), jnp.float32)
    qd3 = jax.random.normal(ks[5], (4, 1, 4, hds), jnp.float32)
    cur3 = jnp.full((4,), S3, jnp.int32)
    dstarts = jnp.asarray([0, 1024, 2048, 3584], jnp.int32)
    db_skip, db_all = dec_kernel.starts_block_counts(
        S3, np.asarray(cur3), np.asarray(dstarts), block_k=512
    )
    dk = functools.partial(dec_kernel.decode_attention_bkgd, block_k=512, interpret=True)
    us_dskip = time_op(functools.partial(dk, skip_pad_blocks=True), qd3, kc3, vc3, cur3, dstarts, repeats=5)
    us_dnosk = time_op(functools.partial(dk, skip_pad_blocks=False), qd3, kc3, vc3, cur3, dstarts, repeats=5)
    rows.append(csv_row(
        "kernel_decode_starts_ragged_4k", us_dskip,
        f"block_skip_speedup={db_all/db_skip:.2f}x;blocks={db_skip}/{db_all}"
        f";noskip_us={us_dnosk:.0f};gate=off",
    ))

    # agreement reduce over a 32k vocab
    logits = jax.random.normal(ks[0], (3, 64, 32768))
    us_a = time_op(_agreement_vote_frac, logits, repeats=5)
    us_ar = time_op(_agreement_vote_frac_oracle, logits, repeats=5)
    rows.append(csv_row("kernel_agreement_32kvocab", us_a, f"ref_us={us_ar:.0f}"))

    if verbose:
        for r_ in rows:
            print("#", r_)
    return "\n".join(rows)
