"""Fig. 2 — Pareto curves: ABC vs confidence-based cascades (WoC) vs best
single models, accuracy vs FLOPs, on the calibrated synthetic pool."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import (
    PoolModel, csv_row, sample_pool_logits, skill_for_accuracy, time_op,
)
from repro.core import calibration, deferral
from repro.kernels.agreement import ops as agree_ops


@jax.jit
def _agreement_vote_frac(logits):
    # module-level jit: repeated run() calls re-enter one cache (ABC101/102)
    return agree_ops.agreement(logits)["vote_frac"]


def _pool():
    # FLOPs ~ exponential in accuracy (paper Fig. 1: scaling-law costs)
    accs = [0.55, 0.65, 0.75, 0.83, 0.90]
    return [
        PoolModel(f"m{i}", skill_for_accuracy(a), flops=10.0 ** (i + 1), seed=i)
        for i, a in enumerate(accs)
    ]


def _acc(pred, y):
    return float((pred == y).mean())


def run(verbose=True):
    models = _pool()
    y, d, logits = sample_pool_logits(models, 6000, seed=3)
    yc, dc, logits_c = sample_pool_logits(models, 600, seed=11)  # calibration

    singles = [( _acc(logits[m.name].argmax(-1), y), m.flops) for m in models]

    def abc_point(lo, hi, k=3):
        """2-level ABC: k-ensemble of models[lo] -> models[hi]."""
        ens_names = [models[lo].name] * 1  # same-skill members, distinct seeds
        ens_models = [
            PoolModel(f"e{j}", models[lo].skill, models[lo].flops, seed=100 + j)
            for j in range(k)
        ]
        _, _, el = sample_pool_logits(ens_models, len(y), seed=3)
        _, _, el_c = sample_pool_logits(ens_models, len(yc), seed=11)
        L = np.stack([el[m.name] for m in ens_models])
        Lc = np.stack([el_c[m.name] for m in ens_models])
        out_c = deferral.vote_rule(jax.numpy.asarray(Lc), 0.0)
        theta, _ = calibration.estimate_threshold(
            np.asarray(out_c.score), np.asarray(out_c.pred) == yc, epsilon=0.03,
            n_samples=100,
        )
        out = deferral.vote_rule(jax.numpy.asarray(L), theta)
        defer = np.asarray(out.defer)
        pred = np.where(defer, logits[models[hi].name].argmax(-1), np.asarray(out.pred))
        # rho=1: ensemble costs one member's flops (parallel)
        flops = models[lo].flops + defer.mean() * models[hi].flops
        return _acc(pred, y), flops

    def woc_point(lo, hi, theta):
        out = deferral.confidence_rule(jax.numpy.asarray(logits[models[lo].name]), theta)
        defer = np.asarray(out.defer)
        pred = np.where(defer, logits[models[hi].name].argmax(-1), np.asarray(out.pred))
        return _acc(pred, y), models[lo].flops + defer.mean() * models[hi].flops

    abc_curve = [abc_point(i, 4) for i in range(4)]
    woc_curve = [woc_point(i, 4, t) for i in range(4) for t in (0.6, 0.8, 0.9, 0.95)]
    best_single = singles[-1]

    # derived: accuracy delta of ABC vs best single at <= 70% of its FLOPs
    cheap = [a for a, f in abc_curve if f <= best_single[1] * 0.7]
    delta = (max(cheap) - best_single[0]) if cheap else float("nan")

    # the hot op: the agreement reduce itself
    E, B, V = 3, 256, 8192
    big = jax.numpy.asarray(np.random.default_rng(0).normal(size=(E, B, V)).astype(np.float32))
    us = time_op(_agreement_vote_frac, big)

    if verbose:
        for (a, f) in singles:
            print(f"# single acc={a:.3f} flops={f:.0f}")
        for (a, f) in abc_curve:
            print(f"# ABC    acc={a:.3f} flops={f:.0f}")
        woc_best = {}
        for (a, f) in woc_curve:
            woc_best[round(f, -1)] = max(woc_best.get(round(f, -1), 0), a)
    return csv_row(
        "fig2_pareto",
        us,
        f"abc_acc_delta_at_70pct_flops={delta:+.3f};best_single={best_single[0]:.3f}",
    )
