"""Fig. 7 — existence of safe deferral rules: selection rate as a function
of ensemble accuracy for error tolerances ε ∈ {1%, 3%, 5%}."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import (
    PoolModel, csv_row, sample_pool_logits, skill_for_accuracy, time_op,
)
from repro.core import calibration, deferral


def run(verbose=True):
    accs = (0.5, 0.6, 0.7, 0.8, 0.88)
    table = {}
    for eps in (0.01, 0.03, 0.05):
        row = []
        for acc in accs:
            ms = [PoolModel(f"m{j}", skill_for_accuracy(acc), 1.0, seed=j) for j in range(3)]
            y, _, logits = sample_pool_logits(ms, 5000, seed=19)
            L = jax.numpy.asarray(np.stack([logits[m.name] for m in ms]))
            out = deferral.vote_rule(L, 0.0)
            theta, info = calibration.estimate_threshold(
                np.asarray(out.score), np.asarray(out.pred) == y, epsilon=eps
            )
            row.append(info["selection_rate"])
        table[eps] = row
        if verbose:
            print(f"# eps={eps:.0%}: sel = " + " ".join(f"{s:.2f}" for s in row))

    # paper: selection rates grow with accuracy and with laxer epsilon
    mono_acc = all(a <= b + 0.02 for a, b in zip(table[0.05], table[0.05][1:]))
    sel_top_5 = table[0.05][-1]
    sel_top_1 = table[0.01][-1]
    us = time_op(lambda: calibration.selection_rate(np.linspace(0, 1, 5000), 0.6), repeats=50)
    return csv_row(
        "fig7_selection_rates",
        us,
        f"sel_at_top_acc_eps5={sel_top_5:.2f};eps1={sel_top_1:.2f};monotone_in_acc={mono_acc}",
    )
