"""Serving-engine benchmark: real (reduced) models end to end — cascade
classify/generate throughput and per-tier routing on the mixture task (the
live counterpart of Table 5's exit-fraction breakdown).

Warmup (first call, pays tracing + XLA compilation) is reported separately
from steady-state per-batch latency: the compile-once runtime means steady
state re-enters the jit cache with zero new traces, which this bench
asserts via ``repro.serve.engine.trace_count``.

Also measures the cross-host continuous-serving overlap (DESIGN.md §8):
the same cascade behind a real-sleep ``AsyncTransport`` edge→cloud link,
serial (blocking hops) vs overlapped (hops drain at admission points) —
reported as ``overlap_ratio`` = serial / overlapped makespan, with
generations asserted identical.

Block-paged KV pools (DESIGN.md §10) are gated here too: at the HBM
budget of a dense 4-slot cache, the paged pool must carry 4x the resident
slots on mixed-length traffic with zero forced completions, cascade
generations must be bitwise-identical paged vs dense, and the E-fold
shared-prefix saving (one page table across all tier member planes) is
reported in MB of pool writes skipped."""
from __future__ import annotations

import math
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row, smoke_mode
from repro.obs import Observability, Tracer, validate_trace
from repro.configs.base import ModelConfig
from repro.core import ensemble as ens
from repro.core.cascade import TierSpec
from repro.models.params import unbox
from repro.serve import CascadeServer, CascadeTier, Request, ServingEngine
from repro.serve.engine import trace_count

SMALL = ModelConfig(
    name="bench-s", family="dense", n_layers=2, d_model=64, d_ff=128,
    vocab_size=256, n_heads=4, n_kv_heads=2, remat=False,
)
BIG = ModelConfig(
    name="bench-b", family="dense", n_layers=4, d_model=128, d_ff=256,
    vocab_size=256, n_heads=8, n_kv_heads=4, remat=False,
)


def _timed(fn, reps: int = 5):
    """Returns (warmup_s, steady_s_per_call, last_result)."""
    t0 = time.perf_counter()
    res = fn()
    warmup = time.perf_counter() - t0
    t0 = time.perf_counter()
    for _ in range(reps):
        res = fn()
    steady = (time.perf_counter() - t0) / reps
    return warmup, steady, res


def run(verbose=True):
    v1, _ = unbox(ens.init_ensemble(SMALL, 3, jax.random.PRNGKey(0)))
    one = ens.take_member(v1, 0)
    same = jax.tree.map(lambda x: jnp.stack([x, x, x]), one)  # agreeing tier
    v2, _ = unbox(ens.init_ensemble(BIG, 1, jax.random.PRNGKey(1)))
    server = CascadeServer([
        CascadeTier(SMALL, same, TierSpec("t1", "vote", 0.9, k=3, cost=1.0)),
        CascadeTier(BIG, v2, TierSpec("t2", "confidence", -1.0, k=1, cost=30.0)),
    ])
    toks = np.random.default_rng(0).integers(0, 256, (64, 32)).astype(np.int32)

    warm_c, steady_c, res = _timed(lambda: server.classify(toks))
    traces_before = trace_count()
    server.classify(toks)
    retraced = trace_count() - traces_before

    warm_g, steady_g, _ = _timed(lambda: server.generate(toks, max_new_tokens=4),
                                 reps=3)

    # --- prompt-admission latency (SlotStream chunked prefill) -------------
    # a 256-token prompt must admit in <= ceil(log2(256)) bucketed prefill
    # calls — not 256 decode-feed steps — with zero steady-state retraces
    P, n_admit = 256, 4
    eng = ServingEngine(SMALL, one, max_seq=512)
    rng = np.random.default_rng(1)

    def admit_reqs():
        return [
            Request(tokens=rng.integers(0, 256, P).astype(np.int32),
                    max_new_tokens=4)
            for _ in range(n_admit)
        ]

    eng.serve_continuous(admit_reqs(), n_slots=n_admit)  # warmup (buckets trace)
    before = trace_count()
    t0 = time.perf_counter()
    eng.serve_continuous(admit_reqs(), n_slots=n_admit)
    chunk_wall = time.perf_counter() - t0
    admission_retraces = trace_count() - before
    st = eng.last_stream_stats
    calls_per_admit = st["chunk_calls"] / st["admitted"]

    # true device-side admission latency: dispatch is async, so time a lone
    # admission with an explicit block on the slot cache (first rep compiles
    # the n_slots=1 bucket programs, second measures steady state)
    for _ in range(2):
        stream = eng.slot_stream(n_slots=1)
        stream.submit(admit_reqs()[:1])
        t0 = time.perf_counter()
        stream.refill()
        # paged backends keep device state in the page pool, dense in the
        # slot cache — block on whichever this stream actually owns
        jax.block_until_ready(
            stream.backend.pool_dev if stream.backend.paged
            else stream.backend.cache
        )
        admit_ms = (time.perf_counter() - t0) * 1e3

    eng.serve_continuous(admit_reqs(), n_slots=n_admit,
                         chunked_prefill=False)  # decode-feed warmup
    t0 = time.perf_counter()
    eng.serve_continuous(admit_reqs(), n_slots=n_admit, chunked_prefill=False)
    plain_wall = time.perf_counter() - t0

    assert admission_retraces == 0, "steady-state chunked admission must not retrace"
    assert calls_per_admit <= math.ceil(math.log2(P)), (
        f"{P}-token prompt took {calls_per_admit} bucket calls"
    )

    # --- block-paged KV pools (DESIGN.md §10) ------------------------------
    # (a) equal-HBM concurrency: a dense 4-slot x 256-row cache holds 1024
    # KV rows; give the paged pool the same row budget (64 pages of 16,
    # plus the never-allocated overflow sink) and it carries 16 resident
    # slots of mixed-length traffic — 4x the admitted concurrency at equal
    # cache HBM — without a single forced completion or admit failure.
    ps, dense_slots, paged_slots = 16, 4, 16
    budget_pages = dense_slots * (256 // ps)
    mix_rng = np.random.default_rng(5)
    n_mix = 12 if smoke_mode() else 24

    def _mixed_requests():
        return [
            Request(tokens=mix_rng.integers(0, 256, int(L)).astype(np.int32),
                    max_new_tokens=4)
            for L in mix_rng.integers(8, 49, n_mix)
        ]

    pstream = eng.slot_stream(n_slots=paged_slots, max_seq=256, paged=True,
                              page_size=ps, n_pages=budget_pages + 1)
    pstream.submit(_mixed_requests())
    t0 = time.perf_counter()
    for _ in pstream.drain():
        pass
    paged_wall = time.perf_counter() - t0
    pool = pstream.backend.pool
    assert pstream.stats["forced_completions"] == 0, pstream.stats
    assert pstream.stats["admit_failures"] == 0, pstream.stats
    assert pool.pages_in_use == 0
    pool.assert_conserved()
    peak_pages = pool.stats["peak_pages_in_use"]
    concurrency_x = paged_slots / dense_slots

    # (b) paged == dense bitwise through the full cascade (greedy): same
    # routing, same tiers, same generations — the pool is a memory layout,
    # not a numeric change
    parity = CascadeServer([
        CascadeTier(SMALL, v1, TierSpec("t1", "vote", 0.67, k=3, cost=1.0)),
        CascadeTier(BIG, v2, TierSpec("t2", "confidence", -1.0, k=1,
                                      cost=30.0)),
    ])

    def _parity_requests():
        r = np.random.default_rng(7)
        return [
            Request(tokens=r.integers(0, 256, int(L)).astype(np.int32),
                    max_new_tokens=4)
            for L in r.integers(8, 33, 8)
        ]

    parity_out = {}
    for paged in (False, True):
        done = parity.serve_continuous(_parity_requests(), n_slots=4,
                                       max_seq=64, paged=paged, page_size=8)
        parity_out[paged] = {
            tuple(r.tokens.tolist()): (r.tier, tuple(r.output.tolist()))
            for r in done
        }
    assert parity_out[True] == parity_out[False], (
        "paged cascade generations must be bitwise-identical to dense"
    )

    # (c) E-fold shared-prefix reuse: one page table serves all E member
    # planes of a tier pool, so every shared-prefix page hit skips E page
    # copies' worth of HBM, not one
    from repro.serve import SlotStream, TierBackend

    pre_rng = np.random.default_rng(9)
    prefix = pre_rng.integers(0, 256, 24).astype(np.int32)

    def _prefix_requests():
        return [
            Request(
                tokens=np.concatenate(
                    [prefix, pre_rng.integers(0, 256, int(t)).astype(np.int32)]
                ),
                max_new_tokens=3,
            )
            for t in pre_rng.integers(2, 9, 6)
        ]

    tb = TierBackend(parity.tiers[0], n_slots=4, max_seq=64, paged=True,
                     page_size=8)
    tstream = SlotStream(tb, n_slots=4, max_seq=64)
    tstream.submit(_prefix_requests())
    for _ in tstream.drain():
        pass
    E = parity.tiers[0].k
    shared_hits = tb.pool.stats["shared_hits"]
    assert shared_hits > 0, "shared-prefix traffic produced no index hits"
    # per-page bytes across every layer AND every member plane: the page
    # axis sits at ndim-4, so nbytes // n_pages already counts E planes
    page_bytes = sum(
        leaf.nbytes // leaf.shape[leaf.ndim - 4]
        for leaf in jax.tree.leaves(tb.pool_dev)
    )
    efold_saved_mb = shared_hits * page_bytes / 1e6
    efold_saved_1plane_mb = efold_saved_mb / E

    # --- open-loop load-adaptive serving A/B (DESIGN.md §12) ---------------
    # identical bursty trace, identical HBM budget, virtual time (the whole
    # A/B replays bit-for-bit): static ServeConfig vs the same config with
    # the greedy controller actuating theta offsets / slot caps / shedding.
    # The acceptance gate is strict: controller-on goodput must EXCEED the
    # static baseline's, and offered == completed + shed on both sides
    # (shed requests come back marked, never silently dropped).
    from repro.serve import (
        ControllerConfig,
        GreedyController,
        ServeConfig,
        bursty,
    )

    # NOT trimmed in smoke mode: the A/B needs the full burst structure to
    # saturate the static config (a shorter trace never backs up, static
    # hits goodput 1.0, and the strict-win gate has nothing to beat); the
    # run is virtual-time, so the cost is model steps only
    ol_n = 80
    ol_wl = bursty(2.0, 300.0, ol_n, seed=7, mean_on_s=0.5, mean_off_s=0.5,
                   prompt_len=(4, 12), max_new_tokens=(2, 5))
    ol_cfg = ServeConfig(n_slots=4, max_seq=64)
    ol_slo_s = 0.3

    def _ol_server():
        # fresh server per arm: each run owns a fresh registry/virtual
        # clock, so the arms cannot leak telemetry into each other
        return CascadeServer([
            CascadeTier(SMALL, v1, TierSpec("t1", "vote", 0.67, k=3, cost=1.0)),
            CascadeTier(BIG, v2, TierSpec("t2", "confidence", -1.0, k=1,
                                          cost=30.0)),
        ])

    ol_static = _ol_server().serve_open_loop(
        ol_wl, ol_cfg, slo_s=ol_slo_s, step_time_s=0.01
    )
    ol_ctl = GreedyController(ControllerConfig(interval_s=0.1))
    ol_adaptive = _ol_server().serve_open_loop(
        ol_wl, ol_cfg, slo_s=ol_slo_s, step_time_s=0.01, controller=ol_ctl
    )
    assert ol_static.offered == ol_adaptive.offered == ol_n
    for rep in (ol_static, ol_adaptive):
        assert len(rep.completed) + len(rep.shed) == ol_n, rep
        assert all(r.shed and r.output is None for r in rep.shed)
    assert ol_adaptive.goodput > ol_static.goodput, (ol_adaptive, ol_static)
    assert ol_ctl.actions, "controller never actuated on a bursty trace"

    # --- cascade-as-drafter speculative decoding (DESIGN.md §13) -----------
    # A/B on identical requests: tier0 = [m0, m0, m2] (the m0 pair agrees,
    # so theta=0.8 defers with m0's generation as the plurality draft),
    # tier1 = [m0] — at T=0 the draft is exactly what tier 1 would decode,
    # so acceptance is deterministic and the gate can be strict: outputs
    # BITWISE identical to plain serving, accept rate > 0, and the big
    # tier spends strictly fewer decode steps per deferral.
    from repro.serve import ServeConfig as _SC

    spec_m0 = ens.take_member(v1, 0)
    spec_m2 = ens.take_member(v1, 2)
    spec_t0 = jax.tree.map(
        lambda a, b: jnp.stack([a, a, b]), spec_m0, spec_m2
    )

    def _spec_server():
        return CascadeServer([
            CascadeTier(SMALL, spec_t0,
                        TierSpec("t1", "vote_preds", 0.8, k=3, cost=1.0)),
            CascadeTier(SMALL, jax.tree.map(lambda v: v[0:1], v1),
                        TierSpec("t2", "vote_preds", 0.0, k=1, cost=30.0)),
        ])

    def _spec_requests():
        r = np.random.default_rng(11)
        return [Request(tokens=r.integers(1, 256, int(L)).astype(np.int32),
                        max_new_tokens=6)
                for L in r.integers(8, 25, 8 if smoke_mode() else 16)]

    spec_out, spec_stats, spec_wall = {}, {}, {}
    for on in (False, True):
        srv = _spec_server()
        scfg = _SC(n_slots=4, max_seq=64, speculative=on)
        srv.serve_continuous(_spec_requests(), scfg)  # warmup (verify traces)
        t0 = time.perf_counter()
        done = srv.serve_continuous(_spec_requests(), scfg)
        spec_wall[on] = time.perf_counter() - t0
        spec_out[on] = {tuple(r.tokens.tolist()): (r.tier, tuple(r.output.tolist()))
                        for r in done}
        spec_stats[on] = [dict(s) for s in srv.last_stream_stats]
    assert spec_out[True] == spec_out[False], (
        "speculative serving must emit bitwise what plain serving emits"
    )
    sp1, pl1 = spec_stats[True][1], spec_stats[False][1]
    n_deferrals = sp1["admitted"]
    spec_accepted = sp1["spec_accepted_tokens"]
    spec_offered = sp1["spec_draft_tokens"]
    assert n_deferrals > 0 and spec_accepted > 0, (sp1, pl1)
    assert sp1["decode_tokens"] < pl1["decode_tokens"], (sp1, pl1)
    acc_per_deferral = spec_accepted / n_deferrals
    accept_rate = spec_accepted / max(1, spec_offered)

    # --- overlapped cross-host continuous serving (DESIGN.md §8) -----------
    # the shared harness (benchmarks/common.py measure_overlap) asserts the
    # equivalence contract; this bench only reports the ratio — the hard
    # wall-clock gates live in bench_edge_cloud, the scenario owner
    from benchmarks.common import measure_overlap

    n_req, delay = (6, 0.02) if smoke_mode() else (12, 0.04)

    def _cont_requests():
        r = np.random.default_rng(3)
        return [Request(tokens=r.integers(0, 256, 8).astype(np.int32),
                        max_new_tokens=4) for _ in range(n_req)]

    def _cont_build(placement):
        # v1's members are independently initialized, so disagreement (and
        # therefore real link traffic) actually occurs — unlike `same`
        return CascadeServer([
            CascadeTier(SMALL, v1, TierSpec("t1", "vote", 0.67, k=3, cost=1.0)),
            CascadeTier(BIG, v2, TierSpec("t2", "confidence", -1.0, k=1,
                                          cost=30.0)),
        ], placement=placement)

    # the overlapped run carries the full telemetry bundle (DESIGN.md §11):
    # registry-backed p50/p99 request latency, per-tier cascade counters,
    # the transport mirror, and — when REPRO_BENCH_TRACE names a path — a
    # schema-validated Perfetto trace of every request's lifecycle
    trace_path = os.environ.get("REPRO_BENCH_TRACE", "")
    ob = Observability(tracer=Tracer()) if trace_path else Observability()
    m = measure_overlap(_cont_build, _cont_requests, delay=delay, obs=ob)
    wall_ser, wall_ovl = m["wall_serial"], m["wall_overlap"]
    ovl_link, overlap_ratio = m["link"], m["ratio"]

    reg = ob.registry
    h_lat = reg.get("serve.request_latency_s")
    assert h_lat is not None and h_lat.count == n_req
    lat_p50_ms = h_lat.percentile(0.50) * 1e3
    lat_p99_ms = h_lat.percentile(0.99) * 1e3
    n_deferred = int(reg.value("cascade.tier0.deferred"))
    link_bytes = int(reg.value("transport.edge0_cloud0.bytes"))
    assert link_bytes == ovl_link.total_bytes  # registry mirror == meter
    if trace_path:
        trace = ob.tracer.export()
        summ = validate_trace(trace)
        assert summ["tracks"] == n_req  # every admitted rid has a track
        ob.tracer.write(trace_path)

    qps = len(toks) / steady_c
    if verbose:
        print(f"# cascade classify: warmup {warm_c*1e3:.0f} ms (compile), "
              f"steady {steady_c*1e3:.1f} ms/batch ({qps:.0f} q/s), "
              f"retraces after warmup: {retraced}")
        print(f"# cascade generate: warmup {warm_g*1e3:.0f} ms, "
              f"steady {steady_g*1e3:.1f} ms/batch, tier fractions "
              f"{np.round(server.tier_fractions(res), 2).tolist()}")
        print(f"# chunked admission: {P}-token prompt in {calls_per_admit:.0f} "
              f"bucket calls (ceil(log2)={math.ceil(math.log2(P))}; decode-feed "
              f"= {P-1} steps), {admit_ms:.1f} ms/admission, "
              f"retraces {admission_retraces}; serve wall "
              f"{chunk_wall:.2f}s chunked vs {plain_wall:.2f}s decode-only "
              f"({plain_wall/chunk_wall:.1f}x)")
        print(f"# paged KV pool: {paged_slots} resident slots on a dense "
              f"{dense_slots}-slot HBM budget ({budget_pages} pages of {ps}; "
              f"peak {peak_pages} in use) = {concurrency_x:.0f}x concurrency, "
              f"{n_mix} mixed-length requests in {paged_wall:.2f}s, 0 forced "
              f"completions; cascade generations bitwise == dense")
        print(f"# shared-prefix reuse (E={E} tier): {shared_hits} page hits "
              f"-> {efold_saved_mb:.3f} MB of pool writes skipped "
              f"({efold_saved_1plane_mb:.3f} MB/plane x {E} member planes)")
        print(f"# cross-host continuous: {ovl_link.total_examples} deferrals "
              f"over a {delay*1e3:.0f}ms link; makespan {wall_ser*1e3:.0f}ms "
              f"serial -> {wall_ovl*1e3:.0f}ms overlapped "
              f"({overlap_ratio:.2f}x), blocked wait "
              f"{ovl_link.total_wait*1e3:.0f}ms")
        print(f"# registry (serve.request_latency_s over {h_lat.count} "
              f"requests): p50 {lat_p50_ms:.0f}ms, p99 {lat_p99_ms:.0f}ms; "
              f"{n_deferred} deferred, {link_bytes} B over link"
              + (f"; Perfetto trace -> {trace_path}" if trace_path else ""))
        print(f"# open-loop ({ol_n} bursty arrivals @ SLO {ol_slo_s*1e3:.0f}ms "
              f"virtual): goodput {ol_static.goodput:.3f} static -> "
              f"{ol_adaptive.goodput:.3f} controller-on "
              f"({len(ol_ctl.actions)} actions, {len(ol_adaptive.shed)} shed "
              f"marked); p50 {ol_adaptive.p50_s*1e3:.0f}ms, "
              f"p99 {ol_adaptive.p99_s*1e3:.0f}ms")
        print(f"# speculative (cascade-as-drafter): {n_deferrals} deferrals, "
              f"{acc_per_deferral:.1f} accepted tokens/deferral "
              f"(accept rate {accept_rate:.2f}); big-tier decode steps "
              f"{pl1['decode_tokens']} plain -> {sp1['decode_tokens']} "
              f"speculative; generations bitwise == plain; wall "
              f"{spec_wall[False]:.2f}s -> {spec_wall[True]:.2f}s")
    assert retraced == 0, "steady-state classify must not retrace"
    # derived keys that read a stats surface carry the surface's
    # fully-qualified registry name (DESIGN.md §11) — tools/perf_compare.py
    # NAME_MAP translates the old unnamespaced keys in committed baselines
    row = csv_row(
        "serving_cascade_classify", steady_c * 1e6,
        f"qps={qps:.0f};warmup_ms={warm_c*1e3:.0f};steady_ms={steady_c*1e3:.2f};"
        f"gen_steady_ms={steady_g*1e3:.1f};tier1_frac={server.tier_fractions(res)[0]:.2f};"
        f"cost_vs_all_big={res.cost/(30.0*len(toks)):.2f};"
        f"admit_calls_per_{P}tok={calls_per_admit:.0f};"
        f"slot_stream.admit_ms={admit_ms:.1f};"
        f"admit_speedup_vs_decode_feed={plain_wall/chunk_wall:.1f};"
        f"paged_concurrency_x={concurrency_x:.0f};"
        f"paging.pool_occupancy.peak={peak_pages};"
        f"paging.shared_prefix_saved_mb={efold_saved_mb:.3f};"
        f"overlap_ratio={overlap_ratio:.2f}",
    )
    # registry-backed report row: every value below reads a fully-qualified
    # metric out of the run's registry, not an ad-hoc accumulator.  gate=off:
    # the us column is p50 request latency over a real-sleep link (wall
    # clock swings on shared runners); presence + non-NaN still gate.
    row_obs = csv_row(
        "serving_obs_registry", lat_p50_ms * 1e3,
        f"serve.request_latency_s.p50_ms={lat_p50_ms:.1f};"
        f"serve.request_latency_s.p99_ms={lat_p99_ms:.1f};"
        f"serve.request_latency_s.count={h_lat.count};"
        f"cascade.tier0.deferred={n_deferred};"
        f"transport.edge0_cloud0.bytes={link_bytes};"
        f"slot_stream.tier0.decode_tokens="
        f"{int(reg.value('slot_stream.tier0.decode_tokens'))};"
        f"gate=off",
    )
    # open-loop A/B row (DESIGN.md §12): the us column is controller-on p50
    # VIRTUAL latency (deterministic, but model step cost is hardware-
    # relative) — gate=off, the hard gate is the asserted strict goodput
    # win above plus derived-key presence.  goodput = SLO-attainment
    # fraction: completed-within-SLO / offered.
    row_ol = csv_row(
        "serving_open_loop", ol_adaptive.p50_s * 1e6,
        f"goodput_ctl={ol_adaptive.goodput:.3f};"
        f"goodput_static={ol_static.goodput:.3f};"
        f"serve.request_latency_s.p50_ms={ol_adaptive.p50_s*1e3:.1f};"
        f"serve.request_latency_s.p99_ms={ol_adaptive.p99_s*1e3:.1f};"
        f"controller_actions={len(ol_ctl.actions)};"
        f"shed={len(ol_adaptive.shed)};offered={ol_adaptive.offered};"
        f"gate=off",
    )
    # speculative A/B row (DESIGN.md §13): the us column is the speculative
    # serve wall (hardware-relative) — gate=off; the hard gates are the
    # asserted bitwise parity, accept rate > 0, and the strict big-tier
    # decode-step drop above.
    row_spec = csv_row(
        "serving_speculative", spec_wall[True] * 1e6,
        f"accepted_per_deferral={acc_per_deferral:.1f};"
        f"accept_rate={accept_rate:.2f};"
        f"deferred={n_deferrals};"
        f"tier1_decode_plain={pl1['decode_tokens']};"
        f"tier1_decode_spec={sp1['decode_tokens']};"
        f"bitwise_vs_plain=True;gate=off",
    )
    return row + "\n" + row_obs + "\n" + row_ol + "\n" + row_spec
