"""Serving-engine benchmark: real (reduced) models end to end — cascade
classify throughput and per-tier routing on the mixture task (the live
counterpart of Table 5's exit-fraction breakdown)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row
from repro.configs.base import ModelConfig
from repro.core import ensemble as ens
from repro.core.cascade import TierSpec
from repro.models.params import unbox
from repro.serve import CascadeServer, CascadeTier

SMALL = ModelConfig(
    name="bench-s", family="dense", n_layers=2, d_model=64, d_ff=128,
    vocab_size=256, n_heads=4, n_kv_heads=2, remat=False,
)
BIG = ModelConfig(
    name="bench-b", family="dense", n_layers=4, d_model=128, d_ff=256,
    vocab_size=256, n_heads=8, n_kv_heads=4, remat=False,
)


def run(verbose=True):
    v1, _ = unbox(ens.init_ensemble(SMALL, 3, jax.random.PRNGKey(0)))
    one = ens.take_member(v1, 0)
    same = jax.tree.map(lambda x: jnp.stack([x, x, x]), one)  # agreeing tier
    v2, _ = unbox(ens.init_ensemble(BIG, 1, jax.random.PRNGKey(1)))
    server = CascadeServer([
        CascadeTier(SMALL, same, TierSpec("t1", "vote", 0.9, k=3, cost=1.0)),
        CascadeTier(BIG, v2, TierSpec("t2", "confidence", -1.0, k=1, cost=30.0)),
    ])
    toks = np.random.default_rng(0).integers(0, 256, (64, 32)).astype(np.int32)
    server.classify(toks)  # warmup/compile
    t0 = time.perf_counter()
    reps = 5
    for _ in range(reps):
        res = server.classify(toks)
    dt = (time.perf_counter() - t0) / reps
    us = dt * 1e6
    qps = len(toks) / dt
    if verbose:
        print(f"# cascade classify: {qps:.0f} q/s, tier fractions "
              f"{np.round(server.tier_fractions(res), 2).tolist()}")
    return csv_row(
        "serving_cascade_classify", us,
        f"qps={qps:.0f};tier1_frac={server.tier_fractions(res)[0]:.2f};cost_vs_all_big={res.cost/(30.0*len(toks)):.2f}",
    )
