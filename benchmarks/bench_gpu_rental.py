"""Fig. 4b + Tables 4/5 — heterogeneous-GPU model serving: tiers placed on
V100/A6000/A100/H100 (Lambda prices); ABC's rental cost vs best single
model on the top GPU, with the per-tier exit-fraction breakdown."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import (
    PoolModel, csv_row, sample_pool_logits, skill_for_accuracy, time_op,
)
from repro.core import calibration, deferral
from repro.core.cascade import TierSpec, cascade_apply_routed
from repro.core.cost_model import LAMBDA_GPU_PRICES, gpu_rental_cost
from repro.serve.transport import LoopbackTransport


@jax.jit
def _vote_score(logits):
    # module-level jit: repeated run() calls re-enter one cache (ABC101/102)
    return deferral.vote_rule(logits, 0.67).score


def run(verbose=True):
    tiers_def = [
        ("V100", 0.68, 3),
        ("A6000", 0.78, 2),
        ("A100", 0.85, 1),
        ("H100", 0.90, 1),
    ]
    models = []
    for i, (gpu, acc, k) in enumerate(tiers_def):
        for j in range(k):
            models.append(PoolModel(f"t{i}m{j}", skill_for_accuracy(acc), 10 ** i, seed=i * 10 + j))
    y, _, logits = sample_pool_logits(models, 10_000, seed=7, difficulty_beta=(1, 3))
    yc, _, logits_c = sample_pool_logits(models, 400, seed=77, difficulty_beta=(1, 3))

    def tier_logits(i, pool, n):
        names = [m.name for m in models if m.name.startswith(f"t{i}")]
        return np.stack([pool[nm] for nm in names])

    # calibrate per-tier thresholds (App. B)
    thetas = []
    for i in range(len(tiers_def) - 1):
        Lc = jax.numpy.asarray(tier_logits(i, logits_c, 400))
        oc = deferral.vote_rule(Lc, 0.0) if Lc.shape[0] > 1 else deferral.confidence_rule(Lc, 0.0)
        th, _ = calibration.estimate_threshold(
            np.asarray(oc.score), np.asarray(oc.pred) == yc, epsilon=0.02, n_samples=100
        )
        thetas.append(th)
    thetas.append(-1.0)

    fns = []
    specs = []
    for i, (gpu, acc, k) in enumerate(tiers_def):
        Lfull = jax.numpy.asarray(tier_logits(i, logits, len(y)))
        fns.append(lambda b, L=Lfull: L[:, b["idx"]])
        rule = "vote" if k > 1 else "confidence"
        specs.append(TierSpec(gpu, rule, thetas[i], k=k, cost=float(10 ** i)))
    # each GPU boundary is a metered hop: only the compacted deferral
    # payload crosses, so tier-transition traffic is measured, not assumed
    link = LoopbackTransport()
    res = cascade_apply_routed(
        fns, specs, {"idx": np.arange(len(y))}, pad_to=64,
        transport=link, hosts=[t[0] for t in tiers_def],
    )

    fracs = res.tier_counts / res.tier_counts.sum()
    gpus = [t[0] for t in tiers_def]
    abc_cost = gpu_rental_cost(gpus, fracs)
    single_cost = LAMBDA_GPU_PRICES["H100"]
    acc_abc = float((res.pred == y).mean())
    acc_single = float((logits["t3m0"].argmax(-1) == y).mean())
    if verbose:
        for g, f in zip(gpus, fracs):
            print(f"# {g}: frac={f:.2f} (${LAMBDA_GPU_PRICES[g]}/h)")
        print(f"# ABC ${abc_cost:.2f}/h acc={acc_abc:.3f} vs single H100 "
              f"${single_cost:.2f}/h acc={acc_single:.3f}")
        for h in link.hops:
            print(f"# hop {h.src}->{h.dst}: {h.n_examples} deferred, "
                  f"{h.payload_bytes/1e3:.1f}kB")

    L0 = jax.numpy.asarray(tier_logits(0, logits, len(y))[:, :256])
    us = time_op(_vote_score, L0)
    return csv_row(
        "fig4b_gpu_rental",
        us,
        f"rental_cost_reduction={single_cost/abc_cost:.2f}x;tier1_frac={fracs[0]:.2f};"
        f"acc_delta={acc_abc-acc_single:+.3f};"
        f"transport.loopback.bytes={link.total_bytes}",
    )
