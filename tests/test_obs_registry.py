"""Metrics-registry tests (DESIGN.md §11).

Three contracts are pinned here:

(a) registry primitives: counters/gauges/histograms record host scalars,
    names are get-or-create with one-kind-per-name, ``StatsView`` is a
    read-only Mapping facade;
(b) EQUIVALENCE: the legacy stats-dict surfaces (``SlotStream.stats``,
    ``PagePool.stats``, ``ServingEngine.stats``, ``host_fetch_stats``) are
    views over the registry — after a ``serve_continuous`` run (E=1 and
    E=3, paged and dense) every legacy total equals the registry value for
    its fully-qualified name, bit for bit;
(c) OVERHEAD: the disabled collector (private registry + NullTracer — the
    default every component gets) costs well under 5% of a decode step's
    host time.
"""
import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import ensemble as ens
from repro.core.cascade import (
    TierSpec,
    host_fetch,
    host_fetch_stats,
    reset_host_fetch_stats,
)
from repro.models import api
from repro.models.params import unbox
from repro.obs import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    NullTracer,
    Observability,
    Scope,
    StatsView,
    UNIT_BUCKETS,
    global_registry,
    perf_clock,
)
from repro.serve import CascadeServer, CascadeTier, Request, ServingEngine

CFG = ModelConfig(
    name="obs-dense", family="dense", n_layers=2, d_model=64, d_ff=128,
    vocab_size=64, n_heads=4, n_kv_heads=2, remat=False,
)


@pytest.fixture(scope="module")
def stack():
    return unbox(ens.init_ensemble(CFG, 3, jax.random.PRNGKey(0)))[0]


@pytest.fixture(scope="module")
def params():
    return unbox(api.init_params(CFG, jax.random.PRNGKey(1)))[0]


def _requests(seed, n, *, hi=14, max_new=4):
    rng = np.random.default_rng(seed)
    return [
        Request(
            tokens=rng.integers(0, 64, int(rng.integers(4, hi))).astype(np.int32),
            max_new_tokens=max_new,
        )
        for _ in range(n)
    ]


# ---------------------------------------------------------------------------
# (a) registry primitives
# ---------------------------------------------------------------------------


def test_counter_gauge_histogram_basics():
    reg = MetricsRegistry()
    c = reg.counter("x.calls")
    c.add()
    c.add(4)
    assert c.value == 5
    g = reg.gauge("x.level")
    g.set(7)
    g.set(3)
    assert (g.value, g.peak) == (3, 7)
    h = reg.histogram("x.time_s")
    for v in (1e-5, 2e-4, 0.5):
        h.record(v)
    assert h.count == 3
    assert h.sum == pytest.approx(1e-5 + 2e-4 + 0.5)
    assert h.mean == pytest.approx(h.sum / 3)
    assert 0.0 < h.percentile(0.5) <= 0.5
    assert h.percentile(1.0) == pytest.approx(0.5)


def test_histogram_sum_matches_adhoc_accumulator_bitwise():
    # the StatsView contract: hist.sum IS the float the old ``+=`` produced
    rng = np.random.default_rng(3)
    vals = rng.random(257).tolist()
    h = Histogram("h")
    acc = 0.0
    for v in vals:
        h.record(v)
        acc += v
    assert h.sum == acc  # same additions in the same order: bitwise equal


def test_get_or_create_and_kind_mismatch():
    reg = MetricsRegistry()
    assert reg.counter("a.b") is reg.counter("a.b")
    with pytest.raises(TypeError):
        reg.gauge("a.b")
    sc = Scope(reg, "tier0")
    assert sc.counter("hits").name == "tier0.hits"
    assert sc.histogram("m", UNIT_BUCKETS).buckets == UNIT_BUCKETS


def test_stats_view_is_read_only_mapping():
    reg = MetricsRegistry()
    c = reg.counter("n")
    view = StatsView({"n": lambda: c.value})
    c.add(2)
    assert view["n"] == 2
    assert dict(view) == {"n": 2}
    assert len(view) == 1 and list(view) == ["n"]
    with pytest.raises(TypeError):
        view["n"] = 5  # Mapping, not MutableMapping


def test_snapshot_shape():
    reg = MetricsRegistry()
    reg.counter("c").add(2)
    reg.gauge("g").set(4)
    reg.histogram("h").record(0.25)
    snap = reg.snapshot()
    assert snap["c"] == 2 and snap["g"] == 4 and snap["g.peak"] == 4
    assert snap["h.sum"] == pytest.approx(0.25) and snap["h.count"] == 1
    assert "h.p50" in snap and "h.p99" in snap


def test_host_fetch_stats_is_registry_backed():
    reset_host_fetch_stats()
    host_fetch(jax.numpy.arange(8, dtype=jax.numpy.int32))
    legacy = host_fetch_stats()
    reg = global_registry()
    assert legacy["bytes"] == reg.value("host_fetch.bytes") == 32
    assert legacy["calls"] == reg.value("host_fetch.calls") == 1


# ---------------------------------------------------------------------------
# (b) legacy stats == registry, across serve_continuous
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("paged", [True, False])
def test_engine_serve_equivalence(params, paged):
    ob = Observability()
    eng = ServingEngine(CFG, params, max_seq=64)
    done = eng.serve_continuous(
        _requests(11, 6), n_slots=2, max_seq=32, paged=paged, obs=ob,
    )
    assert len(done) == 6
    reg = ob.registry
    st = eng.last_stream_stats
    for key in ("admitted", "admit_failures", "forced_completions",
                "chunk_calls", "chunk_tokens", "shared_tokens",
                "decode_tokens", "inflight_admitted"):
        assert st[key] == reg.value(f"slot_stream.{key}"), key
    # the split admit_time: legacy total == sum of the two histograms
    assert st["admit_time"] == (
        reg.value("slot_stream.admit.begin_slot_s")
        + reg.value("slot_stream.admit.prefill_dispatch_s")
    )
    assert st["decode_time"] == reg.value("slot_stream.decode.dispatch_s")
    assert st["inflight_wait"] == reg.value("slot_stream.admit.inflight_wait_s")
    if paged:
        assert reg.value("paging.allocated") > 0
        assert reg.get("paging.pool_occupancy").peak > 0
    assert reg.get("serve.request_latency_s").count == 6


@pytest.mark.parametrize("paged", [True, False])
def test_cascade_serve_equivalence(stack, paged):
    ob = Observability()
    server = CascadeServer(
        [CascadeTier(CFG, stack, TierSpec("t0", "vote", 0.67, k=3, cost=1.0))]
    )
    done = server.serve_continuous(
        _requests(12, 6), n_slots=2, max_seq=32, paged=paged, obs=ob,
    )
    assert len(done) == 6
    reg = ob.registry
    st = server.last_stream_stats[0]
    for key in ("admitted", "chunk_calls", "chunk_tokens", "decode_tokens",
                "forced_completions"):
        assert st[key] == reg.value(f"slot_stream.tier0.{key}"), key
    # every request either answered or deferred exactly once at tier 0
    # (single tier: deferrals are impossible)
    assert reg.value("cascade.tier0.answered") == 6
    assert reg.value("cascade.tier0.deferred") == 0
    assert reg.get("cascade.tier0.agreement_margin").count == 6
    assert reg.value("cascade.tier0.output_tokens") == sum(
        len(r.output) for r in done
    )
    assert reg.get("serve.request_latency_s").count == 6
    if paged:
        assert st and reg.value("paging.tier0.allocated") > 0


def test_pool_stats_view_equivalence():
    from repro.serve.paging import PagePool

    ob = Observability()
    pool = PagePool(9, 4, n_slots=2, max_seq=16, obs=ob, name="paging")
    toks = np.arange(10, dtype=np.int32)
    assert pool.admit(0, toks) == 0
    assert pool.admit(1, toks) == 8  # two full shared prefix pages
    pool.release(0)
    pool.release(1)
    st = dict(pool.stats)
    reg = ob.registry
    assert st["allocated"] == reg.value("paging.allocated")
    assert st["shared_hits"] == reg.value("paging.shared_hits")
    assert st["freed"] == reg.value("paging.freed")
    assert st["peak_pages_in_use"] == reg.get("paging.pool_occupancy").peak
    assert reg.get("paging.pool_occupancy").value == 0  # all released


# ---------------------------------------------------------------------------
# (c) the disabled collector is near-free
# ---------------------------------------------------------------------------


def test_disabled_collector_overhead_under_5pct(params):
    """Per decode step the stream records: 2 clock reads, 1 histogram
    record, 1 counter add (plus the ``tracer.enabled`` checks).  Measure
    that recording cost directly and compare it to the measured decode-step
    host time of a real serve — the telemetry share must stay far under the
    5%% budget."""
    eng = ServingEngine(CFG, params, max_seq=64)
    ob = Observability()  # private registry + NullTracer: the default
    eng.serve_continuous(_requests(13, 6), n_slots=2, max_seq=32, obs=ob)
    h = ob.registry.get("slot_stream.decode.dispatch_s")
    assert h.count > 0
    step_host_s = h.mean  # measured host time of one decode dispatch

    reg = MetricsRegistry()
    c = reg.counter("bench.c")
    hh = reg.histogram("bench.h")
    tr = NullTracer()
    n = 20_000
    t0 = perf_clock()
    for _ in range(n):
        a = perf_clock()
        hh.record(perf_clock() - a)
        c.add(4)
        if tr.enabled:  # pragma: no cover - never taken
            tr.begin(0, "x")
        if tr.enabled:  # pragma: no cover - never taken
            tr.end(0, "x")
    per_step_telemetry_s = (perf_clock() - t0) / n
    assert per_step_telemetry_s < 0.05 * step_host_s, (
        f"telemetry {per_step_telemetry_s * 1e6:.2f}us/step vs decode "
        f"dispatch {step_host_s * 1e6:.2f}us/step"
    )
