"""Training loop learns; checkpoints roundtrip; schedules behave."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.configs.base import ModelConfig
from repro.data.pipeline import TokenDataset, batches
from repro.data.synthetic import sequence_task
from repro.models import api
from repro.models.params import unbox
from repro.optim.adamw import OptimConfig, adamw_init, adamw_update, global_norm
from repro.optim.schedule import cosine_schedule
from repro.train import init_train_state, make_train_step

TINY = ModelConfig(
    name="tiny", family="dense", n_layers=2, d_model=64, d_ff=128,
    vocab_size=128, n_heads=4, n_kv_heads=2, remat=False,
)


def test_loss_decreases():
    """Next = prev + 1 (mod V): pure bigram structure a 2-layer model must
    crush within 60 steps.  (The order-2 Markov `sequence_task` has
    near-uniform unigram/bigram marginals by construction — far too little
    signal for 30k training tokens — so it is NOT used here.)"""
    values, _ = unbox(api.init_params(TINY, jax.random.PRNGKey(0)))
    ocfg = OptimConfig(lr=3e-3)
    state = init_train_state(values, ocfg)
    step = jax.jit(make_train_step(TINY, ocfg, total_steps=60, warmup_steps=5))
    rng = np.random.default_rng(0)
    base = rng.integers(0, 128, (512, 1))
    rows = ((base + np.arange(33)) % 128).astype(np.int32)
    it = batches(TokenDataset(rows), 16)
    losses = []
    for i in range(60):
        state, m = step(state, next(it))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 1.0, losses[::10]


def test_loss_decreases_markov_long():
    """The order-2 Markov task DOES learn given enough steps — a slower
    sanity check on the same pipeline (loss below the unigram floor)."""
    values, _ = unbox(api.init_params(TINY, jax.random.PRNGKey(1)))
    ocfg = OptimConfig(lr=3e-3)
    state = init_train_state(values, ocfg)
    step = jax.jit(make_train_step(TINY, ocfg, total_steps=300, warmup_steps=10))
    rows = sequence_task(1024, 32, vocab=128, seed=0)
    it = batches(TokenDataset(rows), 32)
    first = last = None
    for i in range(300):
        state, m = step(state, next(it))
        if i < 10:
            first = (first or 0) + float(m["loss"]) / 10
        if i >= 290:
            last = (last or 0) + float(m["loss"]) / 10
    assert last < first - 0.1, (first, last)


def test_grad_clip_bounds_update():
    ocfg = OptimConfig(lr=1.0, clip_norm=1e-3, weight_decay=0.0)
    params = {"w": jnp.ones((4, 4))}
    opt = adamw_init(params, ocfg)
    grads = {"w": jnp.full((4, 4), 1e6)}
    newp, _, m = adamw_update(grads, opt, params, ocfg)
    assert float(m["grad_norm"]) > 1e5
    assert float(jnp.abs(newp["w"] - params["w"]).max()) < 1.5  # step bounded


def test_cosine_schedule_shape():
    assert float(cosine_schedule(0, 100, warmup_steps=10)) < 0.2
    peak = float(cosine_schedule(10, 100, warmup_steps=10))
    assert peak > 0.9
    assert float(cosine_schedule(99, 100, warmup_steps=10)) < peak


def test_checkpoint_roundtrip(tmp_path):
    values, _ = unbox(api.init_params(TINY, jax.random.PRNGKey(0)))
    d = str(tmp_path / "ck")
    save_checkpoint(d, 7, values)
    assert latest_step(d) == 7
    template = jax.tree.map(lambda v: jnp.zeros_like(v), values)
    restored = restore_checkpoint(d, template)
    for a, b in zip(jax.tree.leaves(values), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_checkpoint_bf16_preserved(tmp_path):
    tree = {"x": jnp.arange(8, dtype=jnp.bfloat16) / 3}
    d = str(tmp_path / "ck2")
    save_checkpoint(d, 1, tree)
    restored = restore_checkpoint(d, {"x": jnp.zeros(8, jnp.bfloat16)})
    assert restored["x"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(
        np.asarray(tree["x"], np.float32), np.asarray(restored["x"], np.float32)
    )


def test_low_mem_moments_dtype():
    ocfg = OptimConfig(moment_dtype="bfloat16")
    params = {"w": jnp.ones((4,))}
    opt = adamw_init(params, ocfg)
    assert opt["m"]["w"].dtype == jnp.bfloat16
    newp, newopt, _ = adamw_update({"w": jnp.ones((4,))}, opt, params, ocfg)
    assert newopt["v"]["w"].dtype == jnp.bfloat16
