"""Black-box voting must be reproducible: generation digests (and therefore
routing and cost numbers) may not depend on PYTHONHASHSEED or any other
per-process salt."""
import json
import os
import subprocess
import sys

import numpy as np

from repro.serve.cascade_server import digest_generations, stable_digest

_SCRIPT = r"""
import json
import jax
import numpy as np
from repro.configs.base import ModelConfig
from repro.core import ensemble as ens
from repro.core.cascade import TierSpec
from repro.models.params import unbox
from repro.serve import CascadeServer, CascadeTier

SMALL = ModelConfig(name="det-s", family="dense", n_layers=1, d_model=32,
                    d_ff=64, vocab_size=32, n_heads=2, n_kv_heads=2, remat=False)
BIG = ModelConfig(name="det-b", family="dense", n_layers=1, d_model=48,
                  d_ff=96, vocab_size=32, n_heads=2, n_kv_heads=2, remat=False)
v1, _ = unbox(ens.init_ensemble(SMALL, 3, jax.random.PRNGKey(0)))
v2, _ = unbox(ens.init_ensemble(BIG, 1, jax.random.PRNGKey(1)))
server = CascadeServer([
    CascadeTier(SMALL, v1, TierSpec("t1", "vote", 0.67, k=3, cost=1.0)),
    CascadeTier(BIG, v2, TierSpec("t2", "confidence", -1.0, k=1, cost=50.0)),
])
toks = np.random.default_rng(2).integers(0, 32, (6, 8)).astype(np.int32)
res = server.generate(toks, max_new_tokens=3)
print(json.dumps({"pred": res.pred.tolist(), "tier_of": res.tier_of.tolist(),
                  "cost": res.cost}))
"""


def _run(hashseed: str) -> dict:
    env = dict(os.environ, PYTHONHASHSEED=hashseed)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(os.path.dirname(__file__), "..", "src"),
                    env.get("PYTHONPATH")) if p
    )
    out = subprocess.run(
        [sys.executable, "-c", _SCRIPT], env=env, capture_output=True,
        text=True, timeout=600, check=True,
    )
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_stable_digest_is_not_salted():
    # fixed expected value: crc32 of little-endian int32 bytes, masked
    assert stable_digest(np.asarray([1, 2, 3], np.int32)) == 0x30E02293
    # dtype/layout canonicalization: int64 input digests identically
    a = np.asarray([5, 7, 11], np.int64)
    assert stable_digest(a) == stable_digest(a.astype(np.int32))


def test_digest_range_below_vote_sentinel():
    """vote_rule_from_preds tie-breaks via a 2**30 'not a candidate'
    sentinel; a digest >= 2**30 would BE the sentinel and the voted pred
    would match no member (regression: 31-bit digests silently elected
    member 0).  Digests must stay strictly below, and a majority at the
    top of the range must win the vote."""
    import jax.numpy as jnp

    from repro.core.deferral import vote_rule_from_preds

    rng = np.random.default_rng(3)
    for _ in range(50):
        assert stable_digest(rng.integers(0, 1 << 20, 8)) < 2**30
    top = (1 << 30) - 1  # max possible digest
    preds = jnp.asarray([[top], [top], [0x123]], jnp.int32)
    out = vote_rule_from_preds(preds, 0.5)
    assert int(out.pred[0]) == top


def test_digest_generations_shape_and_collision_freedom():
    rng = np.random.default_rng(0)
    out = rng.integers(0, 64, (3, 5, 4)).astype(np.int32)
    d = digest_generations(out)
    assert d.shape == (3, 5) and d.dtype == np.int32 and (d >= 0).all()
    # identical generations -> identical ids (that is what voting counts)
    out[1] = out[0]
    d = digest_generations(out)
    np.testing.assert_array_equal(d[0], d[1])


def test_generate_routing_identical_across_fresh_processes():
    """The regression the ISSUE names: `hash(bytes)` salting made the same
    member generations vote differently per process.  Two fresh interpreters
    with different PYTHONHASHSEED must produce bit-identical routing."""
    a = _run("0")
    b = _run("12345")
    assert a == b
