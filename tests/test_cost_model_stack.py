"""Tests for the measurement stack: the scan-aware jaxpr cost walker and
the hierarchical HLO collective parser (EXPERIMENTS.md §Roofline
methodology — each test pins one of the corrections documented there)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import config as kcfg
from repro.launch.jaxpr_cost import estimate_fn_cost
from repro.launch.roofline import parse_collectives, roofline_terms


# ---------------------------------------------------------------------------
# jaxpr walker
# ---------------------------------------------------------------------------


def test_matmul_flops_exact():
    a = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    b = jax.ShapeDtypeStruct((512, 128), jnp.float32)
    c = estimate_fn_cost(lambda x, y: x @ y, a, b)
    assert c["flops"] == 2 * 256 * 512 * 128


def test_scan_multiplies_trip_count():
    a = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def f(x):
        def body(c, _):
            return c @ x, None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    c1 = estimate_fn_cost(lambda x: x @ x, a)
    c10 = estimate_fn_cost(f, a)
    assert c10["flops"] >= 10 * c1["flops"]
    assert c10["flops"] < 11 * c1["flops"] + 64 * 64 * 20


def test_inner_jit_is_not_skipped():
    """Regression: this JAX names the pjit primitive 'jit'; kernel wrappers
    are jit-wrapped and must still be counted."""
    a = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    inner = jax.jit(lambda x: x @ x)
    c = estimate_fn_cost(lambda x: inner(x), a)
    assert c["flops"] >= 2 * 128**3


def test_dynamic_update_slice_charged_for_slice_only():
    buf = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    upd = jax.ShapeDtypeStruct((1, 1024), jnp.float32)
    c = estimate_fn_cost(
        lambda b, u: jax.lax.dynamic_update_slice(b, u, (5, 0)), buf, upd
    )
    # 2 * slice bytes, NOT the 4 MB buffer
    assert c["bytes"] <= 4 * 1024 * 2 + 1024
    assert c["bytes"] > 0


def test_pallas_kernel_block_traffic_counted():
    from repro.kernels.decode_attention import ops as dops

    B, KVH, S, hd, H = 2, 2, 2048, 64, 4
    q = jax.ShapeDtypeStruct((B, 1, H, hd), jnp.bfloat16)
    kc = jax.ShapeDtypeStruct((B, KVH, S, hd), jnp.bfloat16)
    with kcfg.use_impl("pallas"):
        c = estimate_fn_cost(
            lambda q, k, v: dops.decode_attention_bksd(q, k, v, 100), q, kc, kc
        )
    sweep = B * KVH * S * hd * 2 * 2  # k+v streamed once
    assert c["bytes"] >= sweep


def test_flash_kernel_flops_counted():
    from repro.kernels.flash_attention import ops as fops

    B, S, H, hd = 1, 512, 2, 64
    q = jax.ShapeDtypeStruct((B, S, H, hd), jnp.bfloat16)
    with kcfg.use_impl("pallas"):
        c = estimate_fn_cost(lambda q, k, v: fops.flash_attention(q, k, v), q, q, q)
    assert c["flops"] >= 2 * 2 * B * H * S * S * hd // 2  # at least causal half


# ---------------------------------------------------------------------------
# HLO collective parser
# ---------------------------------------------------------------------------

_FAKE_HLO = """HloModule test

%cond.1 (arg: (s32[], f32[8])) -> pred[] {
  %c = s32[] constant(7)
  ROOT %lt = pred[] compare(%iter, %c), direction=LT
}

%body.2 (arg: (s32[], f32[8])) -> (s32[], f32[8]) {
  %ar = f32[1024,32]{1,0} all-reduce(%x), replica_groups={}
  ROOT %t = (s32[], f32[8]) tuple(%i, %y)
}

ENTRY %main.3 (p0: f32[8]) -> f32[8] {
  %ag = bf16[64,128]{1,0} all-gather(%p0), dimensions={0}
  %w = (s32[], f32[8]) while(%init), condition=%cond.1, body=%body.2
  ROOT %out = f32[8] get-tuple-element(%w), index=1
}
"""


def test_parse_collectives_hierarchical():
    out = parse_collectives(_FAKE_HLO)
    assert out["all-gather"] == 64 * 128 * 2
    # the while body's all-reduce executes 7 times
    assert out["all-reduce"] == 7 * 1024 * 32 * 4


def test_parse_collectives_empty():
    out = parse_collectives("HloModule empty\n\nENTRY %m () -> f32[] {\n}\n")
    assert sum(out.values()) == 0


def test_roofline_terms_bottleneck():
    t = roofline_terms({"flops": 197e12, "bytes accessed": 1.0}, 0, 256)
    assert t["bottleneck"] == "compute" and abs(t["t_compute_s"] - 1.0) < 1e-9
    t2 = roofline_terms({"flops": 1.0, "bytes accessed": 819e9}, 0, 256)
    assert t2["bottleneck"] == "memory"
    t3 = roofline_terms({"flops": 0.0, "bytes accessed": 0.0}, 256 * 50e9, 256)
    assert t3["bottleneck"] == "collective" and abs(t3["t_collective_s"] - 1.0) < 1e-9
