import os
import sys

import pytest

# src layout without install (+ repo root for the benchmarks package)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# real single CPU device; only launch/dryrun.py forces 512 host devices.


@pytest.fixture
def trace_validation(request, monkeypatch):
    """Schema-validate every trace the test emits, even tests that never
    ask for tracing: Observability bundles built without an explicit
    tracer get a recording ``Tracer`` instead of the NullTracer, and at
    teardown each recorded stream must pass ``validate_trace`` (span
    nesting, per-track monotone timestamps, B/E pairing).  Terminal
    completes are not required — tests legitimately stop servers with
    requests in flight.  Opt a module in with
    ``pytestmark = pytest.mark.usefixtures("trace_validation")``."""
    from repro.obs import Observability
    from repro.obs.trace import Tracer, validate_trace

    recorded = []
    orig = Observability.__init__

    def patched(self, registry=None, tracer=None, clock=None):
        if tracer is None:
            tracer = Tracer()
            recorded.append(tracer)
        orig(self, registry, tracer, clock)

    monkeypatch.setattr(Observability, "__init__", patched)
    yield
    # tests that abort serving mid-request (e.g. an admission that raises)
    # leave spans legitimately open — they opt out per-test
    if request.node.get_closest_marker("no_trace_validation"):
        return
    for tr in recorded:
        validate_trace(tr.export(), require_terminal=False)
