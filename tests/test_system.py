"""End-to-end behaviour test of the paper's system: train tier models on a
mixture-difficulty task, calibrate the agreement threshold on ~100 samples
(App. B), build the drop-in cascade, and verify the paper's two headline
claims — accuracy >= the large model's (Prop 4.1.1 within epsilon) and cost
strictly below always-using-the-large-model (Prop 4.1.2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import calibration, deferral
from repro.core import ensemble as ens
from repro.core.cascade import TierSpec
from repro.data.synthetic import MixtureTask
from repro.models import api
from repro.models.params import unbox
from repro.optim.adamw import OptimConfig
from repro.serve import CascadeServer, CascadeTier
from repro.train import init_train_state, make_train_step

SMALL = ModelConfig(
    name="e2e-small", family="dense", n_layers=1, d_model=48, d_ff=96,
    vocab_size=256, n_heads=2, n_kv_heads=2, remat=False,
)
BIG = ModelConfig(
    name="e2e-big", family="dense", n_layers=2, d_model=128, d_ff=256,
    vocab_size=256, n_heads=4, n_kv_heads=4, remat=False,
)

TASK = MixtureTask(vocab=256, n_classes=16, seq_len=32, easy_frac=0.6, seed=0)


def _train_classifier(cfg, steps, rng_seed, lr=2e-3, n=2048, batch=64):
    """Train last-token classification via the LM loss (label in last slot)."""
    toks, labels, _ = TASK.sample(n, seed=rng_seed + 100)
    values, _ = unbox(api.init_params(cfg, jax.random.PRNGKey(rng_seed)))
    ocfg = OptimConfig(lr=lr, weight_decay=0.01)
    state = init_train_state(values, ocfg)
    step = jax.jit(make_train_step(cfg, ocfg, total_steps=steps, warmup_steps=10))
    rng = np.random.default_rng(rng_seed)
    mask = np.zeros((batch, TASK.seq_len), np.float32)
    mask[:, -1] = 1.0  # supervise only the final position
    for i in range(steps):
        idx = rng.integers(0, n, batch)
        tgt = np.zeros((batch, TASK.seq_len), np.int32)
        tgt[:, -1] = labels[idx]
        b = {"tokens": toks[idx], "targets": tgt, "mask": mask}
        state, m = step(state, b)
    return state.params


@pytest.fixture(scope="module")
def cascade():
    # ensemble of 3 small models (different seeds), 1 big model
    small_params = [_train_classifier(SMALL, 250, s) for s in (0, 1, 2)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *small_params)
    big_params = _train_classifier(BIG, 500, 7)
    big_stacked = jax.tree.map(lambda x: x[None], big_params)
    return stacked, big_stacked


def _acc(preds, y):
    return float((np.asarray(preds) == y).mean())


def test_end_to_end_drop_in_cascade(cascade):
    stacked, big_stacked = cascade
    # --- calibrate theta on ~100 held-out samples (App. B) ---
    cal_toks, cal_y, _ = TASK.sample(128, seed=999)
    logits = ens.ensemble_last_logits(stacked, {"tokens": jnp.asarray(cal_toks)}, SMALL)
    out = deferral.vote_rule(logits, theta=0.0)
    theta, info = calibration.estimate_threshold(
        np.asarray(out.score), np.asarray(out.pred) == cal_y, epsilon=0.05,
        n_samples=100,
    )

    # --- build and run the cascade on fresh test data ---
    test_toks, test_y, easy = TASK.sample(512, seed=1234)
    server = CascadeServer([
        CascadeTier(SMALL, stacked, TierSpec("small", "vote", theta, k=3, cost=1.0)),
        CascadeTier(BIG, big_stacked, TierSpec("big", "confidence", -1.0, k=1, cost=25.0)),
    ])
    res = server.classify(test_toks)

    big_logits = ens.ensemble_last_logits(
        big_stacked, {"tokens": jnp.asarray(test_toks)}, BIG
    )
    big_pred = np.asarray(big_logits[0].argmax(-1))
    acc_casc, acc_big = _acc(res.pred, test_y), _acc(big_pred, test_y)

    # Prop 4.1.1 within the calibrated epsilon (+ sampling slack)
    assert acc_casc >= acc_big - 0.08, (acc_casc, acc_big)
    # Prop 4.1.2: cheaper than always-large
    assert res.cost < 25.0 * len(test_toks), res.cost
    # a non-trivial fraction answered at tier 1 (the task has easy structure)
    assert res.tier_counts[0] > 0.2 * len(test_toks), res.tier_counts
    # selected-subset accuracy is high (safe deferral in action)
    sel = res.tier_of == 0
    if sel.any():
        assert _acc(res.pred[sel], test_y[sel]) >= acc_big - 0.05


def test_easy_examples_exit_earlier(cascade):
    stacked, big_stacked = cascade
    test_toks, test_y, easy = TASK.sample(512, seed=4321)
    server = CascadeServer([
        CascadeTier(SMALL, stacked, TierSpec("small", "vote", 0.67, k=3, cost=1.0)),
        CascadeTier(BIG, big_stacked, TierSpec("big", "confidence", -1.0, k=1, cost=25.0)),
    ])
    res = server.classify(test_toks)
    exit1 = res.tier_of == 0
    if exit1.any() and (~exit1).any():
        # easy fraction among tier-1 exits should exceed among deferrals
        assert easy[exit1].mean() > easy[~exit1].mean()
