"""benchmarks/run.py harness contract: a raising bench prints an ERROR row
but the process exits nonzero (CI's bench-smoke job depends on this), and
--smoke trims the timing loops without changing results plumbing."""
import sys
import types

import pytest


def test_run_exits_nonzero_when_a_bench_raises(monkeypatch, capsys):
    import benchmarks.run as br

    boom = types.ModuleType("benchmarks.bench_boom")
    boom.run = lambda verbose=True: (_ for _ in ()).throw(RuntimeError("rot"))
    ok = types.ModuleType("benchmarks.bench_ok")
    ok.run = lambda verbose=True: "bench_ok,1.0,fine"
    monkeypatch.setitem(sys.modules, "benchmarks.bench_boom", boom)
    monkeypatch.setitem(sys.modules, "benchmarks.bench_ok", ok)
    monkeypatch.setattr(br, "BENCHES", ["bench_ok", "bench_boom"])
    monkeypatch.setattr(sys, "argv", ["run.py", "--quiet"])
    with pytest.raises(SystemExit) as e:
        br.main()
    assert e.value.code == 1
    out = capsys.readouterr().out
    # the healthy bench still reported before the failure surfaced
    assert "bench_ok,1.0,fine" in out
    assert "bench_boom,nan,ERROR" in out


def test_smoke_flag_sets_env_and_quiet(monkeypatch):
    import os

    import benchmarks.run as br

    # setenv (not delenv) so pytest records the key and restores its
    # original absence at teardown even though main() overwrites it
    monkeypatch.setenv("REPRO_BENCH_SMOKE", "0")
    monkeypatch.setattr(br, "BENCHES", [])
    monkeypatch.setattr(sys, "argv", ["run.py", "--smoke"])
    br.main()
    assert os.environ.get("REPRO_BENCH_SMOKE") == "1"

    from benchmarks.common import smoke_mode

    assert smoke_mode()


def test_import_failure_reported_not_fatal(monkeypatch, capsys):
    """An import-time rot in one bench prints its ERROR row and the others
    still run (and the harness still exits nonzero)."""
    import benchmarks.run as br

    ok = types.ModuleType("benchmarks.bench_ok2")
    ok.run = lambda verbose=True: "bench_ok2,1.0,fine"
    monkeypatch.setitem(sys.modules, "benchmarks.bench_ok2", ok)
    monkeypatch.setattr(br, "BENCHES", ["bench_no_such_module", "bench_ok2"])
    monkeypatch.setattr(sys, "argv", ["run.py", "--quiet"])
    with pytest.raises(SystemExit) as e:
        br.main()
    assert e.value.code == 1
    out = capsys.readouterr().out
    assert "bench_no_such_module,nan,ERROR" in out
    assert "bench_ok2,1.0,fine" in out
