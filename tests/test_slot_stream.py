"""SlotStream equivalence harness.

The unified slot state machine (serve/slot_stream.py) must be *semantics-
free* infrastructure: for every model family and every ensemble width E,
a request served through a SlotStream — mid-stream admission, chunked
prefill, slot reuse and all — must emit exactly the tokens the same request
produces alone through the batch ``generate`` path (greedy).  Three
contracts are pinned here:

(a) stream == solo generate, per family x E in {1, 3};
(b) chunked-prefill admission == decode-only admission, token for token;
(c) back-to-back requests through a REUSED slot == fresh-engine runs for
    constant-state families (the slot state reset that lifts the
    attention-families-only restriction).
"""
import copy
import math

import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import ensemble as ens
from repro.core.cascade import TierSpec, prompt_chunks
from repro.models import api
from repro.models.params import unbox
from repro.serve import (
    CascadeServer,
    CascadeTier,
    PagePool,
    Request,
    ServingEngine,
    SlotStream,
    TierBackend,
)

# every Observability these tests build gets a recording tracer; its
# stream is schema-validated at teardown (tests/conftest.py)
pytestmark = pytest.mark.usefixtures("trace_validation")

_BASE = dict(n_layers=2, d_model=64, d_ff=128, vocab_size=64, remat=False)
CONFIGS = {
    "dense": ModelConfig(
        name="ss-dense", family="dense", n_heads=4, n_kv_heads=2, **_BASE
    ),
    # capacity_factor >= n_experts -> no token ever drops, so MoE routing is
    # per-token independent and every admission path is exactly equivalent
    "moe": ModelConfig(
        name="ss-moe", family="moe", n_heads=4, n_kv_heads=2, n_experts=4,
        top_k=2, capacity_factor=4.0, **_BASE
    ),
    "moe_interleaved": ModelConfig(
        name="ss-moe-il", family="moe", n_heads=4, n_kv_heads=2, n_experts=4,
        top_k=2, moe_every=2, capacity_factor=4.0, **_BASE
    ),
    "ssm_mamba2": ModelConfig(
        name="ss-mamba", family="ssm_mamba2", ssm_state=16, ssm_head_dim=32,
        **_BASE
    ),
    "ssm_rwkv6": ModelConfig(
        name="ss-rwkv", family="ssm_rwkv6", ssm_head_dim=32, rwkv_lora_rank=8,
        **_BASE
    ),
    "hybrid": ModelConfig(
        name="ss-hybrid", family="hybrid", n_heads=4, n_kv_heads=2,
        ssm_state=16, ssm_head_dim=32, attn_every=2, **_BASE
    ),
}
FAMILIES = list(CONFIGS)
CONSTANT_STATE = ["ssm_mamba2", "ssm_rwkv6", "hybrid"]


@pytest.fixture(scope="module")
def stacks():
    return {
        f: unbox(ens.init_ensemble(cfg, 3, jax.random.PRNGKey(i)))[0]
        for i, (f, cfg) in enumerate(CONFIGS.items())
    }


def _requests(seed, n, *, lo=4, hi=20, max_new=(2, 5)):
    rng = np.random.default_rng(seed)
    return [
        Request(
            tokens=rng.integers(0, 64, int(rng.integers(lo, hi))).astype(np.int32),
            max_new_tokens=int(rng.integers(*max_new)),
        )
        for _ in range(n)
    ]


# ---------------------------------------------------------------------------
# (a) stream == solo generate, per family x E
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("E", [1, 3])
@pytest.mark.parametrize("family", FAMILIES)
def test_stream_matches_solo_generate(family, E, stacks):
    cfg = CONFIGS[family]
    reqs = _requests(seed=100 + E, n=5)
    if E == 1:
        member = ens.take_member(stacks[family], 0)
        eng = ServingEngine(cfg, member, max_seq=64)
        done = eng.serve_continuous(
            [copy.deepcopy(r) for r in reqs], n_slots=2
        )
        assert eng.last_stream_stats["chunk_calls"] > 0, (
            "chunked-prefill admission must be exercised"
        )
        ref_eng = ServingEngine(cfg, member)
        by_rid = {d.rid: d for d in done}
        assert sorted(by_rid) == sorted(r.rid for r in reqs)
        for r in reqs:
            ref = ref_eng.generate(r.tokens[None, :], r.max_new_tokens)[0]
            np.testing.assert_array_equal(ref, by_rid[r.rid].output)
    else:
        tier = CascadeTier(cfg, stacks[family], TierSpec("t", "vote", 0.67, k=3))
        stream = SlotStream(
            TierBackend(tier, n_slots=2, max_seq=64), n_slots=2, max_seq=64
        )
        stream.submit([copy.deepcopy(r) for r in reqs])
        got = {r.rid: gen for r, gen in stream.drain()}
        assert stream.stats["chunk_calls"] > 0
        assert sorted(got) == sorted(r.rid for r in reqs)
        for r in reqs:
            # every member's stream row == that member's vmapped generation
            ref = tier.generate(r.tokens[None, :], r.max_new_tokens)  # (E,1,T)
            np.testing.assert_array_equal(ref[:, 0, :], got[r.rid])


# ---------------------------------------------------------------------------
# (b) chunked-prefill admission == decode-only admission
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("family", FAMILIES)
def test_chunked_matches_decode_only_admission(family, stacks):
    cfg = CONFIGS[family]
    member = ens.take_member(stacks[family], 0)
    eng = ServingEngine(cfg, member, max_seq=64)
    # include a prompt long enough to need several pow2 buckets
    reqs = _requests(seed=7, n=4, lo=4, hi=16)
    reqs.append(
        Request(
            tokens=np.random.default_rng(8).integers(0, 64, 33).astype(np.int32),
            max_new_tokens=4,
        )
    )
    chunked = eng.serve_continuous(
        [copy.deepcopy(r) for r in reqs], n_slots=2, chunked_prefill=True
    )
    assert eng.last_stream_stats["chunk_tokens"] >= 32
    plain = eng.serve_continuous(
        [copy.deepcopy(r) for r in reqs], n_slots=2, chunked_prefill=False
    )
    assert eng.last_stream_stats["chunk_calls"] == 0
    a = {r.rid: r for r in chunked}
    b = {r.rid: r for r in plain}
    assert sorted(a) == sorted(b)
    for rid in a:
        np.testing.assert_array_equal(a[rid].output, b[rid].output)


# ---------------------------------------------------------------------------
# (c) slot reuse isolation for constant-state families
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("chunked", [True, False])
@pytest.mark.parametrize("family", CONSTANT_STATE)
def test_slot_reuse_matches_fresh_engine(family, chunked, stacks):
    """n_slots=1 forces every request back-to-back through the SAME slot;
    outputs must equal fresh-engine runs, proving the admitted slot's state
    leaves are zeroed (SSM/RWKV state is not pos-masked)."""
    cfg = CONFIGS[family]
    assert api.has_slot_state(cfg)
    member = ens.take_member(stacks[family], 0)
    eng = ServingEngine(cfg, member, max_seq=64)
    reqs = _requests(seed=21, n=3, max_new=(3, 5))
    done = eng.serve_continuous(
        [copy.deepcopy(r) for r in reqs], n_slots=1, chunked_prefill=chunked
    )
    by_rid = {d.rid: d for d in done}
    ref_eng = ServingEngine(cfg, member)
    for r in reqs:
        ref = ref_eng.generate(r.tokens[None, :], r.max_new_tokens)[0]
        np.testing.assert_array_equal(ref, by_rid[r.rid].output)


# ---------------------------------------------------------------------------
# chunk sizing: exact pow2 cover from the O(log S) bucket set
# ---------------------------------------------------------------------------


def test_prompt_chunks_exact_pow2_cover():
    for n in (1, 2, 3, 7, 8, 20, 255, 256, 257, 1000):
        sizes = prompt_chunks(n, max_chunk=256)
        assert sum(sizes) == n, "prompt chunks must tile exactly (no overshoot)"
        assert all(c & (c - 1) == 0 for c in sizes), "chunks must be pow2"
        assert all(c <= 256 for c in sizes)
    # a 256-token prompt admits in <= ceil(log2(256)) bucket calls
    assert len(prompt_chunks(255)) <= math.ceil(math.log2(256))


# ---------------------------------------------------------------------------
# force-complete: the cache wall sets the truncated flag
# ---------------------------------------------------------------------------


def test_truncated_flag_on_cache_wall(stacks):
    cfg = CONFIGS["dense"]
    member = ens.take_member(stacks["dense"], 0)
    eng = ServingEngine(cfg, member, max_seq=16)
    rng = np.random.default_rng(31)
    big = Request(tokens=rng.integers(0, 64, 8).astype(np.int32), max_new_tokens=32)
    small = Request(tokens=rng.integers(0, 64, 8).astype(np.int32), max_new_tokens=2)
    done = {r.rid: r for r in eng.serve_continuous([big, small], n_slots=2)}
    assert done[big.rid].truncated, "hitting pos >= max_seq-1 must flag truncation"
    assert len(done[big.rid].output) < 32
    assert not done[small.rid].truncated
    assert len(done[small.rid].output) == 2


# ---------------------------------------------------------------------------
# block-paged pools: paged serving == dense oracle, conservation, the wall
# ---------------------------------------------------------------------------

PAGED_FAMILIES = [f for f in FAMILIES if api.supports_paging(CONFIGS[f])]
FALLBACK_FAMILIES = [f for f in FAMILIES if not api.supports_paging(CONFIGS[f])]


def _prefix_requests(seed, n, prefix_len, *, tail_hi=12, max_new=(2, 5)):
    """Ragged prompts all sharing the same ``prefix_len``-token prefix."""
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, 64, prefix_len).astype(np.int32)
    reqs = []
    for _ in range(n):
        tail = rng.integers(0, 64, int(rng.integers(1, tail_hi))).astype(np.int32)
        reqs.append(
            Request(
                tokens=np.concatenate([prefix, tail]),
                max_new_tokens=int(rng.integers(*max_new)),
            )
        )
    return reqs


@pytest.mark.parametrize("E", [1, 3])
@pytest.mark.parametrize("family", PAGED_FAMILIES)
def test_paged_matches_dense_oracle(family, E, stacks):
    """Block-paged serving (page_size=8, prompts sharing a >=2-page prefix,
    ragged fillers) emits bitwise the dense-slot-cache oracle's tokens, the
    prefix index actually shares pages, and every page returns to the free
    list once the stream drains."""
    cfg = CONFIGS[family]
    reqs = _requests(seed=50 + E, n=3, lo=4, hi=20) + _prefix_requests(
        seed=60 + E, n=4, prefix_len=17
    )
    outs = {}
    for paged in (True, False):
        if E == 1:
            member = ens.take_member(stacks[family], 0)
            eng = ServingEngine(cfg, member, max_seq=64)
            stream = eng.slot_stream(n_slots=2, paged=paged, page_size=8)
        else:
            tier = CascadeTier(
                cfg, stacks[family], TierSpec("t", "vote", 0.67, k=3)
            )
            stream = SlotStream(
                TierBackend(
                    tier, n_slots=2, max_seq=64, paged=paged, page_size=8
                ),
                n_slots=2,
                max_seq=64,
            )
        assert stream.backend.paged is paged
        stream.submit([copy.deepcopy(r) for r in reqs])
        outs[paged] = {r.rid: gen for r, gen in stream.drain()}
        if paged:
            pool = stream.backend.pool
            # the 17-token shared prefix spans two full pages; later prefix
            # requests admit while an earlier holder is still resident
            assert pool.stats["shared_hits"] >= 2
            assert stream.stats["shared_tokens"] >= 16
            assert pool.pages_in_use == 0, "drained stream must free all pages"
            pool.assert_conserved()
    assert sorted(outs[True]) == sorted(outs[False])
    for rid in outs[True]:
        np.testing.assert_array_equal(outs[True][rid], outs[False][rid])


def test_paged_pool_wall_forces_completion(stacks):
    """A pool too small for the offered load: admission fails while a slot
    is free (request re-queued), growth fails mid-decode (slot is force-
    completed with truncated=True), everything still completes exactly once
    and the free list ends conserved with zero pages mapped."""
    cfg = CONFIGS["dense"]
    member = ens.take_member(stacks["dense"], 0)
    eng = ServingEngine(cfg, member, max_seq=64)
    rng = np.random.default_rng(71)
    reqs = [
        Request(tokens=rng.integers(0, 64, 9).astype(np.int32), max_new_tokens=40)
        for _ in range(2)
    ]
    # 3 allocatable pages + sink; each prompt needs 2 pages at admission
    stream = eng.slot_stream(n_slots=2, paged=True, page_size=8, n_pages=4)
    stream.submit([copy.deepcopy(r) for r in reqs])
    done = {r.rid: r for r, _ in stream.drain()}
    pool = stream.backend.pool
    assert sorted(done) == sorted(r.rid for r in reqs)
    assert all(d.truncated for d in done.values()), "the wall must truncate"
    assert stream.stats["forced_completions"] == 2
    assert stream.stats["admit_failures"] >= 1
    assert pool.pages_in_use == 0
    pool.assert_conserved()


@pytest.mark.no_trace_validation  # aborts admission: queue_wait stays open
def test_paged_pool_too_small_for_prompt_raises(stacks):
    """A prompt needing more pages than the whole pool can never admit —
    with every slot free that is a configuration error, not a retry."""
    cfg = CONFIGS["dense"]
    member = ens.take_member(stacks["dense"], 0)
    eng = ServingEngine(cfg, member, max_seq=64)
    stream = eng.slot_stream(n_slots=1, paged=True, page_size=8, n_pages=3)
    stream.submit([
        Request(
            tokens=np.arange(17, dtype=np.int32) % 64, max_new_tokens=2
        )
    ])
    with pytest.raises(RuntimeError, match="pool"):
        list(stream.drain())


@pytest.mark.parametrize("family", FALLBACK_FAMILIES)
def test_state_families_fall_back_to_dense(family, stacks):
    """Constant-state families (SSM/RWKV/hybrid) have no paged path yet;
    paged=None must auto-select the dense slot cache and still serve."""
    cfg = CONFIGS[family]
    assert not api.supports_paging(cfg)
    member = ens.take_member(stacks[family], 0)
    eng = ServingEngine(cfg, member, max_seq=64)
    stream = eng.slot_stream(n_slots=2)
    assert stream.backend.paged is False
    reqs = _requests(seed=81, n=2)
    stream.submit([copy.deepcopy(r) for r in reqs])
    done = {r.rid: gen for r, gen in stream.drain()}
    assert sorted(done) == sorted(r.rid for r in reqs)


# ---------------------------------------------------------------------------
# PagePool mechanics (host-side, no model)
# ---------------------------------------------------------------------------


def test_page_pool_admit_share_release_conserves():
    pool = PagePool(8, 4, n_slots=3, max_seq=16)
    toks = list(range(11))  # m=10 -> 2 full pages, 3 pages mapped
    assert pool.admit(0, toks) == 0, "cold admission shares nothing"
    pool.assert_conserved()
    assert pool.admit(1, toks) == 8, "both full prefix pages hit"
    assert pool.stats["shared_hits"] == 2
    assert pool.shared_pages_saved() == 2
    pool.assert_conserved()
    # prompt diverging inside page 1: only page 0 is shareable
    toks2 = list(range(4)) + [63, 62, 61, 60, 59, 58, 57]
    assert pool.admit(2, toks2) == 4
    assert pool.stats["shared_hits"] == 3
    pool.assert_conserved()
    for s in range(3):
        pool.release(s)
    pool.assert_conserved()
    assert pool.pages_in_use == 0
    assert pool.free_pages == 7  # everything but the overflow sink


def test_page_pool_cow_and_unregister_guard_shared_pages():
    """Serving never writes into a registered page (writes start at or past
    the full-page prefix), but the pool still guards the case: a write into
    a multi-owner page COW-splits it, and a solo-owner write unregisters the
    page before it mutates so later admissions cannot share stale content."""
    pool = PagePool(8, 4, n_slots=2, max_seq=16)
    toks = list(range(9))  # m=8: pages 0,1 registered, page 2 private
    pool.admit(0, toks)
    pool.admit(1, toks)
    ok, copies = pool.prepare(1, 5)  # pos 5 -> page index 1, refcount 2
    assert ok and len(copies) == 1
    src, dst = copies[0]
    assert int(pool.table[0, 1]) == src != dst == int(pool.table[1, 1])
    assert pool.stats["cow_copies"] == 1
    pool.assert_conserved()
    pool.release(1)
    ok, copies = pool.prepare(0, 1)  # now solo-owned: no copy, unregister
    assert ok and copies == []
    pool.assert_conserved()
    # the mutated page no longer serves the prefix index: nothing shared
    assert pool.admit(1, toks) == 0
    pool.assert_conserved()
    pool.release(0)
    pool.release(1)
    assert pool.pages_in_use == 0
    pool.assert_conserved()


def test_page_pool_admission_rollback_frees_everything():
    pool = PagePool(4, 4, n_slots=2, max_seq=16)  # 3 allocatable pages
    toks = list(range(8))  # m=7: 1 full page, 2 pages mapped
    assert pool.admit(0, toks) == 0  # takes 2 pages, 1 free
    assert pool.admit(1, toks) == 4  # shares page 0 + allocs 1: 0 free
    pool.release(1)  # back to 1 free page
    # a non-sharing prompt needing 2 fresh pages cannot fit -> full rollback
    assert pool.admit(1, [63] * 8, share=False) is None
    assert pool.stats["admit_failures"] == 1
    assert np.all(pool.table[1] < 0), "failed admission must leave row empty"
    pool.assert_conserved()


# ---------------------------------------------------------------------------
# cascade end-to-end: deferrals admitted mid-stream by the next tier
# ---------------------------------------------------------------------------


def test_cascade_defer_completes_exactly_once(stacks):
    """Tier-0 members are independent (untrained -> essentially never
    agree), so every request is deferred and re-admitted mid-stream into
    tier-1 slots; each must complete exactly once with tier-1's answer.
    Tier-0 is a constant-state RWKV tier — the lifted family restriction
    in action."""
    rw_cfg = CONFIGS["ssm_rwkv6"]
    d_cfg = CONFIGS["dense"]
    tier1 = CascadeTier(
        d_cfg,
        jax.tree.map(lambda v: v[:1], stacks["dense"]),
        TierSpec("t1", "confidence", -1.0, k=1, cost=10.0),
    )
    server = CascadeServer([
        CascadeTier(rw_cfg, stacks["ssm_rwkv6"], TierSpec("t0", "vote", 0.67, k=3)),
        tier1,
    ])
    reqs = _requests(seed=41, n=5, lo=4, hi=10, max_new=(4, 5))
    done = server.serve_continuous(
        [copy.deepcopy(r) for r in reqs], n_slots=2, max_seq=32
    )
    assert sorted(r.rid for r in done) == sorted(r.rid for r in reqs)
    assert all(r.tier == 1 for r in done), "untrained members never agree"
    for r, d in zip(reqs, sorted(done, key=lambda x: x.rid)):
        # the k=1 top tier's answer is member 0's own generation
        ref = tier1.generate(r.tokens[None, :], r.max_new_tokens)[0, 0]
        np.testing.assert_array_equal(ref, d.output)


def test_cascade_agreement_answers_at_tier0(stacks):
    """Identical tier-0 members always agree: nothing reaches tier-1."""
    d_cfg = CONFIGS["dense"]
    one = ens.take_member(stacks["dense"], 0)
    same = jax.tree.map(lambda x: jax.numpy.stack([x, x, x]), one)
    server = CascadeServer([
        CascadeTier(d_cfg, same, TierSpec("t0", "vote", 0.9, k=3)),
        CascadeTier(
            d_cfg,
            jax.tree.map(lambda v: v[:1], stacks["dense"]),
            TierSpec("t1", "confidence", -1.0, k=1),
        ),
    ])
    reqs = _requests(seed=43, n=4, lo=4, hi=10, max_new=(3, 4))
    done = server.serve_continuous(
        [copy.deepcopy(r) for r in reqs], n_slots=2, max_seq=32
    )
    assert sorted(r.rid for r in done) == sorted(r.rid for r in reqs)
    assert all(r.tier == 0 for r in done)
    eng = ServingEngine(d_cfg, one)
    for r, d in zip(reqs, sorted(done, key=lambda x: x.rid)):
        ref = eng.generate(r.tokens[None, :], r.max_new_tokens)[0]
        np.testing.assert_array_equal(ref, d.output)
