"""SlotStream equivalence harness.

The unified slot state machine (serve/slot_stream.py) must be *semantics-
free* infrastructure: for every model family and every ensemble width E,
a request served through a SlotStream — mid-stream admission, chunked
prefill, slot reuse and all — must emit exactly the tokens the same request
produces alone through the batch ``generate`` path (greedy).  Three
contracts are pinned here:

(a) stream == solo generate, per family x E in {1, 3};
(b) chunked-prefill admission == decode-only admission, token for token;
(c) back-to-back requests through a REUSED slot == fresh-engine runs for
    constant-state families (the slot state reset that lifts the
    attention-families-only restriction).
"""
import copy
import math

import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import ensemble as ens
from repro.core.cascade import TierSpec, prompt_chunks
from repro.models import api
from repro.models.params import unbox
from repro.serve import (
    CascadeServer,
    CascadeTier,
    Request,
    ServingEngine,
    SlotStream,
    TierBackend,
)

_BASE = dict(n_layers=2, d_model=64, d_ff=128, vocab_size=64, remat=False)
CONFIGS = {
    "dense": ModelConfig(
        name="ss-dense", family="dense", n_heads=4, n_kv_heads=2, **_BASE
    ),
    # capacity_factor >= n_experts -> no token ever drops, so MoE routing is
    # per-token independent and every admission path is exactly equivalent
    "moe": ModelConfig(
        name="ss-moe", family="moe", n_heads=4, n_kv_heads=2, n_experts=4,
        top_k=2, capacity_factor=4.0, **_BASE
    ),
    "moe_interleaved": ModelConfig(
        name="ss-moe-il", family="moe", n_heads=4, n_kv_heads=2, n_experts=4,
        top_k=2, moe_every=2, capacity_factor=4.0, **_BASE
    ),
    "ssm_mamba2": ModelConfig(
        name="ss-mamba", family="ssm_mamba2", ssm_state=16, ssm_head_dim=32,
        **_BASE
    ),
    "ssm_rwkv6": ModelConfig(
        name="ss-rwkv", family="ssm_rwkv6", ssm_head_dim=32, rwkv_lora_rank=8,
        **_BASE
    ),
    "hybrid": ModelConfig(
        name="ss-hybrid", family="hybrid", n_heads=4, n_kv_heads=2,
        ssm_state=16, ssm_head_dim=32, attn_every=2, **_BASE
    ),
}
FAMILIES = list(CONFIGS)
CONSTANT_STATE = ["ssm_mamba2", "ssm_rwkv6", "hybrid"]


@pytest.fixture(scope="module")
def stacks():
    return {
        f: unbox(ens.init_ensemble(cfg, 3, jax.random.PRNGKey(i)))[0]
        for i, (f, cfg) in enumerate(CONFIGS.items())
    }


def _requests(seed, n, *, lo=4, hi=20, max_new=(2, 5)):
    rng = np.random.default_rng(seed)
    return [
        Request(
            tokens=rng.integers(0, 64, int(rng.integers(lo, hi))).astype(np.int32),
            max_new_tokens=int(rng.integers(*max_new)),
        )
        for _ in range(n)
    ]


# ---------------------------------------------------------------------------
# (a) stream == solo generate, per family x E
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("E", [1, 3])
@pytest.mark.parametrize("family", FAMILIES)
def test_stream_matches_solo_generate(family, E, stacks):
    cfg = CONFIGS[family]
    reqs = _requests(seed=100 + E, n=5)
    if E == 1:
        member = ens.take_member(stacks[family], 0)
        eng = ServingEngine(cfg, member, max_seq=64)
        done = eng.serve_continuous(
            [copy.deepcopy(r) for r in reqs], n_slots=2
        )
        assert eng.last_stream_stats["chunk_calls"] > 0, (
            "chunked-prefill admission must be exercised"
        )
        ref_eng = ServingEngine(cfg, member)
        by_rid = {d.rid: d for d in done}
        assert sorted(by_rid) == sorted(r.rid for r in reqs)
        for r in reqs:
            ref = ref_eng.generate(r.tokens[None, :], r.max_new_tokens)[0]
            np.testing.assert_array_equal(ref, by_rid[r.rid].output)
    else:
        tier = CascadeTier(cfg, stacks[family], TierSpec("t", "vote", 0.67, k=3))
        stream = SlotStream(
            TierBackend(tier, n_slots=2, max_seq=64), n_slots=2, max_seq=64
        )
        stream.submit([copy.deepcopy(r) for r in reqs])
        got = {r.rid: gen for r, gen in stream.drain()}
        assert stream.stats["chunk_calls"] > 0
        assert sorted(got) == sorted(r.rid for r in reqs)
        for r in reqs:
            # every member's stream row == that member's vmapped generation
            ref = tier.generate(r.tokens[None, :], r.max_new_tokens)  # (E,1,T)
            np.testing.assert_array_equal(ref[:, 0, :], got[r.rid])


# ---------------------------------------------------------------------------
# (b) chunked-prefill admission == decode-only admission
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("family", FAMILIES)
def test_chunked_matches_decode_only_admission(family, stacks):
    cfg = CONFIGS[family]
    member = ens.take_member(stacks[family], 0)
    eng = ServingEngine(cfg, member, max_seq=64)
    # include a prompt long enough to need several pow2 buckets
    reqs = _requests(seed=7, n=4, lo=4, hi=16)
    reqs.append(
        Request(
            tokens=np.random.default_rng(8).integers(0, 64, 33).astype(np.int32),
            max_new_tokens=4,
        )
    )
    chunked = eng.serve_continuous(
        [copy.deepcopy(r) for r in reqs], n_slots=2, chunked_prefill=True
    )
    assert eng.last_stream_stats["chunk_tokens"] >= 32
    plain = eng.serve_continuous(
        [copy.deepcopy(r) for r in reqs], n_slots=2, chunked_prefill=False
    )
    assert eng.last_stream_stats["chunk_calls"] == 0
    a = {r.rid: r for r in chunked}
    b = {r.rid: r for r in plain}
    assert sorted(a) == sorted(b)
    for rid in a:
        np.testing.assert_array_equal(a[rid].output, b[rid].output)


# ---------------------------------------------------------------------------
# (c) slot reuse isolation for constant-state families
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("chunked", [True, False])
@pytest.mark.parametrize("family", CONSTANT_STATE)
def test_slot_reuse_matches_fresh_engine(family, chunked, stacks):
    """n_slots=1 forces every request back-to-back through the SAME slot;
    outputs must equal fresh-engine runs, proving the admitted slot's state
    leaves are zeroed (SSM/RWKV state is not pos-masked)."""
    cfg = CONFIGS[family]
    assert api.has_slot_state(cfg)
    member = ens.take_member(stacks[family], 0)
    eng = ServingEngine(cfg, member, max_seq=64)
    reqs = _requests(seed=21, n=3, max_new=(3, 5))
    done = eng.serve_continuous(
        [copy.deepcopy(r) for r in reqs], n_slots=1, chunked_prefill=chunked
    )
    by_rid = {d.rid: d for d in done}
    ref_eng = ServingEngine(cfg, member)
    for r in reqs:
        ref = ref_eng.generate(r.tokens[None, :], r.max_new_tokens)[0]
        np.testing.assert_array_equal(ref, by_rid[r.rid].output)


# ---------------------------------------------------------------------------
# chunk sizing: exact pow2 cover from the O(log S) bucket set
# ---------------------------------------------------------------------------


def test_prompt_chunks_exact_pow2_cover():
    for n in (1, 2, 3, 7, 8, 20, 255, 256, 257, 1000):
        sizes = prompt_chunks(n, max_chunk=256)
        assert sum(sizes) == n, "prompt chunks must tile exactly (no overshoot)"
        assert all(c & (c - 1) == 0 for c in sizes), "chunks must be pow2"
        assert all(c <= 256 for c in sizes)
    # a 256-token prompt admits in <= ceil(log2(256)) bucket calls
    assert len(prompt_chunks(255)) <= math.ceil(math.log2(256))


# ---------------------------------------------------------------------------
# force-complete: the cache wall sets the truncated flag
# ---------------------------------------------------------------------------


def test_truncated_flag_on_cache_wall(stacks):
    cfg = CONFIGS["dense"]
    member = ens.take_member(stacks["dense"], 0)
    eng = ServingEngine(cfg, member, max_seq=16)
    rng = np.random.default_rng(31)
    big = Request(tokens=rng.integers(0, 64, 8).astype(np.int32), max_new_tokens=32)
    small = Request(tokens=rng.integers(0, 64, 8).astype(np.int32), max_new_tokens=2)
    done = {r.rid: r for r in eng.serve_continuous([big, small], n_slots=2)}
    assert done[big.rid].truncated, "hitting pos >= max_seq-1 must flag truncation"
    assert len(done[big.rid].output) < 32
    assert not done[small.rid].truncated
    assert len(done[small.rid].output) == 2


# ---------------------------------------------------------------------------
# cascade end-to-end: deferrals admitted mid-stream by the next tier
# ---------------------------------------------------------------------------


def test_cascade_defer_completes_exactly_once(stacks):
    """Tier-0 members are independent (untrained -> essentially never
    agree), so every request is deferred and re-admitted mid-stream into
    tier-1 slots; each must complete exactly once with tier-1's answer.
    Tier-0 is a constant-state RWKV tier — the lifted family restriction
    in action."""
    rw_cfg = CONFIGS["ssm_rwkv6"]
    d_cfg = CONFIGS["dense"]
    tier1 = CascadeTier(
        d_cfg,
        jax.tree.map(lambda v: v[:1], stacks["dense"]),
        TierSpec("t1", "confidence", -1.0, k=1, cost=10.0),
    )
    server = CascadeServer([
        CascadeTier(rw_cfg, stacks["ssm_rwkv6"], TierSpec("t0", "vote", 0.67, k=3)),
        tier1,
    ])
    reqs = _requests(seed=41, n=5, lo=4, hi=10, max_new=(4, 5))
    done = server.serve_continuous(
        [copy.deepcopy(r) for r in reqs], n_slots=2, max_seq=32
    )
    assert sorted(r.rid for r in done) == sorted(r.rid for r in reqs)
    assert all(r.tier == 1 for r in done), "untrained members never agree"
    for r, d in zip(reqs, sorted(done, key=lambda x: x.rid)):
        # the k=1 top tier's answer is member 0's own generation
        ref = tier1.generate(r.tokens[None, :], r.max_new_tokens)[0, 0]
        np.testing.assert_array_equal(ref, d.output)


def test_cascade_agreement_answers_at_tier0(stacks):
    """Identical tier-0 members always agree: nothing reaches tier-1."""
    d_cfg = CONFIGS["dense"]
    one = ens.take_member(stacks["dense"], 0)
    same = jax.tree.map(lambda x: jax.numpy.stack([x, x, x]), one)
    server = CascadeServer([
        CascadeTier(d_cfg, same, TierSpec("t0", "vote", 0.9, k=3)),
        CascadeTier(
            d_cfg,
            jax.tree.map(lambda v: v[:1], stacks["dense"]),
            TierSpec("t1", "confidence", -1.0, k=1),
        ),
    ])
    reqs = _requests(seed=43, n=4, lo=4, hi=10, max_new=(3, 4))
    done = server.serve_continuous(
        [copy.deepcopy(r) for r in reqs], n_slots=2, max_seq=32
    )
    assert sorted(r.rid for r in done) == sorted(r.rid for r in reqs)
    assert all(r.tier == 0 for r in done)
    eng = ServingEngine(d_cfg, one)
    for r, d in zip(reqs, sorted(done, key=lambda x: x.rid)):
        ref = eng.generate(r.tokens[None, :], r.max_new_tokens)[0]
        np.testing.assert_array_equal(ref, d.output)
