"""Compaction kernel correctness: interpret-mode Pallas and the XLA
fallback vs the ref.py oracle across mask densities, plus the routing
round-trip (compact -> route -> scatter-back) permutation identity.

Unlike the V-sweep kernels, compaction shapes are serving-batch sized, so
the interpret-mode runs are cheap enough to live in tier 1 unmarked."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import config as kcfg
from repro.kernels.compaction import ops as comp_ops, ref as comp_ref

IMPLS = ["xla", "pallas_interpret"]


def _masks(B, rng):
    """The densities the routing layer actually produces: nothing deferred,
    everything deferred, and ragged middles."""
    return {
        "0%": np.zeros(B, bool),
        "100%": np.ones(B, bool),
        "one": np.eye(1, B, 3, dtype=bool)[0],
        "ragged30": rng.random(B) < 0.3,
        "ragged70": rng.random(B) < 0.7,
        "run": np.array([i % 5 < 2 for i in range(B)]),
    }


@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize(
    # 600 pads to 640: exercises the block_d divisor choice above one tile
    "B,D", [(8, 4), (13, 7), (64, 130), (100, 1), (16, 600)],
)
def test_compact_matches_ref(impl, B, D):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(B, D)).astype(np.float32))
    for name, m in _masks(B, rng).items():
        mask = jnp.asarray(m)
        r_out, r_im, r_cnt = comp_ref.compact_ref(x, mask)
        with kcfg.use_impl(impl):
            out, im, cnt = comp_ops.compact(x, mask)
        np.testing.assert_array_equal(np.asarray(cnt), np.asarray(r_cnt), err_msg=name)
        np.testing.assert_array_equal(np.asarray(im), np.asarray(r_im), err_msg=name)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(r_out), err_msg=name)


@pytest.mark.parametrize("impl", IMPLS)
def test_compact_int_payload_exact(impl):
    """Integer payloads are exact at ANY value: they route through the
    index-map gather, never the f32 matmul (which rounds above 2**24)."""
    rng = np.random.default_rng(1)
    toks = np.asarray(rng.integers(0, 250_000, (23, 9)), np.int32)
    # values the f32 route would corrupt: 2**24 + 1 rounds to 2**24
    toks[0, 0] = 2**24 + 1
    toks[5, 3] = 2**31 - 1
    toks = jnp.asarray(toks)
    mask = np.zeros(23, bool)
    mask[[0, 5, 7]] = True
    mask = jnp.asarray(mask)
    with kcfg.use_impl(impl):
        out, im, cnt = comp_ops.compact(toks, mask)
    assert out.dtype == jnp.int32
    n = int(cnt)
    src = np.flatnonzero(np.asarray(mask))
    np.testing.assert_array_equal(np.asarray(im)[:n], src)
    np.testing.assert_array_equal(np.asarray(out)[:n], np.asarray(toks)[src])
    assert (np.asarray(im)[n:] == -1).all()


@pytest.mark.parametrize("impl", IMPLS)
def test_compact_tree_shares_index_map(impl):
    rng = np.random.default_rng(2)
    tree = {
        "tokens": jnp.asarray(rng.integers(0, 64, (17, 12)).astype(np.int32)),
        "feat": jnp.asarray(rng.normal(size=(17, 3, 5)).astype(np.float32)),
        "idx": jnp.arange(17, dtype=jnp.int32),
    }
    mask = jnp.asarray(rng.random(17) < 0.5)
    with kcfg.use_impl(impl):
        ctree, im, cnt = comp_ops.compact_tree(tree, mask)
    n = int(cnt)
    src = np.flatnonzero(np.asarray(mask))
    np.testing.assert_array_equal(np.asarray(ctree["idx"])[:n], src)
    np.testing.assert_array_equal(
        np.asarray(ctree["feat"])[:n], np.asarray(tree["feat"])[src]
    )
    assert ctree["feat"].shape == tree["feat"].shape  # static shapes for jit


@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize("seed", range(8))
def test_compact_route_scatter_roundtrip(impl, seed):
    """Property: compact -> process-per-deferred-row -> scatter-back is a
    permutation identity on the deferred rows and leaves the rest alone —
    the invariant the routed cascade's bookkeeping rests on."""
    rng = np.random.default_rng(seed)
    B = int(rng.integers(4, 60))
    vals = jnp.asarray(rng.normal(size=(B,)).astype(np.float32))
    mask = jnp.asarray(rng.random(B) < rng.random())
    with kcfg.use_impl(impl):
        out, im, cnt = comp_ops.compact(vals, mask)
    n = int(cnt)
    # 'route': an arbitrary per-row transform of the compacted payload
    routed = out[:n] * 2.0 + 1.0
    back = comp_ops.scatter_back(routed, im[:n], B)
    expect = np.where(np.asarray(mask), np.asarray(vals) * 2.0 + 1.0, 0.0)
    np.testing.assert_allclose(np.asarray(back), expect, rtol=1e-6, atol=1e-6)
    # the index map is a permutation of exactly the deferred rows
    src = np.flatnonzero(np.asarray(mask))
    np.testing.assert_array_equal(np.sort(np.asarray(im)[:n]), src)
