"""Hypothesis stateful property suite for ``serve/paging.py``.

A ``RuleBasedStateMachine`` drives a small ``PagePool`` (chosen so
exhaustion is common) through random admit / prefix-share / COW-prepare /
extend / truncate / release sequences, mirrored step-for-step by a
dict-based oracle allocator that models only the SEMANTICS — which slot
spans are covered, which prefixes are shared, which pages are live — and
none of the mechanics (free-list order, page ids, crc keys).  After every
rule the pool must agree with the oracle on every observable (occupancy,
sharing savings, per-slot coverage, the return value of the operation
itself), ``assert_conserved`` must hold, and exhaustion must be reported
via return values (None/False), never by raising.

Hypothesis is an optional dev dependency (requirements-dev.txt): this
module import-skips without it and runs for real in the CI lane that sets
``REPRO_REQUIRE_HYPOTHESIS`` (see tests/test_hypothesis_gate.py).
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")

from hypothesis import settings, strategies as st  # noqa: E402
from hypothesis.stateful import (  # noqa: E402
    RuleBasedStateMachine, invariant, rule,
)

from repro.serve.paging import PagePool  # noqa: E402

N_PAGES = 8          # 7 allocatable: 3 slots x 4 pages/slot oversubscribes
PAGE_SIZE = 4
N_SLOTS = 3
MAX_SEQ = 16
PAGES_PER_SLOT = MAX_SEQ // PAGE_SIZE
CAPACITY = N_PAGES - 1

# tiny alphabet + canned stems -> prefix collisions are common, so the
# sharing rules actually fire instead of always missing the index
tokens_st = st.lists(
    st.integers(min_value=1, max_value=3), min_size=1, max_size=MAX_SEQ
)
slot_st = st.integers(min_value=0, max_value=N_SLOTS - 1)


class _Oracle:
    """Dict/counter model of the pool: page ids are synthetic ints, state
    is {pid: refcount}, {pid: registered key}, {key: pid}, and per-slot
    entry lists (table index -> pid)."""

    def __init__(self):
        self.ref = {}
        self.key_of = {}
        self.index = {}
        self.slots = {s: [None] * PAGES_PER_SLOT for s in range(N_SLOTS)}
        self._next = 0

    @property
    def live(self):
        return len(self.ref)

    @property
    def free(self):
        return CAPACITY - self.live

    def saved(self):
        return sum(r - 1 for r in self.ref.values() if r > 1)

    def _new(self):
        pid = self._next
        self._next += 1
        self.ref[pid] = 1
        return pid

    def _decref(self, pid):
        assert self.ref[pid] > 0
        self.ref[pid] -= 1
        if self.ref[pid] == 0:
            key = self.key_of.pop(pid, None)
            if key is not None:
                del self.index[key]
            del self.ref[pid]

    def admit(self, slot, toks, share):
        """Predicted return value of PagePool.admit; mutates on success."""
        m = len(toks) - 1
        n_need = m // PAGE_SIZE + 1
        n_full = m // PAGE_SIZE
        keys = [
            tuple(toks[: (i + 1) * PAGE_SIZE]) for i in range(n_full)
        ] if share else []
        shared = 0
        for key in keys:
            if key not in self.index:
                break
            shared += 1
        if self.free < n_need - shared:
            return None  # rollback restores prior refcounts exactly
        row = self.slots[slot]
        for i in range(shared):
            pid = self.index[keys[i]]
            self.ref[pid] += 1
            row[i] = pid
        for i in range(shared, n_need):
            row[i] = self._new()
        if share:
            for i in range(shared, n_full):
                if keys[i] not in self.index:
                    self.index[keys[i]] = row[i]
                    self.key_of[row[i]] = keys[i]
        return shared * PAGE_SIZE

    def extend(self, slot, n_rows):
        row = self.slots[slot]
        n_need = (n_rows - 1) // PAGE_SIZE + 1
        missing = [i for i in range(n_need) if row[i] is None]
        if self.free < len(missing):
            return False
        for i in missing:
            row[i] = self._new()  # private: never registered
        return True

    def truncate(self, slot, keep_rows):
        first = 0 if keep_rows <= 0 else (keep_rows - 1) // PAGE_SIZE + 1
        row = self.slots[slot]
        for i in range(first, PAGES_PER_SLOT):
            if row[i] is not None:
                self._decref(row[i])
                row[i] = None

    def release(self, slot):
        for i, pid in enumerate(self.slots[slot]):
            if pid is not None:
                self._decref(pid)
        self.slots[slot] = [None] * PAGES_PER_SLOT

    def prepare(self, slot, pos):
        """Predicted (ok, n_copies) of PagePool.prepare; mutates to match."""
        i = pos // PAGE_SIZE
        pid = self.slots[slot][i]
        if pid is None:
            if self.free < 1:
                return False, 0
            self.slots[slot][i] = self._new()
            return True, 0
        if self.ref[pid] > 1:
            if self.free < 1:
                return False, 0
            self.ref[pid] -= 1  # still shared by the remaining owners
            self.slots[slot][i] = self._new()
            return True, 1
        key = self.key_of.pop(pid, None)  # solo-owned: unregister pre-write
        if key is not None:
            del self.index[key]
        return True, 0


class PagingMachine(RuleBasedStateMachine):
    def __init__(self):
        super().__init__()
        self.pool = PagePool(
            N_PAGES, PAGE_SIZE, n_slots=N_SLOTS, max_seq=MAX_SEQ
        )
        self.oracle = _Oracle()

    # -- rules -------------------------------------------------------------
    @rule(slot=slot_st, toks=tokens_st, share=st.booleans())
    def admit(self, slot, toks, share):
        if any(p is not None for p in self.oracle.slots[slot]):
            return  # occupied; PagePool.admit asserts on that
        got = self.pool.admit(slot, toks, share=share)
        want = self.oracle.admit(slot, toks, share)
        assert got == want, (got, want)

    @rule(slot=slot_st, n_rows=st.integers(min_value=1, max_value=MAX_SEQ))
    def extend(self, slot, n_rows):
        got = self.pool.extend(slot, n_rows)
        want = self.oracle.extend(slot, n_rows)
        assert got == want, (got, want)

    @rule(slot=slot_st, keep=st.integers(min_value=0, max_value=MAX_SEQ))
    def truncate(self, slot, keep):
        self.pool.truncate(slot, keep)
        self.oracle.truncate(slot, keep)

    @rule(slot=slot_st, pos=st.integers(min_value=0, max_value=MAX_SEQ - 1))
    def prepare(self, slot, pos):
        ok, copies = self.pool.prepare(slot, pos)
        want_ok, want_copies = self.oracle.prepare(slot, pos)
        assert (ok, len(copies)) == (want_ok, want_copies)
        for src, dst in copies:
            assert src != dst and 0 <= dst < N_PAGES - 1

    @rule(slot=slot_st)
    def release(self, slot):
        self.pool.release(slot)
        self.oracle.release(slot)

    # -- invariants (checked after every rule) -----------------------------
    @invariant()
    def conserved(self):
        self.pool.assert_conserved()

    @invariant()
    def occupancy_matches_oracle(self):
        assert self.pool.pages_in_use == self.oracle.live
        assert self.pool.free_pages == self.oracle.free
        assert self.pool.shared_pages_saved() == self.oracle.saved()

    @invariant()
    def coverage_matches_oracle(self):
        for s in range(N_SLOTS):
            got = {i for i in range(PAGES_PER_SLOT) if self.pool.table[s, i] >= 0}
            want = {
                i for i, p in enumerate(self.oracle.slots[s]) if p is not None
            }
            assert got == want, (s, got, want)

    @invariant()
    def sharing_structure_matches_oracle(self):
        # registered-page count and per-page refcounts agree (page ids are
        # incomparable across pool and oracle, so compare the multisets)
        assert len(self.pool._page_key) == len(self.oracle.key_of)
        got = sorted(int(r) for r in self.pool.refcount if r > 0)
        want = sorted(self.oracle.ref.values())
        assert got == want, (got, want)


PagingMachine.TestCase.settings = settings(
    max_examples=120, stateful_step_count=60, deadline=None
)
TestPagingProperties = PagingMachine.TestCase
