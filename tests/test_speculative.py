"""Cascade-as-drafter speculative decoding (serve/speculative.py, DESIGN.md
§13): plan/acceptance unit behavior, pool extend/truncate bookkeeping, and
the headline contract — speculative serving emits BITWISE what plain
serving emits (greedy and sampled, paged and dense, with and without a
transport link) while the receiving tier spends fewer decode steps."""
import copy

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import ensemble as ens
from repro.core.cascade import TierSpec
from repro.models import api
from repro.models.params import unbox
from repro.serve import CascadeServer, CascadeTier, Request, ServeConfig
from repro.serve.engine import trace_count
from repro.serve.paging import PagePool
from repro.serve.speculative import accepted_prefix, plan_draft

_BASE = dict(n_layers=2, d_model=64, d_ff=128, vocab_size=64, remat=False)
CONFIGS = {
    "dense": ModelConfig(
        name="spec-dense", family="dense", n_heads=4, n_kv_heads=2, **_BASE
    ),
    "moe": ModelConfig(
        name="spec-moe", family="moe", n_heads=4, n_kv_heads=2, n_experts=4,
        top_k=2, capacity_factor=4.0, **_BASE
    ),
    "moe_interleaved": ModelConfig(
        name="spec-moe-il", family="moe", n_heads=4, n_kv_heads=2,
        n_experts=4, top_k=2, moe_every=2, capacity_factor=4.0, **_BASE
    ),
}
ATTENTION = list(CONFIGS)


@pytest.fixture(scope="module")
def stacks():
    return {
        f: unbox(ens.init_ensemble(cfg, 3, jax.random.PRNGKey(i)))[0]
        for i, (f, cfg) in enumerate(CONFIGS.items())
    }


def _requests(seed, n, *, lo=4, hi=14, max_new=(2, 6)):
    rng = np.random.default_rng(seed)
    return [
        Request(
            tokens=rng.integers(1, 64, size=int(rng.integers(lo, hi))).astype(
                np.int32
            ),
            max_new_tokens=int(rng.integers(*max_new)),
        )
        for _ in range(n)
    ]


def _agreeing_server(stacks, family, temperature=0.0):
    """tier0 = [m0, m0, m2]: the m0 pair agrees, so the plurality draft is
    m0's own generation; theta=0.8 makes a 2/3 vote defer.  tier1 = [m0]:
    identical params, so at T=0 the draft is exactly what tier 1 would
    decode — deterministic full acceptance on every deferral."""
    cfg = CONFIGS[family]
    vals = stacks[family]
    stacked = jax.tree.map(lambda v: jnp.stack([v[0], v[0], v[2]]), vals)
    t0 = CascadeTier(
        cfg, stacked, TierSpec("t0", "vote_preds", 0.8, k=3),
        temperature=temperature,
    )
    t1 = CascadeTier(
        cfg, jax.tree.map(lambda v: v[0:1], vals),
        TierSpec("t1", "vote_preds", 0.0, k=1), temperature=temperature,
    )
    return CascadeServer([t0, t1])


def _by_prompt(done):
    return {
        tuple(r.tokens): (r.tier, tuple(r.output), r.truncated) for r in done
    }


def _run_pair(server, reqs, *, paged=None, max_seq=64, n_slots=2):
    """(plain, speculative) serve_continuous runs over fresh copies of the
    same requests; returns (plain done, spec done, tier-1 stats pair)."""
    mk = lambda s: ServeConfig(
        n_slots=n_slots, max_seq=max_seq, paged=paged, speculative=s
    )
    base = server.serve_continuous([copy.deepcopy(r) for r in reqs], mk(False))
    base_stats = [dict(s) for s in server.last_stream_stats]
    spec = server.serve_continuous([copy.deepcopy(r) for r in reqs], mk(True))
    spec_stats = [dict(s) for s in server.last_stream_stats]
    return base, spec, base_stats, spec_stats


# ---------------------------------------------------------------------------
# unit behavior: plan, acceptance rule, pool extend/truncate
# ---------------------------------------------------------------------------


def test_plan_draft_clamps_and_rejects():
    prompt = np.arange(1, 9, dtype=np.int32)  # P = 8
    draft = np.array([9, 10, 11, 12], np.int32)
    p = plan_draft(prompt, draft, max_new_tokens=6, max_seq=64)
    assert p.start == 7
    np.testing.assert_array_equal(p.draft, draft)
    np.testing.assert_array_equal(p.tokens, [8, 9, 10, 11, 12])
    # budget clamp: the verify pass emits n_acc + 1, so T_use <= max_new - 1
    p = plan_draft(prompt, draft, max_new_tokens=3, max_seq=64)
    assert len(p.draft) == 2 and len(p.tokens) == 3
    # wall clamp: draft rows must fit below max_seq
    p = plan_draft(prompt, draft, max_new_tokens=6, max_seq=10)
    assert len(p.draft) == 2
    # nothing verifiable: max_new_tokens=1 never drafts
    assert plan_draft(prompt, draft, max_new_tokens=1, max_seq=64) is None
    assert plan_draft(prompt, np.zeros(0, np.int32), 6, 64) is None


def test_accepted_prefix_is_min_over_members():
    draft = np.array([5, 6, 7], np.int32)
    full = np.tile(np.array([5, 6, 7, 9], np.int32), (2, 1))
    assert accepted_prefix(full, draft) == 3
    partial = full.copy()
    partial[1, 1] = 0  # member 1 diverges at position 1
    assert accepted_prefix(partial, draft) == 1
    none = full.copy()
    none[0, 0] = 0
    assert accepted_prefix(none, draft) == 0


def test_pool_extend_and_truncate_conserve_pages():
    pool = PagePool(n_pages=9, page_size=4, n_slots=2, max_seq=32)
    pool.admit(0, np.arange(6, dtype=np.int32), share=True)  # rows 0..4 -> 2pg
    assert pool.extend(0, 13)  # rows 0..12 -> 4 pages total
    assert sum(p >= 0 for p in pool.table[0].tolist()) == 4
    pool.assert_conserved()
    # rollback keeps the page holding the last live row
    pool.truncate(0, keep_rows=6)  # rows 0..5 -> pages 0..1 stay
    assert sum(p >= 0 for p in pool.table[0].tolist()) == 2
    pool.assert_conserved()
    # extend refusal rolls back ONLY its own allocations
    pool.admit(1, np.arange(21, dtype=np.int32), share=True)  # 5 pages
    before = [p for p in pool.table[1].tolist() if p >= 0]
    assert not pool.extend(1, 32)  # needs 8, pool has 9-2-5-1(sink)=1 free
    assert [p for p in pool.table[1].tolist() if p >= 0] == before
    pool.assert_conserved()
    pool.release(0)
    pool.release(1)
    pool.assert_conserved()
    assert pool.free_pages == pool.n_pages - 1  # overflow sink stays out


def test_extension_pages_never_register_for_sharing():
    """COW-safety is structural: pages mapped by ``extend`` must never
    enter the prefix index, so no sharer can observe provisional draft
    rows."""
    pool = PagePool(n_pages=16, page_size=4, n_slots=3, max_seq=32)
    prompt = np.arange(9, dtype=np.int32)  # m=8 -> 2 full pages registered
    pool.admit(0, prompt, share=True)
    registered = set(pool._page_key)
    pool.extend(0, 16)  # draft rows through page index 3
    assert set(pool._page_key) == registered, "extend registered a page"
    # a sharer admitting the same prompt shares ONLY the admission prefix
    shared = pool.admit(1, prompt, share=True)
    assert shared == 8
    pool.assert_conserved()


# ---------------------------------------------------------------------------
# the serving contract: bitwise parity + fewer big-tier decode steps
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("family", ATTENTION)
@pytest.mark.parametrize("paged", [False, True])
def test_speculative_bitwise_and_fewer_decodes(stacks, family, paged):
    if paged and not api.supports_paging(CONFIGS[family]):
        pytest.skip("family has no paged backend")
    server = _agreeing_server(stacks, family)
    reqs = _requests(seed=31, n=8)
    base, spec, bs, ss = _run_pair(server, reqs, paged=paged)
    assert _by_prompt(base) == _by_prompt(spec)
    assert bs[1]["spec_drafts"] == 0
    deferred = sum(r.tier == 1 for r in base)
    assert deferred >= 2, "fixture must defer for the test to mean anything"
    # identical params + greedy -> every draft token accepted
    assert ss[1]["spec_accepted_tokens"] == ss[1]["spec_draft_tokens"] > 0
    assert ss[1]["decode_tokens"] < bs[1]["decode_tokens"]


@pytest.mark.parametrize("temperature", [0.7])
def test_speculative_bitwise_at_sampled_temperature(stacks, temperature):
    """T>0: the verify sampler reproduces the per-slot decode rng stream,
    so speculative serving still emits bitwise-identical generations even
    when acceptance is partial (tier-1's sampled stream diverges from the
    tier-0 draft wherever it likes — parity must survive every n_acc)."""
    server = _agreeing_server(stacks, "dense", temperature=temperature)
    reqs = _requests(seed=33, n=8)
    base, spec, _, ss = _run_pair(server, reqs, paged=True)
    assert _by_prompt(base) == _by_prompt(spec)
    assert ss[1]["spec_drafts"] > 0


def test_partial_acceptance_still_bitwise(stacks):
    """tier1 = a DIFFERENT member than the draft's author: acceptance is
    whatever prefix happens to match (often zero), and the divergence-point
    fallback must splice into ordinary decode without shifting a single
    token."""
    cfg = CONFIGS["dense"]
    vals = stacks["dense"]
    stacked = jax.tree.map(lambda v: jnp.stack([v[0], v[0], v[2]]), vals)
    server = CascadeServer([
        CascadeTier(cfg, stacked, TierSpec("t0", "vote_preds", 0.8, k=3)),
        CascadeTier(cfg, jax.tree.map(lambda v: v[1:2], vals),
                    TierSpec("t1", "vote_preds", 0.0, k=1)),
    ])
    reqs = _requests(seed=35, n=8)
    base, spec, _, ss = _run_pair(server, reqs, paged=True)
    assert _by_prompt(base) == _by_prompt(spec)
    assert ss[1]["spec_drafts"] > 0
    assert ss[1]["spec_accepted_tokens"] < ss[1]["spec_draft_tokens"]


def test_paged_equals_dense_speculative(stacks):
    """The paged pool (extend/rollback included) is bitwise the dense slot
    cache under speculative serving."""
    server = _agreeing_server(stacks, "dense")
    reqs = _requests(seed=37, n=8)
    mk = lambda paged: ServeConfig(
        n_slots=2, max_seq=64, paged=paged, speculative=True
    )
    dense = server.serve_continuous([copy.deepcopy(r) for r in reqs], mk(False))
    paged = server.serve_continuous([copy.deepcopy(r) for r in reqs], mk(True))
    assert _by_prompt(dense) == _by_prompt(paged)


def test_constant_state_families_fall_back_to_plain_admission(stacks):
    """SSM/RWKV/hybrid tiers cannot roll rejected draft tokens out of
    their recurrent state: a draft arriving at such a tier is dropped at
    admission (plain chunked prefill runs instead) and the outputs are
    unchanged."""
    cfg = ModelConfig(
        name="spec-mamba", family="ssm_mamba2", ssm_state=16,
        ssm_head_dim=32, **_BASE
    )
    vals, _ = unbox(ens.init_ensemble(cfg, 3, jax.random.PRNGKey(9)))
    stacked = jax.tree.map(lambda v: jnp.stack([v[0], v[0], v[2]]), vals)
    server = CascadeServer([
        CascadeTier(cfg, stacked, TierSpec("t0", "vote_preds", 0.8, k=3)),
        CascadeTier(cfg, jax.tree.map(lambda v: v[0:1], vals),
                    TierSpec("t1", "vote_preds", 0.0, k=1)),
    ])
    reqs = _requests(seed=39, n=6)
    base, spec, _, ss = _run_pair(server, reqs)
    assert _by_prompt(base) == _by_prompt(spec)
    assert sum(r.tier == 1 for r in base) >= 1
    assert ss[1]["spec_drafts"] == 0  # no verify pass ever ran


def test_speculative_trace_counts_flat_after_warmup(stacks):
    """Compile-once: a second speculative run (same geometry) must not
    trace a single new program — verify chunks land in the same pow2
    buckets chunked prefill already warmed."""
    server = _agreeing_server(stacks, "dense")
    reqs = _requests(seed=41, n=8)
    cfgv = ServeConfig(n_slots=2, max_seq=64, paged=True, speculative=True)
    server.serve_continuous([copy.deepcopy(r) for r in reqs], cfgv)
    n0 = trace_count()
    server.serve_continuous([copy.deepcopy(r) for r in reqs], cfgv)
    assert trace_count() == n0


# ---------------------------------------------------------------------------
# transport: the draft rides the metered hop, delivery order irrelevant
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("link", ["sim", "serial", "async"])
def test_draft_rides_metered_hop_and_order_is_irrelevant(stacks, link):
    from repro.serve import edge_cloud

    cfg = CONFIGS["dense"]
    vals = stacks["dense"]
    stacked = jax.tree.map(lambda v: jnp.stack([v[0], v[0], v[2]]), vals)

    def build(speculative):
        placement = edge_cloud(delay=0.01, link=link)
        server = CascadeServer([
            CascadeTier(cfg, stacked, TierSpec("t0", "vote_preds", 0.8, k=3)),
            CascadeTier(cfg, jax.tree.map(lambda v: v[0:1], vals),
                        TierSpec("t1", "vote_preds", 0.0, k=1)),
        ], placement=placement)
        reqs = _requests(seed=43, n=6)
        done = server.serve_continuous(
            reqs, ServeConfig(n_slots=2, max_seq=64, speculative=speculative)
        )
        return done, placement.link(0), server

    base, link_plain, _ = build(False)
    spec, link_spec, server = build(True)
    assert _by_prompt(base) == _by_prompt(spec)
    assert len(link_spec.hops) == len(link_plain.hops) > 0
    for hp, hs in zip(link_plain.hops, link_spec.hops):
        # same deferral, same prompt — the spec hop carries the draft too
        assert hs.payload_bytes > hp.payload_bytes
    st = server.last_stream_stats[1]
    assert st["spec_accepted_tokens"] == st["spec_draft_tokens"] > 0
