"""ServeConfig consolidation tests (serve/config.py): the config-style and
legacy-kwarg spellings of every serving entrypoint are bitwise equivalent,
mixing them is a TypeError, the deprecation warning fires once per
process, and the engine.py obs-resolution fix lands stream counters in the
engine's own registry."""
import copy
import warnings

import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import ensemble as ens
from repro.core.cascade import TierSpec
from repro.models.params import unbox
from repro.obs import Observability
from repro.serve import (
    CascadeServer,
    CascadeTier,
    Request,
    ServeConfig,
    ServingEngine,
)
from repro.serve.config import _reset_legacy_warning, resolve_serve_config

SMALL = ModelConfig(
    name="tiny-s", family="dense", n_layers=2, d_model=64, d_ff=128,
    vocab_size=64, n_heads=4, n_kv_heads=2, remat=False,
)
BIG = ModelConfig(
    name="tiny-b", family="dense", n_layers=3, d_model=96, d_ff=192,
    vocab_size=64, n_heads=4, n_kv_heads=4, remat=False,
)


@pytest.fixture(scope="module")
def stacks():
    v1, _ = unbox(ens.init_ensemble(SMALL, 3, jax.random.PRNGKey(0)))
    v2, _ = unbox(ens.init_ensemble(BIG, 1, jax.random.PRNGKey(1)))
    return v1, v2


def _requests(n=6, seed=0, vocab=64, max_new=(2, 5)):
    rng = np.random.default_rng(seed)
    return [
        Request(
            tokens=rng.integers(0, vocab, int(rng.integers(4, 12))).astype(np.int32),
            max_new_tokens=int(rng.integers(*max_new)),
        )
        for _ in range(n)
    ]


def _by_rid(done):
    return {r.rid: (r.tier, r.truncated, r.output.tolist()) for r in done}


_TIME_KEYS = ("admit_time", "decode_time", "inflight_wait")


def _counters(stats):
    """Stream stats minus the wall-time histograms (dispatch timings are
    real clock reads — identical WORK, not identical seconds)."""
    return {k: v for k, v in dict(stats).items() if k not in _TIME_KEYS}


# -- resolution mechanics ---------------------------------------------------


def test_mixing_config_and_legacy_is_typeerror(stacks):
    v1, _ = stacks
    eng = ServingEngine(SMALL, ens.take_member(v1, 0), max_seq=64)
    with pytest.raises(TypeError, match="not both"):
        eng.serve_continuous(_requests(1), ServeConfig(n_slots=2), n_slots=4)
    with pytest.raises(TypeError, match="not both"):
        eng.slot_stream(ServeConfig(), chunked_prefill=False)


def test_deprecation_warning_fires_once_per_process():
    _reset_legacy_warning()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        resolve_serve_config(None, "caller_a", n_slots=4)
        resolve_serve_config(None, "caller_b", n_slots=2)
    deps = [x for x in w if issubclass(x.category, DeprecationWarning)]
    assert len(deps) == 1 and "ServeConfig" in str(deps[0].message)
    # config-style resolution never warns
    _reset_legacy_warning()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        resolve_serve_config(ServeConfig(n_slots=4), "caller_c")
    assert not [x for x in w if issubclass(x.category, DeprecationWarning)]


def test_legacy_kwargs_map_onto_the_same_fields():
    _reset_legacy_warning()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        cfg = resolve_serve_config(
            None, "caller", n_slots=3, max_seq=128, chunked_prefill=False,
            page_size=8,
        )
    assert cfg == ServeConfig(
        n_slots=3, max_seq=128, chunked_prefill=False, page_size=8
    )
    # max_seq=None resolves to the caller's historical default, a set
    # max_seq survives untouched
    assert ServeConfig().with_max_seq_default(512).max_seq == 512
    assert cfg.with_max_seq_default(512).max_seq == 128


# -- bitwise equivalence: old spelling vs config spelling -------------------


def test_engine_serve_continuous_old_vs_config_bitwise(stacks):
    v1, _ = stacks
    member = ens.take_member(v1, 0)
    reqs = _requests(6, seed=5)
    eng_a = ServingEngine(SMALL, member, max_seq=64)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        done_a = eng_a.serve_continuous(
            [copy.deepcopy(r) for r in reqs], n_slots=3, chunked_prefill=True
        )
    stats_a = _counters(eng_a.last_stream_stats)
    eng_b = ServingEngine(SMALL, member, max_seq=64)
    done_b = eng_b.serve_continuous(
        [copy.deepcopy(r) for r in reqs],
        ServeConfig(n_slots=3, chunked_prefill=True),
    )
    assert _by_rid(done_a) == _by_rid(done_b)
    assert stats_a == _counters(eng_b.last_stream_stats)


def test_cascade_serve_continuous_old_vs_config_bitwise(stacks):
    v1, v2 = stacks

    def server():
        return CascadeServer([
            CascadeTier(SMALL, v1, TierSpec("t1", "vote", 0.67, k=3, cost=1.0)),
            CascadeTier(BIG, v2, TierSpec("t2", "confidence", -1.0, k=1,
                                          cost=50.0)),
        ])

    reqs = _requests(6, seed=6)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        done_a = server().serve_continuous(
            [copy.deepcopy(r) for r in reqs], n_slots=4, max_seq=64, seed=3
        )
    done_b = server().serve_continuous(
        [copy.deepcopy(r) for r in reqs],
        ServeConfig(n_slots=4, max_seq=64, seed=3),
    )
    assert _by_rid(done_a) == _by_rid(done_b)


def test_slot_stream_old_vs_config_bitwise(stacks):
    v1, _ = stacks
    member = ens.take_member(v1, 0)
    reqs = _requests(5, seed=7)
    eng = ServingEngine(SMALL, member, max_seq=64)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        st_a = eng.slot_stream(n_slots=2, max_seq=48)
    st_b = eng.slot_stream(ServeConfig(n_slots=2, max_seq=48))
    ra = [copy.deepcopy(r) for r in reqs]
    rb = [copy.deepcopy(r) for r in reqs]
    st_a.submit(ra)
    st_b.submit(rb)
    out_a = {r.rid: g.tolist() for r, g in st_a.drain()}
    out_b = {r.rid: g.tolist() for r, g in st_b.drain()}
    assert out_a == out_b
    assert _counters(st_a.stats) == _counters(st_b.stats)


# -- the engine.py obs-resolution fix ---------------------------------------


def test_engine_stream_obs_lands_in_engine_registry(stacks):
    """Regression (ISSUE 9 satellite): with no bundle passed,
    ``serve_continuous`` must wire the stream into the ENGINE's registry —
    the old code passed the raw ``obs=None`` through, so stream counters
    vanished into a private bundle nobody could read."""
    v1, _ = stacks
    eng = ServingEngine(SMALL, ens.take_member(v1, 0), max_seq=64)
    done = eng.serve_continuous(_requests(4, seed=8), ServeConfig(n_slots=2))
    assert len(done) == 4
    names = eng.obs.registry.names()
    assert "slot_stream.admitted" in names, names
    assert eng.obs.registry.value("slot_stream.admitted") == 4
    assert eng.obs.registry.value("slot_stream.decode_tokens") > 0
    # the run's latency histogram lands there too
    h = eng.obs.registry.get("serve.request_latency_s")
    assert h is not None and h.count == 4


def test_engine_explicit_obs_still_wins(stacks):
    """An explicitly-passed bundle keeps precedence over the engine's."""
    v1, _ = stacks
    eng = ServingEngine(SMALL, ens.take_member(v1, 0), max_seq=64)
    ob = Observability()
    eng.serve_continuous(_requests(3, seed=9), ServeConfig(n_slots=2, obs=ob))
    assert ob.registry.value("slot_stream.admitted") == 3
    assert eng.obs.registry.get("slot_stream.admitted") is None


def test_engine_last_stream_stats_stay_per_run(stacks):
    """Shared-registry counters are cumulative across serves on one
    engine; the legacy ``last_stream_stats`` contract is per-run deltas —
    a second serve must not inherit the first one's totals."""
    v1, _ = stacks
    eng = ServingEngine(SMALL, ens.take_member(v1, 0), max_seq=64)
    eng.serve_continuous(_requests(4, seed=10), ServeConfig(n_slots=2))
    first = dict(eng.last_stream_stats)
    eng.serve_continuous(_requests(2, seed=11), ServeConfig(n_slots=2))
    second = dict(eng.last_stream_stats)
    assert first["admitted"] == 4 and second["admitted"] == 2
    # but the registry keeps the running total
    assert eng.obs.registry.value("slot_stream.admitted") == 6
