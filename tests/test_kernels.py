"""Per-kernel correctness sweeps: Pallas (interpret=True) and the XLA
fallback vs the pure-jnp ref.py oracle, across shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import config as kcfg
from repro.kernels.agreement import ops as agree_ops, ref as agree_ref
from repro.kernels.decode_attention import ops as dec_ops, ref as dec_ref
from repro.kernels.flash_attention import ops as flash_ops, ref as flash_ref
from repro.kernels.mamba2_ssd import ops as ssd_ops, ref as ssd_ref
from repro.kernels.rwkv6_wkv import ops as wkv_ops, ref as wkv_ref

# interpret-mode Pallas runs execute the kernel body in Python on CPU and
# take many minutes across the sweeps — marked slow, excluded from tier 1
# (pyproject.toml addopts); run them with `pytest -m slow` or `-m ""`.
IMPLS = ["xla", pytest.param("pallas_interpret", marks=pytest.mark.slow)]


def _tol(dtype):
    return dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 else dict(atol=2e-4, rtol=2e-4)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize(
    "B,S,H,KVH,hd,causal,window",
    [
        (1, 128, 4, 4, 64, True, None),
        (2, 256, 4, 2, 64, True, None),
        (2, 256, 8, 1, 32, True, 64),
        (1, 512, 4, 4, 64, False, None),  # encoder
        (2, 128, 4, 2, 128, True, 32),
    ],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(impl, B, S, H, KVH, hd, causal, window, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, KVH, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, KVH, hd), jnp.float32).astype(dtype)
    ref = flash_ref.attention_ref(q, k, v, causal=causal, window=window)
    with kcfg.use_impl(impl):
        out = flash_ops.flash_attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **_tol(dtype)
    )


@pytest.mark.parametrize(
    "causal,window,softcap",
    [(True, None, None), (True, 32, None), (False, None, None), (True, None, 10.0)],
)
def test_flash_attention_custom_vjp_grads(causal, window, softcap):
    """The chunked flash backward (custom_vjp) matches AD through the naive
    oracle for q/k/v cotangents."""
    ks = jax.random.split(jax.random.PRNGKey(11), 4)
    B, S, H, KVH, hd = 2, 128, 4, 2, 32
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KVH, hd))
    v = jax.random.normal(ks[2], (B, S, KVH, hd))
    do = jax.random.normal(ks[3], (B, S, H, hd))
    f1 = lambda q, k, v: (
        flash_ops.flash_attention(q, k, v, causal=causal, window=window, softcap=softcap) * do
    ).sum()
    f2 = lambda q, k, v: (
        flash_ref.attention_ref(q, k, v, causal=causal, window=window, softcap=softcap) * do
    ).sum()
    g1 = jax.grad(f1, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f2, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("impl", IMPLS)
def test_flash_attention_softcap(impl):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 128, 2, 64))
    k = jax.random.normal(ks[1], (1, 128, 2, 64))
    v = jax.random.normal(ks[2], (1, 128, 2, 64))
    ref = flash_ref.attention_ref(q, k, v, causal=True, softcap=20.0)
    with kcfg.use_impl(impl):
        out = flash_ops.flash_attention(q, k, v, causal=True, softcap=20.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-4, rtol=2e-4)


# ---------------------------------------------------------------------------
# flash attention: per-row starts (left-pad carve-out on the kernel path)
# ---------------------------------------------------------------------------

# starts patterns over (B=4, S=128): all-zero (must equal the starts-free
# run), ragged left-padding, one fully-padded row (start == S -> zeros),
# and the extreme one-valid-column start == S-1
_STARTS_PATTERNS = {
    "all_zero": [0, 0, 0, 0],
    "ragged": [0, 37, 64, 101],
    "full_pad_row": [0, 37, 128, 64],
    "last_col": [127, 127, 127, 127],
}
_MASK_FAMILIES = {
    "causal": dict(causal=True, window=None, softcap=None),
    "window": dict(causal=True, window=32, softcap=None),
    "softcap": dict(causal=True, window=None, softcap=10.0),
}


@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize("pattern", sorted(_STARTS_PATTERNS))
@pytest.mark.parametrize("maskfam", sorted(_MASK_FAMILIES))
def test_flash_attention_starts_parity(impl, pattern, maskfam):
    """With ``starts`` supplied the dispatcher must keep the kernel path and
    agree with the XLA path and the ref oracle to 1e-5."""
    kw = _MASK_FAMILIES[maskfam]
    starts = jnp.asarray(_STARTS_PATTERNS[pattern], jnp.int32)
    ks = jax.random.split(jax.random.PRNGKey(31), 3)
    B, S, H, KVH, hd = 4, 128, 4, 2, 64
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KVH, hd))
    v = jax.random.normal(ks[2], (B, S, KVH, hd))
    ref = flash_ref.attention_ref(q, k, v, starts=starts, **kw)
    with kcfg.use_impl(impl):
        out = flash_ops.flash_attention(q, k, v, starts=starts, **kw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5, rtol=1e-5)
    if pattern == "all_zero":
        with kcfg.use_impl(impl):
            plain = flash_ops.flash_attention(q, k, v, **kw)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(plain), atol=1e-5, rtol=1e-5
        )
    if pattern == "full_pad_row":  # row 2 is pure padding -> zeros
        np.testing.assert_array_equal(np.asarray(out)[2], 0.0)


@pytest.mark.slow
def test_flash_attention_starts_no_xla_fallback(monkeypatch):
    """starts used to force impl='xla'; the kernel path must now serve it
    without touching any XLA fallback."""

    def _boom(*a, **kw):
        raise AssertionError("starts fell back to the XLA path")

    monkeypatch.setattr(flash_ops, "_xla_flash", _boom)
    monkeypatch.setattr(flash_ops, "_flash_diff", _boom)
    ks = jax.random.split(jax.random.PRNGKey(32), 3)
    q = jax.random.normal(ks[0], (2, 128, 4, 64))
    k = jax.random.normal(ks[1], (2, 128, 2, 64))
    v = jax.random.normal(ks[2], (2, 128, 2, 64))
    starts = jnp.asarray([0, 57], jnp.int32)
    ref = flash_ref.attention_ref(q, k, v, causal=True, starts=starts)
    with kcfg.use_impl("pallas_interpret"):
        out = flash_ops.flash_attention(q, k, v, causal=True, starts=starts)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5, rtol=1e-5)


@pytest.mark.slow
def test_flash_attention_starts_multiblock_skip():
    """Small blocks force a multi-block KV sweep so below-start blocks are
    actually skipped; skip on/off must agree bitwise (the skipped blocks
    were fully masked) and match the oracle."""
    from repro.kernels.flash_attention import kernel as flash_kernel

    ks = jax.random.split(jax.random.PRNGKey(33), 3)
    B, S, H, KVH, hd = 4, 128, 2, 1, 64
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KVH, hd))
    v = jax.random.normal(ks[2], (B, S, KVH, hd))
    starts = jnp.asarray([0, 33, 96, 128], jnp.int32)
    ref = flash_ref.attention_ref(q, k, v, causal=True, starts=starts)
    qt, kt, vt = (a.transpose(0, 2, 1, 3) for a in (q, k, v))
    outs = {}
    for skip in (True, False):
        o = flash_kernel.flash_attention_bhsd(
            qt, kt, vt, starts, causal=True, block_q=32, block_k=32,
            interpret=True, skip_pad_blocks=skip,
        )
        outs[skip] = np.asarray(o.transpose(0, 2, 1, 3))
        np.testing.assert_allclose(outs[skip], np.asarray(ref), atol=1e-5, rtol=1e-5)
    np.testing.assert_array_equal(outs[True], outs[False])


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize(
    "B,S,H,KVH,hd,cur,window",
    [
        (2, 256, 4, 2, 64, 100, None),
        (1, 512, 8, 8, 64, 512, None),
        (2, 256, 4, 1, 128, 200, 64),
        (3, 128, 6, 2, 32, 1, None),
    ],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention(impl, B, S, H, KVH, hd, cur, window, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (B, 1, H, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, KVH, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, KVH, hd), jnp.float32).astype(dtype)
    ref = dec_ref.decode_attention_ref(q, k, v, cur, window=window)
    with kcfg.use_impl(impl):
        out = dec_ops.decode_attention(q, k, v, cur, window=window)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **_tol(dtype)
    )


# ---------------------------------------------------------------------------
# decode attention: per-row starts (left-pad carve-out on the kernel path)
# ---------------------------------------------------------------------------

# (cur_len per row, starts per row) over (B=4, S=256): all-zero, ragged,
# one row whose start swallows its whole valid cache (pure padding ->
# zeros), and the one-valid-column extreme start == S-1
_DEC_STARTS_PATTERNS = {
    "all_zero": ([200, 100, 256, 64], [0, 0, 0, 0]),
    "ragged": ([200, 100, 256, 64], [0, 37, 128, 63]),
    "full_pad_row": ([200, 100, 50, 64], [0, 37, 50, 0]),
    "last_col": ([256, 256, 256, 256], [255, 255, 255, 255]),
}


@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize("pattern", sorted(_DEC_STARTS_PATTERNS))
@pytest.mark.parametrize("maskfam", sorted(_MASK_FAMILIES))
def test_decode_attention_starts_parity(impl, pattern, maskfam):
    kw = {k_: v_ for k_, v_ in _MASK_FAMILIES[maskfam].items() if k_ != "causal"}
    cur, starts = _DEC_STARTS_PATTERNS[pattern]
    cur = jnp.asarray(cur, jnp.int32)
    starts = jnp.asarray(starts, jnp.int32)
    ks = jax.random.split(jax.random.PRNGKey(41), 3)
    B, S, H, KVH, hd = 4, 256, 4, 2, 64
    q = jax.random.normal(ks[0], (B, 1, H, hd))
    k = jax.random.normal(ks[1], (B, S, KVH, hd))
    v = jax.random.normal(ks[2], (B, S, KVH, hd))
    kt, vt = k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3)
    ref = dec_ref.decode_attention_ref(q, k, v, cur, starts=starts, **kw)
    with kcfg.use_impl(impl):
        out = dec_ops.decode_attention_bksd(q, kt, vt, cur, starts=starts, **kw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5, rtol=1e-5)
    if pattern == "all_zero":
        with kcfg.use_impl(impl):
            plain = dec_ops.decode_attention_bksd(q, kt, vt, cur, **kw)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(plain), atol=1e-5, rtol=1e-5
        )
    if pattern == "full_pad_row":  # row 2's start swallows its cache
        np.testing.assert_array_equal(np.asarray(out)[2], 0.0)


@pytest.mark.slow
def test_decode_attention_starts_no_xla_fallback(monkeypatch):
    def _boom(*a, **kw):
        raise AssertionError("starts fell back to the XLA path")

    monkeypatch.setattr(dec_ops, "_xla_decode_bksd", _boom)
    ks = jax.random.split(jax.random.PRNGKey(42), 3)
    q = jax.random.normal(ks[0], (2, 1, 4, 64))
    kt = jax.random.normal(ks[1], (2, 2, 256, 64))
    vt = jax.random.normal(ks[2], (2, 2, 256, 64))
    cur = jnp.asarray([100, 256], jnp.int32)
    starts = jnp.asarray([0, 57], jnp.int32)
    ref = dec_ref.decode_attention_ref(
        q, kt.transpose(0, 2, 1, 3), vt.transpose(0, 2, 1, 3), cur, starts=starts
    )
    with kcfg.use_impl("pallas_interpret"):
        out = dec_ops.decode_attention_bksd(q, kt, vt, cur, starts=starts)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5, rtol=1e-5)


@pytest.mark.slow
def test_decode_attention_starts_multiblock_skip():
    """block_k=64 over S=256 -> 4 cache blocks; below-start blocks skip and
    skip on/off agree bitwise."""
    from repro.kernels.decode_attention import kernel as dec_kernel

    ks = jax.random.split(jax.random.PRNGKey(43), 3)
    B, S, KVH, G, hd = 4, 256, 2, 2, 64
    q = jax.random.normal(ks[0], (B, KVH, G, hd))
    kt = jax.random.normal(ks[1], (B, KVH, S, hd))
    vt = jax.random.normal(ks[2], (B, KVH, S, hd))
    cur = jnp.asarray([256, 200, 150, 256], jnp.int32)
    starts = jnp.asarray([0, 70, 140, 255], jnp.int32)
    ref = dec_ref.decode_attention_ref(
        q.reshape(B, 1, KVH * G, hd),
        kt.transpose(0, 2, 1, 3),
        vt.transpose(0, 2, 1, 3),
        cur,
        starts=starts,
    )
    outs = {}
    for skip in (True, False):
        o = dec_kernel.decode_attention_bkgd(
            q, kt, vt, cur, starts, block_k=64, interpret=True,
            skip_pad_blocks=skip,
        )
        outs[skip] = np.asarray(o.reshape(B, 1, KVH * G, hd))
        np.testing.assert_allclose(outs[skip], np.asarray(ref), atol=1e-5, rtol=1e-5)
    np.testing.assert_array_equal(outs[True], outs[False])


# ---------------------------------------------------------------------------
# decode attention: block-paged pools (page table via scalar prefetch)
# ---------------------------------------------------------------------------


def _paged_case(seed, B, n_pg, ps, KVH, H, hd, cur, share_first=False):
    """Random pool + page table: each row maps exactly the pages its
    cur_len needs (rest -1 = unmapped), scattered through the pool in
    permuted order.  ``share_first`` points every row's first table entry
    at the SAME pool page — the shared-prefix layout."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    P = B * n_pg + 2  # head-room + the overflow sink (never mapped)
    q = jax.random.normal(ks[0], (B, 1, H, hd))
    k_pool = jax.random.normal(ks[1], (P, KVH, ps, hd))
    v_pool = jax.random.normal(ks[2], (P, KVH, ps, hd))
    perm = np.random.default_rng(seed).permutation(P - 1)
    pages = np.full((B, n_pg), -1, np.int32)
    t = 0
    for b in range(B):
        for i in range((int(cur[b]) + ps - 1) // ps):
            pages[b, i] = perm[t]
            t += 1
    if share_first:
        pages[:, 0] = pages[0, 0]
    return q, k_pool, v_pool, jnp.asarray(pages), jnp.asarray(cur, jnp.int32)


_PAGED_CASES = {
    "ragged": dict(B=4, n_pg=4, ps=8, cur=[32, 17, 8, 1]),
    "full": dict(B=2, n_pg=2, ps=16, cur=[32, 32]),
    "window": dict(B=3, n_pg=4, ps=8, cur=[25, 32, 9], window=16),
    "softcap": dict(B=2, n_pg=3, ps=8, cur=[24, 5], softcap=10.0),
    "shared_prefix": dict(B=4, n_pg=3, ps=8, cur=[24, 20, 10, 9], share=True),
}


@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize("case", sorted(_PAGED_CASES))
def test_decode_attention_paged(impl, case):
    c = dict(_PAGED_CASES[case])
    window = c.pop("window", None)
    softcap = c.pop("softcap", None)
    share = c.pop("share", False)
    H, KVH, hd = 4, 2, 64
    q, kp, vp, pages, cur = _paged_case(
        5, c["B"], c["n_pg"], c["ps"], KVH, H, hd, c["cur"], share_first=share
    )
    ref = dec_ref.decode_attention_paged_ref(
        q, kp, vp, pages, cur, window=window, softcap=softcap
    )
    with kcfg.use_impl(impl):
        out = dec_ops.decode_attention_paged(
            q, kp, vp, pages, cur, window=window, softcap=softcap
        )
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref), atol=2e-4, rtol=2e-4
    )


def test_decode_attention_paged_xla_bitwise_matches_dense():
    """The gathered-view XLA route is BITWISE the dense masked sweep over
    the equivalent contiguous cache — the foundation of the serving
    paged == dense parity contract (unmapped pages gather zero rows, which
    the length mask pins to softmax weight exactly 0.0)."""
    B, n_pg, ps, KVH, H, hd = 4, 4, 8, 2, 4, 64
    cur = [32, 17, 8, 1]
    q, kp, vp, pages, curj = _paged_case(
        9, B, n_pg, ps, KVH, H, hd, cur, share_first=True
    )
    S = n_pg * ps
    kd = np.zeros((B, S, KVH, hd), np.float32)
    vd = np.zeros((B, S, KVH, hd), np.float32)
    pg = np.asarray(pages)
    for b in range(B):
        for i in range(n_pg):
            if pg[b, i] >= 0:  # (KVH, ps, hd) -> (ps, KVH, hd)
                kd[b, i * ps:(i + 1) * ps] = np.asarray(kp[pg[b, i]]).transpose(1, 0, 2)
                vd[b, i * ps:(i + 1) * ps] = np.asarray(vp[pg[b, i]]).transpose(1, 0, 2)
    with kcfg.use_impl("xla"):
        paged = dec_ops.decode_attention_paged(q, kp, vp, pages, curj)
        dense = dec_ops.decode_attention(q, jnp.asarray(kd), jnp.asarray(vd), curj)
    np.testing.assert_array_equal(np.asarray(paged), np.asarray(dense))


def test_decode_attention_paged_rejects_bad_inputs():
    q, kp, vp, pages, cur = _paged_case(3, 2, 2, 8, 2, 4, 64, [9, 16])
    with pytest.raises(ValueError, match="pool mismatch"):
        dec_ops.decode_attention_paged(q, kp, vp[:-1], pages, cur)
    with pytest.raises(ValueError, match="page table"):
        dec_ops.decode_attention_paged(q, kp, vp, pages[:1], cur)
    from repro.kernels.decode_attention import kernel as dec_kernel

    # sublane guard: a 4-row page cannot tile the TPU block layout
    qk = q.reshape(2, 2, 2, 64)
    with pytest.raises(ValueError, match="sublane"):
        dec_kernel.decode_attention_paged_bkgd(
            qk, kp[:, :, :4], vp[:, :, :4], cur, pages, interpret=True
        )


# ---------------------------------------------------------------------------
# serving regression: the left-pad carve-out stays on the kernel path
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_serving_leftpad_kernel_path_matches_solo():
    """Left-padded generate AND slot-based serve_continuous, with
    impl='pallas_interpret' forced end to end, still reproduce solo runs
    token-for-token — serving never needs the XLA detour."""
    from repro.configs.base import ModelConfig
    from repro.models import api
    from repro.models.params import unbox
    from repro.serve.batching import Request
    from repro.serve.engine import ServingEngine

    cfg = ModelConfig(
        name="tiny-dense-kernelpath", family="dense", n_layers=2, d_model=32,
        d_ff=64, vocab_size=64, n_heads=2, n_kv_heads=2, remat=False,
    )
    values, _ = unbox(api.init_params(cfg, jax.random.PRNGKey(0)))
    rng = np.random.default_rng(5)
    lens, S = [3, 7, 12, 16], 16
    toks = np.zeros((4, S), np.int32)
    starts = np.zeros((4,), np.int32)
    prompts = []
    for i, L in enumerate(lens):
        p = rng.integers(0, 64, L).astype(np.int32)
        prompts.append(p)
        toks[i, S - L:] = p
        starts[i] = S - L

    with kcfg.use_impl("pallas_interpret"):
        eng = ServingEngine(cfg, values, max_batch=4)
        gen = eng.generate(toks, 5, starts=starts)
        solo = ServingEngine(cfg, values)
        for i, p in enumerate(prompts):
            np.testing.assert_array_equal(gen[i], solo.generate(p[None], 5)[0])

        reqs = [
            Request(
                tokens=rng.integers(0, 64, int(rng.integers(3, 10))).astype(np.int32),
                max_new_tokens=3,
            )
            for _ in range(5)
        ]
        done = eng.serve_continuous(reqs, n_slots=3, max_seq=32)
        assert len(done) == 5
        for r in done:
            ref = solo.generate(r.tokens[None], r.max_new_tokens)[0]
            np.testing.assert_array_equal(r.output, ref)


# ---------------------------------------------------------------------------
# mamba2 ssd
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize(
    "B,S,H,P,G,N,chunk",
    [
        (2, 128, 4, 32, 2, 16, 32),
        (1, 256, 2, 64, 1, 64, 64),
        (2, 96, 4, 32, 4, 16, 32),  # padded (96 % 32 == 0 but test chunk 64)
    ],
)
def test_mamba2_ssd(impl, B, S, H, P, G, N, chunk):
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H))) * 0.5
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, G, N)) * 0.5
    Cm = jax.random.normal(ks[4], (B, S, G, N)) * 0.5
    ref, href = ssd_ref.ssd_ref(x, dt, A, Bm, Cm, return_final_state=True)
    with kcfg.use_impl(impl):
        if impl == "pallas_interpret" and S % chunk:
            pytest.skip("pallas path requires divisible chunks")
        out, h = ssd_ops.ssd(x, dt, A, Bm, Cm, chunk=chunk, return_final_state=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=5e-4, rtol=5e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(href), atol=5e-4, rtol=5e-4)


@pytest.mark.parametrize("impl", IMPLS)
def test_mamba2_ssd_initial_state(impl):
    ks = jax.random.split(jax.random.PRNGKey(4), 6)
    B, S, H, P, G, N = 2, 64, 2, 16, 1, 8
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H))) * 0.5
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, G, N)) * 0.5
    Cm = jax.random.normal(ks[4], (B, S, G, N)) * 0.5
    h0 = jax.random.normal(ks[5], (B, H, N, P)) * 0.2
    ref = ssd_ref.ssd_ref(x, dt, A, Bm, Cm, initial_state=h0)
    with kcfg.use_impl(impl):
        out = ssd_ops.ssd(x, dt, A, Bm, Cm, chunk=32, initial_state=h0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=5e-4, rtol=5e-4)


def test_mamba2_step_matches_scan():
    ks = jax.random.split(jax.random.PRNGKey(5), 5)
    B, S, H, P, G, N = 2, 16, 2, 16, 1, 8
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H))) * 0.5
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, G, N)) * 0.5
    Cm = jax.random.normal(ks[4], (B, S, G, N)) * 0.5
    full = ssd_ref.ssd_ref(x, dt, A, Bm, Cm)
    st = jnp.zeros((B, H, N, P))
    for t in range(S):
        y, st = ssd_ops.ssd_step(x[:, t], dt[:, t], A, Bm[:, t], Cm[:, t], st)
        np.testing.assert_allclose(np.asarray(y), np.asarray(full[:, t]), atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# rwkv6 wkv
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize(
    "B,S,H,D,chunk",
    [(2, 128, 3, 32, 32), (1, 64, 2, 64, 32), (2, 80, 2, 32, 32)],
)
def test_rwkv6_wkv(impl, B, S, H, D, chunk):
    ks = jax.random.split(jax.random.PRNGKey(6), 6)
    r = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, H, D))
    v = jax.random.normal(ks[2], (B, S, H, D))
    logw = -jnp.exp(jax.random.normal(ks[3], (B, S, H, D)) * 0.5)
    u = jax.random.normal(ks[4], (H, D)) * 0.5
    s0 = jax.random.normal(ks[5], (B, H, D, D)) * 0.1
    ref, sref = wkv_ref.wkv6_ref(r, k, v, logw, u, initial_state=s0, return_final_state=True)
    with kcfg.use_impl(impl):
        if impl == "pallas_interpret" and S % chunk:
            pytest.skip("pallas path requires divisible chunks")
        out, s = wkv_ops.wkv6(r, k, v, logw, u, chunk=chunk, initial_state=s0, return_final_state=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sref), atol=2e-3, rtol=2e-3)


def test_rwkv6_step_matches_scan():
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    B, S, H, D = 2, 12, 2, 16
    r = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, H, D))
    v = jax.random.normal(ks[2], (B, S, H, D))
    logw = -jnp.exp(jax.random.normal(ks[3], (B, S, H, D)) * 0.5)
    u = jax.random.normal(ks[4], (H, D)) * 0.5
    full = wkv_ref.wkv6_ref(r, k, v, logw, u)
    st = jnp.zeros((B, H, D, D))
    for t in range(S):
        y, st = wkv_ops.wkv6_step(r[:, t], k[:, t], v[:, t], logw[:, t], u, st)
        np.testing.assert_allclose(np.asarray(y), np.asarray(full[:, t]), atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# agreement
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize("E,B,V", [(2, 128, 2048), (3, 256, 4096), (5, 128, 512)])
def test_agreement(impl, E, B, V):
    logits = jax.random.normal(jax.random.PRNGKey(8), (E, B, V)) * 2
    ref = agree_ref.agreement_ref(logits)
    with kcfg.use_impl(impl):
        out = agree_ops.agreement(logits)
    np.testing.assert_array_equal(np.asarray(out["pred"]), np.asarray(ref["pred"]))
    np.testing.assert_allclose(np.asarray(out["vote_frac"]), np.asarray(ref["vote_frac"]))
    np.testing.assert_allclose(
        np.asarray(out["mean_score"]), np.asarray(ref["mean_score"]), atol=1e-5, rtol=1e-5
    )


@pytest.mark.parametrize("impl", IMPLS)
def test_agreement_identical_members(impl):
    logits = jnp.tile(jax.random.normal(jax.random.PRNGKey(9), (1, 64, 512)), (4, 1, 1))
    with kcfg.use_impl(impl):
        out = agree_ops.agreement(logits)
    assert float(out["vote_frac"].min()) == 1.0
