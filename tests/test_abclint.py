"""abclint self-tests: one violating + one clean fixture per rule, pragma
and baseline mechanics, and the tier-1 "repo is clean against the committed
baseline" regression.

Fixture files are written into tmp repo trees shaped like the real one
(``src/repro/serve/...`` etc.) because every pass scopes by relpath."""
import json
import os
import textwrap

import pytest

from tools.abclint import engine
from tools.abclint.__main__ import main as abclint_main
from tools.abclint.passes import ALL_PASSES


def lint_fixture(tmp_path, relpath, code):
    """Write ``code`` at ``relpath`` under a tmp repo root and lint it."""
    p = tmp_path / relpath
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(textwrap.dedent(code))
    return engine.run_passes(
        ALL_PASSES, root=str(tmp_path), scope=(relpath,)
    )


def rules_of(findings):
    return sorted(f.rule for f in findings)


# ---------------------------------------------------------------------------
# pass 1 — retrace hazards
# ---------------------------------------------------------------------------


def test_abc101_jit_inside_function(tmp_path):
    findings = lint_fixture(tmp_path, "src/repro/core/fx.py", """
        import jax

        def per_call(step, x):
            return jax.jit(step)(x)
    """)
    assert rules_of(findings) == ["ABC101"]


def test_abc101_clean_module_level_and_lru_factory(tmp_path):
    findings = lint_fixture(tmp_path, "src/repro/core/fx.py", """
        import functools
        import jax

        @jax.jit
        def decorated(x):
            return x + 1

        module_level = jax.jit(decorated)

        @functools.lru_cache(maxsize=None)
        def programs(step):
            return jax.jit(step)
    """)
    assert findings == []


def test_abc102_lambda_to_jit(tmp_path):
    findings = lint_fixture(tmp_path, "benchmarks/bx.py", """
        import jax

        def run(x):
            f = jax.jit(lambda y: y + 1)
            return f(x)
    """)
    assert rules_of(findings) == ["ABC101", "ABC102"]


def test_abc103_branch_on_tracer(tmp_path):
    findings = lint_fixture(tmp_path, "src/repro/core/fx.py", """
        import jax.numpy as jnp

        def f(x):
            if jnp.max(x) > 0:
                return x
            return -x
    """)
    assert rules_of(findings) == ["ABC103"]


def test_abc103_clean_static_dtype_predicate(tmp_path):
    findings = lint_fixture(tmp_path, "src/repro/core/fx.py", """
        import jax.numpy as jnp

        def f(x):
            if jnp.issubdtype(x.dtype, jnp.floating):
                return x
            return -x
    """)
    assert findings == []


def test_abc104_per_token_decode_over_draft(tmp_path):
    findings = lint_fixture(tmp_path, "src/repro/serve/sx.py", """
        def verify(backend, plan, slot):
            for j, tok in enumerate(plan.draft):
                logits, _ = backend.decode_step(tok, slot, plan.start + j)
    """)
    assert rules_of(findings) == ["ABC104"]


def test_abc104_clean_single_verify_pass_and_out_of_scope(tmp_path):
    findings = lint_fixture(tmp_path, "src/repro/serve/sx.py", """
        def verify(backend, plan, slot, max_chunk):
            choices = backend.verify_draft(
                plan.tokens, slot, plan.start, max_chunk
            )
            for tok in plan.draft:
                record(tok)
            return choices
    """)
    assert findings == []
    findings = lint_fixture(tmp_path, "src/repro/models/mx.py", """
        def reference(api, params, cache, draft, cfg):
            for j, tok in enumerate(draft):
                logits, cache = api.decode_step(params, tok, cache, j, cfg)
            return cache
    """)
    assert findings == []


# ---------------------------------------------------------------------------
# pass 2 — host-sync leaks (scope: serve/ + core/cascade.py)
# ---------------------------------------------------------------------------


def test_abc201_item(tmp_path):
    findings = lint_fixture(tmp_path, "src/repro/serve/sx.py", """
        def f(x):
            return x.item()
    """)
    assert rules_of(findings) == ["ABC201"]


def test_abc202_bool_over_array_expr(tmp_path):
    findings = lint_fixture(tmp_path, "src/repro/serve/sx.py", """
        import jax.numpy as jnp

        def f(x):
            return bool(jnp.any(x))
    """)
    assert rules_of(findings) == ["ABC202"]


def test_abc202_clean_fetched_scalar(tmp_path):
    findings = lint_fixture(tmp_path, "src/repro/serve/sx.py", """
        from repro.core.cascade import host_fetch

        def f(x):
            return bool(host_fetch(x)[0])
    """)
    assert findings == []


def test_abc203_np_asarray(tmp_path):
    findings = lint_fixture(tmp_path, "src/repro/serve/sx.py", """
        import numpy as np

        def f(x):
            return np.asarray(x)
    """)
    assert rules_of(findings) == ["ABC203"]


def test_abc203_clean_wrapping_explicit_fetch(tmp_path):
    findings = lint_fixture(tmp_path, "src/repro/serve/sx.py", """
        import numpy as np
        from repro.core.cascade import host_fetch

        def f(x):
            return np.asarray(host_fetch(x), np.int32)
    """)
    assert findings == []


def test_abc204_device_get_outside_fetch(tmp_path):
    findings = lint_fixture(tmp_path, "src/repro/serve/sx.py", """
        import jax

        def f(x):
            return jax.device_get(x)
    """)
    assert rules_of(findings) == ["ABC204"]


def test_host_sync_out_of_scope_and_transport_whitelist(tmp_path):
    # transport.py IS the metered boundary; train/ is out of scope entirely
    code = """
        import jax

        def f(x):
            return jax.device_get(x).item()
    """
    assert lint_fixture(tmp_path, "src/repro/serve/transport.py", code) == []
    assert lint_fixture(tmp_path, "src/repro/train/tx.py", code) == []


# ---------------------------------------------------------------------------
# pass 3 — determinism (scope: core/ + serve/)
# ---------------------------------------------------------------------------


def test_abc301_builtin_hash(tmp_path):
    findings = lint_fixture(tmp_path, "src/repro/core/dx.py", """
        def digest(b):
            return hash(b)
    """)
    assert rules_of(findings) == ["ABC301"]


def test_abc301_clean_crc32(tmp_path):
    findings = lint_fixture(tmp_path, "src/repro/core/dx.py", """
        import zlib

        def digest(b):
            return zlib.crc32(b)
    """)
    assert findings == []


def test_abc302_set_iteration(tmp_path):
    findings = lint_fixture(tmp_path, "src/repro/core/dx.py", """
        def f(xs):
            return [x + 1 for x in set(xs)]
    """)
    assert rules_of(findings) == ["ABC302"]


def test_abc302_clean_sorted_set(tmp_path):
    findings = lint_fixture(tmp_path, "src/repro/core/dx.py", """
        def f(xs):
            return [x + 1 for x in sorted(set(xs))]
    """)
    assert findings == []


def test_abc303_wall_clock_and_seed_free_rng(tmp_path):
    findings = lint_fixture(tmp_path, "src/repro/core/dx.py", """
        import time
        import numpy as np

        def f():
            a = time.time()
            b = np.random.rand(3)
            rng = np.random.default_rng()
            return a, b, rng
    """)
    assert rules_of(findings) == ["ABC303", "ABC303", "ABC303"]


def test_abc303_clean_metering_clock_and_seeded_rng(tmp_path):
    # perf_counter is the blessed metering clock for ABC303; in serve/ it
    # would additionally trip ABC601 (injectable-clock discipline), so the
    # fixture lives in core/
    findings = lint_fixture(tmp_path, "src/repro/core/dx.py", """
        import time
        import numpy as np

        def f():
            t = time.perf_counter()
            rng = np.random.default_rng(0)
            return t, rng
    """)
    assert findings == []


# ---------------------------------------------------------------------------
# pass 4 — kernel contract (scope: kernels/)
# ---------------------------------------------------------------------------


def _kernel_pkg(tmp_path, name, files):
    pkg = tmp_path / "src" / "repro" / "kernels" / name
    pkg.mkdir(parents=True)
    for fn, code in files.items():
        (pkg / fn).write_text(textwrap.dedent(code))
    return engine.run_passes(
        ALL_PASSES, root=str(tmp_path), scope=("src/repro/kernels",)
    )


def test_abc401_missing_trio(tmp_path):
    findings = _kernel_pkg(tmp_path, "mykern", {"ops.py": "X = 1\n"})
    assert rules_of(findings) == ["ABC401"]
    assert "kernel.py" in findings[0].message
    assert "ref.py" in findings[0].message


def test_abc401_clean_full_trio(tmp_path):
    findings = _kernel_pkg(
        tmp_path, "mykern",
        {"ops.py": "X = 1\n", "kernel.py": "Y = 1\n", "ref.py": "Z = 1\n"},
    )
    assert findings == []


def test_abc402_raw_compiler_params(tmp_path):
    findings = _kernel_pkg(tmp_path, "mykern", {
        "ops.py": "", "ref.py": "",
        "kernel.py": """
            from jax.experimental.pallas import tpu as pltpu

            def params():
                return pltpu.TPUCompilerParams(dimension_semantics=())
        """,
    })
    assert "ABC402" in rules_of(findings)


def test_abc403_pallas_call_without_interpret(tmp_path):
    findings = _kernel_pkg(tmp_path, "mykern", {
        "ops.py": "", "ref.py": "",
        "kernel.py": """
            import functools
            import jax
            import jax.experimental.pallas as pl

            @functools.partial(jax.jit, static_argnames=("block",))
            def launch(x, block):
                if x.shape[0] % block:
                    raise ValueError(x.shape)
                return pl.pallas_call(_body)(x)
        """,
    })
    assert rules_of(findings) == ["ABC403"]


def test_abc404_bare_assert_in_dispatcher(tmp_path):
    findings = _kernel_pkg(tmp_path, "mykern", {
        "kernel.py": "", "ref.py": "",
        "ops.py": """
            def dispatch(x, block):
                assert x.shape[0] % block == 0
                return x
        """,
    })
    assert rules_of(findings) == ["ABC404"]


def test_abc405_launch_without_divisibility_guard(tmp_path):
    findings = _kernel_pkg(tmp_path, "mykern", {
        "ops.py": "", "ref.py": "",
        "kernel.py": """
            import jax
            import jax.experimental.pallas as pl

            @jax.jit
            def launch(x):
                return pl.pallas_call(_body, interpret=True)(x)
        """,
    })
    assert rules_of(findings) == ["ABC405"]


def test_kernel_contract_clean_guarded_launch(tmp_path):
    findings = _kernel_pkg(tmp_path, "mykern", {
        "ops.py": """
            def dispatch(x, block):
                if x.shape[0] % block != 0:
                    raise ValueError(
                        f"size {x.shape[0]} not divisible by {block}"
                    )
                return x
        """,
        "ref.py": "def oracle(x):\n    return x\n",
        "kernel.py": """
            import functools
            import jax
            import jax.experimental.pallas as pl

            @functools.partial(jax.jit, static_argnames=("block", "interpret"))
            def launch(x, block, *, interpret):
                if x.shape[0] % block != 0:
                    raise ValueError((x.shape, block))
                return pl.pallas_call(_body, interpret=interpret)(x)
        """,
    })
    assert findings == []


# ---------------------------------------------------------------------------
# pass 5 — serving memory
# ---------------------------------------------------------------------------


def test_abc501_init_cache_in_serving_layer(tmp_path):
    findings = lint_fixture(tmp_path, "src/repro/serve/mx.py", """
        from repro.models import api

        def build(cfg, n_slots, max_seq):
            return api.init_cache(cfg, n_slots, max_seq)
    """)
    assert rules_of(findings) == ["ABC501"]


def test_abc501_out_of_scope_in_models(tmp_path):
    # batch-generation caches in the model layer are not slot memory
    findings = lint_fixture(tmp_path, "src/repro/models/mx.py", """
        from repro.models import api

        def build(cfg, batch, max_seq):
            return api.init_cache(cfg, batch, max_seq)
    """)
    assert findings == []


def test_abc502_e_stacked_zeros(tmp_path):
    findings = lint_fixture(tmp_path, "src/repro/serve/mx.py", """
        import jax
        import jax.numpy as jnp

        def stack(values0, E):
            return jax.tree.map(
                lambda v: jnp.zeros((E,) + v.shape, v.dtype), values0
            )
    """)
    assert rules_of(findings) == ["ABC502"]


def test_abc502_clean_plain_shapes(tmp_path):
    # literal-tuple and same-shape allocations are not the stack idiom
    findings = lint_fixture(tmp_path, "src/repro/serve/mx.py", """
        import jax.numpy as jnp

        def build(v, n_pages, page_size):
            a = jnp.zeros((n_pages, page_size), jnp.float32)
            b = jnp.zeros(v.shape, v.dtype)
            return a, b
    """)
    assert findings == []


def test_memory_pragma_covers_oracle_site(tmp_path):
    findings = lint_fixture(tmp_path, "src/repro/serve/mx.py", """
        from repro.models import api

        def build(cfg, n_slots, max_seq):
            # abclint: disable=ABC501(fixture parity oracle justification)
            return api.init_cache(cfg, n_slots, max_seq)
    """)
    assert findings == []


# ---------------------------------------------------------------------------
# pragma mechanics
# ---------------------------------------------------------------------------


def test_pragma_same_line_suppresses(tmp_path):
    findings = lint_fixture(tmp_path, "src/repro/core/px.py", """
        def f(b):
            return hash(b)  # abclint: disable=ABC301(fixture justification)
    """)
    assert findings == []


def test_pragma_comment_line_above_suppresses(tmp_path):
    findings = lint_fixture(tmp_path, "src/repro/core/px.py", """
        def f(b):
            # abclint: disable=ABC301(fixture justification)
            return hash(b)
    """)
    assert findings == []


def test_pragma_without_reason_is_abc001(tmp_path):
    findings = lint_fixture(tmp_path, "src/repro/core/px.py", """
        def f(b):
            return hash(b)  # abclint: disable=ABC301
    """)
    # the reasonless pragma is itself a finding AND suppresses nothing
    assert rules_of(findings) == ["ABC001", "ABC301"]


def test_unused_pragma_is_abc002(tmp_path):
    findings = lint_fixture(tmp_path, "src/repro/core/px.py", """
        def f(b):
            return b  # abclint: disable=ABC301(nothing here to suppress)
    """)
    assert rules_of(findings) == ["ABC002"]


def test_pragma_wrong_rule_does_not_suppress(tmp_path):
    findings = lint_fixture(tmp_path, "src/repro/core/px.py", """
        def f(b):
            return hash(b)  # abclint: disable=ABC302(wrong rule id)
    """)
    assert rules_of(findings) == ["ABC002", "ABC301"]


# ---------------------------------------------------------------------------
# pass 6 — telemetry discipline
# ---------------------------------------------------------------------------


def test_abc601_raw_perf_counter_in_serve(tmp_path):
    findings = lint_fixture(tmp_path, "src/repro/serve/mx.py", """
        import time

        def step(self):
            t0 = time.perf_counter()
            return time.perf_counter() - t0
    """)
    assert rules_of(findings) == ["ABC601", "ABC601"]


def test_abc601_clean_injected_clock_and_link_physics(tmp_path):
    # holding the clock FUNCTION (assignment) and calling through it is the
    # blessed pattern; time.monotonic/time.sleep are transport link physics
    findings = lint_fixture(tmp_path, "src/repro/serve/mx.py", """
        import time

        from repro.obs import perf_clock

        class C:
            def __init__(self, obs):
                self._clock = obs.clock if obs else perf_clock

            def step(self):
                t0 = self._clock()
                time.sleep(0.0)
                now = time.monotonic()
                return self._clock() - t0, now
    """)
    assert findings == []


def test_abc601_out_of_scope_outside_serve(tmp_path):
    findings = lint_fixture(tmp_path, "src/repro/core/mx.py", """
        import time

        def bench():
            return time.perf_counter()
    """)
    assert findings == []


def test_abc602_stats_dict_mutation(tmp_path):
    findings = lint_fixture(tmp_path, "src/repro/serve/mx.py", """
        class C:
            def __init__(self):
                self.stats = {"n": 0}
                self._stats = {"m": 0}

            def step(self, stats):
                self.stats["n"] += 1
                self._stats["m"] = 5
                stats["k"] = 2
    """)
    assert rules_of(findings) == ["ABC602", "ABC602", "ABC602"]


def test_abc602_clean_registry_and_plain_dicts(tmp_path):
    # registry metrics and unrelated dicts stay silent — only stats-named
    # subscript targets are the legacy surface
    findings = lint_fixture(tmp_path, "src/repro/serve/mx.py", """
        class C:
            def __init__(self, sc):
                self._c = sc.counter("n")
                self.table = {}

            def step(self, r):
                self._c.add(1)
                self.table[r] = 1
                view = self.table["x"] if "x" in self.table else None
                return view
    """)
    assert findings == []


# ---------------------------------------------------------------------------
# baseline mechanics
# ---------------------------------------------------------------------------


def _one_finding(tmp_path):
    p = tmp_path / "src" / "repro" / "core" / "bx.py"
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text("def f(b):\n    return hash(b)\n")
    return "src/repro/core/bx.py"


def test_baseline_suppresses_and_reports(tmp_path):
    rel = _one_finding(tmp_path)
    findings = engine.run_passes(ALL_PASSES, root=str(tmp_path), scope=(rel,))
    (f, fp), = engine.fingerprinted(findings)
    baseline = {fp: {"fingerprint": fp, "rule": f.rule, "reason": "audited"}}
    res = engine.run(
        ALL_PASSES, root=str(tmp_path), scope=(rel,), baseline=baseline
    )
    assert res.ok
    assert res.findings == [] and rules_of(res.baselined) == ["ABC301"]


def test_baseline_fingerprint_survives_line_moves(tmp_path):
    rel = _one_finding(tmp_path)
    findings = engine.run_passes(ALL_PASSES, root=str(tmp_path), scope=(rel,))
    (_, fp), = engine.fingerprinted(findings)
    # shift the offending line down: content fingerprint must not change
    p = tmp_path / rel
    p.write_text("X = 1\n\n\ndef f(b):\n    return hash(b)\n")
    moved = engine.run_passes(ALL_PASSES, root=str(tmp_path), scope=(rel,))
    (_, fp2), = engine.fingerprinted(moved)
    assert fp2 == fp


def test_stale_baseline_entry_fails_run(tmp_path):
    rel = _one_finding(tmp_path)
    baseline = {"deadbeefdeadbeef": {
        "fingerprint": "deadbeefdeadbeef", "rule": "ABC301",
        "reason": "the code this suppressed is gone",
    }}
    res = engine.run(
        ALL_PASSES, root=str(tmp_path), scope=(rel,), baseline=baseline
    )
    assert not res.ok and len(res.stale_baseline) == 1


def test_baseline_load_rejects_empty_reason(tmp_path):
    bp = tmp_path / "baseline.json"
    bp.write_text(json.dumps({"version": 1, "entries": [
        {"fingerprint": "ab" * 8, "rule": "ABC301", "reason": "  "}
    ]}))
    with pytest.raises(engine.BaselineError, match="no justification"):
        engine.load_baseline(str(bp))


def test_write_baseline_preserves_old_reasons(tmp_path):
    rel = _one_finding(tmp_path)
    findings = engine.run_passes(ALL_PASSES, root=str(tmp_path), scope=(rel,))
    (_, fp), = engine.fingerprinted(findings)
    bp = tmp_path / "baseline.json"
    engine.write_baseline(str(bp), findings, {fp: {"reason": "kept reason"}})
    loaded = engine.load_baseline(str(bp))
    assert loaded[fp]["reason"] == "kept reason"
    # fresh entries get an empty reason, which load_baseline refuses
    engine.write_baseline(str(bp), findings, {})
    with pytest.raises(engine.BaselineError):
        engine.load_baseline(str(bp))


# ---------------------------------------------------------------------------
# CLI + the tier-1 repo-clean regression
# ---------------------------------------------------------------------------


def test_cli_list_rules_and_usage_error(capsys):
    assert abclint_main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for rule in ("ABC001", "ABC101", "ABC201", "ABC301", "ABC401"):
        assert rule in out
    assert abclint_main(["no/such/path.py"]) == 2


def test_repo_is_abclint_clean_against_committed_baseline():
    """Tier-1 invariant: the repo lints clean — every finding is either
    fixed, pragma'd with a reason, or in the committed justified baseline,
    and no baseline entry is stale."""
    baseline = engine.load_baseline(
        os.path.join(engine.REPO, engine.BASELINE_DEFAULT)
    )
    res = engine.run(ALL_PASSES, baseline=baseline)
    msg = "\n".join(f.render() for f in res.findings)
    msg += "".join(f"\nstale: {e}" for e in res.stale_baseline)
    assert res.ok, f"abclint regressions:\n{msg}"


def test_cli_json_report(capsys):
    # full default scope: a narrower scope would strand the committed
    # baseline entries as stale (by design — the baseline only shrinks)
    assert abclint_main(["--json"]) == 0
    report = json.loads(capsys.readouterr().out)
    assert report["findings"] == []
    assert report["stale_baseline"] == []
    assert report["summary"]["baselined"] == 1


def test_baseline_guard_shrink_only(tmp_path, capsys):
    """CI guard: fingerprints may leave the baseline, never join it."""
    from tools.abclint.baseline_guard import main as guard_main

    def write(path, fps):
        path.write_text(json.dumps(
            {"version": 1,
             "entries": [{"fingerprint": f, "rule": "ABC203",
                          "path": "x.py", "snippet": "s", "reason": "r"}
                         for f in fps]}))

    old, new = tmp_path / "old.json", tmp_path / "new.json"
    write(old, ["aaaa", "bbbb"])
    write(new, ["aaaa"])
    assert guard_main([str(old), str(new)]) == 0  # shrank: ok
    write(new, ["aaaa", "bbbb", "cccc"])
    assert guard_main([str(old), str(new)]) == 1  # grew: fail
    assert "cccc" in capsys.readouterr().err
    # missing base file (first PR that introduces a baseline) == empty set
    assert guard_main([str(tmp_path / "absent.json"), str(old)]) == 1
    assert guard_main(["a", "b", "c"]) == 2  # usage


def test_baseline_guard_default_new_is_committed_baseline(capsys):
    from tools.abclint.baseline_guard import main as guard_main

    committed = os.path.join(engine.REPO, engine.BASELINE_DEFAULT)
    assert guard_main([committed]) == 0  # committed vs itself: no growth
    assert "baseline ok" in capsys.readouterr().out
