"""Async transport + overlap (DESIGN.md §8): the future-based hop contract,
async==sync serving equivalence (same generations, same metered hops),
wall-clock overlap on a slow link, in-flight SlotStream admission, and the
transfer-guard discipline of the async classify path."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import cascade, ensemble as ens
from repro.core.cascade import TierSpec
from repro.models.params import unbox
from repro.serve import (
    AsyncTransport,
    CascadeServer,
    CascadeTier,
    Request,
    SendHandle,
    ServingEngine,
    edge_cloud,
)

SMALL = ModelConfig(
    name="tiny-s", family="dense", n_layers=2, d_model=64, d_ff=128,
    vocab_size=64, n_heads=4, n_kv_heads=2, remat=False,
)
BIG = ModelConfig(
    name="tiny-b", family="dense", n_layers=3, d_model=96, d_ff=192,
    vocab_size=64, n_heads=4, n_kv_heads=4, remat=False,
)


@pytest.fixture(scope="module")
def stacks():
    v1, _ = unbox(ens.init_ensemble(SMALL, 3, jax.random.PRNGKey(0)))
    v2, _ = unbox(ens.init_ensemble(BIG, 1, jax.random.PRNGKey(1)))
    return v1, v2


def _server(stacks, placement):
    v1, v2 = stacks
    return CascadeServer(
        [
            CascadeTier(SMALL, v1, TierSpec("t1", "vote", 0.67, k=3, cost=1.0)),
            CascadeTier(BIG, v2, TierSpec("t2", "confidence", -1.0, k=1, cost=50.0)),
        ],
        placement=placement,
    )


def _requests(n=8, max_new=5):
    rng = np.random.default_rng(6)
    return [
        Request(tokens=rng.integers(0, 64, 8).astype(np.int32),
                max_new_tokens=max_new)
        for _ in range(n)
    ]


# ---------------------------------------------------------------------------
# the hop/handle contract
# ---------------------------------------------------------------------------


def test_send_async_returns_live_handle_and_meters_at_send_time():
    tr = AsyncTransport(delay=0.05)
    payload = {"x": np.arange(12, dtype=np.int32)}
    t0 = time.perf_counter()
    h = tr.send_async("edge0", "cloud0", payload, n_examples=3)
    assert time.perf_counter() - t0 < 0.04, "send_async must not block"
    # the hop is metered at SEND time, before the payload lands
    assert tr.total_bytes == 48 and tr.total_examples == 3
    assert tr.hops[0].latency == pytest.approx(0.05)
    out = h.result()
    assert h.done()
    np.testing.assert_array_equal(np.asarray(out["x"]), payload["x"])
    assert h.result() is out  # memoized


def test_serial_mode_blocks_but_meters_identically():
    tr = AsyncTransport(delay=0.05, overlap=False)
    t0 = time.perf_counter()
    h = tr.send_async("edge0", "cloud0", {"x": np.zeros(4, np.float32)},
                      n_examples=4)
    assert time.perf_counter() - t0 >= 0.05, "serial send must sleep inline"
    assert h.done() and tr.total_wait == 0.0
    assert tr.hops[0].latency == pytest.approx(0.05)


def test_sync_backends_return_resolved_handles():
    from repro.serve import LoopbackTransport, SimulatedLinkTransport

    for tr in (LoopbackTransport(), SimulatedLinkTransport(delay=0.01)):
        h = tr.send_async("a", "b", {"x": np.ones(2, np.float32)}, n_examples=2)
        assert isinstance(h, SendHandle) and h.done()
        assert tr.total_examples == 2


def test_handle_wait_time_is_the_unhidden_link_time():
    tr = AsyncTransport(delay=0.08)
    h = tr.send_async("e", "c", {"x": np.zeros(2, np.int32)}, n_examples=1)
    h.result()  # nothing overlapped: the full latency shows up as wait
    assert tr.total_wait == pytest.approx(0.08, abs=0.05)
    h2 = tr.send_async("e", "c", {"x": np.zeros(2, np.int32)}, n_examples=1)
    time.sleep(0.12)  # "compute" hides the whole hop
    h2.result()
    assert h2.wait_time < 0.04


# ---------------------------------------------------------------------------
# async == sync serving equivalence + measured overlap
# ---------------------------------------------------------------------------


def _serve(stacks, link, delay=0.05):
    placement = edge_cloud(delay=delay, link=link)
    server = _server(stacks, placement)
    t0 = time.perf_counter()
    done = server.serve_continuous(_requests(), n_slots=2, max_seq=32)
    wall = time.perf_counter() - t0
    return done, wall, placement.link(0)


def test_async_equals_sync_generations_and_metered_hops(stacks):
    """The equivalence sweep: same generations, same answering tiers, same
    per-hop metered bytes across sim / serial / overlapped links, and an
    overlap ratio > 1 on the slow link (link time really hidden)."""
    done_sim, _, link_sim = _serve(stacks, "sim")  # also compile warmup
    done_ser, wall_ser, link_ser = _serve(stacks, "serial")
    done_ovl, wall_ovl, link_ovl = _serve(stacks, "async")

    key = lambda done: {tuple(r.tokens): (r.tier, tuple(r.output))
                        for r in done}
    assert key(done_sim) == key(done_ser) == key(done_ovl)
    hops = lambda link: [(h.src, h.dst, h.n_examples, h.payload_bytes)
                         for h in link.hops]
    assert hops(link_sim) == hops(link_ser) == hops(link_ovl)
    assert link_ovl.total_examples > 0, "test needs real deferrals"

    # wall clock: the serial run pays every hop inline; the overlapped run
    # hides (most of) the link behind continuing decode work.  total_wait is
    # the monotone check (more compute can only hide MORE link time).
    assert link_ovl.total_wait < link_ovl.total_latency
    assert wall_ovl < wall_ser, (
        f"overlap ratio <= 1: serial {wall_ser:.3f}s vs "
        f"overlapped {wall_ovl:.3f}s"
    )


def test_async_serving_completes_all_requests_with_one_slot_tiers(stacks):
    """Degenerate capacity (n_slots=1): the all-idle fallback must block on
    in-flight hops instead of dropping them or spinning."""
    placement = edge_cloud(delay=0.03, link="async")
    server = _server(stacks, placement)
    reqs = _requests(n=4, max_new=3)
    done = server.serve_continuous(reqs, n_slots=1, max_seq=32)
    assert len(done) == 4
    assert all(r.output is not None for r in done)


# ---------------------------------------------------------------------------
# sampled voting is transport-invariant (per-slot admission rng)
# ---------------------------------------------------------------------------


def _sampled_server(stacks, placement):
    v1, v2 = stacks
    return CascadeServer(
        [
            CascadeTier(SMALL, v1, TierSpec("t1", "vote", 0.9, k=3, cost=1.0),
                        temperature=0.8),
            CascadeTier(BIG, v2,
                        TierSpec("t2", "confidence", -1.0, k=1, cost=50.0),
                        temperature=0.8),
        ],
        placement=placement,
    )


def test_sampled_voting_bitwise_identical_across_transports(stacks):
    """temperature=0.8 voting across sim / serial / overlapped links: every
    slot's sampling key is fold_in(base, admit_seq) assigned at admission
    (FIFO, so transport-timing-invariant), and each token draws from
    fold_in(fold_in(slot_key, pos), e) — a trajectory never depends on
    which OTHER slots share its decode dispatches.  Delivery timing
    reshuffles slot co-residency between these three links, so bitwise
    equality here is exactly the regression test for the old shared-rng
    thread that made sampled voting interleaving-dependent."""
    outs = []
    for link in ("sim", "serial", "async"):
        server = _sampled_server(stacks, edge_cloud(delay=0.03, link=link))
        done = server.serve_continuous(
            _requests(), n_slots=2, max_seq=32, seed=7
        )
        outs.append(
            {tuple(r.tokens): (r.tier, tuple(r.output)) for r in done}
        )
    assert outs[0] == outs[1] == outs[2]


# ---------------------------------------------------------------------------
# link capacity: the token bucket serializes concurrent transmissions
# ---------------------------------------------------------------------------


def test_bandwidth_token_bucket_serializes_concurrent_sends():
    """Two concurrent sends of tx=0.08s each on a shared wire: the second
    delivery queues behind the first transmission (~2*tx end-to-end), while
    pure-delay hops (no bandwidth) stay fully concurrent — the old model
    let concurrent hops share the wire for free."""
    payload = {"x": np.zeros(1000, np.float32)}  # 4000 bytes
    tr = AsyncTransport(delay=0.0, bandwidth=50_000.0)  # tx = 0.08s
    t0 = time.perf_counter()
    h1 = tr.send_async("e", "c", payload, n_examples=1)
    h2 = tr.send_async("e", "c", payload, n_examples=1)
    h1.result()
    t1 = time.perf_counter() - t0
    h2.result()
    t2 = time.perf_counter() - t0
    assert t1 >= 0.08, f"first send must pay its own tx: {t1:.3f}s"
    assert t2 >= 0.15, f"second send must queue behind the first: {t2:.3f}s"
    # metering stays uncontended: both hops account delay + bytes/bandwidth
    assert [h.latency for h in tr.hops] == [pytest.approx(0.08)] * 2
    assert tr.total_wait > 0.0
    # without a bandwidth the link is delay-dominated: hops fully overlap
    tr2 = AsyncTransport(delay=0.08)
    t0 = time.perf_counter()
    hs = [tr2.send_async("e", "c", payload, n_examples=1) for _ in range(4)]
    for h in hs:
        h.result()
    assert time.perf_counter() - t0 < 0.25, "pure-delay hops must overlap"


def test_bandwidth_metering_identical_serial_vs_overlapped():
    """Serial and overlapped drains of the same sends meter IDENTICAL hop
    lists (order, bytes, examples, latency): contention exists only on the
    wall clock and in total_wait, never in the accounting the benches and
    cost model read."""
    payload = {"x": np.arange(256, dtype=np.float32)}
    hop_lists = []
    for overlap in (False, True):
        tr = AsyncTransport(delay=0.01, bandwidth=1e6, overlap=overlap)
        hs = [tr.send_async("e", "c", payload, n_examples=2) for _ in range(3)]
        for h in hs:
            h.result()
        assert tr.total_bytes == 3 * 256 * 4
        hop_lists.append([
            (h.src, h.dst, h.n_examples, h.payload_bytes, h.latency)
            for h in tr.hops
        ])
    assert hop_lists[0] == hop_lists[1]


# ---------------------------------------------------------------------------
# SlotStream in-flight admission (unit level)
# ---------------------------------------------------------------------------


def test_slot_stream_inflight_admission(stacks):
    v1, _ = stacks
    one = ens.take_member(v1, 0)
    eng = ServingEngine(SMALL, one, max_seq=64)
    stream = eng.slot_stream(n_slots=2)
    tr = AsyncTransport(delay=0.02)
    rng = np.random.default_rng(1)
    reqs = [Request(tokens=rng.integers(0, 64, 6).astype(np.int32),
                    max_new_tokens=3) for _ in range(3)]
    for r in reqs:
        h = tr.send_async("edge0", "cloud0",
                          {"tokens": r.tokens}, n_examples=1)
        stream.submit_inflight(
            h, lambda delivered, r=r: r
        )
    assert stream.active and not stream.runnable
    done = stream.drain()
    assert len(done) == 3
    assert stream.stats["inflight_admitted"] == 3
    assert not stream.inflight and not stream.active


def test_slot_stream_inflight_preserves_fifo_order():
    """Handles resolve in submission order even when a later handle is done
    first — admission order must match a blocking transport's."""

    class _StubTransport:
        total_wait = 0.0

        def _waited(self, s):
            pass

    class _StubHandle(SendHandle):
        def __init__(self, value, ready):
            super().__init__(_StubTransport(), value=value)
            self._ready = ready

        def done(self):
            return self._ready()

    from repro.serve.slot_stream import SlotStream

    class _NullBackend:
        E = 1
        supports_chunked_prefill = False

        def decode(self, tok, pos):
            return np.zeros((1, tok.shape[1]), np.int32)

        def reset_slot(self, s):
            pass

    stream = SlotStream(_NullBackend(), n_slots=1, max_seq=8)
    first_ready = {"v": False}
    r1 = Request(tokens=np.array([1], np.int32), max_new_tokens=1)
    r2 = Request(tokens=np.array([2], np.int32), max_new_tokens=1)
    stream.submit_inflight(_StubHandle(None, lambda: first_ready["v"]),
                           lambda _: r1)
    stream.submit_inflight(_StubHandle(None, lambda: True), lambda _: r2)
    stream.poll_inflight(block=False)
    # second handle is done, but the first isn't: nothing may land yet
    assert not stream.queue and len(stream.inflight) == 2
    first_ready["v"] = True
    stream.poll_inflight(block=False)
    assert [r.rid for r in stream.queue] == [r1.rid, r2.rid]


# ---------------------------------------------------------------------------
# sharded hand-off (single-device degenerate case; the real 8-device sweep
# lives in test_placement_transport.py's subprocess)
# ---------------------------------------------------------------------------


def test_sharded_transport_single_device_degrades_to_replication():
    """On a trivial (1,1,1) pod mesh the example axis has nowhere to shard:
    delivery must degrade to replication, with metering unchanged."""
    from repro.serve import ShardedDevicePutTransport

    mesh = jax.make_mesh((1, 1, 1), ("pod", "data", "model"))
    tr = ShardedDevicePutTransport(mesh)
    payload = {"x": jnp.ones((8, 4), jnp.float32),
               "__idx": jnp.arange(8, dtype=jnp.int32)}
    assert tr.shard_counts(payload) == [1, 1]
    out = tr.send("pod0", "pod1", payload, n_examples=8)
    np.testing.assert_array_equal(np.asarray(out["x"]),
                                  np.asarray(payload["x"]))
    np.testing.assert_array_equal(np.asarray(out["__idx"]),
                                  np.asarray(payload["__idx"]))
    assert tr.total_bytes == 8 * 4 * 4 + 8 * 4
    assert tr.total_examples == 8
    spec = tr.example_sharding(payload["x"])
    assert spec.mesh.shape["data"] == 1


# ---------------------------------------------------------------------------
# transfer guard: the async defer path still fetches one scalar per hop
# ---------------------------------------------------------------------------


def test_async_classify_fetches_one_count_scalar_per_transition(stacks):
    """The routed cascade over an AsyncTransport link under a device->host
    transfer guard: implicit transfers raise, and the explicit-fetch meter
    must see only per-tier count scalars + final (B,) results — the async
    path must not regress the device-resident defer path."""
    placement = edge_cloud(delay=0.005, link="async")
    server = _server(stacks, placement)
    B, S = 16, 12
    toks = np.random.default_rng(2).integers(0, 64, (B, S)).astype(np.int32)
    cascade.reset_host_fetch_stats()
    with jax.transfer_guard_device_to_host("disallow"):
        res = server.classify(toks)
    assert res.tier_counts.sum() == B
    stats = cascade.host_fetch_stats()
    result_bytes = B * 4 * 3 + 2 * 4
    scalar_bytes = 4
    assert stats["bytes"] <= result_bytes + scalar_bytes, stats
    assert stats["bytes"] < B * S * 4
    link = placement.link(0)
    assert link.total_examples == int(res.tier_counts[1])
