"""Required per-arch smoke tests: instantiate the REDUCED variant of each
assigned architecture (<=2 layers, d_model<=512, <=4 experts) and run one
forward / train step on CPU asserting output shapes + no NaNs.  The FULL
configs are exercised only via the dry-run (launch/dryrun.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ShapeConfig, get_config, list_configs, shape_supported, INPUT_SHAPES
from repro.models import api
from repro.models.params import unbox

SMOKE_TRAIN = ShapeConfig("smoke_train", 64, 2, "train")
SMOKE_PREFILL = ShapeConfig("smoke_prefill", 64, 2, "prefill")
SMOKE_DECODE = ShapeConfig("smoke_decode", 64, 2, "decode")

ARCHS = list_configs()


@pytest.fixture(scope="module")
def zoo():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = get_config(arch).reduced()
            values, axes = unbox(api.init_params(cfg, jax.random.PRNGKey(0)))
            cache[arch] = (cfg, values)
        return cache[arch]

    return get


def test_all_archs_registered():
    assert len(ARCHS) == 10
    fams = {get_config(a).family for a in ARCHS}
    assert len(fams) == 6  # spanning 6 arch types


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_constraints(arch):
    cfg = get_config(arch).reduced()
    assert cfg.n_layers <= 2
    assert cfg.d_model <= 512
    assert cfg.n_experts <= 4
    assert cfg.family == get_config(arch).family


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch, zoo):
    cfg, values = zoo(arch)
    batch = api.make_inputs(cfg, SMOKE_TRAIN)
    loss, metrics = jax.jit(lambda v, b: api.loss_fn(v, b, cfg))(values, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"
    assert 0.0 <= float(metrics["acc"]) <= 1.0


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch, zoo):
    cfg, values = zoo(arch)
    batch = api.make_inputs(cfg, SMOKE_PREFILL)
    logits = api.forward_logits(values, batch, cfg)
    B = SMOKE_PREFILL.global_batch
    S = SMOKE_PREFILL.seq_len - (cfg.n_vision_tokens if cfg.n_vision_tokens else 0)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step_smoke(arch, zoo):
    cfg, values = zoo(arch)
    ok, reason = shape_supported(cfg, SMOKE_DECODE)
    if not ok:
        pytest.skip(reason)
    B, S = 2, 64
    cache_boxed = api.init_cache(cfg, B, S)
    cache, _ = unbox(cache_boxed)
    tok = jnp.zeros((B, 1), jnp.int32)
    logits, new_cache = api.decode_step(values, tok, cache, jnp.int32(3), cfg)
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    # cache structure preserved
    assert jax.tree.structure(new_cache) == jax.tree.structure(cache)


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_matches_forward(arch, zoo):
    cfg, values = zoo(arch)
    batch = api.make_inputs(cfg, SMOKE_PREFILL)
    full = api.forward_logits(values, batch, cfg)
    last, _ = api.prefill(values, batch, cfg)
    np.testing.assert_allclose(
        np.asarray(last), np.asarray(full[:, -1]), atol=2e-2, rtol=2e-2
    )


def test_skip_matrix_documented():
    """The assignment's skip matrix: hubert (encoder-only) skips decode."""
    hubert = get_config("hubert-xlarge")
    for name in ("decode_32k", "long_500k"):
        ok, reason = shape_supported(hubert, INPUT_SHAPES[name])
        assert not ok and "encoder" in reason
    # everything else supports all four shapes
    for arch in ARCHS:
        if arch == "hubert-xlarge":
            continue
        for shape in INPUT_SHAPES.values():
            ok, _ = shape_supported(get_config(arch), shape)
            assert ok, (arch, shape.name)
