"""ABC core: deferral rules, calibration, cascade forms, cost model."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import calibration, cost_model, deferral, theory
from repro.core.cascade import TierSpec, cascade_apply_dense, cascade_apply_routed


def _synthetic_tier(E, B, V, correct_p, y, seed=0):
    rng = np.random.default_rng(seed)
    logits = rng.normal(0, 1, (E, B, V)).astype(np.float32)
    for e in range(E):
        corr = rng.random(B) < correct_p
        wrong = (y + 1 + rng.integers(0, V - 1, B)) % V
        logits[e, np.arange(B), np.where(corr, y, wrong)] += 4
    return jnp.asarray(logits)


@pytest.fixture(scope="module")
def setup():
    rng = np.random.default_rng(0)
    B, V, E = 1500, 10, 3
    y = rng.integers(0, V, B)
    easy = rng.random(B) < 0.6
    p1 = np.where(easy, 0.97, 0.25)
    t1 = _synthetic_tier(E, B, V, p1, y, seed=1)
    t2 = _synthetic_tier(1, B, V, 0.9, y, seed=2)
    return {"y": y, "easy": easy, "t1": t1, "t2": t2, "B": B}


def test_vote_rule_bounds(setup):
    out = deferral.vote_rule(setup["t1"], theta=0.5)
    s = np.asarray(out.score)
    E = setup["t1"].shape[0]
    assert (s >= 1.0 / E - 1e-6).all() and (s <= 1.0 + 1e-6).all()


def test_vote_rule_from_preds_matches_logits(setup):
    logits = setup["t1"]
    preds = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    a = deferral.vote_rule(logits, 0.6)
    b = deferral.vote_rule_from_preds(preds, 0.6)
    np.testing.assert_allclose(np.asarray(a.score), np.asarray(b.score))
    np.testing.assert_array_equal(np.asarray(a.defer), np.asarray(b.defer))


def test_selected_subset_is_accurate(setup):
    """The heart of ABC: agreement identifies the subset where the small
    ensemble is right (safe deferral, Def. 4.1)."""
    out = deferral.vote_rule(setup["t1"], theta=0.67)
    sel = ~np.asarray(out.defer)
    acc_sel = (np.asarray(out.pred)[sel] == setup["y"][sel]).mean()
    assert acc_sel > 0.97
    assert sel.mean() > 0.3  # and it actually selects a useful fraction


def test_calibration_feasible(setup):
    out = deferral.vote_rule(setup["t1"], theta=0.0)
    correct = np.asarray(out.pred) == setup["y"]
    theta, info = calibration.estimate_threshold(
        np.asarray(out.score), correct, epsilon=0.02
    )
    assert info["failure_rate"] <= 0.02
    assert info["selection_rate"] > 0.2


def test_calibration_monotone_in_epsilon(setup):
    out = deferral.vote_rule(setup["t1"], theta=0.0)
    correct = np.asarray(out.pred) == setup["y"]
    s = np.asarray(out.score)
    sels = []
    for eps in (0.0, 0.01, 0.03, 0.05, 0.2):
        _, info = calibration.estimate_threshold(s, correct, epsilon=eps)
        sels.append(info["selection_rate"])
    assert all(a <= b + 1e-9 for a, b in zip(sels, sels[1:]))


def test_calibration_infeasible_degenerates_safely():
    scores = np.full(100, 1.0)
    correct = np.zeros(100, bool)  # always wrong at max confidence
    theta, info = calibration.estimate_threshold(scores, correct, epsilon=0.0)
    assert info["selection_rate"] == 0.0  # always defer


def test_dense_equals_routed(setup):
    fns = [
        lambda b: setup["t1"][:, b["idx"]],
        lambda b: setup["t2"][:, b["idx"]],
    ]
    specs = [
        TierSpec("t1", "vote", 0.67, k=3, cost=1.0),
        TierSpec("t2", "confidence", -1.0, k=1, cost=50.0),
    ]
    idx = np.arange(setup["B"])
    pred_d, tier_d, _ = cascade_apply_dense(fns, specs, {"idx": idx})
    res = cascade_apply_routed(fns, specs, {"idx": idx}, pad_to=8)
    np.testing.assert_array_equal(np.asarray(pred_d), res.pred)
    np.testing.assert_array_equal(np.asarray(tier_d), res.tier_of)


def test_routed_cost_less_than_all_large(setup):
    fns = [
        lambda b: setup["t1"][:, b["idx"]],
        lambda b: setup["t2"][:, b["idx"]],
    ]
    specs = [
        TierSpec("t1", "vote", 0.67, k=3, cost=1.0),
        TierSpec("t2", "confidence", -1.0, k=1, cost=50.0),
    ]
    res = cascade_apply_routed(fns, specs, {"idx": np.arange(setup["B"])})
    assert res.cost < 50.0 * setup["B"]
    # drop-in: accuracy >= large model alone - small epsilon
    acc_casc = (res.pred == setup["y"]).mean()
    acc_large = (np.asarray(setup["t2"][0].argmax(-1)) == setup["y"]).mean()
    assert acc_casc >= acc_large - 0.02


def test_prop_4_1_cost_formula():
    # E[C] = (k^rho * gamma + P(defer)) * C(h2)
    c = cost_model.two_level_expected_cost(gamma=0.02, k=3, rho=1.0, defer_rate=0.4)
    assert np.isclose(c, 3 * 0.02 + 0.4)


def test_fig3_cost_saved_shapes():
    # gamma <= 1/50: sequential ~ parallel (paper Fig. 3 right)
    seq = cost_model.fraction_cost_saved(1 / 50, 3, 0.0, 0.6)
    par = cost_model.fraction_cost_saved(1 / 50, 3, 1.0, 0.6)
    assert abs(seq - par) < 0.05
    # gamma >= 1/5: sequential loses most of the savings
    seq5 = cost_model.fraction_cost_saved(1 / 5, 3, 0.0, 0.6)
    par5 = cost_model.fraction_cost_saved(1 / 5, 3, 1.0, 0.6)
    assert par5 - seq5 > 0.2


def test_theory_identities(setup):
    out = deferral.vote_rule(setup["t1"], theta=0.67)
    small = np.asarray(out.pred)
    large = np.asarray(setup["t2"][0].argmax(-1))
    defer = np.asarray(out.defer)
    y = setup["y"]
    t1, t2, r = theory.cascade_risk_decomposition(small, large, defer, y)
    assert np.isclose(t1 + t2, r)
    ex = theory.excess_risk(small, large, defer, y)
    exi = theory.excess_risk_identity(small, large, defer, y)
    assert np.isclose(ex, exi, atol=1e-12)
    eps = theory.safe_rule_epsilon(small, defer, y)
    # Prop 4.1.1: R(cascade) <= R(h2) + eps
    casc_risk = theory.risk(np.where(defer, large, small), y)
    assert casc_risk <= theory.risk(large, y) + eps + 1e-12
