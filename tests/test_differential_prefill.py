"""Differential fuzz of chunked prefill (seeded numpy — runs in tier 1,
no hypothesis needed): across all six families, random (prompt length ×
chunk split × slot) trials assert that

* any chunk split of ``prefill_into_slot`` produces bitwise-identical
  decode logits (the split is an implementation detail, never semantics);
* the chunked path matches the one-shot batch ``prefill`` — bitwise for
  every family except hybrid (whose one-shot recurrent scan re-associates
  bf16 state differently than the chunk-carried path; argmax + tolerance
  there);
* the paged chunk path is bitwise the dense chunk path;
* the speculative verify surface (``prefill_into_slot_logits``) is split-
  invariant, scores the decode head bitwise, and fully accepts the
  model's own greedy continuation — the api-level seed of the serving
  parity tests in tests/test_speculative.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core.cascade import prompt_chunks
from repro.models import api
from repro.models.params import unbox
from repro.serve.paging import PagePool

_BASE = dict(n_layers=2, d_model=64, d_ff=128, vocab_size=64, remat=False)
CONFIGS = {
    "dense": ModelConfig(
        name="df-dense", family="dense", n_heads=4, n_kv_heads=2, **_BASE
    ),
    "moe": ModelConfig(
        name="df-moe", family="moe", n_heads=4, n_kv_heads=2, n_experts=4,
        top_k=2, capacity_factor=4.0, **_BASE
    ),
    "moe_interleaved": ModelConfig(
        name="df-moe-il", family="moe", n_heads=4, n_kv_heads=2, n_experts=4,
        top_k=2, moe_every=2, capacity_factor=4.0, **_BASE
    ),
    "ssm_mamba2": ModelConfig(
        name="df-mamba", family="ssm_mamba2", ssm_state=16, ssm_head_dim=32,
        **_BASE
    ),
    "ssm_rwkv6": ModelConfig(
        name="df-rwkv", family="ssm_rwkv6", ssm_head_dim=32,
        rwkv_lora_rank=8, **_BASE
    ),
    "hybrid": ModelConfig(
        name="df-hybrid", family="hybrid", n_heads=4, n_kv_heads=2,
        ssm_state=16, ssm_head_dim=32, attn_every=2, **_BASE
    ),
}
FAMILIES = list(CONFIGS)
MAX_SEQ = 48
N_SLOTS = 2


@pytest.fixture(scope="module")
def models():
    return {
        f: unbox(api.init_params(cfg, jax.random.PRNGKey(i)))[0]
        for i, (f, cfg) in enumerate(CONFIGS.items())
    }


def _random_split(rng, m):
    """A random composition of m (chunk lengths summing to m)."""
    split = []
    left = m
    while left:
        c = int(rng.integers(1, left + 1))
        split.append(c)
        left -= c
    return split


def _chunked_decode_logits(cfg, params, prompt, split, slot):
    """Chunk prompt[:-1] by ``split`` into ``slot``, then decode the last
    prompt token — the serving admission path, run at the api level."""
    cache, _ = unbox(api.init_cache(cfg, N_SLOTS, MAX_SEQ))
    off = 0
    for c in split:
        cache = api.prefill_into_slot(
            params, jnp.asarray(prompt[off : off + c]), cache,
            jnp.int32(slot), jnp.int32(off), cfg,
        )
        off += c
    P = len(prompt)
    tok = np.zeros((N_SLOTS, 1), np.int32)
    tok[slot, 0] = prompt[-1]
    # per-slot positions: idle slots sit at 0, the active slot at P-1
    pos = np.zeros(N_SLOTS, np.int32)
    pos[slot] = P - 1
    logits, _ = api.decode_step(
        params, jnp.asarray(tok), cache, jnp.asarray(pos), cfg
    )
    return np.asarray(logits[slot])


def _splits(rng, m):
    """Canonical pow2 bucket split plus two random compositions."""
    out = [prompt_chunks(m, 256)]
    out.append(_random_split(rng, m))
    out.append(_random_split(rng, m))
    return out


@pytest.mark.parametrize("family", FAMILIES)
def test_chunk_split_is_bitwise_invariant_and_matches_one_shot(models, family):
    cfg = CONFIGS[family]
    params = models[family]
    rng = np.random.default_rng(FAMILIES.index(family))
    for _ in range(2):
        P = int(rng.integers(3, 34))
        slot = int(rng.integers(0, N_SLOTS))
        prompt = rng.integers(1, cfg.vocab_size, P).astype(np.int32)
        ref = None
        for split in _splits(rng, P - 1):
            logits = _chunked_decode_logits(cfg, params, prompt, split, slot)
            if ref is None:
                ref = logits
            else:
                np.testing.assert_array_equal(
                    ref, logits, err_msg=f"{family} split={split}"
                )
        # the chunked admission path matches the one-shot prefill: bitwise
        # for every family except hybrid, whose one-shot path folds the
        # whole sequence through a single recurrent scan while the chunked
        # path re-associates the bf16 state at chunk boundaries — there the
        # contract is argmax-identical within bf16 tolerance
        one_shot = np.asarray(
            api.prefill(params, {"tokens": jnp.asarray(prompt[None])}, cfg)[0]
        )[0]
        if family == "hybrid":
            np.testing.assert_allclose(ref, one_shot, atol=5e-3, rtol=0)
            assert int(ref.argmax()) == int(one_shot.argmax())
        else:
            np.testing.assert_array_equal(ref, one_shot)


@pytest.mark.parametrize("family", [f for f in FAMILIES
                                    if api.supports_paging(CONFIGS[f])])
def test_paged_chunk_path_is_bitwise_dense(models, family):
    cfg = CONFIGS[family]
    params = models[family]
    rng = np.random.default_rng(1000 + FAMILIES.index(family))
    for _ in range(2):
        P = int(rng.integers(3, 34))
        prompt = rng.integers(1, cfg.vocab_size, P).astype(np.int32)
        dense = _chunked_decode_logits(
            cfg, params, prompt, _random_split(rng, P - 1), 0
        )
        pool = PagePool(16, 4, n_slots=1, max_seq=MAX_SEQ)
        pool.admit(0, prompt, share=False)
        pool_dev, _ = unbox(api.init_paged_pool(cfg, pool.n_pages, 4))
        off = 0
        for c in _random_split(rng, P - 1):
            pool_dev = api.prefill_into_slot_paged(
                params, jnp.asarray(prompt[off : off + c]), pool_dev,
                jnp.asarray(pool.table[0]), jnp.int32(off), cfg,
            )
            off += c
        logits, _ = api.decode_step_paged(
            params, jnp.asarray(prompt[-1:][None]), pool_dev,
            jnp.asarray([P - 1], np.int32), jnp.asarray(pool.table), cfg,
        )
        np.testing.assert_array_equal(dense, np.asarray(logits[0]))


@pytest.mark.parametrize("family", [f for f in FAMILIES
                                    if api.supports_draft_verify(CONFIGS[f])])
def test_verify_surface_split_invariant_and_scores_decode_head(models, family):
    """The verify pass is just chunked prefill + the head: its per-position
    logits must be split-invariant AND its last position must be bitwise
    the decode step's logits for the same token at the same position."""
    cfg = CONFIGS[family]
    params = models[family]
    rng = np.random.default_rng(2000 + FAMILIES.index(family))
    for _ in range(2):
        P = int(rng.integers(3, 26))
        prompt = rng.integers(1, cfg.vocab_size, P).astype(np.int32)
        ref = None
        for split in _splits(rng, P):
            cache, _ = unbox(api.init_cache(cfg, N_SLOTS, MAX_SEQ))
            outs, off = [], 0
            for c in split:
                logits, cache = api.prefill_into_slot_logits(
                    params, jnp.asarray(prompt[off : off + c]), cache,
                    jnp.int32(0), jnp.int32(off), cfg,
                )
                outs.append(np.asarray(logits))
                off += c
            all_pos = np.concatenate(outs, axis=0)  # (P, V)
            if ref is None:
                ref = all_pos
            else:
                np.testing.assert_array_equal(ref, all_pos)
        decode = _chunked_decode_logits(
            cfg, params, prompt, prompt_chunks(P - 1, 256), 0
        )
        np.testing.assert_array_equal(ref[-1], decode)


def test_verify_fully_accepts_own_greedy_continuation(models):
    """api-level seed of the serving acceptance tests: draft = the model's
    own greedy continuation -> every verify choice matches the draft."""
    cfg = CONFIGS["dense"]
    params = models["dense"]
    rng = np.random.default_rng(77)
    prompt = rng.integers(1, cfg.vocab_size, 9).astype(np.int32)
    P, T = len(prompt), 5
    # sequential greedy continuation through the decode program
    cache, _ = unbox(api.init_cache(cfg, 1, MAX_SEQ))
    for off in range(P - 1):
        cache = api.prefill_into_slot(
            params, jnp.asarray(prompt[off : off + 1]), cache,
            jnp.int32(0), jnp.int32(off), cfg,
        )
    tok, cont = int(prompt[-1]), []
    for t in range(T):
        logits, cache = api.decode_step(
            params, jnp.asarray([[tok]], np.int32), cache,
            jnp.asarray([P - 1 + t], np.int32), cfg,
        )
        tok = int(np.asarray(logits[0]).argmax())
        cont.append(tok)
    # verify chunk [prompt[-1], cont[:-1]] scores positions P-1..P+T-2
    cache, _ = unbox(api.init_cache(cfg, 1, MAX_SEQ))
    for off in range(P - 1):
        cache = api.prefill_into_slot(
            params, jnp.asarray(prompt[off : off + 1]), cache,
            jnp.int32(0), jnp.int32(off), cfg,
        )
    chunk = np.asarray([int(prompt[-1])] + cont[:-1], np.int32)
    logits, cache = api.prefill_into_slot_logits(
        params, jnp.asarray(chunk), cache, jnp.int32(0), jnp.int32(P - 1), cfg
    )
    choices = np.asarray(logits).argmax(-1)
    np.testing.assert_array_equal(choices, cont)
