"""Per-request Perfetto tracing tests (DESIGN.md §11).

(a) tracer unit behaviour under a FAKE clock: deterministic microsecond
    timestamps, track metadata, the span vocabulary;
(b) ``validate_trace`` negative cases: malformed events, non-monotone
    track timestamps, mis-nested / unclosed spans, a request that
    vanishes mid-cascade;
(c) the tier-1 integration contract: a two-tier cascade over a real
    ``AsyncTransport`` link emits a schema-valid trace in which EVERY
    admitted request reaches a terminal event, hops carry the
    hidden-vs-blocked overlap split, and the whole serve runs under
    ``jax.transfer_guard_device_to_host("disallow")`` — recording never
    adds a device→host sync.
"""
import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import ensemble as ens
from repro.core.cascade import TierSpec
from repro.models.params import unbox
from repro.obs import (
    Observability,
    REQUEST_PID,
    Tracer,
    validate_trace,
)
from repro.serve import CascadeServer, CascadeTier, Request, edge_cloud

SMALL = ModelConfig(
    name="otr-s", family="dense", n_layers=2, d_model=64, d_ff=128,
    vocab_size=64, n_heads=4, n_kv_heads=2, remat=False,
)
BIG = ModelConfig(
    name="otr-b", family="dense", n_layers=3, d_model=96, d_ff=192,
    vocab_size=64, n_heads=4, n_kv_heads=4, remat=False,
)


class FakeClock:
    def __init__(self):
        self.t = 100.0

    def __call__(self):
        self.t += 0.001
        return self.t


# ---------------------------------------------------------------------------
# (a) tracer unit behaviour
# ---------------------------------------------------------------------------


def test_tracer_deterministic_under_fake_clock():
    tr = Tracer(clock=FakeClock())
    tr.begin(7, "queue_wait", stream="s")
    tr.end(7, "queue_wait")
    tr.instant(7, "complete", tier=0)
    evs = tr.export()["traceEvents"]
    meta = [e for e in evs if e["ph"] == "M"]
    assert meta[0]["name"] == "process_name"
    assert any(e["name"] == "thread_name" and e["tid"] == 7 for e in meta)
    b, e, i = [ev for ev in evs if ev["ph"] in ("B", "E", "i")]
    # the fake clock ticks 1ms per read; ts is µs from tracer construction
    assert b["ts"] == pytest.approx(1000.0)
    assert e["ts"] == pytest.approx(2000.0)
    assert i["ts"] == pytest.approx(3000.0)
    assert b["pid"] == e["pid"] == i["pid"] == REQUEST_PID
    assert i["s"] == "t" and i["args"] == {"tier": 0}
    assert tr.export()["displayTimeUnit"] == "ms"


def test_tracer_track_metadata_idempotent():
    tr = Tracer(clock=FakeClock())
    tr.begin(1, "a")
    tr.end(1, "a")
    tr.begin(1, "b")
    tr.end(1, "b")
    names = [
        e for e in tr.export()["traceEvents"]
        if e["ph"] == "M" and e["name"] == "thread_name"
    ]
    assert len(names) == 1


def test_write_and_validate_roundtrip(tmp_path):
    import json

    tr = Tracer(clock=FakeClock())
    tr.begin(1, "decode")
    tr.end(1, "decode")
    tr.instant(1, "complete")
    path = tmp_path / "trace.json"
    tr.write(str(path))
    loaded = json.loads(path.read_text())
    summ = validate_trace(loaded)
    assert summ["tracks"] == 1 and summ["spans"] == 1


# ---------------------------------------------------------------------------
# (b) validator negative cases
# ---------------------------------------------------------------------------


def _track(events):
    return {"traceEvents": events}


def _ev(ph, name, ts, **kw):
    ev = {"ph": ph, "pid": 1, "tid": 1, "name": name, "ts": ts, "cat": "serve"}
    if ph == "i":
        ev["s"] = "t"
    ev.update(kw)
    return ev


def test_validator_rejects_non_monotone_timestamps():
    with pytest.raises(AssertionError, match="non-monotone"):
        validate_trace(_track([
            _ev("B", "a", 10.0), _ev("E", "a", 5.0),
            _ev("i", "complete", 6.0),
        ]))


def test_validator_rejects_mismatched_span_end():
    with pytest.raises(AssertionError, match="does not close"):
        validate_trace(_track([
            _ev("B", "a", 1.0), _ev("B", "b", 2.0), _ev("E", "a", 3.0),
        ]))


def test_validator_rejects_unclosed_span():
    with pytest.raises(AssertionError, match="unclosed"):
        validate_trace(_track([
            _ev("B", "a", 1.0), _ev("i", "complete", 2.0),
        ]))


def test_validator_rejects_end_without_begin():
    with pytest.raises(AssertionError, match="E without open span"):
        validate_trace(_track([_ev("E", "a", 1.0)]))


def test_validator_requires_terminal_event():
    with pytest.raises(AssertionError, match="vanished"):
        validate_trace(_track([_ev("B", "a", 1.0), _ev("E", "a", 2.0)]))
    # opt-out for partial traces
    summ = validate_trace(
        _track([_ev("B", "a", 1.0), _ev("E", "a", 2.0)]),
        require_terminal=False,
    )
    assert summ["spans"] == 1


def test_validator_rejects_malformed_events():
    with pytest.raises(AssertionError):
        validate_trace({"events": []})  # wrong wrapping
    with pytest.raises(AssertionError):
        validate_trace(_track([{"ph": "B", "pid": 1}]))  # no name/tid/ts


# ---------------------------------------------------------------------------
# (c) two-tier cascade over AsyncTransport: the tier-1 contract
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def stacks():
    v1, _ = unbox(ens.init_ensemble(SMALL, 3, jax.random.PRNGKey(0)))
    v2, _ = unbox(ens.init_ensemble(BIG, 1, jax.random.PRNGKey(1)))
    return v1, v2


def test_cascade_trace_end_to_end(stacks):
    v1, v2 = stacks
    server = CascadeServer(
        [
            CascadeTier(SMALL, v1, TierSpec("t1", "vote", 0.67, k=3, cost=1.0)),
            CascadeTier(BIG, v2, TierSpec("t2", "confidence", -1.0, k=1,
                                          cost=50.0)),
        ],
        placement=edge_cloud(delay=0.02, link="async"),
    )
    rng = np.random.default_rng(6)
    reqs = [
        Request(tokens=rng.integers(0, 64, 8).astype(np.int32),
                max_new_tokens=5)
        for _ in range(6)
    ]
    rids = {r.rid for r in reqs}
    ob = Observability(tracer=Tracer())
    with jax.transfer_guard_device_to_host("disallow"):
        done = server.serve_continuous(reqs, n_slots=2, max_seq=32, obs=ob)
    assert len(done) == len(reqs)

    trace = ob.tracer.export()
    summ = validate_trace(trace)  # schema + nesting + terminal per track
    evs = trace["traceEvents"]
    lifecycle = [e for e in evs if e["ph"] != "M"]
    # every admitted request has a track, and no extra tracks appear
    assert {e["tid"] for e in lifecycle} == rids
    assert summ["tracks"] == len(reqs)

    # every request that crossed the link shows the hop overlap split
    hop_ends = [e for e in lifecycle if e["name"] == "hop" and e["ph"] == "E"]
    n_deferred = ob.registry.value("cascade.tier0.deferred")
    assert len(hop_ends) == n_deferred > 0
    for e in hop_ends:
        args = e["args"]
        assert set(args) == {"link_s", "blocked_s", "hidden_s"}
        assert args["link_s"] == pytest.approx(
            args["blocked_s"] + args["hidden_s"], abs=1e-6,
        ) or args["blocked_s"] > args["link_s"]  # contention can over-block
    hop_begins = [e for e in lifecycle
                  if e["name"] == "hop" and e["ph"] == "B"]
    assert all(
        {"src", "dst", "n_bytes"} <= set(e["args"]) for e in hop_begins
    )

    # span vocabulary: each track walks the lifecycle in order
    for r in done:
        names = [e["name"] for e in lifecycle if e["tid"] == r.rid]
        spans = [e["name"] for e in lifecycle
                 if e["tid"] == r.rid and e["ph"] == "B"]
        assert names[0] == "queue_wait"
        assert "admit" in spans and "decode" in spans
        assert names[-1] == "complete"
        assert names.index("defer_vote") > names.index("decode")
        if r.tier == 1:  # deferred: a hop and a second tier's admission
            assert "hop" in spans
            assert spans.count("admit") == 2
            assert spans.count("queue_wait") == 2

    # deferral accounting matches the per-request outcomes
    assert ob.registry.value("cascade.tier1.answered") == sum(
        r.tier == 1 for r in done
    )


def test_null_tracer_emits_nothing(stacks):
    v1, _ = stacks
    server = CascadeServer(
        [CascadeTier(SMALL, v1, TierSpec("t1", "vote", 0.0, k=3, cost=1.0))]
    )
    ob = Observability()  # NullTracer
    done = server.serve_continuous(
        [Request(tokens=np.arange(1, 9, dtype=np.int32), max_new_tokens=3)],
        n_slots=1, max_seq=32, obs=ob,
    )
    assert len(done) == 1
    assert ob.tracer.export() == {"traceEvents": []}
