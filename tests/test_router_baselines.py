"""Learned-router baseline (FrugalGPT-style) vs ABC on the same pool."""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", ".."))

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks.common import PoolModel, sample_pool_logits, skill_for_accuracy  # noqa: E402
from repro.core import calibration, deferral  # noqa: E402
from repro.core.router_baselines import (  # noqa: E402
    logits_features,
    margin_rule,
    router_rule,
    train_router,
)


@pytest.fixture(scope="module")
def pool():
    ms = [PoolModel(f"m{j}", skill_for_accuracy(0.72), 1.0, seed=j) for j in range(3)]
    y, d, logits = sample_pool_logits(ms, 4000, seed=31)
    yt, _, logits_t = sample_pool_logits(ms, 1000, seed=32)
    return ms, y, logits, yt, logits_t


def test_router_learns_correctness(pool):
    ms, y, logits, yt, logits_t = pool
    L = jnp.asarray(logits[ms[0].name])
    correct = np.asarray(L.argmax(-1)) == y
    router = train_router(np.asarray(logits_features(L)), correct)
    Lt = jnp.asarray(logits_t[ms[0].name])
    s = np.asarray(router.score(logits_features(Lt)))
    corr_t = np.asarray(Lt.argmax(-1)) == yt
    # the router's score should rank correct answers above incorrect ones
    auc_proxy = s[corr_t].mean() - s[~corr_t].mean()
    assert auc_proxy > 0.1


def test_router_as_deferral_rule(pool):
    ms, y, logits, yt, logits_t = pool
    L = jnp.asarray(logits[ms[0].name])
    correct = np.asarray(L.argmax(-1)) == y
    router = train_router(np.asarray(logits_features(L)), correct)
    out = router_rule(router, jnp.asarray(logits_t[ms[0].name]), theta=0.8)
    sel = ~np.asarray(out.defer)
    if sel.any():
        acc_sel = (np.asarray(out.pred)[sel] == yt[sel]).mean()
        acc_all = (np.asarray(out.pred) == yt).mean()
        assert acc_sel >= acc_all  # selection concentrates on correct cases


def test_margin_rule_selects_confident(pool):
    ms, y, logits, *_ = pool
    out = margin_rule(jnp.asarray(logits[ms[0].name]), theta=0.5)
    sel = ~np.asarray(out.defer)
    acc_sel = (np.asarray(out.pred)[sel] == y[sel]).mean()
    acc_all = (np.asarray(out.pred) == y).mean()
    assert acc_sel > acc_all


def test_abc_vote_competitive_with_learned_router(pool):
    """The paper's headline: the training-free vote rule matches (or beats)
    a per-task trained router at equal selection rate."""
    ms, y, logits, yt, logits_t = pool
    # ABC vote over the 3-member ensemble
    Lte = jnp.asarray(np.stack([logits_t[m.name] for m in ms]))
    vote = deferral.vote_rule(Lte, theta=0.5)
    sel_v = ~np.asarray(vote.defer)
    acc_v = (np.asarray(vote.pred)[sel_v] == yt[sel_v]).mean()

    # learned router on member 0, threshold matched to the SAME selection rate
    L = jnp.asarray(logits[ms[0].name])
    router = train_router(
        np.asarray(logits_features(L)), np.asarray(L.argmax(-1)) == y
    )
    s = np.asarray(router.score(logits_features(jnp.asarray(logits_t[ms[0].name]))))
    theta_r = np.quantile(s, 1 - sel_v.mean())
    sel_r = s > theta_r
    pred_r = np.asarray(jnp.asarray(logits_t[ms[0].name]).argmax(-1))
    acc_r = (pred_r[sel_r] == yt[sel_r]).mean()
    assert acc_v >= acc_r - 0.03
