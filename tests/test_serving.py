"""Serving engine + cascade server integration tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import ensemble as ens
from repro.core.cascade import TierSpec
from repro.models import api
from repro.models.params import unbox
from repro.serve import CascadeServer, CascadeTier, Request, RequestQueue, ServingEngine

# every Observability these tests build gets a recording tracer; its
# stream is schema-validated at teardown (tests/conftest.py)
pytestmark = pytest.mark.usefixtures("trace_validation")

SMALL = ModelConfig(
    name="tiny-s", family="dense", n_layers=2, d_model=64, d_ff=128,
    vocab_size=64, n_heads=4, n_kv_heads=2, remat=False,
)
BIG = ModelConfig(
    name="tiny-b", family="dense", n_layers=3, d_model=96, d_ff=192,
    vocab_size=64, n_heads=4, n_kv_heads=4, remat=False,
)


@pytest.fixture(scope="module")
def stacks():
    v1, _ = unbox(ens.init_ensemble(SMALL, 3, jax.random.PRNGKey(0)))
    v2, _ = unbox(ens.init_ensemble(BIG, 1, jax.random.PRNGKey(1)))
    return v1, v2


def test_greedy_generate_matches_forward(stacks):
    v1, _ = stacks
    member = ens.take_member(v1, 0)
    eng = ServingEngine(SMALL, member, temperature=0.0)
    toks = np.random.default_rng(0).integers(0, 64, (2, 16)).astype(np.int32)
    gen = eng.generate(toks, max_new_tokens=3)
    # first generated token == argmax of forward at last prompt position
    full = api.forward_logits(member, {"tokens": jnp.asarray(toks)}, SMALL)
    np.testing.assert_array_equal(gen[:, 0], np.asarray(full[:, -1].argmax(-1)))
    # second generated token consistent with a full re-forward
    ext = np.concatenate([toks, gen[:, :1]], axis=1)
    full2 = api.forward_logits(member, {"tokens": jnp.asarray(ext)}, SMALL)
    np.testing.assert_array_equal(gen[:, 1], np.asarray(full2[:, -1].argmax(-1)))


def test_queue_padding_shapes():
    q = RequestQueue(max_batch=4)
    for n in (3, 5, 9):
        q.submit(Request(tokens=np.arange(n, dtype=np.int32)))
    batch = q.next_batch()
    toks, n = q.pad_batch(batch)
    assert n == 3
    assert toks.shape[0] in (4, 8) and toks.shape[1] == 16  # pow2 pads
    # prompts right-aligned
    assert toks[0, -3:].tolist() == [0, 1, 2]


def test_queue_serves_all(stacks):
    v1, _ = stacks
    eng = ServingEngine(SMALL, ens.take_member(v1, 0), max_batch=4)
    rng = np.random.default_rng(1)
    reqs = [
        Request(tokens=rng.integers(0, 64, rng.integers(4, 12)).astype(np.int32),
                max_new_tokens=int(rng.integers(1, 5)))
        for _ in range(7)
    ]
    for r in reqs:
        eng.queue.submit(r)
    done = eng.serve_pending()
    assert len(done) == 7
    for r in done:
        assert r.output is not None and len(r.output) == r.max_new_tokens


def test_cascade_untrained_always_defers(stacks):
    v1, v2 = stacks
    server = CascadeServer([
        CascadeTier(SMALL, v1, TierSpec("t1", "vote", 0.67, k=3, cost=1.0)),
        CascadeTier(BIG, v2, TierSpec("t2", "confidence", -1.0, k=1, cost=50.0)),
    ])
    toks = np.random.default_rng(2).integers(0, 64, (16, 12)).astype(np.int32)
    res = server.classify(toks)
    # independently-random untrained members essentially never agree
    assert res.tier_counts[1] >= 14
    assert (res.tier_of >= 0).all()


def test_cascade_identical_members_never_defer(stacks):
    v1, v2 = stacks
    one = ens.take_member(v1, 0)
    same = jax.tree.map(lambda x: jnp.stack([x, x, x]), one)
    server = CascadeServer([
        CascadeTier(SMALL, same, TierSpec("t1", "vote", 0.99, k=3, cost=1.0)),
        CascadeTier(BIG, v2, TierSpec("t2", "confidence", -1.0, k=1, cost=50.0)),
    ])
    toks = np.random.default_rng(3).integers(0, 64, (16, 12)).astype(np.int32)
    res = server.classify(toks)
    assert res.tier_counts[0] == 16  # unanimity -> all answered at tier 1
    assert res.cost < 50.0


def test_continuous_batching_matches_generate(stacks):
    """Slot-based continuous batching (per-slot positions, mid-stream
    admission) emits exactly what per-request greedy generation emits."""
    import copy

    v1, _ = stacks
    member = ens.take_member(v1, 0)
    eng = ServingEngine(SMALL, member, max_seq=64)
    rng = np.random.default_rng(11)
    reqs = [
        Request(tokens=rng.integers(0, 64, rng.integers(5, 12)).astype(np.int32),
                max_new_tokens=int(rng.integers(2, 5)))
        for _ in range(9)
    ]
    done = eng.serve_continuous([copy.deepcopy(r) for r in reqs], n_slots=4)
    assert len(done) == 9
    ref_eng = ServingEngine(SMALL, member)
    for r, d in zip(reqs, sorted(done, key=lambda x: x.rid)):
        ref = ref_eng.generate(r.tokens[None, :], r.max_new_tokens)[0]
        np.testing.assert_array_equal(ref, d.output)


def test_decode_attention_per_sequence_lengths():
    """decode_attention accepts a (B,) length vector (continuous batching)."""
    from repro.kernels import config as kcfg
    from repro.kernels.decode_attention import ops as dops, ref as dref

    ks = jax.random.split(jax.random.PRNGKey(12), 3)
    B, S, H, KVH, hd = 3, 256, 4, 2, 32
    q = jax.random.normal(ks[0], (B, 1, H, hd))
    k = jax.random.normal(ks[1], (B, S, KVH, hd))
    v = jax.random.normal(ks[2], (B, S, KVH, hd))
    lens = jnp.asarray([5, 100, 256], jnp.int32)
    ref = dref.decode_attention_ref(q, k, v, lens)
    xla = dops.decode_attention(q, k, v, lens)
    np.testing.assert_allclose(np.asarray(xla), np.asarray(ref), atol=2e-4, rtol=2e-4)
    kt, vt = k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3)
    with kcfg.use_impl("pallas_interpret"):
        pal = dops.decode_attention_bksd(q, kt, vt, lens)
    np.testing.assert_allclose(np.asarray(pal), np.asarray(ref), atol=2e-4, rtol=2e-4)


def test_mixed_family_cascade():
    """Tiers from different families (RWKV6 SSM tier -> dense tier) serve
    through the same cascade machinery (constant-state decode included)."""
    from repro.configs import get_config

    rw_cfg = get_config("rwkv6-7b").reduced()
    d_cfg = get_config("olmo-1b").reduced()
    v1, _ = unbox(ens.init_ensemble(rw_cfg, 2, jax.random.PRNGKey(5)))
    v2, _ = unbox(ens.init_ensemble(d_cfg, 1, jax.random.PRNGKey(6)))
    server = CascadeServer([
        CascadeTier(rw_cfg, v1, TierSpec("rwkv", "vote", 0.6, k=2, cost=1.0)),
        CascadeTier(d_cfg, v2, TierSpec("dense", "confidence", -1.0, k=1, cost=10.0)),
    ])
    vocab = min(rw_cfg.vocab_size, d_cfg.vocab_size)
    toks = np.random.default_rng(7).integers(0, vocab, (8, 16)).astype(np.int32)
    res = server.classify(toks)
    assert res.tier_counts.sum() == 8
    # rwkv engine generates too (O(1)-state decode path)
    eng = ServingEngine(rw_cfg, ens.take_member(v1, 0))
    gen = eng.generate(toks[:2], max_new_tokens=3)
    assert gen.shape == (2, 3)


def test_padded_batch_logits_match_solo(stacks):
    """Left-pad carve-out: classify on a right-aligned padded batch equals
    per-request solo logits — padded rows cannot attend across their prompt
    start and RoPE runs relative to it."""
    v1, _ = stacks
    member = ens.take_member(v1, 0)
    eng = ServingEngine(SMALL, member)
    rng = np.random.default_rng(21)
    lens = [3, 7, 11, 16]
    S = 16
    toks = np.zeros((4, S), np.int32)
    starts = np.zeros((4,), np.int32)
    prompts = []
    for i, L in enumerate(lens):
        p = rng.integers(0, 64, L).astype(np.int32)
        prompts.append(p)
        toks[i, S - L:] = p
        starts[i] = S - L
    logits = eng.classify(toks, starts=starts)
    solo = ServingEngine(SMALL, member)
    for i, p in enumerate(prompts):
        ref = solo.classify(p[None])
        np.testing.assert_allclose(logits[i], ref[0], atol=2e-4, rtol=2e-4)
    # without the carve-out, short-prompt rows see pad garbage: regression
    # guard that the masking is actually doing something
    unmasked = eng.classify(toks)
    assert not np.allclose(unmasked[0], logits[0], atol=2e-4)


def test_padded_batch_generation_matches_solo(stacks):
    """The carve-out rides decode too: greedy generation from a left-padded
    batch is token-for-token the solo generation."""
    v1, _ = stacks
    member = ens.take_member(v1, 0)
    eng = ServingEngine(SMALL, member)
    rng = np.random.default_rng(22)
    lens = [4, 9, 12]
    S = 16
    toks = np.zeros((3, S), np.int32)
    starts = np.zeros((3,), np.int32)
    prompts = []
    for i, L in enumerate(lens):
        p = rng.integers(0, 64, L).astype(np.int32)
        prompts.append(p)
        toks[i, S - L:] = p
        starts[i] = S - L
    gen = eng.generate(toks, 5, starts=starts)
    solo = ServingEngine(SMALL, member)
    for i, p in enumerate(prompts):
        ref = solo.generate(p[None], 5)
        np.testing.assert_array_equal(gen[i], ref[0])


def test_serve_pending_uses_carveout(stacks):
    """Queue-driven serving now pads with per-request starts: mixed-length
    batches produce exactly the solo generations."""
    v1, _ = stacks
    member = ens.take_member(v1, 0)
    eng = ServingEngine(SMALL, member, max_batch=4)
    rng = np.random.default_rng(23)
    reqs = [
        Request(tokens=rng.integers(0, 64, int(rng.integers(3, 12))).astype(np.int32),
                max_new_tokens=3)
        for _ in range(4)
    ]
    for r in reqs:
        eng.queue.submit(r)
    done = eng.serve_pending()
    solo = ServingEngine(SMALL, member)
    for r in done:
        ref = solo.generate(r.tokens[None], r.max_new_tokens)[0]
        np.testing.assert_array_equal(r.output, ref)


def test_starts_rejected_with_vision_prefix():
    """The carve-out indexes token columns; a prepended vision prefix
    would shift every masked column, so the combination is refused."""
    vlm = ModelConfig(
        name="tiny-vlm", family="vlm", n_layers=1, d_model=32, d_ff=64,
        vocab_size=32, n_heads=2, n_kv_heads=2, remat=False,
        n_vision_tokens=4, frontend_dim=8,
    )
    values, _ = unbox(api.init_params(vlm, jax.random.PRNGKey(0)))
    batch = {
        "tokens": jnp.zeros((2, 8), jnp.int32),
        "embeds": jnp.zeros((2, 4, 8), jnp.float32),
        "starts": jnp.asarray([0, 3], jnp.int32),
    }
    with pytest.raises(AssertionError, match="vision prefix"):
        api.prefill(values, batch, vlm)


def test_pad_batch_with_starts_shapes():
    q = RequestQueue(max_batch=4)
    for n in (3, 5, 9):
        q.submit(Request(tokens=np.arange(n, dtype=np.int32)))
    batch = q.next_batch()
    toks, starts, n = q.pad_batch_with_starts(batch)
    assert n == 3
    assert starts.tolist()[:3] == [16 - 3, 16 - 5, 16 - 9]
    # pow2-padded rows clone the last real request (and its start)
    assert (starts[3:] == starts[2]).all()


def test_cascade_generate_mode(stacks):
    v1, v2 = stacks
    server = CascadeServer([
        CascadeTier(SMALL, v1, TierSpec("t1", "vote", 0.67, k=3, cost=1.0)),
        CascadeTier(BIG, v2, TierSpec("t2", "confidence", -1.0, k=1, cost=50.0)),
    ])
    toks = np.random.default_rng(4).integers(0, 64, (8, 12)).astype(np.int32)
    res = server.generate(toks, max_new_tokens=4)
    assert res.tier_counts.sum() == 8


def test_serve_continuous_transfer_guard_single_engine(stacks):
    """The E=1 continuous-batching path under a device->host transfer
    guard: any implicit device->host read raises, so the only bytes that
    cross are the metered host_fetch of one sampled (n_slots,) token row
    per decode step — and the guarded run generates exactly what the
    unguarded run does."""
    import copy

    from repro.core import cascade

    v1, _ = stacks
    member = ens.take_member(v1, 0)
    eng = ServingEngine(SMALL, member, max_seq=64)
    rng = np.random.default_rng(31)
    reqs = [
        Request(
            tokens=rng.integers(0, 64, int(rng.integers(4, 10))).astype(np.int32),
            max_new_tokens=3,
        )
        for _ in range(5)
    ]
    ref = eng.serve_continuous([copy.deepcopy(r) for r in reqs], n_slots=2)
    cascade.reset_host_fetch_stats()
    with jax.transfer_guard_device_to_host("disallow"):
        done = eng.serve_continuous([copy.deepcopy(r) for r in reqs], n_slots=2)
    assert len(done) == 5
    stats = cascade.host_fetch_stats()
    # every fetch is one (n_slots,) int32 sampled-token row — nothing else
    assert stats["bytes"] == stats["calls"] * 2 * 4, stats
    for a, b in zip(
        sorted(ref, key=lambda r: r.rid), sorted(done, key=lambda r: r.rid)
    ):
        np.testing.assert_array_equal(a.output, b.output)
