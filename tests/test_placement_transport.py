"""Distributed tier placement + transport: the defer path never gathers on
host, only deferred examples' bytes cross a placement boundary, and pod
placement puts tiers on disjoint device sets."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import cascade, ensemble as ens
from repro.core.cascade import TierSpec, bucket_chunks
from repro.models.params import unbox
from repro.serve import (
    CascadeServer,
    CascadeTier,
    Request,
    SimulatedLinkTransport,
    edge_cloud,
    single_host,
)

REPO = os.path.join(os.path.dirname(__file__), "..")

SMALL = ModelConfig(
    name="tiny-s", family="dense", n_layers=2, d_model=64, d_ff=128,
    vocab_size=64, n_heads=4, n_kv_heads=2, remat=False,
)
BIG = ModelConfig(
    name="tiny-b", family="dense", n_layers=3, d_model=96, d_ff=192,
    vocab_size=64, n_heads=4, n_kv_heads=4, remat=False,
)


@pytest.fixture(scope="module")
def stacks():
    v1, _ = unbox(ens.init_ensemble(SMALL, 3, jax.random.PRNGKey(0)))
    v2, _ = unbox(ens.init_ensemble(BIG, 1, jax.random.PRNGKey(1)))
    return v1, v2


def _two_tier(stacks, placement=None):
    v1, v2 = stacks
    return CascadeServer(
        [
            CascadeTier(SMALL, v1, TierSpec("t1", "vote", 0.67, k=3, cost=1.0)),
            CascadeTier(BIG, v2, TierSpec("t2", "confidence", -1.0, k=1, cost=50.0)),
        ],
        placement=placement,
    )


# ---------------------------------------------------------------------------
# no host gathers on the defer path
# ---------------------------------------------------------------------------


def test_routed_defer_path_no_host_gather(stacks):
    """The routed cascade under a device->host transfer guard: any IMPLICIT
    device->host transfer (a host gather/re-pad of the payload) raises.
    Intentional reads all go through cascade._fetch, whose byte meter must
    see only per-tier count scalars plus the final (B,) results."""
    server = _two_tier(stacks, single_host(2))
    B, S = 16, 12
    toks = np.random.default_rng(2).integers(0, 64, (B, S)).astype(np.int32)
    cascade.reset_host_fetch_stats()
    with jax.transfer_guard_device_to_host("disallow"):
        res = server.classify(toks)
    assert res.tier_counts.sum() == B
    stats = cascade.host_fetch_stats()
    # final results: pred+tier_of (i32) + scores (f32) + 2 tier counts;
    # per-transition: one count scalar.  Everything else stayed on device.
    result_bytes = B * 4 * 3 + 2 * 4
    scalar_bytes = 4
    assert stats["bytes"] <= result_bytes + scalar_bytes, stats
    # the payload (B x S tokens) dwarfs that bound — none of it was fetched
    assert stats["bytes"] < B * S * 4


def test_routed_matches_legacy_host_semantics(stacks):
    """Device routing is a pure implementation change: results equal the
    dense reference executor's on the shared semantics."""
    from repro.core.cascade import cascade_apply_dense

    v1, v2 = stacks
    server = _two_tier(stacks)
    toks = np.random.default_rng(3).integers(0, 64, (16, 12)).astype(np.int32)
    res = server.classify(toks)

    fns = [
        lambda b, t=server.tiers[0]: t._last_logits(t.values, {"tokens": b["tokens"]}),
        lambda b, t=server.tiers[1]: t._last_logits(t.values, {"tokens": b["tokens"]}),
    ]
    pred, tier_of, _ = cascade_apply_dense(
        fns, [t.spec for t in server.tiers], {"tokens": jnp.asarray(toks)}
    )
    np.testing.assert_array_equal(res.pred, np.asarray(pred))
    np.testing.assert_array_equal(res.tier_of, np.asarray(tier_of))


# ---------------------------------------------------------------------------
# transport: only deferred examples' bytes cross
# ---------------------------------------------------------------------------


def test_edge_cloud_transport_meters_only_deferrals(stacks):
    from repro.core import deferral

    v1, v2 = stacks
    B, S = 16, 12
    toks = np.random.default_rng(4).integers(0, 64, (B, S)).astype(np.int32)
    # median-confidence threshold -> about half the batch defers, so the
    # metered traffic must be strictly the deferred slice, not the batch
    t1_probe = CascadeTier(SMALL, v1, TierSpec("t1", "confidence", 0.0, k=3, cost=1.0))
    logits = t1_probe._last_logits(t1_probe.values, {"tokens": jnp.asarray(toks)})
    theta = float(np.median(np.asarray(deferral.confidence_rule(logits, 0.0).score)))

    placement = edge_cloud(delay="medium")
    server = CascadeServer(
        [
            CascadeTier(SMALL, v1, TierSpec("t1", "confidence", theta, k=3, cost=1.0)),
            CascadeTier(BIG, v2, TierSpec("t2", "confidence", -1.0, k=1, cost=50.0)),
        ],
        placement=placement,
    )
    res = server.classify(toks)
    link = placement.link(0)
    n_def = int(res.tier_counts[1])
    assert 0 < n_def < B
    assert link.total_examples == n_def
    # payload = deferred tokens rows + the i32 routing index, padded to the
    # pow2 bucket cover — never the full batch
    n_pad = min(sum(bucket_chunks(n_def, server.pad_to)), B)
    assert link.total_bytes == n_pad * (S * 4 + 4)
    assert link.total_bytes < B * S * 4
    assert link.total_latency == pytest.approx(0.1)  # one metered hop


def test_no_deferrals_no_traffic(stacks):
    """Unanimous tier 1 -> the link carries zero bytes (the 14x claim's
    limiting case)."""
    v1, v2 = stacks
    one = ens.take_member(v1, 0)
    same = jax.tree.map(lambda x: jnp.stack([x, x, x]), one)
    placement = edge_cloud(delay="large")
    server = CascadeServer(
        [
            CascadeTier(SMALL, same, TierSpec("t1", "vote", 0.99, k=3, cost=1.0)),
            CascadeTier(BIG, v2, TierSpec("t2", "confidence", -1.0, k=1, cost=50.0)),
        ],
        placement=placement,
    )
    toks = np.random.default_rng(5).integers(0, 64, (16, 12)).astype(np.int32)
    res = server.classify(toks)
    assert res.tier_counts[0] == 16
    assert placement.link(0).total_bytes == 0
    assert placement.link(0).total_latency == 0.0


def test_simulated_link_latency_and_bandwidth():
    tr = SimulatedLinkTransport(delay=0.01, bandwidth=1e6)
    payload = {"x": jnp.ones((4, 250), jnp.float32)}  # 4000 B
    out = tr.send("edge0", "cloud0", payload, n_examples=4)
    np.testing.assert_array_equal(np.asarray(out["x"]), np.asarray(payload["x"]))
    assert tr.total_bytes == 4000
    assert tr.total_latency == pytest.approx(0.01 + 4000 / 1e6)
    assert tr.hops[0].src == "edge0" and tr.hops[0].dst == "cloud0"


def test_serve_continuous_requeue_crosses_link(stacks):
    """Continuous-batching deferral re-queue is a metered transport hop:
    exactly the deferred requests' prompts cross edge->cloud."""
    placement = edge_cloud(delay="small")
    server = _two_tier(stacks, placement)
    rng = np.random.default_rng(6)
    reqs = [
        Request(tokens=rng.integers(0, 64, 8).astype(np.int32), max_new_tokens=3)
        for _ in range(5)
    ]
    done = server.serve_continuous(reqs, n_slots=2, max_seq=32)
    assert len(done) == 5
    n_def = sum(1 for r in done if r.tier == 1)
    link = placement.link(0)
    assert link.total_examples == n_def
    assert link.total_bytes == n_def * 8 * 4  # each deferred prompt, once


# ---------------------------------------------------------------------------
# pod placement: tiers on disjoint device sets (subprocess forces 8 devices)
# ---------------------------------------------------------------------------

_POD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np
import jax.numpy as jnp
from repro.configs.base import ModelConfig
from repro.core import ensemble as ens
from repro.core.cascade import TierSpec
from repro.models.params import unbox
from repro.serve import CascadeServer, CascadeTier
from repro.serve.placement import hosts_disjoint, pod_placement

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
pl = pod_placement(mesh, 2)
assert [h.name for h in pl.hosts] == ["pod0", "pod1"]
assert hosts_disjoint(pl), "pod slices must own disjoint devices"
assert len(pl.hosts[0].devices() & pl.hosts[1].devices()) == 0
from repro.serve.transport import ShardedDevicePutTransport
assert isinstance(pl.link(0), ShardedDevicePutTransport)  # sharded default

SMALL = ModelConfig(name="tiny-s", family="dense", n_layers=2, d_model=64,
    d_ff=128, vocab_size=64, n_heads=4, n_kv_heads=2, remat=False)
BIG = ModelConfig(name="tiny-b", family="dense", n_layers=2, d_model=64,
    d_ff=128, vocab_size=64, n_heads=4, n_kv_heads=4, remat=False)
v1, _ = unbox(ens.init_ensemble(SMALL, 2, jax.random.PRNGKey(0)))
v2, _ = unbox(ens.init_ensemble(BIG, 1, jax.random.PRNGKey(1)))

toks = np.random.default_rng(2).integers(0, 64, (16, 8)).astype(np.int32)
# median-confidence threshold -> partial deferral, so 'only the deferred
# slice crossed' is a strict statement
from repro.core import deferral
probe = CascadeTier(SMALL, v1, TierSpec("t1", "confidence", 0.0, k=2, cost=1.0))
logits = probe._last_logits(probe.values, {"tokens": jnp.asarray(toks)})
theta = float(np.median(np.asarray(deferral.confidence_rule(logits, 0.0).score)))

def serve(placement):
    server = CascadeServer([
        CascadeTier(SMALL, v1, TierSpec("t1", "confidence", theta, k=2, cost=1.0)),
        CascadeTier(BIG, v2, TierSpec("t2", "confidence", -1.0, k=1, cost=50.0)),
    ], placement=placement)
    return server, server.classify(toks)

server, res = serve(pl)

# tier weights actually live on their pod slice
d0 = {d for l in jax.tree.leaves(server.tiers[0].values) for d in l.devices()}
d1 = {d for l in jax.tree.leaves(server.tiers[1].values) for d in l.devices()}
assert d0 <= pl.hosts[0].devices(), (d0, pl.hosts[0].devices())
assert d1 <= pl.hosts[1].devices(), (d1, pl.hosts[1].devices())

res_counts = res.tier_counts
assert res_counts.sum() == 16
link = pl.link(0)
n_def = int(res_counts[1])
assert 0 < n_def < 16, n_def
assert link.total_examples == n_def, (link.total_examples, n_def)
assert 0 < link.total_bytes < 16 * (8 * 4 + 4)  # only the deferred slice

# -- sharded hand-off parity vs the replicated baseline --------------------
# the delivered payload's example axis must really shard over the dst
# slice ('pod' x 'data' = 2 shards here), and results/metered traffic must
# be identical to pod-wide replication
h = link.send_async("pod0", "pod1",
                    {"x": jnp.ones((8, 4), jnp.float32)}, n_examples=8)
delivered = h.result()["x"]
shards = {s.data.shape for s in delivered.addressable_shards}
assert shards == {(4, 4)}, shards  # 8 rows -> 2 shards of 4, not replicas
assert link.shard_counts({"x": jnp.ones((8, 4), jnp.float32)}) == [2]
link.hops.pop()  # probe hop: keep the serving meters comparable below

pl_rep = pod_placement(mesh, 2, shard_examples=False)
_, res_rep = serve(pl_rep)
np.testing.assert_array_equal(res.pred, res_rep.pred)
np.testing.assert_array_equal(res.tier_of, res_rep.tier_of)
link_rep = pl_rep.link(0)
assert link_rep.total_bytes == link.total_bytes, (
    link_rep.total_bytes, link.total_bytes)
assert link_rep.total_examples == link.total_examples

# -- chunked re-feeds keep the landed sharding (no replicated intermediate) -
# a 0.75-quantile threshold defers ~12 of 16 rows -> the tier-2 cover needs
# TWO pow2 chunks (8 + 4), so every chunk goes through the slice/pad path
# that cascade_apply_routed must re-place onto the transport's example
# sharding; each fed chunk must arrive 2-way example-sharded, never as
# pod-wide replicas
score = np.asarray(deferral.confidence_rule(logits, 0.0).score)
theta_hi = float(np.quantile(score, 0.75))
server3 = CascadeServer([
    CascadeTier(SMALL, v1, TierSpec("t1", "confidence", theta_hi, k=2, cost=1.0)),
    CascadeTier(BIG, v2, TierSpec("t2", "confidence", -1.0, k=1, cost=50.0)),
], placement=pod_placement(mesh, 2))
shard_log = []
t2 = server3.tiers[1]
orig_logits_fn = t2._last_logits
def spy(values, batch):
    fed = batch["tokens"]
    shard_log.append((int(fed.shape[0]),
                      {s.data.shape for s in fed.addressable_shards}))
    return orig_logits_fn(values, batch)
t2._last_logits = spy
res3 = server3.classify(toks)
n_def3 = int(res3.tier_counts[1])
assert n_def3 > 8, n_def3  # must need a multi-bucket (8 + 4) cover
assert len(shard_log) >= 2, shard_log
for rows, shapes in shard_log:
    assert len(shapes) == 1, (rows, shapes)
    (shape,) = shapes
    assert shape[0] * 2 == rows, (
        "tier-2 chunk fed replicated (or mis-sharded): rows=%d shards=%r"
        % (rows, shapes))
print("POD_PLACEMENT_OK", n_def, link.total_bytes)
"""


def test_pod_placement_disjoint_hosts_subprocess():
    r = subprocess.run(
        [sys.executable, "-c", _POD_SCRIPT],
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")},
        capture_output=True, text=True, timeout=560,
    )
    assert r.returncode == 0, r.stdout + r.stderr
    assert "POD_PLACEMENT_OK" in r.stdout
