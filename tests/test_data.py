"""Data pipeline + synthetic task tests."""
import numpy as np

from repro.data.pipeline import TokenDataset, batches, make_lm_batch
from repro.data.synthetic import MixtureTask, sequence_task


def test_mixture_task_structure():
    task = MixtureTask(vocab=256, n_classes=16, seq_len=32, easy_frac=0.5, seed=0)
    toks, labels, easy = task.sample(2000, seed=1)
    assert toks.shape == (2000, 32) and labels.shape == (2000,)
    assert 0.45 < easy.mean() < 0.55
    # easy examples carry the marker at the read position
    markers = task.markers[labels[easy]]
    assert (toks[easy, -1] == markers).all()
    # hard examples never contain marker ids (exclusive ranges)
    assert (toks[~easy] >= 2 * task.n_classes).all()
    # labels are roughly balanced
    counts = np.bincount(labels, minlength=16)
    assert counts.min() > 0


def test_mixture_task_deterministic():
    t = MixtureTask(seed=3)
    a = t.sample(100, seed=5)
    b = t.sample(100, seed=5)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)


def test_sequence_task_markov_structure():
    rows = sequence_task(64, 128, vocab=512, seed=0)
    assert rows.shape == (64, 129)
    assert rows.min() >= 0 and rows.max() < 512
    # order-2 sparse transitions: each context admits <= 8 next tokens
    ctx = (rows[:, :-2].astype(np.int64) * 31 + rows[:, 1:-1]) % 4096
    nxt = rows[:, 2:]
    support = {}
    for c, n in zip(ctx.ravel(), nxt.ravel()):
        support.setdefault(int(c), set()).add(int(n))
    sizes = np.array([len(s) for s in support.values()])
    assert sizes.max() <= 8


def test_lm_batching_shards_hosts():
    rows = np.arange(32 * 17).reshape(32, 17).astype(np.int32)
    ds = TokenDataset(rows)
    it0 = batches(ds, 8, seed=0, epochs=1, host_id=0, host_count=2)
    it1 = batches(ds, 8, seed=0, epochs=1, host_id=1, host_count=2)
    seen0 = np.concatenate([b["tokens"][:, 0] for b in it0])
    seen1 = np.concatenate([b["tokens"][:, 0] for b in it1])
    # hosts see disjoint rows
    assert len(np.intersect1d(seen0, seen1)) == 0


def test_make_lm_batch_shift():
    rows = np.arange(10).reshape(1, 10).astype(np.int32)
    b = make_lm_batch(rows)
    np.testing.assert_array_equal(b["tokens"][0], np.arange(9))
    np.testing.assert_array_equal(b["targets"][0], np.arange(1, 10))
