"""Hypothesis property tests on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)"
)
from hypothesis import given, settings, strategies as st

from repro.core import calibration, cost_model, deferral, theory
from repro.kernels.agreement import ops as agree_ops
from repro.sharding.logical import logical_to_pspec

SETTINGS = dict(max_examples=25, deadline=None)


# ---------------------------------------------------------------------------
# Prop 4.1.1 holds for ANY deferral rule / predictions (it is an identity
# plus an inequality on finite samples)
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    n=st.integers(20, 200),
    seed=st.integers(0, 10_000),
    p_defer=st.floats(0.0, 1.0),
)
def test_prop411_any_rule(n, seed, p_defer):
    rng = np.random.default_rng(seed)
    y = rng.integers(0, 5, n)
    small = rng.integers(0, 5, n)
    large = rng.integers(0, 5, n)
    defer = rng.random(n) < p_defer
    eps = theory.safe_rule_epsilon(small, defer, y)
    casc = np.where(defer, large, small)
    assert theory.risk(casc, y) <= theory.risk(large, y) + eps + 1e-12
    ex = theory.excess_risk(small, large, defer, y)
    exi = theory.excess_risk_identity(small, large, defer, y)
    assert np.isclose(ex, exi, atol=1e-9)


# ---------------------------------------------------------------------------
# agreement reduce invariances
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    e=st.integers(2, 5),
    b=st.integers(1, 16),
    v=st.integers(2, 64),
    seed=st.integers(0, 1000),
)
def test_agreement_member_permutation_invariant(e, b, v, seed):
    logits = jax.random.normal(jax.random.PRNGKey(seed), (e, b, v))
    out1 = agree_ops.agreement(logits)
    perm = np.random.default_rng(seed).permutation(e)
    out2 = agree_ops.agreement(logits[perm])
    np.testing.assert_allclose(
        np.asarray(out1["vote_frac"]), np.asarray(out2["vote_frac"])
    )
    np.testing.assert_allclose(
        np.asarray(out1["mean_score"]), np.asarray(out2["mean_score"]), atol=1e-6
    )


@settings(**SETTINGS)
@given(e=st.integers(1, 6), b=st.integers(1, 8), seed=st.integers(0, 1000))
def test_vote_frac_bounds_and_unanimity(e, b, seed):
    logits = jax.random.normal(jax.random.PRNGKey(seed), (e, b, 32))
    out = agree_ops.agreement(logits)
    vf = np.asarray(out["vote_frac"])
    assert (vf >= 1.0 / e - 1e-6).all() and (vf <= 1.0 + 1e-6).all()
    same = agree_ops.agreement(jnp.tile(logits[:1], (e, 1, 1)))
    assert np.allclose(np.asarray(same["vote_frac"]), 1.0)


@settings(**SETTINGS)
@given(b=st.integers(1, 8), v=st.integers(2, 64), seed=st.integers(0, 1000))
def test_mean_score_is_probability(b, v, seed):
    logits = jax.random.normal(jax.random.PRNGKey(seed), (3, b, v)) * 3
    out = agree_ops.agreement(logits)
    ms = np.asarray(out["mean_score"])
    assert (ms > 0).all() and (ms <= 1.0 + 1e-6).all()


# ---------------------------------------------------------------------------
# calibration: the returned threshold is always feasible; selection rate is
# monotone in epsilon
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    n=st.integers(30, 300),
    seed=st.integers(0, 10_000),
    eps=st.floats(0.0, 0.3),
)
def test_calibration_always_feasible(n, seed, eps):
    rng = np.random.default_rng(seed)
    scores = rng.random(n)
    correct = rng.random(n) < scores  # higher score -> more likely correct
    theta, info = calibration.estimate_threshold(scores, correct, epsilon=eps)
    assert info["failure_rate"] <= eps + 1e-12


@settings(**SETTINGS)
@given(seed=st.integers(0, 10_000))
def test_calibration_monotone(seed):
    rng = np.random.default_rng(seed)
    scores = rng.random(200)
    correct = rng.random(200) < scores
    prev = -1.0
    for eps in (0.0, 0.05, 0.1, 0.2, 0.4):
        _, info = calibration.estimate_threshold(scores, correct, epsilon=eps)
        assert info["selection_rate"] >= prev - 1e-12
        prev = info["selection_rate"]


# ---------------------------------------------------------------------------
# cost model monotonicity (Eq. 1 / Fig. 3)
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    k=st.integers(1, 8),
    c0=st.floats(0.01, 10.0),
    r1=st.floats(0.0, 1.0),
    r2=st.floats(0.0, 1.0),
)
def test_ensemble_cost_monotone_in_rho(k, c0, r1, r2):
    lo, hi = min(r1, r2), max(r1, r2)
    assert cost_model.ensemble_cost(c0, k, hi) <= cost_model.ensemble_cost(c0, k, lo) + 1e-9
    assert np.isclose(cost_model.ensemble_cost(c0, 1, r1), c0)


@settings(**SETTINGS)
@given(
    g1=st.floats(0.001, 1.0),
    g2=st.floats(0.001, 1.0),
    k=st.integers(1, 5),
    rho=st.floats(0.0, 1.0),
    sel=st.floats(0.0, 1.0),
)
def test_savings_decrease_with_gamma(g1, g2, k, rho, sel):
    lo, hi = min(g1, g2), max(g1, g2)
    assert cost_model.fraction_cost_saved(lo, k, rho, sel) >= cost_model.fraction_cost_saved(hi, k, rho, sel) - 1e-9


# ---------------------------------------------------------------------------
# cascade: the fully-jitted masked form and the host-routed compacting form
# are semantically identical for ANY tier count / thresholds / rules, and
# routed cost accounting matches evaluated-counts × per-tier cost
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    n_tiers=st.integers(2, 4),
    b=st.integers(4, 40),
    v=st.integers(2, 12),
    seed=st.integers(0, 10_000),
    theta=st.floats(0.2, 0.9),
)
def test_cascade_dense_equals_routed_any_config(n_tiers, b, v, seed, theta):
    from repro.core.cascade import TierSpec, cascade_apply_dense, cascade_apply_routed

    rng = np.random.default_rng(seed)
    tier_logits = [
        jnp.asarray(rng.normal(0, 2, (rng.integers(1, 4), b, v)).astype(np.float32))
        for _ in range(n_tiers)
    ]
    fns = [lambda batch, L=L: L[:, batch["idx"]] for L in tier_logits]
    specs = []
    for i, L in enumerate(tier_logits):
        last = i == n_tiers - 1
        rule = "vote" if L.shape[0] > 1 else "confidence"
        specs.append(
            TierSpec(f"t{i}", rule, -1.0 if last else theta, k=L.shape[0],
                     cost=float(10 ** i))
        )
    idx = np.arange(b)
    pred_d, tier_d, _ = cascade_apply_dense(fns, specs, {"idx": idx})
    res = cascade_apply_routed(fns, specs, {"idx": idx}, pad_to=4)
    np.testing.assert_array_equal(np.asarray(pred_d), res.pred)
    np.testing.assert_array_equal(np.asarray(tier_d), res.tier_of)
    assert (res.tier_of >= 0).all()
    assert res.tier_counts.sum() == b
    assert np.isclose(
        res.cost, sum(s.cost * e for s, e in zip(specs, res.evaluated))
    )


# ---------------------------------------------------------------------------
# sharding rules: pspecs never violate divisibility and never reuse a mesh
# axis twice
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    d0=st.integers(1, 64),
    d1=st.integers(1, 64),
    seed=st.integers(0, 100),
)
def test_pspec_divisibility(d0, d1, seed):
    import jax
    from jax.sharding import Mesh

    devs = np.array(jax.devices() * 16)[:16].reshape(4, 4)
    mesh = Mesh(devs, ("data", "model"))
    rules = {"a": ("data",), "b": ("model",)}
    spec = logical_to_pspec(("a", "b"), rules, shape=(d0 * 4, d1), mesh=mesh)
    # axis kept only when it divides
    if spec[1] == "model":
        assert d1 % 4 == 0
    used = [s for s in spec if s is not None]
    flat = []
    for s in used:
        flat.extend(s if isinstance(s, tuple) else [s])
    assert len(flat) == len(set(flat))
