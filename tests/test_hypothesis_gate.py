"""Guard against the property layer silently skipping forever.

tests/test_property_paging.py import-skips when hypothesis is absent —
correct for minimal local environments (the repo vendors nothing), but a
skip in CI would mean the property layer never actually runs anywhere.
The CI lane that installs requirements-dev.txt (where hypothesis is
pinned) sets ``REPRO_REQUIRE_HYPOTHESIS=1``; under that flag a missing
hypothesis is a hard FAILURE, not a skip.  Everywhere else this test
passes vacuously and documents the contract.
"""
import importlib.util
import os


def test_property_layer_runs_where_required():
    if not os.environ.get("REPRO_REQUIRE_HYPOTHESIS"):
        return  # local/minimal env: property suite may import-skip
    assert importlib.util.find_spec("hypothesis") is not None, (
        "REPRO_REQUIRE_HYPOTHESIS is set but hypothesis is not importable: "
        "this lane promised to run the property suite "
        "(tests/test_property_paging.py) and would silently skip it. "
        "Install requirements-dev.txt in this lane."
    )
