"""Open-loop serving tests (serve/cascade_server.py serve_open_loop +
serve/controller.py): virtual-time replay determinism, closed-loop
equivalence at t=0 arrivals, controller-vs-static goodput on a bursty
trace, shed marking (zero silent drops), transfer-guard cleanliness, and
the slot-limit actuation point."""
import copy

import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import ensemble as ens
from repro.core.cascade import TierSpec
from repro.models.params import unbox
from repro.obs import Observability
from repro.serve import (
    ArrivalSpec,
    CascadeServer,
    CascadeTier,
    ControllerConfig,
    GreedyController,
    Request,
    ServeConfig,
    ServingEngine,
    VirtualClock,
    Workload,
    bursty,
    poisson,
)

# every Observability these tests build gets a recording tracer; its
# stream is schema-validated at teardown (tests/conftest.py)
pytestmark = pytest.mark.usefixtures("trace_validation")

SMALL = ModelConfig(
    name="tiny-s", family="dense", n_layers=2, d_model=64, d_ff=128,
    vocab_size=64, n_heads=4, n_kv_heads=2, remat=False,
)
BIG = ModelConfig(
    name="tiny-b", family="dense", n_layers=3, d_model=96, d_ff=192,
    vocab_size=64, n_heads=4, n_kv_heads=4, remat=False,
)


@pytest.fixture(scope="module")
def stacks():
    v1, _ = unbox(ens.init_ensemble(SMALL, 3, jax.random.PRNGKey(0)))
    v2, _ = unbox(ens.init_ensemble(BIG, 1, jax.random.PRNGKey(1)))
    return v1, v2


def _server(stacks):
    v1, v2 = stacks
    return CascadeServer([
        CascadeTier(SMALL, v1, TierSpec("t1", "vote", 0.67, k=3, cost=1.0)),
        CascadeTier(BIG, v2, TierSpec("t2", "confidence", -1.0, k=1,
                                      cost=50.0)),
    ])


CFG = ServeConfig(n_slots=4, max_seq=64)


def _key(report):
    return (
        report.goodput, report.p50_s, report.p99_s, report.makespan_s,
        [(r.tier, r.output.tolist()) for r in report.completed],
        [r.rid is not None and r.shed for r in report.shed],
    )


def test_open_loop_replay_is_deterministic(stacks):
    """Identical (workload, config) inputs replay bit-for-bit — virtual
    time removes every wall-clock dependence from the report."""
    wl = bursty(2.0, 150.0, 30, seed=5, prompt_len=(4, 12),
                max_new_tokens=(2, 5))
    a = _server(stacks).serve_open_loop(wl, CFG, slo_s=0.5, step_time_s=0.01)
    b = _server(stacks).serve_open_loop(wl, CFG, slo_s=0.5, step_time_s=0.01)
    assert _key(a) == _key(b)
    assert a.offered == 30 and not a.shed


def test_open_loop_at_t0_matches_closed_loop(stacks):
    """A trace whose arrivals are all at t=0 degenerates to the closed
    loop: serve_open_loop admits the same list in the same order, so the
    generations, answering tiers, and completion order are identical to
    serve_continuous."""
    rng = np.random.default_rng(9)
    specs = [
        ArrivalSpec(
            t_s=0.0,
            tokens=rng.integers(0, 64, int(rng.integers(4, 12))).astype(np.int32),
            max_new_tokens=int(rng.integers(2, 5)),
        )
        for _ in range(8)
    ]
    closed = _server(stacks).serve_continuous(
        [s.materialize() for s in specs], CFG
    )
    report = _server(stacks).serve_open_loop(
        Workload(specs), CFG, slo_s=10.0, step_time_s=0.01
    )
    assert report.goodput == 1.0 and len(report.completed) == 8
    for rc, ro in zip(closed, report.completed):
        assert rc.tier == ro.tier
        np.testing.assert_array_equal(rc.output, ro.output)


def test_controller_beats_static_on_bursty_trace(stacks):
    """The acceptance bar: identical bursty trace, identical HBM budget —
    the controller-on run reports strictly higher goodput than the static
    config, with zero silently-dropped requests on both sides."""
    wl = bursty(2.0, 300.0, 80, seed=7, mean_on_s=0.5, mean_off_s=0.5,
                prompt_len=(4, 12), max_new_tokens=(2, 5))
    static = _server(stacks).serve_open_loop(
        wl, CFG, slo_s=0.3, step_time_s=0.01
    )
    ctl = GreedyController(ControllerConfig(interval_s=0.1))
    adaptive = _server(stacks).serve_open_loop(
        wl, CFG, slo_s=0.3, step_time_s=0.01, controller=ctl
    )
    assert static.offered == adaptive.offered == 80
    assert len(static.completed) + len(static.shed) == 80
    assert len(adaptive.completed) + len(adaptive.shed) == 80
    assert adaptive.goodput > static.goodput, (adaptive, static)
    # the controller actually acted, and its actions carry the audit trail
    assert ctl.actions and any(
        a["action"] == "theta_offset" for a in ctl.actions
    )
    assert adaptive.controller_actions == ctl.actions


def test_shed_requests_come_back_marked(stacks):
    """Shed requests are returned to the caller with ``shed=True`` and no
    output — never silently dropped — and completed ones are unmarked."""
    wl = bursty(2.0, 400.0, 60, seed=3, mean_on_s=0.8, mean_off_s=0.3,
                prompt_len=(4, 12), max_new_tokens=(2, 5))
    ctl = GreedyController(
        ControllerConfig(interval_s=0.05, shed_margin=1.0)
    )
    report = _server(stacks).serve_open_loop(
        wl, CFG, slo_s=0.2, step_time_s=0.01, controller=ctl
    )
    assert report.shed, "trace tuned to force shedding"
    assert all(r.shed and r.output is None for r in report.shed)
    assert all(not r.shed and r.output is not None for r in report.completed)
    assert report.offered == len(report.completed) + len(report.shed)
    # the registry agrees with the report
    reg_names = ctl.run.ob.registry
    assert reg_names.value("serve.open_loop.shed") == len(report.shed)
    assert reg_names.value("serve.open_loop.offered") == report.offered


def test_open_loop_transfer_guard_clean(stacks):
    """The whole open-loop path — workload admission, virtual clock,
    controller reads/actuations, vote routing — under
    ``jax.transfer_guard_device_to_host("disallow")``: every device->host
    byte goes through the metered host_fetch."""
    wl = poisson(80.0, 12, seed=4, prompt_len=(4, 10), max_new_tokens=(2, 4))
    ctl = GreedyController(ControllerConfig(interval_s=0.05))
    with jax.transfer_guard_device_to_host("disallow"):
        report = _server(stacks).serve_open_loop(
            wl, CFG, slo_s=1.0, step_time_s=0.01, controller=ctl
        )
    assert len(report.completed) + len(report.shed) == 12


def test_open_loop_latency_counts_queue_wait(stacks):
    """Two arrivals at t=0 with one slot: the second request's latency
    includes its wait for the first one's slot, so its recorded latency
    must exceed the first's."""
    specs = [
        ArrivalSpec(t_s=0.0, tokens=np.arange(4, dtype=np.int32) + 1,
                    max_new_tokens=4),
        ArrivalSpec(t_s=0.0, tokens=np.arange(4, dtype=np.int32) + 7,
                    max_new_tokens=4),
    ]
    ob = Observability(clock=VirtualClock())
    cfg = ServeConfig(n_slots=1, max_seq=64, obs=ob)
    report = _server(stacks).serve_open_loop(
        Workload(specs), cfg, slo_s=10.0, step_time_s=0.01
    )
    h = ob.registry.get("serve.request_latency_s")
    assert h.count == 2
    assert h._max > h._min > 0


def test_open_loop_requires_advanceable_clock(stacks):
    wl = poisson(10.0, 2, seed=0)
    cfg = ServeConfig(n_slots=2, max_seq=64, obs=Observability())
    with pytest.raises(AssertionError, match="advanceable"):
        _server(stacks).serve_open_loop(wl, cfg, slo_s=1.0)


def test_slot_limit_caps_admission(stacks):
    """``SlotStream.set_slot_limit`` is admission-side only: with the
    limit at 1, a stream with 4 slots never holds more than one occupant,
    and raising the limit re-opens the idle slots."""
    v1, _ = stacks
    eng = ServingEngine(SMALL, ens.take_member(v1, 0), max_seq=64)
    st = eng.slot_stream(ServeConfig(n_slots=4, max_seq=64))
    st.set_slot_limit(1)
    rng = np.random.default_rng(2)
    st.submit([
        Request(tokens=rng.integers(0, 64, 6).astype(np.int32),
                max_new_tokens=3)
        for _ in range(5)
    ])
    done = []
    while st.active and len(done) < 3:
        done.extend(st.step())
        assert sum(r is not None for r in st.slot_req) <= 1
    st.set_slot_limit(4)
    done.extend(st.drain())
    assert len(done) == 5
    # clamping: out-of-range limits snap into [1, n_slots]
    st.set_slot_limit(0)
    assert st.slot_limit == 1
    st.set_slot_limit(99)
    assert st.slot_limit == 4
