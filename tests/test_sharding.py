"""Sharding substrate tests: rule translation, pjit on a local mesh, and a
subprocess 512-device dry-run (the only place the forced device count may
touch jax state)."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec

from repro.sharding.logical import (
    axis_rules,
    logical_to_pspec,
    make_rules,
    rules_for,
)

REPO = os.path.join(os.path.dirname(__file__), "..")


def _mesh44():
    devs = np.array(jax.devices() * 16)[:16].reshape(4, 4)
    return Mesh(devs, ("data", "model"))


def test_basic_translation():
    mesh = _mesh44()
    rules = make_rules("train")
    spec = logical_to_pspec(("embed", "mlp"), rules, shape=(256, 512), mesh=mesh)
    assert spec == PartitionSpec("data", "model")


def test_indivisible_axis_dropped():
    mesh = _mesh44()
    rules = make_rules("train")
    # kv_heads=2 not divisible by model=4 -> dropped
    spec = logical_to_pspec(
        ("embed", "kv_heads", "head_dim"), rules, shape=(256, 2, 64), mesh=mesh
    )
    assert spec[1] is None


def test_expert_fallback_to_expert_mlp():
    mesh = _mesh44()
    rules = dict(make_rules("train"))
    rules["expert_mlp"] = "model"
    # 8 experts divisible by 4 -> experts take 'model', expert_mlp loses it
    spec = logical_to_pspec(
        ("experts", "embed", "expert_mlp"), rules, shape=(8, 256, 512), mesh=mesh
    )
    assert spec[0] == "model" and spec[2] is None
    # 2 experts NOT divisible -> expert_mlp gets 'model' instead
    spec2 = logical_to_pspec(
        ("experts", "embed", "expert_mlp"), rules, shape=(2, 256, 512), mesh=mesh
    )
    assert spec2[0] is None and spec2[2] == "model"


def test_decode_long_rules():
    r = rules_for("decode", batch=1)
    assert r["kv_seq"] == ("data", "model")
    r2 = rules_for("decode", batch=128)
    assert r2["kv_seq"] == "model"


def test_constrain_noop_without_rules():
    from repro.sharding.logical import constrain

    x = jnp.ones((4, 4))
    y = constrain(x, ("act_batch", "act_embed"))
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_pjit_runs_on_local_mesh():
    """The same model code executes under a (degenerate) mesh + rules."""
    from repro.configs.base import ModelConfig
    from repro.models import api
    from repro.models.params import unbox
    from repro.sharding.mesh import local_mesh

    cfg = ModelConfig(
        name="tiny", family="dense", n_layers=2, d_model=64, d_ff=128,
        vocab_size=64, n_heads=4, n_kv_heads=2, remat=False,
    )
    values, _ = unbox(api.init_params(cfg, jax.random.PRNGKey(0)))
    mesh = local_mesh()
    rules = make_rules("train")
    batch = {
        "tokens": jnp.zeros((4, 16), jnp.int32),
        "targets": jnp.zeros((4, 16), jnp.int32),
        "mask": jnp.ones((4, 16), jnp.float32),
    }
    with mesh, axis_rules(rules, mesh):
        loss, _ = jax.jit(lambda v, b: api.loss_fn(v, b, cfg))(values, batch)
    assert np.isfinite(float(loss))


@pytest.mark.slow
def test_dryrun_subprocess_512_devices(tmp_path):
    """One real dry-run combo on the forced-512-device mesh (cheapest cell)."""
    out = str(tmp_path)
    r = subprocess.run(
        [
            sys.executable, "-m", "repro.launch.dryrun",
            "--arch", "qwen2.5-3b", "--shape", "long_500k", "--out", out,
        ],
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")},
        capture_output=True, text=True, timeout=560,
    )
    assert r.returncode == 0, r.stdout + r.stderr
    rec = json.load(open(os.path.join(out, "qwen2.5-3b__long_500k__pod16x16.json")))
    assert rec["status"] == "ok"
    assert rec["n_chips"] == 256
    assert rec["roofline"]["flops"] > 0
