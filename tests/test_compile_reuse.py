"""Compile-once serving runtime: repeated same-shape calls must re-enter
the jit cache with ZERO new traces, and the vmapped generation path must be
semantically identical to per-member generation and to the dense cascade."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import ensemble as ens
from repro.core.cascade import TierSpec, cascade_apply_dense, cascade_apply_routed
from repro.models.params import unbox
from repro.serve import CascadeServer, CascadeTier, Request, ServingEngine
from repro.serve.cascade_server import digest_generations
from repro.serve.engine import model_programs, trace_count

SMALL = ModelConfig(
    name="reuse-s", family="dense", n_layers=2, d_model=64, d_ff=128,
    vocab_size=64, n_heads=4, n_kv_heads=2, remat=False,
)
BIG = ModelConfig(
    name="reuse-b", family="dense", n_layers=3, d_model=96, d_ff=192,
    vocab_size=64, n_heads=4, n_kv_heads=4, remat=False,
)


@pytest.fixture(scope="module")
def server():
    v1, _ = unbox(ens.init_ensemble(SMALL, 3, jax.random.PRNGKey(0)))
    v2, _ = unbox(ens.init_ensemble(BIG, 1, jax.random.PRNGKey(1)))
    return CascadeServer([
        CascadeTier(SMALL, v1, TierSpec("t1", "vote", 0.67, k=3, cost=1.0)),
        CascadeTier(BIG, v2, TierSpec("t2", "confidence", -1.0, k=1, cost=50.0)),
    ])


def test_classify_zero_retrace_after_warmup(server):
    toks = np.random.default_rng(0).integers(0, 64, (16, 12)).astype(np.int32)
    server.classify(toks)  # warmup: traces (tier transitions included)
    before = trace_count()
    r1 = server.classify(toks)
    r2 = server.classify(toks)
    assert trace_count() == before, "same-shape classify must not retrace"
    np.testing.assert_array_equal(r1.pred, r2.pred)


def test_generate_zero_retrace_after_warmup(server):
    toks = np.random.default_rng(1).integers(0, 64, (8, 10)).astype(np.int32)
    server.generate(toks, max_new_tokens=3)  # warmup
    before = trace_count()
    r1 = server.generate(toks, max_new_tokens=3)
    r2 = server.generate(toks, max_new_tokens=3)
    assert trace_count() == before, "same-shape generate must not retrace"
    np.testing.assert_array_equal(r1.pred, r2.pred)
    np.testing.assert_array_equal(r1.tier_of, r2.tier_of)


def test_engine_programs_shared_across_instances():
    """Two engines for the same config share one jitted program object —
    a fresh engine never recompiles what a previous one already traced."""
    v, _ = unbox(ens.init_ensemble(SMALL, 1, jax.random.PRNGKey(2)))
    member = ens.take_member(v, 0)
    e1 = ServingEngine(SMALL, member)
    e2 = ServingEngine(SMALL, member)
    assert e1._prefill is e2._prefill and e1._decode is e2._decode
    assert e1._prefill is model_programs(SMALL).prefill


def test_serve_continuous_no_rejit():
    v, _ = unbox(ens.init_ensemble(SMALL, 1, jax.random.PRNGKey(3)))
    eng = ServingEngine(SMALL, ens.take_member(v, 0), max_seq=64)
    rng = np.random.default_rng(4)

    def reqs():
        return [
            Request(tokens=rng.integers(0, 64, 6).astype(np.int32),
                    max_new_tokens=3)
            for _ in range(5)
        ]

    eng.serve_continuous(reqs(), n_slots=4)  # warmup
    before = trace_count()
    done = eng.serve_continuous(reqs(), n_slots=4)
    assert len(done) == 5
    assert trace_count() == before, "serve_continuous must reuse its decode program"


def test_serve_continuous_chunked_prefill_no_rejit():
    """Chunked-prefill admission must stay compile-once: the per-bucket
    prefill-into-slot program traces once per DISTINCT pow2 chunk length
    (the O(log S) bucket warmup) and a second serve_continuous call with
    the same prompt lengths re-enters the jit cache with zero new traces."""
    from repro.core.cascade import prompt_chunks

    v, _ = unbox(ens.init_ensemble(SMALL, 1, jax.random.PRNGKey(5)))
    eng = ServingEngine(SMALL, ens.take_member(v, 0), max_seq=64)

    def reqs():
        rr = np.random.default_rng(10)
        return [
            Request(tokens=rr.integers(0, 64, 21).astype(np.int32),
                    max_new_tokens=3)
            for _ in range(5)
        ]

    chunk_key = f"{SMALL.name}/prefill_chunk"
    before_chunk = trace_count(chunk_key)
    eng.serve_continuous(reqs(), n_slots=4)  # warmup: bucket programs trace
    stats = eng.last_stream_stats
    assert stats["chunk_calls"] > 0 and stats["chunk_tokens"] == 5 * 20
    # at most one NEW trace per distinct bucket length (21-token prompt ->
    # chunks 16, 4; earlier tests may have warmed some buckets already)
    assert trace_count(chunk_key) - before_chunk <= len(set(prompt_chunks(20)))
    # and the total bucket set for this config stays O(log S)
    assert trace_count(chunk_key) <= 5  # subset of {1, 2, 4, 8, 16}

    before = trace_count()
    done = eng.serve_continuous(reqs(), n_slots=4)
    assert len(done) == 5
    assert trace_count() == before, (
        "second chunked serve_continuous must not retrace anything"
    )


def test_cascade_serve_continuous_no_rejit(server):
    """Cascade continuous batching (SlotStream per tier, chunked admission)
    re-enters the jit cache on a repeat call with zero new traces."""
    def reqs():
        rr = np.random.default_rng(12)
        prompts = rr.integers(0, 64, (6, 8)).astype(np.int32)
        return [Request(tokens=p.copy(), max_new_tokens=4) for p in prompts]

    server.serve_continuous(reqs(), n_slots=3, max_seq=32)  # warmup
    before = trace_count()
    done = server.serve_continuous(reqs(), n_slots=3, max_seq=32)
    assert len(done) == 6
    assert trace_count() == before, (
        "repeat cascade serve_continuous must not retrace"
    )


def test_routed_equals_dense_on_vmapped_generation(server):
    """The routed (deployment) cascade and the dense (reference) cascade
    agree on every prediction/tier when both consume the vmapped ensemble
    generation digests."""
    toks = np.random.default_rng(5).integers(0, 64, (8, 10)).astype(np.int32)
    digests = [
        jnp.asarray(digest_generations(t.generate(toks, 4, seed=0)))
        for t in server.tiers
    ]

    # index-routed fns so the routed form's compaction picks matching rows
    fns = [lambda batch, D=D: D[:, batch["idx"]] for D in digests]
    specs = [
        TierSpec("t1", "vote_preds", 0.67, k=3, cost=1.0),
        TierSpec("t2", "vote_preds", -1.0, k=1, cost=50.0),
    ]
    idx = np.arange(toks.shape[0])
    pred_d, tier_d, _ = cascade_apply_dense(fns, specs, {"idx": idx})
    res = cascade_apply_routed(fns, specs, {"idx": idx}, pad_to=4)
    np.testing.assert_array_equal(np.asarray(pred_d), res.pred)
    np.testing.assert_array_equal(np.asarray(tier_d), res.tier_of)


def test_vmapped_generation_matches_member_engines(server):
    """Each member's row of the one-program vmapped generation is
    bit-identical to that member generating alone (greedy)."""
    tier = server.tiers[0]
    toks = np.random.default_rng(6).integers(0, 64, (4, 8)).astype(np.int32)
    out = tier.generate(toks, max_new_tokens=4)  # (E, B, T)
    assert out.shape == (3, 4, 4)
    for i in range(tier.k):
        eng = ServingEngine(SMALL, ens.take_member(tier.values, i))
        ref = eng.generate(toks, max_new_tokens=4)
        np.testing.assert_array_equal(out[i], ref)


def test_cascade_continuous_matches_batch_generate(server):
    """Cascade-aware continuous batching (slot streams + live deferral
    admission) routes and answers exactly like the batch generate mode for
    equal-length, equal-budget requests."""
    rng = np.random.default_rng(7)
    prompts = rng.integers(0, 64, (6, 8)).astype(np.int32)
    reqs = [Request(tokens=p.copy(), max_new_tokens=4) for p in prompts]
    done = server.serve_continuous(reqs, n_slots=3, max_seq=32)
    assert len(done) == 6
    by_rid = {r.rid: r for r in done}

    res = server.generate(prompts, max_new_tokens=4, seed=0)
    for i, r in enumerate(reqs):
        d = by_rid[r.rid]
        assert d.tier == res.tier_of[i]
        assert d.output is not None and len(d.output) == 4
