"""Workload generator tests (serve/workload.py): seeded traces replay
bit-for-bit, interarrival statistics match their analytic rates, and the
virtual clock holds the determinism contract."""
import numpy as np
import pytest

from repro.serve import ArrivalSpec, VirtualClock, Workload, bursty, diurnal, poisson
from repro.serve.batching import Request


GENERATORS = [
    ("poisson", lambda seed: poisson(50.0, 60, seed=seed)),
    ("bursty", lambda seed: bursty(5.0, 200.0, 60, seed=seed)),
    ("diurnal", lambda seed: diurnal(10.0, 100.0, 2.0, 60, seed=seed)),
]


@pytest.mark.parametrize("name,gen", GENERATORS, ids=[n for n, _ in GENERATORS])
def test_replay_determinism(name, gen):
    """Same seed -> identical arrival times, prompts, and budgets; a
    different seed -> a different trace (the seed actually binds)."""
    a, b = gen(seed=11), gen(seed=11)
    np.testing.assert_array_equal(a.arrival_times, b.arrival_times)
    for (ta, ra), (tb, rb) in zip(a, b):
        assert ta == tb
        np.testing.assert_array_equal(ra.tokens, rb.tokens)
        assert ra.max_new_tokens == rb.max_new_tokens
    c = gen(seed=12)
    assert not np.array_equal(a.arrival_times, c.arrival_times)


@pytest.mark.parametrize("name,gen", GENERATORS, ids=[n for n, _ in GENERATORS])
def test_iteration_materializes_fresh_requests(name, gen):
    """Two passes over ONE workload yield equal but DISTINCT Request
    objects — serving mutates requests, so replays must never share."""
    wl = gen(seed=3)
    first = [r for _, r in wl]
    second = [r for _, r in wl]
    for ra, rb in zip(first, second):
        assert ra is not rb and ra.rid != rb.rid
        np.testing.assert_array_equal(ra.tokens, rb.tokens)
        # mutating one replay must not leak into the next
        ra.tokens[0] = -1
    third = [r for _, r in wl]
    assert all(r.tokens[0] != -1 for r in third)


def test_poisson_interarrival_statistics():
    """Exponential interarrivals at rate lambda: mean 1/lambda, and the
    empirical mean of a large trace lands within a few standard errors."""
    rate = 80.0
    wl = poisson(rate, 4000, seed=0)
    gaps = np.diff(np.concatenate([[0.0], wl.arrival_times]))
    assert gaps.min() > 0
    mean = gaps.mean()
    se = (1.0 / rate) / np.sqrt(len(gaps))
    assert abs(mean - 1.0 / rate) < 4 * se, (mean, 1.0 / rate)
    # CV of an exponential is 1
    assert abs(gaps.std() / mean - 1.0) < 0.1


def test_bursty_rate_between_extremes_and_overdispersed():
    """The MMPP's long-run rate sits strictly between the off and on
    rates, and interarrivals are MORE variable than Poisson (CV > 1) —
    the burstiness the controller bench leans on."""
    lo, hi = 5.0, 200.0
    wl = bursty(lo, hi, 4000, seed=1, mean_on_s=0.5, mean_off_s=0.5)
    rate = wl.offered_qps
    assert lo < rate < hi
    # equal dwell means -> long-run rate near the midpoint (loose bounds:
    # one trace, finite dwell cycles)
    assert 0.5 * (lo + hi) * 0.7 < rate < 0.5 * (lo + hi) * 1.3
    gaps = np.diff(np.concatenate([[0.0], wl.arrival_times]))
    assert gaps.std() / gaps.mean() > 1.2  # overdispersed vs Poisson


def test_diurnal_rate_tracks_the_cosine():
    """Thinning against the raised cosine: arrivals concentrate at the
    mid-period peak, and the trough/peak empirical rates bracket the
    configured base/peak."""
    base, peak, period = 10.0, 200.0, 2.0
    wl = diurnal(base, peak, period, 4000, seed=2)
    t = wl.arrival_times
    assert base < wl.offered_qps < peak
    phase = np.mod(t, period) / period
    # the half-period around the peak (phase 0.25..0.75) must hold most
    # arrivals; the analytic share for this base/peak is ~0.79
    peak_share = ((phase > 0.25) & (phase < 0.75)).mean()
    assert peak_share > 0.65, peak_share


def test_workload_sorts_and_reports_span():
    specs = [
        ArrivalSpec(t_s=2.0, tokens=np.arange(4, dtype=np.int32), max_new_tokens=2),
        ArrivalSpec(t_s=1.0, tokens=np.arange(5, dtype=np.int32), max_new_tokens=3),
    ]
    wl = Workload(specs, name="manual")
    times = [t for t, _ in wl]
    assert times == [1.0, 2.0]
    assert wl.duration_s == 2.0 and len(wl) == 2
    r = next(iter(wl))[1]
    assert isinstance(r, Request)


def test_virtual_clock_advances_monotonically():
    clk = VirtualClock()
    assert clk() == 0.0
    clk.advance(0.5)
    clk.advance(0.0)
    assert clk() == 0.5
    with pytest.raises(AssertionError):
        clk.advance(-0.1)
