"""Unified serving telemetry (DESIGN.md §11): metrics registry + tracing.

``Observability`` is the bundle the serving layer passes around: a
``MetricsRegistry`` (always live — the legacy stats-dict views read from
it), a tracer (``NullTracer`` by default — per-request Perfetto tracing is
the opt-in half), and the injectable clock every serve-side timestamp goes
through (abclint ABC601 bans raw ``time.perf_counter()`` calls in
``serve/``).

Three invariants every recording site obeys (the §11 contract):

1. **No host sync**: only already-host-resident scalars are recorded.
   Device values cross through the metered ``core.cascade.host_fetch``
   BEFORE they may touch a metric or a trace arg — telemetry never adds a
   device→host transfer the byte meter cannot see (ABC2xx stays clean).
2. **Injectable time**: timestamps come from ``obs.clock`` /
   ``Tracer._clock``, so tests inject fake clocks and traces become
   deterministic; wall-clock never leaks into traced jax programs (ABC3xx).
3. **Near-zero when disabled**: the registry records via pre-resolved
   attribute updates (resolve metrics once at construction); the tracer is
   guarded by a single ``tracer.enabled`` check per site.

This package imports only the stdlib — no jax, no repro modules — so any
layer (core, serve, benchmarks, tools) may depend on it without cycles.
"""
from __future__ import annotations

from repro.obs.registry import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    Scope,
    StatsView,
    TIME_BUCKETS_S,
    UNIT_BUCKETS,
)
from repro.obs.trace import (
    NullTracer,
    REQUEST_PID,
    Tracer,
    perf_clock,
    validate_trace,
)


class Observability:
    """The telemetry bundle: registry + tracer + clock.

    Components that are not handed one create a PRIVATE bundle (own
    registry, disabled tracer) — their legacy stats views keep working and
    nothing is shared accidentally.  Pass one ``Observability`` down a
    serving stack to get a unified registry namespace and a single
    per-request trace across tiers, pools, and transports."""

    __slots__ = ("registry", "tracer", "clock")

    def __init__(self, registry=None, tracer=None, clock=None):
        self.registry = registry if registry is not None else MetricsRegistry()
        self.tracer = tracer if tracer is not None else NullTracer()
        self.clock = clock if clock is not None else perf_clock

    @classmethod
    def private(cls) -> "Observability":
        """A self-contained bundle (fresh registry, disabled tracer)."""
        return cls()

    def scope(self, prefix: str) -> Scope:
        """A name-prefix handle over this bundle's registry."""
        return Scope(self.registry, prefix)


def null_obs() -> Observability:
    """A fresh private bundle — the disabled-collector default."""
    return Observability()


_GLOBAL_REGISTRY: MetricsRegistry = MetricsRegistry()


def global_registry() -> MetricsRegistry:
    """The process-wide registry for module-level meters that predate any
    ``Observability`` (``core.cascade.host_fetch``'s byte/call counters)."""
    return _GLOBAL_REGISTRY


__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "NullTracer",
    "Observability",
    "REQUEST_PID",
    "Scope",
    "StatsView",
    "TIME_BUCKETS_S",
    "Tracer",
    "UNIT_BUCKETS",
    "global_registry",
    "null_obs",
    "perf_clock",
    "validate_trace",
]
