"""Per-request lifecycle tracing: Chrome/Perfetto trace-event JSON
(DESIGN.md §11).

One ``Tracer`` collects begin/end/instant events on per-request tracks
(pid = the ``requests`` process, tid = ``Request.rid``), timestamped in
microseconds from the tracer's construction through an INJECTABLE clock —
the serve layer never calls ``time.perf_counter()`` itself (abclint
ABC601), so tests drive traces with a fake clock and get deterministic
timestamps.

Span vocabulary (what a request's track shows, in lifecycle order):

    queue_wait     B/E  submitted (or landed off a hop) -> admitted
    admit          B/E  slot claim + prompt prefill; ``shared_tokens`` arg
      prefill_chunk B/E   one bucketed chunk dispatch (nested in admit)
      verify_draft  B/E   speculative draft scoring (nested in admit; args:
                          draft_tokens offered, accepted prefix length)
    decode         B/E  slot occupancy: admit -> completion
    defer_vote     i    the agreement vote (args: margin, defer, tier)
    hop            B/E  transport send -> delivery at the next tier's
                        admission point (args: link_s, blocked_s, hidden_s —
                        the overlap split)
    forced_complete i   pool exhaustion cut the request short
    complete       i    terminal: the request exited the cascade

``export()`` returns the standard ``{"traceEvents": [...]}`` wrapping;
``validate_trace`` is the schema checker the tests and the bench-smoke CI
artifact both run: required fields, per-track monotone timestamps, strict
B/E span nesting, and every track reaching a terminal ``complete`` event.

``NullTracer`` is the disabled collector: ``enabled`` is False and every
record is a no-op — hot paths guard arg-dict construction behind
``if tracer.enabled`` so a disabled tracer costs one attribute check.
"""
from __future__ import annotations

import json
import time
from typing import Dict, List, Optional

#: the default injectable clock — the FUNCTION object, handed to components
#: so the serve layer holds a clock reference instead of calling
#: ``time.perf_counter()`` inline (see abclint ABC601)
perf_clock = time.perf_counter

#: the single process id for per-request tracks
REQUEST_PID = 1

_TERMINAL = ("complete", "forced_complete")


class NullTracer:
    """Disabled collector: every hook is a no-op, ``enabled`` gates the
    callers' arg construction."""

    enabled = False

    def begin(self, tid, name, **args):
        pass

    def end(self, tid, name, **args):
        pass

    def instant(self, tid, name, **args):
        pass

    def export(self) -> dict:
        return {"traceEvents": []}


class Tracer:
    """Collecting tracer. All record methods take host scalars only (the
    no-host-sync rule): a device value must go through the metered
    ``core.cascade.host_fetch`` before it may appear in ``args``."""

    enabled = True

    def __init__(self, clock=None, *, process_name: str = "requests"):
        self._clock = clock if clock is not None else perf_clock
        self._t0 = self._clock()
        self.events: List[dict] = [
            {
                "ph": "M",
                "pid": REQUEST_PID,
                "name": "process_name",
                "args": {"name": process_name},
            }
        ]
        self._named_tids: Dict[int, bool] = {}

    def _ts(self) -> float:
        """Microseconds since tracer construction (the trace epoch)."""
        return (self._clock() - self._t0) * 1e6

    def name_track(self, tid: int, name: str) -> None:
        """Label a request track (idempotent per tid)."""
        if tid not in self._named_tids:
            self._named_tids[tid] = True
            self.events.append(
                {
                    "ph": "M",
                    "pid": REQUEST_PID,
                    "tid": int(tid),
                    "name": "thread_name",
                    "args": {"name": name},
                }
            )

    def begin(self, tid, name, **args):
        self.name_track(int(tid), f"req {int(tid)}")
        self.events.append(
            {
                "ph": "B",
                "pid": REQUEST_PID,
                "tid": int(tid),
                "name": name,
                "cat": "serve",
                "ts": self._ts(),
                "args": args,
            }
        )

    def end(self, tid, name, **args):
        self.events.append(
            {
                "ph": "E",
                "pid": REQUEST_PID,
                "tid": int(tid),
                "name": name,
                "cat": "serve",
                "ts": self._ts(),
                "args": args,
            }
        )

    def instant(self, tid, name, **args):
        self.name_track(int(tid), f"req {int(tid)}")
        self.events.append(
            {
                "ph": "i",
                "pid": REQUEST_PID,
                "tid": int(tid),
                "name": name,
                "cat": "serve",
                "ts": self._ts(),
                "s": "t",
                "args": args,
            }
        )

    def export(self) -> dict:
        return {"traceEvents": list(self.events), "displayTimeUnit": "ms"}

    def write(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.export(), f)


def validate_trace(trace: dict, *, require_terminal: bool = True) -> dict:
    """Schema-validate a Perfetto trace-event dump.

    Checks (raising ``AssertionError`` with the offending event):

    * the ``{"traceEvents": [...]}`` wrapping and per-event required fields
      (``ph``/``pid``; non-metadata events also ``tid``/``name``/numeric
      ``ts``; instants carry a scope ``s``);
    * per-(pid, tid) track timestamps are monotone non-decreasing in
      emission order;
    * B/E spans nest strictly (every E matches the innermost open B of the
      same name; no track ends with an open span);
    * with ``require_terminal``, every track that saw any lifecycle event
      contains a terminal ``complete``/``forced_complete`` instant — no
      admitted request may vanish mid-cascade.

    Returns a summary dict: ``{"events", "tracks", "spans"}``.
    """
    assert isinstance(trace, dict) and isinstance(
        trace.get("traceEvents"), list
    ), "trace must be a dict with a traceEvents list"
    tracks: Dict[tuple, List[dict]] = {}
    n_spans = 0
    for ev in trace["traceEvents"]:
        assert isinstance(ev, dict) and "ph" in ev and "pid" in ev, ev
        if ev["ph"] == "M":
            assert ev.get("name") in ("process_name", "thread_name"), ev
            assert "name" in ev.get("args", {}), ev
            continue
        assert ev["ph"] in ("B", "E", "i", "X"), ev
        assert isinstance(ev.get("name"), str) and ev["name"], ev
        assert isinstance(ev.get("tid"), int), ev
        assert isinstance(ev.get("ts"), (int, float)) and ev["ts"] >= 0, ev
        if ev["ph"] == "i":
            assert ev.get("s") in ("t", "p", "g"), ev
        tracks.setdefault((ev["pid"], ev["tid"]), []).append(ev)
    for key, evs in tracks.items():
        last_ts = -1.0
        stack: List[str] = []
        saw_terminal = False
        for ev in evs:
            assert ev["ts"] >= last_ts, (
                f"track {key}: non-monotone ts {ev['ts']} after {last_ts}: {ev}"
            )
            last_ts = ev["ts"]
            if ev["ph"] == "B":
                stack.append(ev["name"])
                n_spans += 1
            elif ev["ph"] == "E":
                assert stack, f"track {key}: E without open span: {ev}"
                assert stack[-1] == ev["name"], (
                    f"track {key}: E {ev['name']!r} does not close the "
                    f"innermost open span {stack[-1]!r}"
                )
                stack.pop()
            elif ev["ph"] == "i" and ev["name"] in _TERMINAL:
                saw_terminal = True
        assert not stack, f"track {key}: unclosed spans at end: {stack}"
        if require_terminal:
            assert saw_terminal, (
                f"track {key}: no terminal complete event — the request "
                "vanished mid-cascade"
            )
    return {
        "events": len(trace["traceEvents"]),
        "tracks": len(tracks),
        "spans": n_spans,
    }
