"""Metrics registry: counters, gauges, fixed-bucket histograms (DESIGN.md §11).

One ``MetricsRegistry`` is the single place serving-path statistics live.
Every metric is get-or-created by its fully-qualified dotted name
(``slot_stream.tier0.admitted``, ``transport.edge_cloud.bytes``,
``paging.pool_occupancy``) so two components can never collide on an
unqualified key — the bench-CSV ambiguity where ``Transport.stats()``'s
``latency``/``wait`` landed next to slot-stream keys in the same row is
structurally impossible here.

Recording discipline (the no-host-sync rule, DESIGN.md §11): metrics accept
ONLY host-resident python scalars — callers fetch through the metered
``core.cascade.host_fetch`` first if a value lives on device.  Recording is
a plain attribute update on a pre-resolved metric object (resolve once at
construction, record per event), cheap enough to stay on every hot path
unconditionally; the on/off half of the telemetry split is the tracer
(``repro.obs.trace``), not the registry.

Legacy compatibility: the pre-registry ad-hoc stats dicts
(``SlotStream.stats``, ``PagePool.stats``, ``ServingEngine.stats``,
``core.cascade.host_fetch_stats()``) survive as ``StatsView`` facades —
read-only ``Mapping``s whose values are computed from registry metrics on
access, so ``stream.stats["admitted"]`` and ``dict(stream.stats)`` keep
working while the registry stays the single source of truth.
"""
from __future__ import annotations

import bisect
import math
from typing import Callable, Dict, Iterator, List, Mapping, Optional, Sequence


def _geometric_buckets(lo: float, hi: float, per_decade: int = 5) -> List[float]:
    """Geometric bucket upper bounds spanning [lo, hi]."""
    n = int(math.ceil(math.log10(hi / lo) * per_decade)) + 1
    return [lo * 10 ** (i / per_decade) for i in range(n)]


#: default histogram buckets: seconds, 1µs .. 100s, 5 per decade — wide
#: enough for dispatch overheads and multi-second request latencies alike
TIME_BUCKETS_S = tuple(_geometric_buckets(1e-6, 100.0))

#: unit-interval buckets (agreement margins, rates)
UNIT_BUCKETS = tuple(i / 20 for i in range(1, 21))


class Counter:
    """Monotone accumulator (int or float — whatever callers add)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def add(self, v=1) -> None:
        self.value += v

    def reset(self) -> None:
        self.value = 0

    def __repr__(self):
        return f"Counter({self.name}={self.value})"


class Gauge:
    """Point-in-time level with a high-water mark (``peak``)."""

    __slots__ = ("name", "value", "peak")

    def __init__(self, name: str):
        self.name = name
        self.value = 0
        self.peak = 0

    def set(self, v) -> None:
        self.value = v
        if v > self.peak:
            self.peak = v

    def reset(self) -> None:
        self.value = 0
        self.peak = 0

    def __repr__(self):
        return f"Gauge({self.name}={self.value}, peak={self.peak})"


class Histogram:
    """Fixed-bucket histogram with an exact sum.

    ``buckets`` are upper bounds (sorted); one overflow bucket catches the
    tail.  ``sum`` accumulates the raw values in record order — a
    ``StatsView`` built on ``sum`` is bit-for-bit the float the old ad-hoc
    ``+=`` accumulator would have produced.  ``percentile`` interpolates
    linearly inside the winning bucket (the usual fixed-bucket estimate)."""

    __slots__ = ("name", "buckets", "counts", "sum", "count", "_min", "_max")

    def __init__(self, name: str, buckets: Optional[Sequence[float]] = None):
        self.name = name
        self.buckets = tuple(buckets) if buckets is not None else TIME_BUCKETS_S
        self.counts = [0] * (len(self.buckets) + 1)  # + overflow
        self.sum = 0.0
        self.count = 0
        self._min = math.inf
        self._max = -math.inf

    def record(self, v: float) -> None:
        self.sum += v
        self.count += 1
        if v < self._min:
            self._min = v
        if v > self._max:
            self._max = v
        self.counts[bisect.bisect_left(self.buckets, v)] += 1

    def reset(self) -> None:
        self.counts = [0] * (len(self.buckets) + 1)
        self.sum = 0.0
        self.count = 0
        self._min = math.inf
        self._max = -math.inf

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def percentile(self, q: float) -> float:
        """Estimated q-quantile (q in [0, 1]) by linear interpolation
        within the winning bucket; exact at the recorded min/max ends."""
        assert 0.0 <= q <= 1.0, q
        if self.count == 0:
            return 0.0
        rank = q * self.count
        seen = 0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            if seen + c >= rank:
                lo = self.buckets[i - 1] if i > 0 else min(self._min, self.buckets[0])
                hi = self.buckets[i] if i < len(self.buckets) else self._max
                lo = max(lo, self._min)
                hi = min(hi, self._max)
                if hi <= lo:
                    return lo
                frac = (rank - seen) / c
                return lo + (hi - lo) * frac
            seen += c
        return self._max

    def __repr__(self):
        return f"Histogram({self.name}: n={self.count}, sum={self.sum:.6g})"


class MetricsRegistry:
    """Get-or-create store of named metrics.

    Names are fully-qualified dotted strings; asking for an existing name
    with a different metric kind raises (one name, one meaning).  The
    registry itself is plain python — safe to construct anywhere, costs
    nothing when nobody records."""

    def __init__(self):
        self._metrics: Dict[str, object] = {}

    def _get(self, name: str, cls, *args):
        m = self._metrics.get(name)
        if m is None:
            m = cls(name, *args)
            self._metrics[name] = m
        elif type(m) is not cls:
            raise TypeError(
                f"metric {name!r} already registered as {type(m).__name__}, "
                f"requested {cls.__name__}"
            )
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(
        self, name: str, buckets: Optional[Sequence[float]] = None
    ) -> Histogram:
        if name in self._metrics:
            return self._get(name, Histogram)
        return self._get(name, Histogram, buckets)

    def get(self, name: str):
        """The metric registered under ``name``, or None."""
        return self._metrics.get(name)

    def value(self, name: str):
        """Scalar reading of a metric: counter/gauge value, histogram sum."""
        m = self._metrics[name]
        return m.sum if isinstance(m, Histogram) else m.value

    def names(self) -> List[str]:
        return sorted(self._metrics)

    def reset(self) -> None:
        for m in self._metrics.values():
            m.reset()

    def snapshot(self) -> Dict[str, float]:
        """Flat fully-qualified-name -> scalar dump (the bench exporter's
        input).  Counters/gauges contribute their value (gauges also a
        ``.peak``); histograms contribute ``.sum``/``.count``/``.p50``/
        ``.p99``."""
        out: Dict[str, float] = {}
        for name in sorted(self._metrics):
            m = self._metrics[name]
            if isinstance(m, Counter):
                out[name] = m.value
            elif isinstance(m, Gauge):
                out[name] = m.value
                out[f"{name}.peak"] = m.peak
            else:
                out[f"{name}.sum"] = m.sum
                out[f"{name}.count"] = m.count
                out[f"{name}.p50"] = m.percentile(0.50)
                out[f"{name}.p99"] = m.percentile(0.99)
        return out


class StatsView(Mapping):
    """Read-only legacy stats-dict facade: each key maps to a zero-arg
    reader over registry metrics, evaluated on access.  ``dict(view)``
    materializes the familiar plain dict; mutation goes through the
    registry, never through the view (abclint ABC602 enforces this in
    ``serve/``)."""

    __slots__ = ("_readers",)

    def __init__(self, readers: Dict[str, Callable[[], object]]):
        self._readers = dict(readers)

    def __getitem__(self, key: str):
        return self._readers[key]()

    def __iter__(self) -> Iterator[str]:
        return iter(self._readers)

    def __len__(self) -> int:
        return len(self._readers)

    def __repr__(self):
        return repr({k: r() for k, r in self._readers.items()})


class Scope:
    """A name-prefix handle over one registry: ``scope.counter("admitted")``
    registers ``<prefix>.admitted``.  Resolve metrics ONCE at component
    construction; record on the resolved objects per event."""

    __slots__ = ("registry", "prefix")

    def __init__(self, registry: MetricsRegistry, prefix: str):
        self.registry = registry
        self.prefix = prefix

    def name(self, suffix: str) -> str:
        return f"{self.prefix}.{suffix}"

    def counter(self, suffix: str) -> Counter:
        return self.registry.counter(self.name(suffix))

    def gauge(self, suffix: str) -> Gauge:
        return self.registry.gauge(self.name(suffix))

    def histogram(
        self, suffix: str, buckets: Optional[Sequence[float]] = None
    ) -> Histogram:
        return self.registry.histogram(self.name(suffix), buckets)
