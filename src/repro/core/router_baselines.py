"""Trained-router baselines (paper §2.2) — the setups ABC competes with.

ABC's pitch is being *training-free*; to compare fairly we implement a real
(small) learned router à la FrugalGPT: a logistic scorer on feature vectors
(e.g. the tier model's last hidden state or its logits) trained to predict
"is the tier's answer correct", used exactly like a score-based deferral
rule.  The training loop is plain JAX — its cost is the "setup cost" the
paper notes the baselines pay per task/model change.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.deferral import RuleOutput


@dataclasses.dataclass
class LearnedRouter:
    w: jax.Array  # (F,)
    b: jax.Array  # ()
    mu: jax.Array  # (F,) feature normalization
    sd: jax.Array  # (F,)

    def score(self, feats: jax.Array) -> jax.Array:
        z = (feats - self.mu) / self.sd
        return jax.nn.sigmoid(z @ self.w + self.b)


def logits_features(logits: jax.Array) -> jax.Array:
    """Router features from tier logits (B, V): top-p, margin, entropy,
    logsumexp — the standard confidence summary vector."""
    lf = logits.astype(jnp.float32)
    p = jax.nn.softmax(lf, axis=-1)
    top2 = jax.lax.top_k(p, 2)[0]
    ent = -jnp.sum(p * jnp.log(p + 1e-9), axis=-1) / jnp.log(lf.shape[-1])
    lse = jax.nn.logsumexp(lf, axis=-1)
    return jnp.stack([top2[:, 0], top2[:, 0] - top2[:, 1], ent, lse], axis=-1)


def _router_loss(params, Xn, y):
    w, b = params
    z = Xn @ w + b
    return jnp.mean(jnp.maximum(z, 0) - z * y + jnp.log1p(jnp.exp(-jnp.abs(z))))


# module-level jit: every train_router call re-enters one program cache
_router_grad = jax.jit(jax.grad(_router_loss))


def train_router(
    feats: np.ndarray,  # (N, F)
    correct: np.ndarray,  # (N,) bool — was the tier's answer right?
    *,
    steps: int = 300,
    lr: float = 0.1,
    seed: int = 0,
) -> LearnedRouter:
    X = jnp.asarray(feats, jnp.float32)
    y = jnp.asarray(correct, jnp.float32)
    mu, sd = X.mean(0), X.std(0) + 1e-6
    Xn = (X - mu) / sd
    w = jax.random.normal(jax.random.PRNGKey(seed), (X.shape[1],)) * 0.01
    b = jnp.zeros(())
    params = (w, b)
    for _ in range(steps):
        gw, gb = _router_grad(params, Xn, y)
        params = (params[0] - lr * gw, params[1] - lr * gb)
    return LearnedRouter(w=params[0], b=params[1], mu=mu, sd=sd)


def router_rule(
    router: LearnedRouter, logits: jax.Array, theta: float
) -> RuleOutput:
    """Use a trained router as a deferral rule (FrugalGPT-style)."""
    if logits.ndim == 3:
        logits = logits[0]
    s = router.score(logits_features(logits))
    return RuleOutput(
        pred=jnp.argmax(logits, axis=-1).astype(jnp.int32),
        score=s,
        defer=s <= theta,
    )


def margin_rule(logits: jax.Array, theta: float) -> RuleOutput:
    """Top-1/top-2 probability margin (another classic score rule)."""
    if logits.ndim == 3:
        logits = logits.mean(axis=0)
    p = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top2 = jax.lax.top_k(p, 2)[0]
    s = top2[:, 0] - top2[:, 1]
    return RuleOutput(
        pred=jnp.argmax(logits, axis=-1).astype(jnp.int32),
        score=s,
        defer=s <= theta,
    )
