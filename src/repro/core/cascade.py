"""Cascade execution (Algorithm 1).

Two execution forms:

``cascade_apply_dense``  — fully-jitted masked form: every tier evaluates the
    whole batch and the first agreeing tier's answer is selected with
    ``jnp.where``.  No FLOPs are saved, but the whole cascade is a single
    XLA program that lowers/shards on the production mesh — this is what the
    cascade dry-run compiles, and it doubles as the reference semantics.

``cascade_apply_routed`` — device-routed compacting form: after tier i only
    the deferred examples flow to tier i+1.  Compaction (defer mask →
    prefix-sum scatter → dense payload + index map) happens ON DEVICE in
    the ``kernels/compaction`` Pallas kernel (or its interpret/XLA
    fallback); the host only ever reads the scalar deferred COUNT to pick
    bucket shapes — the payload itself never crosses device→host on the
    defer path.  When tiers are placed on different hosts, the compacted
    payload takes an explicit ``Transport`` hop (serve/transport.py) whose
    bytes and latency are metered.  This is the deployment path
    (serve/cascade_server.py) and the one whose measured cost reproduces
    Prop 4.1.2.

Both forms take per-tier callables ``tier_fns[i](batch_slice) -> logits
(E_i, B, V)`` so they work for classifier heads, prefill last-token logits,
or sampled-answer ids alike.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import deferral
from repro.kernels.compaction import ops as compaction_ops
from repro.obs import global_registry as _global_registry


@dataclasses.dataclass(frozen=True)
class TierSpec:
    """One cascade level: an ensemble of k models + its deferral rule."""

    name: str
    rule: str  # 'vote' | 'score' | 'confidence' | 'entropy'
    theta: float
    k: int = 1
    cost: float = 1.0  # per-example cost in whatever unit the scenario uses


@dataclasses.dataclass
class CascadeResult:
    pred: np.ndarray  # (B,)
    tier_of: np.ndarray  # (B,) index of the answering tier
    scores: np.ndarray  # (B,) deferral score at the answering tier
    tier_counts: np.ndarray  # (n_tiers,) examples answered per tier
    evaluated: np.ndarray  # (n_tiers,) examples *evaluated* per tier
    cost: float  # total cost under the specs' per-example costs


def cascade_apply_dense(
    tier_fns: Sequence[Callable],
    specs: Sequence[TierSpec],
    batch,
):
    """Jit-friendly masked cascade.  Returns (pred, tier_of, scores)."""
    n = len(tier_fns)
    pred = None
    tier_of = None
    score_out = None
    decided = None
    for i, (fn, spec) in enumerate(zip(tier_fns, specs)):
        logits = fn(batch)
        out = deferral.apply_rule(spec.rule, logits, spec.theta)
        last = i == n - 1
        take = jnp.logical_or(~out.defer, jnp.bool_(last))
        if pred is None:
            pred = out.pred
            tier_of = jnp.zeros_like(out.pred)
            score_out = out.score
            decided = take
        else:
            newly = jnp.logical_and(~decided, take)
            pred = jnp.where(newly, out.pred, pred)
            tier_of = jnp.where(newly, i, tier_of)
            score_out = jnp.where(newly, out.score, score_out)
            decided = jnp.logical_or(decided, take)
    return pred, tier_of, score_out


def bucket_size(n: int, floor: int = 8) -> int:
    """Power-of-two batch bucket (>= floor).  Used everywhere a host-routed
    batch is padded before hitting a jitted program: bucketed shapes bound
    the number of distinct compilations to O(log B) instead of O(B)."""
    p = max(1, floor)
    while p < n:
        p *= 2
    return p


def bucket_chunks(n: int, floor: int = 8) -> List[int]:
    """Greedy power-of-two decomposition of a batch of ``n`` examples into
    bucket-shaped chunks (each a power-of-two multiple of ``floor``).

    This is how deferred examples are re-batched between tiers: every chunk
    shape comes from an O(log B) bucket set (so tier transitions re-enter
    already-compiled programs), while total padding stays < ``2 * floor``
    (a single covering bucket could waste ~2x the batch in padding, which
    would show up directly in the Prop 4.1.2 cost accounting)."""
    sizes: List[int] = []
    rem = n
    while rem > 0:
        c = max(1, floor)
        while c * 2 <= rem:
            c *= 2
        sizes.append(c)  # the last chunk may overshoot rem (that is padding)
        rem -= c
    return sizes


def prompt_chunks(n: int, max_chunk: int = 256) -> List[int]:
    """Exact power-of-two cover of ``n`` prompt tokens (largest-first).

    Chunked-prefill admission (serve/slot_stream.py) consumes a prompt
    prefix through per-bucket jitted prefill programs; every chunk size here
    comes from the O(log S) set {1, 2, 4, ..., max_chunk}, so after warmup
    no admission ever traces a new program.  Unlike ``bucket_chunks`` (batch
    re-padding, where overshoot is just padded rows), prompt chunks must
    tile EXACTLY — a padded prompt token would write a bogus KV row /
    advance SSM state — so the tail reuses ``bucket_chunks`` with floor 1,
    which is the plain binary decomposition and never overshoots."""
    sizes: List[int] = []
    while n >= max_chunk:
        sizes.append(max_chunk)
        n -= max_chunk
    if n > 0:
        sizes.extend(bucket_chunks(n, floor=1))
    return sizes


def _pad_rows(x, n):
    """Edge-pad a device array's leading axis to ``n`` rows."""
    if x.shape[0] == n:
        return x
    pad = x.shape[0]
    reps = [n - pad] + [1] * (x.ndim - 1)
    return jnp.concatenate([x, jnp.tile(x[-1:], reps)], axis=0)


# ---------------------------------------------------------------------------
# host-fetch accounting: every INTENTIONAL device→host read in the routed
# cascade goes through _fetch (explicit jax.device_get, transfer-guard
# clean) and is byte-metered, so tests can assert the defer path moves
# only scalar counts + final results to the host — never payload.  The
# meters are ``host_fetch.*`` counters on the process-wide registry
# (DESIGN.md §11); ``host_fetch_stats()`` is the legacy dict view.
# ---------------------------------------------------------------------------

_C_FETCH_BYTES = _global_registry().counter("host_fetch.bytes")
_C_FETCH_CALLS = _global_registry().counter("host_fetch.calls")


def host_fetch_stats() -> dict:
    return {"bytes": _C_FETCH_BYTES.value, "calls": _C_FETCH_CALLS.value}


def reset_host_fetch_stats() -> None:
    _C_FETCH_BYTES.reset()
    _C_FETCH_CALLS.reset()


def _fetch(tree):
    for leaf in jax.tree.leaves(tree):
        if hasattr(leaf, "dtype"):
            _C_FETCH_BYTES.add(int(leaf.size) * jnp.dtype(leaf.dtype).itemsize)
    _C_FETCH_CALLS.add(1)
    return jax.device_get(tree)


def host_fetch(tree):
    """The public metered explicit fetch (numpy leaves out, bytes counted).

    Every INTENTIONAL device→host read on a serving path goes through here
    (or the module-private ``_fetch``): the serving engines' sampled-token
    reads, the cascade's vote scalars, the per-tier deferred counts.  This
    is what keeps the transfer-guard regressions meaningful — implicit
    transfers raise, and the byte meter sees everything that did cross.
    abclint pass 2 (ABC2xx) enforces the discipline statically."""
    return _fetch(tree)


def _colocate(x, ref):
    """Re-place ``x`` next to ``ref`` (device→device, never via host) so
    result accumulators can merge per-tier answers produced on other hosts'
    device sets (pod placement)."""
    xs, rs = getattr(x, "sharding", None), getattr(ref, "sharding", None)
    if xs is not None and rs is not None and xs != rs:
        return jax.device_put(x, rs)
    return x


def cascade_apply_routed(
    tier_fns: Sequence[Callable],
    specs: Sequence[TierSpec],
    batch: dict,
    *,
    pad_to: int = 8,
    transport=None,
    hosts: Optional[Sequence[str]] = None,
) -> CascadeResult:
    """Device-routed cascade with ON-DEVICE batch compaction between tiers.

    ``batch`` is a dict of numpy/jax arrays with a leading example axis; it
    is moved to device once and never gathered back on host.  After each
    tier, the defer mask drives the ``kernels/compaction`` prefix-sum
    scatter (Pallas on TPU, interpret/XLA fallback elsewhere): deferred
    examples become a dense payload + index map without leaving HBM.  The
    host reads exactly ONE scalar per tier transition (the deferred count,
    via an explicit transfer) to choose greedy power-of-two bucket chunks
    (floor ``pad_to``, see ``bucket_chunks``) so tier transitions re-enter
    already-compiled programs.

    ``transport`` (optional) is a serve/transport.py backend — either one
    Transport applied to every tier boundary or a per-hop sequence (None
    entries = same-host hops).  Only the compacted deferral payload (padded
    to its bucket cover) is sent, which is what makes the §5.2 scenario
    benches report MEASURED bytes-over-link.  ``hosts`` names the per-tier
    placement for hop metering (defaults to tier names).

    Cost accounting: spec.cost · examples evaluated (the chunk padding is
    charged too — that is the real serving cost).
    """
    n = len(tier_fns)
    cur = {k: jnp.asarray(v) for k, v in batch.items()}
    B = int(jax.tree.leaves(cur)[0].shape[0])
    hop_transports = (
        list(transport) if isinstance(transport, (list, tuple))
        else [transport] * (n - 1)
    )
    assert len(hop_transports) >= n - 1, (len(hop_transports), n)
    hop_names = list(hosts) if hosts is not None else [s.name for s in specs]

    pred = jnp.zeros((B,), jnp.int32)
    tier_of = jnp.full((B,), -1, jnp.int32)
    scores = jnp.zeros((B,), jnp.float32)
    tier_counts_dev: List[jax.Array] = []
    evaluated = np.zeros((n,), np.int64)
    cost = 0.0

    active_idx = jnp.arange(B, dtype=jnp.int32)  # local row -> original row
    m = B
    landed_tr = None  # transport whose placement `cur`'s rows currently honor
    for i, (fn, spec) in enumerate(zip(tier_fns, specs)):
        defer_c, p_c, s_c = [], [], []
        charged = 0
        off = 0
        for c in bucket_chunks(m, pad_to):
            take = min(c, m - off)
            if off == 0 and c == int(jax.tree.leaves(cur)[0].shape[0]):
                # the delivered payload IS this chunk (single-bucket cover):
                # feed it exactly as the transport landed it — no slice, no
                # re-layout, rows keep their data-sharded residency
                fed = cur
            else:
                fed = {
                    k: _pad_rows(jax.lax.slice_in_dim(v, off, off + take), c)
                    for k, v in cur.items()
                }
                if landed_tr is not None:
                    # slicing/padding re-laid the rows (XLA picks its own
                    # output sharding for eager slices); put each chunk back
                    # onto the transport's example sharding so a hand-off
                    # landed data-sharded is never silently re-replicated
                    fed = {
                        k: jax.device_put(v, landed_tr.example_sharding(v))
                        for k, v in fed.items()
                    }
            logits = fn(fed)
            out = deferral.apply_rule(spec.rule, logits, spec.theta)
            defer_c.append(out.defer[:take])
            p_c.append(out.pred[:take])
            s_c.append(out.score[:take])
            charged += c
            off += take
        defer = jnp.concatenate(defer_c) if len(defer_c) > 1 else defer_c[0]
        p = jnp.concatenate(p_c) if len(p_c) > 1 else p_c[0]
        s = jnp.concatenate(s_c) if len(s_c) > 1 else s_c[0]
        evaluated[i] = charged
        cost += spec.cost * charged

        last = i == n - 1
        take_m = jnp.logical_or(~defer, jnp.bool_(last))
        # scatter this tier's answers to their original rows (device-side;
        # answers produced on another host's pod slice hop back d2d first)
        take_l, p_l, s_l, idx_l = (
            _colocate(t, pred) for t in (take_m, p, s, active_idx)
        )
        pred = pred.at[idx_l].set(jnp.where(take_l, p_l, pred[idx_l]))
        tier_of = tier_of.at[idx_l].set(
            jnp.where(take_l, jnp.int32(i), tier_of[idx_l])
        )
        scores = scores.at[idx_l].set(
            jnp.where(take_l, s_l, scores[idx_l])
        )
        tier_counts_dev.append(jnp.sum(take_m))

        if last:
            break
        # on-device compaction of the defer path: dense payload + index map
        # straight from the mask — no host gather, no re-pad on host.
        # (cur may carry bucket-padding rows from the previous hop; the
        # mask covers only the m real rows)
        real = {
            k: v if v.shape[0] == m else jax.lax.slice_in_dim(v, 0, m)
            for k, v in cur.items()
        }
        ctree, index_map, count = compaction_ops.compact_tree(
            {**real, "__idx": active_idx}, defer
        )
        n_defer = int(_fetch(count))  # the ONLY per-tier host read: a scalar
        if n_defer == 0:
            break
        n_padded = sum(bucket_chunks(n_defer, pad_to))
        n_padded = min(n_padded, m)  # payload rows physically available
        payload = {
            k: jax.lax.slice_in_dim(v, 0, n_padded) for k, v in ctree.items()
        }
        tr = hop_transports[i]
        if tr is not None:
            # batch mode has no admission point to overlap with — tier i+1
            # needs the whole payload before its first chunk — so the hop
            # handle is drained immediately; the overlapped drain lives in
            # CascadeServer.serve_continuous (SlotStream in-flight admission)
            handle = tr.send_async(
                hop_names[i], hop_names[i + 1], payload, n_examples=n_defer
            )
            payload = {k: jnp.asarray(v) for k, v in handle.result().items()}
        # rows now live where THIS boundary's transport put them; the next
        # tier's chunking must preserve that residency (sharded hand-offs
        # expose example_sharding; others have no placement to honor)
        landed_tr = tr if hasattr(tr, "example_sharding") else None
        active_idx = payload.pop("__idx")[:n_defer]
        cur = payload
        m = n_defer

    while len(tier_counts_dev) < n:
        tier_counts_dev.append(jnp.zeros((), jnp.int32))
    # per-tier counts may live on different hosts' devices — fetch as-is
    pred_h, tier_h, scores_h, counts_h = _fetch(
        (pred, tier_of, scores, tier_counts_dev)
    )
    return CascadeResult(
        pred=pred_h,
        tier_of=tier_h,
        scores=scores_h,
        # abclint: disable=ABC203(counts_h is a host list of fetched per-tier scalars)
        tier_counts=np.asarray(counts_h, np.int64),
        evaluated=evaluated,
        cost=cost,
    )
