"""Cascade execution (Algorithm 1).

Two execution forms:

``cascade_apply_dense``  — fully-jitted masked form: every tier evaluates the
    whole batch and the first agreeing tier's answer is selected with
    ``jnp.where``.  No FLOPs are saved, but the whole cascade is a single
    XLA program that lowers/shards on the production mesh — this is what the
    cascade dry-run compiles, and it doubles as the reference semantics.

``cascade_apply_routed`` — host-routed compacting form: after tier i only the
    deferred examples are gathered (padded to a multiple of ``pad_to``) and
    sent to tier i+1.  This is the deployment path (serve/engine.py) and the
    one whose measured cost reproduces Prop 4.1.2.

Both forms take per-tier callables ``tier_fns[i](batch_slice) -> logits
(E_i, B, V)`` so they work for classifier heads, prefill last-token logits,
or sampled-answer ids alike.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import deferral


@dataclasses.dataclass(frozen=True)
class TierSpec:
    """One cascade level: an ensemble of k models + its deferral rule."""

    name: str
    rule: str  # 'vote' | 'score' | 'confidence' | 'entropy'
    theta: float
    k: int = 1
    cost: float = 1.0  # per-example cost in whatever unit the scenario uses


@dataclasses.dataclass
class CascadeResult:
    pred: np.ndarray  # (B,)
    tier_of: np.ndarray  # (B,) index of the answering tier
    scores: np.ndarray  # (B,) deferral score at the answering tier
    tier_counts: np.ndarray  # (n_tiers,) examples answered per tier
    evaluated: np.ndarray  # (n_tiers,) examples *evaluated* per tier
    cost: float  # total cost under the specs' per-example costs


def cascade_apply_dense(
    tier_fns: Sequence[Callable],
    specs: Sequence[TierSpec],
    batch,
):
    """Jit-friendly masked cascade.  Returns (pred, tier_of, scores)."""
    n = len(tier_fns)
    pred = None
    tier_of = None
    score_out = None
    decided = None
    for i, (fn, spec) in enumerate(zip(tier_fns, specs)):
        logits = fn(batch)
        out = deferral.apply_rule(spec.rule, logits, spec.theta)
        last = i == n - 1
        take = jnp.logical_or(~out.defer, jnp.bool_(last))
        if pred is None:
            pred = out.pred
            tier_of = jnp.zeros_like(out.pred)
            score_out = out.score
            decided = take
        else:
            newly = jnp.logical_and(~decided, take)
            pred = jnp.where(newly, out.pred, pred)
            tier_of = jnp.where(newly, i, tier_of)
            score_out = jnp.where(newly, out.score, score_out)
            decided = jnp.logical_or(decided, take)
    return pred, tier_of, score_out


def bucket_size(n: int, floor: int = 8) -> int:
    """Power-of-two batch bucket (>= floor).  Used everywhere a host-routed
    batch is padded before hitting a jitted program: bucketed shapes bound
    the number of distinct compilations to O(log B) instead of O(B)."""
    p = max(1, floor)
    while p < n:
        p *= 2
    return p


def bucket_chunks(n: int, floor: int = 8) -> List[int]:
    """Greedy power-of-two decomposition of a batch of ``n`` examples into
    bucket-shaped chunks (each a power-of-two multiple of ``floor``).

    This is how deferred examples are re-batched between tiers: every chunk
    shape comes from an O(log B) bucket set (so tier transitions re-enter
    already-compiled programs), while total padding stays < ``2 * floor``
    (a single covering bucket could waste ~2x the batch in padding, which
    would show up directly in the Prop 4.1.2 cost accounting)."""
    sizes: List[int] = []
    rem = n
    while rem > 0:
        c = max(1, floor)
        while c * 2 <= rem:
            c *= 2
        sizes.append(c)  # the last chunk may overshoot rem (that is padding)
        rem -= c
    return sizes


def prompt_chunks(n: int, max_chunk: int = 256) -> List[int]:
    """Exact power-of-two cover of ``n`` prompt tokens (largest-first).

    Chunked-prefill admission (serve/slot_stream.py) consumes a prompt
    prefix through per-bucket jitted prefill programs; every chunk size here
    comes from the O(log S) set {1, 2, 4, ..., max_chunk}, so after warmup
    no admission ever traces a new program.  Unlike ``bucket_chunks`` (batch
    re-padding, where overshoot is just padded rows), prompt chunks must
    tile EXACTLY — a padded prompt token would write a bogus KV row /
    advance SSM state — so the tail reuses ``bucket_chunks`` with floor 1,
    which is the plain binary decomposition and never overshoots."""
    sizes: List[int] = []
    while n >= max_chunk:
        sizes.append(max_chunk)
        n -= max_chunk
    if n > 0:
        sizes.extend(bucket_chunks(n, floor=1))
    return sizes


def _pad_rows(x, n):
    if x.shape[0] == n:
        return x
    pad = [(0, n - x.shape[0])] + [(0, 0)] * (x.ndim - 1)
    return np.pad(x, pad, mode="edge")


def cascade_apply_routed(
    tier_fns: Sequence[Callable],
    specs: Sequence[TierSpec],
    batch: dict,
    *,
    pad_to: int = 8,
) -> CascadeResult:
    """Host-routed cascade with batch compaction between tiers.

    ``batch`` is a dict of numpy/jax arrays with a leading example axis.
    Only deferred examples flow to the next tier, re-batched into greedy
    power-of-two bucket chunks (floor ``pad_to``, see ``bucket_chunks``) so
    tier transitions re-enter already-compiled programs instead of
    triggering one compilation per deferred-count.  Cost accounting:
    spec.cost · examples evaluated (the chunk padding is charged too — that
    is the real serving cost).
    """
    B = int(jax.tree.leaves(batch)[0].shape[0])
    n = len(tier_fns)
    pred = np.zeros((B,), np.int32)
    tier_of = np.full((B,), -1, np.int32)
    scores = np.zeros((B,), np.float32)
    tier_counts = np.zeros((n,), np.int64)
    evaluated = np.zeros((n,), np.int64)
    cost = 0.0

    active = np.arange(B)
    cur = {k: np.asarray(v) for k, v in batch.items()}
    for i, (fn, spec) in enumerate(zip(tier_fns, specs)):
        m = len(active)
        defer_c, p_c, s_c = [], [], []
        charged = 0
        off = 0
        for c in bucket_chunks(m, pad_to):
            take = min(c, m - off)
            fed = {k: _pad_rows(v[off : off + take], c) for k, v in cur.items()}
            logits = fn(fed)
            out = deferral.apply_rule(spec.rule, logits, spec.theta)
            defer_c.append(np.asarray(out.defer)[:take])
            p_c.append(np.asarray(out.pred)[:take])
            s_c.append(np.asarray(out.score)[:take])
            charged += c
            off += take
        defer = np.concatenate(defer_c)
        p = np.concatenate(p_c)
        s = np.concatenate(s_c)
        evaluated[i] = charged
        cost += spec.cost * charged

        last = i == n - 1
        take = ~defer | last
        idx = active[take]
        pred[idx] = p[take]
        tier_of[idx] = i
        scores[idx] = s[take]
        tier_counts[i] = take.sum()

        if last or not (~take).any():
            break
        keep = ~take
        active = active[keep]
        cur = {k: v[:m][keep] for k, v in cur.items()}

    return CascadeResult(
        pred=pred,
        tier_of=tier_of,
        scores=scores,
        tier_counts=tier_counts,
        evaluated=evaluated,
        cost=cost,
    )
