"""Stacked-weight ensembles.

An ensemble H^k is k models of the *same* config whose parameters are
stacked along a leading 'ensemble' logical axis.  The member forward is a
single ``vmap``, which realizes the paper's ρ=1 (fully parallel) execution
structurally; on the multi-pod mesh the 'ensemble' axis maps to the 'pod'
mesh axis so each pod holds one member and agreement is the only cross-pod
collective (DESIGN.md §3).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import api
from repro.models.params import Box, is_box, unbox


def init_ensemble(cfg: ModelConfig, k: int, rng: jax.Array):
    """Boxed params with a leading 'ensemble' axis on every leaf."""
    keys = jax.random.split(rng, k)
    stacked = jax.vmap(lambda r: api.init_params(cfg, r))(keys)
    return jax.tree.map(
        lambda b: Box(b.value, ("ensemble",) + b.axes), stacked, is_leaf=is_box
    )


def ensemble_logits(values, batch, cfg: ModelConfig, *, window_override=None):
    """Full-sequence logits for every member: (E, B, S, V)."""
    return jax.vmap(
        lambda p: api.forward_logits(p, batch, cfg, window_override=window_override)
    )(values)


def ensemble_last_logits(values, batch, cfg: ModelConfig):
    """Last-token (classification-head) logits per member: (E, B, V)."""
    def one(p):
        logits, _ = api.prefill(p, batch, cfg)
        return logits

    return jax.vmap(one)(values)


def ensemble_prefill(values, batch, cfg: ModelConfig):
    """Vmapped prompt prefill for every member: the batch is shared, the
    parameters carry the leading ensemble axis.  Returns
    (logits (E, B, V), caches with a leading ensemble axis on every leaf)."""
    return jax.vmap(lambda p: api.prefill(p, batch, cfg))(values)


def ensemble_decode_step(values, token, caches, pos, cfg: ModelConfig):
    """Vmapped decode step; caches and per-member tokens carry a leading
    ensemble axis (token (E, B, 1) — members diverge once they sample).
    ``pos`` is shared (scalar or per-slot (B,) vector).  Returns
    (logits (E, B, V), new caches)."""
    return jax.vmap(
        lambda p, t, c: api.decode_step(p, t, c, pos, cfg)
    )(values, token, caches)


def member_count(values) -> int:
    return jax.tree.leaves(values)[0].shape[0]


def take_member(values, i: int):
    return jax.tree.map(lambda v: v[i], values)
