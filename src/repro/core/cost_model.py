"""ABC cost model (paper §4.1, §4.4, §5.2) + the paper's published cost
constants, kept verbatim so the dollar/latency tables reproduce offline.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

# ---------------------------------------------------------------------------
# Eq. 1 and Prop 4.1.2
# ---------------------------------------------------------------------------


def ensemble_cost(c0: float, k: int, rho: float) -> float:
    """C(H^k) = c0 · k^(1-ρ): ρ=1 fully parallel, ρ=0 sequential."""
    assert 0.0 <= rho <= 1.0 and k >= 1
    return c0 * k ** (1.0 - rho)


def two_level_expected_cost(
    gamma: float, k: int, rho: float, defer_rate: float, c_large: float = 1.0
) -> float:
    """Prop 4.1.2: E[C(M_r)] = (k^ρ·γ + P(r=1)) · C(h2).

    Note k^ρ·γ = C(H1^k)/C(h2) with C(h1)=γ·C(h2)·k^... (the paper folds
    k^(1-ρ)·k·γ/k = k^... ; equivalently ensemble_cost(γ·c2, k, ρ)/c2 — the
    identity k^(1-ρ)·γ = k^ρ·γ/k^(2ρ-1) only matches the paper's k^ρ·γ form
    when the per-member cost is c0 = γ·C(h2)·k^(2ρ-1).  We follow the
    paper's printed formula exactly."""
    return (k**rho * gamma + defer_rate) * c_large


def fraction_cost_saved(
    gamma: float, k: int, rho: float, selection_rate: float
) -> float:
    """Fig. 3: 1 - E[C]/C(h2) with E[C] from ensemble_cost semantics:
    lower tier always runs (cost k^(1-ρ)·γ·C), large model runs on deferrals.
    """
    lower = ensemble_cost(gamma, k, rho)
    expected = lower + (1.0 - selection_rate)
    return 1.0 - expected


def multi_tier_expected_cost(
    tier_costs: Sequence[float],
    ks: Sequence[int],
    rho: float,
    reach_probs: Sequence[float],
) -> float:
    """E[C] = Σ_i P(reach tier i) · C_i(k_i, ρ)."""
    assert len(tier_costs) == len(ks) == len(reach_probs)
    return float(
        sum(
            p * ensemble_cost(c, k, rho)
            for c, k, p in zip(tier_costs, ks, reach_probs)
        )
    )


# ---------------------------------------------------------------------------
# Published constants (paper Tables 1 & 4, §5.2.1 delay grid)
# ---------------------------------------------------------------------------

# Table 4 — Lambda Cloud GPU rental (USD/hour, September 2024)
LAMBDA_GPU_PRICES = {"V100": 0.50, "A6000": 0.80, "A100": 1.29, "H100": 2.49}

# §5.2.1 — edge-to-cloud delay grid (seconds)
EDGE_DELAYS = {"local_ipc": 1e-6, "small": 10e-3, "medium": 100e-3, "large": 1.0}

# Table 1 — Together.ai serverless pricing (USD per million tokens)
TOGETHER_PRICES = {
    "llama3.1-8b-instruct-turbo": 0.18,
    "gemma2-9b-it": 0.30,
    "llama3-8b-instruct-lite": 0.10,
    "llama3.1-70b-instruct-turbo": 0.88,
    "gemma2-27b-instruct": 0.80,
    "qwen2-72b-instruct": 0.90,
    "llama3.1-405b-instruct-turbo": 5.00,
}

API_TIERS = {
    1: ["llama3.1-8b-instruct-turbo", "gemma2-9b-it", "llama3-8b-instruct-lite"],
    2: ["llama3.1-70b-instruct-turbo", "gemma2-27b-instruct", "qwen2-72b-instruct"],
    3: ["llama3.1-405b-instruct-turbo"],
}

# TPU v5e roofline constants (§Roofline)
TPU_V5E = {
    "peak_flops_bf16": 197e12,  # FLOP/s per chip
    "hbm_bw": 819e9,  # B/s per chip
    "ici_bw": 50e9,  # B/s per link
}


@dataclasses.dataclass(frozen=True)
class EdgeCloudCost:
    """§5.2.1 cost model: the response latency is dominated by the
    edge->cloud delay paid only on deferral; on-device inference pays
    local IPC."""

    delay: float  # seconds per deferred request
    local: float = 1e-6

    def mean_latency(self, defer_rate: float, edge_compute: float = 0.0) -> float:
        return edge_compute + self.local + defer_rate * self.delay


def gpu_rental_cost(
    tier_gpus: Sequence[str], tier_fracs: Sequence[float]
) -> float:
    """§5.2.2: Σ fraction-of-requests-served · GPU $/hour per tier.
    (Paper Table 5 'Total GPU Cost' columns.)"""
    return float(
        sum(LAMBDA_GPU_PRICES[g] * f for g, f in zip(tier_gpus, tier_fracs))
    )


def api_cost_per_query(
    tier_prices: Sequence[float],
    reach_probs: Sequence[float],
    tokens_per_query: float = 1000.0,
) -> float:
    """§5.2.3: expected $ per query; every reached tier's members are billed."""
    return float(
        sum(p * c * tokens_per_query / 1e6 for c, p in zip(tier_prices, reach_probs))
    )
