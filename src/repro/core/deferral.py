"""Deferral rules.

The paper's two agreement flavors (§4.3):
  r_v (Eq. 3) — vote: defer when the majority vote fraction <= θ_v
                (black-box: needs only each member's prediction)
  r_s (Eq. 4) — score: defer when the mean majority-class probability <= θ_s
                (white-box: needs member logits)

Baselines (§2.1):
  confidence (Wisdom-of-Committees-style): single model max-softmax <= θ
  entropy: defer when predictive entropy >= θ

Every rule maps example-level statistics to a boolean defer mask (True =
send to the next tier) plus the prediction the tier would emit if selected.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.agreement import ops as agree_ops


@dataclasses.dataclass(frozen=True)
class RuleOutput:
    pred: jax.Array  # (B,) int32 tier prediction
    score: jax.Array  # (B,) f32 the statistic s(x)
    defer: jax.Array  # (B,) bool r(x)=1


def vote_rule(logits: jax.Array, theta: float) -> RuleOutput:
    """Eq. 3 on member logits (E, B, V)."""
    stats = agree_ops.agreement(logits)
    s = stats["vote_frac"]
    return RuleOutput(pred=stats["pred"], score=s, defer=s <= theta)


def vote_rule_from_preds(preds: jax.Array, theta: float) -> RuleOutput:
    """Eq. 3 black-box flavor: preds (E, B) are member answers (e.g. sampled
    generations mapped to canonical ids).  No logits needed."""
    E = preds.shape[0]
    votes = (preds[:, None, :] == preds[None, :, :]).sum(axis=0)  # (E, B)
    # canonical tie-break (as in kernels/agreement): max votes, smallest id
    vmax = jnp.max(votes, axis=0, keepdims=True)
    pred = jnp.min(jnp.where(votes == vmax, preds, jnp.int32(2**30)), axis=0)
    s = vmax[0].astype(jnp.float32) / E
    return RuleOutput(pred=pred, score=s, defer=s <= theta)


def score_rule(logits: jax.Array, theta: float) -> RuleOutput:
    """Eq. 4 on member logits (E, B, V)."""
    stats = agree_ops.agreement(logits)
    s = stats["mean_score"]
    return RuleOutput(pred=stats["pred"], score=s, defer=s <= theta)


def confidence_rule(logits: jax.Array, theta: float) -> RuleOutput:
    """WoC-style single-model confidence; logits (B, V) or (1, B, V)."""
    if logits.ndim == 3:
        logits = logits[0]
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    s = jnp.max(probs, axis=-1)
    return RuleOutput(
        pred=jnp.argmax(logits, axis=-1).astype(jnp.int32), score=s, defer=s <= theta
    )


def entropy_rule(logits: jax.Array, theta: float) -> RuleOutput:
    """Defer when predictive entropy (normalized to [0,1]) >= theta.
    Score is 1 - normalized entropy so that 'higher score = more confident'
    matches the other rules."""
    if logits.ndim == 3:
        logits = logits.mean(axis=0)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ent = -jnp.sum(jnp.exp(logp) * logp, axis=-1) / jnp.log(logits.shape[-1])
    s = 1.0 - ent
    return RuleOutput(
        pred=jnp.argmax(logits, axis=-1).astype(jnp.int32), score=s, defer=s <= theta
    )


def _margin_rule(logits, theta):
    from repro.core.router_baselines import margin_rule

    return margin_rule(logits, theta)


RULES = {
    "vote": vote_rule,
    "score": score_rule,
    "confidence": confidence_rule,
    "entropy": entropy_rule,
    "margin": _margin_rule,
    # black-box Eq. 3 on member answer ids (E, B) — the serving ``generate``
    # mode routes through this; registered here, not at call time
    "vote_preds": vote_rule_from_preds,
}


def apply_rule(kind: str, logits: jax.Array, theta: float) -> RuleOutput:
    return RULES[kind](logits, theta)
