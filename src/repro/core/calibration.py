"""Threshold estimation (paper Appendix B).

Given validation scores s(x) and correctness indicators for a tier, pick the
smallest θ whose plug-in failure-rate estimate

    p̂(θ) = (1/n) Σ 1[s(x_i) > θ ∧ wrong_i]

is ≤ ε.  Smallest feasible θ maximizes the selection rate P(s > θ) while
keeping the rule safe (Def. 4.1).  The paper shows ~100 samples suffice
(Fig. 6); the benchmark bench_threshold.py reproduces that stability curve.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np


def failure_rate(scores: np.ndarray, correct: np.ndarray, theta: float) -> float:
    """p̂(θ) = P(select ∧ wrong) with selection s > θ."""
    scores = np.asarray(scores, np.float64)
    correct = np.asarray(correct, bool)
    return float(np.mean((scores > theta) & ~correct))


def selection_rate(scores: np.ndarray, theta: float) -> float:
    return float(np.mean(np.asarray(scores, np.float64) > theta))


def estimate_threshold(
    scores: np.ndarray,
    correct: np.ndarray,
    epsilon: float,
    *,
    n_samples: Optional[int] = None,
    seed: int = 0,
) -> Tuple[float, dict]:
    """Returns (theta, info).  If no feasible θ exists the rule degenerates
    to 'always defer' (θ = 1.0, selection rate 0) — still safe."""
    scores = np.asarray(scores, np.float64)
    correct = np.asarray(correct, bool)
    if n_samples is not None and n_samples < len(scores):
        idx = np.random.default_rng(seed).choice(
            len(scores), size=n_samples, replace=False
        )
        scores, correct = scores[idx], correct[idx]

    # candidate thresholds: just below each distinct score (plus extremes)
    cand = np.unique(scores)
    cands = np.concatenate([[-np.inf], (cand[1:] + cand[:-1]) / 2.0, cand, [1.0]])
    cands = np.unique(cands)
    best_theta, best_sel = 1.0, 0.0
    for theta in cands:
        if failure_rate(scores, correct, theta) <= epsilon:
            sel = selection_rate(scores, theta)
            if sel > best_sel or (sel == best_sel and theta < best_theta):
                best_theta, best_sel = float(theta), sel
    info = {
        "selection_rate": best_sel,
        "failure_rate": failure_rate(scores, correct, best_theta),
        "n": len(scores),
        "epsilon": epsilon,
    }
    return best_theta, info


def threshold_stability_curve(
    scores: np.ndarray,
    correct: np.ndarray,
    epsilon: float,
    sample_sizes=(100, 200, 400, 800, 1600, 3200),
    seed: int = 0,
):
    """Fig. 6: θ̂ as a function of the number of calibration samples."""
    out = []
    for n in sample_sizes:
        if n > len(scores):
            break
        theta, info = estimate_threshold(
            scores, correct, epsilon, n_samples=n, seed=seed
        )
        out.append({"n": n, "theta": theta, **info})
    return out
