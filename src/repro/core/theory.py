"""Theory quantities (Prop 4.1, Appendix A) used by the property tests and
EXPERIMENTS.md validation.

All functions operate on empirical arrays so the tests can check the
theorem's *inequalities* hold exactly on finite samples where the proof's
decomposition is an identity.
"""
from __future__ import annotations

import numpy as np


def risk(pred: np.ndarray, y: np.ndarray) -> float:
    return float(np.mean(np.asarray(pred) != np.asarray(y)))


def cascade_risk_decomposition(
    small_pred: np.ndarray,
    large_pred: np.ndarray,
    defer: np.ndarray,
    y: np.ndarray,
):
    """R(M_r) = P(r=0, H1≠y) + P(r=1, h2≠y)  (proof of Prop 4.1.1)."""
    defer = np.asarray(defer, bool)
    t1 = np.mean(~defer & (small_pred != y))
    t2 = np.mean(defer & (large_pred != y))
    casc = np.where(defer, large_pred, small_pred)
    assert abs((t1 + t2) - risk(casc, y)) < 1e-12
    return float(t1), float(t2), risk(casc, y)


def safe_rule_epsilon(small_pred, defer, y) -> float:
    """ε̂ = P(r=0 ∧ H1 wrong) — the Def 4.1 failure mass."""
    defer = np.asarray(defer, bool)
    return float(np.mean(~defer & (np.asarray(small_pred) != np.asarray(y))))


def excess_risk(small_pred, large_pred, defer, y) -> float:
    """R_excess = R(M_r) - R(h2)  (Appendix A, Eq. 6)."""
    casc = np.where(np.asarray(defer, bool), large_pred, small_pred)
    return risk(casc, y) - risk(large_pred, y)


def excess_risk_identity(small_pred, large_pred, defer, y) -> float:
    """Appendix A Eq. 6:
    R_excess = (P(H1≠y | r=0) - P(h2≠y | r=0)) · P(r=0)."""
    defer = np.asarray(defer, bool)
    sel = ~defer
    if not sel.any():
        return 0.0
    p_sel = sel.mean()
    a = np.mean(np.asarray(small_pred)[sel] != np.asarray(y)[sel])
    b = np.mean(np.asarray(large_pred)[sel] != np.asarray(y)[sel])
    return float((a - b) * p_sel)


def admissible(small_pred, large_pred, defer, y) -> bool:
    """Def A.1: the cascade is admissible iff excess risk <= 0."""
    return excess_risk(small_pred, large_pred, defer, y) <= 1e-12
