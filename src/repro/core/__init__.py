"""Agreement-Based Cascading (ABC) — the paper's contribution.

ensemble.py     stacked-weight ensembles, vmapped member forward, and the
                ensemble-parallel ('ensemble' logical axis -> 'pod' mesh
                axis) mapping used by the multi-pod dry-run
deferral.py     the agreement deferral rules r_v (Eq. 3) / r_s (Eq. 4) and
                the score-based baselines (WoC confidence, entropy)
calibration.py  threshold estimation from ~100 validation samples (App. B)
cascade.py      cascade execution: fully-jitted masked form (lowerable on
                the production mesh) and host-routed compacting form (real
                savings; used by serve/)
cost_model.py   gamma / rho / Eq. 1 / Prop 4.1.2 cost accounting + the
                paper's published deployment cost tables
theory.py       Prop 4.1 / Appendix A quantities for the property tests
"""
from repro.core import calibration, cascade, cost_model, deferral, ensemble, theory

__all__ = [
    "calibration",
    "cascade",
    "cost_model",
    "deferral",
    "ensemble",
    "theory",
]
