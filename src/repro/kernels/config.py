"""Kernel implementation selector.

impl = 'xla'               chunked pure-jnp path (default; what the
                           multi-device dry-run lowers, since this container
                           compiles for CPU and the Pallas kernels target TPU)
impl = 'pallas_interpret'  Pallas kernel body executed in Python on CPU —
                           used by the correctness test sweeps
impl = 'pallas'            real TPU lowering (target hardware)
"""
from __future__ import annotations

import contextlib
import threading

_VALID = ("xla", "pallas", "pallas_interpret")


class _State(threading.local):
    def __init__(self):
        self.impl = "xla"


_STATE = _State()


def get_impl() -> str:
    return _STATE.impl


def set_impl(impl: str) -> None:
    if impl not in _VALID:
        raise ValueError(
            f"unknown kernel impl {impl!r}: expected one of {_VALID}"
        )
    _STATE.impl = impl


@contextlib.contextmanager
def use_impl(impl: str):
    prev = _STATE.impl
    set_impl(impl)
    try:
        yield
    finally:
        set_impl(prev)


def pallas_kwargs() -> dict:
    """kwargs forwarded to pl.pallas_call depending on the selected impl."""
    return {"interpret": get_impl() == "pallas_interpret"}


def tpu_compiler_params(**kwargs):
    """TPU compiler params across jax versions: the class was renamed
    TPUCompilerParams -> CompilerParams; build whichever this jax has."""
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    return cls(**kwargs)
