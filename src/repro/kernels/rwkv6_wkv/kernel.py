"""RWKV6 WKV — Pallas TPU kernel (chunked, data-dependent per-channel decay).

Grid (B, H, num_chunks); the chunk dimension is sequential and carries the
(D × D) state in VMEM scratch.

Tiling note (TPU adaptation recorded in DESIGN.md): unlike Mamba2's scalar
per-head decay, RWKV6 decays **per key channel**, so the intra-chunk decay
cannot be folded into an (L × L) matrix — the exact pairwise form is an
(L, L, D) tensor.  We keep the chunk short (L=32) so that tensor is a
256 KiB VMEM tile computed on the VPU, while the three big contractions
(A@V, r·e^{ecum}@S, (k·w)ᵀ@V) stay on the MXU.  A production variant would
sub-chunk at 16 with an FLA-style secondary decomposition; L=32 keeps the
kernel readable and is already ~L× fewer HBM round trips than the step scan.
All exponentials are of non-positive numbers.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import config as kcfg


def _wkv_kernel(
    r_ref,  # (1, 1, L, D)
    k_ref,
    v_ref,
    lw_ref,  # (1, 1, L, D) log decay
    u_ref,  # (1, D)
    s0_ref,  # (1, 1, D, D)
    y_ref,  # (1, 1, L, D)
    sT_ref,  # (1, 1, D, D)
    s_scr,  # (D, D) f32
    *,
    num_chunks: int,
    chunk: int,
):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        s_scr[...] = s0_ref[0, 0].astype(jnp.float32)

    L = chunk
    r = r_ref[0, 0].astype(jnp.float32)  # (L, D)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    lw = lw_ref[0, 0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)  # (D,)

    cum = jnp.cumsum(lw, axis=0)  # (L, D) inclusive
    ecum = cum - lw  # exclusive

    # pairwise decay (L, L, D) on the VPU; exponents <= 0
    diff = ecum[:, None, :] - cum[None, :, :]
    rows = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    tri = (cols < rows)[:, :, None]
    decay = jnp.where(tri, jnp.exp(jnp.where(tri, diff, 0.0)), 0.0)
    A = jnp.einsum("td,sd,tsd->ts", r, k, decay)
    diag = jnp.sum(r * u[None, :] * k, axis=-1)  # (L,)
    A = A + jnp.where(rows == cols, diag[:, None], 0.0)

    s = s_scr[...]
    y = jax.lax.dot_general(
        A, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    y = y + jax.lax.dot_general(
        r * jnp.exp(ecum), s, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    y_ref[0, 0, :, :] = y.astype(y_ref.dtype)

    w_end = jnp.exp(cum[-1:, :] - cum)  # (L, D)
    s_scr[...] = s * jnp.exp(cum[-1, :])[:, None] + jax.lax.dot_general(
        k * w_end, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(ic == num_chunks - 1)
    def _fin():
        sT_ref[0, 0, :, :] = s_scr[...]


@functools.partial(
    jax.jit, static_argnames=("chunk", "return_final_state", "interpret")
)
def wkv6_pallas(
    r: jax.Array,  # (B, S, H, D)
    k: jax.Array,
    v: jax.Array,
    logw: jax.Array,
    u: jax.Array,  # (H, D)
    *,
    chunk: int = 32,
    initial_state: Optional[jax.Array] = None,
    return_final_state: bool = False,
    interpret: bool = False,
):
    B, S, H, D = r.shape
    L = min(chunk, S)
    if S % L != 0:
        raise ValueError(
            f"wkv6 kernel chunking: S={S} is not divisible by chunk L={L} "
            f"(r shape {r.shape})"
        )
    nc = S // L
    tr = lambda a: a.astype(jnp.float32).transpose(0, 2, 1, 3)  # (B,H,S,D)
    s0 = (
        jnp.zeros((B, H, D, D), jnp.float32)
        if initial_state is None
        else initial_state.astype(jnp.float32)
    )
    kern = functools.partial(_wkv_kernel, num_chunks=nc, chunk=L)
    y, sT = pl.pallas_call(
        kern,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, L, D), lambda b, h, ic: (b, h, ic, 0)),
            pl.BlockSpec((1, 1, L, D), lambda b, h, ic: (b, h, ic, 0)),
            pl.BlockSpec((1, 1, L, D), lambda b, h, ic: (b, h, ic, 0)),
            pl.BlockSpec((1, 1, L, D), lambda b, h, ic: (b, h, ic, 0)),
            pl.BlockSpec((1, D), lambda b, h, ic: (h, 0)),
            pl.BlockSpec((1, 1, D, D), lambda b, h, ic: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, L, D), lambda b, h, ic: (b, h, ic, 0)),
            pl.BlockSpec((1, 1, D, D), lambda b, h, ic: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S, D), r.dtype),
            jax.ShapeDtypeStruct((B, H, D, D), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((D, D), jnp.float32)],
        compiler_params=kcfg.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(tr(r), tr(k), tr(v), tr(logw), u.astype(jnp.float32), s0)
    y = y.transpose(0, 2, 1, 3).astype(r.dtype)
    if return_final_state:
        return y, sT
    return y
