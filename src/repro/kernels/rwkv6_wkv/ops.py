"""Chunked RWKV6 WKV wrapper.

impl='xla': chunked linear-attention-with-decay in pure jnp.  Intra-chunk
uses the exact pairwise decay tensor exp(ecum_t - cum_s) (all exponents
<= 0 — stable for any data-dependent decay), inter-chunk carries the
(D x D) state through a lax.scan.  Chunk length defaults to 32 to bound the
(L, L, D) pairwise tensor; see kernel.py for the TPU tiling discussion.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import config as kcfg


def _chunk_wkv_body(u):
    def body(s, inp):
        r, k, v, logw = inp  # (B, H, L, D)
        L = r.shape[2]
        cum = jnp.cumsum(logw, axis=2)  # inclusive
        ecum = cum - logw  # exclusive: sum_{s<t}
        diff = ecum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,H,L,L,D)
        tri = jnp.tril(jnp.ones((L, L), bool), k=-1)[None, None, :, :, None]
        decay = jnp.where(tri, jnp.exp(jnp.where(tri, diff, 0.0)), 0.0)
        A = jnp.einsum("bhtd,bhsd,bhtsd->bhts", r, k, decay)
        diag = jnp.einsum("bhtd,hd,bhtd->bht", r, u, k)  # bonus-u self term
        A = A + diag[..., None] * jnp.eye(L)[None, None]
        y = jnp.einsum("bhts,bhsd->bhtd", A, v)
        y = y + jnp.einsum("bhtd,bhde->bhte", r * jnp.exp(ecum), s)
        w_end = jnp.exp(cum[:, :, -1:, :] - cum)  # (B,H,L,D)
        s = s * jnp.exp(cum[:, :, -1, :])[..., None] + jnp.einsum(
            "bhsd,bhse->bhde", k * w_end, v
        )
        return s, y

    return body


def _pad_seq(a, pad):
    return jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))


def _xla_wkv6(r, k, v, logw, u, *, chunk, initial_state, return_final_state):
    B, S, H, D = r.shape
    L = min(chunk, S)
    if S % L:
        # zero k/v and zero log-decay padding is exact: contributes nothing
        # to outputs and leaves the final state untouched
        pad = L - S % L
        out = _xla_wkv6(
            _pad_seq(r, pad), _pad_seq(k, pad), _pad_seq(v, pad),
            _pad_seq(logw, pad), u,
            chunk=chunk, initial_state=initial_state,
            return_final_state=return_final_state,
        )
        if return_final_state:
            return out[0][:, :S], out[1]
        return out[:, :S]
    nc = S // L
    rf, kf, vf = (a.astype(jnp.float32) for a in (r, k, v))
    lf = logw.astype(jnp.float32)
    uf = u.astype(jnp.float32)

    def chunked(a):  # (B,S,H,D) -> (nc, B, H, L, D)
        return a.reshape(B, nc, L, H, D).transpose(1, 0, 3, 2, 4)

    s0 = (
        jnp.zeros((B, H, D, D), jnp.float32)
        if initial_state is None
        else initial_state.astype(jnp.float32)
    )
    sT, yc = jax.lax.scan(
        _chunk_wkv_body(uf), s0, tuple(map(chunked, (rf, kf, vf, lf)))
    )
    y = yc.transpose(1, 0, 3, 2, 4).reshape(B, S, H, D).astype(r.dtype)
    if return_final_state:
        return y, sT
    return y


def wkv6(
    r: jax.Array,  # (B, S, H, D)
    k: jax.Array,
    v: jax.Array,
    logw: jax.Array,  # (B, S, H, D), <= 0
    u: jax.Array,  # (H, D)
    *,
    chunk: int = 32,
    initial_state: Optional[jax.Array] = None,
    return_final_state: bool = False,
):
    impl = kcfg.get_impl()
    if impl == "xla":
        return _xla_wkv6(
            r, k, v, logw, u,
            chunk=chunk,
            initial_state=initial_state,
            return_final_state=return_final_state,
        )
    from repro.kernels.rwkv6_wkv import kernel as _kernel

    return _kernel.wkv6_pallas(
        r, k, v, logw, u,
        chunk=chunk,
        initial_state=initial_state,
        return_final_state=return_final_state,
        interpret=(impl == "pallas_interpret"),
    )


def wkv6_step(r, k, v, logw, u, state):
    from repro.kernels.rwkv6_wkv import ref as _ref

    return _ref.wkv6_step_ref(r, k, v, logw, u, state)
