from repro.kernels.rwkv6_wkv import kernel, ops, ref

__all__ = ["kernel", "ops", "ref"]
