"""Naive per-step recurrence oracle for RWKV6 (Finch) WKV.

Per head with channel dim D (state S: D_k x D_v):
    y_t = r_tᵀ (S_{t-1} + diag(u) k_t v_tᵀ)
    S_t = diag(w_t) S_{t-1} + k_t v_tᵀ
with data-dependent per-channel decay w_t = exp(-exp(logw_t)) ∈ (0,1);
inputs carry logw directly as log(w_t) <= 0 for numerical clarity.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_ref(
    r: jax.Array,  # (B, S, H, D)
    k: jax.Array,  # (B, S, H, D)
    v: jax.Array,  # (B, S, H, D)
    logw: jax.Array,  # (B, S, H, D)  log decay, <= 0
    u: jax.Array,  # (H, D) bonus
    *,
    initial_state=None,  # (B, H, D, D)  [key, value]
    return_final_state: bool = False,
):
    B, S, H, D = r.shape
    rf, kf, vf = (a.astype(jnp.float32) for a in (r, k, v))
    wf = jnp.exp(logw.astype(jnp.float32))
    uf = u.astype(jnp.float32)

    s0 = (
        jnp.zeros((B, H, D, D), jnp.float32)
        if initial_state is None
        else initial_state.astype(jnp.float32)
    )

    def step(s, inp):
        rt, kt, vt, wt = inp  # (B,H,D)
        kv = jnp.einsum("bhi,bhj->bhij", kt, vt)
        y = jnp.einsum("bhi,bhij->bhj", rt, s + uf[None, :, :, None] * kv)
        s = s * wt[..., :, None] + kv
        return s, y

    xs = tuple(a.transpose(1, 0, 2, 3) for a in (rf, kf, vf, wf))
    sT, ys = jax.lax.scan(step, s0, xs)
    y = ys.transpose(1, 0, 2, 3).astype(r.dtype)
    if return_final_state:
        return y, sT
    return y


def wkv6_step_ref(r, k, v, logw, u, state):
    """Single decode step: all (B, H, D); state (B, H, D, D)."""
    rf, kf, vf = (a.astype(jnp.float32) for a in (r, k, v))
    wf = jnp.exp(logw.astype(jnp.float32))
    uf = u.astype(jnp.float32)
    kv = jnp.einsum("bhi,bhj->bhij", kf, vf)
    y = jnp.einsum("bhi,bhij->bhj", rf, state + uf[None, :, :, None] * kv)
    new = state * wf[..., :, None] + kv
    return y.astype(r.dtype), new
