"""Pallas TPU kernels for the compute hot spots ABC serves.

Each kernel package ships:
  kernel.py — ``pl.pallas_call`` + explicit BlockSpec VMEM tiling (TPU target)
  ops.py    — jit-friendly wrapper with an XLA (chunked pure-jnp) fallback;
              the multi-device dry-run uses the XLA path since this container
              lowers for CPU; the TPU path is selected via
              ``repro.kernels.config.set_impl('pallas')`` on real hardware
  ref.py    — naive pure-jnp oracle used by the allclose test sweeps

Kernels: flash_attention (prefill), decode_attention (GQA single-token),
mamba2_ssd (chunked state-space dual), rwkv6_wkv (data-dependent-decay
linear attention), agreement (ABC's ensemble vote/score reduce).
"""
from repro.kernels import config

__all__ = ["config"]
