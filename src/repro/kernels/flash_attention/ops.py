"""Dispatching wrapper for flash attention.

``flash_attention(q, k, v)`` with q (B, Sq, H, hd), kv (B, Sk, KVH, hd).

impl='xla' (default): chunked-softmax pure-jnp path — scan over query blocks
so peak memory is O(block_q · Sk) not O(Sq · Sk), and with a sliding window
the KV is dynamically sliced to O(window + block_q) per block, making SWA
prefill genuinely sub-quadratic.  This is the path the 512-device dry-run
lowers; GSPMD shards it like any einsum.

impl='pallas[_interpret]': the TPU kernel in kernel.py.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import config as kcfg
from repro.kernels.flash_attention import kernel as _kernel


def _xla_flash(
    q, k, v, *, causal, window, softcap, q_offset=0, block_q: int = 512,
    return_lse: bool = False, starts=None,
):
    B, Sq, H, hd = q.shape
    _, Sk, KVH, _ = k.shape
    G = H // KVH
    scale = 1.0 / math.sqrt(hd)
    block_q = min(block_q, Sq)
    if Sq % block_q != 0:
        raise ValueError(
            f"flash_attention xla path: Sq={Sq} is not divisible by "
            f"block_q={block_q} (q shape {q.shape})"
        )
    nq = Sq // block_q

    if window is not None:
        kv_len = min(Sk, window + block_q)
    else:
        kv_len = Sk

    qb = q.reshape(B, nq, block_q, KVH, G, hd).transpose(1, 0, 2, 3, 4, 5)

    def body(_, inp):
        iq, qblk = inp  # qblk: (B, bq, KVH, G, hd)
        q_start = iq * block_q + q_offset
        if window is not None and kv_len < Sk:
            start = jnp.clip(q_start - (window - 1), 0, Sk - kv_len)
        else:
            start = jnp.int32(0)
        k_sl = jax.lax.dynamic_slice_in_dim(k, start, kv_len, axis=1)
        v_sl = jax.lax.dynamic_slice_in_dim(v, start, kv_len, axis=1)
        s = jnp.einsum(
            "bqkgd,bskd->bkgqs",
            qblk.astype(jnp.float32) * scale,
            k_sl.astype(jnp.float32),
        )
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        rows = q_start + jnp.arange(block_q)[:, None]
        cols = start + jnp.arange(kv_len)[None, :]
        mask = jnp.ones((block_q, kv_len), bool)
        if causal:
            mask &= cols <= rows
        if window is not None:
            mask &= (rows - cols) < window
        if starts is not None:
            # left-pad carve-out: row b's tokens never attend before its
            # prompt start — a per-batch (B, bq, kv) mask
            maskb = mask[None] & (cols[None] >= starts[:, None, None])
            s = jnp.where(maskb[:, None, None], s, -1e30)
        elif causal or window is not None:
            s = jnp.where(mask[None, None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        if starts is not None:
            # fully-masked rows (pure left-padding) emit zeros, matching the
            # Pallas kernel's l == 0 carve-out and the ref oracle's NaN -> 0
            p = jnp.where(maskb[:, None, None], p, 0.0)
        o = jnp.einsum("bkgqs,bskd->bqkgd", p, v_sl.astype(jnp.float32))
        lse = jax.nn.logsumexp(s, axis=-1)  # (B, KVH, G, bq)
        return None, (o.astype(q.dtype), lse)

    _, (ob, lseb) = jax.lax.scan(body, None, (jnp.arange(nq), qb))
    out = ob.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, H, hd)
    if return_lse:
        # (nq, B, KVH, G, bq) -> (B, Sq, H)
        lse = lseb.transpose(1, 0, 4, 2, 3).reshape(B, Sq, H)
        return out, lse
    return out


# ---------------------------------------------------------------------------
# custom_vjp: flash-style chunked backward (O(block_q · Sk) memory — the
# (Sq × Sk) probability matrix is never materialized in either direction)
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _flash_diff(q, k, v, causal, window, softcap):
    return _xla_flash(q, k, v, causal=causal, window=window, softcap=softcap)


def _flash_diff_fwd(q, k, v, causal, window, softcap):
    out, lse = _xla_flash(
        q, k, v, causal=causal, window=window, softcap=softcap, return_lse=True
    )
    return out, (q, k, v, out, lse)


def _flash_diff_bwd(causal, window, softcap, res, do):
    """Chunked over q blocks; dk/dv accumulate in the scan carry.  Backward
    recomputes each block's logits from (q, k, lse) — the flash recipe."""
    q, k, v, out, lse = res
    B, Sq, H, hd = q.shape
    _, Sk, KVH, _ = k.shape
    G = H // KVH
    scale = 1.0 / math.sqrt(hd)
    block_q = min(512, Sq)
    nq = Sq // block_q

    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    # D_i = dO_i · O_i  (B, Sq, H)
    delta = jnp.sum(do.astype(jnp.float32) * out.astype(jnp.float32), axis=-1)

    def reshape_q(a, last):
        return a.reshape(B, nq, block_q, KVH, G, last).transpose(1, 0, 2, 3, 4, 5)

    qb = reshape_q(q.astype(jnp.float32), hd)
    dob = reshape_q(do.astype(jnp.float32), hd)
    deltab = delta.reshape(B, nq, block_q, KVH, G).transpose(1, 0, 2, 3, 4)
    lseb = lse.reshape(B, nq, block_q, KVH, G).transpose(1, 0, 2, 3, 4)

    def body(carry, inp):
        dk, dv = carry
        iq, qblk, doblk, dblk, lblk = inp
        q_start = iq * block_q
        s_raw = jnp.einsum("bqkgd,bskd->bkgqs", qblk * scale, kf)
        if softcap is not None:
            t = jnp.tanh(s_raw / softcap)
            s = softcap * t
            dcap = 1.0 - jnp.square(t)
        else:
            s = s_raw
            dcap = None
        rows = q_start + jnp.arange(block_q)[:, None]
        cols = jnp.arange(Sk)[None, :]
        mask = jnp.ones((block_q, Sk), bool)
        if causal:
            mask &= cols <= rows
        if window is not None:
            mask &= (rows - cols) < window
        if causal or window is not None:
            s = jnp.where(mask[None, None, None], s, -1e30)
        # P_ij = exp(s_ij - lse_i)
        p = jnp.exp(s - lblk.transpose(0, 2, 3, 1)[..., None])  # (B,K,G,bq,Sk)
        dvb = jnp.einsum("bkgqs,bqkgd->bskd", p, doblk)
        dp = jnp.einsum("bqkgd,bskd->bkgqs", doblk, vf)
        ds = p * (dp - dblk.transpose(0, 2, 3, 1)[..., None])
        if dcap is not None:
            ds = ds * dcap
        dqb = jnp.einsum("bkgqs,bskd->bqkgd", ds, kf) * scale
        dkb = jnp.einsum("bkgqs,bqkgd->bskd", ds, qblk) * scale
        return (dk + dkb, dv + dvb), dqb

    zeros = jnp.zeros((B, Sk, KVH, hd), jnp.float32)
    (dk, dv), dqb = jax.lax.scan(
        body, (zeros, zeros), (jnp.arange(nq), qb, dob, deltab, lseb)
    )
    dq = dqb.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, H, hd)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_flash_diff.defvjp(_flash_diff_fwd, _flash_diff_bwd)


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    q_offset: int = 0,
    starts: Optional[jax.Array] = None,
) -> jax.Array:
    """``starts`` (B,) int32, optional: per-request prompt starts for
    left-padded batches — row b attends no column < starts[b] (the serving
    engine's pad carve-out).  Inference-only (routes around the custom_vjp)
    and served on EVERY impl: the Pallas kernel carries starts via scalar
    prefetch and skips KV blocks wholly below a row's start, so left-padded
    continuous batching never falls back to XLA."""
    impl = kcfg.get_impl()
    if starts is not None:
        starts = jnp.asarray(starts, jnp.int32)
    if impl == "xla":
        if starts is not None:
            return _xla_flash(
                q, k, v, causal=causal, window=window, softcap=softcap,
                q_offset=q_offset, starts=starts,
            )
        if q_offset == 0:
            return _flash_diff(q, k, v, causal, window, softcap)
        return _xla_flash(
            q, k, v, causal=causal, window=window, softcap=softcap, q_offset=q_offset
        )
    if q_offset != 0:
        raise ValueError(
            f"flash_attention: q_offset={q_offset} is unsupported on the "
            f"Pallas path (impl={impl!r}) — the kernel assumes q starts at "
            "position 0; use impl='xla' for offset prefill"
        )
    qt = q.transpose(0, 2, 1, 3)  # (B, H, S, hd)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = _kernel.flash_attention_bhsd(
        qt,
        kt,
        vt,
        starts,
        causal=causal,
        window=window,
        softcap=softcap,
        interpret=(impl == "pallas_interpret"),
    )
    return out.transpose(0, 2, 1, 3)
