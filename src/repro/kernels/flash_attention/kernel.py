"""Flash attention (prefill) — Pallas TPU kernel.

Layout: q (B, H, Sq, hd), k/v (B, KVH, Sk, hd); GQA handled in the k/v
index_map (`h // group`) so grouped heads stream the same KV block from HBM
once per q-head — no expanded KV is ever materialized.

Tiling: (block_q × hd) query tile and (block_k × hd) KV tile live in VMEM;
the running max / denominator / accumulator live in VMEM scratch across the
sequential k-block grid dimension (online softmax).  block sizes default to
256×512 with hd in {64, 128} — MXU-aligned (multiples of 128 on the matmul
dims) and < 4 MiB of VMEM working set per core.

Supports: causal masking, sliding-window masking, logit soft-capping and
bidirectional (encoder) attention.  Fully-masked k-blocks are skipped with
``pl.when`` (structural work-skipping — this is where the sliding-window
sub-quadratic behaviour comes from).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import config as kcfg

NEG_INF = -1e30


def _flash_kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    m_scr,
    l_scr,
    acc_scr,
    *,
    scale: float,
    causal: bool,
    window: Optional[int],
    softcap: Optional[float],
    block_q: int,
    block_k: int,
    num_k_blocks: int,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = iq * block_q
    k_start = ik * block_k

    # Structural block skipping: causal blocks strictly above the diagonal
    # and blocks entirely left of the sliding window contribute nothing.
    relevant = jnp.bool_(True)
    if causal:
        relevant = jnp.logical_and(relevant, k_start <= q_start + block_q - 1)
    if window is not None:
        # newest k position needed for the oldest q row in this tile
        relevant = jnp.logical_and(
            relevant, k_start + block_k - 1 >= q_start - (window - 1)
        )

    @pl.when(relevant)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * scale  # (block_q, hd)
        k = k_ref[0, 0].astype(jnp.float32)  # (block_k, hd)
        v = v_ref[0, 0].astype(jnp.float32)  # (block_k, hd)

        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (block_q, block_k)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)

        rows = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = jnp.bool_(True)
        if causal:
            mask = jnp.logical_and(mask, cols <= rows)
        if window is not None:
            mask = jnp.logical_and(mask, rows - cols < window)
        if causal or window is not None:
            s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]  # (block_q, 1)
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)  # (block_q, block_k)
        l_new = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ik == num_k_blocks - 1)
    def _finalize():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)  # rows with no valid k (padding only)
        o_ref[0, 0, :, :] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "window", "softcap", "block_q", "block_k", "interpret"
    ),
)
def flash_attention_bhsd(
    q: jax.Array,  # (B, H, Sq, hd)
    k: jax.Array,  # (B, KVH, Sk, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    block_q: int = 256,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    B, H, Sq, hd = q.shape
    _, KVH, Sk, _ = k.shape
    group = H // KVH
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, Sk, block_q, block_k)
    nq, nk = Sq // block_q, Sk // block_k
    scale = 1.0 / math.sqrt(hd)

    kern = functools.partial(
        _flash_kernel,
        scale=scale,
        causal=causal,
        window=window,
        softcap=softcap,
        block_q=block_q,
        block_k=block_k,
        num_k_blocks=nk,
    )
    grid = (B, H, nq, nk)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec(
                (1, 1, block_k, hd), lambda b, h, iq, ik: (b, h // group, ik, 0)
            ),
            pl.BlockSpec(
                (1, 1, block_k, hd), lambda b, h, iq, ik: (b, h // group, ik, 0)
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, block_q, hd), lambda b, h, iq, ik: (b, h, iq, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        compiler_params=kcfg.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
