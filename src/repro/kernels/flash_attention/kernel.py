"""Flash attention (prefill) — Pallas TPU kernel.

Layout: q (B, H, Sq, hd), k/v (B, KVH, Sk, hd); GQA handled in the k/v
index_map (`h // group`) so grouped heads stream the same KV block from HBM
once per q-head — no expanded KV is ever materialized.

Tiling: (block_q × hd) query tile and (block_k × hd) KV tile live in VMEM;
the running max / denominator / accumulator live in VMEM scratch across the
sequential k-block grid dimension (online softmax).  block sizes default to
256×512 with hd in {64, 128} — MXU-aligned (multiples of 128 on the matmul
dims) and < 4 MiB of VMEM working set per core.

Supports: causal masking, sliding-window masking, logit soft-capping,
bidirectional (encoder) attention, and per-row ``starts`` (the serving
left-pad carve-out).  ``starts`` (B,) int32 rides scalar prefetch (SMEM),
so the per-request mask needs no recompilation per batch; row b never
attends a column < starts[b], and KV blocks wholly below starts[b] are
skipped together with the causal/window-irrelevant blocks via ``pl.when``
(structural work-skipping — left-padded prefill gets cheaper, not just
correct).  Rows that are pure padding (q row < starts[b]) produce zeros.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import config as kcfg

NEG_INF = -1e30


def _flash_kernel(
    starts_ref,  # scalar prefetch: (B,) int32 per-row prompt starts
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    m_scr,
    l_scr,
    acc_scr,
    *,
    scale: float,
    causal: bool,
    window: Optional[int],
    softcap: Optional[float],
    block_q: int,
    block_k: int,
    num_k_blocks: int,
    has_starts: bool,
    skip_pad_blocks: bool,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    # read once at kernel top level (pl.when bodies must not touch
    # program_id / prefetch refs in interpret mode on older jax)
    start_b = starts_ref[pl.program_id(0)] if has_starts else None

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = iq * block_q
    k_start = ik * block_k

    # Structural block skipping: causal blocks strictly above the diagonal,
    # blocks entirely left of the sliding window, and blocks wholly below
    # the row's prompt start (left-pad carve-out) contribute nothing.
    relevant = jnp.bool_(True)
    if causal:
        relevant = jnp.logical_and(relevant, k_start <= q_start + block_q - 1)
    if window is not None:
        # newest k position needed for the oldest q row in this tile
        relevant = jnp.logical_and(
            relevant, k_start + block_k - 1 >= q_start - (window - 1)
        )
    if has_starts and skip_pad_blocks:
        relevant = jnp.logical_and(relevant, k_start + block_k - 1 >= start_b)

    @pl.when(relevant)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * scale  # (block_q, hd)
        k = k_ref[0, 0].astype(jnp.float32)  # (block_k, hd)
        v = v_ref[0, 0].astype(jnp.float32)  # (block_k, hd)

        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (block_q, block_k)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)

        rows = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = jnp.bool_(True)
        if causal:
            mask = jnp.logical_and(mask, cols <= rows)
        if window is not None:
            mask = jnp.logical_and(mask, rows - cols < window)
        if has_starts:
            mask = jnp.logical_and(mask, cols >= start_b)
        if causal or window is not None or has_starts:
            s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]  # (block_q, 1)
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)  # (block_q, block_k)
        if has_starts:
            # fully-masked rows (pure left-padding) must stay at l == 0 so
            # _finalize emits zeros; without this, m_new == NEG_INF makes
            # exp(s - m_new) == 1 for every masked column
            p = jnp.where(mask, p, 0.0)
        l_new = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ik == num_k_blocks - 1)
    def _finalize():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)  # rows with no valid k (padding only)
        o_ref[0, 0, :, :] = (acc_scr[...] / l).astype(o_ref.dtype)


def starts_block_counts(
    Sq: int,
    Sk: int,
    starts,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    block_q: int = 256,
    block_k: int = 512,
):
    """(blocks_swept_with_skip, blocks_swept_without) per q/KV block pair,
    summed over the batch — a host-side mirror of ``_flash_kernel``'s exact
    ``relevant`` predicate, so the ratio is the kernel's structural
    block-skip win on a given starts pattern (deterministic, unlike
    interpret-mode wall clock on a shared CPU).  The skipped blocks are
    fully masked, so skip on/off is bitwise identical (tested)."""
    import numpy as np

    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    nq, nk = Sq // block_q, Sk // block_k
    q_start = np.arange(nq)[:, None] * block_q  # (nq, 1)
    k_start = np.arange(nk)[None, :] * block_k  # (1, nk)
    rel = np.ones((nq, nk), bool)
    if causal:
        rel &= k_start <= q_start + block_q - 1
    if window is not None:
        rel &= k_start + block_k - 1 >= q_start - (window - 1)
    starts = np.asarray(starts)
    with_skip = int(
        (rel[None] & (k_start[None] + block_k - 1 >= starts[:, None, None])).sum()
    )
    without = int(rel.sum()) * len(starts)
    return with_skip, without


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "window", "softcap", "block_q", "block_k", "interpret",
        "skip_pad_blocks",
    ),
)
def flash_attention_bhsd(
    q: jax.Array,  # (B, H, Sq, hd)
    k: jax.Array,  # (B, KVH, Sk, hd)
    v: jax.Array,
    starts: Optional[jax.Array] = None,  # (B,) int32 per-row prompt starts
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    block_q: int = 256,
    block_k: int = 512,
    interpret: bool = False,
    skip_pad_blocks: bool = True,
) -> jax.Array:
    """``starts`` rides scalar prefetch: None keeps the starts-free program
    (zeros are prefetched but never read).  ``skip_pad_blocks=False`` keeps
    the per-row mask but disables the below-start block skipping — the
    no-skip baseline bench_kernels measures the structural win against."""
    B, H, Sq, hd = q.shape
    _, KVH, Sk, _ = k.shape
    group = H // KVH
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    if Sq % block_q != 0 or Sk % block_k != 0:
        raise ValueError(
            f"flash kernel BlockSpec tiling: Sq={Sq}/Sk={Sk} must divide "
            f"block_q={block_q}/block_k={block_k} (q {q.shape}, k {k.shape})"
        )
    nq, nk = Sq // block_q, Sk // block_k
    scale = 1.0 / math.sqrt(hd)

    has_starts = starts is not None
    starts_arr = (
        jnp.asarray(starts, jnp.int32)
        if has_starts
        else jnp.zeros((B,), jnp.int32)
    )

    kern = functools.partial(
        _flash_kernel,
        scale=scale,
        causal=causal,
        window=window,
        softcap=softcap,
        block_q=block_q,
        block_k=block_k,
        num_k_blocks=nk,
        has_starts=has_starts,
        skip_pad_blocks=skip_pad_blocks,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, H, nq, nk),
        # index_maps receive the scalar-prefetch ref as a trailing argument
        in_specs=[
            pl.BlockSpec(
                (1, 1, block_q, hd), lambda b, h, iq, ik, starts: (b, h, iq, 0)
            ),
            pl.BlockSpec(
                (1, 1, block_k, hd),
                lambda b, h, iq, ik, starts: (b, h // group, ik, 0),
            ),
            pl.BlockSpec(
                (1, 1, block_k, hd),
                lambda b, h, iq, ik, starts: (b, h // group, ik, 0),
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, block_q, hd), lambda b, h, iq, ik, starts: (b, h, iq, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        compiler_params=kcfg.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(starts_arr, q, k, v)
