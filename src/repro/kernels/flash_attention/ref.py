"""Naive pure-jnp oracle for flash attention (materializes full logits)."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def attention_ref(
    q: jax.Array,  # (B, Sq, H, hd)
    k: jax.Array,  # (B, Sk, KVH, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    q_offset: int = 0,
    starts: Optional[jax.Array] = None,  # (B,) per-row prompt starts
) -> jax.Array:
    B, Sq, H, hd = q.shape
    _, Sk, KVH, _ = k.shape
    group = H // KVH
    qf = q.astype(jnp.float32) / math.sqrt(hd)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    # expand kv heads for the oracle (fine at test sizes)
    kf = jnp.repeat(kf, group, axis=2)
    vf = jnp.repeat(vf, group, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", qf, kf)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    rows = (jnp.arange(Sq) + q_offset)[:, None]
    cols = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= cols <= rows
    if window is not None:
        mask &= (rows - cols) < window
    if starts is not None:
        # left-pad carve-out: row b never attends a column < starts[b]
        maskb = mask[None] & (cols[None] >= jnp.asarray(starts)[:, None, None])
        s = jnp.where(maskb[:, None], s, -jnp.inf)
    else:
        s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)  # fully-masked rows
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vf)
    return out.astype(q.dtype)
