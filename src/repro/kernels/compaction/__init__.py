"""Deferral compaction kernels: defer mask → dense payload + index map,
entirely on device.

The tier-transition hot path (DESIGN.md §3 deferral data path): after a
tier votes, the deferred rows of the batch must become a dense payload for
the next tier WITHOUT the payload visiting the host — the host reads one
count scalar, and only the compacted payload (plus its i32 index map)
crosses the tier boundary's ``Transport`` hop.

Modules: ``kernel`` (Pallas TPU lowering — prefix-sum scatter expressed as
a one-hot MXU matmul), ``ops`` (dispatcher + XLA fallback + the exact
integer gather route; the public ``compact``/``compact_tree``/
``scatter_back`` API), ``ref`` (naive host-loop oracle for parity tests).
"""
