"""Deferral compaction — Pallas TPU kernel (ABC's tier-transition hot path).

Routing deferred examples to the next tier is a mask→prefix-sum→scatter:
row i of the payload moves to row ``cumsum(mask)[i]-mask[i]`` of a dense
output iff ``mask[i]``.  Doing this on host (np.flatnonzero + re-pad) drags
the whole activation payload across PCIe twice per tier transition; this
kernel keeps it in HBM.

A per-row dynamic scatter does not vectorize on the VPU, so the kernel
expresses the permutation as a one-hot selection matrix and rides the MXU:

  sel[d, i] = (prefix[i] == d) & mask[i]      # (B, B) one-hot rows
  out       = sel @ payload                   # (B, D) dense compaction

The feature axis D streams through VMEM in ``block_d`` tiles along the
grid; the (B, B) selection matrix is recomputed per tile from the (1, B)
mask — B is a serving batch (≤ a few thousand), so sel is tiny next to the
payload sweep and the payload itself is read exactly once from HBM.  The
first tile also emits the index map (original row index per output row,
-1 past the deferred count) and the scalar count.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import config as kcfg


def _compact_kernel(mask_ref, x_ref, out_ref, im_ref, cnt_ref):
    j = pl.program_id(0)
    m = mask_ref[...]  # (1, B) int32
    B = m.shape[1]
    prefix = jnp.cumsum(m, axis=1) - m  # (1, B) exclusive prefix sum
    d_iota = jax.lax.broadcasted_iota(jnp.int32, (B, B), 0)
    sel = jnp.logical_and(prefix == d_iota, m == 1)  # (B, B): dest d <- src i
    sel_f = sel.astype(jnp.float32)
    out_ref[...] = jnp.dot(
        sel_f, x_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(j == 0)
    def _emit_indices():
        i_iota = jax.lax.broadcasted_iota(jnp.int32, (B, B), 1)
        # one-hot rows: sum(sel * (i+1)) - 1 is the source index, -1 if empty
        src = jnp.sum(sel_f * (i_iota + 1).astype(jnp.float32), axis=1, keepdims=True)
        im_ref[...] = src.astype(jnp.int32) - 1  # (B, 1)
        cnt_ref[0, 0] = jnp.sum(m)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def compact_pallas(
    x: jax.Array,  # (B, D) float32 payload
    mask: jax.Array,  # (B,) bool / int32 defer mask
    *,
    block_d: int = 512,
    interpret: bool = False,
):
    """Dense compaction of deferred rows.  Returns (out (B, D) f32,
    index_map (B,) i32, count () i32).  B should be sublane-friendly and
    D lane-friendly — ops.py pads both before calling."""
    B, D = x.shape
    block_d = min(block_d, D)
    if D % block_d != 0:
        raise ValueError(
            f"compaction kernel BlockSpec tiling: D={D} is not divisible "
            f"by block_d={block_d} (payload {x.shape})"
        )
    nd = D // block_d
    m_row = mask.astype(jnp.int32).reshape(1, B)
    out, im, cnt = pl.pallas_call(
        _compact_kernel,
        grid=(nd,),
        in_specs=[
            pl.BlockSpec((1, B), lambda j: (0, 0)),
            pl.BlockSpec((B, block_d), lambda j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((B, block_d), lambda j: (0, j)),
            pl.BlockSpec((B, 1), lambda j: (0, 0)),
            pl.BlockSpec((1, 1), lambda j: (0, 0), memory_space=pltpu.SMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, D), jnp.float32),
            jax.ShapeDtypeStruct((B, 1), jnp.int32),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
        ],
        compiler_params=kcfg.tpu_compiler_params(
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
    )(m_row, x.astype(jnp.float32))
    return out, im[:, 0], cnt[0, 0]
