"""Naive oracle for deferral compaction.

Given a payload ``x`` (B, D) and a defer mask (B,), produce the dense
compacted payload: row ``d`` of the output is the ``d``-th deferred row of
``x`` (original order preserved), rows past the deferred count are zero.
Alongside it, the index map back into the original batch:

  out[d]        = x[index_map[d]]            for d <  count
  index_map[d]  = original row index         for d <  count, else -1
  count         = mask.sum()

Deliberately a host-side python row loop — clearly correct by inspection
and structurally unlike both the ops.py scatter form and the kernel's
one-hot matmul, so the parity tests compare three independent
implementations.  Shapes are static (out is (B, D)): the real paths jit,
and callers slice ``out[:bucket(count)]`` after reading only the count.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def compact_ref(x, mask):
    """x: (B, D...); mask: (B,) bool.  Returns (out (B, ...), index_map
    (B,), count) as device arrays computed by a naive host loop."""
    xs = np.asarray(x)
    ms = np.asarray(mask).astype(bool)
    B = xs.shape[0]
    out = np.zeros_like(xs)
    index_map = np.full((B,), -1, np.int32)
    d = 0
    for i in range(B):
        if ms[i]:
            out[d] = xs[i]
            index_map[d] = i
            d += 1
    return jnp.asarray(out), jnp.asarray(index_map), jnp.asarray(d, jnp.int32)


def scatter_back_ref(values, index_map, total: int):
    """Inverse of ``compact_ref`` for result rows: place ``values[d]`` at
    original index ``index_map[d]`` in a (total, ...) buffer (rows whose
    index_map is -1 are dropped).  Naive host loop."""
    vs = np.asarray(values)
    im = np.asarray(index_map)
    out = np.zeros((total,) + vs.shape[1:], vs.dtype)
    for d in range(vs.shape[0]):
        if im[d] >= 0:
            out[im[d]] = vs[d]
    return jnp.asarray(out)
