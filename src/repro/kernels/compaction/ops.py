"""Dispatching wrapper for deferral compaction.

``compact(x, mask)`` turns a defer mask into a dense compacted payload plus
an index map WITHOUT the payload ever visiting the host:

  out (B, ...)      rows [0, count) are the deferred rows of ``x`` in
                    original order; rows past the count are zero padding
  index_map (B,)    original row index per output row, -1 past the count
  count ()          number of deferred rows (the only thing a host-side
                    router ever needs to fetch)

``compact_tree`` applies the same mask to every leaf of a batch pytree (one
kernel pass per leaf — each leaf is read from HBM exactly once) and
``scatter_back`` is the inverse permutation for per-example results.
Float payloads ride the kernel's one-hot f32 matmul (exact for f32/bf16
inputs); integer payloads (token ids, hashes) are compacted by a device
row-gather over the kernel's index map instead, so they are exact at ANY
value — the f32 route would round above 2**24.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import config as kcfg


def _xla_compact(x2: jax.Array, mask: jax.Array):
    """Vectorized scatter form (what the multi-device dry-run lowers):
    every row writes either its prefix-sum destination or a sacrificial
    row B that is sliced off.  (ref.py holds the naive row-loop oracle.)"""
    B = x2.shape[0]
    m = mask.astype(jnp.int32)
    pos = jnp.cumsum(m) - m
    dst = jnp.where(mask, pos, B)
    out = jnp.zeros((B + 1, x2.shape[1]), x2.dtype).at[dst].set(x2)[:B]
    index_map = (
        jnp.full((B + 1,), -1, jnp.int32)
        .at[dst]
        .set(jnp.arange(B, dtype=jnp.int32))[:B]
    )
    return out, index_map, jnp.sum(m)


def _pad_to(n: int, mult: int) -> int:
    return max(mult, ((n + mult - 1) // mult) * mult)


def _kernel_compact(x2: jax.Array, mask: jax.Array, impl: str):
    from repro.kernels.compaction import kernel as _kernel

    B, D = x2.shape
    Bp, Dp = _pad_to(B, 8), _pad_to(D, 128)
    # block_d must divide the padded width; 128 always does
    block_d = max(b for b in (512, 256, 128) if Dp % b == 0)
    xp = jnp.pad(x2.astype(jnp.float32), ((0, Bp - B), (0, Dp - D)))
    mp = jnp.pad(mask.astype(jnp.int32), (0, Bp - B))
    out, index_map, count = _kernel.compact_pallas(
        xp, mp, block_d=block_d, interpret=(impl == "pallas_interpret")
    )
    return out[:B, :D], index_map[:B], count


def compact(x: jax.Array, mask: jax.Array):
    """x: (B, ...); mask: (B,) bool.  Returns (out, index_map, count) with
    ``out`` shaped and typed like ``x`` (deferred rows dense at the front,
    zeros past the count).  All three live on device."""
    B = x.shape[0]
    trail = x.shape[1:]
    D = int(np.prod(trail)) if trail else 1
    x2 = x.reshape(B, D)
    impl = kcfg.get_impl()
    if impl == "xla":
        out, index_map, count = _xla_compact(x2, mask)
    elif jnp.issubdtype(x.dtype, jnp.integer):
        # exact integer route: index map from the kernel, payload rows by
        # device gather (the f32 matmul would round values >= 2**24)
        index_map, count = compact_indices(mask)
        out = _gather_rows(x2, index_map)
    else:
        out, index_map, count = _kernel_compact(x2, mask, impl)
        out = out.astype(x.dtype)
    return out.reshape((B,) + trail), index_map, count


def compact_indices(mask: jax.Array):
    """(index_map (B,), count ()) without touching any payload — the
    kernel runs on a 1-wide dummy column (integer leaves route through
    this, then gather their rows exactly)."""
    impl = kcfg.get_impl()
    dummy = jnp.zeros((mask.shape[0], 1), jnp.float32)
    if impl == "xla":
        _, index_map, count = _xla_compact(dummy, mask)
    else:
        _, index_map, count = _kernel_compact(dummy, mask, impl)
    return index_map, count


def gather_rows(x: jax.Array, index_map: jax.Array):
    """Row-gather over a precomputed index map: out[i] = x[index_map[i]],
    zero rows where index_map[i] < 0.  Exact for every dtype.  The output
    has index_map's row count — callers may gather more or fewer rows than
    ``x`` holds (the paged-KV view gathers per-slot page lists out of a
    shared pool)."""
    trail = x.shape[1:]
    x2 = x.reshape(x.shape[0], int(np.prod(trail)) if trail else 1)
    safe = jnp.where(index_map >= 0, index_map, 0)
    out = jnp.where((index_map >= 0)[:, None], x2[safe], 0)
    return out.reshape((index_map.shape[0],) + trail)


def _gather_rows(x: jax.Array, index_map: jax.Array):
    """Compacted payload by device row-gather over a precomputed index map
    (exact for every dtype; rows past the count come out zero)."""
    return gather_rows(x, index_map)


def compact_tree(tree, mask: jax.Array):
    """Compact every (B, ...) leaf of ``tree`` under one defer mask.
    Returns (compacted tree, index_map (B,), count).

    Float leaves take the kernel's single-HBM-pass matmul route, whose
    first pass yields the index map as a free byproduct; integer leaves
    gather through that shared map (exact at any value).  The dedicated
    dummy-column index pass only runs for an all-integer tree."""
    leaves, treedef = jax.tree.flatten(tree)
    outs = [None] * len(leaves)
    index_map = count = None
    for i, leaf in enumerate(leaves):
        if not jnp.issubdtype(leaf.dtype, jnp.integer):
            outs[i], index_map, count = compact(leaf, mask)
    if index_map is None:
        index_map, count = compact_indices(mask)
    for i, leaf in enumerate(leaves):
        if outs[i] is None:
            outs[i] = _gather_rows(leaf, index_map)
    return treedef.unflatten(outs), index_map, count


def scatter_back(values: jax.Array, index_map: jax.Array, total: int):
    """Place compacted per-example results back at their original rows:
    ``out[index_map[d]] = values[d]`` for every d with index_map[d] >= 0.
    A (B,)-sized scatter, not a feature sweep — plain XLA on every impl."""
    dst = jnp.where(index_map >= 0, index_map, total)
    out = jnp.zeros((total + 1,) + values.shape[1:], values.dtype)
    return out.at[dst].set(values)[:total]
