"""Naive pure-jnp oracle for single-token GQA decode attention."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def decode_attention_ref(
    q: jax.Array,  # (B, 1, H, hd)
    k_cache: jax.Array,  # (B, S, KVH, hd)
    v_cache: jax.Array,
    cur_len,  # scalar or (B,): number of valid cache positions
    *,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    starts: Optional[jax.Array] = None,  # (B,) per-row prompt starts
) -> jax.Array:
    B, _, H, hd = q.shape
    _, S, KVH, _ = k_cache.shape
    G = H // KVH
    qf = q.astype(jnp.float32) / math.sqrt(hd)
    kf = jnp.repeat(k_cache.astype(jnp.float32), G, axis=2)
    vf = jnp.repeat(v_cache.astype(jnp.float32), G, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", qf, kf)  # (B, H, 1, S)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    cols = jnp.arange(S)
    cur = jnp.broadcast_to(jnp.asarray(cur_len), (B,))
    mask = cols[None, :] < cur[:, None]  # (B, S); supports per-sequence lens
    if window is not None:
        mask &= cols[None, :] >= (cur - window)[:, None]
    if starts is not None:
        # left-pad carve-out: row b never attends a cache column < starts[b]
        mask &= cols[None, :] >= jnp.asarray(starts)[:, None]
    s = jnp.where(mask[:, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)  # fully-masked rows (pure padding)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vf)
    return out.astype(q.dtype)


def decode_attention_paged_ref(
    q: jax.Array,  # (B, 1, H, hd)
    k_pool: jax.Array,  # (P, KVH, page_size, hd) shared page pool
    v_pool: jax.Array,
    pages: jax.Array,  # (B, n_pg) int32 page table, -1 = unmapped
    cur_len,  # scalar or (B,)
    *,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
) -> jax.Array:
    """Paged oracle: gather each slot's pages into a dense (B, S, KVH, hd)
    view (unmapped entries as zero rows) and defer to the dense oracle."""
    P, KVH, ps, hd = k_pool.shape
    B, n_pg = pages.shape
    safe = jnp.where(pages >= 0, pages, 0)
    mapped = (pages >= 0)[:, :, None, None, None]
    k = jnp.where(mapped, k_pool[safe], 0)  # (B, n_pg, KVH, ps, hd)
    v = jnp.where(mapped, v_pool[safe], 0)
    k_view = k.transpose(0, 1, 3, 2, 4).reshape(B, n_pg * ps, KVH, hd)
    v_view = v.transpose(0, 1, 3, 2, 4).reshape(B, n_pg * ps, KVH, hd)
    return decode_attention_ref(
        q, k_view, v_view, cur_len, window=window, softcap=softcap
    )
