"""Naive pure-jnp oracle for single-token GQA decode attention."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def decode_attention_ref(
    q: jax.Array,  # (B, 1, H, hd)
    k_cache: jax.Array,  # (B, S, KVH, hd)
    v_cache: jax.Array,
    cur_len,  # scalar or (B,): number of valid cache positions
    *,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    starts: Optional[jax.Array] = None,  # (B,) per-row prompt starts
) -> jax.Array:
    B, _, H, hd = q.shape
    _, S, KVH, _ = k_cache.shape
    G = H // KVH
    qf = q.astype(jnp.float32) / math.sqrt(hd)
    kf = jnp.repeat(k_cache.astype(jnp.float32), G, axis=2)
    vf = jnp.repeat(v_cache.astype(jnp.float32), G, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", qf, kf)  # (B, H, 1, S)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    cols = jnp.arange(S)
    cur = jnp.broadcast_to(jnp.asarray(cur_len), (B,))
    mask = cols[None, :] < cur[:, None]  # (B, S); supports per-sequence lens
    if window is not None:
        mask &= cols[None, :] >= (cur - window)[:, None]
    if starts is not None:
        # left-pad carve-out: row b never attends a cache column < starts[b]
        mask &= cols[None, :] >= jnp.asarray(starts)[:, None]
    s = jnp.where(mask[:, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)  # fully-masked rows (pure padding)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vf)
    return out.astype(q.dtype)
