"""Dispatching wrapper for decode attention.

``decode_attention(q, k_cache, v_cache, cur_len)`` — q (B, 1, H, hd),
caches (B, S, KVH, hd), cur_len a (traced) scalar count of valid positions.

impl='xla': masked full-cache sweep — linear in S, shardable; the KV-cache
sequence dim carries the 'kv_seq' logical axis so GSPMD keeps the sweep
distributed (partial softmax + all-reduce over the sharded seq axis).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import config as kcfg
from repro.kernels.decode_attention import kernel as _kernel


def _xla_decode(q, k_cache, v_cache, cur_len, *, window, softcap):
    B, _, H, hd = q.shape
    _, S, KVH, _ = k_cache.shape
    G = H // KVH
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(B, KVH, G, hd).astype(jnp.float32) * scale
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache.astype(jnp.float32))
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    cols = jnp.arange(S)
    cur = jnp.broadcast_to(jnp.asarray(cur_len), (B,))
    mask = cols[None, :] < cur[:, None]  # (B, S); supports per-sequence lens
    if window is not None:
        mask &= cols[None, :] >= (cur - window)[:, None]
    s = jnp.where(mask[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, H, hd).astype(q.dtype)


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    cur_len,
    *,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
) -> jax.Array:
    impl = kcfg.get_impl()
    if impl == "xla":
        return _xla_decode(
            q, k_cache, v_cache, cur_len, window=window, softcap=softcap
        )
    kt = k_cache.transpose(0, 2, 1, 3)  # (B, KVH, S, hd)
    vt = v_cache.transpose(0, 2, 1, 3)
    return decode_attention_bksd(
        q, kt, vt, cur_len=cur_len, window=window, softcap=softcap
    )


def _xla_decode_bksd(q, k_cache, v_cache, cur_len, *, window, softcap, starts=None):
    B, _, H, hd = q.shape
    KVH, S = k_cache.shape[1], k_cache.shape[2]
    G = H // KVH
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(B, KVH, G, hd).astype(jnp.float32) * scale
    s = jnp.einsum("bkgd,bksd->bkgs", qg, k_cache.astype(jnp.float32))
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    cols = jnp.arange(S)
    cur = jnp.asarray(cur_len)
    if cur.ndim == 0:  # scalar: shared position
        mask = (cols < cur)[None, :]
    else:  # (B,): per-slot positions (continuous batching)
        mask = cols[None, :] < cur[:, None]
    if window is not None:
        lo = (cur - window)[..., None] if cur.ndim else cur - window
        mask = mask & (cols[None, :] >= lo)
    if starts is not None:  # left-pad carve-out (per-request prompt starts)
        mask = mask & (cols[None, :] >= jnp.asarray(starts)[:, None])
    s = jnp.where(mask[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    if starts is not None:
        # rows whose start swallows the whole valid cache emit zeros —
        # matching the Pallas kernel's l == 0 path and the ref oracle
        p = jnp.where(mask[:, None, None, :], p, 0.0)
    out = jnp.einsum("bkgs,bksd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, H, hd).astype(q.dtype)


def _pool_view(pool, pages):
    """(B, KVH, n_pg * page_size, hd) per-slot contiguous view of a paged
    pool — unmapped (-1) table entries come out as zero rows."""
    from repro.kernels.compaction.ops import gather_rows

    P, KVH, ps, hd = pool.shape
    B, n_pg = pages.shape
    rows = gather_rows(pool, pages.reshape(-1))
    return (
        rows.reshape(B, n_pg, KVH, ps, hd)
        .transpose(0, 2, 1, 3, 4)
        .reshape(B, KVH, n_pg * ps, hd)
    )


def _xla_decode_paged(q, k_pool, v_pool, pages, cur_len, *, window, softcap):
    """Gathered-view route: materialize each slot's (KVH, S, hd) view and
    run the dense bksd sweep over it.  The view is exactly max_seq rows, so
    the reduction is bitwise the dense cache's."""
    k_view = _pool_view(k_pool, pages)
    v_view = _pool_view(v_pool, pages)
    return _xla_decode_bksd(
        q, k_view, v_view, cur_len, window=window, softcap=softcap
    )


def decode_attention_paged(
    q: jax.Array,  # (B, 1, H, hd)
    k_pool: jax.Array,  # (P, KVH, page_size, hd) shared page pool
    v_pool: jax.Array,
    pages: jax.Array,  # (B, n_pg) int32 page table, -1 = unmapped
    cur_len,  # (B,) per-slot valid lengths
    *,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
) -> jax.Array:
    """Decode attention against block-paged KV pools.  On the kernel impls
    the page table rides scalar prefetch and the pool is streamed page by
    page (no gathered cache copy); the XLA impl gathers the per-slot view
    and reuses the dense masked sweep."""
    if k_pool.shape != v_pool.shape:
        raise ValueError(f"pool mismatch: k {k_pool.shape} v {v_pool.shape}")
    if pages.shape[0] != q.shape[0]:
        raise ValueError(
            f"page table {pages.shape} does not match batch {q.shape[0]}"
        )
    impl = kcfg.get_impl()
    if impl == "xla":
        return _xla_decode_paged(
            q, k_pool, v_pool, pages, cur_len, window=window, softcap=softcap
        )
    B, _, H, hd = q.shape
    KVH = k_pool.shape[1]
    G = H // KVH
    qk = q.reshape(B, KVH, G, hd)
    out = _kernel.decode_attention_paged_bkgd(
        qk,
        k_pool,
        v_pool,
        jnp.asarray(cur_len, jnp.int32),
        jnp.asarray(pages, jnp.int32),
        window=window,
        softcap=softcap,
        interpret=(impl == "pallas_interpret"),
    )
    return out.reshape(B, 1, H, hd)


def decode_attention_bksd(
    q: jax.Array,  # (B, 1, H, hd)
    k_cache: jax.Array,  # (B, KVH, S, hd)  kernel-native layout
    v_cache: jax.Array,
    cur_len,
    *,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    starts: Optional[jax.Array] = None,
) -> jax.Array:
    """Decode attention over caches stored sequence-innermost — the layout
    the Pallas kernel streams directly, so no per-step transpose of the full
    cache exists on any path (§Perf iteration 1).  ``starts`` (B,) masks
    cache columns before each request's prompt start (left-padded batches)
    and is served on EVERY impl: the Pallas kernel carries it via scalar
    prefetch and skips cache blocks wholly below a row's start, so
    left-padded continuous batching never leaves the kernel path."""
    impl = kcfg.get_impl()
    if impl == "xla":
        return _xla_decode_bksd(
            q, k_cache, v_cache, cur_len, window=window, softcap=softcap,
            starts=starts,
        )
    B, _, H, hd = q.shape
    KVH = k_cache.shape[1]
    G = H // KVH
    qk = q.reshape(B, KVH, G, hd)
    out = _kernel.decode_attention_bkgd(
        qk,
        k_cache,
        v_cache,
        jnp.asarray(cur_len, jnp.int32),
        None if starts is None else jnp.asarray(starts, jnp.int32),
        window=window,
        softcap=softcap,
        interpret=(impl == "pallas_interpret"),
    )
    return out.reshape(B, 1, H, hd)
