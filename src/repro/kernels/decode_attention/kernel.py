"""GQA decode attention — Pallas TPU kernel.

One query token per sequence attends over a long KV cache.  The cache is
streamed through VMEM in (block_k × hd) tiles along the sequential grid
dimension; online-softmax accumulators live in VMEM scratch.  All G query
heads of a KV head are processed together, so the logits matmul is
(G × hd) @ (hd × block_k) — G·hd and block_k are the MXU dims (hd ∈ {64,128},
block_k a multiple of 512).

``cur_len`` and ``starts`` are runtime scalars delivered via scalar prefetch
(SMEM) so the masks need no recompilation per step.  Blocks entirely past
``cur_len``, before the sliding window, or wholly below a row's prompt start
(``starts`` — the serving left-pad carve-out) are skipped with ``pl.when``
— the sweep cost is O(cur_len - start), or O(window) with SWA.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import config as kcfg

NEG_INF = -1e30


def _decode_kernel(
    len_ref,  # scalar prefetch: (B,) int32  valid cache length per sequence
    starts_ref,  # scalar prefetch: (B,) int32  per-row prompt starts
    q_ref,  # (1, 1, G, hd)
    k_ref,  # (1, 1, block_k, hd)
    v_ref,  # (1, 1, block_k, hd)
    o_ref,  # (1, 1, G, hd)
    m_scr,  # (G, 1) f32
    l_scr,  # (G, 1) f32
    acc_scr,  # (G, hd) f32
    *,
    scale: float,
    window: Optional[int],
    softcap: Optional[float],
    block_k: int,
    num_k_blocks: int,
    has_starts: bool,
    skip_pad_blocks: bool,
):
    ik = pl.program_id(2)
    cur_len = len_ref[pl.program_id(0)]  # per-sequence (continuous batching)
    start_b = starts_ref[pl.program_id(0)] if has_starts else None

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    k_start = ik * block_k
    relevant = k_start < cur_len
    if window is not None:
        relevant = jnp.logical_and(relevant, k_start + block_k > cur_len - window)
    if has_starts and skip_pad_blocks:
        # left-pad carve-out: cache blocks wholly below the row's prompt
        # start hold only pad rows — skip them structurally
        relevant = jnp.logical_and(relevant, k_start + block_k > start_b)

    @pl.when(relevant)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * scale  # (G, hd)
        k = k_ref[0, 0].astype(jnp.float32)  # (block_k, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (G, block_k)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = cols < cur_len
        if window is not None:
            mask = jnp.logical_and(mask, cols >= cur_len - window)
        if has_starts:
            mask = jnp.logical_and(mask, cols >= start_b)
        s = jnp.where(mask, s, NEG_INF)

        m_prev, l_prev = m_scr[...], l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        if has_starts:
            # a row whose start swallows the whole valid cache must keep
            # l == 0 (zeros out), not exp(NEG_INF - NEG_INF) == 1 weights
            p = jnp.where(mask, p, 0.0)
        l_scr[...] = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(ik == num_k_blocks - 1)
    def _finalize():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0, :, :] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "softcap", "interpret"))
def decode_attention_paged_bkgd(
    q: jax.Array,  # (B, KVH, G, hd)
    k_pool: jax.Array,  # (P, KVH, page_size, hd) shared page pool
    v_pool: jax.Array,
    cur_len: jax.Array,  # (B,) int32
    pages: jax.Array,  # (B, n_pg) int32 page table, -1 = unmapped
    *,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    interpret: bool = False,
) -> jax.Array:
    """Block-paged decode attention: the SAME online-softmax body as
    ``decode_attention_bkgd`` with block_k = page_size and the page table
    riding the second scalar-prefetch slot.  Instead of streaming a dense
    per-slot cache, the K/V index maps dereference ``pages[b, ik]`` so each
    sequential grid step pulls the slot's ik-th page straight out of the
    shared pool — no gathered copy of the cache ever materializes.  Blocks
    past ``cur_len`` (or before the sliding window) are skipped exactly as
    in the dense kernel; an unmapped page with in-length columns can only
    belong to an inactive slot (allocation is a monotone prefix of the
    sequence), whose output the server discards, so the clamped page-0
    fetch is harmless."""
    B, KVH, G, hd = q.shape
    P, KVHp, page_size, hdp = k_pool.shape
    if k_pool.shape != v_pool.shape:
        raise ValueError(f"pool mismatch: k {k_pool.shape} v {v_pool.shape}")
    if (KVHp, hdp) != (KVH, hd):
        raise ValueError(f"pool {k_pool.shape} does not match q {q.shape}")
    if pages.shape[0] != B:
        raise ValueError(f"page table {pages.shape} does not match batch {B}")
    n_pg = pages.shape[1]
    if page_size % 8 != 0:
        raise ValueError(
            f"paged decode BlockSpec tiling: page_size={page_size} is not a "
            f"multiple of the f32 sublane (8); pool {k_pool.shape}"
        )
    scale = 1.0 / math.sqrt(hd)

    # pages_ref occupies the starts slot of the shared body; has_starts=False
    # means it is only ever read by the index maps below
    kern = functools.partial(
        _decode_kernel,
        scale=scale,
        window=window,
        softcap=softcap,
        block_k=page_size,
        num_k_blocks=n_pg,
        has_starts=False,
        skip_pad_blocks=False,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KVH, n_pg),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h, ik, lens, pages: (b, h, 0, 0)),
            pl.BlockSpec(
                (1, 1, page_size, hd),
                lambda b, h, ik, lens, pages: (jnp.maximum(pages[b, ik], 0), h, 0, 0),
            ),
            pl.BlockSpec(
                (1, 1, page_size, hd),
                lambda b, h, ik, lens, pages: (jnp.maximum(pages[b, ik], 0), h, 0, 0),
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, G, hd), lambda b, h, ik, lens, pages: (b, h, 0, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    lens = jnp.broadcast_to(jnp.asarray(cur_len, jnp.int32), (B,))
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KVH, G, hd), q.dtype),
        compiler_params=kcfg.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(lens, jnp.asarray(pages, jnp.int32), q, k_pool, v_pool)


def starts_block_counts(
    S: int,
    cur_len,
    starts,
    *,
    window: Optional[int] = None,
    block_k: int = 512,
):
    """(blocks_swept_with_skip, blocks_swept_without) summed over the
    batch — a host-side mirror of ``_decode_kernel``'s exact ``relevant``
    predicate; the ratio is the structural block-skip win of the left-pad
    carve-out on a given (cur_len, starts) pattern (deterministic, unlike
    interpret-mode wall clock).  Skipped blocks are fully masked, so skip
    on/off is bitwise identical (tested)."""
    import numpy as np

    block_k = min(block_k, S)
    nk = S // block_k
    k_start = np.arange(nk)[None, :] * block_k  # (1, nk)
    cur = np.broadcast_to(np.asarray(cur_len), np.asarray(starts).shape)
    rel = k_start < cur[:, None]
    if window is not None:
        rel &= k_start + block_k > cur[:, None] - window
    with_skip = int((rel & (k_start + block_k > np.asarray(starts)[:, None])).sum())
    return with_skip, int(rel.sum())


@functools.partial(
    jax.jit,
    static_argnames=("window", "softcap", "block_k", "interpret", "skip_pad_blocks"),
)
def decode_attention_bkgd(
    q: jax.Array,  # (B, KVH, G, hd)
    k_cache: jax.Array,  # (B, KVH, S, hd)
    v_cache: jax.Array,
    cur_len: jax.Array,  # scalar or (B,) int32
    starts: Optional[jax.Array] = None,  # (B,) int32 per-row prompt starts
    *,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    block_k: int = 512,
    interpret: bool = False,
    skip_pad_blocks: bool = True,
) -> jax.Array:
    """``starts`` rides a second scalar-prefetch ref: None keeps the
    starts-free program (zeros are prefetched but never read).
    ``skip_pad_blocks=False`` keeps the per-row mask but disables the
    below-start block skipping (bench_kernels' no-skip baseline)."""
    B, KVH, G, hd = q.shape
    S = k_cache.shape[2]
    block_k = min(block_k, S)
    if S % block_k != 0:
        raise ValueError(
            f"decode kernel BlockSpec tiling: cache S={S} is not divisible "
            f"by block_k={block_k} (k_cache {k_cache.shape})"
        )
    nk = S // block_k
    scale = 1.0 / math.sqrt(hd)

    has_starts = starts is not None
    kern = functools.partial(
        _decode_kernel,
        scale=scale,
        window=window,
        softcap=softcap,
        block_k=block_k,
        num_k_blocks=nk,
        has_starts=has_starts,
        skip_pad_blocks=skip_pad_blocks,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KVH, nk),
        # index_maps receive the scalar-prefetch refs as trailing arguments
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h, ik, lens, starts: (b, h, 0, 0)),
            pl.BlockSpec(
                (1, 1, block_k, hd), lambda b, h, ik, lens, starts: (b, h, ik, 0)
            ),
            pl.BlockSpec(
                (1, 1, block_k, hd), lambda b, h, ik, lens, starts: (b, h, ik, 0)
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, G, hd), lambda b, h, ik, lens, starts: (b, h, 0, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    lens = jnp.broadcast_to(jnp.asarray(cur_len, jnp.int32), (B,))
    starts_arr = (
        jnp.broadcast_to(jnp.asarray(starts, jnp.int32), (B,))
        if has_starts
        else jnp.zeros((B,), jnp.int32)
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KVH, G, hd), q.dtype),
        compiler_params=kcfg.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(lens, starts_arr, q, k_cache, v_cache)
