"""GQA decode attention — Pallas TPU kernel.

One query token per sequence attends over a long KV cache.  The cache is
streamed through VMEM in (block_k × hd) tiles along the sequential grid
dimension; online-softmax accumulators live in VMEM scratch.  All G query
heads of a KV head are processed together, so the logits matmul is
(G × hd) @ (hd × block_k) — G·hd and block_k are the MXU dims (hd ∈ {64,128},
block_k a multiple of 512).

``cur_len`` is a runtime scalar (how much of the cache is valid) delivered
via scalar prefetch (SMEM) so the mask needs no recompilation per step, and
blocks entirely past ``cur_len`` (or before the sliding window) are skipped
with ``pl.when`` — the sweep cost is O(cur_len), or O(window) with SWA.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import config as kcfg

NEG_INF = -1e30


def _decode_kernel(
    len_ref,  # scalar prefetch: (B,) int32  valid cache length per sequence
    q_ref,  # (1, 1, G, hd)
    k_ref,  # (1, 1, block_k, hd)
    v_ref,  # (1, 1, block_k, hd)
    o_ref,  # (1, 1, G, hd)
    m_scr,  # (G, 1) f32
    l_scr,  # (G, 1) f32
    acc_scr,  # (G, hd) f32
    *,
    scale: float,
    window: Optional[int],
    softcap: Optional[float],
    block_k: int,
    num_k_blocks: int,
):
    ik = pl.program_id(2)
    cur_len = len_ref[pl.program_id(0)]  # per-sequence (continuous batching)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    k_start = ik * block_k
    relevant = k_start < cur_len
    if window is not None:
        relevant = jnp.logical_and(relevant, k_start + block_k > cur_len - window)

    @pl.when(relevant)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * scale  # (G, hd)
        k = k_ref[0, 0].astype(jnp.float32)  # (block_k, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (G, block_k)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = cols < cur_len
        if window is not None:
            mask = jnp.logical_and(mask, cols >= cur_len - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev, l_prev = m_scr[...], l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_scr[...] = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(ik == num_k_blocks - 1)
    def _finalize():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0, :, :] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("window", "softcap", "block_k", "interpret"),
)
def decode_attention_bkgd(
    q: jax.Array,  # (B, KVH, G, hd)
    k_cache: jax.Array,  # (B, KVH, S, hd)
    v_cache: jax.Array,
    cur_len: jax.Array,  # scalar int32
    *,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    B, KVH, G, hd = q.shape
    S = k_cache.shape[2]
    block_k = min(block_k, S)
    assert S % block_k == 0
    nk = S // block_k
    scale = 1.0 / math.sqrt(hd)

    kern = functools.partial(
        _decode_kernel,
        scale=scale,
        window=window,
        softcap=softcap,
        block_k=block_k,
        num_k_blocks=nk,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, KVH, nk),
        # index_maps receive the scalar-prefetch ref as a trailing argument
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h, ik, lens: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, ik, lens: (b, h, ik, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, ik, lens: (b, h, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, ik, lens: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    lens = jnp.broadcast_to(jnp.asarray(cur_len, jnp.int32), (B,))
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KVH, G, hd), q.dtype),
        compiler_params=kcfg.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(lens, q, k_cache, v_cache)
