"""Mamba2 SSD — Pallas TPU kernel (chunked state-space dual).

Grid (B, H, num_chunks); the chunk dimension is sequential ('arbitrary') and
carries the (N × P) recurrent state in VMEM scratch.  Per chunk the work is
three MXU matmuls — C@Bᵀ (L×L), scores@X (L×P), Bwᵀ@X (N×P) — over an
(L × max(N,P)) VMEM tile, L=128/256, N,P ∈ {64,128}: all matmul dims are
multiples of the 128-lane MXU tile (P=64 uses half-tile packing).

Numerics: every exponential is of a non-positive cumulative log-decay, so
the dual form is stable at any chunk length.  Inputs arrive pre-scaled
(x~ = dt·x, l = A·dt) from ops.py so the kernel streams four operands.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import config as kcfg


def _ssd_kernel(
    x_ref,  # (1, 1, L, P)   x~ = dt * x
    l_ref,  # (1, 1, L, 1)   l = A * dt  (<= 0)
    b_ref,  # (1, 1, L, N)
    c_ref,  # (1, 1, L, N)
    h0_ref,  # (1, 1, N, P)  initial state
    y_ref,  # (1, 1, L, P)
    hT_ref,  # (1, 1, N, P)  final state
    h_scr,  # (N, P) f32
    *,
    num_chunks: int,
    chunk: int,
):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_scr[...] = h0_ref[0, 0].astype(jnp.float32)

    x = x_ref[0, 0].astype(jnp.float32)  # (L, P)
    l = l_ref[0, 0].astype(jnp.float32)  # (L, 1)
    b = b_ref[0, 0].astype(jnp.float32)  # (L, N)
    c = c_ref[0, 0].astype(jnp.float32)  # (L, N)

    cum = jnp.cumsum(l, axis=0)  # (L, 1)
    total = cum[-1:, :]  # (1, 1)

    # intra-chunk
    g = jax.lax.dot_general(
        c, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (L, L)
    diff = cum - cum.T  # (L, L): cum_t - cum_s
    rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    tri = cols <= rows
    decay = jnp.where(tri, jnp.exp(jnp.where(tri, diff, 0.0)), 0.0)
    y = jax.lax.dot_general(
        g * decay, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (L, P)

    # inter-chunk (contribution of the carried state)
    h = h_scr[...]
    y = y + jax.lax.dot_general(
        c * jnp.exp(cum), h, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    y_ref[0, 0, :, :] = y.astype(y_ref.dtype)

    # state update
    w = jnp.exp(total - cum)  # (L, 1)
    h_scr[...] = h * jnp.exp(total[0, 0]) + jax.lax.dot_general(
        b * w, x, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (N, P)

    @pl.when(ic == num_chunks - 1)
    def _fin():
        hT_ref[0, 0, :, :] = h_scr[...]


@functools.partial(
    jax.jit, static_argnames=("chunk", "return_final_state", "interpret")
)
def ssd_pallas(
    x: jax.Array,  # (B, S, H, P)
    dt: jax.Array,  # (B, S, H)
    A: jax.Array,  # (H,)
    Bm: jax.Array,  # (B, S, G, N)
    Cm: jax.Array,  # (B, S, G, N)
    *,
    chunk: int = 128,
    initial_state: Optional[jax.Array] = None,
    return_final_state: bool = False,
    interpret: bool = False,
):
    B, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    L = min(chunk, S)
    if S % L != 0:
        raise ValueError(
            f"ssd kernel chunking: S={S} is not divisible by chunk L={L} "
            f"(x shape {x.shape})"
        )
    nc = S // L

    xt = (x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None]).transpose(
        0, 2, 1, 3
    )  # (B, H, S, P)
    lt = (A.astype(jnp.float32)[None, None, :] * dt.astype(jnp.float32)).transpose(
        0, 2, 1
    )[..., None]  # (B, H, S, 1)
    bt = Bm.astype(jnp.float32).transpose(0, 2, 1, 3)  # (B, G, S, N)
    ct = Cm.astype(jnp.float32).transpose(0, 2, 1, 3)
    h0 = (
        jnp.zeros((B, H, N, P), jnp.float32)
        if initial_state is None
        else initial_state.astype(jnp.float32)
    )

    kern = functools.partial(_ssd_kernel, num_chunks=nc, chunk=L)
    y, hT = pl.pallas_call(
        kern,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, L, P), lambda b, h, ic: (b, h, ic, 0)),
            pl.BlockSpec((1, 1, L, 1), lambda b, h, ic: (b, h, ic, 0)),
            pl.BlockSpec((1, 1, L, N), lambda b, h, ic: (b, h // rep, ic, 0)),
            pl.BlockSpec((1, 1, L, N), lambda b, h, ic: (b, h // rep, ic, 0)),
            pl.BlockSpec((1, 1, N, P), lambda b, h, ic: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, L, P), lambda b, h, ic: (b, h, ic, 0)),
            pl.BlockSpec((1, 1, N, P), lambda b, h, ic: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        compiler_params=kcfg.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(xt, lt, bt, ct, h0)
    y = y.transpose(0, 2, 1, 3)  # (B, S, H, P)
    if return_final_state:
        return y, hT
    return y
