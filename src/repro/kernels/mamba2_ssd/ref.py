"""Naive per-step recurrence oracle for the Mamba2 SSD.

Recurrence (per batch b, head h):
    a_t = exp(A_h * dt_t)                                (scalar decay)
    H_t = a_t * H_{t-1} + dt_t * B_t x_t^T               (H: N x P)
    y_t = C_t^T H_t                                      (P,)
with B_t, C_t in R^N shared across the heads of a group.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(
    x: jax.Array,  # (B, S, H, P)
    dt: jax.Array,  # (B, S, H)   positive step sizes
    A: jax.Array,  # (H,)        negative
    Bm: jax.Array,  # (B, S, G, N)
    Cm: jax.Array,  # (B, S, G, N)
    *,
    initial_state=None,  # (B, H, N, P)
    return_final_state: bool = False,
):
    B, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = jnp.repeat(Bm.astype(jnp.float32), rep, axis=2)  # (B, S, H, N)
    Cf = jnp.repeat(Cm.astype(jnp.float32), rep, axis=2)
    Af = A.astype(jnp.float32)

    h0 = (
        jnp.zeros((B, H, N, P), jnp.float32)
        if initial_state is None
        else initial_state.astype(jnp.float32)
    )

    def step(h, inp):
        xt, dtt, bt, ct = inp  # (B,H,P), (B,H), (B,H,N), (B,H,N)
        a = jnp.exp(Af[None] * dtt)  # (B,H)
        h = h * a[..., None, None] + jnp.einsum("bhn,bhp->bhnp", bt, xt * dtt[..., None])
        y = jnp.einsum("bhn,bhnp->bhp", ct, h)
        return h, y

    xs = (
        xf.transpose(1, 0, 2, 3),
        dtf.transpose(1, 0, 2),
        Bf.transpose(1, 0, 2, 3),
        Cf.transpose(1, 0, 2, 3),
    )
    hT, ys = jax.lax.scan(step, h0, xs)
    y = ys.transpose(1, 0, 2, 3).astype(x.dtype)  # (B, S, H, P)
    if return_final_state:
        return y, hT
    return y


def ssd_step_ref(x, dt, A, Bm, Cm, state):
    """Single decode step. x (B,H,P), dt (B,H), Bm/Cm (B,G,N),
    state (B,H,N,P) -> (y, new_state)."""
    H = x.shape[1]
    G = Bm.shape[1]
    rep = H // G
    Bf = jnp.repeat(Bm.astype(jnp.float32), rep, axis=1)
    Cf = jnp.repeat(Cm.astype(jnp.float32), rep, axis=1)
    a = jnp.exp(A.astype(jnp.float32)[None] * dt.astype(jnp.float32))
    new = state * a[..., None, None] + jnp.einsum(
        "bhn,bhp->bhnp", Bf, x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None]
    )
    y = jnp.einsum("bhn,bhnp->bhp", Cf, new)
    return y.astype(x.dtype), new
