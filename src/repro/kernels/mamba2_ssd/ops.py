"""Chunked SSD (state-space dual) wrapper.

impl='xla': the chunked dual form in pure jnp — intra-chunk attention-like
matmuls + an inter-chunk state scan.  O(S·L) work with chunk L, vectorized
over (batch, heads) so GSPMD shards it along 'data'/'model' like everything
else.  This is also exactly the math the Pallas kernel implements, with the
state scan living in VMEM scratch instead of a lax.scan carry.

All exponentials are of non-positive numbers (cumulative log-decays), so the
chunked form is numerically safe at any chunk length.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import config as kcfg


def _chunk_quantities(l_chunk):
    """l_chunk: (..., L) per-step log decays (<= 0).
    Returns (cum, total) where cum[t] = sum_{s<=t} l_s."""
    cum = jnp.cumsum(l_chunk, axis=-1)
    total = cum[..., -1:]
    return cum, total


def _xla_ssd(x, dt, A, Bm, Cm, *, chunk, initial_state, return_final_state):
    B, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    L = min(chunk, S)
    if S % L:
        # zero-x / zero-dt padding is exact: decay exp(A·0)=1 and zero input
        # leave the state untouched; padded outputs are discarded
        pad = L - S % L
        p4 = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        p3 = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        out = _xla_ssd(
            p4(x), p3(dt), A, p4(Bm), p4(Cm),
            chunk=chunk, initial_state=initial_state,
            return_final_state=return_final_state,
        )
        if return_final_state:
            return out[0][:, :S], out[1]
        return out[:, :S]
    nc = S // L

    xf = (x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None])  # x~ = dt*x
    lf = A.astype(jnp.float32)[None, None, :] * dt.astype(jnp.float32)  # (B,S,H) <=0
    Bf = Bm.astype(jnp.float32)
    Cf = Cm.astype(jnp.float32)

    # chunked views: (nc, B, L, ...)
    def chunked(a):
        return a.reshape(B, nc, L, *a.shape[2:]).transpose(1, 0, 2, *range(3, a.ndim + 1))

    xc, lc = chunked(xf), chunked(lf)  # (nc,B,L,H,P), (nc,B,L,H)
    Bc, Cc = chunked(Bf), chunked(Cf)  # (nc,B,L,G,N)

    h0 = (
        jnp.zeros((B, H, N, P), jnp.float32)
        if initial_state is None
        else initial_state.astype(jnp.float32)
    )

    def body(h, inp):
        xk, lk, bk, ck = inp
        cum, total = _chunk_quantities(lk.transpose(0, 2, 1))  # (B,H,L)
        # intra-chunk: scores[t,s] = (C_t . B_s) * exp(cum_t - cum_s) * [s<=t]
        gmat = jnp.einsum("blgn,bsgn->bgls", ck, bk)  # (B,G,L,L)
        gmat = jnp.repeat(gmat, rep, axis=1)  # (B,H,L,L)
        diff = cum[..., :, None] - cum[..., None, :]  # (B,H,L,L)
        tri = jnp.tril(jnp.ones((L, L), bool))
        decay = jnp.where(tri, jnp.exp(jnp.where(tri, diff, 0.0)), 0.0)
        scores = gmat * decay
        xh = xk.transpose(0, 2, 1, 3)  # (B,H,L,P)
        y_intra = jnp.einsum("bhls,bhsp->bhlp", scores, xh)
        # inter-chunk: y_t += exp(cum_t) * C_t . h
        crep = jnp.repeat(ck, rep, axis=2).transpose(0, 2, 1, 3)  # (B,H,L,N)
        y_inter = jnp.einsum("bhln,bhnp->bhlp", crep * jnp.exp(cum)[..., None], h)
        # state update: h = exp(total)*h + sum_s exp(total - cum_s) B_s x~_s^T
        w = jnp.exp(total - cum)  # (B,H,L)
        brep = jnp.repeat(bk, rep, axis=2).transpose(0, 2, 1, 3)  # (B,H,L,N)
        h = h * jnp.exp(total)[..., None] + jnp.einsum(
            "bhln,bhlp->bhnp", brep * w[..., None], xh
        )
        return h, (y_intra + y_inter).transpose(0, 2, 1, 3)  # (B,L,H,P)

    hT, yc = jax.lax.scan(body, h0, (xc, lc, Bc, Cc))
    y = yc.transpose(1, 0, 2, 3, 4).reshape(B, S, H, P).astype(x.dtype)
    if return_final_state:
        return y, hT
    return y


def ssd(
    x: jax.Array,  # (B, S, H, P)
    dt: jax.Array,  # (B, S, H) positive
    A: jax.Array,  # (H,) negative
    Bm: jax.Array,  # (B, S, G, N)
    Cm: jax.Array,  # (B, S, G, N)
    *,
    chunk: int = 128,
    initial_state: Optional[jax.Array] = None,
    return_final_state: bool = False,
):
    impl = kcfg.get_impl()
    if impl == "xla":
        return _xla_ssd(
            x, dt, A, Bm, Cm,
            chunk=chunk,
            initial_state=initial_state,
            return_final_state=return_final_state,
        )
    from repro.kernels.mamba2_ssd import kernel as _kernel

    return _kernel.ssd_pallas(
        x, dt, A, Bm, Cm,
        chunk=chunk,
        initial_state=initial_state,
        return_final_state=return_final_state,
        interpret=(impl == "pallas_interpret"),
    )


def ssd_step(x, dt, A, Bm, Cm, state):
    """Single decode step (always jnp: O(1) work)."""
    from repro.kernels.mamba2_ssd import ref as _ref

    return _ref.ssd_step_ref(x, dt, A, Bm, Cm, state)
