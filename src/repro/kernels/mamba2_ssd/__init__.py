from repro.kernels.mamba2_ssd import kernel, ops, ref

__all__ = ["kernel", "ops", "ref"]
