from repro.kernels.agreement import kernel, ops, ref

__all__ = ["kernel", "ops", "ref"]
