"""ABC agreement reduce — Pallas TPU kernel (the paper's deferral hot path).

The expensive part of computing vote/score agreement over ensemble logits
(E, B, V) is the sweep over the vocabulary V (up to 256 K classes for the
assigned archs): per member we need max, argmax and log-sum-exp.  This
kernel streams V through VMEM in (block_b × block_v) tiles along the
sequential v-grid dimension, keeping running (m, idx, l) accumulators in
VMEM scratch — one HBM pass instead of the three separate passes XLA emits
for argmax + max + logsumexp.  The tiny O(E²·B) majority-vote epilogue and
the gather of each member's probability for the majority class happen in
ops.py (they are not V-sweeps).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import config as kcfg

NEG_INF = -1e30


def _agree_kernel(
    x_ref,  # (1, block_b, block_v)
    m_ref,  # (1, block_b, 1)  out: max
    i_ref,  # (1, block_b, 1)  out: argmax (int32)
    l_ref,  # (1, block_b, 1)  out: sum exp(x - m)
    m_scr,  # (block_b, 1) f32
    i_scr,  # (block_b, 1) i32
    l_scr,  # (block_b, 1) f32
    *,
    block_v: int,
    num_v_blocks: int,
):
    iv = pl.program_id(2)

    @pl.when(iv == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        i_scr[...] = jnp.zeros_like(i_scr)
        l_scr[...] = jnp.zeros_like(l_scr)

    x = x_ref[0].astype(jnp.float32)  # (block_b, block_v)
    bm = jnp.max(x, axis=1, keepdims=True)
    bidx = jnp.argmax(x, axis=1).astype(jnp.int32)[:, None] + iv * block_v

    m_prev, l_prev = m_scr[...], l_scr[...]
    m_new = jnp.maximum(m_prev, bm)
    l_scr[...] = l_prev * jnp.exp(m_prev - m_new) + jnp.sum(
        jnp.exp(x - m_new), axis=1, keepdims=True
    )
    i_scr[...] = jnp.where(bm > m_prev, bidx, i_scr[...])
    m_scr[...] = m_new

    @pl.when(iv == num_v_blocks - 1)
    def _fin():
        m_ref[0] = m_scr[...]
        i_ref[0] = i_scr[...]
        l_ref[0] = l_scr[...]


@functools.partial(jax.jit, static_argnames=("block_b", "block_v", "interpret"))
def member_stats_pallas(
    logits: jax.Array,  # (E, B, V)
    *,
    block_b: int = 128,
    block_v: int = 2048,
    interpret: bool = False,
):
    """Per-member (max, argmax, sumexp) over V.  Returns (m, idx, l): (E, B)."""
    E, B, V = logits.shape
    block_b = min(block_b, B)
    block_v = min(block_v, V)
    if B % block_b != 0 or V % block_v != 0:
        raise ValueError(
            f"agreement kernel BlockSpec tiling: B={B}/V={V} must divide "
            f"block_b={block_b}/block_v={block_v} (logits {logits.shape})"
        )
    nb, nv = B // block_b, V // block_v
    kern = functools.partial(_agree_kernel, block_v=block_v, num_v_blocks=nv)
    m, idx, l = pl.pallas_call(
        kern,
        grid=(E, nb, nv),
        in_specs=[
            pl.BlockSpec((1, block_b, block_v), lambda e, ib, iv: (e, ib, iv)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_b, 1), lambda e, ib, iv: (e, ib, 0)),
            pl.BlockSpec((1, block_b, 1), lambda e, ib, iv: (e, ib, 0)),
            pl.BlockSpec((1, block_b, 1), lambda e, ib, iv: (e, ib, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((E, B, 1), jnp.float32),
            jax.ShapeDtypeStruct((E, B, 1), jnp.int32),
            jax.ShapeDtypeStruct((E, B, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_b, 1), jnp.float32),
            pltpu.VMEM((block_b, 1), jnp.int32),
            pltpu.VMEM((block_b, 1), jnp.float32),
        ],
        compiler_params=kcfg.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(logits)
    return m[..., 0], idx[..., 0], l[..., 0]
