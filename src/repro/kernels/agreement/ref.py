"""Naive oracle for the ABC agreement reduce.

Given ensemble logits (E, B, V) compute, per example b:
  pred[b]        majority top-1 class across the E members
  vote_frac[b]   fraction of members voting for pred[b]   (paper Eq. 3)
  mean_score[b]  mean over members of softmax_e(logits)[pred[b]] (Eq. 4)
Vote ties break toward the smallest class id (member-permutation invariant).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def agreement_ref(logits: jax.Array):
    E, B, V = logits.shape
    lf = logits.astype(jnp.float32)
    top1 = jnp.argmax(lf, axis=-1).astype(jnp.int32)  # (E, B)
    votes = (top1[:, None, :] == top1[None, :, :]).sum(axis=0)  # (E, B)
    # canonical tie-break: max votes, then smallest class id
    vmax = jnp.max(votes, axis=0, keepdims=True)
    pred = jnp.min(jnp.where(votes == vmax, top1, jnp.int32(2**30)), axis=0)
    vote_frac = vmax[0].astype(jnp.float32) / E
    probs = jax.nn.softmax(lf, axis=-1)  # (E, B, V)
    p_maj = jnp.take_along_axis(probs, pred[None, :, None], axis=2)[..., 0]  # (E, B)
    mean_score = p_maj.mean(axis=0)
    return {"pred": pred, "vote_frac": vote_frac, "mean_score": mean_score}
