"""Dispatching wrapper for the ABC agreement reduce.

``agreement(logits)`` with logits (E, B, V) returns
``{'pred', 'vote_frac', 'mean_score'}`` per example — the inputs to the
paper's deferral rules r_v (Eq. 3) and r_s (Eq. 4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import config as kcfg


def _epilogue(logits, m, idx, l):
    """Majority vote + mean majority-class probability from member stats.
    m/idx/l: (E, B).  O(E²·B) — tiny next to the V sweep."""
    E, _, V = logits.shape
    votes = (idx[:, None, :] == idx[None, :, :]).sum(axis=0)  # (E, B)
    # canonical tie-break: max votes, then smallest class id
    vmax = jnp.max(votes, axis=0, keepdims=True)
    pred = jnp.min(jnp.where(votes == vmax, idx, jnp.int32(2**30)), axis=0)
    vote_frac = vmax[0].astype(jnp.float32) / E
    # each member's probability for the majority class: one gather over V
    lm = jnp.take_along_axis(
        logits.astype(jnp.float32), pred[None, :, None], axis=2
    )[..., 0]  # (E, B)
    p_maj = jnp.exp(lm - m) / l
    return {"pred": pred, "vote_frac": vote_frac, "mean_score": p_maj.mean(axis=0)}


def _xla_member_stats(logits):
    lf = logits.astype(jnp.float32)
    m = jnp.max(lf, axis=-1)
    idx = jnp.argmax(lf, axis=-1).astype(jnp.int32)
    l = jnp.sum(jnp.exp(lf - m[..., None]), axis=-1)
    return m, idx, l


def agreement(logits: jax.Array):
    impl = kcfg.get_impl()
    if impl == "xla":
        m, idx, l = _xla_member_stats(logits)
    else:
        from repro.kernels.agreement import kernel as _kernel

        m, idx, l = _kernel.member_stats_pallas(
            logits, interpret=(impl == "pallas_interpret")
        )
    return _epilogue(logits, m, idx, l)
