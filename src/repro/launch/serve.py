"""Cascade serving CLI: stand up an ABC cascade from the arch registry and
serve a batched synthetic workload, reporting per-tier routing and cost.

  PYTHONPATH=src python -m repro.launch.serve \
      --tiers qwen2.5-3b:2 internlm2-1.8b:1 --reduced --requests 64
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.core import ensemble as ens
from repro.core.cascade import TierSpec
from repro.models.params import unbox
from repro.serve import CascadeServer, CascadeTier


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--tiers", nargs="+", required=True,
        help="arch:k per tier, cheapest first, e.g. qwen2.5-3b:2 command-r-plus-104b:1",
    )
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--theta", type=float, default=0.67)
    ap.add_argument("--rule", default="vote", choices=["vote", "score"])
    ap.add_argument("--mode", default="classify", choices=["classify", "generate"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    tiers = []
    rng = jax.random.PRNGKey(args.seed)
    for i, t in enumerate(args.tiers):
        arch, k = t.rsplit(":", 1)
        cfg = get_config(arch)
        if args.reduced:
            cfg = cfg.reduced()
        rng, sub = jax.random.split(rng)
        values, _ = unbox(ens.init_ensemble(cfg, int(k), sub))
        cost = cfg.active_param_count() * int(k) / 1e6  # MFLOP-ish units
        last = i == len(args.tiers) - 1
        spec = TierSpec(
            name=arch,
            rule="confidence" if (last and int(k) == 1) else args.rule,
            theta=-1.0 if last else args.theta,
            k=int(k),
            cost=cost,
        )
        tiers.append(CascadeTier(cfg, values, spec))
        print(f"tier {i}: {arch} k={k} cost/ex={cost:.1f}")

    server = CascadeServer(tiers)
    vocab = min(t.cfg.vocab_size for t in tiers)
    toks = np.random.default_rng(args.seed).integers(
        0, vocab, (args.requests, args.seq)
    ).astype(np.int32)
    if args.mode == "classify":
        res = server.classify(toks)
    else:
        res = server.generate(toks, max_new_tokens=8)
    fr = server.tier_fractions(res)
    print(f"tier fractions: {np.round(fr, 3).tolist()}")
    print(f"evaluated per tier: {res.evaluated.tolist()}")
    print(f"total cost: {res.cost:.1f}  vs all-top-tier: "
          f"{tiers[-1].spec.cost * args.requests:.1f}")


if __name__ == "__main__":
    main()
