"""Training CLI.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b --reduced \
      --steps 200 --batch 16 --seq 128

--reduced trains the smoke-scale variant on this CPU container; the full
configs are exercised via the dry-run.  On real hardware the same script
runs the production mesh by passing --mesh pod (the pjit path is identical —
see launch/dryrun.py for the sharding derivation).
"""
from __future__ import annotations

import argparse
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.configs import get_config
from repro.data.pipeline import TokenDataset, batches
from repro.data.synthetic import sequence_task
from repro.models import api
from repro.models.params import unbox
from repro.optim.adamw import OptimConfig
from repro.train import init_train_state, make_train_step, train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--n-examples", type=int, default=4096)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    print(f"arch={cfg.name} family={cfg.family} params≈{cfg.param_count():,}")

    params_boxed = api.init_params(cfg, jax.random.PRNGKey(args.seed))
    values, _ = unbox(params_boxed)
    ocfg = OptimConfig(lr=args.lr)
    state = init_train_state(values, ocfg)
    step = make_train_step(cfg, ocfg, total_steps=args.steps, warmup_steps=min(50, args.steps // 10 + 1))

    rows = sequence_task(args.n_examples, args.seq, vocab=min(cfg.vocab_size, 512), seed=args.seed)
    rows = rows % cfg.vocab_size
    it = batches(TokenDataset(rows), args.batch)

    def maybe_embed(b):
        if cfg.is_encoder:
            # encoder: random frame embeddings carrying the token identity
            emb = jax.nn.one_hot(b["tokens"] % cfg.frontend_dim, cfg.frontend_dim)
            return {"embeds": emb.astype(jnp.float32), "targets": b["targets"], "mask": b["mask"]}
        return b

    it = map(maybe_embed, it)
    ckpt_fn = None
    if args.ckpt_dir:
        ckpt_fn = lambda st, i: save_checkpoint(args.ckpt_dir, i, st.params)  # noqa: E731
    state, hist = train_loop(
        step, state, it, steps=args.steps, checkpoint_every=max(1, args.steps // 2),
        checkpoint_fn=ckpt_fn,
    )
    print(f"final loss {hist[-1]['loss']:.4f} (start {hist[0]['loss']:.4f})")


if __name__ == "__main__":
    main()
