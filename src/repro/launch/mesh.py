"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax init,
smoke tests and benches see the real single device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod (v5e); 2 pods = 512 chips multi-pod.

    Axes: ('data', 'model') single-pod; ('pod', 'data', 'model') multi-pod.
    The 'pod' axis carries ABC's ensemble parallelism (DESIGN.md §3) and
    folds into data parallelism for single-model steps.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def pod_submeshes(mesh, n_slices: int):
    """Carve a mesh with a leading 'pod' axis into ``n_slices`` contiguous
    pod slices (DESIGN.md §3: tier placement).  Each slice keeps a 'pod'
    axis (its share of pods) so a tier's 'ensemble' logical axis still maps
    onto it; distinct slices own disjoint device sets.  The slice also
    keeps its 'data' axis, which is what the data-sharded tier hand-off
    shards deferral payload rows over on arrival
    (``serve.transport.ShardedDevicePutTransport``, DESIGN.md §8)."""
    from jax.sharding import Mesh

    assert mesh.axis_names[0] == "pod", mesh.axis_names
    n_pods = mesh.devices.shape[0]
    assert n_pods % n_slices == 0, (n_pods, n_slices)
    per = n_pods // n_slices
    return [
        Mesh(mesh.devices[i * per : (i + 1) * per], mesh.axis_names)
        for i in range(n_slices)
    ]
