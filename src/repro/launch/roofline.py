"""Roofline term extraction from compiled dry-run artifacts.

compute term    = HLO_FLOPs / (chips × peak_FLOP/s)
memory term     = HLO_bytes / (chips × HBM_bw)
collective term = collective_bytes / (chips × link_bw)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``.
collective_bytes is parsed from the (post-SPMD-partitioning) HLO text:
we sum the *output* shape bytes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute op — i.e. bytes landed per
participating device, the quantity the ICI links must move.
"""
from __future__ import annotations

import re
from typing import Dict

import numpy as np

from repro.core.cost_model import TPU_V5E

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(bf16|f8e4m3fn|f8e5m2|f64|f32|f16|s64|u64|s32|u32|s16|u16|s8|u8|pred)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _line_collective(line: str):
    """(kind, bytes) for a collective op line, else None."""
    s = line.strip()
    if "=" not in s:
        return None
    lhs, rhs = s.split("=", 1)
    rhs = rhs.strip()
    m = re.match(r"^(\([^)]*\)|[a-z0-9\[\],{}_:\- ]+?)\s+([a-z0-9\-]+)\(", rhs)
    if not m:
        return None
    op = m.group(2)
    base = op[:-6] if op.endswith("-start") else op
    if base not in _COLLECTIVES or op.endswith("-done"):
        return None
    total = sum(_shape_bytes(dt, dims) for dt, dims in _SHAPE_RE.findall(m.group(1)))
    return base, total


_CALLEE_RE = re.compile(
    r"(?:body|condition|calls|to_apply)=%?([\w.\-]+)|branch_computations=\{([^}]*)\}"
)
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")


def parse_collectives(hlo_text: str) -> Dict[str, int]:
    """Sum output bytes per collective kind, multiplying collectives inside
    ``while`` bodies by the loop trip count (scan-over-layers!).  Trip count
    is recovered from the largest integer constant in the loop's condition
    computation — exact for lax.scan's counted loops."""
    # 1. split into computations
    comps: Dict[str, list] = {}
    entry = None
    cur = None
    for line in hlo_text.splitlines():
        if not line.startswith(" "):
            m = _COMP_HDR_RE.match(line.strip())
            if m:
                cur = m.group(1)
                comps[cur] = []
                if line.strip().startswith("ENTRY"):
                    entry = cur
                continue
            cur = None
        elif cur is not None:
            comps[cur].append(line)

    const_re = re.compile(r"constant\((\d+)\)")

    def trip_count(cond_name: str) -> int:
        best = 1
        for line in comps.get(cond_name, []):
            for c in const_re.findall(line):
                best = max(best, int(c))
        return best

    # 2. per-computation direct bytes + callees
    direct: Dict[str, Dict[str, int]] = {}
    callees: Dict[str, list] = {}
    for name, lines in comps.items():
        d = {k: 0 for k in _COLLECTIVES}
        cl = []
        for line in lines:
            r = _line_collective(line)
            if r:
                d[r[0]] += r[1]
            if "while(" in line:
                body = cond = None
                for m in _CALLEE_RE.finditer(line):
                    pass
                bm = re.search(r"body=%?([\w.\-]+)", line)
                cm = re.search(r"condition=%?([\w.\-]+)", line)
                if bm:
                    cl.append((bm.group(1), trip_count(cm.group(1)) if cm else 1))
            else:
                for m in _CALLEE_RE.finditer(line):
                    if m.group(1) and "condition=" not in m.group(0):
                        cl.append((m.group(1), 1))
                    elif m.group(2):
                        for b in m.group(2).split(","):
                            b = b.strip().lstrip("%")
                            if b:
                                cl.append((b, 1))
        direct[name] = d
        callees[name] = cl

    # 3. DFS with multipliers (memoized per computation)
    memo: Dict[str, Dict[str, int]] = {}

    def total(name: str) -> Dict[str, int]:
        if name in memo:
            return memo[name]
        memo[name] = {k: 0 for k in _COLLECTIVES}  # cycle guard
        acc = dict(direct.get(name, {k: 0 for k in _COLLECTIVES}))
        for callee, mult in callees.get(name, []):
            sub = total(callee)
            for k in _COLLECTIVES:
                acc[k] += mult * sub[k]
        memo[name] = acc
        return acc

    if entry is None:
        return {k: 0 for k in _COLLECTIVES}
    return total(entry)


def roofline_terms(
    cost: dict,
    collective_bytes: int,
    n_chips: int,
    hw: dict = TPU_V5E,
) -> dict:
    """cost: compiled.cost_analysis() dict (per-device program).

    Note: on this container XLA:CPU reports whole-program FLOPs of the
    SPMD-partitioned per-device program, so terms are already per-chip.
    """
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    t_compute = flops / hw["peak_flops_bf16"]
    t_memory = byts / hw["hbm_bw"]
    t_collective = collective_bytes / n_chips / hw["ici_bw"]
    terms = {
        "flops": flops,
        "bytes": byts,
        "collective_bytes": collective_bytes,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_collective,
    }
    dom = max(
        ("compute", t_compute), ("memory", t_memory), ("collective", t_collective),
        key=lambda kv: kv[1],
    )
    terms["bottleneck"] = dom[0]
    terms["t_bound_s"] = dom[1]
    return terms
