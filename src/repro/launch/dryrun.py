import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × input shape) on the
production meshes, prove memory fits, and extract roofline terms.

MUST be run as its own process (the XLA_FLAGS line above has to execute
before any jax initialization — hence the import-order heresy).

  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-3b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all --multi-pod both

Results land in experiments/dryrun/<arch>__<shape>__<mesh>.json and feed
EXPERIMENTS.md §Dry-run / §Roofline.
"""
import argparse  # noqa: E402
import functools  # noqa: E402
import json  # noqa: E402
import subprocess  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec  # noqa: E402

from repro.configs import INPUT_SHAPES, get_config, list_configs, shape_supported  # noqa: E402
from repro.kernels import config as kcfg  # noqa: E402
from repro.launch.jaxpr_cost import estimate_fn_cost  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.roofline import parse_collectives, roofline_terms  # noqa: E402
from repro.models import api  # noqa: E402
from repro.models.counting import count_params, model_flops_per_token  # noqa: E402
from repro.models.params import unbox  # noqa: E402
from repro.optim.adamw import OptimConfig, adamw_init  # noqa: E402
from repro.sharding.logical import axis_rules, logical_to_pspec, rules_for  # noqa: E402
from repro.train.step import TrainState, init_train_state, make_train_step  # noqa: E402

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "experiments", "dryrun")

LONG_WINDOW = 4096  # sliding window forced for long_500k on attention archs


def _sds_tree(shapes_tree, axes_tree, rules, mesh):
    """ShapeDtypeStructs with NamedShardings derived from logical axes."""
    leaves_s, treedef = jax.tree.flatten(shapes_tree)
    leaves_a = treedef.flatten_up_to(axes_tree)
    out = []
    for s, a in zip(leaves_s, leaves_a):
        pspec = logical_to_pspec(a, rules, shape=s.shape, mesh=mesh)
        out.append(jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=NamedSharding(mesh, pspec)))
    return treedef.unflatten(out)


_BATCH_AXES = {
    "tokens": ("act_batch", None),
    "targets": ("act_batch", None),
    "mask": ("act_batch", None),
    "embeds": ("act_batch", None, None),
    "token": ("act_batch", None),
    "pos": (),
}


def _batch_sds(specs, rules, mesh):
    out = {}
    for name, s in specs.items():
        pspec = logical_to_pspec(
            _BATCH_AXES[name], rules, shape=s.shape, mesh=mesh
        )
        out[name] = jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, pspec)
        )
    return out


def _moment_dtype(cfg) -> str:
    # >=80B params: bf16 AdamW moments (DESIGN.md §7) to fit 16 GB/chip
    return "bfloat16" if count_params(cfg) > 80e9 else "float32"


def run_one(arch: str, shape_name: str, multi_pod: bool, out_dir: str) -> dict:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "kind": shape.kind,
        "params": count_params(cfg),
        "active_params": count_params(cfg, active_only=True),
    }

    ok, reason = shape_supported(cfg, shape)
    if not ok:
        rec["status"] = "skipped"
        rec["reason"] = reason
        return rec

    window = None
    if shape_name == "long_500k" and not cfg.attention_free:
        window = cfg.sliding_window or LONG_WINDOW
        rec["window_override"] = window

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(mesh.devices.shape))
    rules = rules_for(shape.kind, pod=multi_pod, batch=shape.global_batch)

    # abstract params (+ axes) — nothing is materialized
    boxed = jax.eval_shape(functools.partial(api.init_params, cfg), jax.random.PRNGKey(0))
    p_shapes, p_axes = unbox(boxed)
    params_sds = _sds_tree(p_shapes, p_axes, rules, mesh)
    specs = api.input_specs(cfg, shape)

    t0 = time.time()
    jcost = None
    with mesh, axis_rules(rules, mesh):
        if shape.kind == "train":
            ocfg = OptimConfig(moment_dtype=_moment_dtype(cfg))
            step = make_train_step(cfg, ocfg, window_override=window)
            opt_shapes = jax.eval_shape(functools.partial(adamw_init, cfg=ocfg), p_shapes)
            opt_sds = {
                "m": _sds_tree(opt_shapes["m"], p_axes, rules, mesh),
                "v": _sds_tree(opt_shapes["v"], p_axes, rules, mesh),
                "count": jax.ShapeDtypeStruct(
                    (), jnp.int32, sharding=NamedSharding(mesh, PartitionSpec())
                ),
            }
            state_sds = TrainState(
                params=params_sds,
                opt=opt_sds,
                step=jax.ShapeDtypeStruct(
                    (), jnp.int32, sharding=NamedSharding(mesh, PartitionSpec())
                ),
            )
            batch_sds = _batch_sds(specs, rules, mesh)
            # kernelized (TPU-target) cost: pallas forward trace scaled by
            # the XLA-path train/forward ratio (AD through pallas_call is
            # not defined; the ratio captures backward + remat + optimizer)
            fwd = lambda p, b: api.loss_fn(p, b, cfg, window_override=window)[0]
            jc_train_xla = estimate_fn_cost(step, state_sds, batch_sds)
            jc_fwd_xla = estimate_fn_cost(fwd, params_sds, batch_sds)
            with kcfg.use_impl("pallas"):
                jc_fwd_pal = estimate_fn_cost(fwd, params_sds, batch_sds)
            jcost = {
                "flops": jc_fwd_pal["flops"]
                * (jc_train_xla["flops"] / max(1, jc_fwd_xla["flops"])),
                "bytes": jc_fwd_pal["bytes"]
                * (jc_train_xla["bytes"] / max(1, jc_fwd_xla["bytes"])),
                "xla_train": jc_train_xla,
            }
            # §Perf iteration 4: pin the output state to the input shardings
            # (grads/optimizer update reduce-scatter instead of all-reduce)
            # and donate the state buffers
            state_shardings = jax.tree.map(lambda s: s.sharding, state_sds)
            # abclint: disable=ABC101(AOT lower-compile path — traces exactly once by construction)
            lowered = jax.jit(
                step, out_shardings=(state_shardings, None), donate_argnums=(0,)
            ).lower(state_sds, batch_sds)
        elif shape.kind == "prefill":
            fn = functools.partial(api.prefill, cfg=cfg, window_override=window)
            batch_sds = _batch_sds(specs, rules, mesh)
            with kcfg.use_impl("pallas"):
                jcost = estimate_fn_cost(fn, params_sds, batch_sds)
            # abclint: disable=ABC101(AOT lower-compile path — traces exactly once by construction)
            lowered = jax.jit(fn).lower(params_sds, batch_sds)
        else:  # decode
            fn = functools.partial(api.decode_step, cfg=cfg, window_override=window)
            cache_boxed = jax.eval_shape(
                lambda: api.init_cache(cfg, shape.global_batch, shape.seq_len)
            )
            c_shapes, c_axes = unbox(cache_boxed)
            cache_sds = _sds_tree(c_shapes, c_axes, rules, mesh)
            batch_sds = _batch_sds(specs, rules, mesh)
            with kcfg.use_impl("pallas"):
                jcost = estimate_fn_cost(
                    fn, params_sds, batch_sds["token"], cache_sds, batch_sds["pos"]
                )
            # abclint: disable=ABC101(AOT lower-compile path — traces exactly once by construction)
            lowered = jax.jit(fn).lower(
                params_sds, batch_sds["token"], cache_sds, batch_sds["pos"]
            )
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    xla_cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = parse_collectives(hlo)
    # roofline terms from the scan-aware jaxpr cost (global -> per-chip);
    # XLA's cost_analysis counts scan bodies once, kept as a cross-check
    per_chip = {
        "flops": jcost["flops"] / n_chips,
        "bytes accessed": jcost["bytes"] / n_chips,
    }
    terms = roofline_terms(per_chip, sum(coll.values()), n_chips)

    # MODEL_FLOPS: 6·N·D for train, 2·N·D for inference, N = active non-embed
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    per_tok = model_flops_per_token(cfg) / 6.0
    model_flops = (6.0 if shape.kind == "train" else 2.0) * per_tok * tokens
    rec.update(
        status="ok",
        n_chips=n_chips,
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        collectives=coll,
        roofline=terms,
        xla_cost={
            "flops_per_dev": float(xla_cost.get("flops", 0.0)),
            "bytes_per_dev": float(xla_cost.get("bytes accessed", 0.0)),
        },
        model_flops=model_flops,
        useful_ratio=(model_flops / jcost["flops"]) if jcost["flops"] else None,
        memory={
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "temp_size_in_bytes", 0)
            + getattr(mem, "argument_size_in_bytes", 0),
        },
    )
    return rec


def run_cascade(multi_pod: bool, out_dir: str) -> dict:
    """The paper's technique on the production mesh: a 2-member tier-1
    ensemble stacked on the 'ensemble' logical axis (mapped to the 'pod'
    mesh axis on the 2×16×16 mesh — one member per pod), agreement reduce
    across pods, and the dense masked tier-2 pass.  Proves ABC's ensemble-
    parallel execution lowers + shards end to end."""
    import dataclasses

    from repro.core import deferral
    from repro.core import ensemble as ens_mod

    cfg1 = get_config("qwen2.5-3b")
    cfg2 = dataclasses.replace(
        cfg1, name="qwen2.5-14b-like", n_layers=48, d_model=5120,
        n_heads=40, n_kv_heads=8, d_ff=13824, head_dim=128,
    )
    B, S, E = 32, 8192, 2
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(mesh.devices.shape))
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rules = dict(rules_for("prefill", pod=multi_pod, batch=B))
    # the pod axis carries the ensemble, not the batch
    rules["act_batch"] = ("data",)
    rules["kv_batch"] = ("data",)
    rules["ensemble"] = "pod" if multi_pod else None

    b1 = jax.eval_shape(
        functools.partial(ens_mod.init_ensemble, cfg1, E), jax.random.PRNGKey(0)
    )
    s1, a1 = unbox(b1)
    b2 = jax.eval_shape(functools.partial(api.init_params, cfg2), jax.random.PRNGKey(1))
    s2, a2 = unbox(b2)
    v1_sds = _sds_tree(s1, a1, rules, mesh)
    v2_sds = _sds_tree(s2, a2, rules, mesh)
    batch_sds = _batch_sds(api.input_specs(cfg1, INPUT_SHAPES["prefill_32k"]), rules, mesh)
    batch_sds["tokens"] = jax.ShapeDtypeStruct(
        (B, S), jnp.int32, sharding=batch_sds["tokens"].sharding
    )

    def cascade_step(v1, v2, batch):
        logits1 = jax.vmap(lambda p: api.prefill(p, batch, cfg1)[0])(v1)  # (E,B,V)
        out = deferral.vote_rule(logits1, 0.67)
        logits2, _ = api.prefill(v2, batch, cfg2)
        pred = jnp.where(
            out.defer, jnp.argmax(logits2, -1).astype(jnp.int32), out.pred
        )
        return pred, out.defer, out.score

    rec = {"arch": "abc-cascade-2tier", "shape": f"prefill_{S}", "mesh": mesh_name,
           "kind": "cascade"}
    t0 = time.time()
    with mesh, axis_rules(rules, mesh):
        jcost = estimate_fn_cost(cascade_step, v1_sds, v2_sds, batch_sds)
        # abclint: disable=ABC101(AOT lower-compile path — traces exactly once by construction)
        lowered = jax.jit(cascade_step).lower(v1_sds, v2_sds, batch_sds)
        compiled = lowered.compile()
    coll = parse_collectives(compiled.as_text())
    per_chip = {"flops": jcost["flops"] / n_chips, "bytes accessed": jcost["bytes"] / n_chips}
    rec.update(
        status="ok",
        n_chips=n_chips,
        compile_s=round(time.time() - t0, 2),
        collectives=coll,
        roofline=roofline_terms(per_chip, sum(coll.values()), n_chips),
    )
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", default="false", choices=["false", "true", "both"])
    ap.add_argument("--out", default=os.path.abspath(OUT_DIR))
    ap.add_argument("--cascade", action="store_true",
                    help="dry-run the ABC cascade step itself (ensemble on the pod axis)")
    ap.add_argument("--subprocess", action="store_true", help=argparse.SUPPRESS)
    args = ap.parse_args()

    if args.cascade:
        os.makedirs(args.out, exist_ok=True)
        mp = args.multi_pod == "true"
        rec = run_cascade(mp, args.out)
        mesh_name = "pod2x16x16" if mp else "pod16x16"
        with open(os.path.join(args.out, f"abc-cascade__{mesh_name}.json"), "w") as f:
            json.dump(rec, f, indent=2)
        t = rec["roofline"]
        print(f"[ok] abc-cascade × {mesh_name}: compile={rec['compile_s']}s "
              f"coll={t['collective_bytes']:.3e} bottleneck={t['bottleneck']} "
              f"collectives={rec['collectives']}")
        return

    archs = list_configs() if args.arch == "all" else [args.arch]
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]
    pods = {"false": [False], "true": [True], "both": [False, True]}[args.multi_pod]
    os.makedirs(args.out, exist_ok=True)

    combos = [(a, s, mp) for a in archs for s in shapes for mp in pods]
    if len(combos) > 1:
        # one subprocess per combo: isolates XLA state and survives failures
        for a, s, mp in combos:
            mesh_name = "pod2x16x16" if mp else "pod16x16"
            out_file = os.path.join(args.out, f"{a}__{s}__{mesh_name}.json")
            if os.path.exists(out_file):
                print(f"[skip existing] {out_file}")
                continue
            cmd = [
                sys.executable, "-m", "repro.launch.dryrun",
                "--arch", a, "--shape", s,
                "--multi-pod", "true" if mp else "false",
                "--out", args.out,
            ]
            print(f"[dryrun] {a} × {s} × {mesh_name}")
            r = subprocess.run(cmd, env=dict(os.environ))
            if r.returncode != 0:
                print(f"  FAILED rc={r.returncode}")
        return

    arch, shape_name, mp = combos[0]
    mesh_name = "pod2x16x16" if mp else "pod16x16"
    out_file = os.path.join(args.out, f"{arch}__{shape_name}__{mesh_name}.json")
    try:
        rec = run_one(arch, shape_name, mp, args.out)
    except Exception as e:  # record the failure — these are bugs to fix
        rec = {
            "arch": arch, "shape": shape_name, "mesh": mesh_name,
            "status": "error", "error": f"{type(e).__name__}: {e}",
            "traceback": traceback.format_exc()[-4000:],
        }
    with open(out_file, "w") as f:
        json.dump(rec, f, indent=2)
    status = rec["status"]
    if status == "ok":
        t = rec["roofline"]
        print(
            f"[ok] {arch} × {shape_name} × {mesh_name}: "
            f"compile={rec['compile_s']}s flops={t['flops']:.3e} "
            f"bytes={t['bytes']:.3e} coll={t['collective_bytes']:.3e} "
            f"bottleneck={t['bottleneck']}"
        )
    else:
        print(f"[{status}] {arch} × {shape_name} × {mesh_name}: {rec.get('reason', rec.get('error'))}")
        if status == "error":
            sys.exit(1)


if __name__ == "__main__":
    main()
