"""Scan-aware FLOP/byte estimation over a closed jaxpr.

Why: ``compiled.cost_analysis()`` counts a ``while``/``scan`` body ONCE,
regardless of trip count (verified empirically on this container) — with
scan-over-layers models that undercounts by ~n_layers.  This walker
multiplies through scan lengths, so the roofline compute/memory terms are
trip-count-correct.  XLA's numbers are still recorded per run as a
cross-check (EXPERIMENTS.md reports both).

Cost model:
  flops — dot_general exact (2·M·N·K·batch); elementwise/reduce ops 1 per
          output element (transcendentals counted as 1 — matmul-dominated
          workloads make this rounding irrelevant)
  bytes — perfect-fusion HBM traffic model: operand+output bytes are
          charged for matmuls, data movement (gather/scatter/slice/concat/
          transpose) and reductions; pure elementwise ops are assumed fused
          into their producers (0 traffic).  This is the optimistic lower
          bound a well-fused TPU program approaches; weights re-read every
          scan iteration are real traffic and are counted × trip count.
Both are GLOBAL (unpartitioned) quantities; divide by chips for per-chip
terms (assumes compute/traffic shard evenly — the collectives term, parsed
from the partitioned HLO, captures what does not).
"""
from __future__ import annotations

import numpy as np
from jax import core
from jax._src import core as _core  # jaxpr structure is stable enough here

_NO_FLOPS = {
    "broadcast_in_dim", "reshape", "squeeze", "transpose", "slice",
    "dynamic_slice", "dynamic_update_slice", "gather", "scatter",
    "scatter-add", "concatenate", "pad", "convert_element_type", "iota",
    "rev", "copy", "select_n", "stop_gradient",
}
# ops that necessarily move data through HBM even under perfect fusion
_DATA_MOVEMENT = {
    "transpose", "slice", "dynamic_slice", "dynamic_update_slice", "gather",
    "scatter", "scatter-add", "concatenate", "pad", "rev", "copy", "sort",
}
_REDUCTION_PREFIXES = ("reduce", "cum", "argmax", "argmin", "top_k", "scan_")


def _size_bytes(aval) -> int:
    try:
        return int(np.prod(aval.shape)) * aval.dtype.itemsize
    except Exception:
        return 0


def _numel(aval) -> int:
    try:
        return int(np.prod(aval.shape))
    except Exception:
        return 0


def _dot_flops(eqn) -> int:
    (lhs, rhs) = eqn.invars[:2]
    dims = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dims
    lshape = lhs.aval.shape
    batch = int(np.prod([lshape[i] for i in lb])) if lb else 1
    contract = int(np.prod([lshape[i] for i in lc])) if lc else 1
    m = int(
        np.prod([d for i, d in enumerate(lshape) if i not in set(lc) | set(lb)])
    )
    rshape = rhs.aval.shape
    n = int(
        np.prod([d for i, d in enumerate(rshape) if i not in set(rc) | set(rb)])
    )
    return 2 * batch * m * n * contract


def _sub_jaxprs(eqn):
    """(jaxpr, multiplier) pairs for higher-order primitives."""
    p = eqn.primitive.name
    params = eqn.params
    if p == "scan":
        return [(params["jaxpr"].jaxpr, int(params["length"]))]
    if p == "while":
        # bounded fori loops appear as while; trip count is not in the
        # params — we do not emit unbounded whiles in model code, scans
        # cover the loops that matter.  Count body once.
        return [
            (params["body_jaxpr"].jaxpr, 1),
            (params["cond_jaxpr"].jaxpr, 1),
        ]
    if p == "cond":
        # both branches lowered; roofline takes the max-cost branch
        return [("COND", [b.jaxpr for b in params["branches"]])]
    if p in ("jit", "pjit", "closed_call", "core_call", "remat_call", "xla_call", "custom_vjp_call", "custom_jvp_call"):
        j = params.get("jaxpr") or params.get("call_jaxpr")
        if j is None:
            return []
        return [(getattr(j, "jaxpr", j), 1)]
    if p == "checkpoint" or p == "remat2":
        return [(params["jaxpr"], 1)]
    if p == "custom_vjp_call_jaxpr":
        return [(params["fun_jaxpr"].jaxpr, 1)]
    return []


def _pallas_cost(eqn):
    """Pallas kernels: per-block body cost × grid size; HBM traffic = the
    BlockSpec streaming traffic (each operand/output block is DMA'd once per
    grid point — exactly the kernel's tiling contract).  This is what makes
    the roofline reflect the TPU-target program: e.g. flash attention's
    logits never appear as HBM traffic because they live in VMEM scratch."""
    gm = eqn.params["grid_mapping"]
    grid = 1
    for g in gm.grid:
        grid *= int(g)
    body = eqn.params["jaxpr"]
    body = getattr(body, "jaxpr", body)
    c = jaxpr_cost(body)
    byts = 0
    for bm in gm.block_mappings:
        aval = bm.block_aval
        inner = getattr(aval, "inner_aval", aval)
        byts += grid * _size_bytes(inner)
    return grid * c["flops"], byts


def jaxpr_cost(jaxpr) -> dict:
    flops = 0
    byts = 0
    for eqn in jaxpr.eqns:
        p = eqn.primitive.name
        if p == "pallas_call":
            f, b = _pallas_cost(eqn)
            flops += f
            byts += b
            continue
        subs = _sub_jaxprs(eqn)
        if subs:
            for item in subs:
                if item[0] == "COND":
                    costs = [jaxpr_cost(j) for j in item[1]]
                    best = max(costs, key=lambda c: c["flops"] + c["bytes"])
                    flops += best["flops"]
                    byts += best["bytes"]
                else:
                    j, mult = item
                    c = jaxpr_cost(j)
                    flops += mult * c["flops"]
                    byts += mult * c["bytes"]
            continue
        out_elems = sum(_numel(v.aval) for v in eqn.outvars)
        out_bytes = sum(_size_bytes(v.aval) for v in eqn.outvars)
        in_bytes = sum(
            _size_bytes(v.aval) for v in eqn.invars if hasattr(v, "aval")
        )
        moves = (
            p == "dot_general"
            or p in _DATA_MOVEMENT
            or p.startswith(_REDUCTION_PREFIXES)
        )
        if p == "dot_general":
            flops += _dot_flops(eqn)
        elif p not in _NO_FLOPS:
            flops += out_elems
        if p == "dynamic_update_slice":
            # in-place on TPU (buffer donation): traffic = the written slice
            # (read update + write), NOT the whole buffer
            byts += 2 * sum(
                _size_bytes(v.aval) for v in eqn.invars[1:2] if hasattr(v, "aval")
            )
        elif p == "dynamic_slice" or p == "slice":
            byts += 2 * out_bytes  # read slice + write result
        elif p in ("gather",):
            byts += 2 * out_bytes
        elif p in ("scatter", "scatter-add"):
            # read+write touched rows (the updates operand) + index traffic
            upd = eqn.invars[2].aval if len(eqn.invars) > 2 else None
            byts += 3 * (_size_bytes(upd) if upd is not None else out_bytes)
        elif moves:
            byts += in_bytes + out_bytes
    return {"flops": int(flops), "bytes": int(byts)}


def estimate_fn_cost(fn, *args, **kwargs) -> dict:
    import jax

    # fresh wrapper per call: the pjit trace cache keys on (function, avals)
    # and is blind to the kernels impl flag — without this, tracing the same
    # fn under impl='pallas' then lowering under impl='xla' (or vice versa)
    # would silently reuse the wrong jaxpr
    wrapper = lambda *a, **k: fn(*a, **k)  # noqa: E731
    closed = jax.make_jaxpr(wrapper)(*args, **kwargs)
    return jaxpr_cost(closed.jaxpr)
