"""Cascade-as-drafter speculative decoding (DESIGN.md §13).

A deferred request used to throw the fast tier's whole generation away:
the big tier re-decoded every output token from scratch.  But the fast
tier's members *agreed* on (a prefix of) that generation — agreement is
the paper's signal of correctness, so those tokens are an unusually good
draft.  This module turns them into one: the deferral payload carries the
winning member's generation (``Request.draft``), and the receiving tier
scores EVERY draft position in one chunked-prefill-shaped pass instead of
one decode step per token.

The contract, in terms the rest of the repo already enforces:

* **Verify inputs.**  For a prompt of length P and draft d_0..d_{T-1},
  the verify chunk is ``[prompt[P-1], d_0, .., d_{T-1}]`` at absolute
  positions ``P-1 .. P-1+T``: feeding the token BEFORE each draft
  position yields the model's own next-token choice at that position.
  The pass runs through ``api.prefill_into_slot_logits`` (paged twin:
  ``..._paged_logits``), which is the SAME chunked-prefill program family
  the admission path compiles — ``core.cascade.prompt_chunks`` buckets,
  no new traces per request — with the head projection bolted on.

* **Acceptance rule.**  ``choices[e, j]`` is member e's sampled/greedy
  token at draft position j.  The accepted length ``n_acc`` is the
  longest prefix where EVERY member's choice matches the draft; the
  emission at position ``n_acc`` is each member's own ``choices[:,
  n_acc]`` — exactly the token that member's autoregressive decode would
  have produced, because all of its context tokens matched the draft.
  One pass therefore emits ``n_acc + 1`` tokens that are bitwise what
  per-token decode would have emitted (greedy, or sampled: see below).

* **Rollback.**  Rejected draft tokens wrote KV rows past ``P-1+n_acc``.
  Dense slots need no action — the per-slot pos mask already hides rows
  at/after the slot's position, and decode's scatter-then-attend
  overwrites a row before ever attending to it.  Paged slots unmap the
  pages wholly past the kept span (``PagePool.truncate``); verify wrote
  only PRIVATE extension pages (``PagePool.extend`` never registers them
  in the prefix index), so rollback is COW-safe and ``assert_conserved``
  holds at every step.

* **Sampling determinism (T>0).**  Decode samples token at position p
  from ``categorical(fold_in(fold_in(slot_key, p), e))`` — a pure
  function of (slot key, position, member), not of how many steps got
  batched together.  ``verify_sampler`` reproduces that exact stream at
  chunk positions ``start + j``, so sampled verification accepts against
  the very tokens decode WOULD have sampled: speculative and plain
  serving emit bitwise-identical generations at any temperature.

Families: attention-cache only (``api.supports_draft_verify``).  A
constant-state tier (SSM/RWKV/hybrid) cannot roll rejected tokens out of
its recurrent state, so it falls back to plain admission — semantics
unchanged, just no speedup.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DraftPlan:
    """One slot's verify pass, fully determined at admission time.

    ``tokens`` (T_use+1,) — the verify chunk ``[prompt[-1], d_0..d_{T_use-1}]``;
    ``draft`` (T_use,) — the draft positions being scored;
    ``start`` — absolute position of ``tokens[0]`` (= P-1)."""

    tokens: np.ndarray
    draft: np.ndarray
    start: int


def plan_draft(
    prompt_tokens: np.ndarray,
    draft: np.ndarray,
    max_new_tokens: int,
    max_seq: int,
) -> Optional[DraftPlan]:
    """Clamp a draft to what the slot can legally verify, or None.

    ``T_use <= max_new_tokens - 1``: the verify pass emits ``n_acc + 1``
    tokens (accepted prefix plus the model's own token at the divergence
    point), so a full-length draft would overshoot the budget by one.
    ``T_use <= max_seq - P``: draft rows live at positions P..P+T_use-1
    and the slot wall is max_seq.  Anything below one verifiable token
    (e.g. ``max_new_tokens == 1`` — the first emission is never drafted)
    is not worth a pass."""
    P = int(len(prompt_tokens))
    T_use = min(int(len(draft)), max_new_tokens - 1, max_seq - P)
    if T_use < 1:
        return None
    # abclint: disable=ABC203(the draft arrived host-side on the deferral hop)
    draft = np.asarray(draft[:T_use], np.int32)
    tokens = np.concatenate(
        # abclint: disable=ABC203(r.tokens is the host prompt array)
        [np.asarray(prompt_tokens[-1:], np.int32), draft]
    )
    return DraftPlan(tokens=tokens, draft=draft, start=P - 1)


def accepted_prefix(choices: np.ndarray, draft: np.ndarray) -> int:
    """Longest prefix where every member's choice equals the draft.

    choices (E, >=T), draft (T,) -> n_acc in [0, T].  Min over members:
    a position is accepted only if ALL member trajectories would have
    produced the draft token there, which is what keeps each member's
    emitted sequence identical to its own autoregressive decode."""
    T = int(draft.shape[0])
    ok = (choices[:, :T] == draft[None, :]).all(axis=0)
    # abclint: disable=ABC202(choices is the host array the backend already fetched)
    return T if ok.all() else int(np.argmin(ok))


def verify_sampler(temperature: float):
    """Per-position member choices for the verify chunk, reproducing the
    decode-time ``_slot_sampler`` stream exactly (cascade_server.py):
    token(e, p) = categorical(fold_in(fold_in(slot_key, p), e), l/T).

    Returns ``sample(logits (E, C, V), slot_key (2,), positions (C,)) ->
    (E, C) int32``.  Greedy (T<=0) is a plain argmax."""

    def sample(logits, slot_key, positions):
        if temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        E = logits.shape[0]

        def one(p, ls):  # (), (E, V)
            kp = jax.random.fold_in(slot_key, p)
            return jax.vmap(
                lambda e, l: jax.random.categorical(
                    jax.random.fold_in(kp, e), l / temperature
                )
            )(jnp.arange(E), ls)

        return jax.vmap(one, in_axes=(0, 1), out_axes=1)(
            positions, logits
        ).astype(jnp.int32)

    return sample
