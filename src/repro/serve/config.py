"""ServeConfig: the one place serving-tuning knobs live.

Before this module the three serving entrypoints
(``ServingEngine.serve_continuous``, ``CascadeServer.serve_continuous``,
``ServingEngine.slot_stream`` / ``SlotStream`` construction) each
re-declared the same eight tuning kwargs (``n_slots``, ``max_seq``,
``seed``, ``chunked_prefill``, ``paged``, ``page_size``, ``n_pages``,
``obs`` — plus ``max_chunk``), so adding a knob meant editing every
signature and drift between them was invisible.  ``ServeConfig`` is the
consolidated value object all of them (and the open-loop
``CascadeServer.serve_open_loop``) accept as ``config=``.

Legacy kwargs keep working through ONE deprecation pathway:
``resolve_serve_config`` is the single function that maps old-style
keyword arguments onto a ``ServeConfig`` (warning once per process), and
every entrypoint routes through it — there is no second place where the
legacy names are interpreted, so the mapping cannot fork.  Passing BOTH a
``config`` and explicit legacy kwargs is a ``TypeError``: a call site is
either migrated or it is not.

No behavior change: an old-style call and its ``ServeConfig`` spelling
resolve to identical field values, drive identical code, and produce
bitwise-identical generations (regression-tested old-vs-new in
``tests/test_serve_config.py``).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Optional

from repro.obs import Observability


class _Unset:
    """Sentinel distinguishing 'kwarg not passed' from any real value
    (``None`` is meaningful for ``max_seq``/``paged``/``n_pages``/``obs``)."""

    __slots__ = ()

    def __repr__(self):
        return "<unset>"


UNSET = _Unset()


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Tuning knobs shared by every serving entrypoint.

    ``max_seq=None`` keeps each entrypoint's historical default (the
    engine's own ``max_seq``; 256 for the cascade drivers).  ``paged=None``
    auto-selects block-paged KV pools wherever the family supports them
    (``paged=False`` keeps the dense slot cache as the parity oracle);
    ``n_pages=None`` sizes pools at dense-equivalent capacity plus the
    overflow sink.  ``seed`` feeds the per-tier sampling keys (cascade
    tiers only — the single engine holds its own rng).  ``obs=None`` gives
    each component the private-bundle legacy behavior; pass one
    ``Observability`` to unify the registry/trace across the run."""

    n_slots: int = 8
    max_seq: Optional[int] = None
    seed: int = 0
    chunked_prefill: bool = True
    max_chunk: int = 256
    paged: Optional[bool] = None
    page_size: int = 16
    n_pages: Optional[int] = None
    obs: Optional[Observability] = None
    # cascade-as-drafter speculative decoding (serve/speculative.py,
    # DESIGN.md §13): deferrals carry the fast tier's agreeing generation
    # as a draft, verified by the next tier in one chunked pass.  Output
    # tokens are bitwise-identical either way (at any temperature); the
    # knob only trades a verify pass for per-token decode steps.  New-style
    # only — there is no legacy kwarg for it.
    speculative: bool = False

    def with_max_seq_default(self, default: int) -> "ServeConfig":
        """This config with ``max_seq=None`` resolved to the caller's
        historical default (the engine's ``self.max_seq``, the cascade's
        256) — the one per-entrypoint difference the consolidation keeps."""
        if self.max_seq is not None:
            return self
        return dataclasses.replace(self, max_seq=int(default))

    def resolved_obs(self) -> Observability:
        """The run's telemetry bundle: the configured one, or a fresh
        private bundle (the legacy fresh-per-run default)."""
        return self.obs if self.obs is not None else Observability.private()


_FIELD_NAMES = tuple(f.name for f in dataclasses.fields(ServeConfig))

# one warning per process: the single deprecation pathway stays quiet after
# its first firing so legacy-heavy suites are not drowned in repeats
_warned_legacy = False


def _reset_legacy_warning() -> None:
    """Test hook: re-arm the once-per-process deprecation warning."""
    global _warned_legacy
    _warned_legacy = False


def resolve_serve_config(
    config: Optional[ServeConfig], caller: str, **legacy
) -> ServeConfig:
    """THE deprecation pathway: fold legacy serving kwargs into a
    ``ServeConfig``.

    ``legacy`` values are either ``UNSET`` (kwarg not passed — the
    ``ServeConfig`` field default applies) or the caller-supplied value.
    With ``config`` given, any explicitly-passed legacy kwarg is a
    ``TypeError`` — mixing the two styles would make precedence ambiguous.
    With only legacy kwargs, a ``DeprecationWarning`` fires once per
    process pointing at the ``config=ServeConfig(...)`` spelling."""
    explicit = {k: v for k, v in legacy.items() if v is not UNSET}
    unknown = set(explicit) - set(_FIELD_NAMES)
    assert not unknown, f"{caller}: unknown serving kwargs {sorted(unknown)}"
    if config is not None:
        if explicit:
            raise TypeError(
                f"{caller}: pass config=ServeConfig(...) OR legacy kwargs, "
                f"not both (got legacy {sorted(explicit)})"
            )
        return config
    if explicit:
        global _warned_legacy
        if not _warned_legacy:
            _warned_legacy = True
            warnings.warn(
                f"{caller}: individual serving kwargs "
                f"({', '.join(sorted(explicit))}) are deprecated — pass "
                "config=repro.serve.ServeConfig(...) instead (the legacy "
                "names map onto the same fields, behavior unchanged)",
                DeprecationWarning,
                stacklevel=3,
            )
    return ServeConfig(**explicit)
