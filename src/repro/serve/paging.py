"""Host-side bookkeeping for block-paged KV pools (the serving memory wall
fix): a fixed pool of ``n_pages`` fixed-size blocks per cache leaf, a
per-slot page table, refcounted prefix sharing, and copy-on-write.

Device memory holds ONE pool tensor per cache leaf, shaped
``(n_pages, KVH, page_size, hd)`` (tiers add a leading E plane — members
score the same tokens at the same positions, so one page table serves all
E members and every shared page is an E-fold saving).  This module owns
only the *table*: which pool page backs which ``page_size``-token span of
which slot.  All methods are plain-python/numpy — allocation decisions are
host control flow that steers traced programs, never traced math.

Layout contract (what makes paged == dense bitwise):

* ``page_size`` must divide ``max_seq``; a slot's gathered view is always
  exactly ``pages_per_slot * page_size == max_seq`` rows, so the attention
  reduction runs over the same S lanes in the same order as the dense slot
  cache.  Unmapped (-1) table entries gather as zero rows; they are masked
  to exactly ``-1e30`` logits, whose softmax weight underflows to exactly
  0.0 — the same mechanism that hides a dense slot's stale rows.
* the last pool page is a sacrificial overflow sink, never allocated: a
  decode write against an unmapped row (an inactive slot, or a slot being
  force-completed this step) lands there harmlessly.

Prefix sharing: at admission, the prompt's leading FULL pages are keyed by
a crc32 chain over their tokens (deterministic across processes — see
``stable_digest``'s rationale) and looked up in the pool's prefix index.
A hit increments the page's refcount instead of allocating; a miss
allocates and registers the page once its contents are written (chunked
prefill writes the whole prefix before any sharer can be admitted, and
device programs execute in dispatch order, so a sharer's reads always see
the owner's writes).  Decode-only admission skips sharing entirely — its
prefix pages fill one token per step, so registering them at admission
would expose unwritten rows.

Copy-on-write: a slot never writes a page it shares (``refcount > 1``) —
``prepare`` hands the backend a (src, dst) device copy and repoints the
slot's table entry first.  In the serving flow this cannot trigger (shared
pages are fully-covered prompt prefixes; a slot's first write lands at
``len(tokens) - 1``, which is always past its last shared page), but the
guard keeps the pool correct under any direct-API write pattern, and
``prepare`` also unregisters a solo-owned registered page before its owner
writes into it, so future sharers can never pick up a mutated page.
"""
from __future__ import annotations

import zlib
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.obs import Observability, StatsView


def prefix_page_keys(tokens, page_size: int, n_pages: int) -> List[int]:
    """Chain-crc32 keys for the first ``n_pages`` full pages of a prompt:
    key i digests tokens[0 : (i+1)*page_size], so equal keys mean equal
    whole prefixes (not just equal pages at the same index)."""
    # abclint: disable=ABC203(prompt tokens are a host list; hashing precedes any device work)
    toks = np.ascontiguousarray(np.asarray(tokens, np.int32)).astype("<i4")
    keys, crc = [], 0
    for i in range(n_pages):
        crc = zlib.crc32(toks[i * page_size : (i + 1) * page_size].tobytes(), crc)
        keys.append(crc)
    return keys


class PagePool:
    """Free-list page allocator + per-slot page table + prefix index.

    ``table`` is the (n_slots, pages_per_slot) int32 page-table array the
    decode/prefill programs consume directly (-1 = unmapped); it is plain
    numpy, re-asarray'd per dispatch — table contents are traced data, so
    reshaping the mapping never retraces anything.
    """

    def __init__(self, n_pages: int, page_size: int, *, n_slots: int,
                 max_seq: int, obs: Optional[Observability] = None,
                 name: str = "paging"):
        if max_seq % page_size != 0:
            raise ValueError(
                f"page_size {page_size} must divide max_seq {max_seq} "
                "(the gathered slot view must be exactly max_seq rows)"
            )
        if n_pages < 2:
            raise ValueError(f"need >= 2 pages (1 overflow sink), got {n_pages}")
        self.n_pages = n_pages
        self.page_size = page_size
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.pages_per_slot = max_seq // page_size
        self.overflow_page = n_pages - 1  # sacrificial sink, never allocated
        self.table = np.full((n_slots, self.pages_per_slot), -1, np.int32)
        self.refcount = np.zeros(n_pages, np.int32)
        # LIFO free list over the allocatable pages [0, n_pages - 1)
        self._free: List[int] = list(range(n_pages - 2, -1, -1))
        self._prefix_index: Dict[int, int] = {}  # chain key -> page
        self._page_key: Dict[int, int] = {}  # page -> chain key (registered)
        # registry-backed accounting (DESIGN.md §11): counters for the
        # allocator events, gauges for occupancy (peak = the old
        # peak_pages_in_use) and cross-slot sharing; ``stats`` is the
        # legacy read-only view over them
        self.obs = obs if obs is not None else Observability.private()
        sc = self.obs.scope(name)
        self._c_allocated = sc.counter("allocated")
        self._c_freed = sc.counter("freed")
        self._c_shared_hits = sc.counter("shared_hits")
        self._c_cow = sc.counter("cow_copies")
        self._c_admit_failures = sc.counter("admit_failures")
        self._g_occupancy = sc.gauge("pool_occupancy")
        self._g_sharing = sc.gauge("shared_pages_saved")
        self.stats = StatsView({
            "allocated": lambda: self._c_allocated.value,
            "freed": lambda: self._c_freed.value,
            "shared_hits": lambda: self._c_shared_hits.value,
            "cow_copies": lambda: self._c_cow.value,
            "admit_failures": lambda: self._c_admit_failures.value,
            "peak_pages_in_use": lambda: self._g_occupancy.peak,
        })

    # -- accounting --------------------------------------------------------
    @property
    def pages_in_use(self) -> int:
        return (self.n_pages - 1) - len(self._free)

    @property
    def free_pages(self) -> int:
        return len(self._free)

    def shared_pages_saved(self) -> int:
        """Cross-slot page copies avoided RIGHT NOW: sum of (refcount - 1)
        over shared pages.  Each is additionally an E-fold saving on a tier
        pool — every member plane skips its copy of the page."""
        return int(np.sum(np.maximum(self.refcount - 1, 0)))

    def assert_conserved(self):
        """Refcount conservation: every page's refcount equals its table
        occurrences; free pages are unreferenced and never mapped; the
        overflow sink is never allocated or mapped."""
        counts = np.bincount(
            self.table[self.table >= 0].ravel(), minlength=self.n_pages
        )
        assert np.array_equal(counts, self.refcount), (counts, self.refcount)
        for pg in self._free:
            assert self.refcount[pg] == 0, (pg, self.refcount[pg])
        assert len(set(self._free)) == len(self._free), "free list duplicates"
        assert self.refcount[self.overflow_page] == 0
        assert self.overflow_page not in self._free
        for key, pg in self._prefix_index.items():
            assert self._page_key.get(pg) == key and self.refcount[pg] > 0
        for pg, key in self._page_key.items():
            assert self._prefix_index.get(key) == pg, (pg, key)

    # -- allocator core ----------------------------------------------------
    def _alloc(self) -> Optional[int]:
        if not self._free:
            return None
        pg = self._free.pop()
        self.refcount[pg] = 1
        self._c_allocated.add(1)
        self._g_occupancy.set(self.pages_in_use)
        return pg

    def _unregister(self, pg: int):
        key = self._page_key.pop(pg, None)
        if key is not None:
            del self._prefix_index[key]

    def _decref(self, pg: int):
        assert self.refcount[pg] > 0, pg
        self.refcount[pg] -= 1
        if self.refcount[pg] == 0:
            self._unregister(pg)
            self._free.append(pg)
            self._c_freed.add(1)
            self._g_occupancy.set(self.pages_in_use)

    # -- slot lifecycle ----------------------------------------------------
    def admit(self, slot: int, tokens, *, share: bool = True) -> Optional[int]:
        """Map pages for a new occupant of ``slot``; returns the number of
        prompt tokens covered by shared prefix pages (0 if none), or None
        when the pool cannot cover the prompt — the admission must be
        retried later, the table row is left empty.

        Pages are mapped for positions [0, len(tokens) - 1] inclusive: the
        prompt's prefill span plus the last prompt token's decode write.
        With ``share``, the leading full pages first consult the prefix
        index (hit -> refcount bump) and misses are registered for future
        sharers; ``share=False`` (decode-only admission) always allocates
        private pages and registers nothing."""
        row = self.table[slot]
        assert np.all(row < 0), f"slot {slot} admitted while still mapped"
        ps = self.page_size
        m = len(tokens) - 1  # prefill span; first decode write lands at m
        n_need = m // ps + 1
        n_full = m // ps  # pages fully covered by the prefill span [0, m)
        keys = prefix_page_keys(tokens, ps, n_full) if share else []
        shared = 0
        mapped: List[int] = []  # Python-int mirror of the row being built
        for i, key in enumerate(keys):
            pg = self._prefix_index.get(key)
            if pg is None:
                break
            row[i] = pg
            mapped.append(pg)
            self.refcount[pg] += 1
            shared = i + 1
            self._c_shared_hits.add(1)
            self._g_sharing.set(self.shared_pages_saved())
        for i in range(shared, n_need):
            pg = self._alloc()
            if pg is None:
                # roll the whole admission back; the caller re-queues
                for j in range(i):
                    self._decref(mapped[j])
                    row[j] = -1
                self._c_admit_failures.add(1)
                return None
            row[i] = pg
            mapped.append(pg)
        if share:
            for i in range(shared, n_full):
                # never steal a live entry: a key can already be registered
                # to another page after a defensive unregister broke the
                # chain above it (unreachable in serving, where registered
                # pages never mutate, but the pool stays consistent anyway)
                if keys[i] not in self._prefix_index:
                    self._prefix_index[keys[i]] = mapped[i]
                    self._page_key[mapped[i]] = keys[i]
        return shared * ps

    def extend(self, slot: int, n_rows: int) -> bool:
        """Map PRIVATE pages so rows ``[0, n_rows)`` of ``slot`` are all
        covered — the speculative verify pass writes draft KV rows past the
        admission span (serve/speculative.py).  Extension pages are never
        looked up in, or registered with, the prefix index: their contents
        are provisional until the acceptance decision, so they must not be
        visible to sharers (COW-safety is structural — registration only
        ever covers the admission prefix, which verify never writes).

        Returns False (rolling back its OWN allocations only) when the pool
        cannot cover the span; the caller falls back to plain admission."""
        row = self.table[slot]
        mapped = row.tolist()
        n_need = (n_rows - 1) // self.page_size + 1
        assert n_need <= self.pages_per_slot, (n_rows, self.max_seq)
        added: List[Tuple[int, int]] = []  # (table index, page) this call mapped
        for i in range(n_need):
            if mapped[i] >= 0:
                continue
            pg = self._alloc()
            if pg is None:
                for j, old in added:
                    self._decref(old)
                    row[j] = -1
                self._c_admit_failures.add(1)
                return False
            row[i] = pg
            added.append((i, pg))
        return True

    def truncate(self, slot: int, keep_rows: int):
        """Unmap every page of ``slot`` wholly past rows ``[0, keep_rows)``
        — the speculative rollback.  The page holding row ``keep_rows - 1``
        stays mapped (it carries live rows; any stale tail rows inside it
        are pos-masked and overwritten by subsequent decode writes), so the
        gathered view of the kept span is untouched."""
        row = self.table[slot]
        mapped = row.tolist()
        first = 0 if keep_rows <= 0 else (keep_rows - 1) // self.page_size + 1
        for i in range(first, self.pages_per_slot):
            if mapped[i] >= 0:
                self._decref(mapped[i])
                row[i] = -1
        self._g_sharing.set(self.shared_pages_saved())

    def release(self, slot: int):
        """Unmap the slot: decref every page; zero-ref pages return to the
        free list (registered ones leave the prefix index with them)."""
        row = self.table[slot]
        for pg in row.tolist():
            if pg >= 0:
                self._decref(pg)
        row[:] = -1
        self._g_sharing.set(self.shared_pages_saved())

    def prepare(self, slot: int, pos: int) -> Tuple[bool, List[Tuple[int, int]]]:
        """Make position ``pos`` of ``slot`` writable before a decode step.

        Returns (ok, copies): ``ok`` False means the pool is exhausted (the
        slot must be force-completed); ``copies`` lists (src, dst) device
        page copies the backend must execute (copy-on-write splits)."""
        i = pos // self.page_size
        pg = self.table[slot].tolist()[i]
        if pg < 0:
            new = self._alloc()
            if new is None:
                return False, []
            self.table[slot, i] = new
            return True, []
        if self.refcount[pg] > 1:
            new = self._alloc()
            if new is None:
                return False, []
            self.refcount[pg] -= 1  # still shared by the remaining owners
            self.table[slot, i] = new
            self._c_cow.add(1)
            return True, [(pg, new)]
        # solo-owned: if registered, unregister before the owner mutates it
        self._unregister(pg)
        return True, []
