"""Greedy online admission controller for open-loop serving (DESIGN.md §12).

The controller closes the loop the PR-8 registry opened: every signal it
reads is a streaming registry metric the serving path already records —
no new plumbing, no device traffic, no host syncs.  Each control interval
(``ControllerConfig.interval_s`` of VIRTUAL time — the open-loop driver
ticks it, so control decisions replay bit-for-bit with the trace) it reads:

    slot_stream.tier{i}.queue_depth      ready-queue backlog (gauge)
    cascade.tier{i}.answered/deferred    per-tier exit counts (counters;
                                         the controller differences them
                                         into per-interval rates)
    cascade.tier{i}.agreement_margin     vote-share histogram
    serve.request_latency_s              request latency histogram (p50/p99)
    serve.open_loop.completed            completion count -> throughput EMA

and actuates at the admission point only (never at a slot mid-decode):

  * **deferral-threshold offsets** — ``run.theta_offset[i]`` shifts tier
    i's effective theta (``vote_frac <= clamp(theta + offset, 0, 1)``).
    Under backlog with a deferral-dominated exit mix, lowering theta keeps
    more answers at the cheap tier (vote fractions are quantized at k
    members, so one ``theta_step`` can retire a whole defer band); offsets
    recover toward 0 when the backlog clears.
  * **per-tier slot caps** — ``SlotStream.set_slot_limit`` shifts the slot
    budget toward the backlogged tier within the paged-pool budget;
    lowered limits drain naturally (admission-side actuation only).
  * **admission shedding** — ``should_shed`` estimates a new arrival's
    queue wait from the backlog and the completion-rate EMA; when the
    estimate exceeds ``slo_s * shed_margin`` the driver marks the request
    ``shed=True`` and returns it to the caller (never a silent drop).
    Shedding is disabled until the first completions exist — the
    controller never sheds blind at cold start.

Every actuation appends to ``controller.actions`` (a host-side audit log
the bench and tests read) and mirrors into ``controller.*`` registry
metrics.  Determinism (abclint ABC3xx): the module takes time as the
``now_s`` argument the driver passes from the virtual clock — there is no
wall-clock read and no RNG anywhere in the control path.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional


@dataclasses.dataclass(frozen=True)
class ControllerConfig:
    """Greedy-controller tuning knobs (all in virtual-time units).

    ``backlog_slots`` is the overload watermark in units of the tier's
    slot count (queue deeper than ``backlog_slots * n_slots`` = overload);
    ``shift_hysteresis`` is the queue-depth gap (in requests) that
    justifies moving one slot of admission budget between adjacent tiers;
    ``shed_margin`` scales the SLO before the estimated queue wait is
    declared hopeless (1.0 = shed exactly at the deadline estimate)."""

    interval_s: float = 0.25
    backlog_slots: float = 2.0
    theta_step: float = 0.35  # one step clears a whole vote band at k=3
    theta_min_offset: float = -1.0
    shift_hysteresis: int = 4
    shed_margin: float = 2.0
    rate_ema: float = 0.5  # weight of the newest completion-rate sample


class GreedyController:
    """Reads registry signals, actuates admission — see module docstring.

    Lifecycle: construct (optionally with a ``ControllerConfig``), pass to
    ``CascadeServer.serve_open_loop(..., controller=...)``; the driver
    calls ``bind`` once (resolving every metric handle against the run's
    registry), then ``should_shed()`` per arrival and ``tick(now_s)`` per
    control interval.  One controller drives one run — bind again (or
    build a fresh one) for the next."""

    def __init__(self, config: Optional[ControllerConfig] = None):
        self.config = config if config is not None else ControllerConfig()
        self.actions: List[dict] = []
        self.run = None

    # -- binding -----------------------------------------------------------
    def bind(self, run, *, slo_s: float) -> None:
        """Resolve metric handles once against the run's registry (the
        record-per-event / resolve-at-construction registry discipline)."""
        self.run = run
        self.slo_s = float(slo_s)
        self.actions = []
        reg = run.ob.registry
        n = len(run.streams)
        self._g_queue = [
            reg.gauge(f"slot_stream.tier{i}.queue_depth") for i in range(n)
        ]
        self._c_answered = [
            reg.counter(f"cascade.tier{i}.answered") for i in range(n)
        ]
        self._c_deferred = [
            reg.counter(f"cascade.tier{i}.deferred") for i in range(n)
        ]
        self._h_margin = [
            reg.histogram(f"cascade.tier{i}.agreement_margin")
            for i in range(n)
        ]
        self._h_lat = reg.histogram("serve.request_latency_s")
        self._c_completed = reg.counter("serve.open_loop.completed")
        sc = run.ob.scope("controller")
        self._c_ticks = sc.counter("ticks")
        self._c_shed_decisions = sc.counter("shed_decisions")
        self._g_theta = [sc.gauge(f"theta_offset.tier{i}") for i in range(n)]
        self._g_limit = [sc.gauge(f"slot_limit.tier{i}") for i in range(n)]
        for i, st in enumerate(run.streams):
            self._g_limit[i].set(st.slot_limit)
        # interval-differencing state (counters are cumulative)
        self._last_t: Optional[float] = None
        self._last_completed = self._c_completed.value
        self._last_answered = [c.value for c in self._c_answered]
        self._last_deferred = [c.value for c in self._c_deferred]
        self._rate: Optional[float] = None  # completions/s EMA

    def _record(
        self, now_s: float, action: str, tier: int, value, **extra
    ) -> None:
        self.actions.append(
            {"t_s": now_s, "action": action, "tier": tier, "value": value,
             **extra}
        )

    # -- per-arrival shed decision -----------------------------------------
    def should_shed(self) -> bool:
        """True when a new arrival's estimated queue wait already busts the
        SLO: backlog / completion-rate-EMA > slo_s * shed_margin.  The
        caller (the open-loop driver) marks and returns the request — the
        controller only decides."""
        if self._rate is None or self._rate <= 0.0:
            return False  # no throughput signal yet: never shed blind
        q0 = self._g_queue[0].value
        if q0 <= self.run.streams[0].n_slots:
            return False  # backlog fits the slot set: admission is cheap
        est_wait_s = q0 / self._rate
        if est_wait_s > self.slo_s * self.config.shed_margin:
            self._c_shed_decisions.add(1)
            return True
        return False

    # -- per-interval control step -----------------------------------------
    def tick(self, now_s: float) -> None:
        """One greedy control step at virtual time ``now_s``: refresh the
        throughput EMA, then actuate theta offsets and slot caps from this
        interval's signal deltas."""
        cfg = self.config
        run = self.run
        dt = (
            now_s - self._last_t
            if self._last_t is not None else cfg.interval_s
        )
        dt = max(dt, 1e-9)
        comp = self._c_completed.value
        sample = (comp - self._last_completed) / dt
        self._rate = (
            sample if self._rate is None
            else (1.0 - cfg.rate_ema) * self._rate + cfg.rate_ema * sample
        )
        self._last_completed = comp
        self._last_t = now_s
        n = len(run.streams)
        q = [g.value for g in self._g_queue]
        # the tail-latency overload signal: once observed p99 busts the
        # SLO, even a moderate backlog is already too deep
        hot = self._h_lat.count > 0 and self._h_lat.percentile(0.99) > self.slo_s
        # theta offsets: only tiers that CAN defer (the last tier always
        # answers) are actuated
        for i in range(n - 1):
            n_slots = run.streams[i].n_slots
            d_ans = self._c_answered[i].value - self._last_answered[i]
            d_dfr = self._c_deferred[i].value - self._last_deferred[i]
            self._last_answered[i] = self._c_answered[i].value
            self._last_deferred[i] = self._c_deferred[i].value
            overloaded = q[i] > cfg.backlog_slots * n_slots or (
                hot and q[i] > n_slots
            )
            off = run.theta_offset[i]
            if overloaded and d_dfr >= d_ans:
                # backlog and the interval's exit mix is deferral-dominated
                # (a zero-exit interval mid-burst counts: the backlog IS
                # the evidence): keep more answers at this tier by lowering
                # its effective theta
                new = max(cfg.theta_min_offset, off - cfg.theta_step)
            elif not overloaded and q[i] == 0 and off < 0.0:
                # backlog cleared: recover toward the configured theta
                new = min(0.0, off + cfg.theta_step)
            else:
                new = off
            if new != off:
                run.theta_offset[i] = new
                self._g_theta[i].set(new)
                # the tier's observed mean vote share rides along in the
                # audit log: it is the quality price of the offset (1.0 =
                # members were unanimous anyway, the offset is free)
                self._record(
                    now_s, "theta_offset", i, new,
                    mean_margin=self._h_margin[i].mean,
                )
        # slot budget: shift one slot of admission cap toward the
        # backlogged side of each tier boundary (total cap never grows —
        # the paged-pool budget is the ceiling)
        for i in range(n - 1):
            lo, hi = run.streams[i], run.streams[i + 1]
            if q[i] > q[i + 1] + cfg.shift_hysteresis and hi.slot_limit > 1:
                hi.set_slot_limit(hi.slot_limit - 1)
                lo.set_slot_limit(lo.slot_limit + 1)
            elif q[i + 1] > q[i] + cfg.shift_hysteresis and lo.slot_limit > 1:
                lo.set_slot_limit(lo.slot_limit - 1)
                hi.set_slot_limit(hi.slot_limit + 1)
            else:
                continue
            for j, st in ((i, lo), (i + 1, hi)):
                if self._g_limit[j].value != st.slot_limit:
                    self._g_limit[j].set(st.slot_limit)
                    self._record(now_s, "slot_limit", j, st.slot_limit)
        self._c_ticks.add(1)
