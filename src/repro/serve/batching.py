"""Request batching: static-shape buckets (pad to powers of two) so the
jitted prefill/decode programs are reused across batches."""
from __future__ import annotations

import dataclasses
import itertools
from collections import deque
from typing import List, Optional

import numpy as np

from repro.core.cascade import bucket_size

_ids = itertools.count()


@dataclasses.dataclass
class Request:
    """One serving request: an (S,) int32 prompt plus a generation budget.
    The engine fills ``output`` (the generated tokens), ``tier`` (which
    cascade tier answered, -1 outside a cascade) and ``truncated``.  In a
    placed cascade, ``tokens`` is the ONLY payload a deferral re-queue
    sends across a tier boundary (serve/transport.py bytes contract)."""

    tokens: np.ndarray  # (S,) int32 prompt
    max_new_tokens: int = 16
    rid: int = dataclasses.field(default_factory=lambda: next(_ids))
    # filled by the engine:
    output: Optional[np.ndarray] = None
    tier: int = -1
    # True when the slot hit the cache wall (pos >= max_seq - 1) before the
    # full max_new_tokens budget was generated: ``output`` is short, not
    # silently complete.
    truncated: bool = False
    # True when open-loop admission control rejected the request under
    # overload: it still comes back to the caller (never silently dropped),
    # with ``output=None`` and this flag set.
    shed: bool = False
    # speculative deferral (serve/speculative.py): the previous tier's
    # agreeing generation, set by the cascade when ``ServeConfig.
    # speculative`` is on.  Consumed (and cleared) at admission by the
    # receiving SlotStream's verify pass; rides the deferral hop as part
    # of the metered payload.
    draft: Optional[np.ndarray] = None


_pow2_at_least = bucket_size  # canonical bucket helper lives in core.cascade


class RequestQueue:
    """FIFO queue that emits fixed-shape batches."""

    def __init__(self, max_batch: int = 32, pad_token: int = 0):
        self.max_batch = max_batch
        self.pad_token = pad_token
        self._q: deque = deque()

    def submit(self, req: Request):
        """Enqueue one request (FIFO)."""
        self._q.append(req)

    def __len__(self):
        return len(self._q)

    def next_batch(self) -> Optional[List[Request]]:
        """Pop up to ``max_batch`` requests, or None when empty."""
        if not self._q:
            return None
        batch = []
        while self._q and len(batch) < self.max_batch:
            batch.append(self._q.popleft())
        return batch

    def pad_batch(self, batch: List[Request]):
        """Returns (tokens (B', S') int32, n_real) with B'/S' padded to
        powers of two (B' also padded so jit programs are reused)."""
        toks, _, n = self.pad_batch_with_starts(batch)
        return toks, n

    def pad_batch_with_starts(self, batch: List[Request]):
        """Like ``pad_batch`` but also returns the per-row prompt starts
        (B',) int32 — row i's prompt occupies columns [starts[i], S'); the
        engine feeds these to the attention left-pad carve-out so padded
        rows cannot attend across their prompt start."""
        n = len(batch)
        B = _pow2_at_least(n)
        S = _pow2_at_least(max(len(r.tokens) for r in batch))
        toks = np.full((B, S), self.pad_token, np.int32)
        starts = np.zeros((B,), np.int32)
        for i, r in enumerate(batch):
            toks[i, S - len(r.tokens):] = r.tokens  # right-align prompts
            starts[i] = S - len(r.tokens)
        for i in range(n, B):
            toks[i] = toks[n - 1]
            starts[i] = starts[n - 1]
        return toks, starts, n
