"""Single-model serving engine: persistent jitted prefill + decode programs.

Prompts in a batch are padded to a common length (left-aligned padding is
prepended so the *ends* of all prompts coincide — the causal mask then makes
pad tokens only able to pollute other pads' cache rows, not real tokens'
futures; per-request attention masks are a noted production extension).

Compile-once discipline: every jitted program lives in a module-level cache
keyed by the (hashable, frozen) ``ModelConfig`` — constructing a new
``ServingEngine`` (or ``CascadeTier``) for a config that has already served
traffic reuses the existing programs and their jit caches.  Each program
body bumps a trace counter as a Python side effect, which only runs when
jax actually (re)traces — ``trace_count()`` therefore measures compilations,
and the serving tests assert it stays flat across repeated same-shape calls.
"""
from __future__ import annotations

import collections
import functools
from types import SimpleNamespace
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import api
from repro.serve.batching import Request, RequestQueue

# ---------------------------------------------------------------------------
# compile-once program cache + trace accounting
# ---------------------------------------------------------------------------

_TRACE_COUNTS: collections.Counter = collections.Counter()


def trace_count(key: Optional[str] = None) -> int:
    """Total number of traces (= compilations) across all serving programs,
    or for one ``"<cfg.name>/<program>"`` key."""
    if key is None:
        return sum(_TRACE_COUNTS.values())
    return _TRACE_COUNTS[key]


def trace_counts() -> dict:
    return dict(_TRACE_COUNTS)


def _counted(key: str, fn):
    """Wrap ``fn`` so every jax trace of it bumps ``_TRACE_COUNTS[key]``.
    The increment is a host side effect inside the traced body: it fires
    exactly once per (re)trace and never during cached executions."""

    def wrapped(*args, **kw):
        _TRACE_COUNTS[key] += 1
        return fn(*args, **kw)

    return wrapped


@functools.lru_cache(maxsize=None)
def model_programs(cfg: ModelConfig) -> SimpleNamespace:
    """Long-lived jitted prefill/decode programs for one model config."""
    prefill = jax.jit(
        _counted(f"{cfg.name}/prefill", functools.partial(api.prefill, cfg=cfg))
    )
    decode = jax.jit(
        _counted(f"{cfg.name}/decode", functools.partial(api.decode_step, cfg=cfg))
    )
    return SimpleNamespace(prefill=prefill, decode=decode)


def grow_cache(cache, pad: int, cfg: ModelConfig, *, lead: int = 0):
    """Pad the sequence axis of an attention KV cache by ``pad`` positions.

    ``lead`` counts extra leading axes before the canonical cache layout
    (1 for stacked-ensemble caches).  SSM/RWKV state is constant-size, so
    those families are a no-op.
    """
    if pad <= 0:
        return cache
    if cfg.family in ("dense", "moe", "vlm"):
        # (L, B, KVH, S, hd): sequence axis 3 (+lead)
        ax = 3 + lead
        return {
            k: jnp.pad(v, [(0, pad) if i == ax else (0, 0) for i in range(v.ndim)])
            for k, v in cache.items()
        }
    if cfg.family == "hybrid":
        # per-invocation leaves: (B, KVH, S, hd) — sequence axis 2 (+lead)
        ax = 2 + lead
        cache = dict(cache)
        for k in ("attn_k", "attn_v"):
            cache[k] = [
                jnp.pad(c, [(0, pad) if i == ax else (0, 0) for i in range(c.ndim)])
                for c in cache[k]
            ]
        return cache
    return cache  # constant-state families (ssm_mamba2, ssm_rwkv6)


class ServingEngine:
    def __init__(
        self,
        cfg: ModelConfig,
        params,
        *,
        max_batch: int = 32,
        max_seq: int = 512,
        temperature: float = 0.0,
        seed: int = 0,
    ):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.temperature = temperature
        self._rng = jax.random.PRNGKey(seed)
        self.queue = RequestQueue(max_batch=max_batch)
        programs = model_programs(cfg)
        self._prefill = programs.prefill
        self._decode = programs.decode
        self.stats = {"prefill_tokens": 0, "decode_tokens": 0, "batches": 0}

    # -- low-level --------------------------------------------------------
    def classify(self, tokens: np.ndarray) -> np.ndarray:
        """Last-token logits as a classifier head: tokens (B, S) -> (B, V)."""
        logits, _ = self._prefill(self.params, {"tokens": jnp.asarray(tokens)})
        self.stats["prefill_tokens"] += tokens.size
        return np.asarray(logits)

    def _sample(self, logits: jax.Array) -> jax.Array:
        if self.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self._rng, k = jax.random.split(self._rng)
        return jax.random.categorical(k, logits / self.temperature).astype(jnp.int32)

    def generate(self, tokens: np.ndarray, max_new_tokens: int) -> np.ndarray:
        """Greedy/temperature generation: tokens (B, S) -> (B, max_new)."""
        B, S = tokens.shape
        total = S + max_new_tokens
        logits, cache = self._prefill(self.params, {"tokens": jnp.asarray(tokens)})
        self.stats["prefill_tokens"] += tokens.size
        cache = grow_cache(cache, total - S, self.cfg)
        out = []
        tok = self._sample(logits)[:, None]
        for t in range(max_new_tokens):
            out.append(np.asarray(tok)[:, 0])
            if t == max_new_tokens - 1:
                break
            logits, cache = self._decode(
                self.params, tok, cache, jnp.int32(S + t)
            )
            self.stats["decode_tokens"] += B
            tok = self._sample(logits)[:, None]
        return np.stack(out, axis=1)

    # -- continuous batching ----------------------------------------------
    def serve_continuous(
        self, requests: List[Request], *, n_slots: int = 8, max_seq: Optional[int] = None
    ) -> List[Request]:
        """Slot-based continuous batching: one decode step advances every
        active slot by one token at its OWN position (per-slot ``pos``
        vector; see decode_attention per-sequence lengths).  New requests
        are admitted into freed slots mid-stream; their prompts are
        consumed through the same decode program (decode-only admission —
        uniform shapes, one compiled program; chunked prefill admission is
        the production extension).  Repeated invocations reuse the
        module-level jitted decode — nothing is re-jitted per call.
        Returns the completed requests."""
        cfg = self.cfg
        assert not cfg.is_encoder
        if max_seq is None:
            max_seq = self.max_seq
        cache_boxed = api.init_cache(cfg, n_slots, max_seq)
        cache = jax.tree.map(lambda b: b.value, cache_boxed,
                             is_leaf=lambda x: hasattr(x, "axes"))

        queue = list(requests)
        done: List[Request] = []
        slot_req: List[Optional[Request]] = [None] * n_slots
        slot_consumed = np.zeros(n_slots, np.int64)  # prompt tokens fed
        slot_emitted = [list() for _ in range(n_slots)]
        pos = np.zeros(n_slots, np.int32)
        tok = np.zeros((n_slots, 1), np.int32)

        def admit(s):
            if not queue:
                slot_req[s] = None
                return
            r = queue.pop(0)
            slot_req[s] = r
            slot_consumed[s] = 1
            slot_emitted[s] = []
            pos[s] = 0
            tok[s, 0] = r.tokens[0]

        for s in range(n_slots):
            admit(s)

        while any(r is not None for r in slot_req):
            logits, cache = self._decode(
                self.params, jnp.asarray(tok), cache, jnp.asarray(pos)
            )
            nxt = np.asarray(self._sample(logits))
            self.stats["decode_tokens"] += int(sum(r is not None for r in slot_req))
            for s, r in enumerate(slot_req):
                if r is None:
                    continue
                pos[s] += 1
                if slot_consumed[s] < len(r.tokens):
                    # still feeding the prompt
                    tok[s, 0] = r.tokens[slot_consumed[s]]
                    slot_consumed[s] += 1
                else:
                    slot_emitted[s].append(int(nxt[s]))
                    tok[s, 0] = nxt[s]
                    if len(slot_emitted[s]) >= r.max_new_tokens or pos[s] >= max_seq - 1:
                        r.output = np.asarray(slot_emitted[s], np.int32)
                        done.append(r)
                        admit(s)
        return done

    # -- queue-driven serving --------------------------------------------
    def serve_pending(self) -> List[Request]:
        done = []
        while True:
            batch = self.queue.next_batch()
            if batch is None:
                return done
            toks, n = self.queue.pad_batch(batch)
            max_new = max(r.max_new_tokens for r in batch)
            gen = self.generate(toks, max_new)
            self.stats["batches"] += 1
            for i, r in enumerate(batch):
                r.output = gen[i, : r.max_new_tokens]
                done.append(r)
