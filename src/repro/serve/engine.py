"""Single-model serving engine: jitted prefill + decode loop.

Prompts in a batch are padded to a common length (left-aligned padding is
prepended so the *ends* of all prompts coincide — the causal mask then makes
pad tokens only able to pollute other pads' cache rows, not real tokens'
futures; per-request attention masks are a noted production extension).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import api
from repro.serve.batching import Request, RequestQueue


class ServingEngine:
    def __init__(
        self,
        cfg: ModelConfig,
        params,
        *,
        max_batch: int = 32,
        max_seq: int = 512,
        temperature: float = 0.0,
        seed: int = 0,
    ):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.temperature = temperature
        self._rng = jax.random.PRNGKey(seed)
        self.queue = RequestQueue(max_batch=max_batch)
        self._prefill = jax.jit(functools.partial(api.prefill, cfg=cfg))
        self._decode = jax.jit(functools.partial(api.decode_step, cfg=cfg))
        self.stats = {"prefill_tokens": 0, "decode_tokens": 0, "batches": 0}

    # -- low-level --------------------------------------------------------
    def classify(self, tokens: np.ndarray) -> np.ndarray:
        """Last-token logits as a classifier head: tokens (B, S) -> (B, V)."""
        logits, _ = self._prefill(self.params, {"tokens": jnp.asarray(tokens)})
        self.stats["prefill_tokens"] += tokens.size
        return np.asarray(logits)

    def _sample(self, logits: jax.Array) -> jax.Array:
        if self.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self._rng, k = jax.random.split(self._rng)
        return jax.random.categorical(k, logits / self.temperature).astype(jnp.int32)

    def generate(self, tokens: np.ndarray, max_new_tokens: int) -> np.ndarray:
        """Greedy/temperature generation: tokens (B, S) -> (B, max_new)."""
        B, S = tokens.shape
        total = S + max_new_tokens
        logits, cache = self._prefill(self.params, {"tokens": jnp.asarray(tokens)})
        self.stats["prefill_tokens"] += tokens.size
        # grow the kv cache to the full generation length
        # cache layout is (L/inv, B, KVH, S, hd) — pad the sequence axis (3)
        if self.cfg.family in ("dense", "moe", "vlm"):
            pad = total - cache["k"].shape[3]
            if pad > 0:
                cache = {
                    k2: jnp.pad(v2, ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0)))
                    for k2, v2 in cache.items()
                }
        elif self.cfg.family == "hybrid":
            # per-invocation caches: list of (B, K, S, hd)
            pad = total - cache["attn_k"][0].shape[2]
            if pad > 0:
                cache = dict(cache)
                for k2 in ("attn_k", "attn_v"):
                    cache[k2] = [
                        jnp.pad(c, ((0, 0), (0, 0), (0, pad), (0, 0)))
                        for c in cache[k2]
                    ]
        out = []
        tok = self._sample(logits)[:, None]
        for t in range(max_new_tokens):
            out.append(np.asarray(tok)[:, 0])
            if t == max_new_tokens - 1:
                break
            logits, cache = self._decode(
                self.params, tok, cache, jnp.int32(S + t)
            )
            self.stats["decode_tokens"] += B
            tok = self._sample(logits)[:, None]
        return np.stack(out, axis=1)

    # -- continuous batching ----------------------------------------------
    def serve_continuous(
        self, requests: List[Request], *, n_slots: int = 8, max_seq: Optional[int] = None
    ) -> List[Request]:
        """Slot-based continuous batching: one decode step advances every
        active slot by one token at its OWN position (per-slot ``pos``
        vector; see decode_attention per-sequence lengths).  New requests
        are admitted into freed slots mid-stream; their prompts are
        consumed through the same decode program (decode-only admission —
        uniform shapes, one compiled program; chunked prefill admission is
        the production extension).  Returns the completed requests."""
        from repro.models import api
        from repro.models.params import unbox as _unbox

        cfg = self.cfg
        assert not cfg.is_encoder
        if max_seq is None:
            max_seq = self.max_seq
        cache_boxed = api.init_cache(cfg, n_slots, max_seq)
        cache = jax.tree.map(lambda b: b.value, cache_boxed,
                             is_leaf=lambda x: hasattr(x, "axes"))
        decode = jax.jit(functools.partial(api.decode_step, cfg=cfg))

        queue = list(requests)
        done: List[Request] = []
        slot_req: List[Optional[Request]] = [None] * n_slots
        slot_consumed = np.zeros(n_slots, np.int64)  # prompt tokens fed
        slot_emitted = [list() for _ in range(n_slots)]
        pos = np.zeros(n_slots, np.int32)
        tok = np.zeros((n_slots, 1), np.int32)

        def admit(s):
            if not queue:
                slot_req[s] = None
                return
            r = queue.pop(0)
            slot_req[s] = r
            slot_consumed[s] = 1
            slot_emitted[s] = []
            pos[s] = 0
            tok[s, 0] = r.tokens[0]

        for s in range(n_slots):
            admit(s)

        while any(r is not None for r in slot_req):
            logits, cache = decode(
                self.params, jnp.asarray(tok), cache, jnp.asarray(pos)
            )
            nxt = np.asarray(self._sample(logits))
            self.stats["decode_tokens"] += int(sum(r is not None for r in slot_req))
            for s, r in enumerate(slot_req):
                if r is None:
                    continue
                pos[s] += 1
                if slot_consumed[s] < len(r.tokens):
                    # still feeding the prompt
                    tok[s, 0] = r.tokens[slot_consumed[s]]
                    slot_consumed[s] += 1
                else:
                    slot_emitted[s].append(int(nxt[s]))
                    tok[s, 0] = nxt[s]
                    if len(slot_emitted[s]) >= r.max_new_tokens or pos[s] >= max_seq - 1:
                        r.output = np.asarray(slot_emitted[s], np.int32)
                        done.append(r)
                        admit(s)
        return done

    # -- queue-driven serving --------------------------------------------
    def serve_pending(self) -> List[Request]:
        done = []
        while True:
            batch = self.queue.next_batch()
            if batch is None:
                return done
            toks, n = self.queue.pad_batch(batch)
            max_new = max(r.max_new_tokens for r in batch)
            gen = self.generate(toks, max_new)
            self.stats["batches"] += 1
            for i, r in enumerate(batch):
                r.output = gen[i, : r.max_new_tokens]
                done.append(r)
