"""Single-model serving engine: persistent jitted prefill + decode programs.

Prompts in a batch are padded to a common length (left-aligned padding is
prepended so the *ends* of all prompts coincide).  Per-request attention
masks carve the padding out entirely: ``pad_batch_with_starts`` records each
row's prompt start, and attention-family prefill/decode mask columns before
it while running RoPE relative to it — so padded-batch logits and
generations match the solo (unpadded) runs exactly, not just approximately.
Recurrent families sweep the whole sequence and keep the old
pads-pollute-only-pads contract.

Compile-once discipline: every jitted program lives in a module-level cache
keyed by the (hashable, frozen) ``ModelConfig`` — constructing a new
``ServingEngine`` (or ``CascadeTier``) for a config that has already served
traffic reuses the existing programs and their jit caches.  Each program
body bumps a trace counter as a Python side effect, which only runs when
jax actually (re)traces — ``trace_count()`` therefore measures compilations,
and the serving tests assert it stays flat across repeated same-shape calls.

Continuous batching lives in ``serve/slot_stream.py`` (the shared slot
state machine; see its docstring for the per-slot pos-masking / state-reset
contract).  ``ServingEngine.serve_continuous`` is the E=1 driver over it,
with chunked-prefill admission on by default.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
from types import SimpleNamespace
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.cascade import host_fetch
from repro.models import api
from repro.obs import Observability, StatsView
from repro.serve.batching import Request, RequestQueue
from repro.serve.config import UNSET, ServeConfig, resolve_serve_config

# ---------------------------------------------------------------------------
# compile-once program cache + trace accounting
# ---------------------------------------------------------------------------

_TRACE_COUNTS: collections.Counter = collections.Counter()


def trace_count(key: Optional[str] = None) -> int:
    """Total number of traces (= compilations) across all serving programs,
    or for one ``"<cfg.name>/<program>"`` key."""
    if key is None:
        return sum(_TRACE_COUNTS.values())
    return _TRACE_COUNTS[key]


def trace_counts() -> dict:
    """Per-program trace counts, keyed ``"<cfg.name>/<program>"``."""
    return dict(_TRACE_COUNTS)


def _counted(key: str, fn):
    """Wrap ``fn`` so every jax trace of it bumps ``_TRACE_COUNTS[key]``.
    The increment is a host side effect inside the traced body: it fires
    exactly once per (re)trace and never during cached executions."""

    def wrapped(*args, **kw):
        _TRACE_COUNTS[key] += 1
        return fn(*args, **kw)

    return wrapped


@functools.lru_cache(maxsize=None)
def model_programs(cfg: ModelConfig) -> SimpleNamespace:
    """Long-lived jitted programs for one model config.

    ``prefill``/``decode`` are the batch programs; ``prefill_chunk`` is the
    slot-stream chunked-prefill-into-slot program (traces once per pow2
    chunk length — the O(log S) bucket warmup) and ``reset_slot`` the
    constant-state slot zeroing program (families without recurrent slot
    state get ``None``: the per-slot pos mask already isolates them)."""
    prefill = jax.jit(
        _counted(f"{cfg.name}/prefill", functools.partial(api.prefill, cfg=cfg))
    )
    decode = jax.jit(
        _counted(f"{cfg.name}/decode", functools.partial(api.decode_step, cfg=cfg))
    )
    prefill_chunk = (
        jax.jit(
            _counted(
                f"{cfg.name}/prefill_chunk",
                functools.partial(api.prefill_into_slot, cfg=cfg),
            )
        )
        if api.supports_chunked_prefill(cfg)
        else None
    )
    reset_slot = (
        jax.jit(
            _counted(
                f"{cfg.name}/slot_reset",
                functools.partial(api.reset_slot, cfg=cfg),
            )
        )
        if api.has_slot_state(cfg)
        else None
    )
    return SimpleNamespace(
        prefill=prefill,
        decode=decode,
        prefill_chunk=prefill_chunk,
        reset_slot=reset_slot,
    )


@functools.lru_cache(maxsize=None)
def paged_model_programs(cfg: ModelConfig) -> SimpleNamespace:
    """Long-lived jitted block-paged serving programs for one config
    (families where ``api.supports_paging``): page-table decode, paged
    chunked prefill, and the copy-on-write page copy.  Page size and pool
    size are DATA shapes, not static arguments — a given (pool, table)
    geometry traces once and every allocator decision after that is just
    different int32 table contents."""
    assert api.supports_paging(cfg), cfg.family
    decode = jax.jit(
        _counted(
            f"{cfg.name}/decode_paged",
            functools.partial(api.decode_step_paged, cfg=cfg),
        )
    )
    prefill_chunk = jax.jit(
        _counted(
            f"{cfg.name}/prefill_chunk_paged",
            functools.partial(api.prefill_into_slot_paged, cfg=cfg),
        )
    )
    copy_page = jax.jit(
        _counted(f"{cfg.name}/copy_pool_page", api.copy_pool_page)
    )
    return SimpleNamespace(
        decode=decode, prefill_chunk=prefill_chunk, copy_page=copy_page
    )


def grow_cache(cache, pad: int, cfg: ModelConfig, *, lead: int = 0):
    """Pad the sequence axis of an attention KV cache by ``pad`` positions.

    ``lead`` counts extra leading axes before the canonical cache layout
    (1 for stacked-ensemble caches).  SSM/RWKV state is constant-size, so
    those families are a no-op.
    """
    if pad <= 0:
        return cache
    if cfg.family in ("dense", "moe", "vlm"):
        # (L, B, KVH, S, hd): sequence axis 3 (+lead)
        ax = 3 + lead
        return {
            k: jnp.pad(v, [(0, pad) if i == ax else (0, 0) for i in range(v.ndim)])
            for k, v in cache.items()
        }
    if cfg.family == "hybrid":
        # per-invocation leaves: (B, KVH, S, hd) — sequence axis 2 (+lead)
        ax = 2 + lead
        cache = dict(cache)
        for k in ("attn_k", "attn_v"):
            cache[k] = [
                jnp.pad(c, [(0, pad) if i == ax else (0, 0) for i in range(c.ndim)])
                for c in cache[k]
            ]
        return cache
    return cache  # constant-state families (ssm_mamba2, ssm_rwkv6)


class ServingEngine:
    """Single-model serving front end over the compile-once ``model_programs``:
    ``classify`` (last-token logits), ``generate`` (batch decode loop),
    ``serve_continuous`` (the E=1 ``SlotStream`` driver) and the
    queue-driven ``serve_pending``.  Holds the params, the sampling policy
    (temperature + rng), and per-engine token/batch counters in ``stats``;
    all jitted programs are shared module-level state."""

    def __init__(
        self,
        cfg: ModelConfig,
        params,
        *,
        max_batch: int = 32,
        max_seq: int = 512,
        temperature: float = 0.0,
        seed: int = 0,
        obs: Optional[Observability] = None,
    ):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.temperature = temperature
        self._rng = jax.random.PRNGKey(seed)
        self.queue = RequestQueue(max_batch=max_batch)
        programs = model_programs(cfg)
        self._prefill = programs.prefill
        self._decode = programs.decode
        # registry-backed counters (DESIGN.md §11); ``stats`` is the legacy
        # read-only dict view over them
        self.obs = obs if obs is not None else Observability.private()
        sc = self.obs.scope("engine")
        self._c_prefill = sc.counter("prefill_tokens")
        self._c_decode = sc.counter("decode_tokens")
        self._c_batches = sc.counter("batches")
        self.stats = StatsView({
            "prefill_tokens": lambda: self._c_prefill.value,
            "decode_tokens": lambda: self._c_decode.value,
            "batches": lambda: self._c_batches.value,
        })

    # -- low-level --------------------------------------------------------
    def _supports_starts(self) -> bool:
        return self.cfg.family in ("dense", "moe", "vlm")

    def _prefill_batch(self, tokens, starts):
        batch = {"tokens": jnp.asarray(tokens)}
        if starts is not None:
            assert self._supports_starts(), (
                f"left-pad carve-out unsupported for family {self.cfg.family}"
            )
            batch["starts"] = jnp.asarray(starts, jnp.int32)
        return batch

    def classify(self, tokens: np.ndarray, starts=None) -> np.ndarray:
        """Last-token logits as a classifier head: tokens (B, S) -> (B, V).
        ``starts`` (B,), optional: per-row prompt starts for left-padded
        batches (rows never attend across their own prompt start, RoPE runs
        relative to it — padded logits match solo logits)."""
        logits, _ = self._prefill(self.params, self._prefill_batch(tokens, starts))
        self._c_prefill.add(tokens.size)
        return host_fetch(logits)

    def _sample(self, logits: jax.Array) -> jax.Array:
        if self.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self._rng, k = jax.random.split(self._rng)
        return jax.random.categorical(k, logits / self.temperature).astype(jnp.int32)

    def generate(self, tokens: np.ndarray, max_new_tokens: int, starts=None) -> np.ndarray:
        """Greedy/temperature generation: tokens (B, S) -> (B, max_new).
        With ``starts``, the left-pad carve-out also rides every decode
        step (pad cache rows stay masked, RoPE stays prompt-relative), so a
        left-padded batch generates token-for-token what solo runs do."""
        B, S = tokens.shape
        total = S + max_new_tokens
        logits, cache = self._prefill(self.params, self._prefill_batch(tokens, starts))
        self._c_prefill.add(tokens.size)
        cache = grow_cache(cache, total - S, self.cfg)
        out = []
        tok = self._sample(logits)[:, None]
        dec_kw = {} if starts is None else {"starts": jnp.asarray(starts, jnp.int32)}
        for t in range(max_new_tokens):
            out.append(host_fetch(tok)[:, 0])
            if t == max_new_tokens - 1:
                break
            logits, cache = self._decode(
                self.params, tok, cache, jnp.int32(S + t), **dec_kw
            )
            self._c_decode.add(B)
            tok = self._sample(logits)[:, None]
        return np.stack(out, axis=1)

    # -- continuous batching ----------------------------------------------
    def slot_stream(
        self,
        config: Optional[ServeConfig] = None,
        *,
        n_slots=UNSET,
        max_seq=UNSET,
        chunked_prefill=UNSET,
        max_chunk=UNSET,
        paged=UNSET,
        page_size=UNSET,
        n_pages=UNSET,
        obs=UNSET,
    ):
        """A fresh ``SlotStream`` (serve/slot_stream.py) over this engine's
        compile-once programs — the E=1 instantiation of the shared slot
        state machine.  Takes a ``ServeConfig`` (``config=``) or the legacy
        kwargs (one deprecation pathway — serve/config.py).  ``paged``
        selects block-paged KV pools (default: wherever the family supports
        them; ``paged=False`` keeps the dense slot cache as the parity
        oracle); ``n_pages`` bounds pool HBM (default: dense-equivalent
        capacity plus the overflow sink).  ``obs`` shares a telemetry
        bundle with the stream and its pool (default: the stream keeps a
        private registry, preserving the fresh-per-stream legacy stats
        contract)."""
        from repro.serve.slot_stream import EngineBackend, SlotStream

        cfg = resolve_serve_config(
            config, "ServingEngine.slot_stream", n_slots=n_slots,
            max_seq=max_seq, chunked_prefill=chunked_prefill,
            max_chunk=max_chunk, paged=paged, page_size=page_size,
            n_pages=n_pages, obs=obs,
        ).with_max_seq_default(self.max_seq)
        backend = EngineBackend(
            self.cfg, self.params, model_programs(self.cfg), self._sample,
            n_slots=cfg.n_slots, max_seq=cfg.max_seq,
            prefill_counter=self._c_prefill,
            paged=cfg.paged, page_size=cfg.page_size, n_pages=cfg.n_pages,
            obs=cfg.obs,
        )
        return SlotStream(backend, cfg)

    def serve_continuous(
        self,
        requests: List[Request],
        config: Optional[ServeConfig] = None,
        *,
        n_slots=UNSET,
        max_seq=UNSET,
        chunked_prefill=UNSET,
        paged=UNSET,
        page_size=UNSET,
        n_pages=UNSET,
        obs=UNSET,
    ) -> List[Request]:
        """Slot-based continuous batching: a thin driver over ``SlotStream``
        (the E=1 case of the shared slot state machine).  One decode step
        advances every active slot by one token at its OWN position
        (per-slot ``pos`` vector; see decode_attention per-sequence
        lengths); freed slots admit new requests mid-stream, consuming
        ``prompt[:-1]`` through bucketed chunked prefill (or token-by-token
        through the decode program with ``chunked_prefill=False``).
        Takes a ``ServeConfig`` (``config=``) or the legacy kwargs (one
        deprecation pathway — serve/config.py; the two spellings are
        bitwise-equivalent).  Repeated invocations reuse the module-level
        jitted programs — nothing is re-jitted per call.  Requests cut
        short by the cache wall (``pos >= max_seq - 1``) come back with
        ``truncated=True``.  With ``obs``, the stream/pool record into the
        shared registry, each completion lands in the
        ``serve.request_latency_s`` histogram, and an enabled tracer gets
        the full per-request lifecycle plus the terminal ``complete``
        instant; without one, the stream records into the ENGINE's own
        registry (``self.obs``), so stream counters are never lost to an
        unreachable private bundle.  Returns the completed requests."""
        cfg = resolve_serve_config(
            config, "ServingEngine.serve_continuous", n_slots=n_slots,
            max_seq=max_seq, chunked_prefill=chunked_prefill, paged=paged,
            page_size=page_size, n_pages=n_pages, obs=obs,
        ).with_max_seq_default(self.max_seq)
        ob = cfg.obs if cfg.obs is not None else self.obs
        # the stream must record into the RESOLVED bundle: with obs=None the
        # engine's registry is the destination, not a private stream bundle
        # (regression: tests/test_serve_config.py::test_engine_stream_obs)
        stream = self.slot_stream(dataclasses.replace(cfg, obs=ob))
        clk = ob.clock
        h_lat = ob.registry.histogram("serve.request_latency_s")
        # counters in a shared registry are cumulative across serves — the
        # engine's decode credit and the legacy per-run ``last_stream_stats``
        # are this run's DELTA, not the running total
        st0 = dict(stream.stats)
        t_submit = {r.rid: clk() for r in requests}
        stream.submit(requests)
        done: List[Request] = []
        for r, gen in stream.drain():
            r.output = gen[0].astype(np.int32)  # gen is host-side (backend fetched)
            h_lat.record(clk() - t_submit[r.rid])
            if ob.tracer.enabled:
                ob.tracer.instant(r.rid, "complete", truncated=r.truncated)
            done.append(r)
        st1 = dict(stream.stats)
        self._c_decode.add(st1["decode_tokens"] - st0["decode_tokens"])
        self.last_stream_stats = {k: v - st0[k] for k, v in st1.items()}
        return done

    # -- queue-driven serving --------------------------------------------
    def serve_pending(self) -> List[Request]:
        """Drain ``self.queue`` batch-by-batch: each batch is padded to its
        pow2 bucket (``RequestQueue.pad_batch_with_starts`` — right-aligned
        prompts, per-row starts for the attention left-pad carve-out) and
        generated in one call.  Returns the completed requests."""
        done = []
        while True:
            batch = self.queue.next_batch()
            if batch is None:
                return done
            toks, starts, n = self.queue.pad_batch_with_starts(batch)
            max_new = max(r.max_new_tokens for r in batch)
            gen = self.generate(
                toks, max_new,
                starts=starts if self._supports_starts() else None,
            )
            self._c_batches.add(1)
            for i, r in enumerate(batch):
                r.output = gen[i, : r.max_new_tokens]
                done.append(r)
