"""Tier placement: which host (device set) serves each cascade tier.

The paper's deployment scenarios are all PLACEMENT statements: tier 1 on
the edge device and tier 2 in the cloud (§5.2.1), tiers on heterogeneous
GPUs (§5.2.2), tiers behind different API endpoints (§5.2.3).  A
``TierPlacement`` makes that a runtime object: each tier gets a ``Host``
(name + kind + optional jax submesh carved from the 'pod' axis of the
production mesh, DESIGN.md §3), and every tier boundary gets the
``Transport`` its deferrals must cross — ``None`` when both tiers share a
host (in-process hand-off, no metered traffic).

With a multi-pod mesh, ``pod_placement`` slices the 'pod' axis so tier i's
stacked ensemble weights live on pod slice i (``place_tier_values``
device_puts them there, 'ensemble' mapping onto the slice's 'pod' axis via
the logical rule table); deferral between tiers is then an explicit
transport hop instead of an implicit same-device handoff — by default a
``ShardedDevicePutTransport`` that lands the payload's example axis
SHARDED over the destination slice's ('pod', 'data') axes rather than
replicated (DESIGN.md §8).  On a single device the same code runs with
simulated hosts — the placement, transport metering, and routing logic are
identical, only the device sets coincide.

``edge_cloud`` additionally picks the link physics: the simulated-clock
link for metering-only benches, or the real-sleep ``AsyncTransport``
(overlapped or serial) for wall-clock overlap measurement — see its
docstring and DESIGN.md §8.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding

from repro.serve.transport import (
    AsyncTransport,
    DevicePutTransport,
    LoopbackTransport,
    ShardedDevicePutTransport,
    SimulatedLinkTransport,
    Transport,
)
from repro.sharding.logical import logical_to_pspec, make_rules


@dataclasses.dataclass(frozen=True)
class Host:
    """One placement target: a named device set (mesh may be None for
    simulated hosts — the routing and metering behave identically)."""

    name: str
    kind: str = "local"  # 'local' | 'edge' | 'cloud' | 'pod'
    mesh: Optional[Mesh] = None

    def devices(self):
        """This host's device set (empty for simulated hosts)."""
        return set(self.mesh.devices.flat) if self.mesh is not None else set()


@dataclasses.dataclass(frozen=True)
class TierPlacement:
    """hosts[i] serves tier i; links[i] is the transport tier i's deferrals
    take to tier i+1 (None = same host, in-process)."""

    hosts: Tuple[Host, ...]
    links: Tuple[Optional[Transport], ...]

    def __post_init__(self):
        assert len(self.links) == max(0, len(self.hosts) - 1), (
            f"{len(self.hosts)} hosts need {len(self.hosts) - 1} links, "
            f"got {len(self.links)}"
        )

    @property
    def n_tiers(self) -> int:
        """Number of placed tiers (== len(hosts))."""
        return len(self.hosts)

    def link(self, i: int) -> Optional[Transport]:
        """The transport tier i's deferrals cross to reach tier i+1
        (None = same host, unmetered in-process hand-off)."""
        return self.links[i]

    def transports(self) -> Tuple[Transport, ...]:
        """Distinct transport objects, for stats aggregation."""
        seen, out = set(), []
        for t in self.links:
            if t is not None and id(t) not in seen:
                seen.add(id(t))
                out.append(t)
        return tuple(out)

    def describe(self) -> str:
        """Human-readable tier chain, e.g. ``edge0(edge) -> cloud0(cloud)``."""
        parts = [f"{h.name}({h.kind})" for h in self.hosts]
        return " -> ".join(parts)


# ---------------------------------------------------------------------------
# constructors
# ---------------------------------------------------------------------------


def single_host(n_tiers: int, *, meter: bool = True) -> TierPlacement:
    """Every tier on one host.  With ``meter=True`` hops still go through a
    shared LoopbackTransport so tests can assert WHAT would cross a real
    boundary (only the compacted deferral payload) without paying one."""
    host = Host("local0", "local")
    link = LoopbackTransport() if meter else None
    return TierPlacement(
        hosts=(host,) * n_tiers, links=(link,) * max(0, n_tiers - 1)
    )


def edge_cloud(
    n_edge_tiers: int = 1,
    n_cloud_tiers: int = 1,
    *,
    delay="medium",
    bandwidth: Optional[float] = None,
    link: str = "sim",
) -> TierPlacement:
    """§5.2.1: the first ``n_edge_tiers`` tiers on-device, the rest in the
    cloud; intra-host hops are free.  ``link`` picks the edge→cloud
    boundary's physics (all three meter identical hops, DESIGN.md §8):

    ``'sim'``     SimulatedLinkTransport — latency is an accounted
                  simulated clock, ``send`` returns immediately (the fast
                  default for benches that only need metered traffic);
    ``'async'``   AsyncTransport — latency is real wall-clock sleep served
                  from a worker thread; ``serve_continuous`` overlaps edge
                  decode with the in-flight hop;
    ``'serial'``  AsyncTransport(overlap=False) — same real sleeps, but
                  every send blocks: the stop-the-world baseline the
                  measured overlap ratio compares against."""
    assert n_edge_tiers >= 1 and n_cloud_tiers >= 1
    edge = Host("edge0", "edge")
    cloud = Host("cloud0", "cloud")
    hosts = (edge,) * n_edge_tiers + (cloud,) * n_cloud_tiers
    if link == "sim":
        uplink = SimulatedLinkTransport(delay=delay, bandwidth=bandwidth)
    elif link in ("async", "serial"):
        uplink = AsyncTransport(
            delay=delay, bandwidth=bandwidth, overlap=(link == "async")
        )
    else:
        raise ValueError(f"unknown link kind: {link!r}")
    links = []
    for i in range(len(hosts) - 1):
        links.append(uplink if hosts[i] is not hosts[i + 1] else None)
    return TierPlacement(hosts=hosts, links=tuple(links))


def pod_placement(
    mesh: Mesh, n_tiers: int, *, shard_examples: bool = True
) -> TierPlacement:
    """Carve the 'pod' axis of a ('pod', 'data', 'model') mesh into one
    slice per tier: tier i's ensemble lives on pod slice i (disjoint device
    sets), and every tier boundary is a metered transport hop that
    re-places the compacted payload onto the next slice's devices.

    With ``shard_examples=True`` (the default, DESIGN.md §8) each hop is a
    ``ShardedDevicePutTransport``: the payload's example axis lands sharded
    over the destination slice's ('pod', 'data') axes, so per-device HBM
    residency on arrival is ``1/shard_count`` of the payload instead of a
    full replica.  ``shard_examples=False`` keeps the legacy pod-wide
    replication (``DevicePutTransport``) — the parity baseline
    (tests/test_placement_transport.py asserts both routes produce
    identical cascade results and meter identical bytes)."""
    from jax.sharding import PartitionSpec

    from repro.launch.mesh import pod_submeshes

    subs = pod_submeshes(mesh, n_tiers)
    hosts = tuple(
        Host(f"pod{i}", "pod", mesh=sub) for i, sub in enumerate(subs)
    )
    if shard_examples:
        links = tuple(
            ShardedDevicePutTransport(subs[i + 1]) for i in range(n_tiers - 1)
        )
    else:
        links = tuple(
            DevicePutTransport(NamedSharding(subs[i + 1], PartitionSpec()))
            for i in range(n_tiers - 1)
        )
    return TierPlacement(hosts=hosts, links=links)


# ---------------------------------------------------------------------------
# weight placement
# ---------------------------------------------------------------------------


def place_tier_values(values, host: Host, *, kind: str = "decode"):
    """device_put a tier's stacked ensemble values onto its host's submesh,
    the leading 'ensemble' axis mapping onto the slice's 'pod' mesh axis
    (logical rule table, pod=True).  No-op for simulated hosts."""
    if host.mesh is None:
        return values
    rules = make_rules(kind, pod=True)

    def put(leaf):
        axes = ("ensemble",) + (None,) * (leaf.ndim - 1)
        pspec = logical_to_pspec(axes, rules, shape=leaf.shape, mesh=host.mesh)
        return jax.device_put(leaf, NamedSharding(host.mesh, pspec))

    return jax.tree.map(put, values)


def hosts_disjoint(placement: TierPlacement) -> bool:
    """True when every pair of distinct hosts owns disjoint device sets
    (the multi-host acceptance check for pod placements)."""
    seen = []
    for h in placement.hosts:
        devs = h.devices()
        if not devs:
            continue
        for prev_name, prev in seen:
            if prev_name != h.name and prev & devs:
                return False
        seen.append((h.name, devs))
    return True
