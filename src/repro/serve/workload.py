"""Open-loop workload driver: seeded arrival traces (DESIGN.md §12).

Everything measured before this module was closed-loop: ``serve_continuous``
takes the whole request list up front, so the system never sees *offered
load* — exactly the regime where CascadeServe (PAPERS.md) shows cascade
gains evaporate, because deferral thresholds and tier capacities tuned for
one QPS are frozen while the arrival rate swings.  A ``Workload`` is the
open-loop counterpart: a replayable trace of ``(arrival_time_s, Request)``
pairs that ``CascadeServer.serve_open_loop`` admits by arrival time.

Determinism contract (abclint ABC3xx applies to this module): every
generator is a pure function of its seed — arrival times, prompt tokens,
prompt lengths and output budgets all come from one
``np.random.default_rng(seed)`` stream, so the same seed replays the same
trace bit-for-bit.  Iterating a ``Workload`` materializes FRESH ``Request``
objects each pass (requests are mutated by serving), which is what makes
controller-on vs static A/B runs over *identical* traffic possible.

Time is injectable: ``VirtualClock`` is the deterministic ``obs.clock``
the open-loop driver advances explicitly (per decode sweep and across idle
gaps), so an entire open-loop serve — arrivals, admissions, controller
ticks, SLO verdicts — replays bit-for-bit with no wall-clock dependence.
With the default real clock the same driver measures wall time instead.

Three arrival shapes (all with mixed prompt/output-length distributions):

``poisson``   stationary rate — exponential interarrivals.
``bursty``    Markov-modulated on/off (two-state MMPP): exponential dwell
              times in an ``on`` state (rate_hi) and an ``off`` state
              (rate_lo); the overload-recovery shape the controller bench
              drives.
``diurnal``   inhomogeneous Poisson via thinning against a raised-cosine
              rate curve between ``base_qps`` and ``peak_qps`` — a day's
              traffic compressed to ``period_s``.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List, Sequence, Tuple

import numpy as np

from repro.serve.batching import Request


class VirtualClock:
    """Deterministic injectable clock (``Observability(clock=...)``).

    Reading never advances it; the open-loop driver advances it explicitly
    (``advance``) by the simulated service time per decode sweep and across
    idle gaps to the next arrival.  Two runs that make the same sequence of
    decisions therefore see the same timestamps — the replay half of the
    ABC3xx determinism contract."""

    __slots__ = ("now_s",)

    def __init__(self, start_s: float = 0.0):
        self.now_s = float(start_s)

    def __call__(self) -> float:
        return self.now_s

    def advance(self, dt_s: float) -> None:
        assert dt_s >= 0.0, f"clock cannot run backwards (dt={dt_s})"
        self.now_s += float(dt_s)


@dataclasses.dataclass(frozen=True)
class ArrivalSpec:
    """One immutable trace entry; ``materialize`` builds the fresh mutable
    ``Request`` each replay serves."""

    t_s: float
    tokens: np.ndarray  # (S,) int32 prompt (never mutated)
    max_new_tokens: int

    def materialize(self) -> Request:
        return Request(
            # abclint: disable=ABC203(spec tokens are a host numpy array — the copy is the fresh-per-replay contract)
            tokens=np.array(self.tokens, np.int32, copy=True),
            max_new_tokens=int(self.max_new_tokens),
        )


class Workload:
    """A replayable open-loop arrival trace.

    Iteration yields ``(arrival_time_s, Request)`` in arrival order, with a
    FRESH ``Request`` per pass — serving mutates requests, so one
    ``Workload`` can drive any number of identical A/B runs."""

    def __init__(self, specs: Sequence[ArrivalSpec], *, name: str = "workload"):
        self.specs: List[ArrivalSpec] = sorted(specs, key=lambda s: s.t_s)
        self.name = name

    def __len__(self) -> int:
        return len(self.specs)

    def __iter__(self) -> Iterator[Tuple[float, Request]]:
        for s in self.specs:
            yield s.t_s, s.materialize()

    @property
    def arrival_times(self) -> np.ndarray:
        """(N,) float64 arrival times — the stats tests' raw material."""
        # abclint: disable=ABC203(arrival times are host floats off frozen specs — no device work exists yet)
        return np.asarray([s.t_s for s in self.specs], np.float64)

    @property
    def duration_s(self) -> float:
        return float(self.specs[-1].t_s) if self.specs else 0.0

    @property
    def offered_qps(self) -> float:
        """Mean offered rate over the trace span."""
        d = self.duration_s
        return len(self.specs) / d if d > 0 else float("inf")

    def __repr__(self):
        return (
            f"Workload({self.name}: n={len(self)}, "
            f"span={self.duration_s:.3g}s, {self.offered_qps:.3g} q/s)"
        )


def _specs_from_times(
    times: Sequence[float],
    rng: np.random.Generator,
    prompt_len: Tuple[int, int],
    max_new_tokens: Tuple[int, int],
    vocab: int,
) -> List[ArrivalSpec]:
    """Attach the mixed prompt/output-length distribution to a time list.
    Lengths and tokens draw from the SAME seeded stream as the times'
    generator, so one seed pins the whole trace."""
    p_lo, p_hi = prompt_len
    m_lo, m_hi = max_new_tokens
    assert 1 <= p_lo <= p_hi and 1 <= m_lo <= m_hi, (prompt_len, max_new_tokens)
    specs = []
    for t in times:
        # abclint: disable=ABC202(numpy Generator draws are host scalars — the workload layer never sees a jax array)
        L = int(rng.integers(p_lo, p_hi + 1))
        specs.append(
            ArrivalSpec(
                t_s=float(t),
                tokens=rng.integers(0, vocab, L).astype(np.int32),
                # abclint: disable=ABC202(host rng scalar, see above)
                max_new_tokens=int(rng.integers(m_lo, m_hi + 1)),
            )
        )
    return specs


def poisson(
    rate_qps: float,
    n_requests: int,
    *,
    seed: int,
    prompt_len: Tuple[int, int] = (8, 32),
    max_new_tokens: Tuple[int, int] = (2, 8),
    vocab: int = 256,
) -> Workload:
    """Stationary Poisson arrivals: interarrivals ~ Exp(rate)."""
    assert rate_qps > 0 and n_requests >= 1
    rng = np.random.default_rng(seed)
    times = np.cumsum(rng.exponential(1.0 / rate_qps, n_requests))
    return Workload(
        _specs_from_times(times, rng, prompt_len, max_new_tokens, vocab),
        name=f"poisson@{rate_qps:g}qps",
    )


def bursty(
    rate_lo_qps: float,
    rate_hi_qps: float,
    n_requests: int,
    *,
    seed: int,
    mean_on_s: float = 1.0,
    mean_off_s: float = 1.0,
    prompt_len: Tuple[int, int] = (8, 32),
    max_new_tokens: Tuple[int, int] = (2, 8),
    vocab: int = 256,
) -> Workload:
    """Markov-modulated on/off arrivals (two-state MMPP).

    The process alternates between an ``on`` state emitting Poisson
    arrivals at ``rate_hi_qps`` and an ``off`` state at ``rate_lo_qps``;
    dwell times in each state are exponential with the given means.  The
    trace starts in ``off`` (so the serving system warms up before the
    first burst) and runs until ``n_requests`` have been emitted."""
    assert 0 < rate_lo_qps <= rate_hi_qps and n_requests >= 1
    rng = np.random.default_rng(seed)
    times: List[float] = []
    t, on = 0.0, False
    while len(times) < n_requests:
        dwell = rng.exponential(mean_on_s if on else mean_off_s)
        rate = rate_hi_qps if on else rate_lo_qps
        # Poisson arrivals inside this dwell window
        tt = t + rng.exponential(1.0 / rate)
        while tt < t + dwell and len(times) < n_requests:
            times.append(tt)
            tt += rng.exponential(1.0 / rate)
        t += dwell
        on = not on
    return Workload(
        _specs_from_times(times, rng, prompt_len, max_new_tokens, vocab),
        name=f"bursty@{rate_lo_qps:g}-{rate_hi_qps:g}qps",
    )


def diurnal(
    base_qps: float,
    peak_qps: float,
    period_s: float,
    n_requests: int,
    *,
    seed: int,
    prompt_len: Tuple[int, int] = (8, 32),
    max_new_tokens: Tuple[int, int] = (2, 8),
    vocab: int = 256,
) -> Workload:
    """Inhomogeneous Poisson via thinning: the rate follows a raised
    cosine from ``base_qps`` (t=0, the trough) up to ``peak_qps`` at
    ``period_s/2`` and back — one compressed diurnal cycle per period."""
    assert 0 < base_qps <= peak_qps and period_s > 0 and n_requests >= 1
    rng = np.random.default_rng(seed)

    def rate(t: float) -> float:
        phase = 0.5 - 0.5 * np.cos(2.0 * np.pi * t / period_s)
        return base_qps + (peak_qps - base_qps) * float(phase)

    times: List[float] = []
    t = 0.0
    while len(times) < n_requests:
        # abclint: disable=ABC202(host rng scalar — thinning runs entirely on host floats)
        t += float(rng.exponential(1.0 / peak_qps))  # candidate at the peak rate
        if rng.random() * peak_qps <= rate(t):  # thin to the instantaneous rate
            times.append(t)
    return Workload(
        _specs_from_times(times, rng, prompt_len, max_new_tokens, vocab),
        name=f"diurnal@{base_qps:g}-{peak_qps:g}qps",
    )
