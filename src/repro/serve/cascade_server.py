"""CascadeServer: ABC as a first-class serving runtime feature.

Tiers hold ensembles (stacked weights, vmapped members).  Two modes:

* ``classify`` — each tier's ensemble produces last-token logits; the
  agreement rule (Eq. 3/4) selects or defers; deferred examples are
  compacted and re-batched for the next tier (host routing — the form whose
  measured cost reproduces Prop 4.1.2).

* ``generate`` — black-box flavor (§5.2.3): each member generates answers
  (optionally temperature-sampled); agreement is exact-match voting over
  canonicalized outputs (Eq. 3 with vote_rule_from_preds).

Cost accounting per tier uses the TierSpec cost units (FLOPs, $/Mtok,
GPU-$/h, comm-delay), so the same server drives all three §5.2 scenarios.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import deferral, ensemble as ens
from repro.core.cascade import CascadeResult, TierSpec, cascade_apply_routed
from repro.serve.engine import ServingEngine


@dataclasses.dataclass
class CascadeTier:
    cfg: ModelConfig
    values: dict  # stacked member params (leading ensemble axis)
    spec: TierSpec
    temperature: float = 0.0  # >0 for black-box sampled voting

    def __post_init__(self):
        self.k = ens.member_count(self.values)
        self._last_logits = jax.jit(
            functools.partial(ens.ensemble_last_logits, cfg=self.cfg)
        )

    def member_engine(self, i: int, **kw) -> ServingEngine:
        return ServingEngine(self.cfg, ens.take_member(self.values, i), **kw)


class CascadeServer:
    def __init__(self, tiers: Sequence[CascadeTier], *, pad_to: int = 8):
        self.tiers = list(tiers)
        self.pad_to = pad_to

    # -- classification serving -------------------------------------------
    def classify(self, tokens: np.ndarray) -> CascadeResult:
        """tokens (B, S) -> CascadeResult with per-tier routing stats."""

        def tier_fn(tier: CascadeTier):
            def fn(batch):
                return tier._last_logits(tier.values, {"tokens": jnp.asarray(batch["tokens"])})

            return fn

        fns = [tier_fn(t) for t in self.tiers]
        specs = [t.spec for t in self.tiers]
        return cascade_apply_routed(fns, specs, {"tokens": tokens}, pad_to=self.pad_to)

    # -- black-box generation serving --------------------------------------
    def generate(
        self, tokens: np.ndarray, max_new_tokens: int = 8, seed: int = 0
    ) -> CascadeResult:
        """Each member generates; members' answers are hashed to ids and
        vote-compared (the paper's API scenario where only text comes back).
        """

        def tier_fn(tier: CascadeTier):
            def fn(batch):
                toks = np.asarray(batch["tokens"])
                preds = []
                for i in range(tier.k):
                    eng = tier.member_engine(
                        i, temperature=tier.temperature, seed=seed + i
                    )
                    out = eng.generate(toks, max_new_tokens)  # (B, T)
                    # canonicalize: hash the generated id sequence
                    h = np.asarray(
                        [hash(bytes(row.tobytes())) % (2**31 - 1) for row in out],
                        np.int32,
                    )
                    preds.append(h)
                return jnp.asarray(np.stack(preds))  # (E, B) ids

            return fn

        # vote_rule_from_preds via a rule shim: reuse 'vote' on preds
        def shim(spec: TierSpec):
            return dataclasses.replace(spec, rule="vote_preds")

        deferral.RULES.setdefault(
            "vote_preds",
            lambda preds, theta: deferral.vote_rule_from_preds(preds, theta),
        )
        fns = [tier_fn(t) for t in self.tiers]
        specs = [shim(t.spec) for t in self.tiers]
        return cascade_apply_routed(fns, specs, {"tokens": tokens}, pad_to=self.pad_to)

    # -- accounting ---------------------------------------------------------
    def expected_cost(self, result: CascadeResult) -> float:
        return result.cost

    def tier_fractions(self, result: CascadeResult) -> np.ndarray:
        return result.tier_counts / max(1, result.tier_counts.sum())
