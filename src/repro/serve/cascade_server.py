"""CascadeServer: ABC as a first-class serving runtime feature.

Tiers hold ensembles (stacked weights, vmapped members).  Three modes:

* ``classify`` — each tier's ensemble produces last-token logits; the
  agreement rule (Eq. 3/4) selects or defers; deferred examples are
  compacted and re-batched for the next tier (host routing — the form whose
  measured cost reproduces Prop 4.1.2).

* ``generate`` — black-box flavor (§5.2.3): every member of a tier
  generates in ONE vmapped XLA program per decode step (stacked weights,
  the paper's ρ=1 parallel execution); agreement is exact-match voting over
  stable digests of the generated sequences (Eq. 3 with vote_rule_from_preds).

* ``serve_continuous`` — cascade-aware continuous batching: each tier runs
  a ``SlotStream`` (serve/slot_stream.py — the SAME slot state machine the
  single-model engine drives at E=1, here at E=k over stacked-ensemble
  programs, with chunked-prefill admission and constant-state slot reset);
  a slot that finishes votes on its member generations, and freed slots
  admit work from the tier's queue — which is fed live by the *previous*
  tier's deferrals (tier streams are stepped round-robin, so tier i+1
  starts while tier i is still decoding).  All families serve: attention
  tiers rely on the per-slot pos mask, SSM/RWKV/hybrid tiers on the
  admitted slot's state leaves being zeroed.

Compile-once discipline: all jitted programs live in a module-level cache
keyed by (config, temperature) — building a new ``CascadeTier`` or calling
``classify``/``generate`` repeatedly reuses the same programs, and batch
shapes are padded to power-of-two buckets so tier transitions re-enter the
jit cache (``repro.serve.engine.trace_count`` asserts this in the tests).

Cost accounting per tier uses the TierSpec cost units (FLOPs, $/Mtok,
GPU-$/h, comm-delay), so the same server drives all three §5.2 scenarios.
"""
from __future__ import annotations

import dataclasses
import functools
import zlib
from types import SimpleNamespace
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import deferral, ensemble as ens
from repro.core.cascade import (
    CascadeResult,
    TierSpec,
    cascade_apply_routed,
    host_fetch,
)
from repro.models import api
from repro.obs import Observability, UNIT_BUCKETS
from repro.serve.batching import Request
from repro.serve.config import UNSET, ServeConfig, resolve_serve_config
from repro.serve.engine import _counted, grow_cache
from repro.serve.slot_stream import SlotStream, TierBackend
from repro.serve.speculative import verify_sampler
from repro.serve.workload import VirtualClock, Workload


# ---------------------------------------------------------------------------
# stable canonicalization of generations (black-box voting)
# ---------------------------------------------------------------------------


def stable_digest(tokens) -> int:
    """PYTHONHASHSEED-independent canonical id for a token sequence.

    ``hash(bytes)`` is salted per process, which made identical member
    generations vote differently across runs; crc32 over the little-endian
    int32 encoding is deterministic everywhere.  Masked to 30 bits so every
    digest stays strictly below ``vote_rule_from_preds``'s 2**30
    not-a-candidate sentinel (a 31-bit digest could BE the sentinel and
    corrupt the majority-id tie-break)."""
    row = np.ascontiguousarray(
        np.asarray(host_fetch(tokens), np.int32)
    ).astype("<i4")
    return zlib.crc32(row.tobytes()) & 0x3FFFFFFF


def digest_generations(out: np.ndarray) -> np.ndarray:
    """(E, B, T) member generations -> (E, B) int32 canonical answer ids."""
    E, B = out.shape[:2]
    # abclint: disable=ABC203(digest matrix is a host list comprehension of ints)
    return np.asarray(
        [[stable_digest(out[e, b]) for b in range(B)] for e in range(E)],
        np.int32,
    )


# ---------------------------------------------------------------------------
# compile-once ensemble programs
# ---------------------------------------------------------------------------


def _slot_sampler(temperature: float):
    """Per-slot, per-position, per-member sampling for continuous batching:
    token = categorical(fold_in(fold_in(slot_key, pos), e)).  The slot's
    key is set once at admission, so a slot's sampled trajectory is a pure
    function of its occupant and position — bitwise invariant to which
    other slots share its decode dispatches (serial, blocking, or
    overlapped transport all see the same votes).  Greedy tiers argmax."""

    def sample(logits, slot_keys, pos):  # (E, B, V), (B, 2), (B,)
        if temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        E = logits.shape[0]

        def one(key, p, ls):  # (2,), (), (E, V)
            kp = jax.random.fold_in(key, p)
            return jax.vmap(
                lambda e, l: jax.random.categorical(
                    jax.random.fold_in(kp, e), l / temperature
                )
            )(jnp.arange(E), ls)

        return jax.vmap(one, in_axes=(0, 0, 1), out_axes=1)(
            slot_keys, pos, logits
        ).astype(jnp.int32)

    return sample


@functools.lru_cache(maxsize=None)
def tier_programs(cfg: ModelConfig, temperature: float) -> SimpleNamespace:
    """Long-lived jitted ensemble programs for one (config, temperature).

    ``last_logits(values, batch) -> (E, B, V)``
    ``prefill(values, batch, rng) -> (tok (E, B, 1), caches, rng)``
    ``decode(values, tok, caches, pos, rng) -> (tok (E, B, 1), caches, rng)``
    ``decode_slots(values, tok, caches, pos, slot_keys) -> (tok, caches)``

    Sampling lives inside the programs (one XLA program advances every
    member of the tier per step).  Batch mode (``decode``, scalar ``pos``)
    threads one rng chain — every row steps in lockstep, so the chain is
    deterministic.  Continuous mode (``decode_slots``, per-slot (B,) pos)
    samples from per-slot admission keys instead (``_slot_sampler``): slots
    advance independently, and a shared chain would make votes depend on
    slot-step interleaving.
    """

    def _sample(logits, rng):  # logits (E, B, V)
        if temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), rng
        rng, sub = jax.random.split(rng)
        keys = jax.random.split(sub, logits.shape[0])
        tok = jax.vmap(
            lambda k, l: jax.random.categorical(k, l / temperature)
        )(keys, logits)
        return tok.astype(jnp.int32), rng

    sample_slots = _slot_sampler(temperature)

    def prefill(values, batch, rng):
        logits, caches = ens.ensemble_prefill(values, batch, cfg)
        tok, rng = _sample(logits, rng)
        return tok[..., None], caches, rng

    def decode(values, tok, caches, pos, rng):
        logits, caches = ens.ensemble_decode_step(values, tok, caches, pos, cfg)
        nxt, rng = _sample(logits, rng)
        return nxt[..., None], caches, rng

    def decode_slots(values, tok, caches, pos, slot_keys):
        logits, caches = ens.ensemble_decode_step(values, tok, caches, pos, cfg)
        nxt = sample_slots(logits, slot_keys, pos)
        return nxt[..., None], caches

    def prefill_chunk(values, caches, tokens, slot, start):
        return jax.vmap(
            lambda p, c: api.prefill_into_slot(p, tokens, c, slot, start, cfg)
        )(values, caches)

    sample_verify = verify_sampler(temperature)

    def verify_chunk(values, caches, tokens, slot, start, slot_key):
        # speculative verify (serve/speculative.py): chunked prefill that
        # also scores every position, then the decode-equivalent sampler
        logits, caches = jax.vmap(
            lambda p, c: api.prefill_into_slot_logits(
                p, tokens, c, slot, start, cfg
            )
        )(values, caches)
        pos = start + jnp.arange(tokens.shape[0])
        return sample_verify(logits, slot_key, pos), caches

    def reset_slot(caches, slot):
        return jax.vmap(lambda c: api.reset_slot(c, slot, cfg))(caches)

    key = f"{cfg.name}@T{temperature:g}"
    return SimpleNamespace(
        last_logits=jax.jit(
            _counted(
                f"{key}/ens_last_logits",
                functools.partial(ens.ensemble_last_logits, cfg=cfg),
            )
        ),
        prefill=jax.jit(_counted(f"{key}/ens_prefill", prefill)),
        decode=jax.jit(_counted(f"{key}/ens_decode", decode)),
        decode_slots=jax.jit(_counted(f"{key}/ens_decode_slots", decode_slots)),
        prefill_chunk=(
            jax.jit(_counted(f"{key}/ens_prefill_chunk", prefill_chunk))
            if api.supports_chunked_prefill(cfg)
            else None
        ),
        verify_chunk=(
            jax.jit(_counted(f"{key}/ens_verify_chunk", verify_chunk))
            if api.supports_draft_verify(cfg)
            else None
        ),
        reset_slot=(
            jax.jit(_counted(f"{key}/ens_slot_reset", reset_slot))
            if api.has_slot_state(cfg)
            else None
        ),
    )


@functools.lru_cache(maxsize=None)
def tier_paged_programs(cfg: ModelConfig, temperature: float) -> SimpleNamespace:
    """Block-paged counterparts of ``tier_programs``'s continuous-mode
    programs: E pool planes advance under ONE shared page table (members
    score the same tokens at the same positions), with per-slot admission
    keys for sampling.  Pool/table geometry is data shape, not static args
    — one trace per geometry."""
    assert api.supports_paging(cfg), cfg.family
    sample_slots = _slot_sampler(temperature)

    def decode_slots(values, tok, pools, pos, pages, slot_keys):
        logits, pools = jax.vmap(
            lambda v, t, pl: api.decode_step_paged(v, t, pl, pos, pages, cfg)
        )(values, tok, pools)
        nxt = sample_slots(logits, slot_keys, pos)
        return nxt[..., None], pools

    def prefill_chunk(values, pools, tokens, pages_row, start):
        return jax.vmap(
            lambda v, pl: api.prefill_into_slot_paged(
                v, tokens, pl, pages_row, start, cfg
            )
        )(values, pools)

    sample_verify = verify_sampler(temperature)

    def verify_chunk(values, pools, tokens, pages_row, start, slot_key):
        logits, pools = jax.vmap(
            lambda v, pl: api.prefill_into_slot_paged_logits(
                v, tokens, pl, pages_row, start, cfg
            )
        )(values, pools)
        pos = start + jnp.arange(tokens.shape[0])
        return sample_verify(logits, slot_key, pos), pools

    key = f"{cfg.name}@T{temperature:g}"
    return SimpleNamespace(
        decode_slots=jax.jit(
            _counted(f"{key}/ens_decode_paged", decode_slots)
        ),
        prefill_chunk=jax.jit(
            _counted(f"{key}/ens_prefill_chunk_paged", prefill_chunk)
        ),
        verify_chunk=jax.jit(
            _counted(f"{key}/ens_verify_chunk_paged", verify_chunk)
        ),
        copy_page=jax.jit(
            _counted(f"{key}/ens_copy_pool_page", api.copy_pool_page)
        ),
    )


@dataclasses.dataclass
class CascadeTier:
    """One cascade level at serving time: a stacked k-member ensemble
    (``values`` with a leading 'ensemble' axis) plus its ``TierSpec``
    deferral rule, bound to the compile-once ``tier_programs`` for its
    (config, temperature).  Construction is cheap — programs are shared
    module-level state, so building tiers repeatedly never re-jits."""

    cfg: ModelConfig
    values: dict  # stacked member params (leading ensemble axis)
    spec: TierSpec
    temperature: float = 0.0  # >0 for black-box sampled voting

    def __post_init__(self):
        self.k = ens.member_count(self.values)
        programs = tier_programs(self.cfg, float(self.temperature))
        self._last_logits = programs.last_logits
        self._prefill = programs.prefill
        self._decode = programs.decode
        self._decode_slots = programs.decode_slots
        self._prefill_chunk = programs.prefill_chunk
        self._verify_chunk = programs.verify_chunk
        self._reset_slot = programs.reset_slot

    def generate(
        self, tokens: np.ndarray, max_new_tokens: int, seed: int = 0
    ) -> np.ndarray:
        """Ensemble generation: tokens (B, S) -> (E, B, max_new).  Every
        decode step is one vmapped XLA program over the stacked (E, ...)
        parameters — no per-member Python loop, no per-member engines."""
        assert max_new_tokens >= 1, max_new_tokens
        B, S = tokens.shape
        rng = jax.random.PRNGKey(seed)
        tok, caches, rng = self._prefill(
            self.values, {"tokens": jnp.asarray(tokens)}, rng
        )
        caches = grow_cache(caches, max_new_tokens, self.cfg, lead=1)
        out = [host_fetch(tok)[..., 0]]
        for t in range(max_new_tokens - 1):
            tok, caches, rng = self._decode(
                self.values, tok, caches, jnp.int32(S + t), rng
            )
            out.append(host_fetch(tok)[..., 0])
        return np.stack(out, axis=2)  # (E, B, T)


@dataclasses.dataclass
class OpenLoopReport:
    """What one ``CascadeServer.serve_open_loop`` run measured.

    ``goodput`` is SLO-attainment: the fraction of OFFERED requests that
    completed within ``slo_s`` of their arrival time — shed requests and
    SLO misses both count against it, so admission control only helps by
    making the requests it keeps finish on time.  ``completed + shed``
    always partitions the offered trace (zero silent drops — asserted by
    the driver); latency percentiles come from the run's
    ``serve.request_latency_s`` registry histogram."""

    offered: int
    completed: List[Request]
    shed: List[Request]
    completed_in_slo: int
    goodput: float
    p50_s: float
    p99_s: float
    makespan_s: float
    controller_actions: List[dict] = dataclasses.field(default_factory=list)

    def __repr__(self):
        return (
            f"OpenLoopReport(offered={self.offered}, "
            f"done={len(self.completed)}, shed={len(self.shed)}, "
            f"goodput={self.goodput:.3f}, p50={self.p50_s:.4g}s, "
            f"p99={self.p99_s:.4g}s, makespan={self.makespan_s:.4g}s)"
        )


class _CascadeRun:
    """One serve run's machinery, shared verbatim by the closed-loop
    (``serve_continuous``) and open-loop (``serve_open_loop``) drivers:
    per-tier ``SlotStream``s over ``TierBackend``s, the vote/defer/complete
    routing, cross-host hop metering, and the telemetry scopes.  The
    drivers differ ONLY in when requests enter (up-front list vs
    arrival-time admission) and how time advances (clock reads vs explicit
    ``VirtualClock`` advances); everything a request experiences after
    submission lives here, which is what makes closed- and open-loop
    results comparable.

    ``theta_offset`` is the online controller's deferral actuation point:
    tier i defers on ``vote_frac <= clamp(spec.theta + theta_offset[i],
    0, 1)``.  Offsets default to 0.0, and the zero-offset path evaluates
    ``spec.theta`` unmodified — the static configuration is bitwise
    identical to the pre-controller code."""

    def __init__(self, server: "CascadeServer", cfg: ServeConfig,
                 ob: Observability):
        self.server = server
        self.tiers = server.tiers
        self.ob = ob
        self.tr = ob.tracer
        self.clk = ob.clock
        self.h_lat = ob.registry.histogram("serve.request_latency_s")
        self.hosts = server._host_names()
        if server.placement is not None:
            for i, link in enumerate(server.placement.links):
                link.attach_obs(ob, f"{self.hosts[i]}_{self.hosts[i + 1]}")
        n = len(self.tiers)
        tier_sc = [ob.scope(f"cascade.tier{i}") for i in range(n)]
        self.c_answered = [sc.counter("answered") for sc in tier_sc]
        self.c_deferred = [sc.counter("deferred") for sc in tier_sc]
        self.c_tokens = [sc.counter("output_tokens") for sc in tier_sc]
        self.h_margin = [
            sc.histogram("agreement_margin", buckets=UNIT_BUCKETS)
            for sc in tier_sc
        ]
        self.h_accept = [
            sc.histogram("draft_accept_rate", buckets=UNIT_BUCKETS)
            for sc in tier_sc
        ]
        self.speculative = bool(cfg.speculative)
        self.theta_offset: List[float] = [0.0] * n
        self.streams = [
            SlotStream(
                TierBackend(
                    t, n_slots=cfg.n_slots, max_seq=cfg.max_seq,
                    seed=cfg.seed + i, paged=cfg.paged,
                    page_size=cfg.page_size, n_pages=cfg.n_pages,
                    obs=ob, pool_name=f"paging.tier{i}",
                ),
                dataclasses.replace(cfg, obs=ob),
                name=f"slot_stream.tier{i}",
            )
            for i, t in enumerate(self.tiers)
        ]
        for i, st in enumerate(self.streams):
            if i > 0:
                st.on_draft_verified = self._accept_recorder(i)
        self.t_start: dict = {}
        self.done: List[Request] = []

    def _accept_recorder(self, i: int):
        def record(r, n_acc, n_draft):
            self.h_accept[i].record(n_acc / max(1, n_draft))

        return record

    # -- driver surface -----------------------------------------------------
    def submit(self, requests: Sequence[Request], *, t0=None) -> None:
        """Enqueue onto tier 0.  ``t0`` overrides the latency-clock origin
        (open loop passes the ARRIVAL time, so queue wait before admission
        counts against the SLO)."""
        for r in requests:
            self.t_start[r.rid] = self.clk() if t0 is None else t0
        self.streams[0].submit(requests)

    @property
    def active(self) -> bool:
        return any(st.active for st in self.streams)

    @property
    def runnable(self) -> bool:
        return any(st.runnable for st in self.streams)

    def block_on_inflight(self) -> None:
        """Every stream idle but payloads still on the wire: block on the
        oldest in-flight hop (the only legal wait — there is no compute
        left to hide it behind)."""
        next(st for st in self.streams if st.inflight).poll_inflight(
            block=True
        )

    def effective_theta(self, i: int) -> float:
        off = self.theta_offset[i]
        th = self.tiers[i].spec.theta
        return th if off == 0.0 else min(1.0, max(0.0, th + off))

    def sweep(self) -> None:
        """One round-robin pass: step every stream once, routing each
        completed slot through its tier's vote.  Deferred re-queues land on
        tier i+1 BEFORE its step in the same sweep — exactly the legacy
        serve_continuous interleaving."""
        for i, st in enumerate(self.streams):
            for r, gen in st.step():
                self._finish_slot(i, r, gen)

    # -- vote / defer / complete --------------------------------------------
    def _finish_slot(self, i: int, r: Request, gen: np.ndarray) -> None:
        tier = self.tiers[i]
        tr = self.tr
        n_tiers = len(self.streams)
        # abclint: disable=ABC203(gen is host-side — the backend fetched it; this is a host list of digests)
        digests = np.asarray(
            [stable_digest(gen[e]) for e in range(tier.k)],
            np.int32,
        )
        out = deferral.vote_rule_from_preds(
            jnp.asarray(digests[:, None]), self.effective_theta(i)
        )
        # one metered fetch per completed slot: the vote verdict
        # and winning digest scalars (8 bytes)
        defer_h, pred_h = host_fetch((out.defer[0], out.pred[0]))
        defer = bool(defer_h) and i < n_tiers - 1
        # agreement margin: the winning digest's vote share
        # (1.0 = unanimous) — digests is a host array
        vote_counts = np.unique(digests, return_counts=True)[1]
        margin = float(vote_counts.max()) / tier.k
        self.h_margin[i].record(margin)
        if tr.enabled:
            tr.instant(
                r.rid, "defer_vote",
                tier=i, margin=margin, defer=bool(defer_h),
            )
        if defer:
            self.c_deferred[i].add(1)
            # cascade-as-drafter (serve/speculative.py): the plurality
            # generation this tier voted on becomes the next tier's draft
            # — the agreeing work travels with the deferral instead of
            # being thrown away
            draft = None
            if self.speculative and gen.shape[1]:
                # abclint: disable=ABC202(argmax over the host digest array — pred_h fetched above)
                w = int(np.argmax(digests == pred_h))
                draft = gen[w].astype(np.int32)
            placement = self.server.placement
            link = placement.link(i) if placement is not None else None
            if link is not None:
                # cross-host re-queue: the prompt is the payload
                # that actually crosses the boundary.  send_async
                # meters the hop NOW; the handle resolves at a
                # tier-(i+1) admission point, so this tier's
                # remaining slots keep decoding over the hop
                # abclint: disable=ABC203(r.tokens is the host prompt array — the payload is built host-side before the metered send)
                payload = {"tokens": np.asarray(r.tokens, np.int32)}
                n_bytes = int(payload["tokens"].nbytes)
                if draft is not None:
                    # draft tokens ride the same metered hop
                    payload["draft"] = draft
                    n_bytes += int(draft.nbytes)
                hosts = self.hosts
                if tr.enabled:
                    tr.begin(
                        r.rid, "hop",
                        src=hosts[i], dst=hosts[i + 1],
                        n_bytes=n_bytes,
                    )
                handle = link.send_async(
                    hosts[i], hosts[i + 1], payload, n_examples=1,
                )
                hop = link.hops[-1]  # metered at send time

                def _land(delivered, r=r, handle=handle, hop=hop):
                    r.tokens = np.asarray(
                        delivered["tokens"], np.int32
                    )
                    if "draft" in delivered:
                        # abclint: disable=ABC203(delivered payload is host-side — the transport already moved it)
                        r.draft = np.asarray(
                            delivered["draft"], np.int32
                        )
                    if tr.enabled:
                        # the hop span closes at delivery (on
                        # the draining thread); its args carry
                        # the overlap split — blocked is what
                        # result() charged the caller, hidden
                        # is the link time decode covered
                        blocked = float(handle.wait_time)
                        tr.end(
                            r.rid, "hop",
                            link_s=float(hop.latency),
                            blocked_s=blocked,
                            hidden_s=max(
                                0.0, float(hop.latency) - blocked
                            ),
                        )
                    return r

                self.streams[i + 1].submit_inflight(handle, _land)
            else:
                r.draft = draft
                self.streams[i + 1].submit([r])
        else:
            self.c_answered[i].add(1)
            self.c_tokens[i].add(int(gen.shape[1]))
            # abclint: disable=ABC202(argmax over the host digest array — pred_h fetched above)
            winner = int(np.argmax(digests == pred_h))
            r.output = gen[winner].astype(np.int32)
            r.tier = i
            self.h_lat.record(self.clk() - self.t_start[r.rid])
            if tr.enabled:
                tr.instant(r.rid, "complete", tier=i)
            self.done.append(r)


class CascadeServer:
    """The ABC serving runtime: a tier list + optional ``TierPlacement``.

    Every tier boundary's traffic contract is the same in all three modes:
    ONLY the compacted deferral payload (batch modes: deferred rows + i32
    index map, padded to their pow2 bucket cover; continuous mode: the
    deferred request's prompt) crosses the placement link, metered by the
    link's ``Transport``.  See the module docstring for the three modes and
    DESIGN.md §8 for how hops overlap with compute."""

    def __init__(
        self,
        tiers: Sequence[CascadeTier],
        *,
        pad_to: int = 8,
        placement=None,
    ):
        """``placement`` (serve/placement.py TierPlacement, optional) pins
        each tier to a host and makes every cross-host deferral an explicit
        metered ``Transport`` hop; tier values are device_put onto their
        host's pod submesh when it has one.  Without a placement, routing
        behaves as a single-host loopback (no metering)."""
        self.tiers = list(tiers)
        self.pad_to = pad_to
        self.placement = placement
        if placement is not None:
            from repro.serve.placement import place_tier_values

            assert placement.n_tiers == len(self.tiers), (
                placement.n_tiers, len(self.tiers),
            )
            # replace, don't mutate: the caller's tier objects keep their
            # original (unplaced) values
            self.tiers = [
                dataclasses.replace(t, values=place_tier_values(t.values, host))
                for t, host in zip(self.tiers, placement.hosts)
            ]

    def _hop_transports(self):
        """Per-boundary transports from the placement (None = no metering)."""
        if self.placement is None:
            return None
        return list(self.placement.links)

    def _host_names(self):
        """Per-tier host names for hop metering (None = unplaced)."""
        if self.placement is None:
            return None
        return [h.name for h in self.placement.hosts]

    # -- classification serving -------------------------------------------
    def classify(self, tokens: np.ndarray) -> CascadeResult:
        """tokens (B, S) -> CascadeResult with per-tier routing stats."""

        def tier_fn(tier: CascadeTier):
            def fn(batch):
                return tier._last_logits(
                    tier.values, {"tokens": jnp.asarray(batch["tokens"])}
                )

            return fn

        fns = [tier_fn(t) for t in self.tiers]
        specs = [t.spec for t in self.tiers]
        return cascade_apply_routed(
            fns, specs, {"tokens": tokens}, pad_to=self.pad_to,
            transport=self._hop_transports(), hosts=self._host_names(),
        )

    # -- black-box generation serving --------------------------------------
    def generate(
        self, tokens: np.ndarray, max_new_tokens: int = 8, seed: int = 0
    ) -> CascadeResult:
        """Each tier's members generate in one vmapped program; answers are
        digested to stable ids and vote-compared (the paper's API scenario
        where only text comes back)."""

        def tier_fn(tier: CascadeTier):
            def fn(batch):
                # the host-side python generate loop needs the prompt rows;
                # this is the tier's own compute, not the defer path —
                # fetched explicitly (transfer-guard clean, bytes metered)
                toks = host_fetch(batch["tokens"])
                out = tier.generate(toks, max_new_tokens, seed=seed)
                return jnp.asarray(digest_generations(out))  # (E, B) ids

            return fn

        fns = [tier_fn(t) for t in self.tiers]
        specs = [dataclasses.replace(t.spec, rule="vote_preds") for t in self.tiers]
        return cascade_apply_routed(
            fns, specs, {"tokens": tokens}, pad_to=self.pad_to,
            transport=self._hop_transports(), hosts=self._host_names(),
        )

    # -- cascade-aware continuous batching ---------------------------------
    def serve_continuous(
        self,
        requests: Sequence[Request],
        config: Optional[ServeConfig] = None,
        *,
        n_slots=UNSET,
        max_seq=UNSET,
        seed=UNSET,
        chunked_prefill=UNSET,
        paged=UNSET,
        page_size=UNSET,
        n_pages=UNSET,
        obs=UNSET,
    ) -> List[Request]:
        """Continuous-batching generate mode: every tier runs a
        ``SlotStream`` (serve/slot_stream.py, the E=k instantiation of the
        shared slot state machine) over its stacked-ensemble programs;
        streams are stepped round-robin, so a request deferred by tier i is
        admitted into a freed tier-i+1 slot while tier i is still decoding
        its remaining slots.  Admission uses bucketed chunked prefill by
        default; constant-state tiers (SSM/RWKV, hybrid) zero the admitted
        slot's state leaves, so every family serves continuously.  A
        completed slot votes over its member generations (Eq. 3 on stable
        digests): agreement -> the request exits with the majority answer
        and ``r.tier`` set; disagreement -> the request is re-queued
        (prompt intact) on the next tier.  Returns completed requests.

        Cross-host re-queues go through the placement link's ``send_async``
        (serve/transport.py): the hop is metered at send time, the handle
        joins the NEXT tier's in-flight queue, and the loop keeps stepping
        every runnable stream — with an ``AsyncTransport`` link the edge
        tier therefore keeps admitting and decoding while deferral payloads
        are on the wire (DESIGN.md §8).  The loop blocks on a handle only
        when NO stream has runnable work (the all-idle fallback).  Tiers
        generate bitwise-identically whether the link overlaps, blocks, or
        is absent — at ANY temperature: delivery timing only moves WHEN a
        request is re-admitted, never what its slot computes (greedy slots
        are rng-free; sampled slots draw from per-slot admission keys —
        see ``_slot_sampler``).

        Tuning knobs arrive as a ``ServeConfig`` (``config=``) or as the
        legacy kwargs (one deprecation pathway — serve/config.py); the
        run machinery itself is ``_CascadeRun``, shared bitwise with
        ``serve_open_loop``."""
        cfg = resolve_serve_config(
            config, "CascadeServer.serve_continuous",
            n_slots=n_slots, max_seq=max_seq, seed=seed,
            chunked_prefill=chunked_prefill, paged=paged,
            page_size=page_size, n_pages=n_pages, obs=obs,
        ).with_max_seq_default(256)
        for r in requests:
            assert len(r.tokens) + r.max_new_tokens <= cfg.max_seq, (
                f"request {r.rid}: prompt+budget "
                f"{len(r.tokens)}+{r.max_new_tokens} exceeds "
                f"max_seq={cfg.max_seq}"
            )
        # telemetry (DESIGN.md §11): one bundle spans every tier's stream,
        # pool, and placement link — pass ``obs`` to get a unified registry
        # namespace and (with an enabled tracer) the per-request lifecycle
        # trace; the default private bundle keeps legacy behaviour
        run = _CascadeRun(self, cfg, cfg.resolved_obs())
        run.submit(requests)
        while run.active:
            if not run.runnable:
                run.block_on_inflight()
                continue
            run.sweep()
        self.last_stream_stats = [dict(st.stats) for st in run.streams]
        return run.done

    # -- open-loop load-adaptive serving ------------------------------------
    def serve_open_loop(
        self,
        workload: Workload,
        config: Optional[ServeConfig] = None,
        *,
        slo_s: float = 1.0,
        controller=None,
        step_time_s: float = 0.01,
    ) -> OpenLoopReport:
        """Open-loop serving (DESIGN.md §12): admission is driven by the
        workload's ARRIVAL TIMES, not an up-front list — the system sees
        offered load, queues build under bursts, and the report scores
        SLO-attainment (``goodput``) rather than raw throughput.

        The run executes in VIRTUAL time: ``obs.clock`` must be an
        advanceable clock (``repro.serve.workload.VirtualClock``; one is
        created when no bundle is passed), and the driver advances it by
        ``step_time_s`` per round-robin sweep (the modeled service time of
        one decode step across the tiers) and across idle gaps to the next
        arrival.  Identical (workload, config, controller) inputs therefore
        replay bit-for-bit — which is what makes the controller-on vs
        static A/B in ``bench_serving`` a like-for-like comparison.

        ``controller`` (``repro.serve.controller.GreedyController``,
        optional) is bound to the run and ticked on its own interval; it
        may lower per-tier deferral thresholds, cap per-tier slot
        admission, and shed arrivals under overload.  Shed requests come
        back in ``report.shed`` with ``r.shed=True`` — never silently
        dropped: ``offered == len(completed) + len(shed)`` is asserted.
        ``config.seed``/geometry knobs mean the same thing as in
        ``serve_continuous``; a trace whose arrivals are all at t=0 and a
        no-op controller reproduce the closed-loop outputs exactly."""
        cfg = resolve_serve_config(
            config, "CascadeServer.serve_open_loop"
        ).with_max_seq_default(256)
        assert slo_s > 0 and step_time_s > 0, (slo_s, step_time_s)
        if cfg.obs is None:
            ob = Observability(clock=VirtualClock())
        else:
            ob = cfg.obs
        assert hasattr(ob.clock, "advance"), (
            "serve_open_loop runs in virtual time: obs.clock must be "
            "advanceable (repro.serve.workload.VirtualClock), got "
            f"{type(ob.clock).__name__}"
        )
        vt = ob.clock
        arrivals = list(workload)  # fresh Request objects, arrival order
        for _, r in arrivals:
            assert len(r.tokens) + r.max_new_tokens <= cfg.max_seq, (
                f"request {r.rid}: prompt+budget "
                f"{len(r.tokens)}+{r.max_new_tokens} exceeds "
                f"max_seq={cfg.max_seq}"
            )
        run = _CascadeRun(self, cfg, ob)
        sc = ob.scope("serve.open_loop")
        c_offered = sc.counter("offered")
        c_shed = sc.counter("shed")
        c_completed = sc.counter("completed")
        c_in_slo = sc.counter("completed_in_slo")
        if controller is not None:
            controller.bind(run, slo_s=slo_s)
        shed: List[Request] = []
        n_in_slo = 0
        n_seen = 0  # run.done prefix already scored against the SLO
        idx = 0
        next_tick = (
            controller.config.interval_s if controller is not None
            else float("inf")
        )
        while idx < len(arrivals) or run.active:
            # admit everything that has arrived by virtual-now; overload
            # shedding happens HERE, at the admission point, before the
            # request ever touches a stream
            while idx < len(arrivals) and arrivals[idx][0] <= vt.now_s + 1e-12:
                t_arrive, r = arrivals[idx]
                idx += 1
                c_offered.add(1)
                if controller is not None and controller.should_shed():
                    r.shed = True
                    shed.append(r)
                    c_shed.add(1)
                    if run.tr.enabled:
                        run.tr.instant(r.rid, "complete", shed=True)
                    continue
                run.submit([r], t0=t_arrive)
            if run.runnable:
                run.sweep()
                # score completions at their recorded completion time,
                # BEFORE this sweep's time charge moves the clock
                for r in run.done[n_seen:]:
                    c_completed.add(1)
                    if vt.now_s - run.t_start[r.rid] <= slo_s:
                        c_in_slo.add(1)
                        n_in_slo += 1
                n_seen = len(run.done)
                vt.advance(step_time_s)
            elif any(st.inflight for st in run.streams):
                run.block_on_inflight()
            elif idx < len(arrivals):
                # nothing runnable, nothing in flight: jump to next arrival
                vt.advance(arrivals[idx][0] - vt.now_s)
            else:
                break
            if controller is not None and vt.now_s + 1e-12 >= next_tick:
                controller.tick(vt.now_s)
                next_tick = vt.now_s + controller.config.interval_s
        self.last_stream_stats = [dict(st.stats) for st in run.streams]
        assert len(run.done) + len(shed) == len(arrivals), (
            "open-loop invariant violated: "
            f"{len(arrivals)} offered != {len(run.done)} completed "
            f"+ {len(shed)} shed"
        )
        return OpenLoopReport(
            offered=len(arrivals),
            completed=run.done,
            shed=shed,
            completed_in_slo=n_in_slo,
            goodput=n_in_slo / max(1, len(arrivals)),
            p50_s=run.h_lat.percentile(0.50),
            p99_s=run.h_lat.percentile(0.99),
            makespan_s=vt.now_s,
            controller_actions=(
                list(controller.actions) if controller is not None else []
            ),
        )

    # -- accounting ---------------------------------------------------------
    def expected_cost(self, result: CascadeResult) -> float:
        """Total cost of a routed run under the tiers' per-example costs
        (chunk padding included — that is the real serving cost)."""
        return result.cost

    def tier_fractions(self, result: CascadeResult) -> np.ndarray:
        """(n_tiers,) fraction of examples ANSWERED by each tier (the
        paper's exit-fraction breakdown, Table 5)."""
        return result.tier_counts / max(1, result.tier_counts.sum())
