from repro.serve.batching import Request, RequestQueue
from repro.serve.config import ServeConfig, resolve_serve_config
from repro.serve.engine import ServingEngine
from repro.serve.paging import PagePool
from repro.serve.slot_stream import EngineBackend, SlotStream, TierBackend
from repro.serve.cascade_server import (
    CascadeServer,
    CascadeTier,
    OpenLoopReport,
)
from repro.serve.controller import ControllerConfig, GreedyController
from repro.serve.workload import (
    ArrivalSpec,
    VirtualClock,
    Workload,
    bursty,
    diurnal,
    poisson,
)
from repro.serve.placement import (
    Host,
    TierPlacement,
    edge_cloud,
    hosts_disjoint,
    pod_placement,
    single_host,
)
from repro.serve.transport import (
    AsyncTransport,
    DevicePutTransport,
    LoopbackTransport,
    SendHandle,
    ShardedDevicePutTransport,
    SimulatedLinkTransport,
    Transport,
)

__all__ = [
    "Request",
    "RequestQueue",
    "ServeConfig",
    "resolve_serve_config",
    "ServingEngine",
    "SlotStream",
    "EngineBackend",
    "TierBackend",
    "PagePool",
    "CascadeServer",
    "CascadeTier",
    "OpenLoopReport",
    "ControllerConfig",
    "GreedyController",
    "ArrivalSpec",
    "VirtualClock",
    "Workload",
    "poisson",
    "bursty",
    "diurnal",
    "Host",
    "TierPlacement",
    "single_host",
    "edge_cloud",
    "pod_placement",
    "hosts_disjoint",
    "Transport",
    "SendHandle",
    "LoopbackTransport",
    "DevicePutTransport",
    "ShardedDevicePutTransport",
    "SimulatedLinkTransport",
    "AsyncTransport",
]
