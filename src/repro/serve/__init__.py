from repro.serve.batching import Request, RequestQueue
from repro.serve.engine import ServingEngine
from repro.serve.paging import PagePool
from repro.serve.slot_stream import EngineBackend, SlotStream, TierBackend
from repro.serve.cascade_server import CascadeServer, CascadeTier
from repro.serve.placement import (
    Host,
    TierPlacement,
    edge_cloud,
    hosts_disjoint,
    pod_placement,
    single_host,
)
from repro.serve.transport import (
    AsyncTransport,
    DevicePutTransport,
    LoopbackTransport,
    SendHandle,
    ShardedDevicePutTransport,
    SimulatedLinkTransport,
    Transport,
)

__all__ = [
    "Request",
    "RequestQueue",
    "ServingEngine",
    "SlotStream",
    "EngineBackend",
    "TierBackend",
    "PagePool",
    "CascadeServer",
    "CascadeTier",
    "Host",
    "TierPlacement",
    "single_host",
    "edge_cloud",
    "pod_placement",
    "hosts_disjoint",
    "Transport",
    "SendHandle",
    "LoopbackTransport",
    "DevicePutTransport",
    "ShardedDevicePutTransport",
    "SimulatedLinkTransport",
    "AsyncTransport",
]
