from repro.serve.batching import Request, RequestQueue
from repro.serve.engine import ServingEngine
from repro.serve.slot_stream import EngineBackend, SlotStream, TierBackend
from repro.serve.cascade_server import CascadeServer, CascadeTier

__all__ = [
    "Request",
    "RequestQueue",
    "ServingEngine",
    "SlotStream",
    "EngineBackend",
    "TierBackend",
    "CascadeServer",
    "CascadeTier",
]
