"""Inter-tier transport: the runtime object behind §5.2's cost boundaries.

A cascade's deferrals cross a placement boundary (edge→cloud, pod→pod,
host→API); the paper's headline numbers (14x edge communication reduction,
3x rental savings) all come from only DISAGREEMENTS paying that boundary's
cost.  This module makes the boundary a first-class runtime object instead
of a closed-form estimate: every deferral hop goes through a ``Transport``
that meters the actual payload bytes and accounts the per-hop latency, so
the scenario benchmarks report measured traffic next to the analytic
``EdgeCloudCost`` numbers.

Two backends:

``LoopbackTransport``       in-process hand-off (same host / ICI).  Zero
                            latency, but still meters bytes — tests assert
                            that ONLY the compacted deferral payload (not
                            the full batch) ever crosses a hop.

``SimulatedLinkTransport``  carries the §5.2.1 delay grid + a bandwidth
                            term (seconds = delay + bytes/bandwidth).  The
                            payload is explicitly fetched and re-fed
                            (device→host→device) — bytes genuinely move,
                            which is what a real edge→cloud RPC does; the
                            simulated clock accumulates instead of
                            sleeping so benches stay fast.

Latency here is SIMULATED time in seconds (the EDGE_DELAYS units from
``core.cost_model``), not wall time.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax

from repro.core.cost_model import EDGE_DELAYS


def tree_bytes(tree) -> int:
    """Total payload bytes of a pytree of arrays."""
    return int(
        sum(l.size * jax.numpy.dtype(l.dtype).itemsize for l in jax.tree.leaves(tree))
    )


@dataclasses.dataclass
class Hop:
    src: str
    dst: str
    n_examples: int
    payload_bytes: int
    latency: float  # simulated seconds


class Transport:
    """Base transport: metering + stats; subclasses set the link physics."""

    def __init__(self):
        self.hops: List[Hop] = []

    # -- link physics (overridden) ----------------------------------------
    def _latency(self, payload_bytes: int) -> float:
        return 0.0

    def _deliver(self, tree):
        return tree

    # -- public API ---------------------------------------------------------
    def send(self, src: str, dst: str, tree, *, n_examples: Optional[int] = None):
        """Move a payload pytree across the link; returns the delivered tree.
        Metering happens here — callers send ONLY what actually crosses the
        boundary (the compacted deferral payload, not the full batch)."""
        b = tree_bytes(tree)
        n = int(n_examples) if n_examples is not None else 0
        self.hops.append(Hop(src, dst, n, b, self._latency(b)))
        return self._deliver(tree)

    def reset(self):
        self.hops = []

    # -- stats ---------------------------------------------------------------
    @property
    def total_bytes(self) -> int:
        return sum(h.payload_bytes for h in self.hops)

    @property
    def total_latency(self) -> float:
        return sum(h.latency for h in self.hops)

    @property
    def total_examples(self) -> int:
        return sum(h.n_examples for h in self.hops)

    def stats(self) -> dict:
        return {
            "hops": len(self.hops),
            "bytes": self.total_bytes,
            "examples": self.total_examples,
            "latency": self.total_latency,
        }


class LoopbackTransport(Transport):
    """Same-host hand-off: no delay, payload stays on device."""


class DevicePutTransport(Transport):
    """Cross-host hand-off inside one jax process (pod→pod over ICI): the
    payload is re-placed onto the destination host's devices so the next
    tier's jitted programs see their own committed device set.  Bytes are
    metered like any hop; latency stays zero (ICI is not the §5.2.1
    bottleneck being modeled)."""

    def __init__(self, dst_sharding):
        super().__init__()
        self.dst_sharding = dst_sharding

    def _deliver(self, tree):
        return jax.tree.map(
            lambda l: jax.device_put(l, self.dst_sharding), tree
        )


class SimulatedLinkTransport(Transport):
    """A constrained link (edge→cloud): per-hop latency = delay + bytes/bw.

    ``delay`` may be a float (seconds) or a key into the paper's
    ``EDGE_DELAYS`` grid; ``bandwidth`` is bytes/second (None = latency is
    delay-dominated, the §5.2.1 model)."""

    def __init__(self, delay="medium", bandwidth: Optional[float] = None):
        super().__init__()
        self.delay = EDGE_DELAYS[delay] if isinstance(delay, str) else float(delay)
        self.bandwidth = bandwidth

    def _latency(self, payload_bytes: int) -> float:
        lat = self.delay
        if self.bandwidth:
            lat += payload_bytes / self.bandwidth
        return lat

    def _deliver(self, tree):
        # the link boundary is real: bytes leave the source device and are
        # re-fed on the destination side (explicit fetch — transfer-guard
        # clean; this is the one place deferral payload crosses the host)
        host = jax.device_get(tree)
        return jax.tree.map(jax.numpy.asarray, host)
