"""Inter-tier transport: the runtime object behind §5.2's cost boundaries.

A cascade's deferrals cross a placement boundary (edge→cloud, pod→pod,
host→API); the paper's headline numbers (14x edge communication reduction,
3x rental savings) all come from only DISAGREEMENTS paying that boundary's
cost.  This module makes the boundary a first-class runtime object instead
of a closed-form estimate: every deferral hop goes through a ``Transport``
that meters the actual payload bytes and accounts the per-hop latency, so
the scenario benchmarks report measured traffic next to the analytic
``EdgeCloudCost`` numbers.

Payload/bytes contract (shared by every backend): callers ``send`` ONLY
what actually crosses the boundary — the compacted deferral payload (plus
its i32 routing index map), never the full batch — and every hop records
``Hop(src, dst, n_examples, payload_bytes, latency)`` at send time, so the
metered hop list is identical whether a hop is drained eagerly or lazily.
Continuous-mode deferral payloads are ``{"tokens": (S,) i32 prompt}``
plus, under ``ServeConfig.speculative``, ``"draft": (T,) i32`` — the
sending tier's agreeing generation, verified by the receiving tier in one
chunked pass (serve/speculative.py); draft bytes are metered on the hop
like any other payload leaf.

Backends:

``LoopbackTransport``       in-process hand-off (same host / ICI).  Zero
                            latency, but still meters bytes — tests assert
                            that ONLY the compacted deferral payload (not
                            the full batch) ever crosses a hop.

``SimulatedLinkTransport``  carries the §5.2.1 delay grid + a bandwidth
                            term (seconds = delay + bytes/bandwidth).  The
                            payload is explicitly fetched and re-fed
                            (device→host→device) — bytes genuinely move,
                            which is what a real edge→cloud RPC does; the
                            simulated clock accumulates instead of
                            sleeping so benches stay fast.

``DevicePutTransport``      pod→pod re-placement inside one jax process:
                            the payload is device_put onto the destination
                            slice (replicated — the parity baseline for
                            the sharded hand-off below).

``ShardedDevicePutTransport``  pod→pod re-placement that SHARDS the
                            payload's example axis over the destination
                            slice's ('pod', 'data') mesh axes via the
                            logical rule table, instead of replicating
                            rows across the whole slice (DESIGN.md §8).

``AsyncTransport``          the same link physics as the simulated link,
                            but latency is REAL wall-clock sleep served
                            from a worker thread: ``send_async`` returns a
                            ``SendHandle`` immediately and the payload
                            "arrives" (the handle resolves) ``latency``
                            seconds later, so a serving loop keeps
                            decoding while the hop is in flight
                            (DESIGN.md §8 overlap contract).

Every backend also exposes the future-based hop API: ``send_async``
returns a ``SendHandle``; for synchronous backends the handle is already
resolved (the hop completed inside ``send_async``), so one call-site
serves both.  Latency units: ``SimulatedLinkTransport`` accounts SIMULATED
seconds (the EDGE_DELAYS units from ``core.cost_model``); ``AsyncTransport``
accounts the same number as real wall-clock seconds.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import List, Optional

import jax

from repro.core.cost_model import EDGE_DELAYS
from repro.obs import perf_clock


def tree_bytes(tree) -> int:
    """Total payload bytes of a pytree of arrays."""
    return int(
        sum(l.size * jax.numpy.dtype(l.dtype).itemsize for l in jax.tree.leaves(tree))
    )


@dataclasses.dataclass
class Hop:
    """One metered boundary crossing: ``n_examples`` real (unpadded)
    deferred examples, ``payload_bytes`` as sent (bucket padding included —
    that is what crosses the wire), ``latency`` in the backend's seconds
    (simulated or wall-clock, see module docstring)."""

    src: str
    dst: str
    n_examples: int
    payload_bytes: int
    latency: float


class SendHandle:
    """The future side of a hop: ``send_async`` returns one immediately;
    ``result()`` blocks until the payload has crossed the link and returns
    the delivered tree (memoized — repeated calls are free).  ``done()``
    never blocks, so admission points can poll.

    ``wait_time`` records how long ``result()`` actually blocked: the part
    of the hop's latency the caller FAILED to hide behind other work.  The
    transport aggregates it (``Transport.total_wait``), which is how the
    benches measure the overlap win without instrumenting the serving loop.
    """

    def __init__(self, transport: "Transport", future: Optional[Future] = None,
                 value=None, finalize=None):
        self._transport = transport
        self._future = future
        self._value = value
        self._finalize = finalize  # runs on the DRAINING thread, once
        self._resolved = future is None
        self.wait_time = 0.0

    @classmethod
    def resolved(cls, transport: "Transport", value) -> "SendHandle":
        """A handle whose hop already completed (synchronous backends)."""
        return cls(transport, value=value)

    def done(self) -> bool:
        """True once the payload has crossed the link (never blocks)."""
        return self._resolved or self._future.done()

    def result(self):
        """The delivered payload tree; blocks until the hop completes and
        charges the blocked time to ``wait_time``/``Transport.total_wait``."""
        if not self._resolved:
            clock = self._transport._clock
            t0 = clock()
            self._value = self._future.result()
            self.wait_time = clock() - t0
            self._transport._waited(self.wait_time)
            self._resolved = True
            self._future = None
            if self._finalize is not None:
                # arrival-side work (re-feeding the payload to the device)
                # happens on the draining thread — workers only sleep the
                # link, so jax device interaction stays single-threaded
                self._value = self._finalize(self._value)
                self._finalize = None
        return self._value


class Transport:
    """Base transport: metering + stats; subclasses set the link physics.

    Subclass hooks: ``_latency(payload_bytes)`` (seconds the hop accounts)
    and ``_deliver(tree)`` (what crossing the boundary does to the payload).
    The base ``send``/``send_async`` are synchronous — ``send_async`` exists
    on every backend so call-sites are written once against the handle API;
    only ``AsyncTransport`` actually defers delivery."""

    def __init__(self):
        self.hops: List[Hop] = []
        self.total_wait = 0.0  # seconds callers blocked in SendHandle.result
        self._wait_lock = threading.Lock()
        # injectable wait clock (DESIGN.md §11 / abclint ABC601); link
        # physics (the token bucket's time.monotonic) stays real wall-clock
        self._clock = perf_clock
        self._obs_c = None  # optional mirrored registry counters

    def attach_obs(self, obs, name: str):
        """Mirror this link's hop metering into ``obs``'s registry under
        ``transport.{name}.*`` (hops / bytes / examples / latency_s /
        wait_s).  The legacy ``stats()`` dict and hop list stay the source
        of truth; the registry mirror is what the unified exporter reads."""
        sc = obs.scope(f"transport.{name}")
        self._clock = obs.clock
        self._obs_c = (
            sc.counter("hops"),
            sc.counter("bytes"),
            sc.counter("examples"),
            sc.counter("latency_s"),
            sc.counter("wait_s"),
        )
        return self

    # -- link physics (overridden) ----------------------------------------
    def _latency(self, payload_bytes: int) -> float:
        return 0.0

    def _deliver(self, tree):
        return tree

    def _waited(self, seconds: float):
        with self._wait_lock:
            self.total_wait += seconds
        if self._obs_c is not None:
            self._obs_c[4].add(seconds)

    # -- public API ---------------------------------------------------------
    def send(self, src: str, dst: str, tree, *, n_examples: Optional[int] = None):
        """Move a payload pytree across the link; returns the delivered tree.
        Metering happens here — callers send ONLY what actually crosses the
        boundary (the compacted deferral payload, not the full batch)."""
        return self.send_async(src, dst, tree, n_examples=n_examples).result()

    def send_async(
        self, src: str, dst: str, tree, *, n_examples: Optional[int] = None
    ) -> SendHandle:
        """Start a hop and return its ``SendHandle``.  The hop is metered
        HERE (at send time), so the hop list — order, bytes, examples,
        latency — is identical whether the handle is drained eagerly or
        lazily.  Base implementation delivers synchronously and returns a
        resolved handle; ``AsyncTransport`` overrides delivery only."""
        self._meter(src, dst, tree, n_examples)
        return SendHandle.resolved(self, self._deliver(tree))

    def _meter(self, src, dst, tree, n_examples) -> Hop:
        b = tree_bytes(tree)
        n = int(n_examples) if n_examples is not None else 0
        hop = Hop(src, dst, n, b, self._latency(b))
        self.hops.append(hop)
        if self._obs_c is not None:
            c_hops, c_bytes, c_examples, c_latency, _ = self._obs_c
            c_hops.add(1)
            c_bytes.add(b)
            c_examples.add(n)
            c_latency.add(hop.latency)
        return hop

    def reset(self):
        """Drop all metered hops (and the blocked-wait accumulator)."""
        self.hops = []
        self.total_wait = 0.0

    # -- stats ---------------------------------------------------------------
    @property
    def total_bytes(self) -> int:
        """Sum of payload bytes over every metered hop."""
        return sum(h.payload_bytes for h in self.hops)

    @property
    def total_latency(self) -> float:
        """Sum of per-hop link seconds — the SERIAL link time: what the
        hops cost a stop-the-world serving loop that blocks on every send.
        An overlapped loop pays only ``total_wait`` of it on the wall."""
        return sum(h.latency for h in self.hops)

    @property
    def total_examples(self) -> int:
        """Sum of real (unpadded) deferred examples over every hop."""
        return sum(h.n_examples for h in self.hops)

    def stats(self) -> dict:
        """Aggregate hop metering as a plain dict (benches' report row)."""
        return {
            "hops": len(self.hops),
            "bytes": self.total_bytes,
            "examples": self.total_examples,
            "latency": self.total_latency,
            "wait": self.total_wait,
        }


class LoopbackTransport(Transport):
    """Same-host hand-off: no delay, payload stays on device.  Exists so
    single-host placements still meter WHAT would cross a real boundary
    (only the compacted deferral payload) without paying one."""


class DevicePutTransport(Transport):
    """Cross-host hand-off inside one jax process (pod→pod over ICI): the
    payload is re-placed onto the destination host's devices so the next
    tier's jitted programs see their own committed device set.  Bytes are
    metered like any hop; latency stays zero (ICI is not the §5.2.1
    bottleneck being modeled).

    ``dst_sharding`` is applied to EVERY leaf as-is — with the default
    ``PartitionSpec()`` that replicates each payload row on every device of
    the destination slice.  This is the parity baseline;
    ``ShardedDevicePutTransport`` is the production hand-off (payload rows
    sharded over the slice, DESIGN.md §8)."""

    def __init__(self, dst_sharding):
        super().__init__()
        self.dst_sharding = dst_sharding

    def _deliver(self, tree):
        return jax.tree.map(
            lambda l: jax.device_put(l, self.dst_sharding), tree
        )


class ShardedDevicePutTransport(Transport):
    """Data-sharded pod→pod hand-off (DESIGN.md §8): the compacted payload's
    leading EXAMPLE axis is device_put sharded over the destination slice's
    ('pod', 'data') mesh axes through the logical rule table ('act_batch'
    row, ``sharding.logical``), instead of replicating every row across the
    whole slice.  Trailing axes stay replicated (deferral payloads are
    per-example rows, not weight matrices).

    Bytes metered are the bytes SENT (one copy of the payload) — the same
    number the replicated transport meters, because what crosses the
    boundary is the payload, not its destination residency; what changes is
    per-device HBM residency on arrival: ``1/shard_count`` of the payload
    per device instead of all of it.  ``logical_to_pspec`` drops any mesh
    axis that does not divide the concrete example count, so odd-sized
    payloads degrade to replication rather than failing."""

    def __init__(self, dst_mesh, *, kind: str = "decode"):
        super().__init__()
        from repro.sharding.logical import make_rules

        self.dst_mesh = dst_mesh
        self.rules = make_rules(kind, pod=True)

    def example_sharding(self, leaf) -> "jax.sharding.NamedSharding":
        """The destination sharding for one (B, ...) payload leaf: leading
        axis 'act_batch' -> the slice's ('pod', 'data'), rest replicated."""
        from jax.sharding import NamedSharding

        from repro.sharding.logical import logical_to_pspec

        axes = ("act_batch",) + (None,) * (leaf.ndim - 1)
        pspec = logical_to_pspec(
            axes, self.rules, shape=leaf.shape, mesh=self.dst_mesh
        )
        return NamedSharding(self.dst_mesh, pspec)

    def shard_counts(self, tree) -> List[int]:
        """Per-leaf number of distinct example-axis shards the delivered
        payload lands in (1 = that leaf degraded to replication)."""
        import numpy as np

        counts = []
        for leaf in jax.tree.leaves(tree):
            spec = self.example_sharding(leaf).spec
            axes = spec[0] if len(spec) else None
            if axes is None:
                counts.append(1)
            else:
                names = (axes,) if isinstance(axes, str) else tuple(axes)
                sizes = dict(zip(self.dst_mesh.axis_names,
                                 self.dst_mesh.devices.shape))
                counts.append(int(np.prod([sizes[a] for a in names])))
        return counts

    def _deliver(self, tree):
        return jax.tree.map(
            lambda l: jax.device_put(l, self.example_sharding(l)), tree
        )


class SimulatedLinkTransport(Transport):
    """A constrained link (edge→cloud): per-hop latency = delay + bytes/bw.

    ``delay`` may be a float (seconds) or a key into the paper's
    ``EDGE_DELAYS`` grid; ``bandwidth`` is bytes/second (None = latency is
    delay-dominated, the §5.2.1 model).  The accounted latency is a
    SIMULATED clock — ``send`` returns immediately and benches sweep the
    delay grid over the metered hops; ``AsyncTransport`` is the wall-clock
    twin whose hops genuinely take that long to resolve."""

    def __init__(self, delay="medium", bandwidth: Optional[float] = None):
        super().__init__()
        self.delay = EDGE_DELAYS[delay] if isinstance(delay, str) else float(delay)
        self.bandwidth = bandwidth

    def _latency(self, payload_bytes: int) -> float:
        lat = self.delay
        if self.bandwidth:
            lat += payload_bytes / self.bandwidth
        return lat

    def _deliver(self, tree):
        # the link boundary is real: bytes leave the source device and are
        # re-fed on the destination side (explicit fetch — transfer-guard
        # clean; this is the one place deferral payload crosses the host)
        host = jax.device_get(tree)
        return jax.tree.map(jax.numpy.asarray, host)


class AsyncTransport(SimulatedLinkTransport):
    """Overlapped edge→cloud link: same physics as the simulated link, but
    latency is REAL.  ``send_async`` meters the hop, snapshots the payload
    off the source device (device_get — the bytes leave NOW, so the sender
    is free to keep mutating its batch), and returns a ``SendHandle`` that
    resolves after a worker thread has slept the hop's ``latency`` — the
    wall-clock behaviour of an in-flight RPC.  The caller (the
    ``SlotStream`` admission points, DESIGN.md §8) keeps decoding while the
    hop is in flight and drains the handle when the payload is needed.

    ``overlap=False`` degrades ``send_async`` to the blocking base
    behaviour (sleep inline, return a resolved handle): the stop-the-world
    serial baseline the benches compare against.  Both modes meter
    IDENTICAL hops (same order, bytes, examples, latency — metering happens
    at send time) and deliver identical payloads, which is what makes the
    measured overlap ratio an apples-to-apples wall-clock comparison.

    Link capacity is a token bucket: a hop's TRANSMISSION time
    (``bytes / bandwidth``) reserves the link exclusively, so N concurrent
    sends serialize on capacity — the k-th departure waits for k-1
    transmissions — while the propagation ``delay`` still overlaps freely
    (many packets in flight at once, none transmitting simultaneously:
    real link physics, where the old model let concurrent hops share the
    wire for free).  Metering is UNCHANGED contended or not: ``hop.latency``
    stays the uncontended ``delay + bytes/bandwidth`` recorded at send
    time, so serial and overlapped drains meter identical hops; contention
    shows up only in wall-clock resolution order and ``total_wait``.
    Without a ``bandwidth``, hops are pure delay and fully concurrent
    (the §5.2.1 delay-dominated model).  Determinism: delivery only
    affects WHEN a deferred example is re-admitted, never its tokens —
    cascades generate bitwise-identically under either mode at any
    temperature (tests/test_async_transport.py).

    Worker threads come from one lazily-created module-level pool shared by
    every AsyncTransport (workers only sleep, so sharing costs nothing and
    bounds the process at ``_MAX_WORKERS`` transport threads no matter how
    many links benches/tests construct); ``shutdown_async_workers()`` tears
    it down for callers that need a clean thread count."""

    _MAX_WORKERS = 8  # in-flight hops beyond this queue behind the pool

    def __init__(self, delay="medium", bandwidth: Optional[float] = None,
                 *, overlap: bool = True):
        super().__init__(delay=delay, bandwidth=bandwidth)
        self.overlap = overlap
        # token bucket over link capacity: _busy_until is the monotonic
        # time the wire finishes its last reserved transmission
        self._bucket_lock = threading.Lock()
        self._busy_until = 0.0

    def _reserve_tx(self, payload_bytes: int) -> float:
        """Reserve this hop's exclusive transmission slot on the wire and
        return the seconds the hop takes END-TO-END from now: queueing
        behind earlier transmissions + its own bytes/bandwidth + the
        propagation delay.  Serial (one-at-a-time) senders never queue, so
        this degenerates to exactly ``_latency(payload_bytes)``."""
        tx = payload_bytes / self.bandwidth if self.bandwidth else 0.0
        with self._bucket_lock:
            now = time.monotonic()
            start = max(now, self._busy_until)
            self._busy_until = start + tx
        return (start - now) + tx + self.delay

    def _executor(self) -> ThreadPoolExecutor:
        global _WORKER_POOL
        with _POOL_LOCK:
            if _WORKER_POOL is None:
                _WORKER_POOL = ThreadPoolExecutor(
                    max_workers=self._MAX_WORKERS,
                    thread_name_prefix="async-transport",
                )
            return _WORKER_POOL

    @staticmethod
    def _refeed(host_tree):
        return jax.tree.map(jax.numpy.asarray, host_tree)

    @staticmethod
    def _sleep_link(host_tree, latency: float):
        time.sleep(latency)
        return host_tree

    def send_async(
        self, src: str, dst: str, tree, *, n_examples: Optional[int] = None
    ) -> SendHandle:
        """Start a real-wall-clock hop; the handle resolves after the
        link's latency has actually elapsed (see class docstring)."""
        hop = self._meter(src, dst, tree, n_examples)
        # the wall-clock duration reserves link capacity (token bucket) and
        # may exceed the metered hop.latency under contention; metering
        # stays the uncontended number so drain order never changes hops
        wall = self._reserve_tx(hop.payload_bytes)
        # snapshot off-device in the CALLER's thread: the payload's bytes
        # leave the source at send time.  The worker ONLY sleeps the link;
        # re-feeding to the device happens on the draining thread via the
        # handle's finalize, so jax device work stays single-threaded
        host = jax.device_get(tree)
        if not self.overlap:
            time.sleep(wall)
            return SendHandle.resolved(self, self._refeed(host))
        fut = self._executor().submit(self._sleep_link, host, wall)
        return SendHandle(self, future=fut, finalize=self._refeed)


# the shared AsyncTransport worker pool (see AsyncTransport docstring)
_WORKER_POOL: Optional[ThreadPoolExecutor] = None
_POOL_LOCK = threading.Lock()


def shutdown_async_workers():
    """Tear down the shared AsyncTransport worker pool (idempotent).  Waits
    for in-flight hops; handles already resolved stay resolvable.  The next
    ``send_async`` lazily recreates the pool."""
    global _WORKER_POOL
    with _POOL_LOCK:
        pool, _WORKER_POOL = _WORKER_POOL, None
    if pool is not None:
        pool.shutdown(wait=True)
