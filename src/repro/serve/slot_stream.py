"""SlotStream: the single slot state machine behind all continuous batching.

One ``SlotStream`` owns the admit / refill / prompt-feed / force-complete
lifecycle of ``n_slots`` decode slots over stacked ``(E, n_slots, ...)``
caches; the single-model engine is just the E=1 case and a cascade tier the
E=k case, so ``ServingEngine.serve_continuous`` and
``CascadeServer.serve_continuous`` are both thin drivers over this module.

Slot-isolation contract (why mid-stream reuse is safe):

* prompts are left-aligned at position 0 of their slot; every slot advances
  at its OWN ``pos`` (the decode program takes a per-slot (B,) position
  vector).  Attention reads cache rows ``< pos+1`` only, so stale KV rows
  written by a slot's previous occupant are invisible — that is the
  pos-masking contract shared with ``attention_decode`` and
  ``attention_prefill_chunk``.
* constant-state families (SSM/RWKV, hybrid's mamba leaves) have no pos
  mask, so admission zeroes the slot's state leaves through the backend's
  jitted ``reset_slot`` program — this is what lifts the old
  attention-families-only restriction on cascade continuous batching.

Chunked-prefill admission: on admit, ``prompt[:-1]`` is consumed in exact
power-of-two chunks (``core.cascade.prompt_chunks``) through a per-bucket
jitted prefill-into-slot program (``models.api.prefill_into_slot``) that
writes KV rows / advances state at the slot's offset — a 400-token prompt
costs a handful of chunk calls instead of ~400 decode steps.  The final
prompt token always goes through the shared decode program (its logits
sample the first output token), which keeps chunked and decode-only
admission token-for-token identical.  Chunk shapes come from the O(log S)
bucket set, so trace counters stay flat across requests after warmup.

Device work goes through a small backend protocol (duck-typed):

    E                        int, ensemble width
    supports_chunked_prefill bool
    decode(tok (E, n_slots, 1), pos (n_slots,)) -> next (E, n_slots)
    prefill_chunk(tokens (C,), slot, start)     -> None   (updates cache)
    reset_slot(slot)                            -> None   (zero state leaves)

``EngineBackend`` (E=1, host-side sampling via the engine's rng) and
``TierBackend`` (ensemble programs with in-program sampling) are provided
here; both reuse the module-level compile-once program caches.
"""
from __future__ import annotations

import time
from collections import deque
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cascade import prompt_chunks
from repro.models import api
from repro.models.params import unbox
from repro.serve.batching import Request


class SlotStream:
    """Slot-based continuous batching over a device backend."""

    def __init__(
        self,
        backend,
        *,
        n_slots: int = 8,
        max_seq: int = 256,
        chunked_prefill: bool = True,
        max_chunk: int = 256,
    ):
        self.backend = backend
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.max_chunk = max_chunk
        self.chunked = bool(chunked_prefill) and backend.supports_chunked_prefill
        E = backend.E
        self.queue: deque = deque()
        self.slot_req: List[Optional[Request]] = [None] * n_slots
        self.slot_consumed = np.zeros(n_slots, np.int64)  # prompt tokens fed
        self.slot_emitted: List[List[np.ndarray]] = [[] for _ in range(n_slots)]
        self.pos = np.zeros(n_slots, np.int32)
        self.tok = np.zeros((E, n_slots, 1), np.int32)
        self.steps = 0
        self.stats = {
            "admitted": 0,
            "chunk_calls": 0,
            "chunk_tokens": 0,
            "decode_tokens": 0,  # active slot-steps through the decode program
            # host wall time inside admission / decode dispatch.  jax
            # dispatch is async, so these measure enqueue overhead, not
            # device compute — block_until_ready on the backend's cache
            # around refill()/step() to measure true device latency
            # (benchmarks/bench_serving.py does).
            "admit_time": 0.0,
            "decode_time": 0.0,
        }

    # -- admission ---------------------------------------------------------
    def submit(self, requests: Sequence[Request]):
        for r in requests:
            assert len(r.tokens) >= 1, f"request {r.rid}: empty prompt"
            assert len(r.tokens) < self.max_seq, (
                f"request {r.rid}: prompt length {len(r.tokens)} does not fit "
                f"max_seq={self.max_seq}"
            )
            self.queue.append(r)

    def _admit(self, s: int):
        if not self.queue:
            self.slot_req[s] = None
            return
        r = self.queue.popleft()
        t0 = time.perf_counter()
        self.backend.reset_slot(s)
        consumed = 0
        if self.chunked and len(r.tokens) > 1:
            # consume prompt[:-1] in bucketed pow2 chunks; the last prompt
            # token rides the decode program (see module docstring)
            m = len(r.tokens) - 1
            chunks = prompt_chunks(m, self.max_chunk)
            off = 0
            for c in chunks:
                self.backend.prefill_chunk(r.tokens[off : off + c], s, off)
                off += c
            consumed = off
            self.stats["chunk_calls"] += len(chunks)
            self.stats["chunk_tokens"] += m
        self.slot_req[s] = r
        self.slot_consumed[s] = consumed + 1
        self.slot_emitted[s] = []
        self.pos[s] = consumed
        self.tok[:, s, 0] = r.tokens[consumed]
        self.stats["admitted"] += 1
        self.stats["admit_time"] += time.perf_counter() - t0

    def refill(self):
        for s in range(self.n_slots):
            if self.slot_req[s] is None and self.queue:
                self._admit(s)

    @property
    def active(self) -> bool:
        return any(r is not None for r in self.slot_req) or bool(self.queue)

    # -- stepping ----------------------------------------------------------
    def step(self) -> List[Tuple[Request, np.ndarray]]:
        """Advance every active slot by one token; returns the list of
        (request, member generations (E, T)) that completed this step.
        Freed slots immediately admit from ``self.queue``."""
        self.refill()
        n_active = sum(r is not None for r in self.slot_req)
        if n_active == 0:
            return []
        t0 = time.perf_counter()
        nxt = self.backend.decode(self.tok, self.pos)  # (E, n_slots)
        self.stats["decode_time"] += time.perf_counter() - t0
        self.stats["decode_tokens"] += n_active
        self.steps += 1
        completed = []
        for s, r in enumerate(self.slot_req):
            if r is None:
                continue
            self.pos[s] += 1
            if self.slot_consumed[s] < len(r.tokens):
                # prompt-feed: still consuming the prompt through decode
                self.tok[:, s, 0] = r.tokens[self.slot_consumed[s]]
                self.slot_consumed[s] += 1
            else:
                self.slot_emitted[s].append(nxt[:, s].copy())
                self.tok[:, s, 0] = nxt[:, s]
                full = len(self.slot_emitted[s]) >= r.max_new_tokens
                wall = self.pos[s] >= self.max_seq - 1  # out of cache rows
                if full or wall:
                    r.truncated = not full
                    gen = (
                        np.stack(self.slot_emitted[s], axis=1)
                        if self.slot_emitted[s]
                        else np.zeros((self.backend.E, 0), np.int32)
                    )
                    completed.append((r, gen))
                    self._admit(s)
        return completed

    def drain(self) -> List[Tuple[Request, np.ndarray]]:
        """Step until every queued request has completed."""
        done = []
        while self.active:
            done.extend(self.step())
        return done


# ---------------------------------------------------------------------------
# backends
# ---------------------------------------------------------------------------


class EngineBackend:
    """E=1 backend over a single model's compile-once programs.

    ``programs`` is the ``model_programs(cfg)`` namespace (decode /
    prefill_chunk / reset_slot); sampling stays on the host through
    ``sample`` (the engine's temperature + rng policy)."""

    def __init__(self, cfg, params, programs, sample, *, n_slots, max_seq,
                 stats=None):
        assert not cfg.is_encoder
        self.cfg = cfg
        self.params = params
        self._decode = programs.decode
        self._chunk = getattr(programs, "prefill_chunk", None)
        self._reset = getattr(programs, "reset_slot", None)
        self._sample = sample
        self._stats = stats
        self.E = 1
        self.cache, _ = unbox(api.init_cache(cfg, n_slots, max_seq))
        self.supports_chunked_prefill = self._chunk is not None

    def decode(self, tok, pos):
        logits, self.cache = self._decode(
            self.params, jnp.asarray(tok[0]), self.cache, jnp.asarray(pos)
        )
        return np.asarray(self._sample(logits))[None]  # (1, n_slots)

    def prefill_chunk(self, tokens, slot, start):
        self.cache = self._chunk(
            self.params, jnp.asarray(tokens), self.cache,
            jnp.int32(slot), jnp.int32(start),
        )
        if self._stats is not None:
            self._stats["prefill_tokens"] += len(tokens)

    def reset_slot(self, slot):
        if self._reset is not None:
            self.cache = self._reset(self.cache, jnp.int32(slot))


class TierBackend:
    """E=k backend over a cascade tier's stacked-ensemble programs (one
    vmapped XLA program advances every member; sampling lives inside the
    programs with the tier's rng threading)."""

    def __init__(self, tier, *, n_slots, max_seq, seed: int = 0):
        assert not tier.cfg.is_encoder
        self.tier = tier
        self.E = tier.k
        self.rng = jax.random.PRNGKey(seed)
        values0, _ = unbox(api.init_cache(tier.cfg, n_slots, max_seq))
        self.caches = jax.tree.map(
            lambda v: jnp.zeros((self.E,) + v.shape, v.dtype), values0
        )
        self.supports_chunked_prefill = (
            getattr(tier, "_prefill_chunk", None) is not None
        )

    def decode(self, tok, pos):
        t, self.caches, self.rng = self.tier._decode(
            self.tier.values, jnp.asarray(tok), self.caches,
            jnp.asarray(pos), self.rng,
        )
        return np.asarray(t)[..., 0]  # (E, n_slots)

    def prefill_chunk(self, tokens, slot, start):
        self.caches = self.tier._prefill_chunk(
            self.tier.values, self.caches, jnp.asarray(tokens),
            jnp.int32(slot), jnp.int32(start),
        )

    def reset_slot(self, slot):
        if getattr(self.tier, "_reset_slot", None) is not None:
            self.caches = self.tier._reset_slot(self.caches, jnp.int32(slot))
