"""SlotStream: the single slot state machine behind all continuous batching.

One ``SlotStream`` owns the admit / refill / prompt-feed / force-complete
lifecycle of ``n_slots`` decode slots over stacked ``(E, n_slots, ...)``
caches; the single-model engine is just the E=1 case and a cascade tier the
E=k case, so ``ServingEngine.serve_continuous`` and
``CascadeServer.serve_continuous`` are both thin drivers over this module.

Slot-isolation contract (why mid-stream reuse is safe):

* prompts are left-aligned at position 0 of their slot; every slot advances
  at its OWN ``pos`` (the decode program takes a per-slot (B,) position
  vector).  Attention reads cache rows ``< pos+1`` only, so stale KV rows
  written by a slot's previous occupant are invisible — that is the
  pos-masking contract shared with ``attention_decode`` and
  ``attention_prefill_chunk``.
* constant-state families (SSM/RWKV, hybrid's mamba leaves) have no pos
  mask, so admission zeroes the slot's state leaves through the backend's
  jitted ``reset_slot`` program — this is what lifts the old
  attention-families-only restriction on cascade continuous batching.

Chunked-prefill admission: on admit, ``prompt[:-1]`` is consumed in exact
power-of-two chunks (``core.cascade.prompt_chunks``) through a per-bucket
jitted prefill-into-slot program (``models.api.prefill_into_slot``) that
writes KV rows / advances state at the slot's offset — a 400-token prompt
costs a handful of chunk calls instead of ~400 decode steps.  The final
prompt token always goes through the shared decode program (its logits
sample the first output token), which keeps chunked and decode-only
admission token-for-token identical.  Chunk shapes come from the O(log S)
bucket set, so trace counters stay flat across requests after warmup.

In-flight admission (the overlap half of DESIGN.md §8): work whose payload
is still crossing a ``Transport`` link enters through ``submit_inflight``
as a (``SendHandle``, finalize) pair instead of a ready ``Request``.  The
stream's ONLY legal drain points are its admission points — the top of
``refill()`` (polls, never blocks: decode keeps running while hops are in
flight) and ``drain()``/the driver's all-idle fallback (blocks on the
oldest handle only when no stream has runnable work, so waiting can never
starve compute).  Handles resolve strictly in submission (FIFO) order,
which keeps the admission order — and therefore the whole stream — equal
to what a blocking transport would produce.

Device work goes through a small backend protocol (duck-typed):

    E                        int, ensemble width
    supports_chunked_prefill bool
    decode(tok (E, n_slots, 1), pos (n_slots,)) -> next (E, n_slots)
    prefill_chunk(tokens (C,), slot, start)     -> None   (updates cache)
    reset_slot(slot)                            -> None   (zero state leaves)

plus three OPTIONAL hooks for backends whose slot memory is allocated
rather than dedicated (the block-paged KV pools — serve/paging.py):

    begin_slot(slot, tokens, share) -> Optional[int]
        claim slot memory for a prompt before any prefill; returns the
        number of leading prompt tokens already covered by shared prefix
        pages (0 for dense), or None when the pool cannot admit — the
        request stays queued and the slot stays free.  Subsumes
        ``reset_slot``.  ``share`` is the stream's chunked flag: prefix
        pages may only be published when chunked prefill writes them in
        full before any sharer can be admitted.
    release_slot(slot) -> None
        return the slot's memory (decref pages) on completion.
    prepare_step(pos, active) -> [slot, ...]
        make each active slot's next write position mapped (grow-by-page,
        copy-on-write); returns the slots the pool could NOT serve — the
        stream force-completes those with ``truncated=True`` (the paged
        analogue of the dense cache wall).

``EngineBackend`` (E=1, host-side sampling via the engine's rng) and
``TierBackend`` (ensemble programs with in-program sampling) are provided
here; both reuse the module-level compile-once program caches, and both
default to block-paged pools where ``api.supports_paging`` allows, keeping
the dense slot cache available behind ``paged=False`` as the bitwise
parity oracle.
"""
from __future__ import annotations

from collections import deque
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cascade import host_fetch, prompt_chunks
from repro.models import api
from repro.models.params import unbox
from repro.obs import Observability, StatsView
from repro.serve.batching import Request
from repro.serve.config import UNSET, ServeConfig, resolve_serve_config
from repro.serve.speculative import accepted_prefix, plan_draft


class SlotStream:
    """Slot-based continuous batching over a device backend.

    Construction takes a ``ServeConfig`` (``config=``) or the legacy
    kwargs (one deprecation pathway — ``serve/config.py``).  The stream
    reads the scheduling fields (``n_slots``/``max_seq``/
    ``chunked_prefill``/``max_chunk``/``obs``); the memory/sampling fields
    (``paged``/``page_size``/``n_pages``/``seed``) belong to the backend
    its caller already built."""

    def __init__(
        self,
        backend,
        config: Optional[ServeConfig] = None,
        *,
        n_slots=UNSET,
        max_seq=UNSET,
        chunked_prefill=UNSET,
        max_chunk=UNSET,
        obs=UNSET,
        name: str = "slot_stream",
    ):
        cfg = resolve_serve_config(
            config, "SlotStream", n_slots=n_slots, max_seq=max_seq,
            chunked_prefill=chunked_prefill, max_chunk=max_chunk, obs=obs,
        ).with_max_seq_default(256)
        n_slots, max_seq = cfg.n_slots, cfg.max_seq
        chunked_prefill, max_chunk, obs = (
            cfg.chunked_prefill, cfg.max_chunk, cfg.obs,
        )
        self.backend = backend
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.max_chunk = max_chunk
        self.chunked = bool(chunked_prefill) and backend.supports_chunked_prefill
        # admission-side slot cap (<= n_slots): the online controller's
        # slot-count actuation point.  Slots at index >= slot_limit stop
        # ADMITTING; occupants above a lowered limit drain naturally, so
        # actuation never aborts in-flight work.
        self.slot_limit = n_slots
        E = backend.E
        self.queue: deque = deque()
        # (SendHandle, finalize) pairs whose payload is still in flight on a
        # transport link; drained FIFO at the admission points (see module
        # docstring — this is where compute/communication overlap happens)
        self.inflight: deque = deque()
        self.slot_req: List[Optional[Request]] = [None] * n_slots
        self.slot_consumed = np.zeros(n_slots, np.int64)  # prompt tokens fed
        self.slot_emitted: List[List[np.ndarray]] = [[] for _ in range(n_slots)]
        self.pos = np.zeros(n_slots, np.int32)
        self.tok = np.zeros((E, n_slots, 1), np.int32)
        self.steps = 0
        # requests whose draft verification finished them AT ADMISSION
        # (full acceptance consumed the whole budget / hit the wall): they
        # never see a decode step, so ``step()`` hands them back from here
        self._admit_done: List[Tuple[Request, np.ndarray]] = []
        # cascade hook: called as (request, n_accepted, n_draft) after
        # every verify pass so the run can record per-tier accept rates
        self.on_draft_verified = None
        # telemetry (DESIGN.md §11): counters + histograms on the stream's
        # obs registry, named under ``name`` (cascade tiers pass
        # ``slot_stream.tier{i}`` so one registry serves every tier).
        # Timestamps go through the injectable ``obs.clock`` (ABC601), and
        # everything recorded is a host scalar the loop already owns.
        self.obs = obs if obs is not None else Observability.private()
        self.name = name
        self._clock = self.obs.clock
        self._tr = self.obs.tracer
        sc = self.obs.scope(name)
        self._c_admitted = sc.counter("admitted")
        self._c_admit_failures = sc.counter("admit_failures")
        self._c_forced = sc.counter("forced_completions")
        self._c_chunk_calls = sc.counter("chunk_calls")
        self._c_chunk_tokens = sc.counter("chunk_tokens")
        self._c_shared_tokens = sc.counter("shared_tokens")
        self._c_decode_tokens = sc.counter("decode_tokens")
        self._c_inflight_admitted = sc.counter("inflight_admitted")
        # speculative verify (serve/speculative.py): passes run, draft
        # tokens offered, draft tokens accepted
        self._c_spec_drafts = sc.counter("spec.drafts")
        self._c_spec_draft_tokens = sc.counter("spec.draft_tokens")
        self._c_spec_accepted = sc.counter("spec.accepted_tokens")
        # ready-queue depth after every enqueue/admit — the streaming
        # backlog signal the online controller reads from the registry
        self._g_queue = sc.gauge("queue_depth")
        # host wall time histograms.  jax dispatch is async, so the admit/
        # decode dispatch times measure enqueue overhead, not device
        # compute — block_until_ready on the backend's cache around
        # refill()/step() to measure true device latency
        # (benchmarks/bench_serving.py does).  The old conflated
        # ``admit_time`` accumulator is split three ways:
        #   admit.begin_slot_s        pool page claim / slot reset
        #   admit.prefill_dispatch_s  bucketed chunk-prefill dispatch
        #   admit.inflight_wait_s     BLOCKED time on unresolved transport
        #                             handles (0 when hops fully hid)
        self._h_begin_slot = sc.histogram("admit.begin_slot_s")
        self._h_prefill_dispatch = sc.histogram("admit.prefill_dispatch_s")
        self._h_decode_dispatch = sc.histogram("decode.dispatch_s")
        self._h_inflight_wait = sc.histogram("admit.inflight_wait_s")
        # the legacy ad-hoc stats dict survives as a read-only view over
        # the registry (same keys, same totals — ``admit_time`` is now the
        # sum of its two split histograms)
        self.stats = StatsView({
            "admitted": lambda: self._c_admitted.value,
            "admit_failures": lambda: self._c_admit_failures.value,
            "forced_completions": lambda: self._c_forced.value,
            "chunk_calls": lambda: self._c_chunk_calls.value,
            "chunk_tokens": lambda: self._c_chunk_tokens.value,
            "shared_tokens": lambda: self._c_shared_tokens.value,
            "decode_tokens": lambda: self._c_decode_tokens.value,
            "admit_time": lambda: (
                self._h_begin_slot.sum + self._h_prefill_dispatch.sum
            ),
            "decode_time": lambda: self._h_decode_dispatch.sum,
            "inflight_admitted": lambda: self._c_inflight_admitted.value,
            "inflight_wait": lambda: self._h_inflight_wait.sum,
            "spec_drafts": lambda: self._c_spec_drafts.value,
            "spec_draft_tokens": lambda: self._c_spec_draft_tokens.value,
            "spec_accepted_tokens": lambda: self._c_spec_accepted.value,
        })

    # -- admission ---------------------------------------------------------
    def _check_request(self, r: Request) -> Request:
        """The admission invariant, shared by BOTH entry paths (direct
        ``submit`` and in-flight ``poll_inflight`` finalizers): the prompt
        must fit the slot, 1 <= len(tokens) < max_seq."""
        assert len(r.tokens) >= 1, f"request {r.rid}: empty prompt"
        assert len(r.tokens) < self.max_seq, (
            f"request {r.rid}: prompt length {len(r.tokens)} does not fit "
            f"max_seq={self.max_seq}"
        )
        return r

    def submit(self, requests: Sequence[Request]):
        """Enqueue ready requests (payload already local — work arriving
        over a transport link enters via ``submit_inflight`` instead).
        Prompts must fit the slot: 1 <= len(tokens) < max_seq."""
        for r in requests:
            self.queue.append(self._check_request(r))
            if self._tr.enabled:
                self._tr.begin(r.rid, "queue_wait", stream=self.name)
        self._g_queue.set(len(self.queue))

    def submit_inflight(self, handle, finalize):
        """Enqueue work whose payload is still crossing a transport link.

        ``handle`` is a ``serve.transport.SendHandle``; ``finalize`` maps
        the delivered payload tree to the ``Request`` to admit (the caller
        owns the payload→request convention — e.g. the cascade re-queue
        rebuilds ``r.tokens`` from the delivered prompt).  The pair joins
        ``self.inflight`` and is drained FIFO at the admission points; the
        stream stays ``active`` (but not ``runnable``) while anything is in
        flight, so drivers never exit with payloads on the wire."""
        self.inflight.append((handle, finalize))

    def poll_inflight(self, *, block: bool = False) -> int:
        """Drain resolved in-flight sends (FIFO, stopping at the first
        unresolved handle so admission order matches a blocking transport)
        into ``self.queue``.  With ``block=True`` and nothing resolved,
        waits on the OLDEST handle — drivers only do this when no stream
        has runnable work left (the all-idle fallback), so blocking here
        never hides compute the loop could be doing.  Returns the number of
        requests that landed."""
        landed = 0
        while self.inflight and (
            self.inflight[0][0].done() or (block and landed == 0)
        ):
            handle, finalize = self.inflight.popleft()
            r = self._check_request(finalize(handle.result()))
            self.queue.append(r)
            self._h_inflight_wait.record(handle.wait_time)
            self._c_inflight_admitted.add(1)
            if self._tr.enabled:
                self._tr.begin(r.rid, "queue_wait", stream=self.name)
            landed += 1
        if landed:
            self._g_queue.set(len(self.queue))
        return landed

    def set_slot_limit(self, k: int) -> None:
        """Cap how many slots may hold occupants (clamped to
        ``[1, n_slots]``) — the controller's slot-count actuation.  A
        lowered limit takes effect as occupied slots free up; raising it
        re-opens admission immediately on the next ``refill``."""
        self.slot_limit = max(1, min(int(k), self.n_slots))

    def _release(self, s: int):
        """Hand the slot's memory back to the backend (paged pools decref
        their pages; dense backends have nothing to return)."""
        release = getattr(self.backend, "release_slot", None)
        if release is not None:
            release(s)
        self.slot_req[s] = None
        self.slot_emitted[s] = []

    def _admit(self, s: int):
        if not self.queue or s >= self.slot_limit:
            self.slot_req[s] = None
            return
        r = self.queue[0]  # peek: admission may be refused by the pool
        t0 = self._clock()
        begin = getattr(self.backend, "begin_slot", None)
        if begin is not None:
            # prefix pages are only shareable under chunked prefill (the
            # owner writes them in full before any sharer can be admitted)
            shared = begin(s, r.tokens, share=self.chunked)
            if shared is None:
                # pool exhausted: the request stays at the queue head and
                # the slot stays free; completions will release pages
                self._h_begin_slot.record(self._clock() - t0)
                self._c_admit_failures.add(1)
                self.slot_req[s] = None
                if not any(q is not None for q in self.slot_req):
                    raise RuntimeError(
                        f"request {r.rid}: prompt needs more pages than the "
                        "pool holds even with every slot free"
                    )
                return
        else:
            self.backend.reset_slot(s)
            shared = 0
        t1 = self._clock()
        self._h_begin_slot.record(t1 - t0)
        self.queue.popleft()
        self._g_queue.set(len(self.queue))
        tr = self._tr
        if tr.enabled:
            tr.end(r.rid, "queue_wait")
            tr.begin(
                r.rid, "admit", stream=self.name, slot=s,
                prompt_tokens=len(r.tokens), shared_tokens=shared,
            )
        consumed = 0
        if self.chunked and len(r.tokens) > 1:
            # consume prompt[:-1] in bucketed pow2 chunks; the last prompt
            # token rides the decode program (see module docstring).  A
            # shared-prefix span is already resident in the pool — chunks
            # start at its end (absolute positions, so the chunk split
            # never changes what any token computes)
            m = len(r.tokens) - 1
            chunks = prompt_chunks(m - shared, self.max_chunk)
            off = shared
            for c in chunks:
                if tr.enabled:
                    tr.begin(r.rid, "prefill_chunk", tokens=c, start=off)
                self.backend.prefill_chunk(r.tokens[off : off + c], s, off)
                if tr.enabled:
                    tr.end(r.rid, "prefill_chunk")
                off += c
            consumed = off
            self._c_chunk_calls.add(len(chunks))
            self._c_chunk_tokens.add(m - shared)
            self._c_shared_tokens.add(shared)
            self._h_prefill_dispatch.record(self._clock() - t1)
        # speculative verify (serve/speculative.py): a deferral arriving
        # with the previous tier's agreeing generation scores every draft
        # position in one chunked pass INSTEAD of the last-prompt-token
        # decode feed — it runs where the chunk loop left off (consumed ==
        # P-1 under chunked admission), and only on backends whose cache
        # can roll rejected rows back (attention families)
        plan = None
        if r.draft is not None:
            draft, r.draft = r.draft, None  # consumed at THIS admission
            if self.chunked and getattr(
                self.backend, "supports_draft_verify", False
            ):
                plan = plan_draft(
                    r.tokens, draft, r.max_new_tokens, self.max_seq
                )
        verified = None
        if plan is not None:
            P = len(r.tokens)
            T_use = len(plan.draft)
            ext = getattr(self.backend, "extend_slot", None)
            # paged: map private pages for the draft rows up front; a
            # refusal (pool pressure) falls back to plain admission
            if ext is None or ext(s, P + T_use):
                if tr.enabled:
                    tr.begin(r.rid, "verify_draft", draft_tokens=T_use)
                choices = self.backend.verify_draft(
                    plan.tokens, s, plan.start, self.max_chunk
                )  # (E, T_use + 1) host choices
                n_acc = accepted_prefix(choices, plan.draft)
                rb = getattr(self.backend, "rollback_slot", None)
                if rb is not None:
                    # unmap pages wholly past the accepted span (dense
                    # backends: the pos mask already hides rejected rows)
                    rb(s, P + n_acc)
                if tr.enabled:
                    tr.end(r.rid, "verify_draft", accepted=n_acc)
                self._c_spec_drafts.add(1)
                self._c_spec_draft_tokens.add(T_use)
                self._c_spec_accepted.add(n_acc)
                if self.on_draft_verified is not None:
                    self.on_draft_verified(r, n_acc, T_use)
                verified = (plan, choices, n_acc)
        self.slot_req[s] = r
        if verified is not None:
            plan, choices, n_acc = verified
            E = self.backend.E
            # accepted draft tokens are each member's own emission (their
            # choices matched the draft there); position n_acc emits each
            # member's OWN choice — together n_acc + 1 decode steps' worth
            # of output from one pass
            emitted = [
                np.full((E,), d, np.int32) for d in plan.draft[:n_acc]
            ]
            emitted.append(choices[:, n_acc].astype(np.int32).copy())
            self.slot_consumed[s] = len(r.tokens)
            self.slot_emitted[s] = emitted
            self.pos[s] = len(r.tokens) + n_acc
            self.tok[:, s, 0] = choices[:, n_acc]
        else:
            self.slot_consumed[s] = consumed + 1
            self.slot_emitted[s] = []
            self.pos[s] = consumed
            self.tok[:, s, 0] = r.tokens[consumed]
        self._c_admitted.add(1)
        if tr.enabled:
            tr.end(r.rid, "admit")
            tr.begin(r.rid, "decode", stream=self.name, slot=s)
        if verified is not None:
            # the verify pass may already satisfy the budget / hit the
            # wall: complete NOW (the slot never decodes) and hand the
            # result back through ``step()``'s _admit_done drain
            full = len(self.slot_emitted[s]) >= r.max_new_tokens
            wall = self.pos[s] >= self.max_seq - 1
            if full or wall:
                r.truncated = not full
                gen = np.stack(self.slot_emitted[s], axis=1)
                if tr.enabled:
                    tr.end(
                        r.rid, "decode",
                        new_tokens=gen.shape[1], truncated=r.truncated,
                    )
                self._admit_done.append((r, gen))
                self._release(s)
                self._admit(s)

    def refill(self):
        """Admit queued requests into every free slot.  This is the
        non-blocking admission point: resolved in-flight sends land first
        (a poll — decode never waits on the link here), then free slots
        admit from the queue."""
        if self.inflight:
            self.poll_inflight(block=False)
        for s in range(self.n_slots):
            if self.slot_req[s] is None and self.queue:
                self._admit(s)

    @property
    def runnable(self) -> bool:
        """True when the stream can make device progress RIGHT NOW: a slot
        is occupied, a ready request is queued, or an admission-time
        completion is waiting to be handed back.  In-flight sends do not
        count — a stream with only in-flight work has nothing to decode
        until a handle resolves (see ``active``)."""
        return (
            any(r is not None for r in self.slot_req)
            or bool(self.queue)
            or bool(self._admit_done)
        )

    @property
    def active(self) -> bool:
        """True while the stream still owes work: runnable, or a payload is
        in flight on a transport link (drivers must not exit on in-flight
        work — its requests have not completed anywhere yet)."""
        return self.runnable or bool(self.inflight)

    # -- stepping ----------------------------------------------------------
    def step(self) -> List[Tuple[Request, np.ndarray]]:
        """Advance every active slot by one token; returns the list of
        (request, member generations (E, T)) that completed this step.
        Freed slots immediately admit from ``self.queue``."""
        self.refill()
        # admission-time completions (fully-accepted drafts) exit first —
        # they were finished by the verify pass and own no slot
        completed = self._admit_done
        self._admit_done = []
        n_active = sum(r is not None for r in self.slot_req)
        if n_active == 0:
            return completed
        prepare = getattr(self.backend, "prepare_step", None)
        if prepare is not None:
            # paged pools: map every active slot's next write position
            # (grow-by-page / COW).  Slots the pool cannot serve force-
            # complete with what they have — the paged cache wall
            active = [s for s, r in enumerate(self.slot_req) if r is not None]
            for s in prepare(self.pos, active):
                r = self.slot_req[s]
                r.truncated = True
                gen = (
                    np.stack(self.slot_emitted[s], axis=1)
                    if self.slot_emitted[s]
                    else np.zeros((self.backend.E, 0), np.int32)
                )
                completed.append((r, gen))
                self._c_forced.add(1)
                if self._tr.enabled:
                    self._tr.end(r.rid, "decode", new_tokens=gen.shape[1])
                    self._tr.instant(r.rid, "forced_complete", slot=s)
                self._release(s)
                self._admit(s)
            n_active = sum(r is not None for r in self.slot_req)
            if n_active == 0:
                return completed
        t0 = self._clock()
        nxt = self.backend.decode(self.tok, self.pos)  # (E, n_slots)
        self._h_decode_dispatch.record(self._clock() - t0)
        self._c_decode_tokens.add(n_active)
        self.steps += 1
        for s, r in enumerate(self.slot_req):
            if r is None:
                continue
            self.pos[s] += 1
            if self.slot_consumed[s] < len(r.tokens):
                # prompt-feed: still consuming the prompt through decode
                self.tok[:, s, 0] = r.tokens[self.slot_consumed[s]]
                self.slot_consumed[s] += 1
            else:
                self.slot_emitted[s].append(nxt[:, s].copy())
                self.tok[:, s, 0] = nxt[:, s]
                full = len(self.slot_emitted[s]) >= r.max_new_tokens
                wall = self.pos[s] >= self.max_seq - 1  # out of cache rows
                if full or wall:
                    r.truncated = not full
                    gen = (
                        np.stack(self.slot_emitted[s], axis=1)
                        if self.slot_emitted[s]
                        else np.zeros((self.backend.E, 0), np.int32)
                    )
                    completed.append((r, gen))
                    if self._tr.enabled:
                        self._tr.end(
                            r.rid, "decode",
                            new_tokens=gen.shape[1], truncated=r.truncated,
                        )
                    self._release(s)
                    self._admit(s)
        return completed

    def drain(self) -> List[Tuple[Request, np.ndarray]]:
        """Step until every queued and in-flight request has completed.
        When only in-flight work remains (nothing runnable), blocks on the
        oldest handle — the single-stream all-idle fallback."""
        done = []
        while self.active:
            if not self.runnable:
                self.poll_inflight(block=True)
            done.extend(self.step())
        return done


# ---------------------------------------------------------------------------
# backends
# ---------------------------------------------------------------------------


def _default_n_pages(n_slots: int, max_seq: int, page_size: int) -> int:
    """Dense-equivalent pool capacity plus the overflow sink: enough pages
    that no admission pattern the dense cache serves can ever fail."""
    return n_slots * (max_seq // page_size) + 1


class _PagedSlots:
    """The shared paged-backend half: host ``PagePool`` bookkeeping plus
    the begin/release/prepare hooks, parameterized over the device-side
    page-copy program (engine pools and E-stacked tier pools differ only
    in leading axes — ``api.copy_pool_page`` locates the page axis from
    the trailing layout)."""

    def _init_pool(self, n_slots, max_seq, page_size, n_pages,
                   obs=None, pool_name="paging"):
        from repro.serve.paging import PagePool

        if n_pages is None:
            n_pages = _default_n_pages(n_slots, max_seq, page_size)
        self.pool = PagePool(
            n_pages, page_size, n_slots=n_slots, max_seq=max_seq,
            obs=obs, name=pool_name,
        )

    def begin_slot(self, slot, tokens, *, share=True):
        """Claim pages for a new occupant (see ``PagePool.admit``); dense
        backends fall back to ``reset_slot`` + private rows."""
        if not self.paged:
            self.reset_slot(slot)
            return 0
        return self.pool.admit(slot, tokens, share=share)

    def release_slot(self, slot):
        if self.paged:
            self.pool.release(slot)

    def extend_slot(self, slot, n_rows):
        """Cover rows ``[0, n_rows)`` with pages before a speculative
        verify pass writes draft rows past the admission span (PRIVATE
        pages only — see ``PagePool.extend``).  Dense backends need
        nothing: their slot rows are dedicated.  Returns False when the
        pool cannot cover the span (caller falls back to plain
        admission)."""
        if not self.paged:
            return True
        return self.pool.extend(slot, n_rows)

    def rollback_slot(self, slot, keep_rows):
        """Speculative rollback: unmap pages wholly past rows
        ``[0, keep_rows)`` (``PagePool.truncate``).  Dense backends rely
        on the pos mask — rejected rows are invisible and the next decode
        overwrites its row before attending."""
        if self.paged:
            self.pool.truncate(slot, keep_rows)

    def prepare_step(self, pos, active):
        """Map each active slot's next write position; COW splits run the
        jitted page-copy program.  Returns slots the pool cannot serve."""
        if not self.paged:
            return []
        oom = []
        for s in active:
            # abclint: disable=ABC202(self.pos is host numpy maintained by the stream loop)
            ok, copies = self.pool.prepare(s, int(pos[s]))
            if not ok:
                oom.append(s)
                continue
            for src, dst in copies:
                self.pool_dev = self._copy_page(
                    self.pool_dev, jnp.int32(src), jnp.int32(dst)
                )
        return oom


class EngineBackend(_PagedSlots):
    """E=1 backend over a single model's compile-once programs.

    ``programs`` is the ``model_programs(cfg)`` namespace (decode /
    prefill_chunk / reset_slot); sampling stays on the host through
    ``sample`` (the engine's temperature + rng policy).  ``paged`` selects
    block-paged KV pools (default wherever the family supports them);
    ``paged=False`` keeps the dense slot cache as the parity oracle."""

    def __init__(self, cfg, params, programs, sample, *, n_slots, max_seq,
                 prefill_counter=None, paged=None, page_size: int = 16,
                 n_pages=None, obs=None, pool_name="paging"):
        assert not cfg.is_encoder
        self.cfg = cfg
        self.params = params
        self._decode = programs.decode
        self._chunk = getattr(programs, "prefill_chunk", None)
        self._reset = getattr(programs, "reset_slot", None)
        self._sample = sample
        # the owning engine's ``engine.prefill_tokens`` counter (legacy
        # engine.stats credit for chunked prefills); None outside an engine
        self._prefill_counter = prefill_counter
        self.E = 1
        self.paged = api.supports_paging(cfg) if paged is None else bool(paged)
        if self.paged:
            from repro.serve.engine import paged_model_programs

            self._init_pool(n_slots, max_seq, page_size, n_pages,
                            obs=obs, pool_name=pool_name)
            self.pool_dev, _ = unbox(
                api.init_paged_pool(cfg, self.pool.n_pages, page_size)
            )
            progs = paged_model_programs(cfg)
            self._decode_paged = progs.decode
            self._chunk_paged = progs.prefill_chunk
            self._copy_page = progs.copy_page
            self.cache = None
            self.supports_chunked_prefill = True
        else:
            # abclint: disable=ABC501(dense parity oracle: paged=False keeps the dense slot cache)
            self.cache, _ = unbox(api.init_cache(cfg, n_slots, max_seq))
            self.supports_chunked_prefill = self._chunk is not None

    def decode(self, tok, pos):
        """One decode step for every slot at its own ``pos``; returns the
        sampled next tokens (1, n_slots)."""
        if self.paged:
            logits, self.pool_dev = self._decode_paged(
                self.params, jnp.asarray(tok[0]), self.pool_dev,
                jnp.asarray(pos), jnp.asarray(self.pool.table),
            )
        else:
            logits, self.cache = self._decode(
                self.params, jnp.asarray(tok[0]), self.cache, jnp.asarray(pos)
            )
        return host_fetch(self._sample(logits))[None]  # (1, n_slots)

    def prefill_chunk(self, tokens, slot, start):
        """Write one pow2 prompt chunk into ``slot`` at offset ``start``."""
        if self.paged:
            self.pool_dev = self._chunk_paged(
                self.params, jnp.asarray(tokens), self.pool_dev,
                jnp.asarray(self.pool.table[slot]), jnp.int32(start),
            )
        else:
            self.cache = self._chunk(
                self.params, jnp.asarray(tokens), self.cache,
                jnp.int32(slot), jnp.int32(start),
            )
        if self._prefill_counter is not None:
            self._prefill_counter.add(len(tokens))

    def reset_slot(self, slot):
        """Zero the slot's constant-state leaves (no-op for pos-masked
        families — stale KV rows are invisible past the slot's pos)."""
        if self._reset is not None:
            self.cache = self._reset(self.cache, jnp.int32(slot))


class TierBackend(_PagedSlots):
    """E=k backend over a cascade tier's stacked-ensemble programs (one
    vmapped XLA program advances every member; sampling lives inside the
    programs).

    Sampling determinism: every slot owns an rng key ``fold_in(base,
    admit_seq)`` assigned at admission (admission order is FIFO and
    transport-timing-invariant), and each sampled token uses
    ``fold_in(fold_in(slot_key, pos), e)`` — a slot's sampled trajectory
    depends only on its own occupant and history, never on which OTHER
    slots happen to share its decode dispatches.  Temperature>0 voting is
    therefore bitwise identical under serial, blocking, or overlapped
    transport (the old shared rng thread made it interleaving-dependent).

    Paged tiers stack E pool planes but keep ONE page table: members score
    the same tokens at the same positions, so every shared prefix page is
    an E-fold HBM saving (the ABC-specific win — see DESIGN.md §10)."""

    def __init__(self, tier, *, n_slots, max_seq, seed: int = 0,
                 paged=None, page_size: int = 16, n_pages=None,
                 obs=None, pool_name="paging"):
        assert not tier.cfg.is_encoder
        self.tier = tier
        self.E = tier.k
        self._base_key = jax.random.PRNGKey(seed)
        self._admit_seq = 0
        self.slot_keys = jnp.tile(self._base_key[None], (n_slots, 1))
        self.paged = (
            api.supports_paging(tier.cfg) if paged is None else bool(paged)
        )
        if self.paged:
            from repro.serve.cascade_server import tier_paged_programs

            self._init_pool(n_slots, max_seq, page_size, n_pages,
                            obs=obs, pool_name=pool_name)
            pool0, _ = unbox(
                api.init_paged_pool(tier.cfg, self.pool.n_pages, page_size)
            )
            # E pool planes, ONE table: HBM scales with pages, not seqs
            self.pool_dev = jax.tree.map(
                # abclint: disable=ABC502(page-bounded pool planes scale with mapped pages, not sequence length)
                lambda v: jnp.zeros((self.E,) + v.shape, v.dtype), pool0
            )
            progs = tier_paged_programs(tier.cfg, float(tier.temperature))
            self._decode_paged = progs.decode_slots
            self._chunk_paged = progs.prefill_chunk
            self._verify_paged = progs.verify_chunk
            self._copy_page = progs.copy_page
            self.caches = None
            self.supports_chunked_prefill = True
            # paged families are attention families: always verifiable
            self.supports_draft_verify = True
        else:
            # abclint: disable=ABC501(dense parity oracle: paged=False keeps the dense slot cache)
            values0, _ = unbox(api.init_cache(tier.cfg, n_slots, max_seq))
            self.caches = jax.tree.map(
                # abclint: disable=ABC502(dense parity oracle: paged=False keeps the E-stacked dense cache)
                lambda v: jnp.zeros((self.E,) + v.shape, v.dtype), values0
            )
            self.supports_chunked_prefill = (
                getattr(tier, "_prefill_chunk", None) is not None
            )
            self.supports_draft_verify = (
                getattr(tier, "_verify_chunk", None) is not None
            )

    def begin_slot(self, slot, tokens, *, share=True):
        """Assign the slot's admission rng key, then claim its memory."""
        shared = super().begin_slot(slot, tokens, share=share)
        if shared is None:
            return None  # pool refusal: the occupant (and its key) stays out
        self._admit_seq += 1
        self.slot_keys = self.slot_keys.at[slot].set(
            jax.random.fold_in(self._base_key, self._admit_seq)
        )
        return shared

    def decode(self, tok, pos):
        """One vmapped decode step for every member x slot; returns the
        sampled next tokens (E, n_slots)."""
        if self.paged:
            t, self.pool_dev = self._decode_paged(
                self.tier.values, jnp.asarray(tok), self.pool_dev,
                jnp.asarray(pos), jnp.asarray(self.pool.table),
                self.slot_keys,
            )
        else:
            t, self.caches = self.tier._decode_slots(
                self.tier.values, jnp.asarray(tok), self.caches,
                jnp.asarray(pos), self.slot_keys,
            )
        return host_fetch(t)[..., 0]  # (E, n_slots)

    def prefill_chunk(self, tokens, slot, start):
        """Write one pow2 prompt chunk into every member's ``slot``."""
        if self.paged:
            self.pool_dev = self._chunk_paged(
                self.tier.values, self.pool_dev, jnp.asarray(tokens),
                jnp.asarray(self.pool.table[slot]), jnp.int32(start),
            )
        else:
            self.caches = self.tier._prefill_chunk(
                self.tier.values, self.caches, jnp.asarray(tokens),
                jnp.int32(slot), jnp.int32(start),
            )

    def verify_draft(self, tokens, slot, start, max_chunk):
        """Score the verify chunk ``[prompt[-1], d_0..d_{T-1}]`` at
        absolute positions ``[start, start + len(tokens))`` and return
        every member's decode-equivalent choices, (E, len(tokens)) host
        int32.  Runs in the SAME pow2 buckets as chunked prefill
        (``prompt_chunks``), so no new program shapes trace per request;
        choices stay on device across chunks and come back in ONE metered
        fetch."""
        key = self.slot_keys[slot]
        outs = []
        off = 0
        for c in prompt_chunks(len(tokens), max_chunk):
            chunk = jnp.asarray(tokens[off : off + c])
            if self.paged:
                t, self.pool_dev = self._verify_paged(
                    self.tier.values, self.pool_dev, chunk,
                    jnp.asarray(self.pool.table[slot]),
                    jnp.int32(start + off), key,
                )
            else:
                t, self.caches = self.tier._verify_chunk(
                    self.tier.values, self.caches, chunk,
                    jnp.int32(slot), jnp.int32(start + off), key,
                )
            outs.append(t)
            off += c
        return np.concatenate(host_fetch(tuple(outs)), axis=1)

    def reset_slot(self, slot):
        """Zero the slot's constant-state leaves across all members."""
        if getattr(self.tier, "_reset_slot", None) is not None:
            self.caches = self.tier._reset_slot(self.caches, jnp.int32(slot))
