"""SlotStream: the single slot state machine behind all continuous batching.

One ``SlotStream`` owns the admit / refill / prompt-feed / force-complete
lifecycle of ``n_slots`` decode slots over stacked ``(E, n_slots, ...)``
caches; the single-model engine is just the E=1 case and a cascade tier the
E=k case, so ``ServingEngine.serve_continuous`` and
``CascadeServer.serve_continuous`` are both thin drivers over this module.

Slot-isolation contract (why mid-stream reuse is safe):

* prompts are left-aligned at position 0 of their slot; every slot advances
  at its OWN ``pos`` (the decode program takes a per-slot (B,) position
  vector).  Attention reads cache rows ``< pos+1`` only, so stale KV rows
  written by a slot's previous occupant are invisible — that is the
  pos-masking contract shared with ``attention_decode`` and
  ``attention_prefill_chunk``.
* constant-state families (SSM/RWKV, hybrid's mamba leaves) have no pos
  mask, so admission zeroes the slot's state leaves through the backend's
  jitted ``reset_slot`` program — this is what lifts the old
  attention-families-only restriction on cascade continuous batching.

Chunked-prefill admission: on admit, ``prompt[:-1]`` is consumed in exact
power-of-two chunks (``core.cascade.prompt_chunks``) through a per-bucket
jitted prefill-into-slot program (``models.api.prefill_into_slot``) that
writes KV rows / advances state at the slot's offset — a 400-token prompt
costs a handful of chunk calls instead of ~400 decode steps.  The final
prompt token always goes through the shared decode program (its logits
sample the first output token), which keeps chunked and decode-only
admission token-for-token identical.  Chunk shapes come from the O(log S)
bucket set, so trace counters stay flat across requests after warmup.

In-flight admission (the overlap half of DESIGN.md §8): work whose payload
is still crossing a ``Transport`` link enters through ``submit_inflight``
as a (``SendHandle``, finalize) pair instead of a ready ``Request``.  The
stream's ONLY legal drain points are its admission points — the top of
``refill()`` (polls, never blocks: decode keeps running while hops are in
flight) and ``drain()``/the driver's all-idle fallback (blocks on the
oldest handle only when no stream has runnable work, so waiting can never
starve compute).  Handles resolve strictly in submission (FIFO) order,
which keeps the admission order — and therefore the whole stream — equal
to what a blocking transport would produce.

Device work goes through a small backend protocol (duck-typed):

    E                        int, ensemble width
    supports_chunked_prefill bool
    decode(tok (E, n_slots, 1), pos (n_slots,)) -> next (E, n_slots)
    prefill_chunk(tokens (C,), slot, start)     -> None   (updates cache)
    reset_slot(slot)                            -> None   (zero state leaves)

plus three OPTIONAL hooks for backends whose slot memory is allocated
rather than dedicated (the block-paged KV pools — serve/paging.py):

    begin_slot(slot, tokens, share) -> Optional[int]
        claim slot memory for a prompt before any prefill; returns the
        number of leading prompt tokens already covered by shared prefix
        pages (0 for dense), or None when the pool cannot admit — the
        request stays queued and the slot stays free.  Subsumes
        ``reset_slot``.  ``share`` is the stream's chunked flag: prefix
        pages may only be published when chunked prefill writes them in
        full before any sharer can be admitted.
    release_slot(slot) -> None
        return the slot's memory (decref pages) on completion.
    prepare_step(pos, active) -> [slot, ...]
        make each active slot's next write position mapped (grow-by-page,
        copy-on-write); returns the slots the pool could NOT serve — the
        stream force-completes those with ``truncated=True`` (the paged
        analogue of the dense cache wall).

``EngineBackend`` (E=1, host-side sampling via the engine's rng) and
``TierBackend`` (ensemble programs with in-program sampling) are provided
here; both reuse the module-level compile-once program caches, and both
default to block-paged pools where ``api.supports_paging`` allows, keeping
the dense slot cache available behind ``paged=False`` as the bitwise
parity oracle.
"""
from __future__ import annotations

import time
from collections import deque
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cascade import host_fetch, prompt_chunks
from repro.models import api
from repro.models.params import unbox
from repro.serve.batching import Request


class SlotStream:
    """Slot-based continuous batching over a device backend."""

    def __init__(
        self,
        backend,
        *,
        n_slots: int = 8,
        max_seq: int = 256,
        chunked_prefill: bool = True,
        max_chunk: int = 256,
    ):
        self.backend = backend
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.max_chunk = max_chunk
        self.chunked = bool(chunked_prefill) and backend.supports_chunked_prefill
        E = backend.E
        self.queue: deque = deque()
        # (SendHandle, finalize) pairs whose payload is still in flight on a
        # transport link; drained FIFO at the admission points (see module
        # docstring — this is where compute/communication overlap happens)
        self.inflight: deque = deque()
        self.slot_req: List[Optional[Request]] = [None] * n_slots
        self.slot_consumed = np.zeros(n_slots, np.int64)  # prompt tokens fed
        self.slot_emitted: List[List[np.ndarray]] = [[] for _ in range(n_slots)]
        self.pos = np.zeros(n_slots, np.int32)
        self.tok = np.zeros((E, n_slots, 1), np.int32)
        self.steps = 0
        self.stats = {
            "admitted": 0,
            "admit_failures": 0,  # begin_slot refusals (pool exhausted)
            "forced_completions": 0,  # slots cut short by pool exhaustion
            "chunk_calls": 0,
            "chunk_tokens": 0,
            "shared_tokens": 0,  # prompt tokens served from shared pages
            "decode_tokens": 0,  # active slot-steps through the decode program
            # host wall time inside admission / decode dispatch.  jax
            # dispatch is async, so these measure enqueue overhead, not
            # device compute — block_until_ready on the backend's cache
            # around refill()/step() to measure true device latency
            # (benchmarks/bench_serving.py does).
            "admit_time": 0.0,
            "decode_time": 0.0,
            # in-flight admissions that arrived over a transport link, and
            # how long the stream actually BLOCKED on unresolved handles
            # (0.0 when every hop was fully hidden behind decode work)
            "inflight_admitted": 0,
            "inflight_wait": 0.0,
        }

    # -- admission ---------------------------------------------------------
    def _check_request(self, r: Request) -> Request:
        """The admission invariant, shared by BOTH entry paths (direct
        ``submit`` and in-flight ``poll_inflight`` finalizers): the prompt
        must fit the slot, 1 <= len(tokens) < max_seq."""
        assert len(r.tokens) >= 1, f"request {r.rid}: empty prompt"
        assert len(r.tokens) < self.max_seq, (
            f"request {r.rid}: prompt length {len(r.tokens)} does not fit "
            f"max_seq={self.max_seq}"
        )
        return r

    def submit(self, requests: Sequence[Request]):
        """Enqueue ready requests (payload already local — work arriving
        over a transport link enters via ``submit_inflight`` instead).
        Prompts must fit the slot: 1 <= len(tokens) < max_seq."""
        for r in requests:
            self.queue.append(self._check_request(r))

    def submit_inflight(self, handle, finalize):
        """Enqueue work whose payload is still crossing a transport link.

        ``handle`` is a ``serve.transport.SendHandle``; ``finalize`` maps
        the delivered payload tree to the ``Request`` to admit (the caller
        owns the payload→request convention — e.g. the cascade re-queue
        rebuilds ``r.tokens`` from the delivered prompt).  The pair joins
        ``self.inflight`` and is drained FIFO at the admission points; the
        stream stays ``active`` (but not ``runnable``) while anything is in
        flight, so drivers never exit with payloads on the wire."""
        self.inflight.append((handle, finalize))

    def poll_inflight(self, *, block: bool = False) -> int:
        """Drain resolved in-flight sends (FIFO, stopping at the first
        unresolved handle so admission order matches a blocking transport)
        into ``self.queue``.  With ``block=True`` and nothing resolved,
        waits on the OLDEST handle — drivers only do this when no stream
        has runnable work left (the all-idle fallback), so blocking here
        never hides compute the loop could be doing.  Returns the number of
        requests that landed."""
        landed = 0
        while self.inflight and (
            self.inflight[0][0].done() or (block and landed == 0)
        ):
            handle, finalize = self.inflight.popleft()
            self.queue.append(self._check_request(finalize(handle.result())))
            self.stats["inflight_wait"] += handle.wait_time
            self.stats["inflight_admitted"] += 1
            landed += 1
        return landed

    def _release(self, s: int):
        """Hand the slot's memory back to the backend (paged pools decref
        their pages; dense backends have nothing to return)."""
        release = getattr(self.backend, "release_slot", None)
        if release is not None:
            release(s)
        self.slot_req[s] = None
        self.slot_emitted[s] = []

    def _admit(self, s: int):
        if not self.queue:
            self.slot_req[s] = None
            return
        r = self.queue[0]  # peek: admission may be refused by the pool
        t0 = time.perf_counter()
        begin = getattr(self.backend, "begin_slot", None)
        if begin is not None:
            # prefix pages are only shareable under chunked prefill (the
            # owner writes them in full before any sharer can be admitted)
            shared = begin(s, r.tokens, share=self.chunked)
            if shared is None:
                # pool exhausted: the request stays at the queue head and
                # the slot stays free; completions will release pages
                self.stats["admit_failures"] += 1
                self.slot_req[s] = None
                if not any(q is not None for q in self.slot_req):
                    raise RuntimeError(
                        f"request {r.rid}: prompt needs more pages than the "
                        "pool holds even with every slot free"
                    )
                return
        else:
            self.backend.reset_slot(s)
            shared = 0
        self.queue.popleft()
        consumed = 0
        if self.chunked and len(r.tokens) > 1:
            # consume prompt[:-1] in bucketed pow2 chunks; the last prompt
            # token rides the decode program (see module docstring).  A
            # shared-prefix span is already resident in the pool — chunks
            # start at its end (absolute positions, so the chunk split
            # never changes what any token computes)
            m = len(r.tokens) - 1
            chunks = prompt_chunks(m - shared, self.max_chunk)
            off = shared
            for c in chunks:
                self.backend.prefill_chunk(r.tokens[off : off + c], s, off)
                off += c
            consumed = off
            self.stats["chunk_calls"] += len(chunks)
            self.stats["chunk_tokens"] += m - shared
            self.stats["shared_tokens"] += shared
        self.slot_req[s] = r
        self.slot_consumed[s] = consumed + 1
        self.slot_emitted[s] = []
        self.pos[s] = consumed
        self.tok[:, s, 0] = r.tokens[consumed]
        self.stats["admitted"] += 1
        self.stats["admit_time"] += time.perf_counter() - t0

    def refill(self):
        """Admit queued requests into every free slot.  This is the
        non-blocking admission point: resolved in-flight sends land first
        (a poll — decode never waits on the link here), then free slots
        admit from the queue."""
        if self.inflight:
            self.poll_inflight(block=False)
        for s in range(self.n_slots):
            if self.slot_req[s] is None and self.queue:
                self._admit(s)

    @property
    def runnable(self) -> bool:
        """True when the stream can make device progress RIGHT NOW: a slot
        is occupied or a ready request is queued.  In-flight sends do not
        count — a stream with only in-flight work has nothing to decode
        until a handle resolves (see ``active``)."""
        return any(r is not None for r in self.slot_req) or bool(self.queue)

    @property
    def active(self) -> bool:
        """True while the stream still owes work: runnable, or a payload is
        in flight on a transport link (drivers must not exit on in-flight
        work — its requests have not completed anywhere yet)."""
        return self.runnable or bool(self.inflight)

    # -- stepping ----------------------------------------------------------
    def step(self) -> List[Tuple[Request, np.ndarray]]:
        """Advance every active slot by one token; returns the list of
        (request, member generations (E, T)) that completed this step.
        Freed slots immediately admit from ``self.queue``."""
        self.refill()
        n_active = sum(r is not None for r in self.slot_req)
        if n_active == 0:
            return []
        completed = []
        prepare = getattr(self.backend, "prepare_step", None)
        if prepare is not None:
            # paged pools: map every active slot's next write position
            # (grow-by-page / COW).  Slots the pool cannot serve force-
            # complete with what they have — the paged cache wall
            active = [s for s, r in enumerate(self.slot_req) if r is not None]
            for s in prepare(self.pos, active):
                r = self.slot_req[s]
                r.truncated = True
                gen = (
                    np.stack(self.slot_emitted[s], axis=1)
                    if self.slot_emitted[s]
                    else np.zeros((self.backend.E, 0), np.int32)
                )
                completed.append((r, gen))
                self.stats["forced_completions"] += 1
                self._release(s)
                self._admit(s)
            n_active = sum(r is not None for r in self.slot_req)
            if n_active == 0:
                return completed
        t0 = time.perf_counter()
        nxt = self.backend.decode(self.tok, self.pos)  # (E, n_slots)
        self.stats["decode_time"] += time.perf_counter() - t0
        self.stats["decode_tokens"] += n_active
        self.steps += 1
        for s, r in enumerate(self.slot_req):
            if r is None:
                continue
            self.pos[s] += 1
            if self.slot_consumed[s] < len(r.tokens):
                # prompt-feed: still consuming the prompt through decode
                self.tok[:, s, 0] = r.tokens[self.slot_consumed[s]]
                self.slot_consumed[s] += 1
            else:
                self.slot_emitted[s].append(nxt[:, s].copy())
                self.tok[:, s, 0] = nxt[:, s]
                full = len(self.slot_emitted[s]) >= r.max_new_tokens
                wall = self.pos[s] >= self.max_seq - 1  # out of cache rows
                if full or wall:
                    r.truncated = not full
                    gen = (
                        np.stack(self.slot_emitted[s], axis=1)
                        if self.slot_emitted[s]
                        else np.zeros((self.backend.E, 0), np.int32)
                    )
                    completed.append((r, gen))
                    self._release(s)
                    self._admit(s)
        return completed

    def drain(self) -> List[Tuple[Request, np.ndarray]]:
        """Step until every queued and in-flight request has completed.
        When only in-flight work remains (nothing runnable), blocks on the
        oldest handle — the single-stream all-idle fallback."""
        done = []
        while self.active:
            if not self.runnable:
                self.poll_inflight(block=True)
            done.extend(self.step())
        return done


# ---------------------------------------------------------------------------
# backends
# ---------------------------------------------------------------------------


def _default_n_pages(n_slots: int, max_seq: int, page_size: int) -> int:
    """Dense-equivalent pool capacity plus the overflow sink: enough pages
    that no admission pattern the dense cache serves can ever fail."""
    return n_slots * (max_seq // page_size) + 1


class _PagedSlots:
    """The shared paged-backend half: host ``PagePool`` bookkeeping plus
    the begin/release/prepare hooks, parameterized over the device-side
    page-copy program (engine pools and E-stacked tier pools differ only
    in leading axes — ``api.copy_pool_page`` locates the page axis from
    the trailing layout)."""

    def _init_pool(self, n_slots, max_seq, page_size, n_pages):
        from repro.serve.paging import PagePool

        if n_pages is None:
            n_pages = _default_n_pages(n_slots, max_seq, page_size)
        self.pool = PagePool(
            n_pages, page_size, n_slots=n_slots, max_seq=max_seq
        )

    def begin_slot(self, slot, tokens, *, share=True):
        """Claim pages for a new occupant (see ``PagePool.admit``); dense
        backends fall back to ``reset_slot`` + private rows."""
        if not self.paged:
            self.reset_slot(slot)
            return 0
        return self.pool.admit(slot, tokens, share=share)

    def release_slot(self, slot):
        if self.paged:
            self.pool.release(slot)

    def prepare_step(self, pos, active):
        """Map each active slot's next write position; COW splits run the
        jitted page-copy program.  Returns slots the pool cannot serve."""
        if not self.paged:
            return []
        oom = []
        for s in active:
            # abclint: disable=ABC202(self.pos is host numpy maintained by the stream loop)
            ok, copies = self.pool.prepare(s, int(pos[s]))
            if not ok:
                oom.append(s)
                continue
            for src, dst in copies:
                self.pool_dev = self._copy_page(
                    self.pool_dev, jnp.int32(src), jnp.int32(dst)
                )
        return oom


class EngineBackend(_PagedSlots):
    """E=1 backend over a single model's compile-once programs.

    ``programs`` is the ``model_programs(cfg)`` namespace (decode /
    prefill_chunk / reset_slot); sampling stays on the host through
    ``sample`` (the engine's temperature + rng policy).  ``paged`` selects
    block-paged KV pools (default wherever the family supports them);
    ``paged=False`` keeps the dense slot cache as the parity oracle."""

    def __init__(self, cfg, params, programs, sample, *, n_slots, max_seq,
                 stats=None, paged=None, page_size: int = 16, n_pages=None):
        assert not cfg.is_encoder
        self.cfg = cfg
        self.params = params
        self._decode = programs.decode
        self._chunk = getattr(programs, "prefill_chunk", None)
        self._reset = getattr(programs, "reset_slot", None)
        self._sample = sample
        self._stats = stats
        self.E = 1
        self.paged = api.supports_paging(cfg) if paged is None else bool(paged)
        if self.paged:
            from repro.serve.engine import paged_model_programs

            self._init_pool(n_slots, max_seq, page_size, n_pages)
            self.pool_dev, _ = unbox(
                api.init_paged_pool(cfg, self.pool.n_pages, page_size)
            )
            progs = paged_model_programs(cfg)
            self._decode_paged = progs.decode
            self._chunk_paged = progs.prefill_chunk
            self._copy_page = progs.copy_page
            self.cache = None
            self.supports_chunked_prefill = True
        else:
            # abclint: disable=ABC501(dense parity oracle: paged=False keeps the dense slot cache)
            self.cache, _ = unbox(api.init_cache(cfg, n_slots, max_seq))
            self.supports_chunked_prefill = self._chunk is not None

    def decode(self, tok, pos):
        """One decode step for every slot at its own ``pos``; returns the
        sampled next tokens (1, n_slots)."""
        if self.paged:
            logits, self.pool_dev = self._decode_paged(
                self.params, jnp.asarray(tok[0]), self.pool_dev,
                jnp.asarray(pos), jnp.asarray(self.pool.table),
            )
        else:
            logits, self.cache = self._decode(
                self.params, jnp.asarray(tok[0]), self.cache, jnp.asarray(pos)
            )
        return host_fetch(self._sample(logits))[None]  # (1, n_slots)

    def prefill_chunk(self, tokens, slot, start):
        """Write one pow2 prompt chunk into ``slot`` at offset ``start``."""
        if self.paged:
            self.pool_dev = self._chunk_paged(
                self.params, jnp.asarray(tokens), self.pool_dev,
                jnp.asarray(self.pool.table[slot]), jnp.int32(start),
            )
        else:
            self.cache = self._chunk(
                self.params, jnp.asarray(tokens), self.cache,
                jnp.int32(slot), jnp.int32(start),
            )
        if self._stats is not None:
            self._stats["prefill_tokens"] += len(tokens)

    def reset_slot(self, slot):
        """Zero the slot's constant-state leaves (no-op for pos-masked
        families — stale KV rows are invisible past the slot's pos)."""
        if self._reset is not None:
            self.cache = self._reset(self.cache, jnp.int32(slot))


class TierBackend(_PagedSlots):
    """E=k backend over a cascade tier's stacked-ensemble programs (one
    vmapped XLA program advances every member; sampling lives inside the
    programs).

    Sampling determinism: every slot owns an rng key ``fold_in(base,
    admit_seq)`` assigned at admission (admission order is FIFO and
    transport-timing-invariant), and each sampled token uses
    ``fold_in(fold_in(slot_key, pos), e)`` — a slot's sampled trajectory
    depends only on its own occupant and history, never on which OTHER
    slots happen to share its decode dispatches.  Temperature>0 voting is
    therefore bitwise identical under serial, blocking, or overlapped
    transport (the old shared rng thread made it interleaving-dependent).

    Paged tiers stack E pool planes but keep ONE page table: members score
    the same tokens at the same positions, so every shared prefix page is
    an E-fold HBM saving (the ABC-specific win — see DESIGN.md §10)."""

    def __init__(self, tier, *, n_slots, max_seq, seed: int = 0,
                 paged=None, page_size: int = 16, n_pages=None):
        assert not tier.cfg.is_encoder
        self.tier = tier
        self.E = tier.k
        self._base_key = jax.random.PRNGKey(seed)
        self._admit_seq = 0
        self.slot_keys = jnp.tile(self._base_key[None], (n_slots, 1))
        self.paged = (
            api.supports_paging(tier.cfg) if paged is None else bool(paged)
        )
        if self.paged:
            from repro.serve.cascade_server import tier_paged_programs

            self._init_pool(n_slots, max_seq, page_size, n_pages)
            pool0, _ = unbox(
                api.init_paged_pool(tier.cfg, self.pool.n_pages, page_size)
            )
            # E pool planes, ONE table: HBM scales with pages, not seqs
            self.pool_dev = jax.tree.map(
                # abclint: disable=ABC502(page-bounded pool planes scale with mapped pages, not sequence length)
                lambda v: jnp.zeros((self.E,) + v.shape, v.dtype), pool0
            )
            progs = tier_paged_programs(tier.cfg, float(tier.temperature))
            self._decode_paged = progs.decode_slots
            self._chunk_paged = progs.prefill_chunk
            self._copy_page = progs.copy_page
            self.caches = None
            self.supports_chunked_prefill = True
        else:
            # abclint: disable=ABC501(dense parity oracle: paged=False keeps the dense slot cache)
            values0, _ = unbox(api.init_cache(tier.cfg, n_slots, max_seq))
            self.caches = jax.tree.map(
                # abclint: disable=ABC502(dense parity oracle: paged=False keeps the E-stacked dense cache)
                lambda v: jnp.zeros((self.E,) + v.shape, v.dtype), values0
            )
            self.supports_chunked_prefill = (
                getattr(tier, "_prefill_chunk", None) is not None
            )

    def begin_slot(self, slot, tokens, *, share=True):
        """Assign the slot's admission rng key, then claim its memory."""
        shared = super().begin_slot(slot, tokens, share=share)
        if shared is None:
            return None  # pool refusal: the occupant (and its key) stays out
        self._admit_seq += 1
        self.slot_keys = self.slot_keys.at[slot].set(
            jax.random.fold_in(self._base_key, self._admit_seq)
        )
        return shared

    def decode(self, tok, pos):
        """One vmapped decode step for every member x slot; returns the
        sampled next tokens (E, n_slots)."""
        if self.paged:
            t, self.pool_dev = self._decode_paged(
                self.tier.values, jnp.asarray(tok), self.pool_dev,
                jnp.asarray(pos), jnp.asarray(self.pool.table),
                self.slot_keys,
            )
        else:
            t, self.caches = self.tier._decode_slots(
                self.tier.values, jnp.asarray(tok), self.caches,
                jnp.asarray(pos), self.slot_keys,
            )
        return host_fetch(t)[..., 0]  # (E, n_slots)

    def prefill_chunk(self, tokens, slot, start):
        """Write one pow2 prompt chunk into every member's ``slot``."""
        if self.paged:
            self.pool_dev = self._chunk_paged(
                self.tier.values, self.pool_dev, jnp.asarray(tokens),
                jnp.asarray(self.pool.table[slot]), jnp.int32(start),
            )
        else:
            self.caches = self.tier._prefill_chunk(
                self.tier.values, self.caches, jnp.asarray(tokens),
                jnp.int32(slot), jnp.int32(start),
            )

    def reset_slot(self, slot):
        """Zero the slot's constant-state leaves across all members."""
        if getattr(self.tier, "_reset_slot", None) is not None:
            self.caches = self.tier._reset_slot(self.caches, jnp.int32(slot))
