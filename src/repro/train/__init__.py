from repro.train.step import TrainState, make_train_step, init_train_state
from repro.train.loop import train_loop

__all__ = ["TrainState", "make_train_step", "init_train_state", "train_loop"]
