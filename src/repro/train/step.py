"""train_step: loss -> grads -> AdamW, as a single pjit-able function.

The same function lowers on 1 CPU device (smoke tests), on the 256-chip pod
and on the 512-chip two-pod mesh — sharding comes entirely from the logical
axis annotations + in/out shardings derived in launch/dryrun.py.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import api
from repro.optim.adamw import OptimConfig, adamw_init, adamw_update
from repro.optim.schedule import cosine_schedule


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class TrainState:
    params: dict
    opt: dict
    step: jax.Array

    def tree_flatten(self):
        return (self.params, self.opt, self.step), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def init_train_state(params, ocfg: OptimConfig) -> TrainState:
    return TrainState(params=params, opt=adamw_init(params, ocfg), step=jnp.zeros((), jnp.int32))


def make_train_step(
    cfg: ModelConfig,
    ocfg: OptimConfig,
    *,
    total_steps: int = 10_000,
    warmup_steps: int = 100,
    window_override: Optional[int] = None,
):
    def train_step(state: TrainState, batch):
        def lf(p):
            return api.loss_fn(p, batch, cfg, window_override=window_override)

        (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(state.params)
        lr_scale = cosine_schedule(state.step, total_steps, warmup_steps)
        new_params, new_opt, om = adamw_update(
            grads, state.opt, state.params, ocfg, lr_scale=lr_scale
        )
        metrics = dict(metrics, loss=loss, **om, step=state.step)
        return TrainState(new_params, new_opt, state.step + 1), metrics

    return train_step
