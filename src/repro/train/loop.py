"""Training loop: jitted step + metrics logging + periodic checkpoints."""
from __future__ import annotations

import functools
import time
from typing import Callable, Iterator, Optional

import jax
import numpy as np


@functools.lru_cache(maxsize=None)
def _jitted_step(train_step: Callable):
    """Compile-once cache: repeated ``train_loop`` calls over the same
    ``train_step`` callable reuse one jitted program instead of rebuilding
    a fresh jit wrapper (and its cache) per call."""
    return jax.jit(train_step)


def train_loop(
    train_step: Callable,
    state,
    data_iter: Iterator[dict],
    *,
    steps: int,
    log_every: int = 10,
    checkpoint_every: Optional[int] = None,
    checkpoint_fn: Optional[Callable] = None,
    log_fn=print,
):
    """Runs ``steps`` steps; returns (state, history)."""
    step_fn = _jitted_step(train_step)
    history = []
    t0 = time.time()
    for i in range(steps):
        batch = next(data_iter)
        state, metrics = step_fn(state, batch)
        if (i + 1) % log_every == 0 or i == 0:
            m = {k: float(np.asarray(v)) for k, v in metrics.items()}
            m["wall"] = time.time() - t0
            history.append(m)
            log_fn(
                f"step {i+1:5d}  loss={m['loss']:.4f}  ce={m.get('ce', 0):.4f}  "
                f"acc={m.get('acc', 0):.3f}  gnorm={m.get('grad_norm', 0):.2f}  "
                f"({m['wall']:.1f}s)"
            )
        if checkpoint_every and checkpoint_fn and (i + 1) % checkpoint_every == 0:
            checkpoint_fn(state, i + 1)
    return state, history
