"""Logical-axis sharding rules (MaxText-style).

Every parameter and hot activation in the model zoo is annotated with a tuple
of *logical* axis names (``('embed', 'mlp')``, ``('act_batch', 'act_seq',
'act_embed')``, ...).  A rule table maps each logical name to zero or more
*mesh* axes.  At lowering time we translate the logical tuple into a
``PartitionSpec``, dropping any mesh axis that does not divide the concrete
dimension (so the same model code lowers on a 1-device CPU for smoke tests
and on the 512-chip production mesh for the dry-run).

The active rule table is held in a context variable so model code can call
``constrain(x, axes)`` unconditionally; with no rules installed it is a
no-op.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Mapping, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

MeshAxes = Union[None, str, Tuple[str, ...]]
LogicalAxisRules = Mapping[str, MeshAxes]


class _RulesContext(threading.local):
    def __init__(self):
        self.rules: Optional[LogicalAxisRules] = None
        self.mesh: Optional[Mesh] = None


_CTX = _RulesContext()


@contextlib.contextmanager
def axis_rules(rules: Optional[LogicalAxisRules], mesh: Optional[Mesh] = None):
    """Install a logical->mesh rule table (and optionally the mesh) for the
    duration of the context.  Model code picks these up via ``constrain``."""
    prev_rules, prev_mesh = _CTX.rules, _CTX.mesh
    _CTX.rules, _CTX.mesh = rules, mesh
    try:
        yield
    finally:
        _CTX.rules, _CTX.mesh = prev_rules, prev_mesh


def current_rules() -> Optional[LogicalAxisRules]:
    return _CTX.rules


def current_mesh() -> Optional[Mesh]:
    return _CTX.mesh


def _as_tuple(spec: MeshAxes) -> Tuple[str, ...]:
    if spec is None:
        return ()
    if isinstance(spec, str):
        return (spec,)
    return tuple(spec)


def _mesh_axis_sizes(mesh: Mesh) -> Mapping[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def logical_to_pspec(
    axes: Sequence[Optional[str]],
    rules: LogicalAxisRules,
    *,
    shape: Optional[Sequence[int]] = None,
    mesh: Optional[Mesh] = None,
) -> PartitionSpec:
    """Translate a logical-axis tuple into a PartitionSpec.

    If ``shape`` and ``mesh`` are given, mesh axes whose combined size does
    not divide the concrete dimension are dropped (greedily, from the right)
    so the spec is always valid.  A mesh axis may appear at most once in the
    result; later logical dims lose conflicting axes.
    """
    sizes = _mesh_axis_sizes(mesh) if mesh is not None else {}
    used: set = set()
    entries = []
    for i, name in enumerate(axes):
        mesh_axes = [a for a in _as_tuple(rules.get(name)) if a not in used] if name else []
        if shape is not None and mesh is not None and mesh_axes:
            kept = []
            prod = 1
            for a in mesh_axes:
                if shape[i] % (prod * sizes[a]) == 0:
                    kept.append(a)
                    prod *= sizes[a]
            mesh_axes = kept
        used.update(mesh_axes)
        if not mesh_axes:
            entries.append(None)
        elif len(mesh_axes) == 1:
            entries.append(mesh_axes[0])
        else:
            entries.append(tuple(mesh_axes))
    return PartitionSpec(*entries)


def logical_sharding(
    mesh: Mesh,
    shape: Sequence[int],
    axes: Sequence[Optional[str]],
    rules: LogicalAxisRules,
) -> NamedSharding:
    return NamedSharding(mesh, logical_to_pspec(axes, rules, shape=shape, mesh=mesh))


def tree_pspecs(axes_tree, rules: LogicalAxisRules, shapes_tree=None, mesh: Optional[Mesh] = None):
    """Map a pytree of logical-axis tuples to a pytree of PartitionSpecs."""
    is_axes = lambda x: isinstance(x, tuple) and all(a is None or isinstance(a, str) for a in x)
    if shapes_tree is None:
        return jax.tree.map(
            lambda a: logical_to_pspec(a, rules), axes_tree, is_leaf=is_axes
        )
    return jax.tree.map(
        lambda a, s: logical_to_pspec(a, rules, shape=tuple(s.shape), mesh=mesh),
        axes_tree,
        shapes_tree,
        is_leaf=is_axes,
    )


def with_logical_constraint(x, axes: Sequence[Optional[str]]):
    """Apply a sharding constraint derived from the active rule table.

    No-op when no rules are installed (single-device smoke tests) or when the
    array rank does not match the annotation (defensive).
    """
    rules = _CTX.rules
    if rules is None:
        return x
    if len(axes) != x.ndim:
        return x
    mesh = _CTX.mesh
    pspec = logical_to_pspec(axes, rules, shape=x.shape, mesh=mesh)
    return jax.lax.with_sharding_constraint(x, pspec)


# Shorthand used throughout the model zoo.
constrain = with_logical_constraint


# ---------------------------------------------------------------------------
# Rule tables.  Mesh axes: ('pod',) 'data', 'model'.
# ---------------------------------------------------------------------------

def _base_rules(pod: bool) -> dict:
    data = ("pod", "data") if pod else ("data",)
    return {
        # -- weights ---------------------------------------------------
        "embed": None,          # overridden to FSDP axis for train
        "mlp": "model",
        "heads": "model",
        "kv_heads": "model",
        "head_dim": None,
        "qkv": None,
        "vocab": "model",
        "experts": "model",
        # fallback: when n_experts doesn't divide the model axis (mixtral's
        # 8 on a 16-wide axis) the experts dim drops and the expert FFN dim
        # takes 'model' instead (TP within experts) — logical_to_pspec's
        # first-come-first-served axis assignment arbitrates
        "expert_mlp": "model",
        "layers": None,
        "ensemble": "pod" if pod else None,
        "norm": None,
        "ssm_inner": "model",
        "ssm_state": None,
        "ssm_heads": "model",
        "ssm_group": None,
        "conv_kernel": None,
        "rwkv_lora": None,
        # -- activations ----------------------------------------------
        "act_batch": data,
        "act_seq": None,
        "act_embed": None,
        "act_mlp": "model",
        "act_heads": "model",
        "act_kv_heads": "model",
        "act_head_dim": None,
        "act_vocab": "model",
        "act_experts": "model",
        # expert capacity buffers: shard capacity over 'data' so the scatter
        # dispatch never all-reduces the full (E, C, D) buffer (§Perf iter 5)
        "act_capacity": ("data",),
        "act_ensemble": "pod" if pod else None,
        # -- kv cache ---------------------------------------------------
        "kv_batch": data,
        "kv_seq": None,
        "cache_kv_heads": "model",
    }


def make_rules(kind: str, *, pod: bool = False) -> dict:
    """Rule table for a shape kind: 'train' | 'prefill' | 'decode' | 'decode_long'."""
    r = _base_rules(pod)
    if kind == "train":
        # FSDP: weight embed dim over the data axis (ZeRO-3 style); XLA
        # all-gathers weights at use and reduce-scatters grads.
        r["embed"] = ("data",)
    elif kind == "prefill":
        r["embed"] = ("data",)  # weights stay fully sharded; long seq amortizes gathers
        r["act_seq"] = None
        # the produced KV cache is stored seq-sharded, matching the decode
        # rules it will be consumed under (and bounding output residency)
        r["kv_seq"] = "model"
        r["cache_kv_heads"] = None
    elif kind == "decode":
        r["embed"] = ("data",)
        r["kv_seq"] = "model"      # GQA kv_heads (2/8) rarely divisible by 16
        r["cache_kv_heads"] = None
    elif kind == "decode_long":
        r["embed"] = ("data",)
        r["kv_seq"] = ("data", "model")  # batch=1: spread the 500k cache everywhere
        r["cache_kv_heads"] = None
        r["act_batch"] = None
    else:
        raise ValueError(f"unknown rule kind: {kind}")
    return r


RULES_TRAIN = make_rules("train")
RULES_PREFILL = make_rules("prefill")
RULES_DECODE = make_rules("decode")


def rules_for(kind: str, *, pod: bool = False, batch: Optional[int] = None) -> dict:
    if kind == "decode" and batch == 1:
        kind = "decode_long"
    return make_rules(kind, pod=pod)
