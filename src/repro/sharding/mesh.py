"""Mesh helpers that are safe to import (no device-state side effects)."""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh


def local_mesh(axis_names: Sequence[str] = ("data", "model")) -> Mesh:
    """A degenerate mesh over however many devices are actually present.

    Used by smoke tests and examples: all devices on the first axis, size-1
    trailing axes, so the same pjit code paths run on 1 CPU device.
    """
    n = jax.device_count()
    shape = (n,) + (1,) * (len(axis_names) - 1)
    devices = np.array(jax.devices()).reshape(shape)
    return Mesh(devices, axis_names)


def mesh_axis_size(mesh: Mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape))[name]
