"""Sharding substrate: mesh helpers and logical-axis partitioning rules."""
from repro.sharding.logical import (
    LogicalAxisRules,
    logical_to_pspec,
    logical_sharding,
    tree_pspecs,
    with_logical_constraint,
    RULES_TRAIN,
    RULES_PREFILL,
    RULES_DECODE,
    rules_for,
)
from repro.sharding.mesh import local_mesh, mesh_axis_size

__all__ = [
    "LogicalAxisRules",
    "logical_to_pspec",
    "logical_sharding",
    "tree_pspecs",
    "with_logical_constraint",
    "RULES_TRAIN",
    "RULES_PREFILL",
    "RULES_DECODE",
    "rules_for",
    "local_mesh",
    "mesh_axis_size",
]
