"""RWKV6 (Finch) block: data-dependent-decay time mix + channel mix.

Faithful structure: token-shift ddlerp with a shared low-rank adapter for
the five mix coefficients (r,k,v,w,g), a LoRA'd data-dependent per-channel
decay, the WKV recurrence (kernels/rwkv6_wkv), per-head GroupNorm, and the
squared-ReLU channel mix.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels.rwkv6_wkv import ops as wkv_ops
from repro.models.params import Initializer
from repro.sharding.logical import constrain

_MIX = 5  # r, k, v, w, g


def init_rwkv6_block(ini: Initializer, cfg: ModelConfig):
    D = cfg.d_model
    R = cfg.rwkv_lora_rank
    H = D // cfg.ssm_head_dim
    hd = cfg.ssm_head_dim
    return {
        "ln1": {"scale": ini.ones((D,), ("norm",), dtype=jnp.float32),
                "bias": ini.zeros((D,), ("norm",), dtype=jnp.float32)},
        "ln2": {"scale": ini.ones((D,), ("norm",), dtype=jnp.float32),
                "bias": ini.zeros((D,), ("norm",), dtype=jnp.float32)},
        "tm": {
            "mu_base": ini.zeros((D,), ("embed",)),
            "mu": ini.normal((_MIX, D), (None, "embed"), std=0.2),
            "lora_w1": ini.normal((D, _MIX * R), ("embed", "rwkv_lora")),
            "lora_w2": ini.normal((_MIX, R, D), (None, "rwkv_lora", "embed"), std=0.01),
            "wr": ini.normal((D, D), ("embed", "mlp")),
            "wk": ini.normal((D, D), ("embed", "mlp")),
            "wv": ini.normal((D, D), ("embed", "mlp")),
            "wg": ini.normal((D, D), ("embed", "mlp")),
            "wo": ini.normal((D, D), ("mlp", "embed")),
            "decay_base": ini.const(jnp.full((D,), -6.0), ("embed",), dtype=jnp.float32),
            "decay_w1": ini.normal((D, R), ("embed", "rwkv_lora")),
            "decay_w2": ini.normal((R, D), ("rwkv_lora", "embed"), std=0.01),
            "u": ini.normal((H, hd), ("ssm_heads", "head_dim"), std=0.5),
            "gn_scale": ini.ones((D,), ("norm",), dtype=jnp.float32),
            "gn_bias": ini.zeros((D,), ("norm",), dtype=jnp.float32),
        },
        "cm": {
            "mu_k": ini.normal((D,), ("embed",), std=0.2),
            "mu_r": ini.normal((D,), ("embed",), std=0.2),
            "wk": ini.normal((D, cfg.d_ff), ("embed", "mlp")),
            "wv": ini.normal((cfg.d_ff, D), ("mlp", "embed")),
            "wr": ini.normal((D, D), ("embed", "mlp")),
        },
    }


def _ln(p, x, eps):
    xf = x.astype(jnp.float32)
    mean = xf.mean(-1, keepdims=True)
    var = xf.var(-1, keepdims=True)
    return ((xf - mean) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]).astype(
        x.dtype
    )


def _group_norm(tm, y, H, hd, eps):
    """Per-head LayerNorm (RWKV's GroupNorm with groups=H)."""
    B, S, D = y.shape
    yf = y.astype(jnp.float32).reshape(B, S, H, hd)
    mean = yf.mean(-1, keepdims=True)
    var = yf.var(-1, keepdims=True)
    yf = (yf - mean) * jax.lax.rsqrt(var + eps)
    return (yf.reshape(B, S, D) * tm["gn_scale"] + tm["gn_bias"]).astype(y.dtype)


def _ddlerp(tm, x, delta):
    """Data-dependent lerp for the five mix channels.  Returns (B,S,5,D)."""
    base = x + delta * tm["mu_base"]
    lora = jnp.tanh(base @ tm["lora_w1"])  # (B,S,5R)
    B_, S_, _ = lora.shape
    lora = lora.reshape(B_, S_, _MIX, -1)
    adj = jnp.einsum("bsmr,mrd->bsmd", lora, tm["lora_w2"])
    mix = tm["mu"][None, None] + adj  # (B,S,5,D)
    return x[:, :, None, :] + delta[:, :, None, :] * mix


def time_mix(tm, x, cfg: ModelConfig, *, prev_x=None, wkv_state=None, return_state=False):
    """x: (B,S,D).  prev_x: (B,D) carried shift token (zeros at seq start)."""
    B, S, D = x.shape
    H, hd = D // cfg.ssm_head_dim, cfg.ssm_head_dim
    if prev_x is None:
        prev_x = jnp.zeros((B, D), x.dtype)
    shifted = jnp.concatenate([prev_x[:, None, :], x[:, :-1, :]], axis=1)
    delta = shifted - x

    mixed = _ddlerp(tm, x, delta)  # (B,S,5,D)
    xr, xk, xv, xw, xg = (mixed[:, :, i, :] for i in range(_MIX))
    r = (xr @ tm["wr"]).reshape(B, S, H, hd)
    k = (xk @ tm["wk"]).reshape(B, S, H, hd)
    v = (xv @ tm["wv"]).reshape(B, S, H, hd)
    g = jax.nn.silu(xg @ tm["wg"])
    logw = -jnp.exp(
        tm["decay_base"] + jnp.tanh(xw.astype(jnp.float32) @ tm["decay_w1"].astype(jnp.float32)) @ tm["decay_w2"].astype(jnp.float32)
    )  # (B,S,D) <= 0
    logw = logw.reshape(B, S, H, hd)
    r = constrain(r, ("act_batch", "act_seq", "act_heads", "act_head_dim"))
    k = constrain(k, ("act_batch", "act_seq", "act_heads", "act_head_dim"))

    y, sT = wkv_ops.wkv6(
        r, k, v, logw, tm["u"], initial_state=wkv_state, return_final_state=True
    )
    y = _group_norm(tm, y.reshape(B, S, D), H, hd, cfg.norm_eps)
    out = (y * g) @ tm["wo"]
    if return_state:
        return out, (x[:, -1, :], sT)
    return out


def channel_mix(cm, x, *, prev_x=None, return_state=False):
    B, S, D = x.shape
    if prev_x is None:
        prev_x = jnp.zeros((B, D), x.dtype)
    shifted = jnp.concatenate([prev_x[:, None, :], x[:, :-1, :]], axis=1)
    delta = shifted - x
    xk = x + delta * cm["mu_k"]
    xr = x + delta * cm["mu_r"]
    k = jnp.square(jax.nn.relu(xk @ cm["wk"]))
    k = constrain(k, ("act_batch", "act_seq", "act_mlp"))
    out = jax.nn.sigmoid(xr @ cm["wr"]) * (k @ cm["wv"])
    if return_state:
        return out, x[:, -1, :]
    return out


def rwkv6_layer_fwd(p, x, cfg: ModelConfig, *, state=None, return_state=False):
    """state: dict(tm_x (B,D), cm_x (B,D), wkv (B,H,hd,hd)) or None."""
    tm_prev = None if state is None else state["tm_x"]
    cm_prev = None if state is None else state["cm_x"]
    wkv_prev = None if state is None else state["wkv"]
    if return_state:
        h, (tm_x, wkv) = time_mix(
            p["tm"], _ln(p["ln1"], x, cfg.norm_eps), cfg,
            prev_x=tm_prev, wkv_state=wkv_prev, return_state=True,
        )
        x = x + h
        h, cm_x = channel_mix(
            p["cm"], _ln(p["ln2"], x, cfg.norm_eps), prev_x=cm_prev, return_state=True
        )
        x = x + h
        return x, {"tm_x": tm_x, "cm_x": cm_x, "wkv": wkv}
    h = time_mix(
        p["tm"], _ln(p["ln1"], x, cfg.norm_eps), cfg,
        prev_x=tm_prev, wkv_state=wkv_prev,
    )
    x = x + h
    x = x + channel_mix(p["cm"], _ln(p["ln2"], x, cfg.norm_eps), prev_x=cm_prev)
    return constrain(x, ("act_batch", "act_seq", "act_embed"))


def init_rwkv6_state(cfg: ModelConfig, batch: int, dtype):
    D = cfg.d_model
    H, hd = D // cfg.ssm_head_dim, cfg.ssm_head_dim
    return {
        "tm_x": jnp.zeros((batch, D), dtype),
        "cm_x": jnp.zeros((batch, D), dtype),
        "wkv": jnp.zeros((batch, H, hd, hd), jnp.float32),
    }


def rwkv6_step(p, x, cfg: ModelConfig, state):
    """Single-token decode via the length-1 sequence path."""
    ln1 = _ln(p["ln1"], x, cfg.norm_eps)
    h, (tm_x, wkv) = time_mix(
        p["tm"], ln1, cfg, prev_x=state["tm_x"], wkv_state=state["wkv"],
        return_state=True,
    )
    x = x + h
    h, cm_x = channel_mix(
        p["cm"], _ln(p["ln2"], x, cfg.norm_eps), prev_x=state["cm_x"],
        return_state=True,
    )
    x = x + h
    return x, {"tm_x": tm_x, "cm_x": cm_x, "wkv": wkv}
