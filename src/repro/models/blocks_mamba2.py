"""Mamba2 block (Zamba2's SSM backbone) with train + decode paths.

in_proj -> [z | x | B | C | dt]; causal depthwise conv over [x|B|C];
y = SSD(x·dt, A·dt, B, C) + D·x;  out = out_proj(RMSNorm(y · silu(z))).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels.mamba2_ssd import ops as ssd_ops
from repro.models.params import Initializer
from repro.sharding.logical import constrain


def _dims(cfg: ModelConfig):
    d_in = cfg.d_inner
    nh = cfg.ssm_nheads
    G, N = cfg.ssm_ngroups, cfg.ssm_state
    conv_dim = d_in + 2 * G * N
    proj_dim = 2 * d_in + 2 * G * N + nh
    return d_in, nh, G, N, conv_dim, proj_dim


def init_mamba2_block(ini: Initializer, cfg: ModelConfig):
    d_in, nh, G, N, conv_dim, proj_dim = _dims(cfg)
    return {
        "in_proj": ini.normal((cfg.d_model, proj_dim), ("embed", "ssm_inner")),
        "conv_w": ini.normal((cfg.ssm_conv, conv_dim), ("conv_kernel", "ssm_inner"), std=0.5),
        "conv_b": ini.zeros((conv_dim,), ("ssm_inner",)),
        "A_log": ini.const(jnp.log(jnp.linspace(1.0, 16.0, nh)), ("ssm_heads",), dtype=jnp.float32),
        "D": ini.ones((nh,), ("ssm_heads",), dtype=jnp.float32),
        "dt_bias": ini.const(jnp.log(jnp.expm1(jnp.full((nh,), 1e-2))), ("ssm_heads",), dtype=jnp.float32),
        "norm": ini.ones((d_in,), ("ssm_inner",), dtype=jnp.float32),
        "out_proj": ini.normal((d_in, cfg.d_model), ("ssm_inner", "embed")),
    }


def _split_proj(proj, cfg: ModelConfig):
    d_in, nh, G, N, _, _ = _dims(cfg)
    z, xBC, dt = jnp.split(proj, [d_in, d_in + d_in + 2 * G * N], axis=-1)
    return z, xBC, dt  # dt: (..., nh)


def _gated_out(p, y, z, cfg: ModelConfig):
    g = y * jax.nn.silu(z)
    gf = g.astype(jnp.float32)
    var = jnp.mean(jnp.square(gf), axis=-1, keepdims=True)
    g = (gf * jax.lax.rsqrt(var + cfg.norm_eps) * p["norm"]).astype(y.dtype)
    return g @ p["out_proj"]


def mamba2_fwd(p, x, cfg: ModelConfig, *, initial=None, return_state: bool = False):
    """Full-sequence forward.  x: (B, S, D).
    initial: optional dict(conv=(B, K-1, conv_dim), ssm=(B, nh, N, hd))."""
    B, S, D = x.shape
    d_in, nh, G, N, conv_dim, _ = _dims(cfg)
    K = cfg.ssm_conv

    proj = x @ p["in_proj"]
    z, xBC, dt = _split_proj(proj, cfg)

    # causal depthwise conv over the sequence
    prev = (
        jnp.zeros((B, K - 1, conv_dim), xBC.dtype)
        if initial is None
        else initial["conv"].astype(xBC.dtype)
    )
    padded = jnp.concatenate([prev, xBC], axis=1)
    conv = sum(
        padded[:, i : i + S, :].astype(jnp.float32)
        * p["conv_w"][i][None, None, :].astype(jnp.float32)
        for i in range(K)
    ).astype(xBC.dtype)
    xBC = jax.nn.silu(conv + p["conv_b"])
    conv_state = padded[:, S:, :] if K > 1 else prev

    xs, Bm, Cm = jnp.split(xBC, [d_in, d_in + G * N], axis=-1)
    xs = xs.reshape(B, S, nh, cfg.ssm_head_dim)
    xs = constrain(xs, ("act_batch", "act_seq", "act_heads", "act_head_dim"))
    Bm = Bm.reshape(B, S, G, N)
    Cm = Cm.reshape(B, S, G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,nh)
    A = -jnp.exp(p["A_log"])

    ssm0 = None if initial is None else initial["ssm"]
    y, ssm_state = ssd_ops.ssd(
        xs, dt, A, Bm, Cm, initial_state=ssm0, return_final_state=True
    )
    y = y + xs * p["D"][None, None, :, None]
    y = y.reshape(B, S, d_in).astype(x.dtype)
    out = _gated_out(p, y, z, cfg)
    out = constrain(out, ("act_batch", "act_seq", "act_embed"))
    if return_state:
        return out, {"conv": conv_state, "ssm": ssm_state}
    return out


def init_mamba2_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    d_in, nh, G, N, conv_dim, _ = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
        "ssm": jnp.zeros((batch, nh, N, cfg.ssm_head_dim), jnp.float32),
    }


def mamba2_step(p, x, cfg: ModelConfig, state):
    """Single-token decode.  x: (B, 1, D) -> (out (B,1,D), new_state)."""
    B = x.shape[0]
    d_in, nh, G, N, conv_dim, _ = _dims(cfg)
    K = cfg.ssm_conv

    proj = x[:, 0] @ p["in_proj"]  # (B, proj_dim)
    z, xBC, dt = _split_proj(proj, cfg)

    window = jnp.concatenate(
        [state["conv"].astype(xBC.dtype), xBC[:, None, :]], axis=1
    )  # (B, K, conv_dim)
    conv = jnp.einsum("bkc,kc->bc", window, p["conv_w"].astype(jnp.float32)).astype(
        xBC.dtype
    )
    xBC = jax.nn.silu(conv + p["conv_b"])
    new_conv = window[:, 1:, :]

    xs, Bm, Cm = jnp.split(xBC, [d_in, d_in + G * N], axis=-1)
    xs = xs.reshape(B, nh, cfg.ssm_head_dim)
    Bm = Bm.reshape(B, G, N)
    Cm = Cm.reshape(B, G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B, nh)
    A = -jnp.exp(p["A_log"])

    y, ssm = ssd_ops.ssd_step(xs, dt, A, Bm, Cm, state["ssm"])
    y = y + xs * p["D"][None, :, None]
    out = _gated_out(p, y.reshape(B, d_in).astype(x.dtype), z, cfg)
    return out[:, None, :], {"conv": new_conv, "ssm": ssm}
