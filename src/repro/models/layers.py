"""Shared building blocks: norms, RoPE, MLP, GQA attention, MoE.

All functions are pure; parameters arrive as (already unboxed) dict leaves.
Hot activations are annotated with ``constrain`` so the same code lowers
single-device (rules absent -> no-op) and on the production mesh.
"""
from __future__ import annotations

import math
import os
from typing import Optional

import jax
import jax.numpy as jnp

# §Perf baselines: REPRO_LEGACY_DECODE=1 re-enables the pre-optimization
# decode paths ((B,S,K,hd) cache layout + per-step transpose; MoE decode
# capacity = T) so before/after roofline numbers use the same cost model.
LEGACY_DECODE = os.environ.get("REPRO_LEGACY_DECODE", "0") == "1"

from repro.configs.base import ModelConfig
from repro.models.params import Initializer
from repro.sharding.logical import constrain

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_norm(ini: Initializer, cfg: ModelConfig, d: int):
    if cfg.norm_type == "rmsnorm":
        return {"scale": ini.ones((d,), ("norm",), dtype=jnp.float32)}
    if cfg.norm_type == "layernorm":
        return {
            "scale": ini.ones((d,), ("norm",), dtype=jnp.float32),
            "bias": ini.zeros((d,), ("norm",), dtype=jnp.float32),
        }
    if cfg.norm_type == "nonparametric_ln":  # OLMo
        return {}
    raise ValueError(cfg.norm_type)


def apply_norm(p, x, cfg: ModelConfig):
    xf = x.astype(jnp.float32)
    if cfg.norm_type == "rmsnorm":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + cfg.norm_eps) * p["scale"]
    else:
        mean = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mean) * jax.lax.rsqrt(var + cfg.norm_eps)
        if cfg.norm_type == "layernorm":
            y = y * p["scale"] + p["bias"]
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def init_mlp(ini: Initializer, cfg: ModelConfig, d: Optional[int] = None, d_ff: Optional[int] = None):
    d = d or cfg.d_model
    d_ff = d_ff or cfg.d_ff
    if cfg.mlp_activation == "silu":  # gated
        return {
            "w_gate": ini.normal((d, d_ff), ("embed", "mlp")),
            "w_up": ini.normal((d, d_ff), ("embed", "mlp")),
            "w_down": ini.normal((d_ff, d), ("mlp", "embed")),
        }
    return {  # plain gelu MLP (encoder-style)
        "w_in": ini.normal((d, d_ff), ("embed", "mlp")),
        "b_in": ini.zeros((d_ff,), ("mlp",)),
        "w_out": ini.normal((d_ff, d), ("mlp", "embed")),
        "b_out": ini.zeros((d,), ("embed",)),
    }


def apply_mlp(p, x, cfg: ModelConfig):
    if cfg.mlp_activation == "silu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
        h = constrain(h, ("act_batch", "act_seq", "act_mlp"))
        return h @ p["w_down"]
    h = jax.nn.gelu(x @ p["w_in"] + p["b_in"])
    h = constrain(h, ("act_batch", "act_seq", "act_mlp"))
    return h @ p["w_out"] + p["b_out"]


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------


def init_attention(ini: Initializer, cfg: ModelConfig, d: Optional[int] = None):
    d = d or cfg.d_model
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    p = {
        "wq": ini.normal((d, H, hd), ("embed", "heads", "head_dim")),
        "wk": ini.normal((d, K, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ini.normal((d, K, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ini.normal((H, hd, d), ("heads", "head_dim", "embed"), std=1.0 / math.sqrt(H * hd)),
    }
    if cfg.qkv_bias:
        p["bq"] = ini.zeros((H, hd), ("heads", "head_dim"))
        p["bk"] = ini.zeros((K, hd), ("kv_heads", "head_dim"))
        p["bv"] = ini.zeros((K, hd), ("kv_heads", "head_dim"))
    if cfg.attn_out_bias:
        p["bo"] = ini.zeros((d,), ("embed",))
    return p


def qkv_project(p, x, cfg: ModelConfig, positions, *, use_rope: bool = True):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if "bq" in p:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = constrain(q, ("act_batch", "act_seq", "act_heads", "act_head_dim"))
    k = constrain(k, ("act_batch", "act_seq", "act_kv_heads", "act_head_dim"))
    v = constrain(v, ("act_batch", "act_seq", "act_kv_heads", "act_head_dim"))
    return q, k, v


def attn_output(p, ctx, cfg: ModelConfig):
    out = jnp.einsum("bshk,hkd->bsd", ctx, p["wo"])
    if "bo" in p:
        out = out + p["bo"]
    return constrain(out, ("act_batch", "act_seq", "act_embed"))


def attention_layer(
    p,
    x,
    cfg: ModelConfig,
    *,
    causal: bool,
    positions=None,
    use_rope: bool = True,
    sliding_window: Optional[int] = None,
    starts=None,
):
    """Full-sequence (train / prefill) attention. Returns (out, (k, v)).

    ``starts`` (B,) optional per-request prompt starts: with left-padded
    batches row b's tokens are masked from attending columns < starts[b],
    and the caller is expected to pass positions offset per row so RoPE
    matches the unpadded run (serve/engine.py's pad carve-out).  The
    carve-out is served on every impl — the Pallas flash kernel takes
    ``starts`` via scalar prefetch and skips below-start KV blocks, so
    left-padded prefill stays on the kernel path."""
    from repro.kernels.flash_attention import ops as flash_ops

    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q, k, v = qkv_project(p, x, cfg, positions, use_rope=use_rope)
    ctx = flash_ops.flash_attention(
        q, k, v, causal=causal, window=sliding_window,
        softcap=cfg.attn_logit_softcap, starts=starts,
    )
    return attn_output(p, ctx, cfg), (k, v)


def attention_decode(
    p,
    x,
    cfg: ModelConfig,
    k_cache,
    v_cache,
    cur_index,
    *,
    use_rope: bool = True,
    sliding_window: Optional[int] = None,
    starts=None,
):
    """Single-token decode.  Caches use the kernel-native layout
    (B, K, S_max, hd) — sequence-innermost, so the per-step update writes one
    (B, K, 1, hd) slice and the attention sweep streams the cache with NO
    transpose (§Perf iteration 1).  ``starts`` (B,) carries the left-pad
    carve-out through decode: cache columns before a request's prompt start
    stay invisible and RoPE positions are taken relative to the start, so
    a left-padded generation step matches the solo run token-for-token —
    on every impl, since the Pallas decode kernel prefetches ``starts``
    alongside the per-slot lengths and skips below-start cache blocks.
    Returns (out, (k_cache, v_cache))."""
    from repro.kernels.decode_attention import ops as dec_ops

    B = x.shape[0]
    cur_index = jnp.asarray(cur_index)
    vector_pos = cur_index.ndim == 1  # per-slot positions (continuous batching)
    positions = (
        cur_index[:, None] if vector_pos else jnp.full((B, 1), cur_index)
    )
    if starts is not None:
        positions = positions - jnp.asarray(starts)[:, None]
    q, k, v = qkv_project(p, x, cfg, positions, use_rope=use_rope)
    if vector_pos:
        # scatter one token per sequence at its own position
        bidx = jnp.arange(B)
        k_cache = k_cache.at[bidx, :, cur_index, :].set(
            k[:, 0].astype(k_cache.dtype)
        )
        v_cache = v_cache.at[bidx, :, cur_index, :].set(
            v[:, 0].astype(v_cache.dtype)
        )
        ctx = dec_ops.decode_attention_bksd(
            q, k_cache, v_cache, cur_len=cur_index + 1,
            window=sliding_window, softcap=cfg.attn_logit_softcap,
            starts=starts,
        )
        return attn_output(p, ctx, cfg), (k_cache, v_cache)
    assert starts is None or not LEGACY_DECODE, (
        "left-pad carve-out requires the kernel-native decode path"
    )
    if LEGACY_DECODE:  # (B, S, K, hd) cache + per-step transpose
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            k_cache, k.astype(k_cache.dtype), cur_index, axis=1
        )
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            v_cache, v.astype(v_cache.dtype), cur_index, axis=1
        )
        ctx = dec_ops.decode_attention(
            q, k_cache, v_cache, cur_len=cur_index + 1,
            window=sliding_window, softcap=cfg.attn_logit_softcap,
        )
        return attn_output(p, ctx, cfg), (k_cache, v_cache)
    k_new = k.transpose(0, 2, 1, 3).astype(k_cache.dtype)  # (B, K, 1, hd)
    v_new = v.transpose(0, 2, 1, 3).astype(v_cache.dtype)
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k_new, cur_index, axis=2)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v_new, cur_index, axis=2)
    ctx = dec_ops.decode_attention_bksd(
        q,
        k_cache,
        v_cache,
        cur_len=cur_index + 1,
        window=sliding_window,
        softcap=cfg.attn_logit_softcap,
        starts=starts,
    )
    return attn_output(p, ctx, cfg), (k_cache, v_cache)


def attention_prefill_chunk(
    p,
    x,
    cfg: ModelConfig,
    k_cache,
    v_cache,
    start,
    *,
    use_rope: bool = True,
    sliding_window: Optional[int] = None,
):
    """Chunked-prefill attention for one slot row (continuous batching).

    x: (1, C, D) — a C-token chunk of one request's prompt; caches are the
    slot's kernel-native (1, KVH, S_max, hd) rows; ``start`` is the (traced)
    absolute position of the chunk's first token.  Writes the chunk's K/V at
    rows [start, start+C) and attends each chunk token causally over the
    cache prefix — row t is visible to chunk token j iff t <= start+j, the
    same per-slot pos-masking contract as ``attention_decode`` (stale rows
    from a slot's previous occupant stay invisible).  Returns
    (out (1, C, D), (k_cache, v_cache))."""
    B, C, _ = x.shape
    start = jnp.asarray(start)
    positions = start + jnp.arange(C)[None, :]  # (1, C) absolute positions
    q, k, v = qkv_project(p, x, cfg, positions, use_rope=use_rope)
    k_cache = jax.lax.dynamic_update_slice(
        k_cache, k.transpose(0, 2, 1, 3).astype(k_cache.dtype), (0, 0, start, 0)
    )
    v_cache = jax.lax.dynamic_update_slice(
        v_cache, v.transpose(0, 2, 1, 3).astype(v_cache.dtype), (0, 0, start, 0)
    )
    ctx = _chunk_attend(q, k_cache, v_cache, positions, cfg, sliding_window)
    return attn_output(p, ctx, cfg), (k_cache, v_cache)


def _chunk_attend(q, k_view, v_view, positions, cfg: ModelConfig, sliding_window):
    """Masked-softmax chunk attention over a (B, KVH, S, hd) cache view —
    the one implementation behind BOTH the dense and the paged chunk
    prefill, which is what makes their outputs bitwise identical: masked
    lanes are pinned to -1e30 so their softmax weight underflows to exactly
    0.0, hiding stale dense rows and unmapped paged rows the same way."""
    B, C = q.shape[0], q.shape[1]
    KVH, S = k_view.shape[1], k_view.shape[2]
    H, hd = q.shape[2], q.shape[3]
    G = H // KVH
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(B, C, KVH, G, hd).astype(jnp.float32) * scale
    s = jnp.einsum("bckgd,bksd->bkgcs", qg, k_view.astype(jnp.float32))
    if cfg.attn_logit_softcap is not None:
        s = cfg.attn_logit_softcap * jnp.tanh(s / cfg.attn_logit_softcap)
    cols = jnp.arange(S)[None, :]  # (1, S)
    rows = positions[0][:, None]  # (C, 1)
    mask = cols <= rows
    if sliding_window is not None:
        mask &= cols > rows - sliding_window
    s = jnp.where(mask[None, None, None, :, :], s, -1e30)
    pr = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bkgcs,bksd->bckgd", pr, v_view.astype(jnp.float32))
    return ctx.reshape(B, C, H, hd).astype(q.dtype)


def project_logits(params, x, cfg: ModelConfig):
    """Final-norm + LM-head projection of hidden states ``x`` (..., S, D)
    to f32 logits (..., S, V) — the one head implementation shared by batch
    prefill/decode and the chunked verify pass (``api.prefill_into_slot_
    logits``), so a draft token scored by either path sees the same
    numerics."""
    x = apply_norm(params["final_norm"], x, cfg)
    head = params["lm_head"] if "lm_head" in params else params["embed"].T
    return (x @ head).astype(jnp.float32)


# ---------------------------------------------------------------------------
# block-paged attention (serve/paging.py owns the table; see DESIGN.md §10)
# ---------------------------------------------------------------------------


def paged_view(pool, pages):
    """Gather per-slot contiguous cache views out of a paged pool.

    pool: (P, KVH, page_size, hd); pages: (B, n_pg) int32 page table, -1 =
    unmapped (gathers as zero rows).  Returns (B, KVH, n_pg * page_size, hd)
    — by construction exactly the dense cache's (B, KVH, S, hd)."""
    from repro.kernels.compaction.ops import gather_rows

    P, KVH, ps, hd = pool.shape
    B, n_pg = pages.shape
    rows = gather_rows(pool, pages.reshape(-1))  # (B * n_pg, KVH, ps, hd)
    return (
        rows.reshape(B, n_pg, KVH, ps, hd)
        .transpose(0, 2, 1, 3, 4)
        .reshape(B, KVH, n_pg * ps, hd)
    )


def attention_decode_paged(
    p,
    x,
    cfg: ModelConfig,
    k_pool,
    v_pool,
    cur_index,
    pages,
    *,
    use_rope: bool = True,
    sliding_window: Optional[int] = None,
):
    """Single-token decode against a block-paged KV pool.

    Pools are (P, KVH, page_size, hd); ``pages`` (B, n_pg) maps each slot's
    sequence spans onto pool pages.  The new K/V row scatters into the
    slot's current page (an unmapped row lands on the overflow sink — the
    last pool page, reserved by the allocator); attention runs over the
    page-gathered view, which is bitwise the dense slot cache.  Per-slot
    (B,) positions only — paging exists for continuous batching.
    Returns (out, (k_pool, v_pool))."""
    from repro.kernels.decode_attention import ops as dec_ops

    cur_index = jnp.asarray(cur_index)
    assert cur_index.ndim == 1, "paged decode takes per-slot (B,) positions"
    ps = k_pool.shape[2]
    positions = cur_index[:, None]
    q, k, v = qkv_project(p, x, cfg, positions, use_rope=use_rope)
    pg = jnp.take_along_axis(pages, (cur_index // ps)[:, None], axis=1)[:, 0]
    pg = jnp.where(pg >= 0, pg, k_pool.shape[0] - 1)  # overflow sink
    off = cur_index % ps
    k_pool = k_pool.at[pg, :, off, :].set(k[:, 0].astype(k_pool.dtype))
    v_pool = v_pool.at[pg, :, off, :].set(v[:, 0].astype(v_pool.dtype))
    ctx = dec_ops.decode_attention_paged(
        q, k_pool, v_pool, pages, cur_len=cur_index + 1,
        window=sliding_window, softcap=cfg.attn_logit_softcap,
    )
    return attn_output(p, ctx, cfg), (k_pool, v_pool)


def attention_prefill_chunk_paged(
    p,
    x,
    cfg: ModelConfig,
    k_pool,
    v_pool,
    start,
    pages_row,
    *,
    use_rope: bool = True,
    sliding_window: Optional[int] = None,
):
    """Chunked-prefill attention for one slot against the paged pool.

    x: (1, C, D); pages_row: (n_pg,) the slot's page-table row.  The
    chunk's K/V rows scatter into the mapped pages at their in-page
    offsets, then the chunk attends over the slot's gathered view through
    the SAME ``_chunk_attend`` as the dense path — token-for-token and
    bitwise what the dense slot row computes.  Returns
    (out (1, C, D), (k_pool, v_pool))."""
    B, C, _ = x.shape
    ps = k_pool.shape[2]
    start = jnp.asarray(start)
    positions = start + jnp.arange(C)[None, :]  # (1, C)
    q, k, v = qkv_project(p, x, cfg, positions, use_rope=use_rope)
    pg = pages_row[positions[0] // ps]  # (C,)
    pg = jnp.where(pg >= 0, pg, k_pool.shape[0] - 1)  # overflow sink
    off = positions[0] % ps
    k_pool = k_pool.at[pg, :, off, :].set(k[0].astype(k_pool.dtype))
    v_pool = v_pool.at[pg, :, off, :].set(v[0].astype(v_pool.dtype))
    k_view = paged_view(k_pool, pages_row[None])  # (1, KVH, S, hd)
    v_view = paged_view(v_pool, pages_row[None])
    ctx = _chunk_attend(q, k_view, v_view, positions, cfg, sliding_window)
    return attn_output(p, ctx, cfg), (k_pool, v_pool)


# ---------------------------------------------------------------------------
# MoE (token-choice top-k, capacity-dropped, scatter dispatch)
# ---------------------------------------------------------------------------


def init_moe(ini: Initializer, cfg: ModelConfig):
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    p = {
        "router": ini.normal((d, E), ("embed", "experts"), dtype=jnp.float32),
        "w_gate": ini.normal((E, d, f), ("experts", "embed", "expert_mlp")),
        "w_up": ini.normal((E, d, f), ("experts", "embed", "expert_mlp")),
        "w_down": ini.normal((E, f, d), ("experts", "expert_mlp", "embed")),
    }
    if cfg.n_shared_experts:
        p["shared"] = init_mlp(ini, cfg, d, f * cfg.n_shared_experts)
    return p


def apply_moe(p, x, cfg: ModelConfig):
    """x: (B, S, D) -> (out, aux_loss).

    Sort-free scatter dispatch: per-token expert choice -> position within the
    expert's capacity buffer via a cumulative count; overflowing tokens are
    dropped (standard capacity-factor semantics).  Experts shard over the
    'model' mesh axis (expert parallelism); GSPMD inserts the all-to-alls.
    """
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    xt = x.reshape(T, D)

    gate_logits = (xt.astype(jnp.float32) @ p["router"])  # (T, E)
    probs = jax.nn.softmax(gate_logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)  # (T, K)
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)

    # Load-balancing auxiliary loss (Switch-style).
    me = probs.mean(axis=0)  # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[expert_idx.reshape(-1)].add(1.0) / (T * K)
    aux = E * jnp.sum(me * ce) * cfg.router_aux_coef

    if S == 1:
        # decode (§Perf iteration 2): a C=T no-drop buffer makes every expert
        # compute T rows — E× overcompute for top-1 at B≈E.  A 2× balance
        # slack keeps drops rare while the expert matmuls stay O(T·K) total.
        if LEGACY_DECODE:
            capacity = T
        else:
            capacity = min(T, max(8, int(math.ceil(T * K / E * 2.0))))
    else:
        capacity = max(1, int(math.ceil(T * K / E * cfg.capacity_factor)))

    # Position of each (token, k) within its expert's buffer.
    onehot = jax.nn.one_hot(expert_idx, E, dtype=jnp.int32)  # (T, K, E)
    flat_oh = onehot.reshape(T * K, E)
    pos_in_expert = (jnp.cumsum(flat_oh, axis=0) - flat_oh)  # exclusive
    pos = (pos_in_expert * flat_oh).sum(-1).reshape(T, K)  # (T, K)
    keep = (pos < capacity).astype(x.dtype)

    # Scatter tokens into (E, C, D) expert buffers.
    buf = jnp.zeros((E, capacity, D), x.dtype)
    scatter_idx = jnp.stack(
        [expert_idx.reshape(-1), jnp.clip(pos.reshape(-1), 0, capacity - 1)], axis=-1
    )  # (T*K, 2)
    contrib = (xt[:, None, :] * keep[:, :, None]).reshape(T * K, D)
    buf = buf.at[scatter_idx[:, 0], scatter_idx[:, 1]].add(contrib)
    _cap_axis = None if LEGACY_DECODE else "act_capacity"  # §Perf iter 5
    buf = constrain(buf, ("act_experts", _cap_axis, "act_embed"))

    # Expert FFNs, vmapped over E (sharded over 'model').
    def expert_ffn(wg, wu, wd, h):
        a = jax.nn.silu(h @ wg) * (h @ wu)
        return a @ wd

    out_buf = jax.vmap(expert_ffn)(p["w_gate"], p["w_up"], p["w_down"], buf)
    out_buf = constrain(out_buf, ("act_experts", _cap_axis, "act_embed"))

    # Gather back and combine with gate values.
    gathered = out_buf[scatter_idx[:, 0], scatter_idx[:, 1]].reshape(T, K, D)
    combined = (gathered * (gate_vals.astype(x.dtype) * keep)[:, :, None]).sum(axis=1)

    if cfg.n_shared_experts:
        combined = combined + apply_mlp(p["shared"], xt[None], cfg)[0]

    return combined.reshape(B, S, D), aux
