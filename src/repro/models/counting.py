"""Analytic parameter counts via eval_shape (no weights materialized)."""
from __future__ import annotations

import functools

import jax
import numpy as np

from repro.configs.base import ModelConfig


@functools.lru_cache(maxsize=64)
def _shapes(cfg: ModelConfig):
    from repro.models.api import init_params
    from repro.models.params import unbox

    def init(rng):
        values, _ = unbox(init_params(cfg, rng))
        return values

    return jax.eval_shape(init, jax.ShapeDtypeStruct((2,), np.uint32))


def count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    shapes = _shapes(cfg)
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        n = int(np.prod(leaf.shape))
        keys = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        if active_only and cfg.n_experts and any(
            k in ("w_gate", "w_up", "w_down") for k in keys
        ) and "moe" in keys:
            # only top_k of n_experts are active per token
            n = int(n * cfg.top_k / cfg.n_experts)
        total += n
    return total


def embedding_params(cfg: ModelConfig) -> int:
    n = cfg.vocab_size * cfg.d_model
    shapes = _shapes(cfg)
    has_head = "lm_head" in shapes
    return n * (2 if has_head else 1)


def model_flops_per_token(cfg: ModelConfig) -> float:
    """6·N (per token) with N = active non-embedding params — the standard
    MODEL_FLOPS used in §Roofline's usefulness ratio."""
    n_active = count_params(cfg, active_only=True) - embedding_params(cfg)
    return 6.0 * max(n_active, 0)
