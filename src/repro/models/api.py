"""Unified model API over the six architecture families.

    params = init_params(cfg, rng)                  # boxed (value + axes)
    values, axes = unbox(params)

    loss, metrics = loss_fn(values, batch, cfg)     # train step ingredient
    logits, cache = prefill(values, batch, cfg)     # inference prefill
    logits, cache = decode_step(values, token, cache_values, pos, cfg)

    input_specs(cfg, shape)   ShapeDtypeStruct stand-ins for the dry-run
    make_inputs(cfg, shape)   concrete random inputs for smoke tests

Layer stacks run under ``lax.scan`` over stacked parameters so HLO size is
depth-independent; remat applies to the scanned body for train shapes.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import blocks_dense as BD
from repro.models import blocks_mamba2 as BM
from repro.models import blocks_rwkv6 as BR
from repro.models import layers as L
from repro.models.params import Box, Initializer, is_box, stack_layers, unbox
from repro.sharding.logical import constrain

# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_layer(ini_key, cfg: ModelConfig, *, moe_override=None):
    ini = Initializer(ini_key, cfg.dtype)
    fam = cfg.family
    if fam in ("dense", "vlm", "encoder"):
        return BD.init_dense_layer(ini, cfg, moe=False)
    if fam == "moe":
        moe = True if moe_override is None else moe_override
        return BD.init_dense_layer(ini, cfg, moe=moe)
    if fam == "ssm_mamba2" or fam == "hybrid":
        return BM.init_mamba2_block(ini, cfg)
    if fam == "ssm_rwkv6":
        return BR.init_rwkv6_block(ini, cfg)
    raise ValueError(fam)


def _interleaved_moe(cfg: ModelConfig) -> bool:
    """MoE every `moe_every`-th layer (llama4-style interleave)."""
    return cfg.family == "moe" and cfg.moe_every > 1


def init_params(cfg: ModelConfig, rng: jax.Array):
    k_embed, k_layers, k_shared, k_head, k_front = jax.random.split(rng, 5)
    ini = Initializer(k_embed, cfg.dtype)
    p = {}
    if not cfg.is_encoder:
        p["embed"] = ini.normal(
            (cfg.vocab_size, cfg.d_model), ("vocab", "embed"), std=0.02
        )
    if cfg.frontend_dim:
        fini = Initializer(k_front, cfg.dtype)
        p["frontend"] = {
            "proj": fini.normal((cfg.frontend_dim, cfg.d_model), (None, "embed"))
        }
    if _interleaved_moe(cfg):
        me = cfg.moe_every
        assert cfg.n_layers % me == 0, (cfg.n_layers, me)
        n_groups = cfg.n_layers // me
        kd, km = jax.random.split(k_layers)
        p["layers"] = {
            "dense": stack_layers(
                functools.partial(_init_layer, cfg=cfg, moe_override=False),
                n_groups * (me - 1), kd,
            ),
            "moe": stack_layers(
                functools.partial(_init_layer, cfg=cfg, moe_override=True),
                n_groups, km,
            ),
        }
    else:
        p["layers"] = stack_layers(
            functools.partial(_init_layer, cfg=cfg), cfg.n_layers, k_layers
        )
    if cfg.family == "hybrid" and cfg.attn_every:
        sini = Initializer(k_shared, cfg.dtype)
        p["shared_attn"] = BD.init_dense_layer(sini, cfg, moe=False)
    hini = Initializer(k_head, cfg.dtype)
    p["final_norm"] = L.init_norm(hini, cfg, cfg.d_model)
    if cfg.tie_embeddings:
        pass  # reuse embed.T at the head
    else:
        p["lm_head"] = hini.normal(
            (cfg.d_model, cfg.vocab_size), ("embed", "vocab"), std=0.02
        )
    return p


# ---------------------------------------------------------------------------
# embedding / frontend
# ---------------------------------------------------------------------------


def embed_inputs(params, batch, cfg: ModelConfig):
    """Returns hidden (B, S, D).  For VLM the (stubbed, precomputed) patch
    embeddings are projected and prepended; for the audio encoder the frame
    embeddings are projected directly (assignment carve-out)."""
    if cfg.is_encoder:
        x = batch["embeds"] @ params["frontend"]["proj"]
        return x.astype(cfg.dtype)
    tok = params["embed"][batch["tokens"]]  # (B, St, D)
    if cfg.n_vision_tokens and "embeds" in batch:
        vis = (batch["embeds"] @ params["frontend"]["proj"]).astype(tok.dtype)
        tok = jnp.concatenate([vis, tok], axis=1)
    return constrain(tok, ("act_batch", "act_seq", "act_embed"))


# ---------------------------------------------------------------------------
# backbone (full sequence)
# ---------------------------------------------------------------------------


def _maybe_remat(fn, cfg: ModelConfig, train: bool):
    if cfg.remat and train:
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    return fn


def backbone_fwd(
    params,
    x,
    cfg: ModelConfig,
    *,
    train: bool,
    window_override: Optional[int] = None,
    collect_kv: bool = False,
    positions=None,
    starts=None,
):
    """Returns (x, aux_loss, kv_stack_or_None).

    ``positions``/``starts`` carry the per-request left-pad carve-out
    (serve/engine.py): attention-family layers offset RoPE per row and mask
    columns before each row's prompt start — on the Pallas kernel path as
    much as on XLA (starts ride scalar prefetch; below-start KV blocks are
    skipped).  Recurrent families sweep the sequence unconditionally, so
    the carve-out cannot apply there."""
    fam = cfg.family
    window = window_override if window_override is not None else cfg.sliding_window
    B, S, D = x.shape
    assert starts is None or fam in ("dense", "moe", "vlm"), (
        f"left-pad carve-out unsupported for family {fam}"
    )

    if fam in ("dense", "moe", "vlm", "encoder") and not _interleaved_moe(cfg):

        def body(carry, lp):
            h, aux = carry
            h, a, kv = BD.dense_layer_fwd(
                lp, h, cfg, causal=not cfg.is_encoder, sliding_window=window,
                positions=positions, starts=starts,
            )
            return (h, aux + a), (kv if collect_kv else None)

        body = _maybe_remat(body, cfg, train)
        (x, aux), kvs = jax.lax.scan(body, (x, jnp.float32(0.0)), params["layers"])
        return x, aux, kvs

    if _interleaved_moe(cfg):
        # llama4-style interleave: groups of (moe_every-1) dense layers
        # followed by one MoE layer; scan over groups
        me = cfg.moe_every
        n_groups = cfg.n_layers // me
        grp_dense = jax.tree.map(
            lambda t: t.reshape((n_groups, me - 1) + t.shape[1:]),
            params["layers"]["dense"],
        )

        def one(h, lp):
            h, a, kv = BD.dense_layer_fwd(
                lp, h, cfg, causal=True, sliding_window=window,
                positions=positions, starts=starts,
            )
            return h, (a, kv if collect_kv else None)

        def body(carry, lps):
            h, aux = carry
            lp_d, lp_m = lps
            h, (a_d, kv_d) = jax.lax.scan(one, h, lp_d)
            h, a_m, kv_m = BD.dense_layer_fwd(
                lp_m, h, cfg, causal=True, sliding_window=window,
                positions=positions, starts=starts,
            )
            ys = (kv_d, kv_m) if collect_kv else None
            return (h, aux + a_d.sum() + a_m), ys

        body = _maybe_remat(body, cfg, train)
        (x, aux), kvs = jax.lax.scan(
            body, (x, jnp.float32(0.0)), (grp_dense, params["layers"]["moe"])
        )
        if collect_kv:
            (kd, vd), (km, vm) = kvs  # kd: (g, me-1, B,S,K,hd); km: (g, B,S,K,hd)
            k_all = jnp.concatenate([kd, km[:, None]], axis=1).reshape(
                (cfg.n_layers,) + km.shape[1:]
            )
            v_all = jnp.concatenate([vd, vm[:, None]], axis=1).reshape(
                (cfg.n_layers,) + vm.shape[1:]
            )
            return x, aux, (k_all, v_all)
        return x, aux, None

    if fam == "ssm_mamba2":

        def body(carry, lp):
            h = carry
            if collect_kv:
                out, st = BM.mamba2_fwd(lp, h, cfg, return_state=True)
                return h + out, st
            return h + BM.mamba2_fwd(lp, h, cfg), None

        body = _maybe_remat(body, cfg, train)
        x, states = jax.lax.scan(body, x, params["layers"])
        return x, jnp.float32(0.0), states

    if fam == "ssm_rwkv6":

        def body(carry, lp):
            h = carry
            if collect_kv:
                h, st = BR.rwkv6_layer_fwd(lp, h, cfg, return_state=True)
                return h, st
            return BR.rwkv6_layer_fwd(lp, h, cfg), None

        body = _maybe_remat(body, cfg, train)
        x, states = jax.lax.scan(body, x, params["layers"])
        return x, jnp.float32(0.0), states

    if fam == "hybrid":
        shared = params["shared_attn"]
        every = cfg.attn_every
        n_inv = cfg.n_layers // every
        if collect_kv:
            ak0 = jnp.zeros((n_inv, B, S, cfg.n_kv_heads, cfg.head_dim), x.dtype)
            av0 = jnp.zeros_like(ak0)
        else:
            ak0 = av0 = jnp.zeros((1,), x.dtype)  # placeholder carry

        def body(carry, inp):
            h, ak, av = carry
            lp, idx = inp
            if collect_kv:
                out, st = BM.mamba2_fwd(lp, h, cfg, return_state=True)
                h = h + out
            else:
                h = h + BM.mamba2_fwd(lp, h, cfg)
                st = None

            def with_attn(args):
                h, ak, av = args
                hh, _, (k, v) = BD.dense_layer_fwd(
                    shared, h, cfg, causal=True, sliding_window=window
                )
                if collect_kv:
                    inv = idx // every
                    ak = jax.lax.dynamic_update_index_in_dim(ak, k.astype(ak.dtype), inv, 0)
                    av = jax.lax.dynamic_update_index_in_dim(av, v.astype(av.dtype), inv, 0)
                return hh, ak, av

            h, ak, av = jax.lax.cond(
                (idx + 1) % every == 0, with_attn, lambda a: a, (h, ak, av)
            )
            return (h, ak, av), st

        body = _maybe_remat(body, cfg, train)
        idxs = jnp.arange(cfg.n_layers)
        (x, ak, av), states = jax.lax.scan(
            body, (x, ak0, av0), (params["layers"], idxs)
        )
        if collect_kv:
            return x, jnp.float32(0.0), (states, (ak, av))
        return x, jnp.float32(0.0), states

    raise ValueError(fam)


# ---------------------------------------------------------------------------
# loss (chunked over sequence so (B, S, V) logits never materialize)
# ---------------------------------------------------------------------------


def _chunked_ce(params, hidden, targets, mask, cfg: ModelConfig, chunk: int = 512):
    B, S, D = hidden.shape
    head = params["lm_head"] if "lm_head" in params else params["embed"].T
    c = min(chunk, S)
    while S % c:  # e.g. VLM text length S - n_vision_tokens
        c //= 2
    c = max(c, 1)
    nc = S // c
    hs = hidden.reshape(B, nc, c, D).transpose(1, 0, 2, 3)
    ts = targets.reshape(B, nc, c).transpose(1, 0, 2)
    ms = mask.reshape(B, nc, c).transpose(1, 0, 2)

    def body(carry, inp):
        nll_sum, z_sum, n, correct = carry
        h, t, m = inp
        logits = (h @ head).astype(jnp.float32)  # (B, c, V)
        logits = constrain(logits, ("act_batch", "act_seq", "act_vocab"))
        logz = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits, t[..., None], axis=-1)[..., 0]
        nll = (logz - tgt) * m
        zl = jnp.square(logz) * m
        acc = (jnp.argmax(logits, -1) == t) * m
        return (
            nll_sum + nll.sum(),
            z_sum + zl.sum(),
            n + m.sum(),
            correct + acc.sum(),
        ), None

    init = (jnp.float32(0.0),) * 4
    (nll_sum, z_sum, n, correct), _ = jax.lax.scan(body, init, (hs, ts, ms))
    n = jnp.maximum(n, 1.0)
    return nll_sum / n, z_sum / n, correct / n


def loss_fn(params, batch, cfg: ModelConfig, *, window_override=None):
    x = embed_inputs(params, batch, cfg)
    x, aux, _ = backbone_fwd(
        params, x, cfg, train=True, window_override=window_override
    )
    x = L.apply_norm(params["final_norm"], x, cfg)
    if cfg.n_vision_tokens and "embeds" in batch:
        x = x[:, cfg.n_vision_tokens :, :]
    targets = batch["targets"]
    mask = batch.get("mask", jnp.ones_like(targets, jnp.float32))
    ce, zl, acc = _chunked_ce(params, x, targets, mask, cfg)
    loss = ce + 1e-4 * zl + aux
    return loss, {"ce": ce, "z_loss": zl, "acc": acc, "aux": aux}


def _pad_carveout(batch, S, cfg: ModelConfig):
    """(positions, starts) for a left-padded batch, or (None, None).
    ``batch['starts']`` (B,) marks each row's prompt start; positions are
    taken relative to it so RoPE matches the unpadded run.  Starts index
    the TOKEN grid, so a prepended vision prefix would shift every column
    the mask refers to — reject that combination instead of silently
    masking the wrong columns."""
    starts = batch.get("starts")
    if starts is None:
        return None, None
    assert not (cfg.n_vision_tokens and "embeds" in batch), (
        "left-pad carve-out indexes token columns; unsupported with a "
        "prepended vision prefix"
    )
    starts = jnp.asarray(starts, jnp.int32)
    return jnp.arange(S)[None, :] - starts[:, None], starts


def forward_logits(params, batch, cfg: ModelConfig, *, window_override=None):
    """Full logits (B, S, V) — small models / ABC ensembles only."""
    x = embed_inputs(params, batch, cfg)
    positions, starts = _pad_carveout(batch, x.shape[1], cfg)
    x, _, _ = backbone_fwd(
        params, x, cfg, train=False, window_override=window_override,
        positions=positions, starts=starts,
    )
    x = L.apply_norm(params["final_norm"], x, cfg)
    if cfg.n_vision_tokens and "embeds" in batch:
        x = x[:, cfg.n_vision_tokens :, :]
    head = params["lm_head"] if "lm_head" in params else params["embed"].T
    return (x @ head).astype(jnp.float32)


forward = forward_logits


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=None):
    """Boxed cache tree (Box carries the logical axes for sharding)."""
    from repro.models.layers import LEGACY_DECODE

    dtype = dtype or jnp.dtype(cfg.dtype)
    Lyr = cfg.n_layers
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        if LEGACY_DECODE:
            shape = (Lyr, batch, max_seq, cfg.n_kv_heads, cfg.head_dim)
            axes = ("layers", "kv_batch", "kv_seq", "cache_kv_heads", "head_dim")
        else:
            # kernel-native layout: sequence innermost (§Perf iteration 1)
            shape = (Lyr, batch, cfg.n_kv_heads, max_seq, cfg.head_dim)
            axes = ("layers", "kv_batch", "cache_kv_heads", "kv_seq", "head_dim")
        return {
            "k": Box(jnp.zeros(shape, dtype), axes),
            "v": Box(jnp.zeros(shape, dtype), axes),
        }
    if fam == "ssm_mamba2":
        d_in, nh, G, N, conv_dim, _ = BM._dims(cfg)
        return {
            "conv": Box(
                jnp.zeros((Lyr, batch, cfg.ssm_conv - 1, conv_dim), dtype),
                ("layers", "kv_batch", None, "ssm_inner"),
            ),
            "ssm": Box(
                jnp.zeros((Lyr, batch, nh, N, cfg.ssm_head_dim), jnp.float32),
                ("layers", "kv_batch", "ssm_heads", None, None),
            ),
        }
    if fam == "ssm_rwkv6":
        D = cfg.d_model
        H, hd = D // cfg.ssm_head_dim, cfg.ssm_head_dim
        return {
            "tm_x": Box(jnp.zeros((Lyr, batch, D), dtype), ("layers", "kv_batch", None)),
            "cm_x": Box(jnp.zeros((Lyr, batch, D), dtype), ("layers", "kv_batch", None)),
            "wkv": Box(
                jnp.zeros((Lyr, batch, H, hd, hd), jnp.float32),
                ("layers", "kv_batch", "ssm_heads", None, None),
            ),
        }
    if fam == "hybrid":
        d_in, nh, G, N, conv_dim, _ = BM._dims(cfg)
        n_inv = cfg.n_layers // cfg.attn_every
        # per-invocation caches as SEPARATE leaves (§Perf iteration 3): the
        # decode path then never dynamic-slices a whole (B,K,S,hd) slab out
        # of a stacked buffer — XLA materializes such slices as full copies
        if LEGACY_DECODE:
            kv_shape = (batch, max_seq, cfg.n_kv_heads, cfg.head_dim)
            kv_axes = ("kv_batch", "kv_seq", "cache_kv_heads", "head_dim")
        else:
            kv_shape = (batch, cfg.n_kv_heads, max_seq, cfg.head_dim)
            kv_axes = ("kv_batch", "cache_kv_heads", "kv_seq", "head_dim")
        return {
            "conv": Box(
                jnp.zeros((Lyr, batch, cfg.ssm_conv - 1, conv_dim), dtype),
                ("layers", "kv_batch", None, "ssm_inner"),
            ),
            "ssm": Box(
                jnp.zeros((Lyr, batch, nh, N, cfg.ssm_head_dim), jnp.float32),
                ("layers", "kv_batch", "ssm_heads", None, None),
            ),
            "attn_k": [Box(jnp.zeros(kv_shape, dtype), kv_axes) for _ in range(n_inv)],
            "attn_v": [Box(jnp.zeros(kv_shape, dtype), kv_axes) for _ in range(n_inv)],
        }
    raise ValueError(f"no cache for family {fam}")


# ---------------------------------------------------------------------------
# prefill
# ---------------------------------------------------------------------------


def prefill(params, batch, cfg: ModelConfig, *, window_override=None):
    """Forward the prompt, return (last-token logits (B, V), cache values).
    ``batch['starts']`` (B,), optional, activates the left-pad carve-out
    for attention families (per-row RoPE offset + pad masking)."""
    x = embed_inputs(params, batch, cfg)
    B, S, _ = x.shape
    positions, starts = _pad_carveout(batch, S, cfg)
    x, _, states = backbone_fwd(
        params, x, cfg, train=False, window_override=window_override,
        collect_kv=True, positions=positions, starts=starts,
    )
    xl = L.apply_norm(params["final_norm"], x[:, -1:, :], cfg)
    head = params["lm_head"] if "lm_head" in params else params["embed"].T
    logits = (xl[:, 0] @ head).astype(jnp.float32)
    logits = constrain(logits, ("act_batch", "act_vocab"))

    fam = cfg.family
    if cfg.is_encoder:
        return logits, None
    cache_axes = ("layers", "kv_batch", "cache_kv_heads", "kv_seq", "head_dim")
    if fam in ("dense", "moe", "vlm"):
        k, v = states  # (L, B, S, KVH, hd) -> kernel-native (L, B, KVH, S, hd)
        return logits, {
            "k": constrain(k.transpose(0, 1, 3, 2, 4), cache_axes),
            "v": constrain(v.transpose(0, 1, 3, 2, 4), cache_axes),
        }
    if fam in ("ssm_mamba2", "ssm_rwkv6"):
        return logits, states
    if fam == "hybrid":
        mamba_st, (ak, av) = states
        n_inv = cfg.n_layers // cfg.attn_every
        akt = ak.transpose(0, 1, 3, 2, 4)  # (n_inv, B, K, S, hd)
        avt = av.transpose(0, 1, 3, 2, 4)
        inv_axes = cache_axes[1:]
        return logits, {
            "conv": mamba_st["conv"],
            "ssm": mamba_st["ssm"],
            "attn_k": [constrain(akt[i], inv_axes) for i in range(n_inv)],
            "attn_v": [constrain(avt[i], inv_axes) for i in range(n_inv)],
        }
    raise ValueError(fam)


# ---------------------------------------------------------------------------
# decode step
# ---------------------------------------------------------------------------


def decode_step(
    params,
    token,
    cache,
    pos,
    cfg: ModelConfig,
    *,
    window_override=None,
    embeds=None,
    starts=None,
):
    """One new token with a KV/SSM cache.

    token: (B, 1) int32; pos: scalar int32 position of the new token;
    cache: values tree from ``init_cache``/``prefill``; starts: (B,)
    optional per-request prompt starts (left-pad carve-out — attention
    families only).  Returns (logits (B, V), new_cache)."""
    window = window_override if window_override is not None else cfg.sliding_window
    fam = cfg.family
    assert starts is None or fam in ("dense", "moe", "vlm"), (
        f"left-pad carve-out unsupported for family {fam}"
    )
    x = params["embed"][token]  # (B, 1, D)
    x = constrain(x, ("act_batch", None, "act_embed"))

    if fam in ("dense", "moe", "vlm") and not _interleaved_moe(cfg):

        def body(h, inp):
            lp, kc, vc = inp
            h, (kc, vc) = BD.dense_layer_decode(
                lp, h, cfg, kc, vc, pos, sliding_window=window, starts=starts
            )
            return h, (kc, vc)

        x, (k_new, v_new) = jax.lax.scan(
            body, x, (params["layers"], cache["k"], cache["v"])
        )
        new_cache = {"k": k_new, "v": v_new}

    elif _interleaved_moe(cfg):
        me = cfg.moe_every
        n_groups = cfg.n_layers // me
        grp_dense = jax.tree.map(
            lambda t: t.reshape((n_groups, me - 1) + t.shape[1:]),
            params["layers"]["dense"],
        )
        # cache layer order is [d × (me-1), m] per group
        grp_cache = jax.tree.map(
            lambda t: t.reshape((n_groups, me) + t.shape[1:]),
            {"k": cache["k"], "v": cache["v"]},
        )

        def one(h, inp):
            lp, kc, vc = inp
            h, (kc, vc) = BD.dense_layer_decode(
                lp, h, cfg, kc, vc, pos, sliding_window=window, starts=starts
            )
            return h, (kc, vc)

        def body(h, inp):
            lp_d, lp_m, cg = inp
            h, (kd, vd) = jax.lax.scan(
                one, h, (lp_d, cg["k"][: me - 1], cg["v"][: me - 1])
            )
            h, (km, vm) = BD.dense_layer_decode(
                lp_m, h, cfg, cg["k"][me - 1], cg["v"][me - 1], pos,
                sliding_window=window, starts=starts,
            )
            k_new = jnp.concatenate([kd, km[None]], axis=0)
            v_new = jnp.concatenate([vd, vm[None]], axis=0)
            return h, (k_new, v_new)

        x, (k_new, v_new) = jax.lax.scan(
            body, x, (grp_dense, params["layers"]["moe"], grp_cache)
        )
        new_cache = {
            "k": k_new.reshape((cfg.n_layers,) + k_new.shape[2:]),
            "v": v_new.reshape((cfg.n_layers,) + v_new.shape[2:]),
        }

    elif fam == "ssm_mamba2":

        def body(h, inp):
            lp, st = inp
            out, st = BM.mamba2_step(lp, h, cfg, st)
            return h + out, st

        x, states = jax.lax.scan(
            body, x, (params["layers"], {"conv": cache["conv"], "ssm": cache["ssm"]})
        )
        new_cache = states

    elif fam == "ssm_rwkv6":

        def body(h, inp):
            lp, st = inp
            out, st = BR.rwkv6_step(lp, h, cfg, st)
            return out, st

        x, states = jax.lax.scan(
            body,
            x,
            (
                params["layers"],
                {"tm_x": cache["tm_x"], "cm_x": cache["cm_x"], "wkv": cache["wkv"]},
            ),
        )
        new_cache = states

    elif fam == "hybrid" and L.LEGACY_DECODE:
        # pre-iteration-3 baseline path: stacked per-invocation caches with
        # cond + dynamic slab slice/update inside the layer scan
        shared = params["shared_attn"]
        every = cfg.attn_every
        ak0 = jnp.stack(cache["attn_k"])
        av0 = jnp.stack(cache["attn_v"])

        def body(carry, inp):
            h, ak, av = carry
            lp, st, idx = inp
            out, st = BM.mamba2_step(lp, h, cfg, st)
            h = h + out

            def with_attn(args):
                h, ak, av = args
                inv = idx // every
                kc = jax.lax.dynamic_index_in_dim(ak, inv, 0, keepdims=False)
                vc = jax.lax.dynamic_index_in_dim(av, inv, 0, keepdims=False)
                h, (kc, vc) = BD.dense_layer_decode(
                    shared, h, cfg, kc, vc, pos, sliding_window=window
                )
                ak = jax.lax.dynamic_update_index_in_dim(ak, kc, inv, 0)
                av = jax.lax.dynamic_update_index_in_dim(av, vc, inv, 0)
                return h, ak, av

            h, ak, av = jax.lax.cond(
                (idx + 1) % every == 0, with_attn, lambda a: a, (h, ak, av)
            )
            return (h, ak, av), st

        idxs = jnp.arange(cfg.n_layers)
        (x, ak, av), states = jax.lax.scan(
            body,
            (x, ak0, av0),
            (params["layers"], {"conv": cache["conv"], "ssm": cache["ssm"]}, idxs),
        )
        n_inv = cfg.n_layers // every
        new_cache = {
            "conv": states["conv"],
            "ssm": states["ssm"],
            "attn_k": [ak[i] for i in range(n_inv)],
            "attn_v": [av[i] for i in range(n_inv)],
        }

    elif fam == "hybrid":
        # §Perf iteration 3: group the scan by shared-attention invocation.
        # Mamba layers still scan (HLO depth-independent within a group);
        # the 9 shared-attention calls are a static python loop over the
        # per-invocation cache leaves — no cond, no slab slice/update of a
        # stacked cache buffer.
        shared = params["shared_attn"]
        every = cfg.attn_every
        n_inv = cfg.n_layers // every
        n_grouped = n_inv * every
        grp_params = jax.tree.map(
            lambda t: t[:n_grouped].reshape((n_inv, every) + t.shape[1:]),
            params["layers"],
        )
        grp_cache = jax.tree.map(
            lambda t: t[:n_grouped].reshape((n_inv, every) + t.shape[1:]),
            {"conv": cache["conv"], "ssm": cache["ssm"]},
        )

        def mamba_body(h, inp):
            lp, st = inp
            out, st = BM.mamba2_step(lp, h, cfg, st)
            return h + out, st

        new_states = []
        new_ak, new_av = [], []
        for g in range(n_inv):
            lp_g = jax.tree.map(lambda t: t[g], grp_params)
            st_g = jax.tree.map(lambda t: t[g], grp_cache)
            x, st_out = jax.lax.scan(mamba_body, x, (lp_g, st_g))
            x, (kc, vc) = BD.dense_layer_decode(
                shared, x, cfg, cache["attn_k"][g], cache["attn_v"][g], pos,
                sliding_window=window,
            )
            new_states.append(st_out)
            new_ak.append(kc)
            new_av.append(vc)

        if n_grouped < cfg.n_layers:  # trailing mamba layers (no attn after)
            lp_t = jax.tree.map(lambda t: t[n_grouped:], params["layers"])
            st_t = jax.tree.map(
                lambda t: t[n_grouped:], {"conv": cache["conv"], "ssm": cache["ssm"]}
            )
            x, st_out = jax.lax.scan(mamba_body, x, (lp_t, st_t))
            new_states.append(st_out)

        merged = jax.tree.map(
            lambda *xs: jnp.concatenate([t for t in xs], axis=0), *new_states
        )
        new_cache = {
            "conv": merged["conv"],
            "ssm": merged["ssm"],
            "attn_k": new_ak,
            "attn_v": new_av,
        }
    else:
        raise ValueError(f"decode unsupported for family {fam}")

    x = L.apply_norm(params["final_norm"], x, cfg)
    head = params["lm_head"] if "lm_head" in params else params["embed"].T
    logits = (x[:, 0] @ head).astype(jnp.float32)
    return constrain(logits, ("act_batch", "act_vocab")), new_cache


# ---------------------------------------------------------------------------
# slot-stream support: chunked prefill into one slot + per-slot state reset
# ---------------------------------------------------------------------------

# Constant-size recurrent state leaves (everything that is NOT pos-masked).
# Attention KV rows are protected by the per-slot pos mask, so a reused slot
# only ever sees rows it wrote itself; these leaves have no such mask and
# must be zeroed when a slot admits a new request.
_SLOT_STATE_KEYS = ("conv", "ssm", "tm_x", "cm_x", "wkv")


def has_slot_state(cfg: ModelConfig) -> bool:
    """True for families whose slot cache carries non-pos-masked state."""
    return cfg.family in ("ssm_mamba2", "ssm_rwkv6", "hybrid")


def reset_slot(cache, slot, cfg: ModelConfig):
    """Zero one slot's constant-state leaves (slot admission for SSM/RWKV
    and hybrid families).  ``cache`` is the canonical ``init_cache`` values
    tree (batch axis = slots, axis 1 of every stacked leaf); attention KV
    leaves are left untouched — the pos mask already isolates them."""
    if not has_slot_state(cfg):
        return cache
    out = dict(cache)
    for name in _SLOT_STATE_KEYS:
        if name in out and not isinstance(out[name], list):
            out[name] = out[name].at[:, slot].set(0)
    return out


def supports_chunked_prefill(cfg: ModelConfig) -> bool:
    """Chunked-prefill admission is available for every decode-capable
    family on the kernel-native cache layout (the legacy layout keeps
    decode-only admission as its baseline)."""
    from repro.models.layers import LEGACY_DECODE

    return (
        not cfg.is_encoder
        and not LEGACY_DECODE
        and cfg.family in ("dense", "moe", "vlm", "ssm_mamba2", "ssm_rwkv6", "hybrid")
    )


def supports_draft_verify(cfg: ModelConfig) -> bool:
    """Speculative draft verification needs (a) chunked prefill to score
    all draft positions in one pass and (b) a pos-masked attention cache so
    rejected draft rows can be rolled back by position alone.  Constant-state
    families fail (b): their recurrent state has absorbed the rejected
    tokens and there is no mask to hide them behind."""
    return supports_chunked_prefill(cfg) and not has_slot_state(cfg)


def prefill_into_slot(
    params,
    tokens,
    cache,
    slot,
    start,
    cfg: ModelConfig,
    *,
    window_override=None,
    return_hidden: bool = False,
):
    """Consume a C-token chunk of one slot's prompt into the slot cache.

    tokens: (C,) int32 — prompt positions [start, start+C); cache: the full
    stacked slot cache (``init_cache`` values, batch axis = slots); slot and
    start are traced scalars, so one jitted program serves every slot and
    offset, tracing once per chunk length C (the O(log S) bucket warmup).

    Attention families write K/V rows at the slot's offset; constant-state
    families thread the slot's recurrent state through the full-sequence
    block forwards (``initial=``/``state=`` continuation).  No logits are
    produced: the LAST prompt token is never chunked — it is fed through
    the shared decode program, whose logits sample the first output token,
    which is what makes chunked and decode-only admission token-identical.

    MoE caveat: ``apply_moe``'s capacity depends on tokens-per-call, so in
    a capacity-LIMITED regime a C-token chunk can drop tokens that
    per-token decode admission would keep (exactly as the batch prefill
    path already differs from decode).  The token-for-token equivalence
    contract therefore holds whenever no capacity drops occur — e.g.
    ``capacity_factor >= n_experts`` guarantees it (tests/test_slot_stream
    pins this); serve capacity-tight MoE with ``chunked_prefill=False`` if
    bitwise admission parity matters more than admission latency.

    Returns the updated cache; with ``return_hidden=True`` (attention
    families only — the speculative verify pass, see
    ``supports_draft_verify``) returns ``(hidden (1, C, D), cache)`` so the
    caller can project per-position logits over the chunk."""
    window = window_override if window_override is not None else cfg.sliding_window
    fam = cfg.family
    slot = jnp.asarray(slot)
    start = jnp.asarray(start)
    x = params["embed"][tokens][None, :, :]  # (1, C, D)
    x = constrain(x, ("act_batch", None, "act_embed"))

    def take(t):  # slot row of a stacked (L, n_slots, ...) leaf, keepdims
        return jax.lax.dynamic_index_in_dim(t, slot, 1, keepdims=True)

    def put(full, part):
        return jax.lax.dynamic_update_index_in_dim(full, part, slot, 1)

    if fam in ("dense", "moe", "vlm") and not _interleaved_moe(cfg):

        def body(h, inp):
            lp, kc, vc = inp
            h, (kc, vc) = BD.dense_layer_prefill_chunk(
                lp, h, cfg, kc, vc, start, sliding_window=window
            )
            return h, (kc, vc)

        h, (k_new, v_new) = jax.lax.scan(
            body, x, (params["layers"], take(cache["k"]), take(cache["v"]))
        )
        new_cache = {"k": put(cache["k"], k_new), "v": put(cache["v"], v_new)}
        return (h, new_cache) if return_hidden else new_cache

    if _interleaved_moe(cfg):
        me = cfg.moe_every
        n_groups = cfg.n_layers // me
        grp_dense = jax.tree.map(
            lambda t: t.reshape((n_groups, me - 1) + t.shape[1:]),
            params["layers"]["dense"],
        )
        grp_cache = jax.tree.map(
            lambda t: t.reshape((n_groups, me) + t.shape[1:]),
            {"k": take(cache["k"]), "v": take(cache["v"])},
        )

        def one(h, inp):
            lp, kc, vc = inp
            h, (kc, vc) = BD.dense_layer_prefill_chunk(
                lp, h, cfg, kc, vc, start, sliding_window=window
            )
            return h, (kc, vc)

        def body(h, inp):
            lp_d, lp_m, cg = inp
            h, (kd, vd) = jax.lax.scan(
                one, h, (lp_d, cg["k"][: me - 1], cg["v"][: me - 1])
            )
            h, (km, vm) = BD.dense_layer_prefill_chunk(
                lp_m, h, cfg, cg["k"][me - 1], cg["v"][me - 1], start,
                sliding_window=window,
            )
            k_new = jnp.concatenate([kd, km[None]], axis=0)
            v_new = jnp.concatenate([vd, vm[None]], axis=0)
            return h, (k_new, v_new)

        h, (k_new, v_new) = jax.lax.scan(
            body, x, (grp_dense, params["layers"]["moe"], grp_cache)
        )
        new_cache = {
            "k": put(cache["k"], k_new.reshape((cfg.n_layers,) + k_new.shape[2:])),
            "v": put(cache["v"], v_new.reshape((cfg.n_layers,) + v_new.shape[2:])),
        }
        return (h, new_cache) if return_hidden else new_cache

    if return_hidden:  # constant-state families cannot roll a verify back
        raise ValueError(f"return_hidden unsupported for family {fam}")

    if fam == "ssm_mamba2":

        def body(h, inp):
            lp, st = inp
            out, st = BM.mamba2_fwd(lp, h, cfg, initial=st, return_state=True)
            return h + out, st

        _, states = jax.lax.scan(
            body, x, (params["layers"],
                      {"conv": take(cache["conv"]), "ssm": take(cache["ssm"])})
        )
        return {"conv": put(cache["conv"], states["conv"]),
                "ssm": put(cache["ssm"], states["ssm"])}

    if fam == "ssm_rwkv6":

        def body(h, inp):
            lp, st = inp
            h, st = BR.rwkv6_layer_fwd(lp, h, cfg, state=st, return_state=True)
            return h, st

        _, states = jax.lax.scan(
            body,
            x,
            (
                params["layers"],
                {"tm_x": take(cache["tm_x"]), "cm_x": take(cache["cm_x"]),
                 "wkv": take(cache["wkv"])},
            ),
        )
        return {k: put(cache[k], states[k]) for k in ("tm_x", "cm_x", "wkv")}

    if fam == "hybrid":
        # mirror the grouped decode path (§Perf iteration 3): scan the mamba
        # chunk-forward within each shared-attention group, then one chunk
        # attention over the group's per-invocation slot rows
        shared = params["shared_attn"]
        every = cfg.attn_every
        n_inv = cfg.n_layers // every
        n_grouped = n_inv * every
        grp_params = jax.tree.map(
            lambda t: t[:n_grouped].reshape((n_inv, every) + t.shape[1:]),
            params["layers"],
        )
        grp_state = jax.tree.map(
            lambda t: t[:n_grouped].reshape((n_inv, every) + t.shape[1:]),
            {"conv": take(cache["conv"]), "ssm": take(cache["ssm"])},
        )

        def mamba_body(h, inp):
            lp, st = inp
            out, st = BM.mamba2_fwd(lp, h, cfg, initial=st, return_state=True)
            return h + out, st

        def take0(t):  # per-invocation (n_slots, KVH, S, hd) leaves
            return jax.lax.dynamic_index_in_dim(t, slot, 0, keepdims=True)

        new_states = []
        new_ak, new_av = [], []
        for g in range(n_inv):
            lp_g = jax.tree.map(lambda t: t[g], grp_params)
            st_g = jax.tree.map(lambda t: t[g], grp_state)
            x, st_out = jax.lax.scan(mamba_body, x, (lp_g, st_g))
            x, (kc, vc) = BD.dense_layer_prefill_chunk(
                shared, x, cfg,
                take0(cache["attn_k"][g]), take0(cache["attn_v"][g]), start,
                sliding_window=window,
            )
            new_states.append(st_out)
            new_ak.append(kc)
            new_av.append(vc)

        if n_grouped < cfg.n_layers:  # trailing mamba layers (no attn after)
            lp_t = jax.tree.map(lambda t: t[n_grouped:], params["layers"])
            st_t = {"conv": take(cache["conv"])[n_grouped:],
                    "ssm": take(cache["ssm"])[n_grouped:]}
            x, st_out = jax.lax.scan(mamba_body, x, (lp_t, st_t))
            new_states.append(st_out)

        merged = jax.tree.map(
            lambda *xs: jnp.concatenate([t for t in xs], axis=0), *new_states
        )
        return {
            "conv": put(cache["conv"], merged["conv"]),
            "ssm": put(cache["ssm"], merged["ssm"]),
            "attn_k": [
                jax.lax.dynamic_update_index_in_dim(cache["attn_k"][g], new_ak[g], slot, 0)
                for g in range(n_inv)
            ],
            "attn_v": [
                jax.lax.dynamic_update_index_in_dim(cache["attn_v"][g], new_av[g], slot, 0)
                for g in range(n_inv)
            ],
        }

    raise ValueError(f"chunked prefill unsupported for family {fam}")


# ---------------------------------------------------------------------------
# block-paged serving: KV pool + page-table decode / chunked prefill
# ---------------------------------------------------------------------------


def supports_paging(cfg: ModelConfig) -> bool:
    """Block-paged KV pools serve the attention-cache families on the
    kernel-native layout.  Constant-state families (SSM/RWKV) have O(1)
    per-slot state — there is nothing to page — and hybrid's per-invocation
    KV leaves keep the dense slot layout for now."""
    from repro.models.layers import LEGACY_DECODE

    return (
        not cfg.is_encoder
        and not LEGACY_DECODE
        and cfg.family in ("dense", "moe", "vlm")
    )


def init_paged_pool(cfg: ModelConfig, n_pages: int, page_size: int, dtype=None):
    """Boxed paged KV pool: per leaf ``(L, n_pages, KVH, page_size, hd)``.

    The page axis replaces the dense cache's (batch, seq) product — HBM is
    bound by pages actually mapped, not slots x max_seq.  Page contents keep
    the kernel-native (KVH, seq, hd) tile layout, so a gathered slot view is
    bitwise the dense cache row."""
    assert supports_paging(cfg), cfg.family
    dtype = dtype or jnp.dtype(cfg.dtype)
    shape = (cfg.n_layers, n_pages, cfg.n_kv_heads, page_size, cfg.head_dim)
    axes = ("layers", None, "cache_kv_heads", "kv_seq", "head_dim")
    return {
        "k": Box(jnp.zeros(shape, dtype), axes),
        "v": Box(jnp.zeros(shape, dtype), axes),
    }


def copy_pool_page(pool, src, dst):
    """Device half of a copy-on-write split: copy page ``src`` to ``dst``
    on every leaf (and every layer / tier-member plane).  The page axis is
    located from the trailing (P, KVH, page_size, hd) layout, so the same
    program serves engine pools and E-stacked tier pools."""

    def cp(t):
        ax = t.ndim - 4
        row = jax.lax.dynamic_index_in_dim(t, src, ax, keepdims=True)
        return jax.lax.dynamic_update_index_in_dim(t, row, dst, ax)

    return jax.tree.map(cp, pool)


def decode_step_paged(
    params,
    token,
    pool,
    pos,
    pages,
    cfg: ModelConfig,
    *,
    window_override=None,
):
    """One decode token per slot against the block-paged KV pool.

    token: (B, 1) int32; pos: (B,) per-slot positions; pages: (B, n_pg)
    int32 page table (-1 = unmapped); pool: values tree from
    ``init_paged_pool``.  Each layer scatters the new K/V row into the
    slot's current page and attends over the gathered page view — bitwise
    what the dense slot cache computes (see serve/paging.py).  Returns
    (logits (B, V), new_pool)."""
    window = window_override if window_override is not None else cfg.sliding_window
    assert supports_paging(cfg), cfg.family
    x = params["embed"][token]  # (B, 1, D)
    x = constrain(x, ("act_batch", None, "act_embed"))

    if not _interleaved_moe(cfg):

        def body(h, inp):
            lp, kp, vp = inp
            h, (kp, vp) = BD.dense_layer_decode_paged(
                lp, h, cfg, kp, vp, pos, pages, sliding_window=window
            )
            return h, (kp, vp)

        x, (k_new, v_new) = jax.lax.scan(
            body, x, (params["layers"], pool["k"], pool["v"])
        )
        new_pool = {"k": k_new, "v": v_new}
    else:
        me = cfg.moe_every
        n_groups = cfg.n_layers // me
        grp_dense = jax.tree.map(
            lambda t: t.reshape((n_groups, me - 1) + t.shape[1:]),
            params["layers"]["dense"],
        )
        grp_pool = jax.tree.map(
            lambda t: t.reshape((n_groups, me) + t.shape[1:]),
            {"k": pool["k"], "v": pool["v"]},
        )

        def one(h, inp):
            lp, kp, vp = inp
            h, (kp, vp) = BD.dense_layer_decode_paged(
                lp, h, cfg, kp, vp, pos, pages, sliding_window=window
            )
            return h, (kp, vp)

        def body(h, inp):
            lp_d, lp_m, pg = inp
            h, (kd, vd) = jax.lax.scan(
                one, h, (lp_d, pg["k"][: me - 1], pg["v"][: me - 1])
            )
            h, (km, vm) = BD.dense_layer_decode_paged(
                lp_m, h, cfg, pg["k"][me - 1], pg["v"][me - 1], pos, pages,
                sliding_window=window,
            )
            k_new = jnp.concatenate([kd, km[None]], axis=0)
            v_new = jnp.concatenate([vd, vm[None]], axis=0)
            return h, (k_new, v_new)

        x, (k_new, v_new) = jax.lax.scan(
            body, x, (grp_dense, params["layers"]["moe"], grp_pool)
        )
        new_pool = {
            "k": k_new.reshape((cfg.n_layers,) + k_new.shape[2:]),
            "v": v_new.reshape((cfg.n_layers,) + v_new.shape[2:]),
        }

    x = L.apply_norm(params["final_norm"], x, cfg)
    head = params["lm_head"] if "lm_head" in params else params["embed"].T
    logits = (x[:, 0] @ head).astype(jnp.float32)
    return constrain(logits, ("act_batch", "act_vocab")), new_pool


def prefill_into_slot_paged(
    params,
    tokens,
    pool,
    pages_row,
    start,
    cfg: ModelConfig,
    *,
    window_override=None,
    return_hidden: bool = False,
):
    """Paged counterpart of ``prefill_into_slot``: consume a C-token chunk
    of one slot's prompt into the pool pages its table row maps.

    tokens: (C,) int32 for positions [start, start+C); pages_row: (n_pg,)
    the slot's page-table row; start is a traced scalar.  Shared-prefix
    admission skips chunks for the shared span, so ``start`` begins at the
    first unshared position.  Returns the updated pool; with
    ``return_hidden=True`` returns ``(hidden (1, C, D), pool)`` for the
    speculative verify pass."""
    window = window_override if window_override is not None else cfg.sliding_window
    assert supports_paging(cfg), cfg.family
    start = jnp.asarray(start)
    x = params["embed"][tokens][None, :, :]  # (1, C, D)
    x = constrain(x, ("act_batch", None, "act_embed"))

    if not _interleaved_moe(cfg):

        def body(h, inp):
            lp, kp, vp = inp
            h, (kp, vp) = BD.dense_layer_prefill_chunk_paged(
                lp, h, cfg, kp, vp, start, pages_row, sliding_window=window
            )
            return h, (kp, vp)

        h, (k_new, v_new) = jax.lax.scan(
            body, x, (params["layers"], pool["k"], pool["v"])
        )
        new_pool = {"k": k_new, "v": v_new}
        return (h, new_pool) if return_hidden else new_pool

    me = cfg.moe_every
    n_groups = cfg.n_layers // me
    grp_dense = jax.tree.map(
        lambda t: t.reshape((n_groups, me - 1) + t.shape[1:]),
        params["layers"]["dense"],
    )
    grp_pool = jax.tree.map(
        lambda t: t.reshape((n_groups, me) + t.shape[1:]),
        {"k": pool["k"], "v": pool["v"]},
    )

    def one(h, inp):
        lp, kp, vp = inp
        h, (kp, vp) = BD.dense_layer_prefill_chunk_paged(
            lp, h, cfg, kp, vp, start, pages_row, sliding_window=window
        )
        return h, (kp, vp)

    def body(h, inp):
        lp_d, lp_m, pg = inp
        h, (kd, vd) = jax.lax.scan(
            one, h, (lp_d, pg["k"][: me - 1], pg["v"][: me - 1])
        )
        h, (km, vm) = BD.dense_layer_prefill_chunk_paged(
            lp_m, h, cfg, pg["k"][me - 1], pg["v"][me - 1], start, pages_row,
            sliding_window=window,
        )
        k_new = jnp.concatenate([kd, km[None]], axis=0)
        v_new = jnp.concatenate([vd, vm[None]], axis=0)
        return h, (k_new, v_new)

    h, (k_new, v_new) = jax.lax.scan(
        body, x, (grp_dense, params["layers"]["moe"], grp_pool)
    )
    new_pool = {
        "k": k_new.reshape((cfg.n_layers,) + k_new.shape[2:]),
        "v": v_new.reshape((cfg.n_layers,) + v_new.shape[2:]),
    }
    return (h, new_pool) if return_hidden else new_pool


def prefill_into_slot_logits(
    params, tokens, cache, slot, start, cfg: ModelConfig, *, window_override=None
):
    """Chunked prefill that ALSO scores every chunk position: returns
    ``(logits (C, V) f32, cache)`` where ``logits[j]`` is the next-token
    distribution after prompt position ``start + j``.  This is the
    speculative verify pass (serve/speculative.py): feeding the token
    before each draft position yields, in one chunk, the model's own
    choice at every draft position — numerically the decode head, since
    chunked prefill and decode share the attention math
    (``layers._chunk_attend``) and the head projection
    (``layers.project_logits``)."""
    assert supports_draft_verify(cfg), cfg.family
    h, cache = prefill_into_slot(
        params, tokens, cache, slot, start, cfg,
        window_override=window_override, return_hidden=True,
    )
    return L.project_logits(params, h, cfg)[0], cache


def prefill_into_slot_paged_logits(
    params, tokens, pool, pages_row, start, cfg: ModelConfig, *, window_override=None
):
    """Paged twin of ``prefill_into_slot_logits``: ``(logits (C, V), pool)``."""
    assert supports_draft_verify(cfg), cfg.family
    h, pool = prefill_into_slot_paged(
        params, tokens, pool, pages_row, start, cfg,
        window_override=window_override, return_hidden=True,
    )
    return L.project_logits(params, h, cfg)[0], pool


# ---------------------------------------------------------------------------
# inputs: ShapeDtypeStruct specs (dry-run) and concrete arrays (smoke)
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape: ShapeConfig):
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    B, S = shape.global_batch, shape.seq_len
    f32, i32 = jnp.float32, jnp.int32
    bf = jnp.dtype(cfg.dtype)
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        if cfg.is_encoder:
            return {
                "embeds": sds((B, S, cfg.frontend_dim), bf),
                "targets": sds((B, S), i32),
                "mask": sds((B, S), f32),
            }
        if cfg.n_vision_tokens:
            St = S - cfg.n_vision_tokens
            return {
                "tokens": sds((B, St), i32),
                "embeds": sds((B, cfg.n_vision_tokens, cfg.frontend_dim), bf),
                "targets": sds((B, St), i32),
                "mask": sds((B, St), f32),
            }
        return {
            "tokens": sds((B, S), i32),
            "targets": sds((B, S), i32),
            "mask": sds((B, S), f32),
        }
    if shape.kind == "prefill":
        if cfg.is_encoder:
            return {"embeds": sds((B, S, cfg.frontend_dim), bf)}
        if cfg.n_vision_tokens:
            St = S - cfg.n_vision_tokens
            return {
                "tokens": sds((B, St), i32),
                "embeds": sds((B, cfg.n_vision_tokens, cfg.frontend_dim), bf),
            }
        return {"tokens": sds((B, S), i32)}
    if shape.kind == "decode":
        return {"token": sds((B, 1), i32), "pos": sds((), i32)}
    raise ValueError(shape.kind)


def make_inputs(cfg: ModelConfig, shape: ShapeConfig, rng=None):
    """Concrete random inputs matching input_specs (smoke tests)."""
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    specs = input_specs(cfg, shape)
    out = {}
    for name, s in specs.items():
        rng, k = jax.random.split(rng)
        if s.dtype == jnp.int32 and name in ("tokens", "targets", "token"):
            out[name] = jax.random.randint(k, s.shape, 0, cfg.vocab_size, jnp.int32)
        elif s.dtype == jnp.int32:
            out[name] = jnp.zeros(s.shape, jnp.int32)
        elif name == "mask":
            out[name] = jnp.ones(s.shape, s.dtype)
        else:
            out[name] = jax.random.normal(k, s.shape, jnp.float32).astype(s.dtype)
    return out
