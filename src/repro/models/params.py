"""Boxed parameters: every leaf carries its logical sharding axes.

:class:`Box` is registered as a pytree node whose ``axes`` are static aux
data, so boxed trees flow through ``jax.eval_shape`` / ``vmap`` untouched —
this is what lets the dry-run derive full-size parameter shapes + shardings
without materializing a single weight.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_pytree_node_class
class Box:
    """An array leaf paired with its logical sharding axes."""

    __slots__ = ("value", "axes")

    def __init__(self, value, axes: Tuple[Optional[str], ...]):
        self.value = value
        self.axes = tuple(axes)

    def tree_flatten(self):
        return (self.value,), self.axes

    @classmethod
    def tree_unflatten(cls, axes, children):
        return cls(children[0], axes)

    def __repr__(self):
        shape = getattr(self.value, "shape", None)
        return f"Box(shape={shape}, axes={self.axes})"


def is_box(x) -> bool:
    return isinstance(x, Box)


def unbox(tree):
    """Split a boxed tree into (values, axes) trees of identical structure."""
    values = jax.tree.map(lambda b: b.value, tree, is_leaf=is_box)
    axes = jax.tree.map(lambda b: b.axes, tree, is_leaf=is_box)
    return values, axes


def box_like(values, axes_tree):
    """Re-pair a values tree with an axes tree (inverse of :func:`unbox`)."""
    leaves_v, treedef = jax.tree.flatten(values)
    leaves_a = treedef.flatten_up_to(axes_tree)
    return treedef.unflatten([Box(v, a) for v, a in zip(leaves_v, leaves_a)])


class Initializer:
    """Sequential PRNG splitter used by the layer init functions."""

    def __init__(self, rng: jax.Array, dtype):
        self._rng = rng
        self.dtype = jnp.dtype(dtype)

    def _next(self) -> jax.Array:
        self._rng, sub = jax.random.split(self._rng)
        return sub

    def normal(self, shape, axes, *, std: Optional[float] = None, dtype=None):
        if std is None:
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            std = 1.0 / math.sqrt(fan_in)
        v = jax.random.normal(self._next(), shape, dtype=jnp.float32) * std
        return Box(v.astype(dtype or self.dtype), tuple(axes))

    def zeros(self, shape, axes, dtype=None):
        return Box(jnp.zeros(shape, dtype=dtype or self.dtype), tuple(axes))

    def ones(self, shape, axes, dtype=None):
        return Box(jnp.ones(shape, dtype=dtype or self.dtype), tuple(axes))

    def const(self, value, axes, dtype=None):
        v = jnp.asarray(value, dtype=dtype or self.dtype)
        return Box(v, tuple(axes))


def stack_layers(init_one, n_layers: int, rng: jax.Array):
    """Initialize ``n_layers`` layers via vmap and prepend a 'layers' logical
    axis to every leaf (for ``lax.scan`` over depth)."""
    keys = jax.random.split(rng, n_layers)
    stacked = jax.vmap(init_one)(keys)
    return jax.tree.map(
        lambda b: Box(b.value, ("layers",) + b.axes), stacked, is_leaf=is_box
    )


def param_count(values_tree) -> int:
    return int(sum(np.prod(v.shape) for v in jax.tree.leaves(values_tree)))


def param_bytes(values_tree) -> int:
    return int(
        sum(
            np.prod(v.shape) * jnp.dtype(v.dtype).itemsize
            for v in jax.tree.leaves(values_tree)
        )
    )
