"""Model zoo: the 6 assigned architecture families, pure-JAX functional."""
from repro.models.api import (
    init_params,
    forward,
    loss_fn,
    init_cache,
    prefill,
    decode_step,
    input_specs,
    make_inputs,
)

__all__ = [
    "init_params",
    "forward",
    "loss_fn",
    "init_cache",
    "prefill",
    "decode_step",
    "input_specs",
    "make_inputs",
]
