"""Transformer layer (dense / MoE / encoder flavors) with train + decode paths."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.params import Initializer
from repro.sharding.logical import constrain


def init_dense_layer(ini: Initializer, cfg: ModelConfig, *, moe: bool):
    p = {
        "ln1": L.init_norm(ini, cfg, cfg.d_model),
        "attn": L.init_attention(ini, cfg),
        "ln2": L.init_norm(ini, cfg, cfg.d_model),
    }
    if moe:
        p["moe"] = L.init_moe(ini, cfg)
    else:
        p["mlp"] = L.init_mlp(ini, cfg)
    return p


def dense_layer_fwd(
    p,
    x,
    cfg: ModelConfig,
    *,
    causal: bool = True,
    sliding_window: Optional[int] = None,
    positions=None,
    starts=None,
):
    """Full-sequence forward.  Returns (x, aux_loss, (k, v))."""
    h, kv = L.attention_layer(
        p["attn"],
        L.apply_norm(p["ln1"], x, cfg),
        cfg,
        causal=causal,
        positions=positions,
        sliding_window=sliding_window,
        starts=starts,
    )
    x = x + h
    aux = jnp.float32(0.0)
    if "moe" in p:
        h, aux = L.apply_moe(p["moe"], L.apply_norm(p["ln2"], x, cfg), cfg)
    else:
        h = L.apply_mlp(p["mlp"], L.apply_norm(p["ln2"], x, cfg), cfg)
    x = x + h
    x = constrain(x, ("act_batch", "act_seq", "act_embed"))
    return x, aux, kv


def dense_layer_prefill_chunk(
    p,
    x,
    cfg: ModelConfig,
    k_cache,
    v_cache,
    start,
    *,
    sliding_window: Optional[int] = None,
):
    """Chunked-prefill for one slot row.  x: (1, C, D); caches are the
    slot's (1, KVH, S_max, hd) rows; ``start`` the chunk's first absolute
    position.  Returns (x, (k_cache, v_cache))."""
    h, caches = L.attention_prefill_chunk(
        p["attn"],
        L.apply_norm(p["ln1"], x, cfg),
        cfg,
        k_cache,
        v_cache,
        start,
        sliding_window=sliding_window,
    )
    x = x + h
    if "moe" in p:
        h, _ = L.apply_moe(p["moe"], L.apply_norm(p["ln2"], x, cfg), cfg)
    else:
        h = L.apply_mlp(p["mlp"], L.apply_norm(p["ln2"], x, cfg), cfg)
    return x + h, caches


def dense_layer_prefill_chunk_paged(
    p,
    x,
    cfg: ModelConfig,
    k_pool,
    v_pool,
    start,
    pages_row,
    *,
    sliding_window: Optional[int] = None,
):
    """Chunked-prefill for one slot against block-paged pools.  x: (1, C, D);
    pools are (P, KVH, page_size, hd); ``pages_row`` the slot's (n_pg,)
    page-table row.  Returns (x, (k_pool, v_pool))."""
    h, pools = L.attention_prefill_chunk_paged(
        p["attn"],
        L.apply_norm(p["ln1"], x, cfg),
        cfg,
        k_pool,
        v_pool,
        start,
        pages_row,
        sliding_window=sliding_window,
    )
    x = x + h
    if "moe" in p:
        h, _ = L.apply_moe(p["moe"], L.apply_norm(p["ln2"], x, cfg), cfg)
    else:
        h = L.apply_mlp(p["mlp"], L.apply_norm(p["ln2"], x, cfg), cfg)
    return x + h, pools


def dense_layer_decode_paged(
    p,
    x,
    cfg: ModelConfig,
    k_pool,
    v_pool,
    cur_index,
    pages,
    *,
    sliding_window: Optional[int] = None,
):
    """Single-token decode against block-paged pools.  x: (B, 1, D);
    ``pages`` the (B, n_pg) page table.  Returns (x, (k_pool, v_pool))."""
    h, pools = L.attention_decode_paged(
        p["attn"],
        L.apply_norm(p["ln1"], x, cfg),
        cfg,
        k_pool,
        v_pool,
        cur_index,
        pages,
        sliding_window=sliding_window,
    )
    x = x + h
    if "moe" in p:
        h, _ = L.apply_moe(p["moe"], L.apply_norm(p["ln2"], x, cfg), cfg)
    else:
        h = L.apply_mlp(p["mlp"], L.apply_norm(p["ln2"], x, cfg), cfg)
    return x + h, pools


def dense_layer_decode(
    p,
    x,
    cfg: ModelConfig,
    k_cache,
    v_cache,
    cur_index,
    *,
    sliding_window: Optional[int] = None,
    starts=None,
):
    """Single-token decode.  x: (B, 1, D).  Returns (x, (k_cache, v_cache))."""
    h, caches = L.attention_decode(
        p["attn"],
        L.apply_norm(p["ln1"], x, cfg),
        cfg,
        k_cache,
        v_cache,
        cur_index,
        sliding_window=sliding_window,
        starts=starts,
    )
    x = x + h
    if "moe" in p:
        h, _ = L.apply_moe(p["moe"], L.apply_norm(p["ln2"], x, cfg), cfg)
    else:
        h = L.apply_mlp(p["mlp"], L.apply_norm(p["ln2"], x, cfg), cfg)
    return x + h, caches
