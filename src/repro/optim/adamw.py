"""AdamW with global-norm clipping; optional bf16 moments for the >=100B
configs (documented in DESIGN.md §7 — keeps the train_4k dry-run inside
16 GB/chip v5e HBM)."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    moment_dtype: str = "float32"  # 'bfloat16' for low-mem variant


def adamw_init(params, cfg: OptimConfig):
    mdt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    )


def adamw_update(grads, state, params, cfg: OptimConfig, lr_scale=1.0):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)

    count = state["count"] + 1
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)
    lr = cfg.lr * lr_scale
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(g, m, v, p):
        gf = g.astype(jnp.float32)
        mf = m.astype(jnp.float32) * cfg.b1 + gf * (1 - cfg.b1)
        vf = v.astype(jnp.float32) * cfg.b2 + jnp.square(gf) * (1 - cfg.b2)
        step = (mf / b1c) / (jnp.sqrt(vf / b2c) + cfg.eps)
        step = step + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * step
        return newp.astype(p.dtype), mf.astype(mdt), vf.astype(mdt)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return (
        new_p,
        {"m": new_m, "v": new_v, "count": count},
        {"grad_norm": gnorm, "lr": jnp.float32(lr)},
    )
