from repro.optim.adamw import adamw_init, adamw_update, OptimConfig
from repro.optim.schedule import cosine_schedule, linear_warmup

__all__ = [
    "adamw_init",
    "adamw_update",
    "OptimConfig",
    "cosine_schedule",
    "linear_warmup",
]
