from repro.data.pipeline import TokenDataset, batches, make_lm_batch
from repro.data.synthetic import MixtureTask, sequence_task

__all__ = ["TokenDataset", "batches", "make_lm_batch", "MixtureTask", "sequence_task"]
