"""Host-side data pipeline: deterministic sharded batching + LM packing."""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import numpy as np


@dataclasses.dataclass
class TokenDataset:
    """In-memory token corpus (rows of equal length)."""

    tokens: np.ndarray  # (N, S+1) int32

    def __len__(self):
        return len(self.tokens)


def make_lm_batch(rows: np.ndarray) -> dict:
    """Next-token prediction: inputs rows[:, :-1], targets rows[:, 1:]."""
    return {
        "tokens": rows[:, :-1].astype(np.int32),
        "targets": rows[:, 1:].astype(np.int32),
        "mask": np.ones_like(rows[:, 1:], np.float32),
    }


def batches(
    ds: TokenDataset,
    batch_size: int,
    *,
    seed: int = 0,
    epochs: Optional[int] = None,
    host_id: int = 0,
    host_count: int = 1,
) -> Iterator[dict]:
    """Shuffled epochs, sharded across hosts by interleaving (each host sees
    rows where (index % host_count) == host_id) — the standard multi-host
    input pipeline contract for pjit: every host feeds its local slice of the
    global batch."""
    rng = np.random.default_rng(seed)
    epoch = 0
    while epochs is None or epoch < epochs:
        order = rng.permutation(len(ds))
        local = order[host_id::host_count]
        per_host = batch_size // host_count
        for i in range(0, len(local) - per_host + 1, per_host):
            rows = ds.tokens[local[i : i + per_host]]
            yield make_lm_batch(rows)
        epoch += 1
