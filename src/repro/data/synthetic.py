"""Synthetic tasks with controllable easy/hard structure.

ABC's premise is that a sizable subset of inference data is 'easy' — solvable
by small models.  Offline (no external datasets), we generate tasks where
that structure is explicit and tunable, so the paper's claims (selection
rates, drop-in accuracy, Fig. 2/3/7 shapes) are checkable quantitatively:

* :class:`MixtureTask` — classification over token sequences.  'Easy'
  examples reveal the label through a dedicated marker token at the read
  position (any small model learns it in ~100 steps); 'hard' examples hide
  it in a bag-of-tokens linear feature over the whole sequence that needs
  far more capacity/steps.  Calibrated so a small ensemble is accurate and
  *in agreement* exactly on the easy subset — the structure ABC exploits.

* :func:`sequence_task` — next-token LM data over a Markov chain with
  per-position entropy spikes, used by the end-to-end training driver.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class MixtureTask:
    vocab: int = 256
    n_classes: int = 16
    seq_len: int = 64
    easy_frac: float = 0.6
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # marker ids are exclusive (regular tokens never collide with them)
        self.markers = np.arange(self.n_classes, 2 * self.n_classes)
        self.w = rng.normal(0, 1, (self.vocab, self.n_classes))

    def sample(self, n: int, seed: int = 1):
        rng = np.random.default_rng(seed)
        lo = 2 * self.n_classes
        toks = rng.integers(lo, self.vocab, (n, self.seq_len))
        feats = np.zeros((n, self.vocab))
        np.add.at(feats, (np.arange(n)[:, None], toks), 1.0)
        labels = np.argmax(feats @ self.w + rng.gumbel(0, 0.5, (n, self.n_classes)), -1)
        easy = rng.random(n) < self.easy_frac
        toks[easy, -1] = self.markers[labels[easy]]  # marker at read position
        return (
            toks.astype(np.int32),
            labels.astype(np.int32),
            easy,
        )


def sequence_task(
    n: int, seq_len: int, vocab: int = 512, order: int = 2, seed: int = 0
):
    """Markov-chain LM data: tokens (n, seq_len+1) for input/target split."""
    rng = np.random.default_rng(seed)
    # sparse transition structure: each context maps to ~8 likely tokens
    n_ctx = 4096
    probs = np.zeros((n_ctx, vocab), np.float64)
    for c in range(n_ctx):
        support = rng.choice(vocab, 8, replace=False)
        probs[c, support] = rng.dirichlet(np.ones(8) * 0.5)
    out = np.zeros((n, seq_len + 1), np.int64)
    state = rng.integers(0, vocab, (n, order))
    for t in range(seq_len + 1):
        ctx = (state[:, -2] * 31 + state[:, -1]) % n_ctx
        cum = probs[ctx].cumsum(axis=1)
        u = rng.random((n, 1))
        tok = (u < cum).argmax(axis=1)
        out[:, t] = tok
        state = np.concatenate([state[:, 1:], tok[:, None]], axis=1)
    return out.astype(np.int32)
