"""Sharding-aware checkpointing.

Format: one ``.npz`` per step with '/'-joined tree paths as keys, plus a
JSON sidecar recording dtypes and the logical sharding axes of every leaf so
a restore onto a *different* mesh re-shards correctly (the values are pulled
to host as full arrays — fine at the scales this container trains; on real
multi-host pods the same layout maps onto per-shard files keyed by
process_index, noted in DESIGN.md).
"""
from __future__ import annotations

import json
import os
import re
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree):
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        flat[key] = leaf
    return flat


def save_checkpoint(directory: str, step: int, tree) -> str:
    os.makedirs(directory, exist_ok=True)
    flat = _flatten(tree)
    arrays = {}
    meta = {}
    for k, v in flat.items():
        a = np.asarray(jax.device_get(v))
        if a.dtype == jnp.bfloat16:
            arrays[k] = a.view(np.uint16)
            meta[k] = "bfloat16"
        else:
            arrays[k] = a
            meta[k] = str(a.dtype)
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    np.savez(path, **arrays)
    with open(path + ".json", "w") as f:
        json.dump({"step": step, "dtypes": meta}, f)
    return path


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(m.group(1))
        for f in os.listdir(directory)
        if (m := re.match(r"ckpt_(\d+)\.npz$", f))
    ]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, template, step: Optional[int] = None):
    """Restore into the structure of ``template`` (values replaced)."""
    step = step if step is not None else latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {directory}")
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    with open(path + ".json") as f:
        meta = json.load(f)["dtypes"]
    data = np.load(path)
    flat_t, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for pth, leaf in flat_t:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in pth
        )
        a = data[key]
        if meta[key] == "bfloat16":
            a = a.view(jnp.bfloat16)
        leaves.append(jnp.asarray(a))
    return jax.tree_util.tree_unflatten(jax.tree.structure(template), leaves)
