from repro.configs.base import (
    ARCH_IDS,
    INPUT_SHAPES,
    ModelConfig,
    ShapeConfig,
    get_config,
    list_configs,
    shape_supported,
)

__all__ = [
    "ARCH_IDS",
    "INPUT_SHAPES",
    "ModelConfig",
    "ShapeConfig",
    "get_config",
    "list_configs",
    "shape_supported",
]
