"""llama4-maverick-400b-a17b [moe] — 128 experts top-1 + shared expert,
MoE interleaved every other layer (Maverick-style; with d_ff=8192 per
expert this lands at ≈430B total / ≈17B active — matching the model card,
where MoE-every-layer would be ≈1.6T).  Early-fusion multimodal embeddings
stubbed like the VLM carve-out; chunked/sliding attention for the
long-context shape.  [hf:meta-llama/Llama-4-Scout-17B-16E]

48L d_model=5120 40H (GQA kv=8) d_ff=8192 (per expert) vocab=202048.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    d_ff=8192,
    vocab_size=202048,
    n_heads=40,
    n_kv_heads=8,
    n_experts=128,
    top_k=1,
    moe_every=2,
    n_shared_experts=1,
    norm_type="rmsnorm",
)
