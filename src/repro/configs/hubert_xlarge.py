"""hubert-xlarge [audio] — encoder-only transformer backbone (same arch as
wav2vec2).  Conv/mel frontend stubbed per the assignment carve-out:
input_specs supplies precomputed frame embeddings.  Masked-frame cluster
prediction over 504 k-means units.  No decode step (encoder-only) —
decode_32k/long_500k skipped, recorded in DESIGN.md.

48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504.  [arXiv:2106.07447]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="encoder",
    n_layers=48,
    d_model=1280,
    d_ff=5120,
    vocab_size=504,
    n_heads=16,
    n_kv_heads=16,
    is_encoder=True,
    frontend_dim=512,  # conv feature-extractor output dim
    norm_type="layernorm",
    mlp_activation="gelu",
)
