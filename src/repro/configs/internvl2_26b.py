"""internvl2-26b [vlm] — InternViT-6B vision encoder (stubbed per the
assignment carve-out: input_specs supplies precomputed patch embeddings)
feeding an InternLM2-20B-family GQA decoder.

48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553.  [arXiv:2404.16821]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    d_ff=16384,
    vocab_size=92553,
    n_heads=48,
    n_kv_heads=8,
    n_vision_tokens=256,
    frontend_dim=3200,  # InternViT-6B hidden size
    norm_type="rmsnorm",
)
