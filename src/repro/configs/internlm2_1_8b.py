"""internlm2-1.8b [dense] — GQA decoder.

24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92544.  [arXiv:2403.17297]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-1.8b",
    family="dense",
    n_layers=24,
    d_model=2048,
    d_ff=8192,
    vocab_size=92544,
    n_heads=16,
    n_kv_heads=8,
    norm_type="rmsnorm",
)
