"""Model / input-shape configuration dataclasses and the --arch registry."""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

FAMILIES = ("dense", "moe", "ssm_mamba2", "ssm_rwkv6", "hybrid", "encoder", "vlm")


@dataclass(frozen=True)
class ModelConfig:
    """A single architecture.  Every assigned arch cites its source in the
    module that builds it (src/repro/configs/<id>.py)."""

    name: str
    family: str
    n_layers: int
    d_model: int
    d_ff: int
    vocab_size: int
    n_heads: int = 0            # 0 for attention-free families
    n_kv_heads: int = 0
    head_dim: int = 0           # 0 -> d_model // n_heads

    # attention
    qkv_bias: bool = False
    attn_out_bias: bool = False
    rope_theta: float = 10_000.0
    sliding_window: Optional[int] = None  # None = full attention
    attn_logit_softcap: Optional[float] = None

    # norm / mlp
    norm_type: str = "rmsnorm"  # 'rmsnorm' | 'layernorm' | 'nonparametric_ln'
    norm_eps: float = 1e-5
    mlp_activation: str = "silu"  # 'silu' (gated) | 'gelu' (ungated)

    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_every: int = 1           # MoE layer every N layers (1 = all layers)
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # SSM (Mamba2 / RWKV6)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_ngroups: int = 1
    rwkv_lora_rank: int = 64

    # hybrid (Zamba2): shared attention block applied every `attn_every` layers
    attn_every: int = 0

    # encoder / vlm frontends (stubbed per assignment)
    is_encoder: bool = False
    n_vision_tokens: int = 0     # >0: prefix of precomputed patch embeddings
    frontend_dim: int = 0        # raw embedding dim fed by the stub frontend

    # numerics
    dtype: str = "bfloat16"
    tie_embeddings: bool = False
    remat: bool = True

    def __post_init__(self):
        assert self.family in FAMILIES, self.family
        if self.n_heads and not self.head_dim:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    # ---- derived quantities -------------------------------------------
    @property
    def attention_free(self) -> bool:
        return self.family in ("ssm_rwkv6",)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_head_dim else 0

    def param_count(self) -> int:
        """Analytic parameter count (used for 6·N·D model-FLOPs)."""
        from repro.models.counting import count_params
        return count_params(self)

    def active_param_count(self) -> int:
        from repro.models.counting import count_params
        return count_params(self, active_only=True)

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: <=2 layers, d_model<=512, <=4 experts — same
        family and structural features."""
        d_model = min(self.d_model, 256)
        n_heads = min(self.n_heads, 4) if self.n_heads else 0
        n_kv = min(self.n_kv_heads, n_heads) if self.n_kv_heads else 0
        if n_kv and self.n_kv_heads < self.n_heads:
            n_kv = max(1, n_heads // max(1, self.n_heads // self.n_kv_heads))
        changes = dict(
            name=self.name + "-reduced",
            n_layers=2,
            d_model=d_model,
            d_ff=min(self.d_ff, 512),
            vocab_size=min(self.vocab_size, 512),
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=(d_model // n_heads) if n_heads else 0,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=min(self.ssm_head_dim, 32) if self.ssm_head_dim else 0,
            rwkv_lora_rank=min(self.rwkv_lora_rank, 16),
            attn_every=min(self.attn_every, 2) if self.attn_every else 0,
            n_vision_tokens=min(self.n_vision_tokens, 16),
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else None,
            remat=False,
        )
        return replace(self, **changes)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


INPUT_SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

ARCH_IDS = (
    "zamba2-2.7b",
    "internvl2-26b",
    "hubert-xlarge",
    "internlm2-1.8b",
    "olmo-1b",
    "rwkv6-7b",
    "mixtral-8x22b",
    "llama4-maverick-400b-a17b",
    "command-r-plus-104b",
    "qwen2.5-3b",
)

_MODULE_FOR = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULE_FOR:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULE_FOR)}")
    mod = importlib.import_module(f"repro.configs.{_MODULE_FOR[arch]}")
    return mod.CONFIG


def list_configs():
    return list(ARCH_IDS)


def shape_supported(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Skip matrix (documented in DESIGN.md §Arch-applicability)."""
    if cfg.is_encoder and shape.kind == "decode":
        return False, "encoder-only architecture has no decode step"
    return True, ""
