"""olmo-1b [dense] — non-parametric LayerNorm (no learned scale/bias).

16L d_model=2048 16H (kv=16) d_ff=8192 vocab=50304.  [arXiv:2402.00838]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    d_ff=8192,
    vocab_size=50304,
    n_heads=16,
    n_kv_heads=16,
    norm_type="nonparametric_ln",
    tie_embeddings=True,
)
