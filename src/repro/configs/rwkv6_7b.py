"""rwkv6-7b [ssm] — Finch: attention-free, data-dependent per-channel decay.

32L d_model=4096 d_ff=14336 vocab=65536; head_dim 64 (64 heads).
O(1) decode state — the natural long_500k tier.  [arXiv:2404.05892]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm_rwkv6",
    n_layers=32,
    d_model=4096,
    d_ff=14336,
    vocab_size=65536,
    ssm_head_dim=64,
    rwkv_lora_rank=64,
    norm_type="layernorm",
)
