"""zamba2-2.7b [hybrid] — Mamba2 backbone + shared attention block.

54L d_model=2560 32H (GQA kv=32) d_ff=10240 vocab=32000, ssm_state=64.
Shared transformer block applied every 6th layer (Zamba2-style weight
sharing; the per-invocation LoRA deltas of the released model are omitted —
recorded in DESIGN.md §7).  [arXiv:2411.15242]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    d_ff=10240,
    vocab_size=32000,
    n_heads=32,
    n_kv_heads=32,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_conv=4,
    ssm_ngroups=1,
    attn_every=6,
    norm_type="rmsnorm",
)
