"""Continuous batching demo: a stream of requests with different prompt
lengths and generation budgets flows through a fixed set of decode slots;
finished slots are refilled mid-stream.  Outputs are bit-identical to
per-request greedy decoding (tests/test_serving.py proves it).

Then the cascade-aware flavor: every tier runs its own slot stream, tiers
are stepped round-robin, and a slot freed by tier-1 agreement admits work
while tier-0 is still decoding — requests whose members disagree are
re-queued on the next tier with their prompt intact.

    PYTHONPATH=src python examples/continuous_batching.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core import ensemble as ens
from repro.core.cascade import TierSpec
from repro.models.params import unbox
from repro.serve import CascadeServer, CascadeTier, Request, ServingEngine

cfg = get_config("qwen2.5-3b").reduced()
stacked = unbox(ens.init_ensemble(cfg, 3, jax.random.PRNGKey(0)))[0]
member = ens.take_member(stacked, 0)
rng = np.random.default_rng(0)
vocab = cfg.vocab_size


def make_requests(n):
    return [
        Request(
            tokens=rng.integers(0, vocab, rng.integers(4, 20)).astype(np.int32),
            max_new_tokens=int(rng.integers(2, 8)),
        )
        for _ in range(n)
    ]


requests = make_requests(24)

eng = ServingEngine(cfg, member, max_seq=64)
t0 = time.perf_counter()
done = eng.serve_continuous(list(requests), n_slots=8)
dt = time.perf_counter() - t0
total_new = sum(len(r.output) for r in done)
print(f"served {len(done)} requests / {total_new} generated tokens in {dt:.1f}s "
      f"with 8 slots ({eng.stats['decode_tokens']} slot-steps)")
print(f"e.g. request {done[0].rid}: prompt[{len(done[0].tokens)}] -> "
      f"{done[0].output.tolist()}")

# the same workload, one request at a time (no batching)
eng2 = ServingEngine(cfg, member)
t0 = time.perf_counter()
for r in requests:
    eng2.generate(r.tokens[None, :], r.max_new_tokens)
dt2 = time.perf_counter() - t0
print(f"sequential per-request baseline: {dt2:.1f}s "
      f"({dt2/dt:.1f}x slower than continuous batching)")

# --- cascade-aware continuous batching -------------------------------------
big_cfg = get_config("olmo-1b").reduced()
big1 = unbox(ens.init_ensemble(big_cfg, 1, jax.random.PRNGKey(1)))[0]
server = CascadeServer([
    CascadeTier(cfg, stacked, TierSpec("small-x3", "vote", 0.67, k=3, cost=1.0)),
    CascadeTier(big_cfg, big1, TierSpec("big", "confidence", -1.0, k=1, cost=25.0)),
])
stream = make_requests(12)
t0 = time.perf_counter()
done = server.serve_continuous(stream, n_slots=4, max_seq=64)
dt = time.perf_counter() - t0
tiers = np.bincount([r.tier for r in done], minlength=2)
print(f"\ncascade continuous: {len(done)} requests in {dt:.1f}s; "
      f"answered per tier: {tiers.tolist()} "
      f"(disagreements were re-queued onto tier 2 mid-stream)")
