"""Continuous batching demo: a stream of requests with different prompt
lengths and generation budgets flows through a fixed set of decode slots
(one shared ``SlotStream`` state machine, serve/slot_stream.py); finished
slots are refilled mid-stream, and admission consumes each prompt's prefix
in bucketed power-of-two prefill chunks — a long prompt costs a handful of
chunk calls instead of one decode step per token.  Outputs are
bit-identical to per-request greedy decoding (tests/test_slot_stream.py
proves it for every family and ensemble width).

Then the cascade-aware flavor: every tier runs its own SlotStream, tiers
are stepped round-robin, and a slot freed by tier-1 agreement admits work
while tier-0 is still decoding — requests whose members disagree are
re-queued on the next tier with their prompt intact.  Constant-state
families (SSM/RWKV/hybrid) serve too: admission zeroes the slot's state
leaves.

    PYTHONPATH=src python examples/continuous_batching.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core import ensemble as ens
from repro.core.cascade import TierSpec
from repro.models.params import unbox
from repro.serve import CascadeServer, CascadeTier, Request, ServingEngine

cfg = get_config("qwen2.5-3b").reduced()
stacked = unbox(ens.init_ensemble(cfg, 3, jax.random.PRNGKey(0)))[0]
member = ens.take_member(stacked, 0)
rng = np.random.default_rng(0)
vocab = cfg.vocab_size


def make_requests(n):
    return [
        Request(
            tokens=rng.integers(0, vocab, rng.integers(4, 20)).astype(np.int32),
            max_new_tokens=int(rng.integers(2, 8)),
        )
        for _ in range(n)
    ]


requests = make_requests(24)
# one long prompt to show chunked admission off the decode path
requests.append(Request(tokens=rng.integers(0, vocab, 100).astype(np.int32),
                        max_new_tokens=4))

eng = ServingEngine(cfg, member, max_seq=128)
t0 = time.perf_counter()
done = eng.serve_continuous(list(requests), n_slots=8)
dt = time.perf_counter() - t0
total_new = sum(len(r.output) for r in done)
st = eng.last_stream_stats
print(f"served {len(done)} requests / {total_new} generated tokens in {dt:.1f}s "
      f"with 8 slots ({st['decode_tokens']} slot-steps; "
      f"{st['chunk_tokens']} prompt tokens admitted via {st['chunk_calls']} "
      f"prefill chunks instead of decode steps)")
print(f"e.g. request {done[0].rid}: prompt[{len(done[0].tokens)}] -> "
      f"{done[0].output.tolist()}")

# the same workload, one request at a time (no batching)
eng2 = ServingEngine(cfg, member)
t0 = time.perf_counter()
for r in requests:
    eng2.generate(r.tokens[None, :], r.max_new_tokens)
dt2 = time.perf_counter() - t0
print(f"sequential per-request baseline: {dt2:.1f}s "
      f"({dt2/dt:.1f}x slower than continuous batching)")

# --- cascade-aware continuous batching -------------------------------------
big_cfg = get_config("olmo-1b").reduced()
big1 = unbox(ens.init_ensemble(big_cfg, 1, jax.random.PRNGKey(1)))[0]
server = CascadeServer([
    CascadeTier(cfg, stacked, TierSpec("small-x3", "vote", 0.67, k=3, cost=1.0)),
    CascadeTier(big_cfg, big1, TierSpec("big", "confidence", -1.0, k=1, cost=25.0)),
])
stream = make_requests(12)
t0 = time.perf_counter()
done = server.serve_continuous(stream, n_slots=4, max_seq=64)
dt = time.perf_counter() - t0
tiers = np.bincount([r.tier for r in done], minlength=2)
print(f"\ncascade continuous: {len(done)} requests in {dt:.1f}s; "
      f"answered per tier: {tiers.tolist()} "
      f"(disagreements were re-queued onto tier 2 mid-stream)")
