"""Continuous batching demo: a stream of requests with different prompt
lengths and generation budgets flows through a fixed set of decode slots;
finished slots are refilled mid-stream.  Outputs are bit-identical to
per-request greedy decoding (tests/test_serving.py proves it).

    PYTHONPATH=src python examples/continuous_batching.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core import ensemble as ens
from repro.models.params import unbox
from repro.serve import Request, ServingEngine

cfg = get_config("qwen2.5-3b").reduced()
member = ens.take_member(unbox(ens.init_ensemble(cfg, 1, jax.random.PRNGKey(0)))[0], 0)
rng = np.random.default_rng(0)
vocab = cfg.vocab_size

requests = [
    Request(
        tokens=rng.integers(0, vocab, rng.integers(4, 20)).astype(np.int32),
        max_new_tokens=int(rng.integers(2, 8)),
    )
    for _ in range(24)
]

eng = ServingEngine(cfg, member, max_seq=64)
t0 = time.perf_counter()
done = eng.serve_continuous(list(requests), n_slots=8)
dt = time.perf_counter() - t0
total_new = sum(len(r.output) for r in done)
print(f"served {len(done)} requests / {total_new} generated tokens in {dt:.1f}s "
      f"with 8 slots ({eng.stats['decode_tokens']} slot-steps)")
print(f"e.g. request {done[0].rid}: prompt[{len(done[0].tokens)}] -> "
      f"{done[0].output.tolist()}")

# the same workload, one request at a time (no batching)
eng2 = ServingEngine(cfg, member)
t0 = time.perf_counter()
for r in requests:
    eng2.generate(r.tokens[None, :], r.max_new_tokens)
dt2 = time.perf_counter() - t0
print(f"sequential per-request baseline: {dt2:.1f}s "
      f"({dt2/dt:.1f}x slower than continuous batching)")
