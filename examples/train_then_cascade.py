"""End-to-end driver (the paper-shaped workflow): TRAIN tier models on a
mixture-difficulty task for a few hundred steps, CALIBRATE the agreement
threshold on ~100 held-out samples (App. B), then SERVE a drop-in cascade
and report the paper's headline quantities — accuracy vs the large model
(Prop 4.1.1) and cost vs always-large (Prop 4.1.2).

    PYTHONPATH=src python examples/train_then_cascade.py [--steps 300]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import calibration, deferral, ensemble as ens
from repro.core.cascade import TierSpec
from repro.data.synthetic import MixtureTask
from repro.models import api
from repro.models.params import unbox
from repro.optim.adamw import OptimConfig
from repro.serve import CascadeServer, CascadeTier
from repro.train import init_train_state, make_train_step

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--big-steps", type=int, default=600)
args = ap.parse_args()

SMALL = ModelConfig(name="ex-small", family="dense", n_layers=1, d_model=48,
                    d_ff=96, vocab_size=256, n_heads=2, n_kv_heads=2, remat=False)
BIG = ModelConfig(name="ex-big", family="dense", n_layers=3, d_model=160,
                  d_ff=320, vocab_size=256, n_heads=4, n_kv_heads=4, remat=False)
TASK = MixtureTask(vocab=256, n_classes=16, seq_len=32, easy_frac=0.6, seed=0)


def train_classifier(cfg, steps, seed, lr=2e-3, batch=64):
    toks, labels, _ = TASK.sample(4096, seed=seed + 100)
    values, _ = unbox(api.init_params(cfg, jax.random.PRNGKey(seed)))
    ocfg = OptimConfig(lr=lr, weight_decay=0.01)
    state = init_train_state(values, ocfg)
    step = jax.jit(make_train_step(cfg, ocfg, total_steps=steps, warmup_steps=20))
    rng = np.random.default_rng(seed)
    mask = np.zeros((batch, TASK.seq_len), np.float32)
    mask[:, -1] = 1.0
    for i in range(steps):
        idx = rng.integers(0, len(toks), batch)
        tgt = np.zeros((batch, TASK.seq_len), np.int32)
        tgt[:, -1] = labels[idx]
        state, m = step(state, {"tokens": toks[idx], "targets": tgt, "mask": mask})
        if (i + 1) % 100 == 0:
            print(f"  [{cfg.name} seed {seed}] step {i+1}: loss {float(m['loss']):.3f}")
    return state.params


print("training 3 small tier members + 1 large model ...")
small_members = [train_classifier(SMALL, args.steps, s) for s in (0, 1, 2)]
stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *small_members)
big = jax.tree.map(lambda x: x[None], train_classifier(BIG, args.big_steps, 7))

print("calibrating theta on 100 held-out samples ...")
cal_toks, cal_y, _ = TASK.sample(100, seed=999)
logits = ens.ensemble_last_logits(stacked, {"tokens": jnp.asarray(cal_toks)}, SMALL)
out = deferral.vote_rule(logits, theta=0.0)
theta, info = calibration.estimate_threshold(
    np.asarray(out.score), np.asarray(out.pred) == cal_y, epsilon=0.05
)
print(f"  theta={theta:.3f}  selection_rate={info['selection_rate']:.2f}  "
      f"failure_rate={info['failure_rate']:.3f}")

print("serving 1024 fresh requests through the cascade ...")
test_toks, test_y, easy = TASK.sample(1024, seed=1234)
server = CascadeServer([
    CascadeTier(SMALL, stacked, TierSpec("small-x3", "vote", theta, k=3, cost=1.0)),
    CascadeTier(BIG, big, TierSpec("big", "confidence", -1.0, k=1, cost=25.0)),
])
res = server.classify(test_toks)
big_logits = ens.ensemble_last_logits(big, {"tokens": jnp.asarray(test_toks)}, BIG)
big_pred = np.asarray(big_logits[0].argmax(-1))

acc_c = (res.pred == test_y).mean()
acc_b = (big_pred == test_y).mean()
fr = server.tier_fractions(res)
print(f"\n=== drop-in cascade report ===")
print(f"accuracy: cascade {acc_c:.3f} vs large-only {acc_b:.3f} "
      f"(Prop 4.1.1: within calibrated eps)")
print(f"tier fractions: small {fr[0]:.2f} / big {fr[1]:.2f}")
print(f"cost: {res.cost:.0f} vs always-large {25.0 * len(test_toks):.0f} "
      f"({25.0 * len(test_toks) / res.cost:.2f}x cheaper)")
sel = res.tier_of == 0
if sel.any():
    print(f"easy-fraction at tier1 exits {easy[sel].mean():.2f} vs deferred "
          f"{easy[~sel].mean():.2f} (ABC routes by difficulty)")
