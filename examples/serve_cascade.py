"""Batched request serving through the queue-driven engine + the black-box
generation cascade (the §5.2.3 API flavor: agreement = exact-match voting
over stable digests of member generations, no logits needed).

Every tier's members generate in ONE vmapped XLA program per decode step
(stacked weights — the paper's ρ=1 execution), and all jitted programs are
compile-once: the second batch below re-enters the jit cache with zero new
traces.

    PYTHONPATH=src python examples/serve_cascade.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import ensemble as ens
from repro.core.cascade import TierSpec
from repro.models.params import unbox
from repro.serve import CascadeServer, CascadeTier, Request, ServingEngine
from repro.serve.engine import trace_count

small_cfg = get_config("olmo-1b").reduced()
big_cfg = get_config("internlm2-1.8b").reduced()
rng = np.random.default_rng(0)
vocab = min(small_cfg.vocab_size, big_cfg.vocab_size)

# --- queue-driven single-model serving -------------------------------------
member = unbox(ens.init_ensemble(small_cfg, 1, jax.random.PRNGKey(0)))[0]
engine = ServingEngine(small_cfg, ens.take_member(member, 0), max_batch=8)
for i in range(12):
    engine.queue.submit(Request(
        tokens=rng.integers(0, vocab, rng.integers(8, 24)).astype(np.int32),
        max_new_tokens=int(rng.integers(2, 6)),
    ))
done = engine.serve_pending()
print(f"served {len(done)} requests in {engine.stats['batches']} batches; "
      f"stats: {engine.stats}")
print(f"  e.g. request {done[0].rid}: generated {done[0].output.tolist()}")

# --- black-box generation cascade (vote on sampled answers, Eq. 3) ---------
small3 = unbox(ens.init_ensemble(small_cfg, 3, jax.random.PRNGKey(1)))[0]
big1 = unbox(ens.init_ensemble(big_cfg, 1, jax.random.PRNGKey(2)))[0]
server = CascadeServer([
    CascadeTier(small_cfg, small3, TierSpec("small-x3", "vote", 0.67, k=3, cost=1.0),
                temperature=0.7),
    CascadeTier(big_cfg, big1, TierSpec("big", "confidence", -1.0, k=1, cost=25.0)),
])
prompts = rng.integers(0, vocab, (16, 16)).astype(np.int32)
res = server.generate(prompts, max_new_tokens=4)
print(f"\nblack-box cascade: tier counts {res.tier_counts.tolist()}, "
      f"cost {res.cost:.0f} vs all-big {25.0 * len(prompts):.0f}")
print("(untrained members rarely agree on sampled text -> most defer, "
      "mirroring the paper's safety behaviour)")

# --- compile-once: serving the same traffic again triggers zero new traces
# (same prompts + same seed -> identical routing, so every chunk shape is
# already compiled; fresh data of the same shape reuses the same programs
# unless its deferral count lands in a not-yet-seen bucket chunk)
before = trace_count()
server.generate(prompts, max_new_tokens=4)
print(f"\nsecond batch: {trace_count() - before} new traces "
      f"(all programs re-entered the jit cache)")
