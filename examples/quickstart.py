"""Quickstart: build a 2-tier ABC cascade from the arch registry (reduced
configs), calibrate the agreement threshold on ~100 samples, and serve a
batch — the whole public API in ~60 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import calibration, deferral, ensemble as ens
from repro.core.cascade import TierSpec
from repro.models.params import unbox
from repro.serve import CascadeServer, CascadeTier

# --- 1. two tiers from the assigned-architecture registry -----------------
small_cfg = get_config("qwen2.5-3b").reduced()
big_cfg = get_config("internlm2-1.8b").reduced()
small = unbox(ens.init_ensemble(small_cfg, k=3, rng=jax.random.PRNGKey(0)))[0]
big = unbox(ens.init_ensemble(big_cfg, k=1, rng=jax.random.PRNGKey(1)))[0]

# --- 2. calibrate the tier-1 agreement threshold (paper App. B) ------------
rng = np.random.default_rng(0)
vocab = min(small_cfg.vocab_size, big_cfg.vocab_size)
cal_toks = rng.integers(0, vocab, (100, 32)).astype(np.int32)
cal_y = rng.integers(0, vocab, 100)  # untrained demo: labels are arbitrary
logits = ens.ensemble_last_logits(small, {"tokens": jnp.asarray(cal_toks)}, small_cfg)
out = deferral.vote_rule(logits, theta=0.0)
theta, info = calibration.estimate_threshold(
    np.asarray(out.score), np.asarray(out.pred) == cal_y, epsilon=0.05
)
print(f"calibrated theta={theta:.3f} selection_rate={info['selection_rate']:.2f}")

# --- 3. serve a batch through the cascade ----------------------------------
server = CascadeServer([
    CascadeTier(small_cfg, small, TierSpec("small", "vote", theta, k=3, cost=1.0)),
    CascadeTier(big_cfg, big, TierSpec("big", "confidence", -1.0, k=1, cost=25.0)),
])
toks = rng.integers(0, vocab, (32, 32)).astype(np.int32)
res = server.classify(toks)
print(f"tier fractions: {np.round(server.tier_fractions(res), 2).tolist()}")
print(f"cost: {res.cost:.1f} vs all-big {25.0 * len(toks):.1f}")
print("(untrained members rarely agree -> most requests defer; see "
      "examples/train_then_cascade.py for the trained behaviour)")
