"""Edge-to-cloud placement simulation (paper §5.2.1): a tiny on-device
ensemble answers agreed requests locally; only disagreements cross the
network.  Uses the paper's delay grid and trained tier models.

    PYTHONPATH=src python examples/edge_to_cloud.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import calibration, deferral, ensemble as ens
from repro.core.cost_model import EDGE_DELAYS, EdgeCloudCost
from repro.data.synthetic import MixtureTask
from repro.models import api
from repro.models.params import unbox
from repro.optim.adamw import OptimConfig
from repro.train import init_train_state, make_train_step

EDGE = ModelConfig(name="edge", family="dense", n_layers=1, d_model=32, d_ff=64,
                   vocab_size=256, n_heads=2, n_kv_heads=2, remat=False)
CLOUD = ModelConfig(name="cloud", family="dense", n_layers=3, d_model=128, d_ff=256,
                    vocab_size=256, n_heads=4, n_kv_heads=4, remat=False)
TASK = MixtureTask(vocab=256, n_classes=16, seq_len=32, easy_frac=0.6, seed=0)


def train(cfg, steps, seed):
    toks, labels, _ = TASK.sample(4096, seed=seed + 100)
    values, _ = unbox(api.init_params(cfg, jax.random.PRNGKey(seed)))
    ocfg = OptimConfig(lr=2e-3)
    state = init_train_state(values, ocfg)
    step = jax.jit(make_train_step(cfg, ocfg, total_steps=steps, warmup_steps=20))
    rng = np.random.default_rng(seed)
    mask = np.zeros((64, TASK.seq_len), np.float32); mask[:, -1] = 1.0
    for _ in range(steps):
        idx = rng.integers(0, len(toks), 64)
        tgt = np.zeros((64, TASK.seq_len), np.int32); tgt[:, -1] = labels[idx]
        state, _ = step(state, {"tokens": toks[idx], "targets": tgt, "mask": mask})
    return state.params


print("training edge ensemble (3x tiny) and cloud model ...")
edge = jax.tree.map(lambda *xs: jnp.stack(xs), *[train(EDGE, 200, s) for s in (0, 1, 2)])
cloud = jax.tree.map(lambda x: x[None], train(CLOUD, 400, 9))

cal_toks, cal_y, _ = TASK.sample(100, seed=77)
lo = ens.ensemble_last_logits(edge, {"tokens": jnp.asarray(cal_toks)}, EDGE)
oc = deferral.vote_rule(lo, 0.0)
theta, _ = calibration.estimate_threshold(
    np.asarray(oc.score), np.asarray(oc.pred) == cal_y, epsilon=0.05
)

test_toks, test_y, _ = TASK.sample(2048, seed=42)
L = ens.ensemble_last_logits(edge, {"tokens": jnp.asarray(test_toks)}, EDGE)
out = deferral.vote_rule(L, theta)
defer = np.asarray(out.defer)
cloud_logits = ens.ensemble_last_logits(cloud, {"tokens": jnp.asarray(test_toks)}, CLOUD)
pred = np.where(defer, np.asarray(cloud_logits[0].argmax(-1)), np.asarray(out.pred))

print(f"\ndefer rate: {defer.mean():.2f}  "
      f"accuracy: ABC {(pred == test_y).mean():.3f} vs cloud-only "
      f"{(np.asarray(cloud_logits[0].argmax(-1)) == test_y).mean():.3f}")
print(f"{'delay tier':12s} {'ABC latency':>12s} {'cloud-only':>12s} {'reduction':>10s}")
for name, delay in EDGE_DELAYS.items():
    cm = EdgeCloudCost(delay=delay)
    a, c = cm.mean_latency(defer.mean()), cm.mean_latency(1.0)
    print(f"{name:12s} {a*1e3:10.3f}ms {c*1e3:10.3f}ms {c/a:9.1f}x")

# -- the same boundary as a runtime object: place the tiers on simulated
# edge/cloud hosts and let the serving path meter what actually crosses
from repro.core.cascade import TierSpec
from repro.serve import CascadeServer, CascadeTier, edge_cloud

placement = edge_cloud(delay="medium")
server = CascadeServer(
    [
        CascadeTier(EDGE, edge, TierSpec("edge", "vote", theta, k=3, cost=1.0)),
        CascadeTier(CLOUD, cloud, TierSpec("cloud", "confidence", -1.0, k=1, cost=50.0)),
    ],
    placement=placement,
)
res = server.classify(test_toks[:256])
link = placement.link(0)
full_bytes = 256 * test_toks.shape[1] * 4
print(f"\nmeasured over the edge->cloud link ({placement.describe()}):")
print(f"  deferred {link.total_examples}/256 requests, "
      f"{link.total_bytes/1e3:.1f} kB crossed vs {full_bytes/1e3:.1f} kB "
      f"always-cloud ({full_bytes/max(1, link.total_bytes):.1f}x reduction), "
      f"simulated link time {link.total_latency*1e3:.1f} ms")

# -- the overlapped path (DESIGN.md §8): continuous serving over a REAL
# (wall-clock) link, once blocking on every deferral hop and once with the
# edge tier decoding while payloads are in flight.  Same generations, same
# metered hops — only the makespan changes.
import time

from repro.serve import Request

def _requests():
    rng = np.random.default_rng(3)
    return [Request(tokens=rng.integers(0, 256, 8).astype(np.int32),
                    max_new_tokens=6) for _ in range(12)]

def _serve(link_kind):
    pl = edge_cloud(delay=0.04, link=link_kind)
    srv = CascadeServer(
        [
            CascadeTier(EDGE, edge, TierSpec("edge", "vote", theta, k=3, cost=1.0)),
            CascadeTier(CLOUD, cloud, TierSpec("cloud", "confidence", -1.0, k=1, cost=50.0)),
        ],
        placement=pl,
    )
    t0 = time.perf_counter()
    done = srv.serve_continuous(_requests(), n_slots=4, max_seq=32)
    return done, time.perf_counter() - t0, pl.link(0)

_serve("sim")  # compile warmup off the clock
done_ser, wall_ser, _ = _serve("serial")
done_ovl, wall_ovl, ovl = _serve("async")
same = {tuple(r.tokens): tuple(r.output) for r in done_ser} == \
       {tuple(r.tokens): tuple(r.output) for r in done_ovl}
print(f"\noverlapped serving over a 40ms wall-clock link "
      f"({ovl.total_examples} deferrals):")
print(f"  makespan {wall_ser*1e3:.0f} ms serial -> {wall_ovl*1e3:.0f} ms "
      f"overlapped = {wall_ser/wall_ovl:.2f}x overlap ratio; "
      f"{(ovl.total_latency - ovl.total_wait)*1e3:.0f} ms of link time hidden "
      f"behind edge decode; generations identical: {same}")
