"""Docs gate: intra-repo links + DESIGN.md section citations + quickstart.

    PYTHONPATH=src python tools/check_docs.py [--run-quickstart]

Three checks (exit nonzero on any failure, every failure printed):

1. **Markdown links** — every relative ``[text](path)`` link in the
   top-level ``*.md`` files must point at a file or directory that exists
   (anchors ``path#frag`` are checked for the file part; absolute URLs are
   skipped).

2. **DESIGN.md § citations** — DESIGN.md's section headers define the
   citable tokens (``## §8 ...`` defines ``§8``).  Every occurrence of
   ``DESIGN.md §<token>`` anywhere in the repo's ``.py`` and ``.md`` files
   must name a section that exists, so docstring citations cannot rot when
   sections are renumbered.  (Bare ``§5.2.1``-style references cite the
   PAPER, not DESIGN.md, and are out of scope.)

3. **Quickstart smoke** (``--run-quickstart``) — the commands in
   README.md's first ```` ```bash ```` block are executed and must exit 0.
   The full-pytest line is run ``--collect-only`` here: the docs job
   proves the documented command line is valid, while test EXECUTION stays
   owned by the fast-tier CI job (running the suite twice per push buys
   nothing).  Bench lines run under ``REPRO_BENCH_SMOKE=1``.
"""
from __future__ import annotations

import argparse
import os
import re
import subprocess
import sys

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

MD_FILES = ["README.md", "DESIGN.md", "ROADMAP.md", "CHANGES.md", "ISSUE.md",
            "PAPER.md", "PAPERS.md"]

_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_SECTION_RE = re.compile(r"^##\s+§(\S+)", re.M)
# a citation token starts with a word character: prose that merely mentions
# the "DESIGN.md §" convention (e.g. a changelog entry) is not a citation
_CITE_RE = re.compile(r"DESIGN\.md\s+§([\w][\w.-]*)")


def _read(path: str) -> str:
    with open(path, encoding="utf-8") as f:
        return f.read()


def check_markdown_links() -> list:
    """Relative links in top-level markdown must resolve inside the repo."""
    failures = []
    for name in MD_FILES:
        path = os.path.join(REPO, name)
        if not os.path.exists(path):
            continue
        for m in _LINK_RE.finditer(_read(path)):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            file_part = target.split("#", 1)[0]
            if not file_part:  # pure same-file anchor
                continue
            if not os.path.exists(os.path.join(REPO, file_part)):
                failures.append(f"{name}: broken link -> {target}")
    return failures


def design_sections() -> set:
    """The citable § tokens, from DESIGN.md's '## §<token>' headers."""
    return set(_SECTION_RE.findall(_read(os.path.join(REPO, "DESIGN.md"))))


def _cited_files():
    for root, dirs, files in os.walk(REPO):
        dirs[:] = [d for d in dirs if not d.startswith(".")
                   and d != "__pycache__"]
        for f in files:
            if f.endswith((".py", ".md")):
                path = os.path.join(root, f)
                if os.path.samefile(path, __file__):
                    continue  # this file's docstring shows placeholder tokens
                yield path


def check_design_citations() -> list:
    """Every 'DESIGN.md §X' in the repo must name an existing section."""
    sections = design_sections()
    if not sections:
        return ["DESIGN.md: no '## §...' section headers found"]
    failures = []
    for path in _cited_files():
        rel = os.path.relpath(path, REPO)
        for i, line in enumerate(_read(path).splitlines(), 1):
            for tok in _CITE_RE.findall(line):
                tok = tok.rstrip(".,;:)")
                if tok not in sections:
                    failures.append(
                        f"{rel}:{i}: cites DESIGN.md §{tok} "
                        f"(have: {', '.join(sorted(sections))})"
                    )
    return failures


def quickstart_commands() -> list:
    """The commands of README.md's first ```bash block (comments stripped)."""
    text = _read(os.path.join(REPO, "README.md"))
    m = re.search(r"```bash\n(.*?)```", text, re.S)
    if not m:
        return []
    cmds = []
    for line in m.group(1).splitlines():
        line = line.split("#", 1)[0].strip()
        if line:
            cmds.append(line)
    return cmds


def run_quickstart() -> list:
    failures = []
    cmds = quickstart_commands()
    if not cmds:
        return ["README.md: no ```bash quickstart block found"]
    env = {**os.environ, "REPRO_BENCH_SMOKE": "1"}
    for cmd in cmds:
        run_cmd = cmd
        if "pytest" in cmd:
            # the docs job validates the documented command LINE; the
            # fast-tier job owns actually executing the suite
            run_cmd = f"{cmd} --collect-only >/dev/null"
        print(f"$ {run_cmd}", flush=True)
        r = subprocess.run(run_cmd, shell=True, cwd=REPO, env=env,
                           timeout=1800)
        if r.returncode != 0:
            failures.append(f"quickstart command failed ({r.returncode}): {cmd}")
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--run-quickstart", action="store_true",
                    help="also execute the README quickstart commands")
    args = ap.parse_args()

    failures = check_markdown_links() + check_design_citations()
    if args.run_quickstart:
        failures += run_quickstart()

    if failures:
        print(f"\nFAIL: {len(failures)} docs problem(s)")
        for f in failures:
            print(f"  {f}")
        sys.exit(1)
    n = len(design_sections())
    print(f"docs OK: links resolve, all DESIGN.md citations hit one of "
          f"{n} sections" + (", quickstart ran" if args.run_quickstart else ""))


if __name__ == "__main__":
    main()
