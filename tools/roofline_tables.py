"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from the dry-run JSONs.

    PYTHONPATH=src python tools/roofline_tables.py [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def fmt_s(x):
    return f"{x:.3g}"


def load(dirpath):
    recs = []
    for f in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        recs.append(json.load(open(f)))
    return recs


def roofline_table(recs, mesh="pod16x16"):
    rows = [
        "| arch | shape | bottleneck | t_compute (s) | t_memory (s) | t_collective (s) | MODEL_FLOPS | useful ratio | note |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("mesh") != mesh or r.get("kind") == "cascade":
            continue
        if r["status"] == "skipped":
            rows.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | — | — | — | SKIP: {r['reason']} |"
            )
            continue
        if r["status"] != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | ERROR | | | | | | {r.get('error','')[:60]} |")
            continue
        t = r["roofline"]
        note = ""
        if r.get("window_override"):
            note = f"SWA window={r['window_override']}"
        rows.append(
            f"| {r['arch']} | {r['shape']} | **{t['bottleneck']}** | {fmt_s(t['t_compute_s'])} | "
            f"{fmt_s(t['t_memory_s'])} | {fmt_s(t['t_collective_s'])} | {r['model_flops']:.3g} | "
            f"{(r['useful_ratio'] or 0):.2f} | {note} |"
        )
    return "\n".join(rows)


def dryrun_table(recs):
    rows = [
        "| arch | shape | mesh | status | compile (s) | per-chip FLOPs | per-chip bytes | collective bytes | state/dev | cpu-temps |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("kind") == "cascade":
            continue
        if r["status"] != "ok":
            rows.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['status']} | | | | | | |"
            )
            continue
        t = r["roofline"]
        mem = r.get("memory", {})
        state = max(mem.get("argument_bytes") or 0, mem.get("output_bytes") or 0)
        temp = mem.get("temp_bytes") or 0
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | {r['compile_s']} | "
            f"{t['flops']:.3g} | {t['bytes']:.3g} | {t['collective_bytes']:.3g} | "
            f"{state/1e9:.2f} GB | {temp/1e9:.0f} GB |"
        )
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--which", default="roofline", choices=["roofline", "dryrun", "both"])
    args = ap.parse_args()
    recs = load(args.dir)
    if args.which in ("roofline", "both"):
        print(roofline_table(recs))
        print()
    if args.which in ("dryrun", "both"):
        print(dryrun_table(recs))


if __name__ == "__main__":
    main()
