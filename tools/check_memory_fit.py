"""Verify every dry-run cell's sharded state fits v5e HBM (16 GB/chip).

What a CPU-backend compile can and cannot prove:
  * argument_bytes + output_bytes — the per-device residency of params,
    optimizer state, caches and batch (+ the donated outputs) under the
    chosen shardings.  This is backend-independent: it is exactly what the
    16×16 sharding must make fit, and what this tool gates on.
  * temp_bytes — XLA:CPU's temporary-buffer assignment.  The CPU backend
    neither fuses nor schedules like TPU (e.g. it materializes unfused scan
    intermediates), so temps are reported for reference only; TPU temp
    residency is governed by the remat policy (see EXPERIMENTS.md §Dry-run).

    PYTHONPATH=src python tools/check_memory_fit.py
"""
from __future__ import annotations

import glob
import json
import sys

HBM = 16e9


def main():
    bad = []
    rows = []
    for f in sorted(glob.glob("experiments/dryrun/*.json")):
        r = json.load(open(f))
        if r.get("status") != "ok" or "memory" not in r:
            continue
        mem = r["memory"]
        args = mem.get("argument_bytes") or 0
        outs = mem.get("output_bytes") or 0
        temp = mem.get("temp_bytes") or 0
        # donation aliases outputs onto arguments for train/decode states
        resident = max(args, outs)
        rows.append((r["arch"], r["shape"], r["mesh"], resident, temp))
        if resident > HBM:
            bad.append((r["arch"], r["shape"], r["mesh"], resident))
    rows.sort(key=lambda t: -t[3])
    print(f"{'arch':28s} {'shape':12s} {'mesh':12s} {'state/dev':>10s} {'cpu-temps':>10s}")
    for a, s, m, p, t in rows[:15]:
        flag = "  <-- OVER 16GB" if p > HBM else ""
        print(f"{a:28s} {s:12s} {m:12s} {p/1e9:9.2f}G {t/1e9:9.1f}G{flag}")
    print(f"\n{len(rows)} cells checked; {len(bad)} with sharded state over 16 GB/chip")
    sys.exit(1 if bad else 0)


if __name__ == "__main__":
    main()
