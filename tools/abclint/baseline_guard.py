"""CI guard: the abclint baseline only ever SHRINKS.

    python -m tools.abclint.baseline_guard OLD_BASELINE [NEW_BASELINE]

Compares two baseline files (OLD = the base branch's committed baseline,
NEW = this branch's — defaults to the repo's ``abclint_baseline.json``)
and exits nonzero if NEW contains any fingerprint absent from OLD.  New
suppressions must go through in-code ``# abclint: disable=RULE(reason)``
pragmas, where review sees the justification next to the code; the
baseline is a ledger of pre-existing audited debt, paid down over time.
Stale-entry detection (the other half of shrink-only) lives in the normal
``python -m tools.abclint`` run, which fails on entries matching nothing.
"""
from __future__ import annotations

import json
import os
import sys

from tools.abclint.engine import BASELINE_DEFAULT, REPO


def _fingerprints(path: str) -> set:
    if not os.path.exists(path):
        return set()
    with open(path, encoding="utf-8") as fh:
        data = json.load(fh)
    return {e["fingerprint"] for e in data.get("entries", [])}


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if len(argv) not in (1, 2):
        print(__doc__, file=sys.stderr)
        return 2
    old_path = argv[0]
    new_path = argv[1] if len(argv) == 2 else os.path.join(
        REPO, BASELINE_DEFAULT
    )
    old, new = _fingerprints(old_path), _fingerprints(new_path)
    added = sorted(new - old)
    if added:
        print(
            f"abclint baseline grew by {len(added)} entr"
            f"{'y' if len(added) == 1 else 'ies'} ({', '.join(added)}) — "
            "the baseline only shrinks; suppress new findings with an "
            "in-code '# abclint: disable=RULE(reason)' pragma instead",
            file=sys.stderr,
        )
        return 1
    print(
        f"abclint baseline ok: {len(new)} entr"
        f"{'y' if len(new) == 1 else 'ies'} (was {len(old)})"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
