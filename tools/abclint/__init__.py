"""abclint — repo-specific static analysis for the ABC serving stack.

Four AST passes enforce the invariants PRs 1–5 earned dynamically
(compile-once, device-resident, bit-deterministic, kernel-contract) across
``src/repro``, ``benchmarks`` and ``tools``:

  retrace          ABC101-103  jit/pallas_call program-cache discipline
  host_sync        ABC201-204  metered-_fetch/Transport boundary discipline
  determinism      ABC301-303  no hash()/set-order/wall-clock nondeterminism
  kernel_contract  ABC401-405  ops/kernel/ref trio, shim, typed errors

Run: ``python -m tools.abclint`` (see ``--help``); policy: DESIGN.md §9.
"""
from tools.abclint.engine import (  # noqa: F401
    Finding,
    Pass,
    RunResult,
    load_baseline,
    run,
    run_passes,
    write_baseline,
)
from tools.abclint.passes import ALL_PASSES, ALL_RULES  # noqa: F401
