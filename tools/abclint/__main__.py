"""abclint CLI.

    python -m tools.abclint [paths...] [--baseline abclint_baseline.json]
                            [--json] [--update-baseline] [--no-baseline]
                            [--list-rules]

Exit codes: 0 clean (every finding suppressed by pragma or justified
baseline entry, no stale entries); 1 findings / stale baseline / invalid
baseline; 2 usage error.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from tools.abclint.engine import (
    BASELINE_DEFAULT,
    DEFAULT_SCOPE,
    REPO,
    BaselineError,
    fingerprinted,
    load_baseline,
    run,
    run_passes,
    write_baseline,
)
from tools.abclint.passes import ALL_PASSES, ALL_RULES


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.abclint",
        description="repo-specific static analysis for the ABC serving "
        "stack (retrace / host-sync / determinism / kernel-contract "
        "invariants, DESIGN.md §9)",
    )
    ap.add_argument(
        "paths", nargs="*", default=None,
        help=f"repo-relative files/dirs to lint (default: {DEFAULT_SCOPE})",
    )
    ap.add_argument(
        "--baseline", default=BASELINE_DEFAULT,
        help="suppression baseline JSON (repo-relative; default: "
        f"{BASELINE_DEFAULT})",
    )
    ap.add_argument(
        "--no-baseline", action="store_true",
        help="report every finding, ignoring the baseline",
    )
    ap.add_argument(
        "--update-baseline", action="store_true",
        help="rewrite the baseline to cover current findings (existing "
        "justifications survive; NEW entries get an empty reason and must "
        "be justified by hand before the baseline loads again)",
    )
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="machine-readable report on stdout")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule table and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule in sorted(ALL_RULES):
            print(f"{rule}  {ALL_RULES[rule]}")
        return 0

    scope = tuple(args.paths) if args.paths else DEFAULT_SCOPE
    for rel in scope:
        if not os.path.exists(os.path.join(REPO, rel)):
            print(f"abclint: no such path in repo: {rel}", file=sys.stderr)
            return 2

    baseline_path = os.path.join(REPO, args.baseline)

    if args.update_baseline:
        findings = run_passes(ALL_PASSES, root=REPO, scope=scope)
        old = {}
        if os.path.exists(baseline_path):
            try:
                old = load_baseline(baseline_path)
            except BaselineError:
                old = {}  # rewriting anyway; reasons that load, survive
        n = write_baseline(baseline_path, findings, old)
        unreasoned = sum(
            1 for _, fp in fingerprinted(findings)
            if not old.get(fp, {}).get("reason")
        )
        print(f"abclint: baseline written: {n} entries "
              f"({unreasoned} need a justification before it loads)")
        return 0 if unreasoned == 0 else 1

    baseline = {}
    if not args.no_baseline and os.path.exists(baseline_path):
        try:
            baseline = load_baseline(baseline_path)
        except BaselineError as e:
            print(f"abclint: invalid baseline: {e}", file=sys.stderr)
            return 1

    result = run(ALL_PASSES, root=REPO, scope=scope, baseline=baseline)

    if args.as_json:
        print(json.dumps(
            {
                "findings": [
                    {"rule": f.rule, "path": f.path, "line": f.line,
                     "message": f.message, "snippet": f.snippet}
                    for f in result.findings
                ],
                "stale_baseline": result.stale_baseline,
                "summary": {
                    "findings": len(result.findings),
                    "baselined": len(result.baselined),
                    "stale_baseline": len(result.stale_baseline),
                    "files_scope": list(scope),
                },
            },
            indent=2,
        ))
    else:
        for f in result.findings:
            print(f.render())
        for e in result.stale_baseline:
            print(
                f"{e.get('path')}: stale baseline entry for {e.get('rule')} "
                f"({e.get('fingerprint')}) — the code it suppressed is gone; "
                "remove the entry (the baseline only shrinks)"
            )
        n, b, s = (len(result.findings), len(result.baselined),
                   len(result.stale_baseline))
        print(
            f"abclint: {n} finding(s), {b} baselined, {s} stale "
            f"baseline entr{'y' if s == 1 else 'ies'}"
        )
    return 0 if result.ok else 1


if __name__ == "__main__":
    sys.exit(main())
