"""abclint core: file walking, pragma handling, baseline compare, reporting.

The engine is deliberately small: it owns everything that is NOT a rule —
discovering files, parsing them once, collecting ``# abclint:`` pragmas,
dispatching to the registered passes (tools/abclint/passes/), matching
findings against the committed suppression baseline, and deciding the exit
code.  Rules live in the pass modules and only ever see a ``FileContext``.

Suppression model (DESIGN.md §9):

* ``# abclint: disable=RULE(reason)`` — in-code pragma, same line or the
  line directly above.  The reason is MANDATORY (a reasonless pragma is
  itself a finding, ABC001) and a pragma that suppresses nothing is a
  finding too (ABC002), so pragmas cannot rot silently.
* ``abclint_baseline.json`` — the audited-legitimate debt ledger.  Every
  entry carries a ``reason`` (empty reasons fail validation) and matches
  findings by content fingerprint (file + rule + source line text), so
  entries survive line renumbering but die with the code they describe.
  A baseline entry that matches nothing is STALE and fails the run: the
  baseline can shrink as debt is paid, never accumulate unnoticed.
"""
from __future__ import annotations

import ast
import dataclasses
import hashlib
import json
import os
import re
from typing import Callable, Dict, List, Optional, Sequence, Tuple

REPO = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "..")
)

#: directories scanned by default (repo-relative).  tests/ is deliberately
#: out of scope: its fixtures SEED violations on purpose.
DEFAULT_SCOPE = ("src/repro", "benchmarks", "tools")

BASELINE_DEFAULT = "abclint_baseline.json"

# pragma grammar: "# abclint: disable=ABC201(reason), ABC303(reason)"
_PRAGMA_RE = re.compile(r"#\s*abclint:\s*disable=(.+?)\s*$")
_PRAGMA_ITEM_RE = re.compile(r"(ABC\d{3})\s*(?:\(([^()]*)\))?")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule hit.  ``snippet`` is the stripped source line — it anchors
    the baseline fingerprint, so a finding is identified by WHAT the code
    says, not where it currently sits."""

    rule: str
    path: str  # repo-relative, '/'-separated
    line: int  # 1-based; 0 for project-level findings
    message: str
    snippet: str = ""

    def location(self) -> str:
        return f"{self.path}:{self.line}" if self.line else self.path

    def render(self) -> str:
        return f"{self.location()}: {self.rule} {self.message}"


def fingerprint(f: Finding, occurrence: int) -> str:
    """Content fingerprint: stable across line moves, distinct for repeated
    identical lines in one file (``occurrence`` = 0, 1, ... in line order)."""
    key = f"{f.path}|{f.rule}|{f.snippet}|{occurrence}"
    return hashlib.sha1(key.encode("utf-8")).hexdigest()[:16]


class Pragma:
    __slots__ = ("line", "rule", "reason", "used")

    def __init__(self, line: int, rule: str, reason: Optional[str]):
        self.line = line
        self.rule = rule
        self.reason = reason
        self.used = False


@dataclasses.dataclass
class FileContext:
    """Everything a rule may look at for one file (parsed exactly once)."""

    path: str  # repo-relative
    source: str
    lines: List[str]
    tree: ast.AST
    pragmas: List[Pragma]

    def line_text(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1].strip()
        return ""

    def finding(self, rule: str, node_or_line, message: str) -> Finding:
        line = (
            node_or_line
            if isinstance(node_or_line, int)
            else getattr(node_or_line, "lineno", 0)
        )
        return Finding(
            rule=rule,
            path=self.path,
            line=line,
            message=message,
            snippet=self.line_text(line),
        )


@dataclasses.dataclass(frozen=True)
class Pass:
    """One lint pass: a rule table + a per-file checker (and optionally a
    whole-project checker for structural rules like the kernel trio)."""

    name: str
    rules: Dict[str, str]  # rule id -> one-line description
    check_file: Optional[Callable[[FileContext], List[Finding]]] = None
    check_project: Optional[Callable[[str], List[Finding]]] = None
    scope: Optional[Callable[[str], bool]] = None  # relpath -> in scope?

    def applies(self, relpath: str) -> bool:
        return self.scope is None or self.scope(relpath)


# ---------------------------------------------------------------------------
# pragma collection
# ---------------------------------------------------------------------------


def collect_pragmas(lines: Sequence[str]) -> Tuple[List[Pragma], List[Finding]]:
    """Parse every ``# abclint: disable=...`` comment.  Malformed items
    (no recognizable RULE token) and reasonless items are ABC001 findings;
    the well-formed ones come back as ``Pragma`` objects for matching."""
    pragmas: List[Pragma] = []
    findings: List[Finding] = []
    for i, raw in enumerate(lines, start=1):
        m = _PRAGMA_RE.search(raw)
        if not m:
            continue
        body = m.group(1)
        items = list(_PRAGMA_ITEM_RE.finditer(body))
        if not items:
            findings.append(
                Finding(
                    "ABC001", "", i,
                    "malformed abclint pragma: expected disable=RULE(reason)",
                    raw.strip(),
                )
            )
            continue
        for item in items:
            rule, reason = item.group(1), item.group(2)
            if not reason or not reason.strip():
                findings.append(
                    Finding(
                        "ABC001", "", i,
                        f"pragma for {rule} has no justification — write "
                        f"disable={rule}(why this line is legitimate)",
                        raw.strip(),
                    )
                )
                continue
            pragmas.append(Pragma(i, rule, reason.strip()))
    return pragmas, findings


def _pragma_targets(p: Pragma, lines: Sequence[str]) -> Tuple[int, ...]:
    """Lines a pragma suppresses: its own line, or — when the pragma sits on
    a comment-only line — the next non-blank line below it."""
    own = lines[p.line - 1].strip()
    if own.startswith("#"):
        for j in range(p.line + 1, len(lines) + 1):
            if lines[j - 1].strip():
                return (p.line, j)
        return (p.line,)
    return (p.line,)


def apply_pragmas(ctx: FileContext, findings: List[Finding]) -> List[Finding]:
    """Drop findings covered by a matching pragma; flag unused pragmas."""
    kept: List[Finding] = []
    targets = {p: _pragma_targets(p, ctx.lines) for p in ctx.pragmas}
    for f in findings:
        suppressor = None
        for p in ctx.pragmas:
            if p.rule == f.rule and f.line in targets[p]:
                suppressor = p
                break
        if suppressor is not None:
            suppressor.used = True
        else:
            kept.append(f)
    for p in ctx.pragmas:
        if not p.used:
            kept.append(
                ctx.finding(
                    "ABC002", p.line,
                    f"pragma disable={p.rule} suppresses nothing — remove it",
                )
            )
    return kept


# ---------------------------------------------------------------------------
# baseline
# ---------------------------------------------------------------------------


class BaselineError(ValueError):
    """The baseline file itself is invalid (bad JSON / missing reasons)."""


def load_baseline(path: str) -> Dict[str, dict]:
    """Load ``{fingerprint: entry}``.  Every entry must carry a non-empty
    ``reason`` — the baseline is a ledger of AUDITED debt, not a mute list."""
    with open(path, encoding="utf-8") as fh:
        data = json.load(fh)
    if not isinstance(data, dict) or "entries" not in data:
        raise BaselineError(f"{path}: expected an object with 'entries'")
    out: Dict[str, dict] = {}
    for e in data["entries"]:
        fp = e.get("fingerprint")
        if not fp:
            raise BaselineError(f"{path}: entry without fingerprint: {e}")
        if not str(e.get("reason", "")).strip():
            raise BaselineError(
                f"{path}: entry {e.get('rule')}@{e.get('path')} ({fp}) has "
                "no justification — every suppression needs a reason"
            )
        out[fp] = e
    return out


def write_baseline(path: str, findings: List[Finding],
                   old: Optional[Dict[str, dict]] = None) -> int:
    """Write a baseline covering ``findings``.  Reasons survive for
    fingerprints already baselined; NEW entries get an empty reason, which
    ``load_baseline`` rejects — so a refreshed baseline cannot be committed
    until a human has justified every new suppression."""
    old = old or {}
    entries = []
    for f, fp in fingerprinted(findings):
        entries.append(
            {
                "fingerprint": fp,
                "rule": f.rule,
                "path": f.path,
                "snippet": f.snippet,
                "reason": old.get(fp, {}).get("reason", ""),
            }
        )
    entries.sort(key=lambda e: (e["path"], e["rule"], e["snippet"]))
    with open(path, "w", encoding="utf-8") as fh:
        json.dump({"version": 1, "entries": entries}, fh, indent=2)
        fh.write("\n")
    return len(entries)


def fingerprinted(findings: List[Finding]) -> List[Tuple[Finding, str]]:
    """Pair each finding with its occurrence-disambiguated fingerprint."""
    seen: Dict[Tuple[str, str, str], int] = {}
    out = []
    for f in sorted(findings, key=lambda f: (f.path, f.line)):
        key = (f.path, f.rule, f.snippet)
        k = seen.get(key, 0)
        seen[key] = k + 1
        out.append((f, fingerprint(f, k)))
    return out


# ---------------------------------------------------------------------------
# running
# ---------------------------------------------------------------------------


#: the linter's own source is wall-to-wall rule-pattern literals and pragma
#: grammar strings — scanning it is pure self-referential noise; its
#: correctness is owned by tests/test_abclint.py's fixtures instead
_SELF = "tools/abclint"


def _iter_py_files(root: str, scope: Sequence[str]) -> List[str]:
    files: List[str] = []
    self_abs = os.path.join(root, _SELF)
    for rel in scope:
        top = os.path.join(root, rel)
        if os.path.isfile(top) and top.endswith(".py"):
            files.append(top)
            continue
        for dirpath, dirnames, filenames in os.walk(top):
            dirnames[:] = [
                d for d in dirnames
                if not d.startswith(".") and d != "__pycache__"
            ]
            if os.path.commonpath([dirpath, self_abs]) == self_abs:
                continue
            for fn in sorted(filenames):
                if fn.endswith(".py"):
                    files.append(os.path.join(dirpath, fn))
    return sorted(set(files))


def make_context(root: str, abspath: str) -> Optional[FileContext]:
    rel = os.path.relpath(abspath, root).replace(os.sep, "/")
    with open(abspath, encoding="utf-8") as fh:
        source = fh.read()
    try:
        tree = ast.parse(source, filename=rel)
    except SyntaxError:
        return None  # unparseable files are a job for python, not abclint
    pragmas, _ = collect_pragmas(source.splitlines())
    return FileContext(
        path=rel,
        source=source,
        lines=source.splitlines(),
        tree=tree,
        pragmas=pragmas,
    )


@dataclasses.dataclass
class RunResult:
    findings: List[Finding]  # unsuppressed, unbaselined
    baselined: List[Finding]
    stale_baseline: List[dict]
    all_findings: List[Finding]  # pre-baseline (post-pragma)

    @property
    def ok(self) -> bool:
        return not self.findings and not self.stale_baseline


def run_passes(
    passes: Sequence[Pass],
    *,
    root: str = REPO,
    scope: Sequence[str] = DEFAULT_SCOPE,
) -> List[Finding]:
    """All findings after pragma filtering, before baseline matching."""
    findings: List[Finding] = []
    for abspath in _iter_py_files(root, scope):
        ctx = make_context(root, abspath)
        if ctx is None:
            continue
        # pragma syntax findings carry the file path themselves
        _, pragma_findings = collect_pragmas(ctx.lines)
        file_findings = [
            dataclasses.replace(f, path=ctx.path) for f in pragma_findings
        ]
        for p in passes:
            if p.check_file is not None and p.applies(ctx.path):
                file_findings.extend(p.check_file(ctx))
        findings.extend(apply_pragmas(ctx, file_findings))
    for p in passes:
        if p.check_project is not None:
            findings.extend(p.check_project(root))
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings


def run(
    passes: Sequence[Pass],
    *,
    root: str = REPO,
    scope: Sequence[str] = DEFAULT_SCOPE,
    baseline: Optional[Dict[str, dict]] = None,
) -> RunResult:
    all_findings = run_passes(passes, root=root, scope=scope)
    baseline = baseline or {}
    new: List[Finding] = []
    matched: List[Finding] = []
    used_fps = set()
    for f, fp in fingerprinted(all_findings):
        if fp in baseline:
            used_fps.add(fp)
            matched.append(f)
        else:
            new.append(f)
    stale = [e for fp, e in sorted(baseline.items()) if fp not in used_fps]
    new.sort(key=lambda f: (f.path, f.line, f.rule))
    return RunResult(
        findings=new,
        baselined=matched,
        stale_baseline=stale,
        all_findings=all_findings,
    )
