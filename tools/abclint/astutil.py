"""Small AST helpers shared by the abclint passes."""
from __future__ import annotations

import ast
from typing import Iterator, List, Optional, Tuple


def dotted(node: ast.AST) -> Optional[str]:
    """'a.b.c' for a Name/Attribute chain, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def call_name(node: ast.AST) -> Optional[str]:
    """Dotted callee name of a Call node ('jax.jit', 'np.asarray', ...)."""
    if isinstance(node, ast.Call):
        return dotted(node.func)
    return None


def calls_in(node: ast.AST) -> Iterator[ast.Call]:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call):
            yield sub


def contains_call_to(node: ast.AST, names: Tuple[str, ...]) -> bool:
    """True if any call inside ``node`` resolves to one of ``names``
    (matched on the full dotted path OR its last component, so both
    ``jax.jit`` and a bare ``jit`` import hit)."""
    for c in calls_in(node):
        d = call_name(c)
        if d is None:
            continue
        if d in names or d.split(".")[-1] in {n.split(".")[-1] for n in names}:
            return True
    return False


def enclosing_functions(tree: ast.AST) -> List[Tuple[ast.AST, List[ast.AST]]]:
    """(node, [enclosing FunctionDef/AsyncFunctionDef/Lambda chain]) for
    every node, outermost first.  Lets rules ask 'is this at module level?'
    and 'what function am I in?' without re-walking per query."""
    out: List[Tuple[ast.AST, List[ast.AST]]] = []

    def visit(node: ast.AST, stack: List[ast.AST]):
        out.append((node, list(stack)))
        push = isinstance(
            node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)
        )
        if push:
            stack = stack + [node]
        for child in ast.iter_child_nodes(node):
            visit(child, stack)

    visit(tree, [])
    return out


def decorator_names(fn: ast.AST) -> List[str]:
    """Dotted names of a function's decorators; a decorator that is itself a
    call (``@functools.lru_cache(maxsize=None)``) reports its callee, and a
    ``functools.partial(jax.jit, ...)``-style decorator reports the partial
    target too."""
    names: List[str] = []
    for dec in getattr(fn, "decorator_list", []):
        d = dotted(dec)
        if d:
            names.append(d)
            continue
        if isinstance(dec, ast.Call):
            d = dotted(dec.func)
            if d:
                names.append(d)
            for arg in dec.args:
                a = dotted(arg)
                if a:
                    names.append(a)
    return names


def jnp_rooted(node: ast.AST) -> bool:
    """True if the expression contains a call rooted at jnp/jax.numpy —
    the cheap static proxy for 'this produces a jax array'."""
    for c in calls_in(node):
        d = call_name(c)
        if d and (d.startswith("jnp.") or d.startswith("jax.numpy.")):
            return True
    return False
