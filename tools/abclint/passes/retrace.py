"""Pass 1 — retrace hazards (ABC1xx).

The serving stack's first invariant is COMPILE ONCE: every jitted program
lives in a module-level cache (``serve.engine.model_programs``,
``serve.cascade_server.tier_programs``) and trace counters prove zero
retrace after warmup.  The hazard class this pass freezes out is the one
that silently re-trace on every call:

ABC101  ``jax.jit`` / ``pl.pallas_call`` constructed inside a plain
        function body.  Each call builds a FRESH jitted callable whose
        cache dies with it — the per-request retrace the PR 1 program
        caches exist to eliminate.  Allowed: module level (including
        module-level decorators) and factories memoized with
        ``functools.lru_cache``/``functools.cache`` (the repo's program-
        cache idiom).

ABC102  a ``lambda`` passed to ``jax.jit``: lambdas compare by identity,
        so even a module-level cache keyed on the function object misses
        every time one is rebuilt.

ABC103  Python branching (``if``/``while``/ternary/``assert``) on an
        expression that calls into ``jnp.``/``jax.numpy.`` — under a jit
        trace that is a TracerBoolConversionError at best and a silent
        host sync + retrace fork at worst.  Static dtype predicates
        (``jnp.issubdtype``/``jnp.isdtype``) are exempt: they run on
        types, not values.

ABC104  (scope: ``src/repro/serve/``) a ``for`` loop over a draft-token
        iterable whose body dispatches ``decode_step`` — re-verifying a
        speculative draft one decode dispatch per token, which is exactly
        the per-token cost the verify pass exists to amortize.  Draft
        positions must be scored in one chunked-prefill-shaped pass
        (``TierBackend.verify_draft`` -> ``api.prefill_into_slot_logits``,
        serve/speculative.py).
"""
from __future__ import annotations

import ast
from typing import List

from tools.abclint import astutil
from tools.abclint.engine import FileContext, Finding, Pass

_JIT_NAMES = ("jax.jit", "pl.pallas_call", "pallas_call")
#: decorators that make in-function program construction compile-once:
#: memoized factories (the program-cache idiom) and module-level jit
#: decoration (the constructed pallas_call is traced once per shape by the
#: function's own jit cache)
_CACHE_DECOS = {
    "functools.lru_cache", "lru_cache", "functools.cache", "cache",
    "jax.jit", "jit",
}
_STATIC_PREDICATES = {
    "jnp.issubdtype", "jnp.isdtype", "jax.numpy.issubdtype",
    "jax.numpy.isdtype",
}

RULES = {
    "ABC101": "jax.jit/pl.pallas_call constructed inside a function "
              "(use a module-level or lru_cache'd program cache)",
    "ABC102": "lambda passed to jax.jit (identity-keyed: every rebuild is "
              "a cache miss)",
    "ABC103": "Python branch on a jnp/jax.numpy expression (tracer "
              "boolification / hidden host sync)",
    "ABC104": "per-token decode loop over draft tokens in serve/ (score "
              "the whole draft in one verify pass)",
}

_DECODE_NAMES = ("decode_step", "decode_step_paged")
_ABC104_SCOPE = "src/repro/serve/"


def _mentions_draft(expr: ast.AST) -> bool:
    """True if the loop's iterable references a draft: any Name or
    Attribute component containing 'draft' (covers ``draft``,
    ``plan.draft``, ``enumerate(draft)``, ``range(len(r.draft))``)."""
    for sub in ast.walk(expr):
        if isinstance(sub, ast.Name) and "draft" in sub.id:
            return True
        if isinstance(sub, ast.Attribute) and "draft" in sub.attr:
            return True
    return False


def _in_cached_factory(stack: List[ast.AST]) -> bool:
    for fn in stack:
        if set(astutil.decorator_names(fn)) & _CACHE_DECOS:
            return True
    return False


def _branch_hazard(test: ast.AST) -> bool:
    for call in astutil.calls_in(test):
        d = astutil.call_name(call)
        if d is None:
            continue
        if d in _STATIC_PREDICATES:
            continue
        if d.startswith("jnp.") or d.startswith("jax.numpy."):
            return True
    return False


def check_file(ctx: FileContext) -> List[Finding]:
    findings: List[Finding] = []
    for node, stack in astutil.enclosing_functions(ctx.tree):
        if isinstance(node, ast.Call):
            name = astutil.call_name(node)
            if name in _JIT_NAMES or (
                name is not None and name.split(".")[-1] == "pallas_call"
            ):
                if stack and not _in_cached_factory(stack):
                    findings.append(
                        ctx.finding(
                            "ABC101", node,
                            f"{name} constructed inside "
                            f"{getattr(stack[-1], 'name', '<lambda>')}(): "
                            "the program cache dies with the call — hoist "
                            "to module level or an lru_cache'd factory",
                        )
                    )
            if name == "jax.jit" or name == "jit":
                if node.args and isinstance(node.args[0], ast.Lambda):
                    findings.append(
                        ctx.finding(
                            "ABC102", node,
                            "lambda passed to jax.jit — name the function "
                            "(module level) so the jit cache can key on it",
                        )
                    )
        if (
            isinstance(node, ast.For)
            and ctx.path.startswith(_ABC104_SCOPE)
            and _mentions_draft(node.iter)
            and any(
                astutil.contains_call_to(stmt, _DECODE_NAMES)
                for stmt in node.body
            )
        ):
            findings.append(
                ctx.finding(
                    "ABC104", node,
                    "decode_step dispatched per draft token — score every "
                    "draft position in ONE chunked verify pass "
                    "(TierBackend.verify_draft / "
                    "api.prefill_into_slot_logits) instead",
                )
            )
        test = None
        if isinstance(node, (ast.If, ast.While, ast.IfExp, ast.Assert)):
            test = node.test
        if test is not None and _branch_hazard(test):
            findings.append(
                ctx.finding(
                    "ABC103", node,
                    "branching on a jnp expression — this forces the value "
                    "to host (and breaks under jit tracing); compute the "
                    "predicate with jnp.where or fetch explicitly",
                )
            )
    return findings


PASS = Pass(name="retrace", rules=RULES, check_file=check_file)
