"""Pass 4 — the Pallas kernel contract (ABC4xx).

Every kernel package under ``src/repro/kernels/`` serves three impls
behind one dispatcher (``ops.py``): the TPU Pallas kernel (``kernel.py``),
its interpret-mode execution, and the pure-XLA fallback, with ``ref.py``
as the parity oracle.  PR 4's flash ``q_offset`` fix is the bug class:
a dispatcher that bare-``assert``s its preconditions crashes opaque under
``python -O`` silently passes them.  This pass freezes the contract:

ABC401  a kernel package missing the ops/kernel/ref trio (project check).
ABC402  raw ``TPUCompilerParams``/``pltpu.CompilerParams`` outside the
        ``kernels/config.py`` shim — the rename across jax versions is
        exactly why the shim exists (30+ interpret failures on 0.4.37).
ABC403  ``pl.pallas_call`` without an ``interpret=`` kwarg: the kernel
        body would be TPU-only, untestable in CI.
ABC404  bare ``assert`` in a dispatcher (``ops.py``) or
        ``kernels/config.py`` — preconditions must raise typed errors
        carrying the offending shapes (``python -O`` deletes asserts).
ABC405  a function that launches ``pl.pallas_call`` without a block-
        divisibility guard (an ``assert``/``raise`` on a ``%`` test):
        BlockSpec tiling silently mis-indexes when shapes don't divide.
"""
from __future__ import annotations

import ast
import os
from typing import List

from tools.abclint import astutil
from tools.abclint.engine import FileContext, Finding, Pass

RULES = {
    "ABC401": "kernel package missing the ops.py/kernel.py/ref.py trio",
    "ABC402": "raw TPU compiler params instead of the "
              "kernels.config.tpu_compiler_params shim",
    "ABC403": "pl.pallas_call without an interpret= kwarg (kernel body "
              "untestable off-TPU)",
    "ABC404": "bare assert in a kernel dispatcher (raise a typed error "
              "carrying the offending shapes)",
    "ABC405": "pallas_call launch without a BlockSpec divisibility guard",
}

_TRIO = ("ops.py", "kernel.py", "ref.py")


def in_scope(relpath: str) -> bool:
    return relpath.startswith("src/repro/kernels/")


def _is_dispatcher(relpath: str) -> bool:
    return relpath.endswith("/ops.py") or relpath.endswith("kernels/config.py")


def _has_mod_guard(fn: ast.AST) -> bool:
    """An assert or a raise-under-if whose test involves ``%``."""
    for node in ast.walk(fn):
        if isinstance(node, ast.Assert):
            if any(isinstance(b.op, ast.Mod)
                   for b in ast.walk(node.test) if isinstance(b, ast.BinOp)):
                return True
        if isinstance(node, ast.If):
            has_mod = any(
                isinstance(b.op, ast.Mod)
                for b in ast.walk(node.test) if isinstance(b, ast.BinOp)
            )
            has_raise = any(
                isinstance(n, ast.Raise) for n in ast.walk(node)
            )
            if has_mod and has_raise:
                return True
    return False


def check_file(ctx: FileContext) -> List[Finding]:
    findings: List[Finding] = []
    is_shim = ctx.path == "src/repro/kernels/config.py"
    for node, stack in astutil.enclosing_functions(ctx.tree):
        if isinstance(node, (ast.Attribute, ast.Name)):
            d = astutil.dotted(node)
            if d and d.split(".")[-1] in (
                "TPUCompilerParams", "CompilerParams"
            ) and not is_shim:
                findings.append(
                    ctx.finding(
                        "ABC402", node,
                        f"raw {d} — use kernels.config.tpu_compiler_params "
                        "(handles the TPUCompilerParams/CompilerParams "
                        "rename across jax versions)",
                    )
                )
        if isinstance(node, ast.Call):
            d = astutil.call_name(node)
            if d is not None and d.split(".")[-1] == "pallas_call":
                kwargs = {k.arg for k in node.keywords}
                if "interpret" not in kwargs and None not in kwargs:
                    findings.append(
                        ctx.finding(
                            "ABC403", node,
                            "pl.pallas_call without interpret= — thread "
                            "kernels.config.pallas_kwargs() through so the "
                            "kernel body runs in CI",
                        )
                    )
                fn = stack[-1] if stack else None
                if fn is not None and not _has_mod_guard(fn):
                    findings.append(
                        ctx.finding(
                            "ABC405", node,
                            f"{getattr(fn, 'name', '<lambda>')}() launches "
                            "pallas_call without a block-divisibility "
                            "guard — BlockSpec tiling mis-indexes on "
                            "non-dividing shapes; raise on `dim % block`",
                        )
                    )
        if isinstance(node, ast.Assert) and _is_dispatcher(ctx.path):
            findings.append(
                ctx.finding(
                    "ABC404", node,
                    "bare assert in a dispatcher — python -O deletes it "
                    "and the failure message hides the shapes; raise "
                    "ValueError carrying the offending values",
                )
            )
    return findings


def check_project(root: str) -> List[Finding]:
    findings: List[Finding] = []
    kroot = os.path.join(root, "src", "repro", "kernels")
    if not os.path.isdir(kroot):
        return findings
    for name in sorted(os.listdir(kroot)):
        pkg = os.path.join(kroot, name)
        if not os.path.isdir(pkg) or name == "__pycache__":
            continue
        missing = [f for f in _TRIO if not os.path.isfile(os.path.join(pkg, f))]
        if missing:
            findings.append(
                Finding(
                    "ABC401",
                    f"src/repro/kernels/{name}",
                    0,
                    f"kernel package missing {', '.join(missing)} — every "
                    "kernel ships the dispatcher/kernel/reference trio "
                    "(DESIGN.md §4)",
                    snippet=name,
                )
            )
    return findings


PASS = Pass(
    name="kernel_contract",
    rules=RULES,
    check_file=check_file,
    check_project=check_project,
    scope=in_scope,
)
