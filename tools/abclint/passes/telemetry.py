"""Pass 6 — telemetry discipline (ABC6xx).

DESIGN.md §11 centralizes serving observability in ``repro.obs``: metrics
live in a ``MetricsRegistry`` behind read-only ``StatsView`` facades, and
every serve-side timestamp goes through the injectable ``obs.clock``.  Two
regressions would silently unwind that unification, and both are purely
syntactic — so they are linted, not reviewed:

ABC601  a raw ``time.perf_counter()`` CALL in ``serve/``.  Components must
        hold the injectable clock (``self._clock = obs.clock`` — an
        attribute assignment, which this rule ignores) and call through it,
        so tests can drive deterministic timestamps and traces.
        ``time.time`` is already ABC303's business (determinism), and
        ``time.monotonic``/``time.sleep`` are exempt here: they are the
        transport token bucket's LINK PHYSICS (real wire occupancy), not
        telemetry timestamps.

ABC602  mutating a stats dict in place (``...stats["k"] += v`` or
        ``...stats["k"] = v`` where the subscripted base is named
        ``stats``/``_stats``/``last_stream_stats``).  The registry is the
        single source of truth; legacy ``stats`` surfaces are read-only
        ``StatsView``s over it.  A new ad-hoc accumulator belongs in a
        ``Counter``/``Gauge``/``Histogram`` on the component's scope.

Scope: ``src/repro/serve/`` — ``repro.obs`` itself lives outside it, so
the one place allowed to touch clocks and raw metric state is structurally
out of scope.
"""
from __future__ import annotations

import ast
from typing import List

from tools.abclint import astutil
from tools.abclint.engine import FileContext, Finding, Pass

RULES = {
    "ABC601": "raw wall-clock call in serve/ (hold obs.clock and call "
              "through it — injectable time, DESIGN.md §11)",
    "ABC602": "in-place stats-dict mutation in serve/ (stats views are "
              "read-only; record into a registry metric instead)",
}

#: wall-clock calls that must go through the injectable obs.clock
_CLOCK_CALLS = ("time.perf_counter",)
#: subscripted base names that mark a legacy stats surface
_STATS_NAMES = ("stats", "_stats", "last_stream_stats")


def in_scope(relpath: str) -> bool:
    return relpath.startswith("src/repro/serve/")


def _stats_subscript(node: ast.AST) -> bool:
    """``<base>.stats[...]`` / ``stats[...]`` with a stats-ish base name."""
    if not isinstance(node, ast.Subscript):
        return False
    base = node.value
    if isinstance(base, ast.Attribute):
        return base.attr in _STATS_NAMES
    if isinstance(base, ast.Name):
        return base.id in _STATS_NAMES
    return False


def check_file(ctx: FileContext) -> List[Finding]:
    findings: List[Finding] = []
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Call):
            d = astutil.call_name(node)
            if d is not None and (
                d in _CLOCK_CALLS
                or d.split(".")[-1] in ("perf_counter",)
            ):
                findings.append(
                    ctx.finding(
                        "ABC601", node,
                        f"{d}() bypasses the injectable clock — hold "
                        "``self._clock = obs.clock`` and call through it",
                    )
                )
        elif isinstance(node, (ast.Assign, ast.AugAssign)):
            targets = (
                node.targets if isinstance(node, ast.Assign) else [node.target]
            )
            for t in targets:
                if _stats_subscript(t):
                    findings.append(
                        ctx.finding(
                            "ABC602", node,
                            "stats dicts are read-only StatsViews over the "
                            "registry — add a Counter/Gauge/Histogram to "
                            "the component's obs scope instead",
                        )
                    )
    return findings


PASS = Pass(
    name="telemetry", rules=RULES, check_file=check_file, scope=in_scope
)
