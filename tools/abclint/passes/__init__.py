"""The six abclint passes (DESIGN.md §9).  ``ALL_PASSES`` is the
registry the CLI and the tests run; adding a rule means adding it to a
pass module's ``RULES`` table and its checker, nothing else."""
from __future__ import annotations

from tools.abclint.passes import (
    determinism,
    host_sync,
    kernel_contract,
    memory,
    retrace,
    telemetry,
)

ALL_PASSES = (
    retrace.PASS,
    host_sync.PASS,
    determinism.PASS,
    kernel_contract.PASS,
    memory.PASS,
    telemetry.PASS,
)

#: every known rule id -> description (including the engine's pragma rules)
ALL_RULES = {
    "ABC001": "abclint pragma without a justification",
    "ABC002": "abclint pragma that suppresses nothing",
}
for _p in ALL_PASSES:
    ALL_RULES.update(_p.rules)
