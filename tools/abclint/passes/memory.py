"""Pass 5 — serving memory (ABC5xx).

The serving memory wall: a dense ``(E, n_slots, S, ...)`` slot cache pays
every tier member full-length HBM for every slot, so max concurrency is
bound by the longest sequence ever admitted.  Block-paged pools
(serve/paging.py) are the fix — HBM scales with pages actually mapped, and
shared prompt prefixes are an E-fold saving.  This pass keeps dense
slot-cache allocations from creeping back into the serving layer outside
the one sanctioned place: the ``paged=False`` parity-oracle branches,
which carry a reasoned pragma.

Scope: ``src/repro/serve/`` — the layer that owns slot memory.  Model and
kernel code constructs caches for batch generation, which is not slot
memory.

ABC501  ``init_cache`` call in the serving layer — allocates a dense
        (batch, max_seq) cache per leaf.  Slot backends must allocate
        ``init_paged_pool`` instead; the dense parity oracle is the one
        exemption (pragma with the reason).
ABC502  ``jnp.zeros`` stacking a leading-axes tuple onto an existing
        leaf's ``.shape`` (the ``jnp.zeros((E,) + v.shape)`` E-fold
        dense-stack idiom) — multiplies whatever the leaf already pays by
        E.  Stacking page-bounded pool planes is fine (pragma says so);
        stacking dense slot caches is the memory wall.
"""
from __future__ import annotations

import ast
from typing import List

from tools.abclint import astutil
from tools.abclint.engine import FileContext, Finding, Pass

RULES = {
    "ABC501": "dense slot-cache allocation (init_cache) in the serving "
              "layer — use init_paged_pool; paged=False oracle needs a "
              "reasoned pragma",
    "ABC502": "jnp.zeros over a leading-tuple + .shape concatenation "
              "(the (E,) + v.shape dense-stack idiom) — E-fold memory; "
              "pragma the page-bounded / oracle sites",
}


def in_scope(relpath: str) -> bool:
    return relpath.startswith("src/repro/serve/")


def _is_shape_concat(node: ast.AST) -> bool:
    """A BinOp ``+`` whose operand chain joins a tuple literal with some
    expression's ``.shape`` attribute — the stack-a-leading-axis idiom."""
    if not (isinstance(node, ast.BinOp) and isinstance(node.op, ast.Add)):
        return False
    has_tuple = has_shape = False
    stack = [node]
    while stack:
        n = stack.pop()
        if isinstance(n, ast.BinOp) and isinstance(n.op, ast.Add):
            stack.extend((n.left, n.right))
        elif isinstance(n, ast.Tuple):
            has_tuple = True
        elif isinstance(n, ast.Attribute) and n.attr == "shape":
            has_shape = True
    return has_tuple and has_shape


def check_file(ctx: FileContext) -> List[Finding]:
    findings: List[Finding] = []
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        d = astutil.call_name(node)
        if d is not None and d.split(".")[-1] == "init_cache":
            findings.append(
                ctx.finding(
                    "ABC501", node,
                    f"{d}() allocates a dense (batch, max_seq) cache per "
                    "leaf in the serving layer — slot memory must come "
                    "from init_paged_pool (serve/paging.py); the "
                    "paged=False parity oracle is the pragma'd exemption",
                )
            )
        elif d in ("jnp.zeros", "jax.numpy.zeros") and node.args:
            if _is_shape_concat(node.args[0]):
                findings.append(
                    ctx.finding(
                        "ABC502", node,
                        "stacking a leading axis onto an existing leaf "
                        "((E,) + v.shape) multiplies its memory E-fold — "
                        "dense slot caches must not be E-stacked; pragma "
                        "page-bounded pool planes and the dense oracle",
                    )
                )
    return findings


PASS = Pass(
    name="memory",
    rules=RULES,
    check_file=check_file,
    scope=in_scope,
)
